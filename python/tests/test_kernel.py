"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes/dtypes/tilings of the output-stationary systolic
GEMM and asserts allclose against ref.matmul_ref.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, systolic

jax.config.update("jax_enable_x64", False)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(a, dtype=dtype)


# ---- exact-tile shapes -----------------------------------------------------

@pytest.mark.parametrize("tile", [8, 16, 32])
@pytest.mark.parametrize("fm,fn,fk", [(1, 1, 1), (2, 1, 3), (3, 2, 1), (2, 2, 2)])
def test_matmul_exact_tiles(tile, fm, fn, fk):
    m, n, k = fm * tile, fn * tile, fk * tile
    x = _rand((m, k), jnp.float32, seed=m * 7 + k)
    w = _rand((k, n), jnp.float32, seed=n * 13 + k)
    got = systolic.systolic_matmul(x, w, tile_m=tile, tile_n=tile, tile_k=tile)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---- hypothesis sweep: arbitrary shapes via padding, mixed tiles -----------

@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    k=st.integers(1, 70),
    tm=st.sampled_from([8, 16, 32]),
    tn=st.sampled_from([8, 16, 32]),
    tk=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_padded_hypothesis(m, n, k, tm, tn, tk, seed):
    x = _rand((m, k), jnp.float32, seed)
    w = _rand((k, n), jnp.float32, seed + 1)
    got = systolic.systolic_matmul_padded(
        x, w, tile_m=tm, tile_n=tn, tile_k=tk
    )
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---- dtypes ----------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = _rand((32, 32), dtype, 3)
    w = _rand((32, 32), dtype, 4)
    got = systolic.systolic_matmul(x, w, tile_m=16, tile_n=16, tile_k=16)
    want = ref.matmul_ref(x, w)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), rtol=tol, atol=tol
    )


def test_matmul_int8_accumulates_in_i32():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-4, 4, (16, 16)), jnp.int8)
    w = jnp.asarray(rng.integers(-4, 4, (16, 16)), jnp.int8)
    got = systolic.systolic_matmul(
        x, w, tile_m=8, tile_n=8, tile_k=8, out_dtype=jnp.int32
    )
    want = np.asarray(x, np.int32) @ np.asarray(w, np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


# ---- fold-count correspondence (mirrors rust dataflow::os) -----------------

@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(1, 4096),
    n=st.integers(1, 4096),
    k=st.integers(1, 4096),
    t=st.sampled_from([8, 16, 32, 64, 128]),
)
def test_fold_counts_match_analytical(m, n, k, t):
    fm, fn, fk = systolic.fold_counts(m, n, k, t, t, t)
    assert fm == -(-m // t) and fn == -(-n // t) and fk == -(-k // t)
    # fold invariants the rust property tests also assert:
    assert (fm - 1) * t < m <= fm * t
    assert (fn - 1) * t < n <= fn * t
    assert (fk - 1) * t < k <= fk * t


# ---- padding helper --------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(r=st.integers(1, 50), c=st.integers(1, 50),
       tr=st.sampled_from([8, 16]), tc=st.sampled_from([8, 16]))
def test_pad_to_tiles(r, c, tr, tc):
    a = jnp.ones((r, c))
    p = systolic.pad_to_tiles(a, tr, tc)
    assert p.shape[0] % tr == 0 and p.shape[1] % tc == 0
    assert p.shape[0] - r < tr and p.shape[1] - c < tc
    np.testing.assert_array_equal(np.asarray(p[:r, :c]), np.asarray(a))
    assert float(jnp.sum(p)) == pytest.approx(r * c)
