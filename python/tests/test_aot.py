"""AOT path: every entry lowers to parseable, entry-bearing HLO text."""

import json
import os

import pytest

from compile import aot, model


@pytest.mark.parametrize("name", sorted(model.ENTRIES))
def test_lower_entry_produces_hlo_text(name):
    text = aot.lower_entry(name)
    assert "ENTRY" in text, "HLO text must contain an ENTRY computation"
    assert "HloModule" in text
    # return_tuple=True => root is a tuple; the rust side unwraps with
    # to_tuple1().
    assert "tuple" in text.lower()


def test_main_writes_manifest(tmp_path):
    import sys
    argv = sys.argv
    sys.argv = ["aot", "--out", str(tmp_path), "--only", "systolic_gemm_8"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    files = os.listdir(tmp_path)
    assert "systolic_gemm_8.hlo.txt" in files
    assert "manifest.json" in files
    manifest = json.load(open(tmp_path / "manifest.json"))
    entry = manifest["systolic_gemm_8"]
    assert entry["args"][0]["shape"] == [8, 8]
    assert len(entry["sha256"]) == 64
