"""L2 model (conv-via-systolic-GEMM) vs lax conv oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import conv as kconv
from compile.kernels import ref


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


@pytest.mark.parametrize("h,w,c,r,s,m,stride", [
    (8, 8, 4, 3, 3, 8, 1),
    (8, 8, 4, 3, 3, 8, 2),
    (16, 16, 8, 1, 1, 16, 1),   # pointwise
    (10, 12, 3, 5, 3, 6, 1),    # non-square filter / ifmap
    (7, 7, 16, 7, 7, 4, 1),     # filter == ifmap (FC-like, Npx == 1)
])
def test_conv_matches_lax(h, w, c, r, s, m, stride):
    x = _rand((1, h, w, c), seed=h * w)
    f = _rand((r, s, c, m), seed=r * s + m)
    got = kconv.conv2d_systolic(x, f, stride, tile_m=8, tile_n=8, tile_k=8)
    want = ref.conv2d_ref(x, f, stride)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_matches_ref():
    x = _rand((2, 9, 7, 3), seed=5)
    got = kconv.im2col(x, 3, 2, stride=2)
    want = ref.im2col_ref(x, 3, 2, stride=2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    h=st.integers(4, 14), w=st.integers(4, 14),
    c=st.integers(1, 6), m=st.integers(1, 6),
    r=st.integers(1, 4), s=st.integers(1, 4),
    stride=st.integers(1, 2), seed=st.integers(0, 10_000),
)
def test_conv_hypothesis(h, w, c, m, r, s, stride, seed):
    if r > h or s > w:
        return
    x = _rand((1, h, w, c), seed)
    f = _rand((r, s, c, m), seed + 1)
    got = kconv.conv2d_systolic(x, f, stride, tile_m=8, tile_n=8, tile_k=8)
    want = ref.conv2d_ref(x, f, stride)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_model_entries_execute():
    """Every AOT entry point runs and matches its oracle."""
    for name, (fn, specs) in model.ENTRIES.items():
        args = [_rand(s.shape, seed=i) for i, s in enumerate(specs)]
        (out,) = fn(*args)
        if name.startswith("systolic_gemm"):
            want = ref.matmul_ref(*args)
        else:
            stride = 1
            want = ref.conv2d_ref(args[0], args[1], stride)
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)
