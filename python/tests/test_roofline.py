"""Structural L1 profile: VMEM budget, MXU occupancy, fold counts."""

from hypothesis import given, settings, strategies as st

from compile import roofline
from compile.kernels import systolic


def test_shipped_configs_fit_vmem():
    for e in roofline.shipped_configs():
        assert e.vmem_ok, e.row()


def test_mxu_full_at_128_tiles():
    e = roofline.KernelEstimate(1024, 1024, 1024, 128, 128, 128, 2)
    assert e.mxu_utilization == 1.0
    assert e.vmem_bytes == 2 * (2 * 128 * 128 * 2) + 128 * 128 * 4


def test_small_tiles_waste_mxu():
    e = roofline.KernelEstimate(8, 8, 8, 8, 8, 8, 4)
    assert e.mxu_utilization == (8 / 128) ** 3


def test_large_stationary_tiles_reach_compute_bound():
    # 128-tile OS streaming re-reads operands once per fold pass and is
    # memory bound even on 4096^3; growing the stationary tile to
    # 512x512 (still ~1.5 MiB of VMEM) pushes intensity past the ridge —
    # the optimization recorded in EXPERIMENTS.md §Perf L1.
    small = roofline.KernelEstimate(4096, 4096, 4096, 128, 128, 128, 2)
    big = roofline.KernelEstimate(4096, 4096, 4096, 512, 512, 128, 2)
    assert not small.compute_bound
    assert big.compute_bound and big.vmem_ok
    assert big.est_efficiency == big.mxu_utilization == 1.0


def test_tiny_gemm_is_memory_bound():
    e = roofline.KernelEstimate(128, 128, 128, 128, 128, 128, 2)
    assert not e.compute_bound
    assert e.est_efficiency < e.mxu_utilization


@settings(max_examples=50, deadline=None)
@given(
    m=st.integers(1, 4096), n=st.integers(1, 4096), k=st.integers(1, 4096),
    t=st.sampled_from([8, 32, 128]),
)
def test_grid_matches_kernel_fold_counts(m, n, k, t):
    e = roofline.KernelEstimate(m, n, k, t, t, t, 2)
    assert e.grid == systolic.fold_counts(m, n, k, t, t, t)


@settings(max_examples=30, deadline=None)
@given(t=st.sampled_from([8, 16, 32, 64, 128, 256]))
def test_utilization_and_vmem_monotone_in_tile(t):
    e = roofline.KernelEstimate(4096, 4096, 4096, t, t, t, 2)
    assert 0.0 < e.mxu_utilization <= 1.0
    if t <= 128:
        bigger = roofline.KernelEstimate(4096, 4096, 4096, 2 * t, 2 * t, 2 * t, 2)
        assert bigger.mxu_utilization >= e.mxu_utilization
        assert bigger.vmem_bytes > e.vmem_bytes
