"""L1 kernel profiling: VMEM footprint + MXU utilization estimates.

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
Pallas kernel is profiled *structurally* (DESIGN.md §7): from the
BlockSpec tiling we derive

  * the VMEM working set (two double-buffered input tiles + the
    stationary accumulator tile) against the ~16 MiB/core budget;
  * the MXU occupancy of each `jnp.dot` (the 128x128 systolic MXU pads
    every operand dim to a multiple of 128);
  * the arithmetic intensity and the roofline verdict on a TPUv3-class
    part (bf16 ~123 TFLOP/s, HBM ~900 GB/s).

`python -m compile.roofline` prints the table for the shipped tile
configurations; EXPERIMENTS.md §Perf records the output.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
TPU_V3_FLOPS = 123e12  # bf16 peak, per chip
TPU_V3_HBM_BPS = 900e9
RIDGE = TPU_V3_FLOPS / TPU_V3_HBM_BPS  # FLOP per HBM byte


def _pad(d: int) -> int:
    return math.ceil(d / MXU_DIM) * MXU_DIM


@dataclass(frozen=True)
class KernelEstimate:
    """Structural profile of one systolic_matmul tiling."""

    m: int
    n: int
    k: int
    tile_m: int
    tile_n: int
    tile_k: int
    dtype_bytes: int

    @property
    def grid(self) -> tuple[int, int, int]:
        return (
            math.ceil(self.m / self.tile_m),
            math.ceil(self.n / self.tile_n),
            math.ceil(self.k / self.tile_k),
        )

    @property
    def vmem_bytes(self) -> int:
        """Working set: double-buffered input tiles + stationary output.

        The output tile accumulates in f32 regardless of input dtype.
        """
        x = self.tile_m * self.tile_k * self.dtype_bytes
        w = self.tile_k * self.tile_n * self.dtype_bytes
        acc = self.tile_m * self.tile_n * 4
        return 2 * (x + w) + acc

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    @property
    def mxu_utilization(self) -> float:
        """Fraction of MXU lanes doing useful work per dot: every dim is
        padded to 128 by the hardware."""
        num = self.tile_m * self.tile_n * self.tile_k
        den = _pad(self.tile_m) * _pad(self.tile_n) * _pad(self.tile_k)
        return num / den

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k

    @property
    def hbm_bytes(self) -> int:
        """HBM traffic under the OS schedule: X and W stream once per
        stationary fold pass, output written once."""
        gm, gn, gk = self.grid
        x = self.m * self.k * self.dtype_bytes * gn  # X re-read per N fold
        w = self.k * self.n * self.dtype_bytes * gm  # W re-read per M fold
        o = self.m * self.n * self.dtype_bytes
        return x + w + o

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / self.hbm_bytes

    @property
    def compute_bound(self) -> bool:
        return self.arithmetic_intensity >= RIDGE

    @property
    def est_efficiency(self) -> float:
        """Roofline efficiency estimate: MXU occupancy when compute
        bound, scaled by intensity/ridge when memory bound."""
        eff = self.mxu_utilization
        if not self.compute_bound:
            eff *= self.arithmetic_intensity / RIDGE
        return eff

    def row(self) -> str:
        gm, gn, gk = self.grid
        return (
            f"{self.m}x{self.n}x{self.k} @ {self.tile_m}/{self.tile_n}/{self.tile_k}"
            f" grid=({gm},{gn},{gk}) vmem={self.vmem_bytes / 1024:.0f}KiB"
            f" mxu={self.mxu_utilization * 100:.0f}%"
            f" ai={self.arithmetic_intensity:.1f}"
            f" {'compute' if self.compute_bound else 'memory'}-bound"
            f" eff~{self.est_efficiency * 100:.0f}%"
        )


def shipped_configs() -> list[KernelEstimate]:
    """The tilings shipped as AOT artifacts + representative layers."""
    return [
        KernelEstimate(128, 128, 128, 128, 128, 128, 2),
        KernelEstimate(1024, 1024, 1024, 128, 128, 128, 2),
        KernelEstimate(4096, 4096, 4096, 128, 128, 128, 2),
        # §Perf L1 optimization: 512x512 stationary tile crosses the ridge
        KernelEstimate(4096, 4096, 4096, 512, 512, 128, 2),
        # ResNet-50 conv2 as GEMM (Npx x K x M)
        KernelEstimate(3136, 64, 576, 128, 128, 128, 2),
        # small-array artifacts (validation tiles)
        KernelEstimate(32, 32, 32, 32, 32, 32, 4),
        KernelEstimate(8, 8, 8, 8, 8, 8, 4),
    ]


def main() -> None:
    print(f"MXU {MXU_DIM}x{MXU_DIM}, VMEM {VMEM_BYTES >> 20} MiB, ridge {RIDGE:.0f} FLOP/B")
    for e in shipped_configs():
        assert e.vmem_ok, f"tiling spills VMEM: {e}"
        print(e.row())


if __name__ == "__main__":
    main()
