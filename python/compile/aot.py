"""AOT compile path: lower every model entry point to HLO *text*.

HLO text (NOT `lowered.compile()` / `.serialize()`) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Usage:  cd python && python -m compile.aot --out ../artifacts

Python runs ONCE here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(name: str) -> str:
    fn, args = model.ENTRIES[name]
    return to_hlo_text(jax.jit(fn).lower(*args))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument("--only", nargs="*", help="subset of entry names")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {}
    names = args.only or list(model.ENTRIES)
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        fn, arg_specs = model.ENTRIES[name]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in arg_specs
            ],
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
