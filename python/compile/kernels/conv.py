"""Convolution lowering onto the systolic GEMM kernel (im2col).

A systolic array computes conv as GEMM: the ifmap is unrolled into the
(Npx x K) im2col matrix (K = R*S*C, one row per convolution window) and
the filters into (K x M). This is exactly the operand view SCALE-Sim's
dataflows stream from the SRAM edges — OS pins the (Npx x M) output, WS
pins the (K x M) filter operand, IS pins the (Npx x K) im2col operand.

The im2col here is a gather expressed with lax.dynamic slices so it fuses
into the surrounding HLO; numerics are checked against ref.im2col_ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import systolic


def im2col(ifmap: jax.Array, r: int, s: int, stride: int = 1) -> jax.Array:
    """(N,H,W,C) -> (N*Eh*Ew, R*S*C) convolution-window matrix."""
    n, h, w, c = ifmap.shape
    eh = (h - r) // stride + 1
    ew = (w - s) // stride + 1
    cols = []
    for dr in range(r):
        for ds in range(s):
            patch = ifmap[:, dr : dr + (eh - 1) * stride + 1 : stride,
                          ds : ds + (ew - 1) * stride + 1 : stride, :]
            cols.append(patch.reshape(n * eh * ew, c))
    return jnp.concatenate(cols, axis=1)


def conv2d_systolic(
    ifmap: jax.Array,
    filters: jax.Array,
    stride: int = 1,
    *,
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Conv via im2col + output-stationary systolic GEMM.

    ifmap (N,H,W,C), filters (R,S,C,M) -> (N,Eh,Ew,M), valid padding.
    """
    n, h, w, c = ifmap.shape
    r, s, c2, m = filters.shape
    assert c == c2, f"channel mismatch {c} != {c2}"
    eh = (h - r) // stride + 1
    ew = (w - s) // stride + 1

    lhs = im2col(ifmap, r, s, stride)             # (N*Eh*Ew, K)
    rhs = filters.reshape(r * s * c, m)            # (K, M)  [HWIO row-major]
    # im2col orders K as (dr, ds, c) — same as HWIO reshape. Good.
    out = systolic.systolic_matmul_padded(
        lhs, rhs, tile_m=tile_m, tile_n=tile_n, tile_k=tile_k,
        interpret=interpret,
    )
    return out.reshape(n, eh, ew, m)
