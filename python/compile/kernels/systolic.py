"""Layer-1 Pallas kernel: the systolic-array functional datapath.

SCALE-Sim *times* an output-stationary (OS) systolic array; this kernel
*computes* the same schedule. The (array_rows x array_cols) PE grid of the
simulator maps onto a (TILE_M x TILE_N) output-stationary tile held in
VMEM; the contraction dimension K is streamed tile-by-tile from HBM into
VMEM by the BlockSpec index maps — exactly the role SCALE-Sim's left/top
SRAM edges play. The pallas grid is (Fm, Fn, Fk):

    Fm = ceil(M / tile_m)   <-> SCALE-Sim OS "horizontal folds" (output px)
    Fn = ceil(N / tile_n)   <-> SCALE-Sim OS "vertical folds"   (filters)
    Fk = ceil(K / tile_k)   <-> streaming passes over the conv window

`fold_counts()` exposes that correspondence; it is asserted against the
Rust analytical model's fold counts by the test suites on both sides.

Because the output BlockSpec's index map ignores the Fk grid axis, the
same output block stays resident ("stationary") across all Fk steps and
is accumulated in place — the literal output-stationary dataflow.

Hardware adaptation (DESIGN.md §1): the paper's substrate is a systolic
ASIC, so "pinned output pixel in a PE register" becomes "pinned output
tile in VMEM", and "operands streamed from SRAM edges" becomes "K-tiles
streamed HBM->VMEM by BlockSpec". On a real TPU the inner `jnp.dot` hits
the MXU; here we lower with interpret=True so the identical HLO runs on
the CPU PJRT client that the Rust runtime embeds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fold_counts(m: int, n: int, k: int, tile_m: int, tile_n: int, tile_k: int):
    """(Fm, Fn, Fk) — must equal the Rust OS-dataflow fold counts for the
    GEMM view of a layer (Npx x K) @ (K x M) on a tile_m x tile_n array."""
    return (-(-m // tile_m), -(-n // tile_n), -(-k // tile_k))


def _matmul_kernel(x_ref, w_ref, o_ref, *, n_k: int, acc_dtype):
    """One grid step: accumulate x_tile @ w_tile into the stationary tile.

    o_ref's block is pinned across the innermost (K) grid axis: zeroed on
    the first K-step, accumulated on every step.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    prod = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=acc_dtype)
    o_ref[...] += prod.astype(o_ref.dtype)


def systolic_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 128,
    out_dtype=None,
    interpret: bool = True,
) -> jax.Array:
    """Output-stationary tiled GEMM: (M,K) @ (K,N) -> (M,N).

    Shapes must be multiples of the tile sizes (callers pad with
    `pad_to_tiles`; SCALE-Sim's residual folds similarly run at full array
    width with idle PEs — zero padding is the numerical equivalent).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} != {k2}"
    assert m % tile_m == 0 and n % tile_n == 0 and k % tile_k == 0, (
        f"shapes ({m},{k})@({k},{n}) not multiples of tiles "
        f"({tile_m},{tile_n},{tile_k}); pad first (see pad_to_tiles)"
    )
    out_dtype = out_dtype or jnp.result_type(x.dtype, w.dtype)
    acc_dtype = (
        jnp.float32 if jnp.issubdtype(out_dtype, jnp.floating) else jnp.int32
    )
    fm, fn, fk = fold_counts(m, n, k, tile_m, tile_n, tile_k)

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=fk, acc_dtype=acc_dtype),
        grid=(fm, fn, fk),
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        # index map ignores kk -> output block is *stationary* across K.
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, w)


def pad_to_tiles(a: jax.Array, tile_r: int, tile_c: int) -> jax.Array:
    """Zero-pad a 2-D operand up to tile multiples (residual-fold padding)."""
    r, c = a.shape
    pr = (-r) % tile_r
    pc = (-c) % tile_c
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def systolic_matmul_padded(x: jax.Array, w: jax.Array, **kw) -> jax.Array:
    """GEMM for arbitrary shapes: pad to tiles, run, slice back."""
    tm = kw.get("tile_m", 128)
    tn = kw.get("tile_n", 128)
    tk = kw.get("tile_k", 128)
    m, _ = x.shape
    _, n = w.shape
    out = systolic_matmul(pad_to_tiles(x, tm, tk), pad_to_tiles(w, tk, tn), **kw)
    return out[:m, :n]
