"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is deliberately naive jax.numpy / lax — no pallas, no
tiling — so that pytest comparisons (`test_kernel.py`, `test_model.py`)
are against an independent implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """(M,K) @ (K,N) with f32 accumulation — the kernel's contract."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        jnp.result_type(x.dtype, w.dtype)
    )


def conv2d_ref(
    ifmap: jax.Array, filters: jax.Array, stride: int = 1
) -> jax.Array:
    """Valid-padding 2-D convolution, NHWC x HWIO -> NHWC.

    ifmap:   (N, H, W, C)
    filters: (R, S, C, M)
    """
    return lax.conv_general_dilated(
        ifmap.astype(jnp.float32),
        filters.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(ifmap.dtype)


def im2col_ref(ifmap: jax.Array, r: int, s: int, stride: int = 1) -> jax.Array:
    """Reference im2col: (N,H,W,C) -> (N*Eh*Ew, R*S*C).

    Row i is the flattened convolution window that produces output pixel i
    — the paper's "convolution window" (§III-B, Input Stationary).
    """
    n, h, w, c = ifmap.shape
    eh = (h - r) // stride + 1
    ew = (w - s) // stride + 1
    cols = []
    for dr in range(r):
        for ds in range(s):
            patch = ifmap[:, dr : dr + (eh - 1) * stride + 1 : stride,
                          ds : ds + (ew - 1) * stride + 1 : stride, :]
            cols.append(patch.reshape(n * eh * ew, c))
    return jnp.concatenate(cols, axis=1)
