"""Layer-2: the JAX compute graph lowered to AOT artifacts.

The Rust coordinator (L3) times layers with the cycle model; this module
defines the *functional* computations the timing model claims to schedule:

  * `gemm(size)`        — a square output-stationary systolic GEMM, tiled
                          at the simulated array size. AOT'd at 8/32/128
                          so Fig-4-style validation and the e2e example can
                          execute real numerics through PJRT.
  * `conv3x3`, `conv1x1` — representative conv layers (ResNet-50 body /
                          pointwise shapes) via im2col + the L1 kernel.

Everything calls the Layer-1 Pallas kernel (`kernels.systolic`), so the
AOT artifacts contain the kernel's HLO — Python is never needed at
runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import conv as kconv
from .kernels import systolic


def gemm(x: jax.Array, w: jax.Array, *, tile: int = 128) -> tuple[jax.Array]:
    """Square systolic GEMM with array-sized tiles; 1-tuple for AOT."""
    return (
        systolic.systolic_matmul(
            x, w, tile_m=tile, tile_n=tile, tile_k=tile, interpret=True
        ),
    )


def conv2d(ifmap: jax.Array, filters: jax.Array, stride: int = 1,
           *, tile: int = 128) -> tuple[jax.Array]:
    """Conv layer via the systolic kernel; 1-tuple for AOT."""
    return (
        kconv.conv2d_systolic(
            ifmap, filters, stride,
            tile_m=tile, tile_n=tile, tile_k=tile, interpret=True,
        ),
    )


# ---- AOT entry points ------------------------------------------------------
# name -> (fn, example arg shapes/dtypes). aot.py lowers each to
# artifacts/<name>.hlo.txt; rust/src/runtime/ loads them by the same name.

def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


ENTRIES = {
    # Array-sized square GEMMs: the systolic array's native op (Fig 4).
    "systolic_gemm_8": (functools.partial(gemm, tile=8), (_f32(8, 8), _f32(8, 8))),
    "systolic_gemm_32": (functools.partial(gemm, tile=32), (_f32(32, 32), _f32(32, 32))),
    "systolic_gemm_128": (functools.partial(gemm, tile=128), (_f32(128, 128), _f32(128, 128))),
    # ResNet-50-body-shaped conv (small spatial extent to keep the
    # interpret-mode artifact fast on CPU) and a pointwise conv.
    "conv_3x3": (
        functools.partial(conv2d, stride=1, tile=32),
        (_f32(1, 16, 16, 32), _f32(3, 3, 32, 32)),
    ),
    "conv_1x1": (
        functools.partial(conv2d, stride=1, tile=32),
        (_f32(1, 16, 16, 64), _f32(1, 1, 64, 32)),
    ),
}
