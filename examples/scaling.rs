//! Scaling-up vs scaling-out (§IV-E, Figs 9/10): sweep the PE budget
//! 64 -> 16384 for one workload under all three dataflows and report the
//! runtime ratio and the weight-DRAM-bandwidth ratio, plus the banked
//! DRAM substrate's view of the resulting traffic (the §III-D system
//! hand-off the paper delegates to DRAMSim2).
//!
//! Run: `cargo run --release --example scaling [workload]`

use scale_sim::config::{self, workloads};
use scale_sim::dataflow::Dataflow;
use scale_sim::dram::{burst_stream, Dram, DramConfig};
use scale_sim::engine::Engine;
use scale_sim::memory;
use scale_sim::scaleout::PE_SWEEP;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "alphagozero".into());
    let topo = workloads::builtin(&name)
        .unwrap_or_else(|| panic!("unknown workload {name:?}"));
    let base = config::paper_default();

    println!("== scale-up vs scale-out ({name}) ==");
    println!(
        "{:>4} {:>7} {:>14} {:>14} {:>10} {:>12}",
        "df", "PEs", "up_cycles", "out_cycles", "up/out", "wbw up/out"
    );
    for df in Dataflow::ALL {
        let engine = Engine::builder().config(base.clone()).dataflow(df).build().unwrap();
        for &pe in &PE_SWEEP {
            let c = engine.compare_scaling(&topo.layers, pe);
            println!(
                "{:>4} {:>7} {:>14} {:>14} {:>10.3} {:>12.3}",
                df.name(),
                pe,
                c.up_cycles,
                c.out_cycles,
                c.runtime_ratio(),
                c.weight_bw_ratio()
            );
        }
    }

    // --- feed the scale-up DRAM traffic into the banked DRAM substrate ----
    println!("\n== DRAM substrate replay (128x128, os, layer 0) ==");
    let cfg = base.clone();
    let layer = &topo.layers[0];
    let (traffic, bw) = memory::simulate(cfg.dataflow, layer, &cfg);
    let cycles = cfg.dataflow.timing(layer, cfg.array_h, cfg.array_w).cycles;
    let dcfg = DramConfig::default();
    let reqs = burst_stream(&dcfg, 0, traffic.read_bytes(), (0, cycles), false);
    let stats = Dram::new(dcfg).replay(reqs);
    println!("layer {:<14} stall-free need {:.3} B/cyc (peak {:.3})", layer.name, bw.avg_read_bw, bw.peak_read_bw);
    println!(
        "substrate: {:.3} B/cyc achieved, {:.1}% row hits, avg latency {:.1} cyc, max {} cyc",
        stats.achieved_bw(),
        stats.hit_rate() * 100.0,
        stats.avg_latency(),
        stats.max_latency
    );
    if stats.achieved_bw() >= bw.avg_read_bw {
        println!("verdict: interface sustains the stall-free requirement");
    } else {
        println!("verdict: interface WOULD STALL the array (provision more banks/prefetch)");
    }
}
