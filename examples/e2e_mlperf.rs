//! End-to-end driver — proves all three layers compose:
//!
//! 1. **L1/L2 artifacts through PJRT (functional)**: load the AOT-compiled
//!    Pallas systolic-GEMM HLO, execute a real conv layer tile-by-tile in
//!    the exact OS fold order the simulator times, and check numerics
//!    against an independent Rust conv reference. Also executes the AOT
//!    conv artifact directly.
//! 2. **RTL cross-check (timing + numerics)**: run the cycle-level PE
//!    grid on an array-sized matmul; cycles must equal the analytical
//!    model (Fig 4) and the product must match the PJRT artifact's.
//! 3. **L3 simulator on the full MLPerf suite (Table III)**: simulate all
//!    seven workloads on the paper-default architecture and report the
//!    headline metrics (cycles, utilization, DRAM bandwidth, energy).
//!
//! Requires `make artifacts` (run once; Python never executes here).
//!
//! Run: `cargo run --release --example e2e_mlperf`

use scale_sim::config::{self, workloads};
use scale_sim::dataflow::Dataflow;
use scale_sim::engine::Engine;
use scale_sim::runtime::{default_artifact_dir, Runtime};
use scale_sim::util::rng::Rng;
use scale_sim::{rtl, LayerShape};

type ExampleResult<T> = Result<T, Box<dyn std::error::Error>>;

fn ensure(cond: bool, msg: &str) -> ExampleResult<()> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string().into())
    }
}

fn max_rel_err(got: &[f32], want: &[f32]) -> f32 {
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
        .fold(0.0, f32::max)
}

/// Independent Rust conv reference (NHWC x HWIO, valid padding).
#[allow(clippy::too_many_arguments)]
fn conv_ref(
    x: &[f32], h: usize, w: usize, c: usize,
    f: &[f32], r: usize, s: usize, m: usize,
    stride: usize,
) -> Vec<f32> {
    let eh = (h - r) / stride + 1;
    let ew = (w - s) / stride + 1;
    let mut out = vec![0f32; eh * ew * m];
    for oy in 0..eh {
        for ox in 0..ew {
            for dm in 0..m {
                let mut acc = 0f32;
                for dr in 0..r {
                    for ds in 0..s {
                        for ch in 0..c {
                            let xv = x[((oy * stride + dr) * w + ox * stride + ds) * c + ch];
                            let fv = f[((dr * s + ds) * c + ch) * m + dm];
                            acc += xv * fv;
                        }
                    }
                }
                out[(oy * ew + ox) * m + dm] = acc;
            }
        }
    }
    out
}

/// im2col matching python/compile/kernels/conv.py (single batch).
fn im2col(x: &[f32], h: usize, w: usize, c: usize, r: usize, s: usize, stride: usize) -> Vec<f32> {
    let eh = (h - r) / stride + 1;
    let ew = (w - s) / stride + 1;
    let k = r * s * c;
    let mut out = vec![0f32; eh * ew * k];
    for p in 0..eh * ew {
        let (oy, ox) = (p / ew, p % ew);
        for dr in 0..r {
            for ds in 0..s {
                for ch in 0..c {
                    out[p * k + (dr * s + ds) * c + ch] =
                        x[((oy * stride + dr) * w + ox * stride + ds) * c + ch];
                }
            }
        }
    }
    out
}

fn main() -> ExampleResult<()> {
    let dir = default_artifact_dir();
    println!("=== stage 1: functional validation (artifacts at {dir:?}) ===");
    let mut rt = Runtime::new(&dir)?;
    println!("runtime platform: {}", rt.platform());

    // -- 1a: conv layer through the tiled systolic GEMM (fold schedule) ----
    let (h, w, c, r, s, m, stride) = (16usize, 16, 8, 3, 3, 16, 1);
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..h * w * c).map(|_| rng.normal_f32()).collect();
    let f: Vec<f32> = (0..r * s * c * m).map(|_| rng.normal_f32()).collect();
    let (eh, ew, k) = ((h - r) / stride + 1, (w - s) / stride + 1, r * s * c);

    let lhs = im2col(&x, h, w, c, r, s, stride);
    let got = rt.tiled_gemm(32, &lhs, &f, eh * ew, k, m)?;
    let want = conv_ref(&x, h, w, c, &f, r, s, m, stride);
    let err = max_rel_err(&got, &want);
    println!(
        "conv {h}x{w}x{c} * {r}x{s}->{m} via tiled systolic GEMM (OS folds {}x{}x{}): max rel err {err:.2e}",
        (eh * ew).div_ceil(32), m.div_ceil(32), k.div_ceil(32)
    );
    ensure(err < 1e-3, "tiled GEMM mismatch")?;

    // -- 1b: the AOT conv artifact end-to-end ------------------------------
    let (ch2, m2) = (32usize, 32usize);
    let x2: Vec<f32> = (0..16 * 16 * ch2).map(|_| rng.normal_f32()).collect();
    let f2: Vec<f32> = (0..3 * 3 * ch2 * m2).map(|_| rng.normal_f32()).collect();
    let got2 = rt.conv("conv_3x3", &x2, &[1, 16, 16, ch2 as i64], &f2, &[3, 3, ch2 as i64, m2 as i64])?;
    let want2 = conv_ref(&x2, 16, 16, ch2, &f2, 3, 3, m2, 1);
    let err2 = max_rel_err(&got2, &want2);
    println!("AOT conv_3x3 artifact: max rel err {err2:.2e}");
    ensure(err2 < 1e-3, "conv artifact mismatch")?;

    // -- stage 2: RTL cross-check ------------------------------------------
    println!("\n=== stage 2: RTL PE-grid cross-check (Fig 4) ===");
    for tile in [8usize, 32] {
        let (a, b) = rtl::random_matrices(tile, tile, tile, tile as u64);
        let rtl_run = rtl::run_matmul(&a, &b, tile, tile, tile);
        let layer = LayerShape::gemm("mm", tile as u64, tile as u64, tile as u64);
        let model = Dataflow::Os.timing(&layer, tile as u64, tile as u64).cycles;
        let kernel = rt.gemm_tile(tile, &a, &b)?;
        let nerr = max_rel_err(&rtl_run.product, &kernel);
        println!(
            "{tile:>3}x{tile}: rtl {} cycles, model {} cycles (match={}), rtl-vs-kernel err {nerr:.2e}",
            rtl_run.cycles, model, rtl_run.cycles == model
        );
        ensure(rtl_run.cycles == model && nerr < 1e-3, "RTL cross-check failed")?;
    }

    // -- stage 3: full MLPerf suite ----------------------------------------
    println!("\n=== stage 3: MLPerf suite on paper-default architecture ===");
    let cfg = config::paper_default();
    println!(
        "{:<4} {:<14} {:>7} {:>14} {:>8} {:>12} {:>10}",
        "tag", "workload", "layers", "cycles", "util%", "avg_rd_bw", "energy_mJ"
    );
    let engine = Engine::builder().config(cfg.clone()).build()?;
    for (tag, name) in workloads::TAGS {
        let topo = workloads::builtin(name).unwrap();
        let rep = engine.run_topology(&topo);
        println!(
            "{:<4} {:<14} {:>7} {:>14} {:>8.2} {:>12.4} {:>10.3}",
            tag,
            name,
            rep.layers.len(),
            rep.total_cycles(),
            rep.overall_utilization(cfg.total_pes()) * 100.0,
            rep.avg_dram_read_bw(),
            rep.total_energy().total_mj()
        );
    }

    println!("\ne2e OK: artifacts execute, RTL matches the model, suite simulated.");
    Ok(())
}
