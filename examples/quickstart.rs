//! Quickstart: simulate ResNet-50 on the paper's default architecture
//! (128x128 OS, 1 MB operand scratchpad) through the `engine` façade and
//! print the summary metrics SCALE-Sim reports (§I: latency,
//! utilization, SRAM/DRAM accesses, bandwidth).
//!
//! Run: `cargo run --release --example quickstart`

use scale_sim::config::workloads;
use scale_sim::engine::Engine;

fn main() {
    let engine = Engine::builder().build().expect("default config is valid");
    let topo = workloads::builtin("resnet50").expect("built-in workload");
    let cfg = engine.cfg().clone();

    println!(
        "SCALE-Sim quickstart: {} on {}x{} {} array, {}+{} KB scratchpad ({} backend)",
        topo.name,
        cfg.array_h,
        cfg.array_w,
        cfg.dataflow,
        cfg.ifmap_sram_kb,
        cfg.filter_sram_kb,
        engine.backend_kind()
    );
    println!(
        "{:<16} {:>12} {:>8} {:>10} {:>12} {:>10}",
        "layer", "cycles", "util%", "remaps", "dram_bytes", "energy_mJ"
    );

    let report = engine.run_topology(&topo);
    for l in report.layers.iter().take(8) {
        println!(
            "{:<16} {:>12} {:>8.2} {:>10} {:>12} {:>10.4}",
            l.name(),
            l.timing.cycles,
            l.timing.utilization * 100.0,
            l.timing.remaps(),
            l.dram.total(),
            l.energy.total_mj()
        );
    }
    println!("... ({} layers total)", report.layers.len());
    println!();
    println!("total cycles:        {}", report.total_cycles());
    println!("total MACs:          {}", report.total_macs());
    println!(
        "overall utilization: {:.2}%",
        report.overall_utilization(cfg.total_pes()) * 100.0
    );
    println!("avg DRAM read bw:    {:.4} bytes/cycle", report.avg_dram_read_bw());
    let e = report.total_energy();
    println!(
        "energy:              {:.3} mJ (compute {:.3} / sram {:.3} / dram {:.3})",
        e.total_mj(),
        e.compute_mj,
        e.sram_mj,
        e.dram_mj
    );
    let stats = engine.cache_stats();
    println!(
        "memo cache:          {} layer sims, {} hits ({:.0}% — repeated bottleneck shapes)",
        stats.layer_sims,
        stats.cache_hits,
        stats.hit_rate() * 100.0
    );
}
