//! Design-space exploration (§IV-B/C/D condensed): for one workload,
//! sweep dataflow x array size, scratchpad size, and aspect ratio
//! through ONE memoizing engine, and print the winner of each axis —
//! the co-design loop the paper argues an architect should run before
//! freezing an accelerator. The three sweeps share layer simulations
//! wherever their grids overlap (the engine cache persists across
//! `sweep()` calls).
//!
//! Run: `cargo run --release --example design_space [workload]`

use scale_sim::config::workloads;
use scale_sim::engine::Engine;
use scale_sim::sweep::fig8_shapes;
use scale_sim::Dataflow;

fn main() {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "alphagozero".into());
    let topo = workloads::builtin(&arg)
        .unwrap_or_else(|| panic!("unknown workload {arg:?} (try: scale-sim workloads)"));
    // builtin() accepts aliases ("W1"); sweep points carry the resolved name
    let name = topo.name.clone();
    let engine = Engine::builder().build().unwrap();

    // --- axis 1: dataflow x square array (Fig 5 slice) --------------------
    println!("== dataflow x array size ({name}) ==");
    println!("{:>8} {:>12} {:>12} {:>12}   winner", "array", "os", "ws", "is");
    let axis1 = engine
        .sweep()
        .workload(&topo)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&[128, 64, 32, 16, 8])
        .run();
    for &n in &[128u64, 64, 32, 16, 8] {
        let cyc: Vec<u64> = Dataflow::ALL
            .iter()
            .map(|&df| axis1.find(&name, df, n, n).unwrap().report.total_cycles())
            .collect();
        let best = Dataflow::ALL[cyc.iter().enumerate().min_by_key(|(_, c)| **c).unwrap().0];
        println!("{:>8} {:>12} {:>12} {:>12}   {best}", format!("{n}x{n}"), cyc[0], cyc[1], cyc[2]);
    }

    // --- axis 2: scratchpad size (Fig 7 slice) -----------------------------
    println!("\n== scratchpad size vs DRAM bandwidth ==");
    println!("{:>8} {:>14} {:>12}", "sram_kb", "dram_bytes", "avg_rd_bw");
    let sizes = [32u64, 64, 128, 256, 512, 1024, 2048];
    let axis2 = engine.sweep().workload(&topo).sram_sizes_kb(&sizes).run();
    let mut last_bw = f64::MAX;
    let mut knee = None;
    for p in &axis2.points {
        let bw = p.report.avg_dram_read_bw();
        println!("{:>8} {:>14} {:>12.4}", p.ifmap_sram_kb, p.report.total_dram().total(), bw);
        if knee.is_none() && last_bw / bw < 1.05 {
            knee = Some(p.ifmap_sram_kb / 2);
        }
        last_bw = bw;
    }
    if let Some(kb) = knee {
        println!("knee of the curve: ~{kb} KB (diminishing returns beyond, §IV-C)");
    }

    // --- axis 3: aspect ratio at fixed 16384 PEs (Fig 8 slice) ------------
    println!("\n== aspect ratio (16384 PEs) ==");
    println!("{:>10} {:>12} {:>12} {:>12}", "shape", "os", "ws", "is");
    let shapes = fig8_shapes();
    let axis3 = engine
        .sweep()
        .workload(&topo)
        .dataflows(&Dataflow::ALL)
        .array_shapes(&shapes)
        .run();
    let mut best: Option<(u64, u64, Dataflow, u64)> = None;
    for &(r, c) in &shapes {
        let mut row = Vec::new();
        for df in Dataflow::ALL {
            let cycles = axis3.find(&name, df, r, c).unwrap().report.total_cycles();
            if best.is_none() || cycles < best.unwrap().3 {
                best = Some((r, c, df, cycles));
            }
            row.push(cycles);
        }
        println!("{:>10} {:>12} {:>12} {:>12}", format!("{r}x{c}"), row[0], row[1], row[2]);
    }
    let (r, c, df, cycles) = best.unwrap();
    println!("\nbest point: {r}x{c} under {df} ({cycles} cycles)");

    let stats = engine.cache_stats();
    println!(
        "engine memo: {} layer sims for {} lookups across all three axes ({:.0}% hit rate)",
        stats.layer_sims,
        stats.lookups(),
        stats.hit_rate() * 100.0
    );
}
