//! Design-space exploration (§IV-B/C/D condensed): for one workload,
//! sweep dataflow x array size, scratchpad size, and aspect ratio, and
//! print the winner of each axis — the co-design loop the paper argues
//! an architect should run before freezing an accelerator.
//!
//! Run: `cargo run --release --example design_space [workload]`

use scale_sim::config::{self, workloads, ArchConfig};
use scale_sim::dataflow::Dataflow;
use scale_sim::sim::Simulator;
use scale_sim::sweep;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "alphagozero".into());
    let topo = workloads::builtin(&name)
        .unwrap_or_else(|| panic!("unknown workload {name:?} (try: scale-sim workloads)"));
    let base = config::paper_default();

    // --- axis 1: dataflow x square array (Fig 5 slice) --------------------
    println!("== dataflow x array size ({name}) ==");
    println!("{:>8} {:>12} {:>12} {:>12}   winner", "array", "os", "ws", "is");
    for &n in &[128u64, 64, 32, 16, 8] {
        let mut cyc = Vec::new();
        for df in Dataflow::ALL {
            let cfg = ArchConfig { array_h: n, array_w: n, dataflow: df, ..base.clone() };
            cyc.push(Simulator::new(cfg).run_topology(&topo).total_cycles());
        }
        let best = Dataflow::ALL[cyc.iter().enumerate().min_by_key(|(_, c)| **c).unwrap().0];
        println!("{:>8} {:>12} {:>12} {:>12}   {best}", format!("{n}x{n}"), cyc[0], cyc[1], cyc[2]);
    }

    // --- axis 2: scratchpad size (Fig 7 slice) -----------------------------
    println!("\n== scratchpad size vs DRAM bandwidth ==");
    println!("{:>8} {:>14} {:>12}", "sram_kb", "dram_bytes", "avg_rd_bw");
    let mut last_bw = f64::MAX;
    let mut knee = None;
    for &kb in &[32u64, 64, 128, 256, 512, 1024, 2048] {
        let cfg = ArchConfig { ifmap_sram_kb: kb, filter_sram_kb: kb, ..base.clone() };
        let r = Simulator::new(cfg).run_topology(&topo);
        let bw = r.avg_dram_read_bw();
        println!("{:>8} {:>14} {:>12.4}", kb, r.total_dram().total(), bw);
        if knee.is_none() && last_bw / bw < 1.05 {
            knee = Some(kb / 2);
        }
        last_bw = bw;
    }
    if let Some(kb) = knee {
        println!("knee of the curve: ~{kb} KB (diminishing returns beyond, §IV-C)");
    }

    // --- axis 3: aspect ratio at fixed 16384 PEs (Fig 8 slice) ------------
    println!("\n== aspect ratio (16384 PEs) ==");
    println!("{:>10} {:>12} {:>12} {:>12}", "shape", "os", "ws", "is");
    let mut best: Option<(u64, u64, Dataflow, u64)> = None;
    for (r, c) in sweep::fig8_shapes() {
        let mut row = Vec::new();
        for df in Dataflow::ALL {
            let cfg = ArchConfig { array_h: r, array_w: c, dataflow: df, ..base.clone() };
            let cycles = Simulator::new(cfg).run_topology(&topo).total_cycles();
            if best.is_none() || cycles < best.unwrap().3 {
                best = Some((r, c, df, cycles));
            }
            row.push(cycles);
        }
        println!("{:>10} {:>12} {:>12} {:>12}", format!("{r}x{c}"), row[0], row[1], row[2]);
    }
    let (r, c, df, cycles) = best.unwrap();
    println!("\nbest point: {r}x{c} under {df} ({cycles} cycles)");
}
