//! System-interface study (§III-D): what happens when the accelerator
//! meets a *real* memory system instead of the stall-free abstraction.
//!
//! 1. Sweep a finite DRAM read bandwidth and report the stall-model
//!    runtime per layer — where does the 128x128 array starve?
//! 2. Provision: the minimum bandwidth for <5% slowdown per workload.
//! 3. Hand the generated DRAM trace to the banked row-buffer substrate
//!    (the in-repo DRAMSim2 stand-in) and compare achieved bandwidth
//!    against the requirement.
//!
//! Run: `cargo run --release --example system_interface [workload]`

use scale_sim::config::{self, workloads};
use scale_sim::dram::{replay_layer, DramConfig};
use scale_sim::engine::Engine;
use scale_sim::memory::stall::{provision_bandwidth, stalled_runtime};

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet50".into());
    let topo = workloads::builtin(&name)
        .unwrap_or_else(|| panic!("unknown workload {name:?}"));
    let cfg = config::paper_default();
    let df = cfg.dataflow;

    // --- 1: bandwidth sweep -------------------------------------------------
    let caps = [256.0, 128.0, 64.0, 32.0, 16.0, 8.0];
    println!("== stall-model slowdown vs DRAM read bandwidth ({name}, {df}) ==");
    print!("{:<16}", "layer");
    for c in caps {
        print!(" {c:>7.0}B/c");
    }
    println!();
    for layer in topo.layers.iter().take(10) {
        print!("{:<16}", layer.name);
        for c in caps {
            let r = stalled_runtime(df, layer, &cfg, c);
            print!(" {:>9.2}", r.slowdown());
        }
        println!();
    }
    if topo.layers.len() > 10 {
        println!("... ({} layers)", topo.layers.len());
    }

    // --- 2: provisioning ------------------------------------------------------
    println!("\n== provisioned bandwidth for <5% slowdown ==");
    let mut worst: (f64, &str) = (0.0, "");
    for layer in &topo.layers {
        let bw = provision_bandwidth(df, layer, &cfg, 0.05);
        if bw > worst.0 {
            worst = (bw, &layer.name);
        }
    }
    println!("workload {name}: provision {:.1} bytes/cycle (bound by layer {})", worst.0, worst.1);

    // --- 3: banked DRAM replay -------------------------------------------------
    println!("\n== banked-DRAM substrate replay (per layer) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>9} {:>12} {:>10}",
        "layer", "need_B/c", "achv_B/c", "hit%", "avg_lat", "verdict"
    );
    let engine = Engine::builder().config(cfg.clone()).build().unwrap();
    for layer in topo.layers.iter().take(10) {
        let rep = engine.run_layer(layer);
        let stats = replay_layer(df, layer, &cfg, DramConfig::default());
        let ok = stats.achieved_bw() >= rep.bandwidth.avg_read_bw;
        println!(
            "{:<16} {:>10.2} {:>10.2} {:>9.1} {:>12.1} {:>10}",
            layer.name,
            rep.bandwidth.avg_read_bw,
            stats.achieved_bw(),
            stats.hit_rate() * 100.0,
            stats.avg_latency(),
            if ok { "ok" } else { "STALLS" }
        );
    }
}
