//! Observability suite: the two-timeline contract, end to end.
//!
//! Simulated time — the span trees built by [`scale_sim::obs::trace`]
//! must tile the engine's reports *exactly*: every per-layer span total
//! equals that layer's `timing.cycles`, across dataflows, array shapes,
//! and workloads (the `scale-sim profile` acceptance identity). Host
//! time — the metrics registry's Prometheus exposition must be
//! deterministic for the deterministic class, and the server's
//! `metrics` surface must cover the promised cache/queue/worker series.

use scale_sim::config::{workloads, ArchConfig};
use scale_sim::engine::{Engine, MultiArrayConfig, Partition};
use scale_sim::obs::metrics::{self, Registry};
use scale_sim::obs::trace;
use scale_sim::util::json::Json;
use scale_sim::Dataflow;

/// Per-layer span totals == LayerReport cycles, for every dataflow and
/// several array shapes, over a conv net (alexnet), an MLPerf net, and
/// a GEMM workload — the `scale-sim profile` acceptance identity.
#[test]
fn span_totals_equal_report_cycles_exactly() {
    let topos = vec![
        workloads::builtin("alexnet").unwrap(),
        workloads::builtin("ncf").unwrap(),
        workloads::builtin_workload("mlp").unwrap().lower().unwrap(),
    ];
    for topo in &topos {
        for df in Dataflow::ALL {
            for &(h, w) in &[(8u64, 8u64), (32, 32), (16, 64)] {
                let cfg = ArchConfig {
                    dataflow: df,
                    array_h: h,
                    array_w: w,
                    ..ArchConfig::default()
                };
                let engine = Engine::new(cfg.clone());
                let report = engine.run_topology(topo);
                let t = trace::workload_trace(df, h, w, &report, None);

                // one layer span per report layer, dur == cycles, laid
                // back-to-back from cycle 0
                let layer_spans: Vec<_> =
                    t.spans.iter().filter(|s| s.cat == "layer").collect();
                assert_eq!(layer_spans.len(), report.layers.len());
                let mut cursor = 0u64;
                for (span, l) in layer_spans.iter().zip(&report.layers) {
                    assert_eq!(span.name, l.name(), "{} {df} {h}x{w}", topo.name);
                    assert_eq!(span.ts, cursor, "{} {df} {h}x{w}", topo.name);
                    assert_eq!(
                        span.dur, l.timing.cycles,
                        "layer span total must equal LayerReport cycles \
                         ({} {} {df} {h}x{w})",
                        topo.name,
                        l.name()
                    );
                    cursor += l.timing.cycles;
                }
                // the phase children tile each layer exactly, so their
                // grand total is the workload's total cycles
                assert_eq!(t.category_total("phase"), report.total_cycles());
                assert_eq!(t.category_total("layer"), report.total_cycles());
                assert_eq!(t.category_total("fold"), report.total_cycles());

                // the aggregate closed form agrees layer by layer
                for l in &report.layers {
                    let p = trace::phase_totals(df, h, w, &l.layer);
                    assert_eq!(p.total(), l.timing.cycles, "{}", l.name());
                }
            }
        }
    }
}

/// Stall spans extend the timeline without disturbing compute spans.
#[test]
fn stall_spans_append_after_compute() {
    let topo = workloads::builtin("ncf").unwrap();
    let cfg = ArchConfig::default();
    let engine = Engine::new(cfg.clone());
    let report = engine.run_topology(&topo);
    let stalls: Vec<u64> = (0..report.layers.len() as u64).map(|i| i * 10).collect();
    let t = trace::workload_trace(cfg.dataflow, cfg.array_h, cfg.array_w, &report, Some(&stalls));
    let stall_total: u64 = stalls.iter().sum();
    assert_eq!(t.category_total("stall"), stall_total);
    assert_eq!(t.category_total("phase"), report.total_cycles());
    let end = t.spans.iter().map(|s| s.ts + s.dur).max().unwrap();
    assert_eq!(end, report.total_cycles() + stall_total);
}

/// Multi-array traces put each node on its own pid track and span the
/// composed system's exact cycle count (stalls included).
#[test]
fn multi_trace_tracks_nodes_and_totals() {
    let topo = workloads::builtin("ncf").unwrap();
    let cfg = ArchConfig { array_h: 16, array_w: 16, ..ArchConfig::default() };
    let engine = Engine::new(cfg.clone());
    let mc = MultiArrayConfig::new(4, 16, 16, Partition::default());
    let m = engine.run_multi_with(&cfg, &topo, &mc, Some(10.0));
    let t = trace::multi_trace(cfg.dataflow, &m);

    let max_used = m.layers.iter().map(|l| l.used_nodes).max().unwrap();
    assert!(max_used > 1, "partitioning must engage more than one node");
    let pids: std::collections::BTreeSet<u64> = t.spans.iter().map(|s| s.pid).collect();
    assert!(pids.len() as u64 >= max_used, "one track per used node: {pids:?}");

    // layers serialize at the slowest node: the timeline ends exactly at
    // the composed runtime (compute + shared-DRAM stalls)
    let end = t.spans.iter().map(|s| s.ts + s.dur).max().unwrap();
    assert_eq!(end, m.total_cycles() + m.total_stall_cycles());

    // per-layer: one span per used node (the remainder share rides the
    // last one), full-share spans lasting exactly the node report cycles
    let mut cursor = 0u64;
    for l in &m.layers {
        let spans: Vec<_> =
            t.spans.iter().filter(|s| s.cat == "layer" && s.ts == cursor).collect();
        assert_eq!(spans.len() as u64, l.used_nodes, "{}", l.layer.name);
        for s in &spans {
            assert_eq!(s.name, l.layer.name);
            if s.pid < l.node_count {
                assert_eq!(s.dur, l.node_report.timing.cycles);
            }
        }
        cursor += l.cycles + l.stall_cycles;
    }
}

/// The Chrome trace document survives an exact util::json round trip and
/// carries the viewer-required fields on every event.
#[test]
fn trace_json_round_trips_and_is_well_formed() {
    let topo = workloads::builtin("ncf").unwrap();
    let cfg = ArchConfig::default();
    let engine = Engine::new(cfg.clone());
    let report = engine.run_topology(&topo);
    let t = trace::workload_trace(cfg.dataflow, cfg.array_h, cfg.array_w, &report, None);

    let text = t.to_json().to_string();
    let parsed = Json::parse(&text).expect("trace JSON parses");
    assert_eq!(parsed.to_string(), text, "exact round trip");

    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert_eq!(events.len(), t.spans.len() + 1, "spans + one process_name metadata event");
    for e in events {
        match e.str_field("ph") {
            Some("M") => assert_eq!(e.str_field("name"), Some("process_name")),
            Some("X") => {
                for field in ["ts", "dur", "pid", "tid"] {
                    assert!(e.u64_field(field).is_some(), "X event missing {field}: {e}");
                }
                assert!(e.str_field("cat").is_some());
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }

    // write() emits the same document plus a trailing newline
    let dir = std::env::temp_dir().join(format!("scale_sim_obs_{}", std::process::id()));
    let path = dir.join("trace.json");
    t.write(&path).unwrap();
    let disk = std::fs::read_to_string(&path).unwrap();
    assert_eq!(disk, format!("{text}\n"));
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Deterministic-class Prometheus exposition is byte-stable and ordered;
/// the wall-clock class stays out unless asked for.
#[test]
fn prometheus_exposition_is_deterministic() {
    let reg = Registry::new();
    reg.set_counter("scale_sim_cache_hits_total", "hits", 42);
    reg.set_gauge("scale_sim_queue_depth", "depth", 3.0);
    reg.observe_seconds("scale_sim_simulate_seconds{backend=\"analytical\"}", "lat", 0.001);

    let det = reg.render(false);
    assert_eq!(det, reg.render(false), "deterministic class must be byte-stable");
    assert!(!det.contains("simulate_seconds"), "histograms are wall-clock class:\n{det}");
    assert!(det.contains("# TYPE scale_sim_cache_hits_total counter"), "{det}");
    assert!(det.contains("# TYPE scale_sim_queue_depth gauge"), "{det}");
    let hits = det.find("scale_sim_cache_hits_total 42").unwrap();
    let depth = det.find("scale_sim_queue_depth 3").unwrap();
    assert!(hits < depth, "lexicographic family order:\n{det}");

    let wall = reg.render(true);
    assert!(wall.contains("scale_sim_simulate_seconds_bucket"), "{wall}");
    assert!(wall.contains("le=\"+Inf\""), "{wall}");
}

/// The server exposition covers the cache, queue, and worker series the
/// protocol promises, and is a pure function of the stats snapshot.
#[test]
fn server_exposition_covers_promised_series() {
    use scale_sim::engine::{MemoStats, WarmStats};
    use scale_sim::server::proto::ServerStats;

    let s = ServerStats {
        queue_depth: 3,
        in_flight: 5,
        completed: 40,
        failed: 1,
        submitted: 46,
        workers: 8,
        workers_busy: 2,
        cache_entries: 17,
        memo: MemoStats { layer_sims: 10, cache_hits: 30, inflight_waits: 4 },
        warm: WarmStats { entries: 6, hits: 9 },
    };
    let text = metrics::server_exposition(&s);
    assert_eq!(text, metrics::server_exposition(&s), "pure function of the snapshot");
    for needle in [
        "scale_sim_cache_misses_total 10",
        "scale_sim_cache_hits_total 30",
        "scale_sim_cache_inflight_waits_total 4",
        "scale_sim_cache_warm_hits_total 9",
        "scale_sim_cache_entries 17",
        "scale_sim_cache_warm_entries 6",
        "scale_sim_queue_depth 3",
        "scale_sim_queue_inflight 5",
        "scale_sim_jobs_submitted_total 46",
        "scale_sim_jobs_completed_total 40",
        "scale_sim_jobs_failed_total 1",
        "scale_sim_workers 8",
        "scale_sim_workers_busy 2",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    assert!(text.ends_with('\n'), "exposition ends with a newline");
}
