//! PJRT runtime integration: execute the AOT Pallas/JAX artifacts from
//! Rust and validate numerics against independent references.
//!
//! These tests exercise real artifacts built by `make artifacts`; when
//! the artifact directory is missing (bare `cargo test` before the
//! build step) they skip with a notice rather than fail, so the Rust
//! suite stays runnable standalone. `make test` always builds artifacts
//! first, so CI-style runs cover them.

use std::path::PathBuf;

use scale_sim::rtl;
use scale_sim::runtime::Runtime;
use scale_sim::util::rng::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = scale_sim::runtime::default_artifact_dir();
    let probe = dir.join("systolic_gemm_8.hlo.txt");
    if probe.exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing at {dir:?} (run `make artifacts`)");
        None
    }
}

fn max_rel_err(got: &[f32], want: &[f32]) -> f32 {
    assert_eq!(got.len(), want.len());
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
        .fold(0.0, f32::max)
}

#[test]
fn gemm_tile_matches_reference() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    for tile in [8usize, 32] {
        rt.load(&format!("systolic_gemm_{tile}")).unwrap();
        let (a, b) = rtl::random_matrices(tile, tile, tile, tile as u64);
        let got = rt.gemm_tile(tile, &a, &b).unwrap();
        let want = rtl::matmul_ref(&a, &b, tile, tile, tile);
        assert!(max_rel_err(&got, &want) < 1e-4, "tile {tile}");
    }
}

#[test]
fn tiled_gemm_handles_ragged_shapes() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(7);
    for &(m, k, n) in &[(8usize, 8usize, 8usize), (20, 50, 13), (1, 40, 9), (33, 8, 65)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let got = rt.tiled_gemm(8, &a, &b, m, k, n).unwrap();
        let want = rtl::matmul_ref(&a, &b, m, k, n);
        assert!(max_rel_err(&got, &want) < 1e-3, "{m}x{k}x{n}");
    }
}

#[test]
fn pjrt_matches_rtl_numerics() {
    // three implementations of the same systolic schedule must agree:
    // the RTL PE grid, the AOT Pallas kernel via PJRT, and software.
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    rt.load("systolic_gemm_8").unwrap();
    let (a, b) = rtl::random_matrices(8, 8, 8, 99);
    let rtl_out = rtl::run_matmul(&a, &b, 8, 8, 8).product;
    let pjrt_out = rt.gemm_tile(8, &a, &b).unwrap();
    assert!(max_rel_err(&rtl_out, &pjrt_out) < 1e-4);
}

#[test]
fn conv_artifact_matches_reference() {
    let Some(dir) = artifact_dir() else { return };
    let mut rt = Runtime::new(&dir).unwrap();
    let mut rng = Rng::new(3);
    let (h, w, c, m) = (16usize, 16, 32, 32);
    let x: Vec<f32> = (0..h * w * c).map(|_| rng.normal_f32()).collect();
    let f: Vec<f32> = (0..3 * 3 * c * m).map(|_| rng.normal_f32()).collect();
    let got = rt
        .conv("conv_3x3", &x, &[1, h as i64, w as i64, c as i64], &f, &[3, 3, c as i64, m as i64])
        .unwrap();
    // reference via tiled gemm on im2col (independently validated above)
    let (eh, ew, k) = (h - 2, w - 2, 9 * c);
    let mut lhs = vec![0f32; eh * ew * k];
    for p in 0..eh * ew {
        let (oy, ox) = (p / ew, p % ew);
        for dr in 0..3 {
            for ds in 0..3 {
                for ch in 0..c {
                    lhs[p * k + (dr * 3 + ds) * c + ch] = x[((oy + dr) * w + ox + ds) * c + ch];
                }
            }
        }
    }
    let want = rtl::matmul_ref(&lhs, &f, eh * ew, k, m);
    assert!(max_rel_err(&got, &want) < 1e-3);
}

#[test]
fn manifest_lists_all_artifacts() {
    let Some(dir) = artifact_dir() else { return };
    let manifest = dir.join("manifest.json");
    assert!(manifest.exists(), "aot.py must write manifest.json");
    let text = std::fs::read_to_string(manifest).unwrap();
    for name in ["systolic_gemm_8", "systolic_gemm_32", "systolic_gemm_128", "conv_3x3", "conv_1x1"] {
        assert!(text.contains(name), "{name} missing from manifest");
    }
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.available().len() >= 5);
}
