//! Loopback integration tests for the serve subsystem: a real TCP
//! server on an ephemeral port, real clients, plus the `MemoStats`
//! edge cases the service surfaces through its `stats` event.

use scale_sim::config::workloads;
use scale_sim::engine::MemoStats;
use scale_sim::server::{self, proto, Client, ServeOpts};
use scale_sim::util::json::Json;
use scale_sim::LayerShape;

fn inline_run_request(id: u64, layers: &[LayerShape]) -> String {
    Json::obj(vec![
        ("req", Json::str("run")),
        ("id", Json::u64(id)),
        ("workload", Json::str("loopback")),
        (
            "layers",
            Json::Arr(layers.iter().map(proto::layer_shape_to_json).collect()),
        ),
        ("array", Json::str("16x16")),
    ])
    .to_string()
}

fn small_layers() -> Vec<LayerShape> {
    vec![
        LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
        LayerShape::conv("c2", 14, 14, 3, 3, 8, 16, 1),
        LayerShape::fc("fc", 1, 256, 10),
    ]
}

fn report_of(events: &[Json]) -> scale_sim::WorkloadReport {
    let result = events
        .iter()
        .find(|e| e.str_field("event") == Some("result"))
        .expect("run job must emit a result event");
    proto::workload_report_from_json(result.get("report").unwrap()).unwrap()
}

/// The issue's core scenario: two clients submit the same layers; the
/// second is served from the shared cache with a bit-identical report.
#[test]
fn second_client_hits_the_shared_cache_bit_identically() {
    let handle = server::start(ServeOpts { workers: 4, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr();

    let mut alice = Client::connect(addr).unwrap();
    let first = report_of(&alice.request(&inline_run_request(1, &small_layers())).unwrap());
    let s1 = alice.stats().unwrap();
    assert_eq!(s1.memo.layer_sims, 3, "cold suite simulates every distinct layer");

    let mut bob = Client::connect(addr).unwrap();
    let second = report_of(&bob.request(&inline_run_request(2, &small_layers())).unwrap());
    let s2 = bob.stats().unwrap();

    assert_eq!(second, first, "cross-client replay must be bit-identical");
    assert_eq!(s2.memo.layer_sims, s1.memo.layer_sims, "no re-simulation for client 2");
    assert_eq!(s2.memo.cache_hits, s1.memo.cache_hits + 3, "every layer of client 2 hits");
    assert!(s2.memo.hit_rate() > 0.0);
    assert_eq!(s2.completed, 2);

    handle.shutdown();
}

/// Warm restart: results flushed to --state-dir come back as warm
/// cache entries, visible in `stats` as warm_entries/warm_hits.
#[test]
fn state_dir_restart_serves_warm_hits() {
    let dir = std::env::temp_dir()
        .join(format!("scale_sim_serve_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let opts = || ServeOpts {
        workers: 2,
        state_dir: Some(dir.clone()),
        ..ServeOpts::default()
    };

    // first life: compute, then flush on shutdown
    let handle = server::start(opts()).unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    let first = report_of(&c.request(&inline_run_request(1, &small_layers())).unwrap());
    assert_eq!(c.stats().unwrap().warm.entries, 0, "cold start has nothing prewarmed");
    drop(c);
    handle.shutdown();

    // second life: pre-warmed from disk; replay must not simulate
    let handle = server::start(opts()).unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    let before = c.stats().unwrap();
    assert_eq!(before.warm.entries, 3, "restart must reload every flushed entry");
    assert_eq!(before.cache_entries, 3);

    let replay = report_of(&c.request(&inline_run_request(9, &small_layers())).unwrap());
    let after = c.stats().unwrap();
    assert_eq!(replay, first, "disk-warmed reports are bit-identical");
    assert_eq!(after.memo.layer_sims, 0, "warm restart re-simulates nothing");
    assert_eq!(after.warm.hits, 3, "stats must attribute the hits to warm start");

    drop(c);
    handle.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Many concurrent clients racing the same cold workload: the in-flight
/// deduplication means the distinct layers are simulated exactly once
/// across the whole fleet, and nothing is dropped.
#[test]
fn concurrent_cold_clients_share_one_computation() {
    let handle = server::start(ServeOpts { workers: 8, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr();
    const CLIENTS: usize = 8;

    let reports: Vec<scale_sim::WorkloadReport> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| {
                s.spawn(move || {
                    let mut c = Client::connect(addr).unwrap();
                    report_of(&c.request(&inline_run_request(i as u64, &small_layers())).unwrap())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    for r in &reports[1..] {
        assert_eq!(*r, reports[0], "all clients must observe identical reports");
    }
    let stats = handle.stats();
    assert_eq!(stats.completed, CLIENTS as u64, "zero dropped jobs");
    assert_eq!(stats.memo.layer_sims, 3, "in-flight dedup: 3 distinct layers, 3 sims total");
    assert_eq!(stats.memo.lookups(), (CLIENTS * 3) as u64);
    handle.shutdown();
}

/// Built-in workload names resolve server-side too (the bench path).
#[test]
fn builtin_workload_runs_by_name() {
    let handle = server::start(ServeOpts::default()).unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    let events = c.request(r#"{"req":"run","id":3,"workload":"ncf"}"#).unwrap();
    let report = report_of(&events);
    assert_eq!(report.layers.len(), workloads::builtin("ncf").unwrap().layers.len());
    assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
    handle.shutdown();
}

// ---------------------------------------------------------------------
// MemoStats edge cases (the counters the stats event reports)

#[test]
fn memostats_hit_rate_with_zero_lookups_is_zero_not_nan() {
    let idle = MemoStats::default();
    assert_eq!(idle.lookups(), 0);
    assert_eq!(idle.hit_rate(), 0.0);
    assert!(!idle.hit_rate().is_nan());
}

#[test]
fn memostats_since_across_a_reset_saturates() {
    // snapshot taken before a server restart (counters restarted at 0)
    let stale = MemoStats { layer_sims: 50, cache_hits: 200, inflight_waits: 8 };
    let fresh = MemoStats { layer_sims: 2, cache_hits: 5, inflight_waits: 1 };
    let delta = fresh.since(&stale);
    assert_eq!((delta.layer_sims, delta.cache_hits, delta.inflight_waits), (0, 0, 0));
    assert_eq!(delta.hit_rate(), 0.0);

    // normal forward delta still exact
    let later = MemoStats { layer_sims: 60, cache_hits: 240, inflight_waits: 10 };
    let d = later.since(&stale);
    assert_eq!((d.layer_sims, d.cache_hits, d.inflight_waits), (10, 40, 2));
    assert!((d.hit_rate() - 0.8).abs() < 1e-12);
}

// ---------------------------------------------------------------------
// Observability surfaces: stats gauges + the Prometheus metrics scrape

/// The stats event carries the queue/worker occupancy gauges, and the
/// `metrics` request exposes the same snapshot as deterministic
/// Prometheus text (byte-identical across scrapes of an idle server).
#[test]
fn stats_and_metrics_surface_queue_and_worker_series() {
    let handle = server::start(ServeOpts { workers: 3, ..ServeOpts::default() }).unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    let _ = report_of(&c.request(&inline_run_request(7, &small_layers())).unwrap());

    // the worker counts the job done (and itself idle) BEFORE emitting
    // `done`, so a stats request issued after the terminal event must
    // observe a fully idle server
    let s = c.stats().unwrap();
    assert_eq!(s.workers, 3);
    assert_eq!(s.queue_depth, 0, "idle server has an empty queue");
    assert_eq!(s.in_flight, 0, "nothing accepted-but-unfinished");
    assert_eq!(s.workers_busy, 0, "no worker mid-job");
    assert_eq!(s.completed, 1);

    // raw wire check: the stats event itself names every gauge
    let raw = c.request(r#"{"req":"stats"}"#).unwrap();
    assert_eq!(raw.len(), 1, "stats is a terminal event");
    for field in ["queue_depth", "in_flight", "workers", "workers_busy", "inflight_waits"] {
        assert!(raw[0].u64_field(field).is_some(), "stats event missing {field}: {}", raw[0]);
    }

    // the Prometheus scrape covers the promised cache/queue/worker series
    let text = c.metrics().unwrap();
    for needle in [
        "# TYPE scale_sim_cache_hits_total counter",
        "scale_sim_cache_misses_total 3",
        "scale_sim_queue_depth 0",
        "scale_sim_queue_inflight 0",
        "scale_sim_jobs_submitted_total 1",
        "scale_sim_jobs_completed_total 1",
        "scale_sim_jobs_failed_total 0",
        "scale_sim_workers 3",
        "scale_sim_workers_busy 0",
    ] {
        assert!(text.contains(needle), "missing {needle} in:\n{text}");
    }
    assert_eq!(text, c.metrics().unwrap(), "idle scrapes must be byte-identical");

    handle.shutdown();
}

// ---------------------------------------------------------------------
// Typed-workload (operator IR) submissions

/// A typed `ops` submission is lowered server-side; a pointwise conv op
/// and a GEMM-workload twin submitted by another client share the
/// server's memo cache (the conv <-> GEMM sharing claim, end-to-end).
#[test]
fn inline_ops_run_and_share_the_cache_with_gemm_twins() {
    let handle = server::start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr();

    let mut alice = Client::connect(addr).unwrap();
    let ops = r#"{"req":"run","id":21,"workload":"typed","ops":[
        {"type":"conv2d","name":"pw","ifmap_h":14,"ifmap_w":14,"in_channels":32,"out_channels":48,"kernel_h":1},
        {"type":"fc","name":"fc","batch":4,"in_features":96,"out_features":24},
        {"type":"pool","name":"mp","ifmap_h":14,"ifmap_w":14,"channels":48,"window_h":2}
    ]}"#
    .replace('\n', " ");
    let events = alice.request(&ops).unwrap();
    let report = report_of(&events);
    assert_eq!(report.layers.len(), 3);
    // the pointwise conv arrived as the canonical GEMM tile
    assert!(report.layers[0].layer.is_gemm());
    assert_eq!(report.layers[0].layer.gemm_view(), (196, 32, 48));
    assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
    let sims = alice.stats().unwrap().memo.layer_sims;

    // a second client submits the GEMM twin of the pointwise conv
    let mut bob = Client::connect(addr).unwrap();
    let twin = r#"{"req":"run","id":22,"ops":[{"type":"gemm","name":"g","m":196,"k":32,"n":48}]}"#;
    let twin_report = report_of(&bob.request(twin).unwrap());
    let stats = bob.stats().unwrap();
    assert_eq!(stats.memo.layer_sims, sims, "the GEMM twin must not re-simulate");
    assert_eq!(twin_report.layers[0].timing, report.layers[0].timing);

    // malformed ops are rejected at admission with an error event
    let bad = bob
        .request(r#"{"req":"run","id":23,"ops":[{"type":"gemm","name":"z","m":0,"k":1,"n":1}]}"#)
        .unwrap();
    assert_eq!(bad[0].str_field("event"), Some("error"));

    handle.shutdown();
}

/// Built-in GEMM workloads resolve by name, like the conv family.
#[test]
fn builtin_gemm_workload_runs_by_name() {
    let handle = server::start(ServeOpts::default()).unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    let events = c.request(r#"{"req":"run","id":5,"workload":"attention"}"#).unwrap();
    let report = report_of(&events);
    assert!(report.layers.iter().all(|l| l.layer.is_gemm()));
    assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
    handle.shutdown();
}
