//! Property tests over the multi-array partition geometry and the
//! engine's scale-out path ([`scale_sim::engine::multi`]):
//!
//! * for random workloads — including depthwise/grouped/dilated convs
//!   lowered through the typed IR — partitioned sub-shapes conserve
//!   total MACs and OFMAP pixels **exactly**;
//! * every node-group is non-empty, and nodes beyond the used count are
//!   explicitly idle (never a zero-work share);
//! * `Auto` is never slower than either fixed strategy;
//! * a single-node multi-array system is the plain engine bit-for-bit.

use scale_sim::config::Topology;
use scale_sim::engine::multi::{split_layer, MultiArrayConfig, Partition, NODE_DIM};
use scale_sim::engine::Engine;
use scale_sim::util::rng::Rng;
use scale_sim::workload::{Conv2d, Op, OpNode, Workload};
use scale_sim::{ArchConfig, Dataflow, LayerShape};

/// A random *valid* Conv2d, biased to exercise the special lowerings:
/// pointwise, depthwise, grouped, dilated, strided.
fn random_conv(rng: &mut Rng) -> Conv2d {
    let flavor = rng.range(0, 4);
    let (groups, in_channels, out_channels) = match flavor {
        // depthwise: groups == Cin == Cout
        0 => {
            let c = rng.range(1, 16);
            (c, c, c)
        }
        // grouped: groups divides both channel counts
        1 => {
            let g = rng.range(2, 4);
            (g, g * rng.range(1, 6), g * rng.range(1, 6))
        }
        // dense (flavors 2/3 double the weight of the common case)
        _ => (1, rng.range(1, 24), rng.range(1, 24)),
    };
    let kernel_h = rng.range(1, 4);
    let kernel_w = rng.range(1, 4);
    let dilation = rng.range(1, 3);
    let ekh = (kernel_h - 1) * dilation + 1;
    let ekw = (kernel_w - 1) * dilation + 1;
    Conv2d {
        ifmap_h: ekh + rng.range(0, 20),
        ifmap_w: ekw + rng.range(0, 20),
        in_channels,
        out_channels,
        kernel_h,
        kernel_w,
        stride: rng.range(1, 3),
        dilation,
        groups,
    }
}

fn random_op(rng: &mut Rng) -> Op {
    match rng.range(0, 4) {
        0 | 1 => Op::Conv2d(random_conv(rng)),
        2 => Op::Gemm { m: rng.range(1, 64), k: rng.range(1, 96), n: rng.range(1, 64) },
        _ => Op::FullyConnected {
            batch: rng.range(1, 8),
            in_features: rng.range(1, 128),
            out_features: rng.range(1, 64),
        },
    }
}

/// Random lowered layer shapes (through the typed IR, so depthwise and
/// grouped convs contribute their per-group tiles).
fn random_layers(rng: &mut Rng, tag: u64) -> Vec<LayerShape> {
    let n = rng.range(1, 4) as usize;
    let nodes = (0..n)
        .map(|i| OpNode::new(&format!("op{tag}_{i}"), random_op(rng)))
        .collect();
    Workload::new(&format!("w{tag}"), nodes)
        .lower()
        .expect("random valid workloads lower")
        .layers
}

const NODE_COUNTS: [u64; 7] = [1, 2, 3, 5, 8, 16, 64];

#[test]
fn partitions_conserve_macs_and_ofmap_pixels_exactly() {
    let mut rng = Rng::new(0x5CA1E_0);
    for tag in 0..40 {
        for layer in random_layers(&mut rng, tag) {
            for &nodes in &NODE_COUNTS {
                for partition in [Partition::OutputChannels, Partition::Pixels] {
                    let shares = split_layer(&layer, nodes, partition);
                    let macs: u64 = shares.iter().map(|s| s.count * s.layer.macs()).sum();
                    let ofmap: u64 =
                        shares.iter().map(|s| s.count * s.layer.ofmap_elems()).sum();
                    assert_eq!(
                        macs,
                        layer.macs(),
                        "MACs not conserved: {partition:?} nodes={nodes} {layer:?}"
                    );
                    assert_eq!(
                        ofmap,
                        layer.ofmap_elems(),
                        "OFMAP pixels not conserved: {partition:?} nodes={nodes} {layer:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn every_share_is_nonempty_and_idle_nodes_are_explicit() {
    let mut rng = Rng::new(0x5CA1E_1);
    for tag in 0..40 {
        for layer in random_layers(&mut rng, tag) {
            for &nodes in &NODE_COUNTS {
                for partition in [Partition::OutputChannels, Partition::Pixels] {
                    let shares = split_layer(&layer, nodes, partition);
                    assert!(!shares.is_empty() && shares.len() <= 2);
                    let used: u64 = shares.iter().map(|s| s.count).sum();
                    assert!(used >= 1 && used <= nodes, "{partition:?} nodes={nodes}");
                    for s in &shares {
                        assert!(s.count >= 1, "empty node-group: {partition:?}");
                        assert!(s.layer.validate().is_ok(), "invalid share {:?}", s.layer);
                        assert!(s.layer.macs() > 0, "zero-work share: {partition:?}");
                    }
                    // the trailing group, when present, is the uneven
                    // remainder on exactly one node
                    if let Some(rem) = shares.get(1) {
                        assert_eq!(rem.count, 1);
                        match partition {
                            Partition::OutputChannels => assert!(
                                rem.layer.num_filters < shares[0].layer.num_filters
                            ),
                            Partition::Pixels => {
                                assert!(rem.layer.ofmap_h() < shares[0].layer.ofmap_h())
                            }
                            Partition::Auto => unreachable!(),
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn auto_is_never_slower_than_either_fixed_strategy() {
    let mut rng = Rng::new(0x5CA1E_2);
    let engine = Engine::new(ArchConfig::default());
    for tag in 0..12 {
        for layer in random_layers(&mut rng, tag) {
            for &nodes in &[2u64, 7, 16] {
                let mk = |p| MultiArrayConfig::new(nodes, NODE_DIM, NODE_DIM, p);
                let auto = engine.run_multi_layer_with(
                    engine.cfg(),
                    &layer,
                    &mk(Partition::Auto),
                    None,
                );
                let ch = engine.run_multi_layer_with(
                    engine.cfg(),
                    &layer,
                    &mk(Partition::OutputChannels),
                    None,
                );
                let px = engine.run_multi_layer_with(
                    engine.cfg(),
                    &layer,
                    &mk(Partition::Pixels),
                    None,
                );
                assert!(
                    auto.cycles <= ch.cycles && auto.cycles <= px.cycles,
                    "auto slower: nodes={nodes} {layer:?}"
                );
                assert_eq!(auto.cycles, ch.cycles.min(px.cycles), "auto must pick the min");
                assert_ne!(auto.partition, Partition::Auto, "auto must resolve");
            }
        }
    }
}

#[test]
fn single_node_multi_array_is_the_plain_engine_bit_for_bit() {
    let mut rng = Rng::new(0x5CA1E_3);
    for tag in 0..12 {
        let layers = random_layers(&mut rng, tag);
        let topo = Topology::new("prop", layers);
        for df in Dataflow::ALL {
            let cfg = ArchConfig {
                dataflow: df,
                array_h: 16,
                array_w: 16,
                ..ArchConfig::default()
            };
            let engine = Engine::new(cfg.clone());
            let plain = engine.run_topology(&topo);
            for partition in Partition::ALL {
                let multi = MultiArrayConfig::new(1, 16, 16, partition);
                let m = engine.run_multi(&topo, &multi);
                assert_eq!(
                    m.to_workload_report(),
                    plain,
                    "single-node multi-array deviates under {partition:?}/{df}"
                );
                assert_eq!(m.total_cycles(), plain.total_cycles());
                assert_eq!(m.total_dram(), plain.total_dram());
                for ml in &m.layers {
                    assert_eq!((ml.used_nodes, ml.idle_nodes), (1, 0));
                    assert!(ml.remainder.is_none());
                }
            }
        }
    }
}

#[test]
fn slowest_node_bounds_and_cache_sharing_across_partition_points() {
    // the composed layer runtime is exactly the slowest node's, and the
    // Auto point after its two fixed siblings is served from cache
    let engine = Engine::new(ArchConfig::default());
    let layer = LayerShape::conv("c", 60, 60, 3, 3, 24, 100, 1);
    for &nodes in &[4u64, 16] {
        let mk = |p| MultiArrayConfig::new(nodes, NODE_DIM, NODE_DIM, p);
        for p in [Partition::OutputChannels, Partition::Pixels] {
            let m = engine.run_multi_layer_with(engine.cfg(), &layer, &mk(p), None);
            let mut expect = m.node_report.timing.cycles;
            if let Some(r) = &m.remainder {
                expect = expect.max(r.timing.cycles);
            }
            assert_eq!(m.cycles, expect, "{p:?} nodes={nodes}");
        }
        let before = engine.cache_stats();
        let _ = engine.run_multi_layer_with(engine.cfg(), &layer, &mk(Partition::Auto), None);
        let delta = engine.cache_stats().since(&before);
        assert_eq!(delta.layer_sims, 0, "auto after fixed must be fully cached");
        assert!(delta.cache_hits >= 2, "{delta:?}");
    }
}
