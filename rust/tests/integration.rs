//! Cross-module integration tests: the paper's qualitative claims must
//! hold end-to-end through config -> simulator -> reports.
//!
//! Deliberately exercises the deprecated legacy entry points
//! (`coordinator::run`, `sweep::*_sweep`) — they are shims over the
//! engine now, and these tests pin their behavior.
#![allow(deprecated)]

use scale_sim::config::{self, workloads, ArchConfig, Topology};
use scale_sim::coordinator::{run, RunSpec};
use scale_sim::dataflow::Dataflow;
use scale_sim::scaleout;
use scale_sim::sim::Simulator;
use scale_sim::sweep;
use scale_sim::LayerShape;

fn suite_cycles(df: Dataflow, array: u64, topo: &Topology) -> u64 {
    let cfg = ArchConfig { array_h: array, array_w: array, dataflow: df, ..config::paper_default() };
    Simulator::new(cfg).run_topology(topo).total_cycles()
}

#[test]
fn w2_deepspeech_prefers_ws_over_is() {
    // §IV-B: "WS and IS are clear winners respectively in these
    // workloads [W2, W7]... invariant of the size of the array"
    let topo = workloads::builtin("deepspeech2").unwrap();
    for n in [128, 64, 32, 16, 8] {
        let ws = suite_cycles(Dataflow::Ws, n, &topo);
        let is = suite_cycles(Dataflow::Is, n, &topo);
        assert!(ws < is, "{n}x{n}: ws={ws} is={is}");
    }
}

#[test]
fn w7_transformer_prefers_is_over_ws() {
    let topo = workloads::builtin("transformer").unwrap();
    for n in [128, 64, 32, 16, 8] {
        let ws = suite_cycles(Dataflow::Ws, n, &topo);
        let is = suite_cycles(Dataflow::Is, n, &topo);
        assert!(is < ws, "{n}x{n}: ws={ws} is={is}");
    }
}

#[test]
fn fig7_bandwidth_curves_have_knees() {
    // Fig 7(c): NCF's operands are tiny — its DRAM requirement stops
    // improving at very small scratchpads; Fig 7(d): SentimentCNN keeps
    // improving to larger sizes than NCF.
    let base = config::paper_default();
    let bw = |name: &str, kb: u64| {
        let cfg = ArchConfig { ifmap_sram_kb: kb, filter_sram_kb: kb, ..base.clone() };
        Simulator::new(cfg)
            .run_topology(&workloads::builtin(name).unwrap())
            .avg_dram_read_bw()
    };
    // NCF flat beyond 64KB
    let ncf_small = bw("ncf", 64);
    let ncf_big = bw("ncf", 2048);
    assert!(ncf_small / ncf_big < 1.05, "ncf should be flat: {ncf_small} vs {ncf_big}");
    // SentimentCNN still improving from 256K to 2048K
    let s_256 = bw("sentimentcnn", 256);
    let s_2048 = bw("sentimentcnn", 2048);
    assert!(s_256 / s_2048 > 1.05, "sentimentcnn should keep improving: {s_256} vs {s_2048}");
}

#[test]
fn fig9_common_case_scale_up_wins() {
    // §IV-E: "For the common case scaled-up implementation turns out to
    // be the best in terms of performance" — assert on the majority of
    // the MLPerf suite under OS at 16384 PEs.
    let base = config::paper_default();
    let mut up_wins = 0;
    let mut total = 0;
    for t in workloads::mlperf_suite() {
        let c = scaleout::compare_topology(&base, &t.layers, 16384);
        total += 1;
        if c.runtime_ratio() < 1.0 {
            up_wins += 1;
        }
    }
    assert!(up_wins * 2 > total, "scale-up should win the common case: {up_wins}/{total}");
}

#[test]
fn fig8_square_arrays_do_well_for_common_case() {
    // §IV-D: "square aspect ratios perform well for the common case" —
    // for most (workload, dataflow) pairs the 128x128 point is within 2x
    // of the best shape.
    let base = config::paper_default();
    let topos = workloads::mlperf_suite();
    let shapes = sweep::fig8_shapes();
    let pts = sweep::shape_sweep(&base, &topos, &shapes, sweep::default_threads());
    let mut good = 0;
    let mut total = 0;
    for t in &topos {
        for df in Dataflow::ALL {
            let series: Vec<&sweep::ShapePoint> = pts
                .iter()
                .filter(|p| p.workload == t.name && p.dataflow == df)
                .collect();
            let best = series.iter().map(|p| p.cycles).min().unwrap();
            let square = series.iter().find(|p| p.rows == 128).unwrap().cycles;
            total += 1;
            if square < 2 * best {
                good += 1;
            }
        }
    }
    assert!(good * 4 >= total * 3, "square good for common case: {good}/{total}");
}

#[test]
fn os_dominates_most_mlperf_points_like_fig5() {
    // Fig 5 "at a glance": OS outperforms the other two dataflows for
    // the bulk of (workload, array) points.
    let mut os_wins = 0;
    let mut total = 0;
    for t in workloads::mlperf_suite() {
        for n in [128, 64, 32, 16, 8] {
            let os = suite_cycles(Dataflow::Os, n, &t);
            let ws = suite_cycles(Dataflow::Ws, n, &t);
            let is = suite_cycles(Dataflow::Is, n, &t);
            total += 1;
            if os <= ws && os <= is {
                os_wins += 1;
            }
        }
    }
    // strict majority; our WS model edges OS on very-large-Npx conv
    // layers (documented deviation, EXPERIMENTS.md §Fig5)
    assert!(os_wins * 2 > total, "OS should win the majority of points: {os_wins}/{total}");
}

#[test]
fn cfg_file_to_reports_round_trip() {
    let dir = std::env::temp_dir().join(format!("scale_sim_int_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // write a cfg + topology, load through the front end, run, check files
    let topo_path = dir.join("tiny.csv");
    std::fs::write(
        &topo_path,
        "Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,\n\
         c1, 12, 12, 3, 3, 4, 8, 1,\n",
    )
    .unwrap();
    let cfg_path = dir.join("run.cfg");
    std::fs::write(
        &cfg_path,
        format!(
            "[general]\nrun_name = int\n[architecture_presets]\nArrayHeight: 16\nArrayWidth: 16\nDataflow: ws\nTopology: {}\n",
            topo_path.display()
        ),
    )
    .unwrap();

    let cfg = ArchConfig::from_file(&cfg_path).unwrap();
    assert_eq!(cfg.dataflow, Dataflow::Ws);
    let topo = Topology::from_file(cfg.topology_path.as_ref().unwrap()).unwrap();
    let mut spec = RunSpec::new(cfg, topo);
    spec.out_dir = Some(dir.join("out"));
    spec.dump_traces = true;
    let out = run(&spec).unwrap();
    assert_eq!(out.report.layers.len(), 1);
    assert!(dir.join("out/summary.md").exists());
    assert!(dir.join("out/traces/c1_sram_trace.csv").exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn gemm_layer_equals_explicit_conv_encoding() {
    // the §III-A encoding: FC as 1x1 conv must time identically to the
    // same GEMM passed through LayerShape::gemm
    let a = LayerShape::gemm("g", 64, 256, 32);
    let b = LayerShape::conv("c", 64, 1, 1, 1, 256, 32, 1);
    for df in Dataflow::ALL {
        assert_eq!(df.timing(&a, 16, 16).cycles, df.timing(&b, 16, 16).cycles);
    }
}

#[test]
fn mlperf_suite_simulates_quickly_and_sanely() {
    let cfg = config::paper_default();
    let sim = Simulator::new(cfg.clone());
    for t in workloads::mlperf_suite() {
        let r = sim.run_topology(&t);
        let util = r.overall_utilization(cfg.total_pes());
        assert!(r.total_cycles() > 0);
        assert!(util > 0.0 && util <= 1.0, "{}: {util}", t.name);
        assert!(r.total_dram().total() > 0);
        assert!(r.total_energy().total_mj() > 0.0);
    }
}
