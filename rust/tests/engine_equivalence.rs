//! Engine/legacy equivalence suite: the `engine` façade must be
//! **bit-identical** to the pre-engine entry points it replaces —
//! `Simulator::run_layer` and the three legacy sweep functions — across
//! all three dataflows, and its fidelity backends must agree with each
//! other. Property-tested over randomized layer shapes and array
//! geometries.
#![allow(deprecated)]

use scale_sim::config::{self, ArchConfig, Topology};
use scale_sim::engine::{BackendKind, Engine};
use scale_sim::sim::Simulator;
use scale_sim::sweep;
use scale_sim::util::prop::{forall, Shrink};
use scale_sim::util::rng::Rng;
use scale_sim::{Dataflow, LayerShape};

/// Random-but-valid layer + array geometry.
#[derive(Clone, Debug)]
struct Case {
    layer: LayerShape,
    rows: u64,
    cols: u64,
}

impl Shrink for Case {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let l = &self.layer;
        let mut push = |layer: LayerShape, rows, cols| {
            if layer.validate().is_ok() {
                out.push(Case { layer, rows, cols });
            }
        };
        if l.ifmap_h > l.filt_h {
            push(LayerShape { ifmap_h: l.ifmap_h - 1, ..l.clone() }, self.rows, self.cols);
        }
        if l.channels > 1 {
            push(LayerShape { channels: l.channels / 2, ..l.clone() }, self.rows, self.cols);
        }
        if l.num_filters > 1 {
            push(LayerShape { num_filters: l.num_filters / 2, ..l.clone() }, self.rows, self.cols);
        }
        if self.rows > 1 {
            push(l.clone(), self.rows / 2, self.cols);
        }
        if self.cols > 1 {
            push(l.clone(), self.rows, self.cols / 2);
        }
        out
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let filt_h = rng.range(1, 4);
    let filt_w = rng.range(1, 4);
    let layer = LayerShape {
        name: "prop".into(),
        ifmap_h: filt_h + rng.range(0, 10),
        ifmap_w: filt_w + rng.range(0, 10),
        filt_h,
        filt_w,
        channels: rng.range(1, 6),
        num_filters: rng.range(1, 16),
        stride: rng.range(1, 3),
    };
    Case { layer, rows: rng.range(1, 14), cols: rng.range(1, 14) }
}

fn cfg_for(case: &Case, df: Dataflow) -> ArchConfig {
    ArchConfig {
        array_h: case.rows,
        array_w: case.cols,
        dataflow: df,
        ..config::paper_default()
    }
}

#[test]
fn prop_engine_bit_identical_to_simulator_all_dataflows() {
    for df in Dataflow::ALL {
        forall(0xE9E + df as u64, 60, gen_case, |case| {
            let cfg = cfg_for(case, df);
            let engine = Engine::new(cfg.clone());
            let sim = Simulator::new(cfg);
            engine.run_layer(&case.layer) == sim.run_layer(&case.layer)
        });
    }
}

#[test]
fn prop_trace_backend_bit_identical_to_analytical() {
    for df in Dataflow::ALL {
        forall(0x7AACE + df as u64, 30, gen_case, |case| {
            let cfg = cfg_for(case, df);
            let trace = Engine::builder()
                .config(cfg.clone())
                .backend(BackendKind::TraceDriven)
                .build()
                .unwrap();
            let sim = Simulator::new(cfg);
            trace.run_layer(&case.layer) == sim.run_layer(&case.layer)
        });
    }
}

#[test]
fn prop_rtl_backend_bit_identical_to_analytical() {
    // fewer cases: each check drives the register-level PE grid
    for df in Dataflow::ALL {
        forall(0x271 + df as u64, 12, gen_case, |case| {
            let cfg = cfg_for(case, df);
            let rtl = Engine::builder()
                .config(cfg.clone())
                .backend(BackendKind::Rtl)
                .build()
                .unwrap();
            let sim = Simulator::new(cfg);
            rtl.run_layer(&case.layer) == sim.run_layer(&case.layer)
        });
    }
}

fn small_suite() -> Vec<Topology> {
    vec![
        Topology::new(
            "a",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::conv("c2", 16, 16, 3, 3, 4, 8, 1), // repeated shape
                LayerShape::fc("fc", 1, 128, 10),
            ],
        ),
        Topology::new(
            "b",
            vec![
                LayerShape::conv("d1", 14, 14, 3, 3, 8, 16, 2),
                LayerShape::gemm("g", 24, 40, 24),
            ],
        ),
    ]
}

/// The historical dataflow_sweep, re-implemented verbatim against
/// `Simulator` (frozen here as the reference the shim must reproduce).
fn reference_dataflow_sweep(
    base: &ArchConfig,
    topos: &[Topology],
    arrays: &[u64],
) -> Vec<(String, Dataflow, u64, u64, f64)> {
    let mut out = Vec::new();
    for t in topos {
        for &df in &Dataflow::ALL {
            for &n in arrays {
                let cfg = ArchConfig { array_h: n, array_w: n, dataflow: df, ..base.clone() };
                let r = Simulator::new(cfg).run_topology(t);
                out.push((
                    t.name.clone(),
                    df,
                    n,
                    r.total_cycles(),
                    r.overall_utilization(n * n),
                ));
            }
        }
    }
    out
}

#[test]
fn legacy_dataflow_sweep_is_bit_identical_to_pre_engine_reference() {
    let base = config::paper_default();
    let topos = small_suite();
    let arrays = [16u64, 8, 5];
    let got = sweep::dataflow_sweep(&base, &topos, &arrays, 4);
    let want = reference_dataflow_sweep(&base, &topos, &arrays);
    assert_eq!(got.len(), want.len());
    for (g, (name, df, n, cycles, util)) in got.iter().zip(&want) {
        assert_eq!(&g.workload, name);
        assert_eq!(g.dataflow, *df);
        assert_eq!(g.array, *n);
        assert_eq!(g.cycles, *cycles, "{name} {df} {n}");
        assert!(g.utilization == *util, "utilization must be bit-identical");
    }
}

#[test]
fn legacy_memory_sweep_matches_simulator_reference() {
    let base = config::paper_default();
    let topos = small_suite();
    let kbs = [1u64, 8, 64, 512];
    let got = sweep::memory_sweep(&base, &topos, &kbs, 4);
    let mut i = 0;
    for t in &topos {
        for &kb in &kbs {
            let cfg = ArchConfig { ifmap_sram_kb: kb, filter_sram_kb: kb, ..base.clone() };
            let r = Simulator::new(cfg).run_topology(t);
            assert_eq!(got[i].workload, t.name);
            assert_eq!(got[i].sram_kb, kb);
            assert_eq!(got[i].dram_bytes, r.total_dram().total(), "{} {kb}", t.name);
            assert!(got[i].avg_read_bw == r.avg_dram_read_bw());
            i += 1;
        }
    }
    assert_eq!(i, got.len());
}

#[test]
fn legacy_shape_sweep_matches_simulator_reference() {
    let base = config::paper_default();
    let topos = small_suite();
    let shapes = [(4u64, 16u64), (8, 8), (16, 4)];
    let got = sweep::shape_sweep(&base, &topos, &shapes, 4);
    let mut i = 0;
    for t in &topos {
        for &df in &Dataflow::ALL {
            for &(r, c) in &shapes {
                let cfg = ArchConfig { array_h: r, array_w: c, dataflow: df, ..base.clone() };
                let want = Simulator::new(cfg).run_topology(t).total_cycles();
                assert_eq!(
                    (got[i].workload.as_str(), got[i].dataflow, got[i].rows, got[i].cols),
                    (t.name.as_str(), df, r, c)
                );
                assert_eq!(got[i].cycles, want, "{} {df} {r}x{c}", t.name);
                i += 1;
            }
        }
    }
    assert_eq!(i, got.len());
}

#[test]
fn engine_grid_reports_cache_hits_and_identical_results_on_rerun() {
    let engine = Engine::new(config::paper_default());
    let topos = small_suite();
    let first = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&[16, 8])
        .run();
    assert!(first.stats.memo.cache_hits > 0, "repeated shapes must hit");
    let second = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&[16, 8])
        .run();
    assert_eq!(second.stats.memo.layer_sims, 0);
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.report, b.report);
    }
}

#[test]
fn coordinator_shim_equals_engine_run() {
    use scale_sim::coordinator::{run, RunSpec};
    let mut cfg = config::paper_default();
    cfg.array_h = 16;
    cfg.array_w = 16;
    for df in Dataflow::ALL {
        cfg.dataflow = df;
        let spec = RunSpec::new(cfg.clone(), small_suite().remove(0));
        let legacy = run(&spec).unwrap();
        let engine = Engine::builder().config(cfg.clone()).build().unwrap();
        let direct = engine.run(&spec.topology).unwrap();
        assert_eq!(legacy.report, direct.report, "{df}");
        // and both equal the plain Simulator path
        let sim_rep = Simulator::new(cfg.clone()).run_topology(&spec.topology);
        assert_eq!(legacy.report, sim_rep, "{df}");
    }
}
