//! Engine/legacy equivalence suite: the `engine` façade must be
//! **bit-identical** to the pre-engine entry points it replaces —
//! `Simulator::run_layer` and the three legacy sweep functions — across
//! all three dataflows, and its fidelity backends must agree with each
//! other. Property-tested over randomized layer shapes and array
//! geometries.
#![allow(deprecated)]

use scale_sim::config::{self, ArchConfig, Topology};
use scale_sim::engine::{BackendKind, Engine};
use scale_sim::sim::Simulator;
use scale_sim::sweep;
use scale_sim::util::prop::{forall, Shrink};
use scale_sim::util::rng::Rng;
use scale_sim::{Dataflow, LayerShape};

/// Random-but-valid layer + array geometry.
#[derive(Clone, Debug)]
struct Case {
    layer: LayerShape,
    rows: u64,
    cols: u64,
}

impl Shrink for Case {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let l = &self.layer;
        let mut push = |layer: LayerShape, rows, cols| {
            if layer.validate().is_ok() {
                out.push(Case { layer, rows, cols });
            }
        };
        if l.ifmap_h > l.filt_h {
            push(LayerShape { ifmap_h: l.ifmap_h - 1, ..l.clone() }, self.rows, self.cols);
        }
        if l.channels > 1 {
            push(LayerShape { channels: l.channels / 2, ..l.clone() }, self.rows, self.cols);
        }
        if l.num_filters > 1 {
            push(LayerShape { num_filters: l.num_filters / 2, ..l.clone() }, self.rows, self.cols);
        }
        if self.rows > 1 {
            push(l.clone(), self.rows / 2, self.cols);
        }
        if self.cols > 1 {
            push(l.clone(), self.rows, self.cols / 2);
        }
        out
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let filt_h = rng.range(1, 4);
    let filt_w = rng.range(1, 4);
    let layer = LayerShape {
        name: "prop".into(),
        ifmap_h: filt_h + rng.range(0, 10),
        ifmap_w: filt_w + rng.range(0, 10),
        filt_h,
        filt_w,
        channels: rng.range(1, 6),
        num_filters: rng.range(1, 16),
        stride: rng.range(1, 3),
    };
    Case { layer, rows: rng.range(1, 14), cols: rng.range(1, 14) }
}

fn cfg_for(case: &Case, df: Dataflow) -> ArchConfig {
    ArchConfig {
        array_h: case.rows,
        array_w: case.cols,
        dataflow: df,
        ..config::paper_default()
    }
}

#[test]
fn prop_engine_bit_identical_to_simulator_all_dataflows() {
    for df in Dataflow::ALL {
        forall(0xE9E + df as u64, 60, gen_case, |case| {
            let cfg = cfg_for(case, df);
            let engine = Engine::new(cfg.clone());
            let sim = Simulator::new(cfg);
            engine.run_layer(&case.layer) == sim.run_layer(&case.layer)
        });
    }
}

#[test]
fn prop_trace_backend_bit_identical_to_analytical() {
    for df in Dataflow::ALL {
        forall(0x7AACE + df as u64, 30, gen_case, |case| {
            let cfg = cfg_for(case, df);
            let trace = Engine::builder()
                .config(cfg.clone())
                .backend(BackendKind::TraceDriven)
                .build()
                .unwrap();
            let sim = Simulator::new(cfg);
            trace.run_layer(&case.layer) == sim.run_layer(&case.layer)
        });
    }
}

#[test]
fn prop_rtl_backend_bit_identical_to_analytical() {
    // fewer cases: each check drives the register-level PE grid
    for df in Dataflow::ALL {
        forall(0x271 + df as u64, 12, gen_case, |case| {
            let cfg = cfg_for(case, df);
            let rtl = Engine::builder()
                .config(cfg.clone())
                .backend(BackendKind::Rtl)
                .build()
                .unwrap();
            let sim = Simulator::new(cfg);
            rtl.run_layer(&case.layer) == sim.run_layer(&case.layer)
        });
    }
}

fn small_suite() -> Vec<Topology> {
    vec![
        Topology::new(
            "a",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::conv("c2", 16, 16, 3, 3, 4, 8, 1), // repeated shape
                LayerShape::fc("fc", 1, 128, 10),
            ],
        ),
        Topology::new(
            "b",
            vec![
                LayerShape::conv("d1", 14, 14, 3, 3, 8, 16, 2),
                LayerShape::gemm("g", 24, 40, 24),
            ],
        ),
    ]
}

/// The historical dataflow_sweep, re-implemented verbatim against
/// `Simulator` (frozen here as the reference the shim must reproduce).
fn reference_dataflow_sweep(
    base: &ArchConfig,
    topos: &[Topology],
    arrays: &[u64],
) -> Vec<(String, Dataflow, u64, u64, f64)> {
    let mut out = Vec::new();
    for t in topos {
        for &df in &Dataflow::ALL {
            for &n in arrays {
                let cfg = ArchConfig { array_h: n, array_w: n, dataflow: df, ..base.clone() };
                let r = Simulator::new(cfg).run_topology(t);
                out.push((
                    t.name.clone(),
                    df,
                    n,
                    r.total_cycles(),
                    r.overall_utilization(n * n),
                ));
            }
        }
    }
    out
}

#[test]
fn legacy_dataflow_sweep_is_bit_identical_to_pre_engine_reference() {
    let base = config::paper_default();
    let topos = small_suite();
    let arrays = [16u64, 8, 5];
    let got = sweep::dataflow_sweep(&base, &topos, &arrays, 4);
    let want = reference_dataflow_sweep(&base, &topos, &arrays);
    assert_eq!(got.len(), want.len());
    for (g, (name, df, n, cycles, util)) in got.iter().zip(&want) {
        assert_eq!(&g.workload, name);
        assert_eq!(g.dataflow, *df);
        assert_eq!(g.array, *n);
        assert_eq!(g.cycles, *cycles, "{name} {df} {n}");
        assert!(g.utilization == *util, "utilization must be bit-identical");
    }
}

#[test]
fn legacy_memory_sweep_matches_simulator_reference() {
    let base = config::paper_default();
    let topos = small_suite();
    let kbs = [1u64, 8, 64, 512];
    let got = sweep::memory_sweep(&base, &topos, &kbs, 4);
    let mut i = 0;
    for t in &topos {
        for &kb in &kbs {
            let cfg = ArchConfig { ifmap_sram_kb: kb, filter_sram_kb: kb, ..base.clone() };
            let r = Simulator::new(cfg).run_topology(t);
            assert_eq!(got[i].workload, t.name);
            assert_eq!(got[i].sram_kb, kb);
            assert_eq!(got[i].dram_bytes, r.total_dram().total(), "{} {kb}", t.name);
            assert!(got[i].avg_read_bw == r.avg_dram_read_bw());
            i += 1;
        }
    }
    assert_eq!(i, got.len());
}

#[test]
fn legacy_shape_sweep_matches_simulator_reference() {
    let base = config::paper_default();
    let topos = small_suite();
    let shapes = [(4u64, 16u64), (8, 8), (16, 4)];
    let got = sweep::shape_sweep(&base, &topos, &shapes, 4);
    let mut i = 0;
    for t in &topos {
        for &df in &Dataflow::ALL {
            for &(r, c) in &shapes {
                let cfg = ArchConfig { array_h: r, array_w: c, dataflow: df, ..base.clone() };
                let want = Simulator::new(cfg).run_topology(t).total_cycles();
                assert_eq!(
                    (got[i].workload.as_str(), got[i].dataflow, got[i].rows, got[i].cols),
                    (t.name.as_str(), df, r, c)
                );
                assert_eq!(got[i].cycles, want, "{} {df} {r}x{c}", t.name);
                i += 1;
            }
        }
    }
    assert_eq!(i, got.len());
}

#[test]
fn engine_grid_reports_cache_hits_and_identical_results_on_rerun() {
    let engine = Engine::new(config::paper_default());
    let topos = small_suite();
    let first = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&[16, 8])
        .run();
    assert!(first.stats.memo.cache_hits > 0, "repeated shapes must hit");
    let second = engine
        .sweep()
        .workloads(&topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(&[16, 8])
        .run();
    assert_eq!(second.stats.memo.layer_sims, 0);
    for (a, b) in first.points.iter().zip(&second.points) {
        assert_eq!(a.report, b.report);
    }
}

#[test]
fn coordinator_shim_equals_engine_run() {
    use scale_sim::coordinator::{run, RunSpec};
    let mut cfg = config::paper_default();
    cfg.array_h = 16;
    cfg.array_w = 16;
    for df in Dataflow::ALL {
        cfg.dataflow = df;
        let spec = RunSpec::new(cfg.clone(), small_suite().remove(0));
        let legacy = run(&spec).unwrap();
        let engine = Engine::builder().config(cfg.clone()).build().unwrap();
        let direct = engine.run(&spec.topology).unwrap();
        assert_eq!(legacy.report, direct.report, "{df}");
        // and both equal the plain Simulator path
        let sim_rep = Simulator::new(cfg.clone()).run_topology(&spec.topology);
        assert_eq!(legacy.report, sim_rep, "{df}");
    }
}

// ------------------------------------------------------- workload IR pins
//
// The typed workload IR (`workload::Workload`) replaced the raw csv
// parser as the front end; these tests pin the equivalences the redesign
// promised: legacy Table-II csv lowers bit-identically, GEMM-csv and
// conv-encoded GEMMs produce identical tiles/reports, and equivalent ops
// share memo-cache entries.

use scale_sim::config::workloads;
use scale_sim::workload::{Conv2d, Workload};

/// An independent mini-parser over the embedded csv text — the reference
/// the IR lowering must reproduce exactly (deliberately NOT routed
/// through any crate parsing code).
fn reference_rows(text: &str) -> Vec<LayerShape> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cells: Vec<&str> = line.split(',').map(str::trim).filter(|c| !c.is_empty()).collect();
        if i == 0 && cells[1].parse::<u64>().is_err() {
            continue; // header
        }
        let n = |j: usize| cells[j].parse::<u64>().unwrap();
        out.push(LayerShape::conv(cells[0], n(1), n(2), n(3), n(4), n(5), n(6), n(7)));
    }
    out
}

#[test]
fn legacy_table_ii_csv_lowers_bit_identically() {
    for (name, text) in [
        ("resnet50", include_str!("../../topologies/resnet50.csv")),
        ("mobilenetv1", include_str!("../../topologies/mobilenetv1.csv")),
        ("ncf", include_str!("../../topologies/ncf.csv")),
        ("transformer", include_str!("../../topologies/transformer.csv")),
    ] {
        let want = reference_rows(text);
        assert!(!want.is_empty(), "{name}");
        let via_ir = Workload::parse_conv_csv(name, name, text).unwrap().lower().unwrap();
        assert_eq!(via_ir.layers, want, "{name}: IR lowering must be verbatim");
        let via_shim = Topology::parse(name, text).unwrap();
        assert_eq!(via_shim, via_ir, "{name}: Topology::parse is a shim over the IR");
        let builtin = workloads::builtin(name).unwrap();
        assert_eq!(builtin.layers, want, "{name}: embedded builtin agrees");
    }
}

#[test]
fn legacy_csv_reports_bit_identical_through_the_workload_path() {
    // same layers, two front doors, one engine: reports must match the
    // cache-free Simulator reference bit-for-bit
    let topo = workloads::builtin("ncf").unwrap();
    let cfg = ArchConfig { array_h: 32, array_w: 32, ..config::paper_default() };
    let engine = Engine::new(cfg.clone());
    let via_workload = engine
        .run_workload(&Workload::from_topology(&topo))
        .unwrap()
        .report;
    let reference = Simulator::new(cfg).run_topology(&topo);
    assert_eq!(via_workload, reference);
}

#[test]
fn gemm_workload_runs_end_to_end_on_all_three_backends() {
    let wl = Workload::builder("g3")
        .gemm("mm", 24, 40, 16)
        .fc("fc", 4, 96, 32)
        .build()
        .unwrap();
    let mut reports = Vec::new();
    for kind in BackendKind::ALL {
        let engine = Engine::builder().array(16, 16).backend(kind).build().unwrap();
        reports.push(engine.run_workload(&wl).unwrap().report);
    }
    assert_eq!(reports[0], reports[1], "trace-driven deviates");
    assert_eq!(reports[0], reports[2], "rtl deviates");
    assert_eq!(reports[0].layers.len(), 2);
}

#[test]
fn conv_and_equivalent_gemm_share_cache_entries() {
    // pointwise Conv2d op and the equivalent Gemm op, one engine: the
    // second lookup must be a cache hit with an identical report body
    let wl = Workload::builder("pair")
        .conv2d(
            "pw",
            Conv2d {
                ifmap_h: 14,
                ifmap_w: 14,
                in_channels: 64,
                out_channels: 96,
                ..Conv2d::default()
            },
        )
        .gemm("g", 14 * 14, 64, 96)
        .build()
        .unwrap();
    let engine = Engine::new(config::paper_default());
    let report = engine.run_workload(&wl).unwrap().report;
    let stats = engine.cache_stats();
    assert_eq!(stats.layer_sims, 1, "one tile simulated");
    assert_eq!(stats.cache_hits, 1, "the twin is served from the cache");
    assert_eq!(report.layers[0].timing, report.layers[1].timing);
    assert_eq!(report.layers[0].dram, report.layers[1].dram);
    assert_eq!(report.layers[0].energy, report.layers[1].energy);

    // and across csv front ends: the GEMM re-encoding of ncf replays the
    // conv-encoded builtin entirely from cache
    let engine = Engine::new(config::paper_default());
    engine.run_topology(&workloads::builtin("ncf").unwrap());
    let sims = engine.cache_stats().layer_sims;
    let gemm = workloads::builtin_gemm("ncf_gemm").unwrap().lower().unwrap();
    engine.run_topology(&gemm);
    assert_eq!(engine.cache_stats().layer_sims, sims, "no new sims for the GEMM re-encoding");
}

#[test]
fn dilated_and_grouped_convs_lower_to_valid_tiles_on_all_backends() {
    let wl = Workload::builder("exotic")
        .conv2d(
            "dil",
            Conv2d {
                ifmap_h: 20,
                ifmap_w: 20,
                in_channels: 4,
                out_channels: 8,
                kernel_h: 3,
                kernel_w: 3,
                dilation: 2,
                ..Conv2d::default()
            },
        )
        .conv2d(
            "grp",
            Conv2d {
                ifmap_h: 12,
                ifmap_w: 12,
                in_channels: 8,
                out_channels: 16,
                kernel_h: 3,
                kernel_w: 3,
                groups: 2,
                ..Conv2d::default()
            },
        )
        .depthwise("dw", 16, 16, 8, 3, 1)
        .pool("mp", 14, 14, 8, 2, 2)
        .build()
        .unwrap();
    let topo = wl.lower().unwrap();
    assert_eq!(topo.layers.len(), 5, "grouped conv expands to 2 tiles");
    let a = Engine::builder().array(8, 8).build().unwrap();
    let b = Engine::builder().array(8, 8).backend(BackendKind::TraceDriven).build().unwrap();
    assert_eq!(a.run_topology(&topo), b.run_topology(&topo));
}

// ------------------------------------------------------------------
// Scale-out shims: the deprecated `scaleout` closed forms must stay
// bit-identical to the engine's multi-array path they now delegate to.
// The reference below is an independent copy of the ORIGINAL pre-engine
// closed forms (dataflow timing + memory::simulate, no memoization).

mod legacy_scaleout_reference {
    use scale_sim::config::ArchConfig;
    use scale_sim::engine::multi::{Partition, NODE_DIM, NODE_PES};
    use scale_sim::memory;
    use scale_sim::util::{ceil_div, isqrt};
    use scale_sim::LayerShape;

    pub fn scale_out_point(
        base: &ArchConfig,
        layer: &LayerShape,
        nodes: u64,
        partition: Partition,
    ) -> (u64, u64) {
        let df = base.dataflow;
        let node_cfg = ArchConfig { array_h: NODE_DIM, array_w: NODE_DIM, ..base.clone() };
        match partition {
            Partition::OutputChannels => {
                let per_node = ceil_div(layer.num_filters, nodes);
                let used = ceil_div(layer.num_filters, per_node);
                let nl = LayerShape { num_filters: per_node, ..layer.clone() };
                let cycles = df.timing(&nl, NODE_DIM, NODE_DIM).cycles;
                let (node_dram, _) = memory::simulate(df, &nl, &node_cfg);
                (cycles, node_dram.filter_bytes * used)
            }
            Partition::Pixels => {
                let eh = layer.ofmap_h();
                let rows_per_node = ceil_div(eh, nodes);
                let used = ceil_div(eh, rows_per_node);
                let ifmap_h = (rows_per_node - 1) * layer.stride + layer.filt_h;
                let nl = LayerShape { ifmap_h, ..layer.clone() };
                let cycles = df.timing(&nl, NODE_DIM, NODE_DIM).cycles;
                let (node_dram, _) = memory::simulate(df, &nl, &node_cfg);
                (cycles, node_dram.filter_bytes * used)
            }
            Partition::Auto => {
                let a = scale_out_point(base, layer, nodes, Partition::OutputChannels);
                let b = scale_out_point(base, layer, nodes, Partition::Pixels);
                if b.0 < a.0 {
                    b
                } else {
                    a
                }
            }
        }
    }

    pub fn compare_topology(
        base: &ArchConfig,
        layers: &[LayerShape],
        pe_budget: u64,
        partition: Partition,
    ) -> (u64, u64, u64, f64, f64) {
        assert!(pe_budget >= NODE_PES);
        let df = base.dataflow;
        let dim = isqrt(pe_budget);
        assert_eq!(dim * dim, pe_budget);
        let up_cfg = ArchConfig { array_h: dim, array_w: dim, ..base.clone() };
        let nodes = pe_budget / NODE_PES;
        let mut up_cycles = 0u64;
        let mut out_cycles = 0u64;
        let mut up_weight_bytes = 0f64;
        let mut out_weight_bytes = 0f64;
        for layer in layers {
            let up_c = df.timing(layer, dim, dim).cycles;
            let (up_dram, _) = memory::simulate(df, layer, &up_cfg);
            let up_weight_bw = up_dram.filter_bytes as f64 / up_c as f64;
            let (out_c, out_bytes) = scale_out_point(base, layer, nodes, partition);
            let out_weight_bw = out_bytes as f64 / out_c as f64;
            up_cycles += up_c;
            out_cycles += out_c;
            up_weight_bytes += up_weight_bw * up_c as f64;
            out_weight_bytes += out_weight_bw * out_c as f64;
        }
        (
            nodes,
            up_cycles,
            out_cycles,
            up_weight_bytes / up_cycles as f64,
            out_weight_bytes / out_cycles as f64,
        )
    }
}

#[test]
fn scaleout_shims_are_bit_identical_to_the_legacy_closed_forms() {
    use scale_sim::engine::multi::{Partition, PE_SWEEP};
    use scale_sim::scaleout;

    let layers = vec![
        LayerShape::conv("a", 32, 32, 3, 3, 16, 100, 1), // uneven channel split
        LayerShape::conv("b", 19, 19, 3, 3, 64, 256, 1),
        LayerShape::conv("s2", 30, 30, 5, 5, 8, 24, 2), // strided pixel stripes
        LayerShape::fc("fc", 4, 512, 300),
        LayerShape::gemm("g", 129, 64, 2048), // residual-fold spill
    ];
    for df in Dataflow::ALL {
        let base = ArchConfig { dataflow: df, ..config::paper_default() };
        let engine = Engine::new(base.clone());
        for partition in Partition::ALL {
            // per-layer scale-out points at assorted node counts
            for layer in &layers {
                for &nodes in &[1u64, 3, 16, 64, 200] {
                    let want =
                        legacy_scaleout_reference::scale_out_point(&base, layer, nodes, partition);
                    let got = scaleout::scale_out_point(&base, layer, nodes, partition);
                    assert_eq!(got, want, "{df} {partition:?} nodes={nodes} {}", layer.name);
                }
            }
            // whole-topology comparison across the paper's PE sweep
            for &pe in &PE_SWEEP {
                let (nodes, up_c, out_c, up_bw, out_bw) =
                    legacy_scaleout_reference::compare_topology(&base, &layers, pe, partition);
                let via_engine = engine.compare_scaling_with(&layers, pe, partition);
                assert_eq!(via_engine.nodes, nodes, "{df} {partition:?} {pe}");
                assert_eq!(via_engine.up_cycles, up_c, "{df} {partition:?} {pe}");
                assert_eq!(via_engine.out_cycles, out_c, "{df} {partition:?} {pe}");
                assert_eq!(
                    via_engine.up_weight_bw.to_bits(),
                    up_bw.to_bits(),
                    "{df} {partition:?} {pe}: up weight bw must be bit-identical"
                );
                assert_eq!(
                    via_engine.out_weight_bw.to_bits(),
                    out_bw.to_bits(),
                    "{df} {partition:?} {pe}: out weight bw must be bit-identical"
                );
                // the deprecated free-function shims route through the
                // same engine path
                if partition == Partition::OutputChannels {
                    let shim = scaleout::compare_topology(&base, &layers, pe);
                    assert_eq!(shim, via_engine, "{df} {pe}");
                }
                let shim_layer =
                    scaleout::compare_layer_with(&base, &layers[0], pe, partition);
                let engine_layer = engine.compare_scaling_with(
                    std::slice::from_ref(&layers[0]),
                    pe,
                    partition,
                );
                assert_eq!(shim_layer, engine_layer, "{df} {partition:?} {pe}");
            }
        }
    }
}
