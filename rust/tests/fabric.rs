//! Property suite for the route-aware fabric + banked-DRAM substrate.
//!
//! Pins the structural guarantees the model documents (flow
//! conservation, Mesh dominating Line at equal link bandwidth,
//! single-node transparency) plus the wrong-share stall regression: the
//! layer's stall must follow whichever node finishes last, which under
//! fabric contention can be the REMAINDER node, not the maximal share.

use scale_sim::arch::LayerShape;
use scale_sim::config::{workloads, ArchConfig};
use scale_sim::engine::multi::{MultiArrayConfig, MultiOpts, Partition, NODE_DIM};
use scale_sim::engine::{Engine, FabricConfig, FabricKind, DEFAULT_LINK_BW};
use scale_sim::Dataflow;

fn engine() -> Engine {
    Engine::builder().dataflow(Dataflow::Os).build().unwrap()
}

fn fabric_opts(kind: FabricKind, link_bw: f64, dram_bw: Option<f64>) -> MultiOpts {
    MultiOpts {
        shared_dram_bw: dram_bw,
        fabric: Some(FabricConfig::new(kind, link_bw)),
        dram: None,
    }
}

fn resnet_head() -> Vec<LayerShape> {
    workloads::builtin("resnet50").unwrap().layers.into_iter().take(3).collect()
}

#[test]
fn link_flows_conserve_bytes() {
    // every byte is accounted on every link it crosses: the per-link
    // totals sum to demand x hops exactly, for every topology
    let e = engine();
    let l = LayerShape::conv("c", 30, 30, 3, 3, 16, 100, 1);
    for kind in [FabricKind::Line, FabricKind::Ring, FabricKind::Mesh] {
        let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::OutputChannels);
        let m = e.run_multi_layer_opts(
            e.cfg(),
            &l,
            &multi,
            &fabric_opts(kind, DEFAULT_LINK_BW, Some(16.0)),
        );
        let f = m.fabric.as_ref().expect("fabric enabled");
        assert_eq!(f.total_link_bytes(), f.hop_bytes, "{kind:?}");
        assert!(f.hop_bytes > 0, "{kind:?}: multi-node traffic must cross links");
        assert_eq!(f.node_total_cycles.len(), m.used_nodes as usize, "{kind:?}");
    }
}

#[test]
fn mesh_is_never_slower_than_line_at_equal_link_bw() {
    // every mesh route's link loads embed termwise into the line's, so
    // per-node effective bandwidth — and hence the layer stall — can
    // only improve; and at 16 nodes the two fabrics must actually
    // differ (the acceptance criterion for the substrate)
    let e = engine();
    let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::OutputChannels);
    let mut stalls_differ = false;
    let mut peaks_differ = false;
    for l in resnet_head() {
        let line = e.run_multi_layer_opts(
            e.cfg(),
            &l,
            &multi,
            &fabric_opts(FabricKind::Line, DEFAULT_LINK_BW, Some(16.0)),
        );
        let mesh = e.run_multi_layer_opts(
            e.cfg(),
            &l,
            &multi,
            &fabric_opts(FabricKind::Mesh, DEFAULT_LINK_BW, Some(16.0)),
        );
        assert!(mesh.stall_cycles <= line.stall_cycles, "{}", l.name);
        stalls_differ |= mesh.stall_cycles != line.stall_cycles;
        let (fl, fm) = (line.fabric.as_ref().unwrap(), mesh.fabric.as_ref().unwrap());
        peaks_differ |= fl.max_link_peak_bw() != fm.max_link_peak_bw();
    }
    assert!(stalls_differ, "16-node mesh vs line must report different stalls");
    assert!(peaks_differ, "16-node mesh vs line must report different per-link peaks");
}

#[test]
fn single_node_fabric_matches_the_plain_engine_bit_for_bit() {
    let e = engine();
    let l = LayerShape::conv("c", 28, 28, 3, 3, 16, 32, 1);
    let node_cfg = ArchConfig { array_h: 8, array_w: 8, ..e.cfg().clone() };
    let plain = e.run_layer_with(&node_cfg, &l);
    for kind in [FabricKind::Line, FabricKind::Ring, FabricKind::Mesh] {
        let multi = MultiArrayConfig::new(1, NODE_DIM, NODE_DIM, Partition::OutputChannels);
        // no DRAM bandwidth: fully unconstrained, zero stalls
        let m = e.run_multi_layer_opts(e.cfg(), &l, &multi, &fabric_opts(kind, 4.0, None));
        assert_eq!(m.node_report, plain, "{kind:?}");
        assert_eq!(m.stall_cycles, 0, "{kind:?}");
        // with one: the single node gets the FULL bandwidth (the
        // demand-proportional share of one node is exactly 1.0), so the
        // stall matches the legacy flat path bit-for-bit
        let m = e.run_multi_layer_opts(e.cfg(), &l, &multi, &fabric_opts(kind, 4.0, Some(16.0)));
        let flat = e.run_multi_layer_with(e.cfg(), &l, &multi, Some(16.0));
        assert_eq!(m.stall_cycles, flat.stall_cycles, "{kind:?}");
        assert_eq!(m.cycles, flat.cycles, "{kind:?}");
    }
}

#[test]
fn flat_fabric_kind_keeps_the_legacy_path() {
    let e = engine();
    let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 100, 1);
    let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::OutputChannels);
    let m = e.run_multi_layer_opts(
        e.cfg(),
        &l,
        &multi,
        &fabric_opts(FabricKind::Flat, DEFAULT_LINK_BW, Some(16.0)),
    );
    let legacy = e.run_multi_layer_with(e.cfg(), &l, &multi, Some(16.0));
    assert!(m.fabric.is_none(), "flat kind must not build a fabric report");
    assert_eq!(m.stall_cycles, legacy.stall_cycles);
    assert_eq!(m.cycles, legacy.cycles);
}

#[test]
fn stall_follows_the_remainder_node_when_it_is_slowest() {
    // channels-partitioning 100 filters over 16 Line nodes leaves a
    // 2-filter remainder share on the FARTHEST node; at a tight link
    // bandwidth its store-and-forward path time makes it the slowest
    // node even though its shape is the smallest. The layer stall must
    // follow it — selecting the maximal share's replay (the historical
    // behavior) reports a different, smaller stall.
    let e = engine();
    let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 100, 1);
    let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::OutputChannels);
    let m = e.run_multi_layer_opts(e.cfg(), &l, &multi, &fabric_opts(FabricKind::Line, 0.5, None));
    assert_eq!(m.used_nodes, 15, "14 full nodes + 1 remainder");
    let f = m.fabric.as_ref().expect("fabric enabled");
    let totals = &f.node_total_cycles;
    assert_eq!(totals.len(), 15);
    let rem_total = *totals.last().unwrap();
    let main_max = *totals[..totals.len() - 1].iter().max().unwrap();
    assert!(
        rem_total > main_max,
        "remainder node must be the slowest (rem {rem_total} vs main {main_max})"
    );
    // the stall is the remainder's completion beyond the stall-free
    // runtime — and differs from the maximal share's replay
    assert_eq!(m.stall_cycles, rem_total - m.cycles);
    assert_ne!(m.stall_cycles, main_max - m.cycles, "main-share-only selection is wrong here");
    // pinned against the independent Python port (gen_fabric.py)
    assert_eq!(m.stall_cycles, 524572);
    assert_eq!(m.cycles, 2317);
}

#[test]
fn fabric_metrics_are_deterministic() {
    // the fabric + stall composition is pure integer/f64 arithmetic: two
    // runs agree exactly (the reports join the golden-pinned class)
    let e = engine();
    let l = LayerShape::conv("c", 30, 30, 3, 3, 16, 100, 1);
    let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::Auto);
    let opts = fabric_opts(FabricKind::Mesh, DEFAULT_LINK_BW, Some(16.0));
    let a = e.run_multi_layer_opts(e.cfg(), &l, &multi, &opts);
    let b = e.run_multi_layer_opts(e.cfg(), &l, &multi, &opts);
    assert_eq!(a, b);
    assert_ne!(a.partition, Partition::Auto, "auto must resolve under the fabric too");
}
