#!/usr/bin/env python3
"""Generate rust/tests/golden/timings.json by porting the analytical model.

Every formula is ported 1:1 from the Rust sources (dataflow/{os,ws,is}.rs,
dataflow/mod.rs, trace/folds.rs, memory/mod.rs, memory/stall.rs). Before
emitting the fixture, the port is validated against the hand-computed
values asserted in the repo's own Rust unit tests; any mismatch aborts.
"""
import json
import math
import os
import sys

REPO = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", ".."))

# ---------------------------------------------------------------- layer shape

class Layer:
    def __init__(self, name, ih, iw, fh, fw, c, nf, s):
        self.name, self.ifmap_h, self.ifmap_w = name, ih, iw
        self.filt_h, self.filt_w, self.channels = fh, fw, c
        self.num_filters, self.stride = nf, s

    def ofmap_h(self): return (self.ifmap_h - self.filt_h) // self.stride + 1
    def ofmap_w(self): return (self.ifmap_w - self.filt_w) // self.stride + 1
    def npx(self): return self.ofmap_h() * self.ofmap_w()
    def window(self): return self.filt_h * self.filt_w * self.channels
    def macs(self): return self.npx() * self.window() * self.num_filters
    def ifmap_elems(self): return self.ifmap_h * self.ifmap_w * self.channels
    def filter_elems(self): return self.window() * self.num_filters
    def ofmap_elems(self): return self.npx() * self.num_filters
    def gemm_view(self): return (self.npx(), self.window(), self.num_filters)

def gemm(name, m, k, n):
    return Layer(name, m, 1, 1, 1, k, n, 1)

def ceil_div(a, b): return -(-a // b)

# ---------------------------------------------------------------- fold shapes

def for_fold_shapes(total_r, rows, total_c, cols):
    """Yield (count, r_used, c_used) for the at-most-4 distinct shapes."""
    full_r, resid_r = total_r // rows, total_r % rows
    full_c, resid_c = total_c // cols, total_c % cols
    if full_r > 0 and full_c > 0: yield (full_r * full_c, rows, cols)
    if resid_r > 0 and full_c > 0: yield (full_c, resid_r, cols)
    if full_r > 0 and resid_c > 0: yield (full_r, rows, resid_c)
    if resid_r > 0 and resid_c > 0: yield (1, resid_r, resid_c)

def mapping_efficiency(total_r, rows, total_c, cols):
    mapped = nfolds = 0
    for n, r, c in for_fold_shapes(total_r, rows, total_c, cols):
        mapped += n * r * c
        nfolds += n
    return mapped / (rows * cols * nfolds)

# ---------------------------------------------------------------- timings

def os_fold_cycles(r, c, k): return 2 * r + c + k - 2
def ws_fold_cycles(r, c, npx): return 2 * r + c + npx - 1
def is_fold_cycles(r, c, nf): return 2 * r + c + nf - 1

def timing(df, layer, rows, cols):
    npx, k, nf = layer.gemm_view()
    if df == "os":
        row_folds, col_folds = ceil_div(npx, rows), ceil_div(nf, cols)
        cycles = sum(n * os_fold_cycles(r, c, k)
                     for n, r, c in for_fold_shapes(npx, rows, nf, cols))
        sram = dict(
            sram_reads_ifmap=k * npx * col_folds,
            sram_reads_filter=k * nf * row_folds,
            sram_writes_ofmap=npx * nf,
            sram_reads_ofmap=0,
        )
        meff = mapping_efficiency(npx, rows, nf, cols)
    elif df == "ws":
        row_folds, col_folds = ceil_div(k, rows), ceil_div(nf, cols)
        cycles = sum(n * ws_fold_cycles(r, c, npx)
                     for n, r, c in for_fold_shapes(k, rows, nf, cols))
        sram = dict(
            sram_reads_ifmap=npx * k * col_folds,
            sram_reads_filter=k * nf,
            sram_writes_ofmap=npx * nf * row_folds,
            sram_reads_ofmap=npx * nf * (row_folds - 1),
        )
        meff = mapping_efficiency(k, rows, nf, cols)
    elif df == "is":
        row_folds, col_folds = ceil_div(k, rows), ceil_div(npx, cols)
        cycles = sum(n * is_fold_cycles(r, c, nf)
                     for n, r, c in for_fold_shapes(k, rows, npx, cols))
        sram = dict(
            sram_reads_ifmap=k * npx,
            sram_reads_filter=nf * k * col_folds,
            sram_writes_ofmap=npx * nf * row_folds,
            sram_reads_ofmap=npx * nf * (row_folds - 1),
        )
        meff = mapping_efficiency(k, rows, npx, cols)
    else:
        raise ValueError(df)
    return dict(
        cycles=cycles,
        row_folds=row_folds,
        col_folds=col_folds,
        utilization=layer.macs() / (rows * cols * cycles),
        mapping_efficiency=meff,
        **sram,
    )

# ---------------------------------------------------------------- fold schedule

def fold_schedule(df, layer, rows, cols):
    """Yield folds as dicts, mirroring trace/folds.rs iteration order."""
    npx, k, nf = layer.gemm_view()
    if df == "os":
        total_r, total_c, stream = npx, nf, k
        fold_cycles = os_fold_cycles
    elif df == "ws":
        total_r, total_c, stream = k, nf, npx
        fold_cycles = ws_fold_cycles
    else:
        total_r, total_c, stream = k, npx, nf
        fold_cycles = is_fold_cycles
    row_folds = ceil_div(total_r, rows)
    col_folds = ceil_div(total_c, cols)
    if df == "os":
        outer_count, inner_count = row_folds, col_folds
    else:
        outer_count, inner_count = col_folds, row_folds

    def rng(total, tile, idx):
        lo = idx * tile
        return (lo, min(lo + tile, total))

    for outer in range(outer_count):
        for inner in range(inner_count):
            if df == "os":
                row_idx, col_idx = outer, inner
            else:
                row_idx, col_idx = inner, outer
            row_range = rng(total_r, rows, row_idx)
            col_range = rng(total_c, cols, col_idx)
            r_used = row_range[1] - row_range[0]
            c_used = col_range[1] - col_range[0]
            yield dict(
                cycles=fold_cycles(r_used, c_used, stream),
                r_used=r_used, c_used=c_used,
                row_range=row_range, col_range=col_range,
            )

# ---------------------------------------------------------------- memory model

class SegCache:
    def __init__(self, cap):
        self.cap, self.used = cap, 0
        self.fifo, self.resident = [], {}

    def touch(self, seg, nbytes):
        if nbytes == 0: return 0
        if seg in self.resident: return 0
        if nbytes > self.cap: return nbytes
        while self.used + nbytes > self.cap:
            victim = self.fifo.pop(0)
            self.used -= self.resident.pop(victim)
        self.resident[seg] = nbytes
        self.fifo.append(seg)
        self.used += nbytes
        return nbytes

class RowCache:
    def __init__(self, cap, row_bytes, rows):
        self.cap, self.used, self.row_bytes = cap, 0, row_bytes
        self.resident = [False] * rows
        self.fifo = []

    def touch(self, y):
        if self.resident[y]: return 0
        if self.row_bytes > self.cap: return self.row_bytes
        while self.used + self.row_bytes > self.cap:
            victim = self.fifo.pop(0)
            self.resident[victim] = False
            self.used -= self.row_bytes
        self.resident[y] = True
        self.fifo.append(y)
        self.used += self.row_bytes
        return self.row_bytes

def ifmap_row_span(layer, p0, p1):
    ew = layer.ofmap_w()
    oy0 = p0 // ew
    oy1 = (p1 - 1) // ew
    y0 = oy0 * layer.stride
    y1 = min(oy1 * layer.stride + layer.filt_h, layer.ifmap_h)
    return (y0, y1)

def ifmap_region_bytes(layer, p0, p1, word):
    y0, y1 = ifmap_row_span(layer, p0, p1)
    return (y1 - y0) * layer.ifmap_w * layer.channels * word

class Cfg:
    def __init__(self, rows, cols, ifmap_kb=512, filter_kb=512, ofmap_kb=256, word=1):
        self.array_h, self.array_w, self.word_bytes = rows, cols, word
        self.ifmap_sram_kb, self.filter_sram_kb, self.ofmap_sram_kb = ifmap_kb, filter_kb, ofmap_kb

    def ifmap_sram_bytes(self): return self.ifmap_sram_kb * 1024
    def filter_sram_bytes(self): return self.filter_sram_kb * 1024
    def ofmap_sram_bytes(self): return self.ofmap_sram_kb * 1024

def simulate_with(df, layer, cfg):
    """Returns (traffic dict, fetches list of (cycles, bytes))."""
    word = cfg.word_bytes
    npx, k, nf = layer.gemm_view()
    ifmap = SegCache(cfg.ifmap_sram_bytes())
    ifmap_rows = RowCache(cfg.ifmap_sram_bytes(),
                          layer.ifmap_w * layer.channels * word, layer.ifmap_h)
    filt = SegCache(cfg.filter_sram_bytes())
    traffic = dict(ifmap_bytes=0, filter_bytes=0, ofmap_bytes=0)
    fetches = []
    total_cycles = 0
    for fold in fold_schedule(df, layer, cfg.array_h, cfg.array_w):
        if df == "os":
            fi = 0
            y0, y1 = ifmap_row_span(layer, fold["row_range"][0], fold["row_range"][1])
            for y in range(y0, y1):
                fi += ifmap_rows.touch(y)
            fseg = fold["col_range"][0] // cfg.array_w
            fb = fold["c_used"] * k * word
            ff = filt.touch(fseg, fb)
        elif df == "ws":
            iseg = fold["row_range"][0] // cfg.array_h
            ib = ceil_div(layer.ifmap_elems() * fold["r_used"], k) * word
            fi = ifmap.touch(iseg, ib)
            ff = fold["r_used"] * fold["c_used"] * word
        else:  # is
            region = ifmap_region_bytes(layer, fold["col_range"][0], fold["col_range"][1], word)
            iseg = fold["col_range"][0] // cfg.array_w * 1_000_003 + fold["row_range"][0] // cfg.array_h
            ib = ceil_div(region * fold["r_used"], k)
            fi = ifmap.touch(iseg, ib)
            fseg = fold["row_range"][0] // cfg.array_h
            fb = nf * fold["r_used"] * word
            ff = filt.touch(fseg, fb)
        traffic["ifmap_bytes"] += fi
        traffic["filter_bytes"] += ff
        fetched = fi + ff
        total_cycles += fold["cycles"]
        fetches.append((fold["cycles"], fetched))

    window_folds = 1 if df == "os" else ceil_div(k, cfg.array_h)
    ofmap_total = layer.ofmap_elems() * word
    if window_folds == 1:
        traffic["ofmap_bytes"] = ofmap_total
    else:
        if df == "ws":
            partial_set = npx * min(cfg.array_w, nf) * word
        else:
            partial_set = min(cfg.array_w, npx) * nf * word
        if partial_set <= cfg.ofmap_sram_bytes():
            traffic["ofmap_bytes"] = ofmap_total
        else:
            traffic["ofmap_bytes"] = ofmap_total * (2 * window_folds - 1)
    return traffic, fetches

def stalled_runtime(df, layer, cfg, bw):
    _, fetches = simulate_with(df, layer, cfg)
    ideal = stall = 0
    for i, (cycles, nbytes) in enumerate(fetches):
        ideal += cycles
        fetch_cycles = math.ceil(nbytes / bw)
        if i == 0:
            stall += fetch_cycles
        else:
            window = fetches[i - 1][0]
            stall += max(fetch_cycles - window, 0)
    return dict(ideal_cycles=ideal, stall_cycles=stall)

# ---------------------------------------------------------------- self-checks

def check(cond, msg):
    if not cond:
        print("SELF-CHECK FAILED:", msg, file=sys.stderr)
        sys.exit(1)

def self_checks():
    # --- os.rs unit tests
    t = timing("os", gemm("mm", 8, 8, 8), 8, 8)
    check(t["cycles"] == 30 and t["row_folds"] == 1 and t["col_folds"] == 1, "os 8x8x8")
    check(t["sram_reads_ifmap"] == 64 and t["sram_reads_filter"] == 64
          and t["sram_writes_ofmap"] == 64 and t["sram_reads_ofmap"] == 0, "os sram 8x8x8")
    check(timing("os", gemm("mm", 16, 8, 16), 8, 8)["cycles"] == 4 * 30, "os folds multiply")
    check(timing("os", gemm("mm", 9, 8, 8), 8, 8)["cycles"] == 30 + 16, "os residual")
    l = Layer("c", 12, 12, 3, 3, 4, 10, 1)
    check(timing("os", l, 8, 8)["sram_writes_ofmap"] == l.npx() * 10, "os ofmap writes")
    l2 = gemm("a", 8, 8, 8); l3 = gemm("b", 8, 8, 16)
    check(timing("os", l3, 8, 8)["sram_reads_ifmap"] == 2 * timing("os", l2, 8, 8)["sram_reads_ifmap"],
          "os ifmap reads scale with col folds")

    # --- ws.rs unit tests
    t = timing("ws", gemm("mm", 8, 8, 8), 8, 8)
    check(t["cycles"] == 31 and t["sram_reads_filter"] == 64 and t["sram_reads_ifmap"] == 64
          and t["sram_writes_ofmap"] == 64 and t["sram_reads_ofmap"] == 0, "ws 8x8x8")
    t = timing("ws", gemm("mm", 8, 16, 8), 8, 8)
    check(t["row_folds"] == 2 and t["sram_writes_ofmap"] == 128 and t["sram_reads_ofmap"] == 64,
          "ws window fold")
    l = Layer("c", 14, 14, 3, 3, 32, 48, 1)
    check(timing("ws", l, 16, 16)["sram_reads_filter"] == l.filter_elems(), "ws weights once")
    l = Layer("c", 112, 112, 1, 1, 8, 8, 1)
    check(timing("ws", l, 8, 8)["cycles"] == ws_fold_cycles(8, 8, l.npx()), "ws npx stream")
    l = Layer("c", 64, 64, 3, 3, 8, 8, 1)
    check(timing("ws", l, 16, 16)["cycles"] < timing("is", l, 16, 16)["cycles"], "ws beats is")
    l = gemm("fc", 4, 2048, 1024)
    check(timing("is", l, 16, 16)["cycles"] < timing("ws", l, 16, 16)["cycles"], "is beats ws")

    # --- is.rs unit tests
    t = timing("is", gemm("mm", 8, 8, 8), 8, 8)
    check(t["cycles"] == 31 and t["sram_reads_ifmap"] == 64 and t["sram_reads_filter"] == 64,
          "is 8x8x8")
    l = gemm("mm", 24, 40, 24)
    check(timing("is", l, 8, 8)["cycles"] == timing("ws", l, 8, 8)["cycles"], "is/ws dual")
    l = Layer("c", 10, 10, 3, 3, 4, 7, 1)
    check(timing("is", l, 8, 8)["sram_reads_ifmap"] == l.window() * l.npx(), "is ifmap once")
    t = timing("is", gemm("mm", 8, 20, 8), 8, 8)
    check(t["row_folds"] == 3 and t["sram_reads_ofmap"] == 2 * 64, "is partial folds")

    # --- dataflow/mod.rs tests
    for (tr, r, tc, c) in [(10, 4, 7, 3), (8, 8, 8, 8), (1, 128, 1, 128), (129, 64, 300, 7)]:
        area = sum(n * ru * cu for n, ru, cu in for_fold_shapes(tr, r, tc, c))
        check(area == tr * tc, f"fold shapes partition {(tr, r, tc, c)}")
    check(mapping_efficiency(16, 8, 24, 8) == 1.0, "meff exact")
    l = Layer("c", 19, 19, 3, 3, 256, 256, 1)
    check(l.window() > l.npx(), "alphago window")
    for n in (8, 16, 32, 64, 128):
        o = timing("os", l, n, n)["cycles"]
        w = timing("ws", l, n, n)["cycles"]
        i = timing("is", l, n, n)["cycles"]
        check(o <= w and o <= i, f"os wins {n}")

    # --- trace/folds.rs tests
    l = Layer("c", 10, 10, 3, 3, 4, 10, 1)
    for df in ("os", "ws", "is"):
        t = timing(df, l, 8, 8)
        folds = list(fold_schedule(df, l, 8, 8))
        check(len(folds) == t["row_folds"] * t["col_folds"], f"{df} fold count")
        check(sum(f["cycles"] for f in folds) == t["cycles"], f"{df} fold cycles")
        npx, k, nf = l.gemm_view()
        tr, tc = dict(os=(npx, nf), ws=(k, nf), **{"is": (k, npx)})[df]
        covered = sum(f["r_used"] * f["c_used"] for f in folds)
        check(covered == tr * tc, f"{df} fold coverage")

    # --- memory/mod.rs tests
    l = Layer("c", 28, 28, 3, 3, 16, 32, 1)
    tr, _ = simulate_with("os", l, Cfg(16, 16, 2048, 2048, 2048))
    check(tr["ifmap_bytes"] == l.ifmap_elems() and tr["filter_bytes"] == l.filter_elems()
          and tr["ofmap_bytes"] == l.ofmap_elems(), "os big sram once")
    big = simulate_with("os", l, Cfg(16, 16, 2048, 2048, 2048))[0]
    tiny = simulate_with("os", l, Cfg(16, 16, 1, 1, 1))[0]
    check(sum(tiny.values()) > sum(big.values()), "tiny refetches")
    for df in ("os", "ws", "is"):
        last = None
        for kb in (1, 4, 16, 64, 256, 1024):
            tot = sum(simulate_with(df, l, Cfg(16, 16, kb, kb, kb))[0].values())
            check(last is None or tot <= last, f"{df} monotone {kb}")
            last = tot
    tr, _ = simulate_with("ws", l, Cfg(16, 16, 64, 64, 64))
    check(tr["filter_bytes"] == l.filter_elems(), "ws weights cross once")
    l = Layer("c", 30, 30, 3, 3, 64, 8, 1)
    spill = simulate_with("ws", l, Cfg(16, 16, 64, 64, 1))[0]["ofmap_bytes"]
    clean = simulate_with("ws", l, Cfg(16, 16, 64, 64, 1024))[0]["ofmap_bytes"]
    check(clean == l.ofmap_elems() and spill > clean, "ws partial spill")
    l = Layer("c", 10, 10, 3, 3, 2, 1, 1)
    check(ifmap_region_bytes(l, 0, 1, 1) == 3 * 10 * 2, "region single px")
    check(ifmap_region_bytes(l, 0, l.npx(), 1) == 10 * 10 * 2, "region full")

    # --- memory/stall.rs tests
    l = Layer("c", 28, 28, 3, 3, 16, 32, 1)
    cfg = Cfg(16, 16)
    r = stalled_runtime("os", l, cfg, 1e12)
    check(r["ideal_cycles"] == timing("os", l, 16, 16)["cycles"], "stall ideal cycles")
    check(r["stall_cycles"] <= 1, "stall near zero at infinite bw")
    last = 0
    for bw in (64.0, 16.0, 4.0, 1.0, 0.25):
        r = stalled_runtime("os", l, cfg, bw)
        check(r["stall_cycles"] >= last, f"stall monotone {bw}")
        last = r["stall_cycles"]
    check(last > 0, "low bw must stall")

    print("all self-checks passed", file=sys.stderr)

# ---------------------------------------------------------------- fixture

def load_conv_csv(path):
    layers = []
    with open(path) as f:
        rows = []
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            cells = [c.strip() for c in line.split(",")]
            if cells and cells[-1] == "":
                cells.pop()
            rows.append(cells)
    for i, row in enumerate(rows):
        if i == 0 and all(not c.isdigit() for c in row[1:]):
            continue  # header
        name = row[0]
        ih, iw, fh, fw, c, nf, s = (int(x) for x in row[1:8])
        layers.append(Layer(name, ih, iw, fh, fw, c, nf, s))
    return layers

def load_gemm_csv(path):
    layers = []
    with open(path) as f:
        rows = []
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            cells = [c.strip() for c in line.split(",")]
            if cells and cells[-1] == "":
                cells.pop()
            rows.append(cells)
    for i, row in enumerate(rows):
        if i == 0 and all(not c.isdigit() for c in row[1:]):
            continue
        name, m, n, k = row[0], int(row[1]), int(row[2]), int(row[3])
        layers.append(gemm(name, m, k, n))  # Gemm{m,k,n} -> conv(m,1,1,1,k,n,1)
    return layers

ARRAY = 32
STALL_BW = 16.0
LAYERS = 3
BACKENDS = ["analytical", "trace", "rtl"]
DATAFLOWS = ["os", "ws", "is"]

def fmt_num(v):
    if isinstance(v, int):
        return str(v)
    r = repr(float(v))
    # Rust f64 Display never uses exponent for these magnitudes and
    # prints integral floats without ".0"; the parser accepts both, but
    # keep the common form compact.
    if r.endswith(".0"):
        r = r[:-2]
    return r

def main():
    self_checks()
    cases = [
        ("resnet50", load_conv_csv(os.path.join(REPO, "topologies/resnet50.csv"))),
        ("alexnet", load_conv_csv(os.path.join(REPO, "topologies/alexnet.csv"))),
        ("mlp", load_gemm_csv(os.path.join(REPO, "topologies/gemm/mlp.csv"))),
    ]
    entries = []
    cfg = Cfg(ARRAY, ARRAY)
    for wname, layers in cases:
        assert len(layers) >= LAYERS, wname
        for layer in layers[:LAYERS]:
            for backend in BACKENDS:
                for df in DATAFLOWS:
                    t = timing(df, layer, ARRAY, ARRAY)
                    stall = stalled_runtime(df, layer, cfg, STALL_BW)["stall_cycles"]
                    check(0.0 < t["utilization"] <= 1.0, f"util bound {wname}/{layer.name}")
                    check(0.0 < t["mapping_efficiency"] <= 1.0, f"meff bound {wname}/{layer.name}")
                    e = [
                        ("workload", json.dumps(wname)),
                        ("layer", json.dumps(layer.name)),
                        ("backend", json.dumps(backend)),
                        ("dataflow", json.dumps(df)),
                        ("cycles", fmt_num(t["cycles"])),
                        ("row_folds", fmt_num(t["row_folds"])),
                        ("col_folds", fmt_num(t["col_folds"])),
                        ("utilization", fmt_num(t["utilization"])),
                        ("mapping_efficiency", fmt_num(t["mapping_efficiency"])),
                        ("sram_reads_ifmap", fmt_num(t["sram_reads_ifmap"])),
                        ("sram_reads_filter", fmt_num(t["sram_reads_filter"])),
                        ("sram_writes_ofmap", fmt_num(t["sram_writes_ofmap"])),
                        ("sram_reads_ofmap", fmt_num(t["sram_reads_ofmap"])),
                        ("stall_cycles_bw16", fmt_num(stall)),
                    ]
                    entries.append("{" + ",".join(f'"{k}":{v}' for k, v in e) + "}")
    assert len(entries) == 3 * LAYERS * 3 * 3, len(entries)
    out = "{\"entries\":[\n" + ",\n".join(entries) + "\n]}\n"
    path = os.path.join(REPO, "rust/tests/golden/timings.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(out)
    print(f"wrote {len(entries)} entries to {path}")

if __name__ == "__main__":
    main()
