#!/usr/bin/env python3
"""Generate rust/tests/golden/fabric.json by porting the route-aware
fabric + banked-DRAM substrate (rust/src/engine/fabric.rs,
rust/src/dram/banked.rs, Engine::fabric_stalls in
rust/src/engine/multi.rs) on top of the verified timing/memory port in
gen_golden.py.

Ports, 1:1 from the Rust sources (all float expressions mirror the Rust
order of operations so pinned f64 values compare bit-exactly):
  - Line / Ring / Mesh XY routing, link indexing, link loads
  - contention(): store-and-forward path time vs demand-proportional
    DRAM share, whichever is slower binds
  - per-node stall replay at the effective bandwidth, slowest node
    completes the layer
  - per-link average (over stalled runtime) and offered-peak bandwidth
  - banked tick-driven DRAM replay (bounded per-bank queues, hit /
    conflict / cold classification) of the slowest share's stream

Self-checks mirror the property assertions in the Rust suites; any
mismatch aborts without writing. Also searches and verifies the
wrong-share stall regression case (a partition where the REMAINDER node
is the slowest under fabric contention) used by rust/tests/fabric.rs.
"""
import json
import math
import os
import sys
from collections import deque

REPO = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", ".."))

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from gen_golden import (  # noqa: E402
    Cfg, Layer, ceil_div, check, fmt_num, load_conv_csv, self_checks,
    simulate_with, timing,
)
from gen_scaleout import split_layer, bandwidth_report  # noqa: E402

NODE_DIM = 8
STALL_BW = 16.0
LINK_BW = 16.0
LAYERS = 3
FABRIC_NODES = [4, 16]
FABRIC_KINDS = ["line", "ring", "mesh"]

DRAM = dict(banks=16, row_bytes=2048, t_rcd=18, t_cas=18, t_rp=18,
            burst_bytes=64, t_burst=4)
QUEUE_CAP = 8


# ------------------------------------------------------------ fabric routing

def mesh_side(nodes):
    s = math.isqrt(nodes)
    side = s if s * s == nodes else s + 1
    return max(side, 1)


def route(kind, nodes, j):
    """Port of the Topology::route impls (link ids in traversal order)."""
    if j == 0 or nodes < 2:
        return []
    if kind == "line":
        return list(range(j))[::-1]
    if kind == "ring":
        down, up = j, nodes - j
        if down <= up:
            return list(range(j))[::-1]
        return list(range(j, nodes))
    if kind == "mesh":
        side = mesh_side(nodes)
        row, col = j // side, j % side
        links = []
        for c in range(col, 0, -1):
            links.append(row * (side - 1) + (c - 1))
        for r in range(row, 0, -1):
            links.append(side * (side - 1) + (r - 1))
        return links
    raise ValueError(kind)


def link_count(kind, nodes):
    if kind == "line":
        return max(nodes - 1, 0)
    if nodes < 2:
        return 0
    if kind == "ring":
        return nodes
    side = mesh_side(nodes)
    return 2 * side * (side - 1)


def contention(kind, link_bw, dram_bw, demands):
    """Port of fabric::contention — float ops in the exact Rust order."""
    n = len(demands)
    routes = [route(kind, n, j) for j in range(n)]
    link_bytes = [0] * link_count(kind, n)
    hop_bytes = 0
    for j, r in enumerate(routes):
        for l in r:
            link_bytes[l] += demands[j]
        hop_bytes += demands[j] * len(r)
    total = sum(demands)
    dram_time = total / dram_bw if dram_bw is not None else 0.0
    eff = []
    for d, r in zip(demands, routes):
        if d == 0:
            eff.append(None)
            continue
        path_time = 0.0
        for l in r:
            path_time += link_bytes[l] / link_bw
        if path_time > dram_time:
            eff.append(d / path_time)
        else:
            eff.append(dram_bw * (d / total) if dram_bw is not None else None)
    return eff, link_bytes, routes, hop_bytes


# -------------------------------------------------------- banked DRAM model

def layer_request_stream(df, layer, cfg):
    """Port of dram::layer_request_stream (read (cycle, addr) pairs)."""
    _, fetches = simulate_with(df, layer, cfg)
    reqs = []
    window_start = 0
    addr = 0
    for i, (cycles, nbytes) in enumerate(fetches):
        if i == 0:
            window = (0, max(cycles, 1))
        else:
            window = (window_start, window_start + fetches[i - 1][0])
        if nbytes > 0:
            n = ceil_div(nbytes, DRAM["burst_bytes"])
            start, end = window
            span = max(end - start, 1)
            for k in range(n):
                reqs.append((start + k * span // n, addr + k * DRAM["burst_bytes"]))
        addr += nbytes
        if i > 0:
            window_start += fetches[i - 1][0]
    return reqs


def banked_replay(reqs, queue_cap=QUEUE_CAP):
    """Port of dram::banked::BankedDram::issue over a whole stream."""
    banks = [dict(open_row=None, ready_at=0, occ=deque())
             for _ in range(DRAM["banks"])]
    s = dict(requests=0, row_hits=0, row_conflicts=0, cold_misses=0,
             total_latency_cycles=0, max_latency_cycles=0,
             queue_wait_cycles=0, max_queue_depth=0, finish_cycle=0, bytes=0)
    cap = max(queue_cap, 1)
    for cycle, addr in reqs:
        row_global = addr // DRAM["row_bytes"]
        bank = banks[row_global % DRAM["banks"]]
        row = row_global // DRAM["banks"]
        occ = bank["occ"]
        while occ and occ[0] <= cycle:
            occ.popleft()
        admitted = cycle
        while len(occ) >= cap:
            admitted = max(admitted, occ.popleft())
        s["queue_wait_cycles"] += admitted - cycle
        start = max(admitted, bank["ready_at"])
        if bank["open_row"] is not None and bank["open_row"] == row:
            s["row_hits"] += 1
            access = DRAM["t_cas"]
        elif bank["open_row"] is None:
            s["cold_misses"] += 1
            access = DRAM["t_rcd"] + DRAM["t_cas"]
        else:
            s["row_conflicts"] += 1
            access = DRAM["t_rp"] + DRAM["t_rcd"] + DRAM["t_cas"]
        bank["open_row"] = row
        done = start + access + DRAM["t_burst"]
        bank["ready_at"] = done
        occ.append(done)
        s["max_queue_depth"] = max(s["max_queue_depth"], len(occ))
        s["requests"] += 1
        s["total_latency_cycles"] += done - cycle
        s["max_latency_cycles"] = max(s["max_latency_cycles"], done - cycle)
        s["finish_cycle"] = max(s["finish_cycle"], done)
        s["bytes"] += DRAM["burst_bytes"]
    return s


# ------------------------------------------------------- fabric layer model

def stall_from_fetches(fetches, bw):
    """stall.rs replay on a precomputed fold/fetch schedule."""
    ideal = stall = 0
    for i, (cycles, nbytes) in enumerate(fetches):
        ideal += cycles
        fetch_cycles = math.ceil(nbytes / bw)
        if i == 0:
            stall += fetch_cycles
        else:
            stall += max(fetch_cycles - fetches[i - 1][0], 0)
    return ideal + stall


def fabric_multi(df, layer, nodes, kind, cfg, link_bw, dram_bw, with_dram):
    """Port of Engine::multi_fixed's fabric path (channels partition)."""
    shares = split_layer(layer, nodes, "channels")
    share_info = []
    for sub, _count in shares:
        traffic, peak = bandwidth_report(df, sub, cfg)
        _, fetches = simulate_with(df, sub, cfg)
        share_info.append(dict(
            layer=sub,
            cycles=timing(df, sub, cfg.array_h, cfg.array_w)["cycles"],
            read_bytes=traffic["ifmap_bytes"] + traffic["filter_bytes"],
            peak=peak,
            fetches=fetches,
        ))
    main = share_info[0]
    main_count = shares[0][1]
    rem = share_info[1] if len(shares) > 1 else None
    demands = [main["read_bytes"]] * main_count
    ideals = [main["cycles"]] * main_count
    peaks = [main["peak"]] * main_count
    if rem is not None:
        demands.append(rem["read_bytes"])
        ideals.append(rem["cycles"])
        peaks.append(rem["peak"])
    cycles = max(ideals)
    eff, link_bytes, routes, hop_bytes = contention(kind, link_bw, dram_bw, demands)
    node_totals = []
    completion, slowest = 0, 0
    for j, e in enumerate(eff):
        is_rem = j >= main_count
        if e is None:
            total = ideals[j]
        else:
            info = rem if is_rem else main
            total = stall_from_fetches(info["fetches"], e)
        node_totals.append(total)
        if total > completion:
            completion, slowest = total, j
    stall_cycles = max(completion - cycles, 0)
    total_cycles = cycles + stall_cycles
    link_avg = [0.0 if total_cycles == 0 else b / total_cycles for b in link_bytes]
    link_peak = [0.0] * len(link_bytes)
    for j, r in enumerate(routes):
        for l in r:
            link_peak[l] += peaks[j]
    dram = None
    if with_dram:
        info = rem if (rem is not None and slowest >= main_count) else main
        dram = banked_replay(layer_request_stream(df, info["layer"], cfg))
    return dict(
        cycles=cycles,
        stall_cycles=stall_cycles,
        node_totals=node_totals,
        main_count=main_count,
        hop_bytes=hop_bytes,
        link_bytes=link_bytes,
        max_link_avg_bw=max(link_avg, default=0.0),
        max_link_peak_bw=max(link_peak, default=0.0),
        dram=dram,
    )


# ------------------------------------------------------------- self-checks

def fabric_self_checks():
    cfg8 = Cfg(NODE_DIM, NODE_DIM)

    # fabric.rs: pinned route shapes
    check(route("line", 4, 3) == [2, 1, 0], "line route")
    check(route("ring", 6, 3) == [2, 1, 0], "ring tie clockwise")
    check(route("ring", 6, 4) == [4, 5], "ring short way up")
    check(route("mesh", 16, 5) == [3, 12], "mesh (1,1) route")
    check(link_count("mesh", 16) == 24, "mesh 4x4 link count")

    # flow conservation across kinds
    demands = [5, 11, 0, 3, 9, 2, 7]
    for kind in FABRIC_KINDS:
        _, link_bytes, routes, hop = contention(kind, 4.0, 16.0, demands)
        check(sum(link_bytes) == hop, f"flow conservation {kind}")
        check(hop == sum(d * len(r) for d, r in zip(demands, routes)),
              f"hop accounting {kind}")

    # single node: exactly the configured DRAM bandwidth (bit-for-bit)
    eff, _, _, hop = contention("mesh", 4.0, 16.0, [1234])
    check(eff == [16.0] and hop == 0, "single-node exact bw")
    eff, _, _, _ = contention("mesh", 4.0, None, [1234])
    check(eff == [None], "single-node unconstrained")

    # mesh effective bandwidth dominates line per node
    demands = [7, 13, 5, 11, 3, 9, 6, 2, 8]
    el, _, _, _ = contention("line", 2.0, 16.0, demands)
    em, _, _, _ = contention("mesh", 2.0, 16.0, demands)
    for j in range(len(demands)):
        l = el[j] if el[j] is not None else math.inf
        m = em[j] if em[j] is not None else math.inf
        check(m >= l, f"mesh >= line node {j}")

    # multi.rs fabric path: mesh never stalls more than line, and the
    # 16-node mesh vs line acceptance criterion holds on resnet50
    layers = load_conv_csv(os.path.join(REPO, "topologies/resnet50.csv"))[:LAYERS]
    saw_diff_stall = saw_diff_peak = False
    for layer in layers:
        ml = fabric_multi("os", layer, 16, "line", cfg8, LINK_BW, STALL_BW, False)
        mm = fabric_multi("os", layer, 16, "mesh", cfg8, LINK_BW, STALL_BW, False)
        check(mm["stall_cycles"] <= ml["stall_cycles"], f"mesh<=line {layer.name}")
        saw_diff_stall |= mm["stall_cycles"] != ml["stall_cycles"]
        saw_diff_peak |= mm["max_link_peak_bw"] != ml["max_link_peak_bw"]
    check(saw_diff_stall, "16-node mesh vs line: stalls must differ")
    check(saw_diff_peak, "16-node mesh vs line: per-link peak must differ")

    # banked model sanity: every request classified exactly once
    reqs = layer_request_stream("os", layers[0], cfg8)
    s = banked_replay(reqs)
    check(s["requests"] == len(reqs), "banked request count")
    check(s["row_hits"] + s["row_conflicts"] + s["cold_misses"] == s["requests"],
          "banked classification total")
    check(s["max_queue_depth"] <= QUEUE_CAP, "queue cap respected")

    print("fabric self-checks passed", file=sys.stderr)


def regression_case():
    """Verify the wrong-share stall case pinned by rust/tests/fabric.rs:
    channels-partitioning 100 filters over 16 Line nodes puts the small
    remainder share on the farthest node, whose store-and-forward path
    time makes it the SLOWEST — stall selection must follow it, not the
    maximal share."""
    cfg8 = Cfg(NODE_DIM, NODE_DIM)
    layer = Layer("c", 16, 16, 3, 3, 8, 100, 1)
    m = fabric_multi("os", layer, 16, "line", cfg8, 0.5, None, False)
    totals = m["node_totals"]
    main_max = max(totals[:m["main_count"]])
    rem_total = totals[-1]
    check(len(totals) == 15, "15 placed nodes")
    check(rem_total > main_max, "remainder node must be the slowest "
          f"(rem {rem_total} vs main {main_max})")
    check(m["stall_cycles"] == rem_total - m["cycles"], "stall follows remainder")
    check(main_max - m["cycles"] != m["stall_cycles"], "main-only selection differs")
    print(f"regression case verified: rem_total={rem_total} main_max={main_max} "
          f"cycles={m['cycles']} stall={m['stall_cycles']}", file=sys.stderr)


# ----------------------------------------------------------------- fixture

def main():
    self_checks()
    fabric_self_checks()
    regression_case()
    cases = [
        ("resnet50", load_conv_csv(os.path.join(REPO, "topologies/resnet50.csv"))),
        ("alexnet", load_conv_csv(os.path.join(REPO, "topologies/alexnet.csv"))),
    ]
    cfg = Cfg(NODE_DIM, NODE_DIM)
    entries = []
    for wname, layers in cases:
        assert len(layers) >= LAYERS, wname
        for kind in FABRIC_KINDS:
            for nodes in FABRIC_NODES:
                stall = hop = 0
                peak = avg = 0.0
                d = dict(requests=0, row_hits=0, row_conflicts=0, cold_misses=0,
                         total_latency_cycles=0, queue_wait_cycles=0,
                         max_latency_cycles=0)
                for layer in layers[:LAYERS]:
                    m = fabric_multi("os", layer, nodes, kind, cfg,
                                     LINK_BW, STALL_BW, True)
                    stall += m["stall_cycles"]
                    hop += m["hop_bytes"]
                    peak = max(peak, m["max_link_peak_bw"])
                    avg = max(avg, m["max_link_avg_bw"])
                    for k in ("requests", "row_hits", "row_conflicts",
                              "cold_misses", "total_latency_cycles",
                              "queue_wait_cycles"):
                        d[k] += m["dram"][k]
                    d["max_latency_cycles"] = max(d["max_latency_cycles"],
                                                  m["dram"]["max_latency_cycles"])
                e = [
                    ("workload", json.dumps(wname)),
                    ("fabric", json.dumps(kind)),
                    ("nodes", fmt_num(nodes)),
                    ("stall_cycles", fmt_num(stall)),
                    ("hop_bytes", fmt_num(hop)),
                    ("max_link_peak_bw", fmt_num(peak)),
                    ("max_link_avg_bw", fmt_num(avg)),
                    ("dram_requests", fmt_num(d["requests"])),
                    ("dram_row_hits", fmt_num(d["row_hits"])),
                    ("dram_row_conflicts", fmt_num(d["row_conflicts"])),
                    ("dram_cold_misses", fmt_num(d["cold_misses"])),
                    ("dram_total_latency_cycles", fmt_num(d["total_latency_cycles"])),
                    ("dram_queue_wait_cycles", fmt_num(d["queue_wait_cycles"])),
                    ("dram_max_latency_cycles", fmt_num(d["max_latency_cycles"])),
                ]
                entries.append("{" + ",".join(f'"{k}":{v}' for k, v in e) + "}")
    assert len(entries) == 2 * len(FABRIC_KINDS) * len(FABRIC_NODES), len(entries)
    out = "{\"entries\":[\n" + ",\n".join(entries) + "\n]}\n"
    path = os.path.join(REPO, "rust/tests/golden/fabric.json")
    with open(path, "w") as f:
        f.write(out)
    print(f"wrote {len(entries)} entries to {path}")


if __name__ == "__main__":
    main()
