#!/usr/bin/env python3
"""Generate rust/tests/golden/scaleout.json by porting the engine's
multi-array model (rust/src/engine/multi.rs) on top of the verified
timing/memory port in gen_golden.py.

Ports, 1:1 from the Rust sources:
  - split_layer (channels / pixels, exact remainder accounting)
  - Auto resolution (pixels iff strictly faster by total runtime;
    ties -> channels)
  - slowest-node cycles, shared-DRAM stall (bw split across used nodes)
  - aggregate DRAM traffic, avg/peak interconnect bandwidth

Self-checks mirror the assertions in rust/src/engine/multi.rs tests and
rust/src/scaleout/mod.rs tests; any mismatch aborts without writing.
"""
import json
import math
import os
import sys

REPO = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", ".."))

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from gen_golden import (  # noqa: E402
    Cfg, Layer, ceil_div, check, fmt_num, gemm, load_conv_csv, load_gemm_csv,
    self_checks, simulate_with, stalled_runtime, timing,
)

NODE_DIM = 8
STALL_BW = 16.0
LAYERS = 3
SCALEOUT_NODES = [4, 16, 64]
PARTITIONS = ["channels", "pixels", "auto"]


def clone_layer(l, **kw):
    vals = dict(name=l.name, ih=l.ifmap_h, iw=l.ifmap_w, fh=l.filt_h,
                fw=l.filt_w, c=l.channels, nf=l.num_filters, s=l.stride)
    vals.update(kw)
    return Layer(vals["name"], vals["ih"], vals["iw"], vals["fh"],
                 vals["fw"], vals["c"], vals["nf"], vals["s"])


def split_layer(layer, nodes, partition):
    """Port of engine::multi::split_layer: [(sub_layer, count), ...]."""
    assert nodes > 0
    if nodes == 1:
        return [(clone_layer(layer), 1)]
    if partition == "channels":
        per = ceil_div(layer.num_filters, nodes)
        full = layer.num_filters // per
        rem = layer.num_filters % per
        out = [(clone_layer(layer, nf=per), full)]
        if rem > 0:
            out.append((clone_layer(layer, nf=rem), 1))
        return out
    if partition == "pixels":
        rows = layer.ofmap_h()
        per = ceil_div(rows, nodes)
        full = rows // per
        rem = rows % per
        stripe = lambda r: clone_layer(layer, ih=(r - 1) * layer.stride + layer.filt_h)
        out = [(stripe(per), full)]
        if rem > 0:
            out.append((stripe(rem), 1))
        return out
    raise ValueError(partition)


def bandwidth_report(df, layer, cfg):
    """Port of memory::simulate's BandwidthReport (avg/peak read bw)."""
    traffic, fetches = simulate_with(df, layer, cfg)
    total_cycles = sum(c for c, _ in fetches)
    peak = 0.0
    prev = None
    for cycles, nbytes in fetches:
        if prev is not None:
            peak = max(peak, nbytes / prev)
        prev = cycles
    read_bytes = traffic["ifmap_bytes"] + traffic["filter_bytes"]
    avg = read_bytes / total_cycles
    return traffic, max(peak, avg)



def multi_fixed(df, layer, nodes, partition, cfg, bw):
    """Port of Engine::multi_fixed (analytical backend)."""
    shares = split_layer(layer, nodes, partition)
    main_layer, main_count = shares[0]
    main_cycles = timing(df, main_layer, cfg.array_h, cfg.array_w)["cycles"]
    main_traffic, main_peak = bandwidth_report(df, main_layer, cfg)
    used = main_count
    cycles = main_cycles
    rem = None
    if len(shares) > 1:
        rem_layer, rem_count = shares[1]
        assert rem_count == 1
        rem_cycles = timing(df, rem_layer, cfg.array_h, cfg.array_w)["cycles"]
        rem_traffic, rem_peak = bandwidth_report(df, rem_layer, cfg)
        used += 1
        cycles = max(main_cycles, rem_cycles)
        rem = dict(layer=rem_layer, cycles=rem_cycles, traffic=rem_traffic, peak=rem_peak)
    # every share replays against its equal split and the layer stalls
    # with whichever node finishes LAST (the maximal share provably
    # dominates under an equal split, so this matches the historical
    # main-share-only numbers bit-for-bit — but the selection must not
    # bake that assumption in; mirrors Engine::multi_fixed)
    stall = 0
    if bw is not None:
        share_bw = bw / used
        sr = stalled_runtime(df, main_layer, cfg, share_bw)
        completion = sr["ideal_cycles"] + sr["stall_cycles"]
        if rem is not None:
            rr = stalled_runtime(df, rem["layer"], cfg, share_bw)
            completion = max(completion, rr["ideal_cycles"] + rr["stall_cycles"])
        stall = max(completion - cycles, 0)
    dram = dict(
        ifmap_bytes=main_traffic["ifmap_bytes"] * main_count,
        filter_bytes=main_traffic["filter_bytes"] * main_count,
        ofmap_bytes=main_traffic["ofmap_bytes"] * main_count,
    )
    peak_bw = main_peak * float(main_count)
    if rem is not None:
        for k in dram:
            dram[k] += rem["traffic"][k]
        peak_bw += rem["peak"]
    read_bytes = dram["ifmap_bytes"] + dram["filter_bytes"]
    avg_bw = 0.0 if cycles == 0 else read_bytes / cycles
    return dict(
        partition=partition,
        used_nodes=used,
        node_cycles=main_cycles,
        cycles=cycles,
        stall_cycles=stall,
        dram=dram,
        dram_total=dram["ifmap_bytes"] + dram["filter_bytes"] + dram["ofmap_bytes"],
        avg_bw=avg_bw,
        peak_bw=peak_bw,
    )


def run_multi_layer(df, layer, nodes, partition, cfg, bw):
    if partition == "auto":
        a = multi_fixed(df, layer, nodes, "channels", cfg, bw)
        b = multi_fixed(df, layer, nodes, "pixels", cfg, bw)
        # total runtime (== stall-free cycles without a shared bw);
        # ties -> channels, matching the legacy closed forms
        total = lambda m: m["cycles"] + m["stall_cycles"]
        return b if total(b) < total(a) else a
    return multi_fixed(df, layer, nodes, partition, cfg, bw)


# ------------------------------------------------------------- self-checks

def scaleout_self_checks():
    cfg8 = Cfg(NODE_DIM, NODE_DIM)

    # multi.rs: split conserves MACs and OFMAP pixels exactly
    l = Layer("c", 30, 30, 3, 3, 8, 100, 1)
    for nodes in (1, 2, 3, 7, 16, 64, 1000):
        for p in ("channels", "pixels"):
            shares = split_layer(l, nodes, p)
            macs = sum(n * s.macs() for s, n in shares)
            ofmap = sum(n * s.ofmap_elems() for s, n in shares)
            check(macs == l.macs(), f"macs conserved {p} {nodes}")
            check(ofmap == l.ofmap_elems(), f"ofmap conserved {p} {nodes}")
            check(sum(n for _, n in shares) <= nodes, f"used <= nodes {p} {nodes}")

    # multi.rs: uneven split puts the remainder on one node
    l = Layer("c", 16, 16, 3, 3, 8, 100, 1)
    shares = split_layer(l, 16, "channels")
    check(len(shares) == 2, "two groups")
    check(shares[0][0].num_filters == 7 and shares[0][1] == 14, "main 7x14")
    check(shares[1][0].num_filters == 2 and shares[1][1] == 1, "rem 2x1")
    m = run_multi_layer("os", l, 16, "channels", cfg8, None)
    check(m["used_nodes"] == 15, "used 15")

    # scaleout/mod.rs: partition_filters legacy expectations
    l = Layer("c", 16, 16, 3, 3, 8, 256, 1)
    shares = split_layer(l, 16, "channels")
    check(shares[0][0].num_filters == 16 and shares[0][1] == 16 and len(shares) == 1,
          "256/16 even")
    l = Layer("c", 16, 16, 3, 3, 8, 4, 1)
    shares = split_layer(l, 16, "channels")
    check(shares[0][0].num_filters == 1 and shares[0][1] == 4, "4 filters 16 nodes")

    # scaleout/mod.rs: pixel partition covers all output rows
    l = Layer("c", 30, 30, 3, 3, 8, 16, 1)
    for nodes in (1, 2, 4, 7, 28, 100):
        shares = split_layer(l, nodes, "pixels")
        rows = sum(n * s.ofmap_h() for s, n in shares)
        check(rows == l.ofmap_h(), f"pixel rows {nodes}")
        check(shares[0][0].ifmap_w == 30 and shares[0][0].channels == 8
              and shares[0][0].num_filters == 16, "stripe geometry")

    # scaleout/mod.rs: pixel partitioning duplicates weights (filter
    # traffic only — channels partitioning duplicates the ifmap instead)
    l = Layer("c", 64, 64, 3, 3, 32, 64, 1)
    ch = multi_fixed("os", l, 16, "channels", cfg8, None)
    px = multi_fixed("os", l, 16, "pixels", cfg8, None)
    check(px["dram"]["filter_bytes"] > ch["dram"]["filter_bytes"],
          "pixel weight duplication")

    # scaleout/mod.rs + multi.rs: auto never slower, resolves to min
    for l in (Layer("fewfilt", 64, 64, 3, 3, 32, 8, 1),
              Layer("deep", 19, 19, 3, 3, 256, 256, 1),
              gemm("fc", 4, 512, 512)):
        auto = run_multi_layer("os", l, 64, "auto", cfg8, None)
        ch = multi_fixed("os", l, 64, "channels", cfg8, None)
        px = multi_fixed("os", l, 64, "pixels", cfg8, None)
        check(auto["cycles"] == min(ch["cycles"], px["cycles"]), f"auto min {l.name}")

    # multi.rs: few filters prefer pixel partition
    l = Layer("fewfilt", 64, 64, 3, 3, 32, 8, 1)
    ch = multi_fixed("os", l, 64, "channels", cfg8, None)
    px = multi_fixed("os", l, 64, "pixels", cfg8, None)
    check(px["cycles"] < ch["cycles"], "few filters prefer pixels")

    # multi.rs: under a shared bandwidth, auto ranks by TOTAL runtime
    for l in (Layer("fewfilt", 64, 64, 3, 3, 32, 8, 1),
              Layer("deep", 19, 19, 3, 3, 256, 256, 1),
              Layer("wide", 60, 60, 3, 3, 24, 100, 1)):
        for bw in (2.0, 16.0):
            auto = run_multi_layer("os", l, 64, "auto", cfg8, bw)
            ch = multi_fixed("os", l, 64, "channels", cfg8, bw)
            px = multi_fixed("os", l, 64, "pixels", cfg8, bw)
            total = lambda m: m["cycles"] + m["stall_cycles"]
            check(total(auto) == min(total(ch), total(px)),
                  f"auto total-runtime min {l.name} {bw}")

    # multi.rs: shared-DRAM stalls grow with node count
    l = Layer("c", 64, 64, 3, 3, 32, 256, 1)
    last = 0
    for nodes in (4, 16, 64):
        m = run_multi_layer("os", l, nodes, "pixels", cfg8, STALL_BW)
        check(m["stall_cycles"] >= last, f"stall monotone {nodes}")
        last = m["stall_cycles"]
    check(last > 0, "64 nodes on 16 B/cyc must stall")

    print("scaleout self-checks passed", file=sys.stderr)


# ----------------------------------------------------------------- fixture


def main():
    self_checks()  # the timing/memory port must still hold
    scaleout_self_checks()
    cases = [
        ("resnet50", load_conv_csv(os.path.join(REPO, "topologies/resnet50.csv"))),
        ("alexnet", load_conv_csv(os.path.join(REPO, "topologies/alexnet.csv"))),
        ("mlp", load_gemm_csv(os.path.join(REPO, "topologies/gemm/mlp.csv"))),
    ]
    cfg = Cfg(NODE_DIM, NODE_DIM)
    entries = []
    for wname, layers in cases:
        assert len(layers) >= LAYERS, wname
        for layer in layers[:LAYERS]:
            for nodes in SCALEOUT_NODES:
                for partition in PARTITIONS:
                    m = run_multi_layer("os", layer, nodes, partition, cfg, STALL_BW)
                    check(m["cycles"] >= m["node_cycles"] > 0, "cycles sane")
                    check(m["avg_bw"] > 0.0 and m["peak_bw"] > 0.0, "bw sane")
                    e = [
                        ("workload", json.dumps(wname)),
                        ("layer", json.dumps(layer.name)),
                        ("partition", json.dumps(partition)),
                        ("nodes", fmt_num(nodes)),
                        ("used_nodes", fmt_num(m["used_nodes"])),
                        ("node_cycles", fmt_num(m["node_cycles"])),
                        ("cycles", fmt_num(m["cycles"])),
                        ("stall_cycles_bw16", fmt_num(m["stall_cycles"])),
                        ("dram_bytes", fmt_num(m["dram_total"])),
                        ("interconnect_avg_bw", fmt_num(m["avg_bw"])),
                        ("interconnect_peak_bw", fmt_num(m["peak_bw"])),
                    ]
                    entries.append("{" + ",".join(f'"{k}":{v}' for k, v in e) + "}")
    assert len(entries) == 3 * LAYERS * len(SCALEOUT_NODES) * len(PARTITIONS), len(entries)
    out = "{\"entries\":[\n" + ",\n".join(entries) + "\n]}\n"
    path = os.path.join(REPO, "rust/tests/golden/scaleout.json")
    with open(path, "w") as f:
        f.write(out)
    print(f"wrote {len(entries)} entries to {path}")


if __name__ == "__main__":
    main()
