//! Golden regression suite: checked-in fixtures pin the per-layer
//! timing numbers (cycles, folds, utilization, mapping efficiency, the
//! four SRAM access counts, and the finite-bandwidth stall cycles) for
//! the first three layers of resnet50 + alexnet + the mlp GEMM suite,
//! across **all three backends x all three dataflows**. Any future
//! change that silently shifts a timing result fails here loudly, with
//! the exact entry and field named.
//!
//! Regenerating after an *intentional* model change:
//!
//! ```text
//! BLESS_GOLDEN=1 cargo test --test golden
//! git diff rust/tests/golden/timings.json   # review the drift!
//! ```
//!
//! The fixture stores numbers as shortest-round-trip decimals
//! ([`scale_sim::util::json`]), so parsed values compare bit-exactly
//! against freshly computed ones.

use std::path::PathBuf;

use scale_sim::config::{workloads, Topology};
use scale_sim::engine::{BackendKind, Engine};
use scale_sim::memory::stall::stalled_runtime;
use scale_sim::util::json::Json;
use scale_sim::Dataflow;

/// Array shape the fixtures pin (32x32: small enough that the trace and
/// RTL backends stay fast, large enough to fold every pinned layer).
const ARRAY: u64 = 32;

/// DRAM bandwidth (bytes/cycle) for the pinned stall count — a power of
/// two so the stall model's `bytes / bw` division is exact.
const STALL_BW: f64 = 16.0;

/// Layers pinned per workload.
const LAYERS: usize = 3;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/timings.json")
}

/// The pinned workloads: two conv suites + one GEMM suite.
fn cases() -> Vec<(&'static str, Topology)> {
    vec![
        ("resnet50", workloads::builtin("resnet50").unwrap()),
        ("alexnet", workloads::builtin("alexnet").unwrap()),
        ("mlp", workloads::builtin_gemm("mlp").unwrap().lower().unwrap()),
    ]
}

/// Compute every fixture entry, in the fixture's canonical order.
fn compute_entries() -> Vec<Json> {
    let mut out = Vec::new();
    for (wname, topo) in cases() {
        for layer in topo.layers.iter().take(LAYERS) {
            for kind in BackendKind::ALL {
                for df in Dataflow::ALL {
                    let engine = Engine::builder()
                        .array(ARRAY, ARRAY)
                        .dataflow(df)
                        .backend(kind)
                        .build()
                        .unwrap();
                    let t = engine.run_layer(layer).timing;
                    let stall =
                        stalled_runtime(df, layer, engine.cfg(), STALL_BW).stall_cycles;
                    out.push(Json::obj(vec![
                        ("workload", Json::str(wname)),
                        ("layer", Json::str(layer.name.clone())),
                        ("backend", Json::str(kind.name())),
                        ("dataflow", Json::str(df.name())),
                        ("cycles", Json::u64(t.cycles)),
                        ("row_folds", Json::u64(t.row_folds)),
                        ("col_folds", Json::u64(t.col_folds)),
                        ("utilization", Json::f64(t.utilization)),
                        ("mapping_efficiency", Json::f64(t.mapping_efficiency)),
                        ("sram_reads_ifmap", Json::u64(t.sram_reads_ifmap)),
                        ("sram_reads_filter", Json::u64(t.sram_reads_filter)),
                        ("sram_writes_ofmap", Json::u64(t.sram_writes_ofmap)),
                        ("sram_reads_ofmap", Json::u64(t.sram_reads_ofmap)),
                        ("stall_cycles_bw16", Json::u64(stall)),
                    ]));
                }
            }
        }
    }
    out
}

fn write_fixture(entries: &[Json]) {
    let path = fixture_path();
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    let mut text = String::from("{\"entries\":[\n");
    for (i, e) in entries.iter().enumerate() {
        text.push_str(&e.to_string());
        if i + 1 < entries.len() {
            text.push(',');
        }
        text.push('\n');
    }
    text.push_str("]}\n");
    std::fs::write(&path, text).unwrap();
}

#[test]
fn timings_match_the_golden_fixture() {
    let entries = compute_entries();
    assert_eq!(entries.len(), 3 * LAYERS * 3 * 3, "3 workloads x 3 layers x 3 backends x 3 dataflows");

    if std::env::var("BLESS_GOLDEN").is_ok_and(|v| v == "1") {
        write_fixture(&entries);
        eprintln!("golden: blessed {} entries into {:?}", entries.len(), fixture_path());
        return;
    }

    let text = std::fs::read_to_string(fixture_path()).unwrap_or_else(|e| {
        panic!(
            "golden fixture {:?} unreadable ({e}); regenerate with BLESS_GOLDEN=1 \
             cargo test --test golden",
            fixture_path()
        )
    });
    let fixture = Json::parse(text.trim()).expect("golden fixture must be valid JSON");
    let pinned = fixture.get("entries").and_then(Json::as_arr).expect("fixture entries array");
    assert_eq!(
        pinned.len(),
        entries.len(),
        "fixture entry count drifted — BLESS_GOLDEN=1 after reviewing why"
    );

    for (got, want) in entries.iter().zip(pinned) {
        let ctx = format!(
            "{}/{} backend={} dataflow={}",
            got.str_field("workload").unwrap(),
            got.str_field("layer").unwrap(),
            got.str_field("backend").unwrap(),
            got.str_field("dataflow").unwrap(),
        );
        for key in ["workload", "layer", "backend", "dataflow"] {
            assert_eq!(got.str_field(key), want.str_field(key), "[{ctx}] fixture order drifted on {key:?}");
        }
        for key in [
            "cycles",
            "row_folds",
            "col_folds",
            "sram_reads_ifmap",
            "sram_reads_filter",
            "sram_writes_ofmap",
            "sram_reads_ofmap",
            "stall_cycles_bw16",
        ] {
            assert_eq!(
                got.u64_field(key),
                want.u64_field(key),
                "[{ctx}] timing drift on {key:?} (got {:?}, golden {:?}) — if intentional, \
                 BLESS_GOLDEN=1 cargo test --test golden",
                got.u64_field(key),
                want.u64_field(key),
            );
        }
        for key in ["utilization", "mapping_efficiency"] {
            let g = got.f64_field(key).unwrap();
            let w = want.f64_field(key).unwrap_or(f64::NAN);
            assert!(
                g.to_bits() == w.to_bits(),
                "[{ctx}] {key} drifted bit-exactly: got {g}, golden {w}"
            );
        }
    }
}

#[test]
fn blessing_is_idempotent_in_memory() {
    // two computations of the entry set must agree exactly — the
    // regeneration path cannot be flaky
    let a = compute_entries();
    let b = compute_entries();
    assert_eq!(a, b);
}
