//! Golden regression suites: checked-in fixtures pin exact simulation
//! numbers so any future change that silently shifts a result fails
//! loudly, with the exact entry and field named.
//!
//! * `timings.json` — per-layer timing (cycles, folds, utilization,
//!   mapping efficiency, the four SRAM access counts, and the
//!   finite-bandwidth stall cycles) for the first three layers of
//!   resnet50 + alexnet + the mlp GEMM suite, across **all three
//!   backends x all three dataflows**.
//! * `scaleout.json` — the multi-array engine path: per-node cycles,
//!   slowest-node cycles, shared-DRAM stall cycles and the required
//!   interconnect bandwidth for the same layers at 4/16/64 nodes of
//!   8x8 under all three partition strategies.
//! * `fabric.json` — the route-aware fabric + banked-DRAM substrate:
//!   stall cycles, per-link peak/average bandwidth, hop bytes and the
//!   banked-DRAM latency/hit accounting for resnet50 + alexnet across
//!   Line/Ring/Mesh at 4 and 16 nodes.
//!
//! Regenerating after an *intentional* model change:
//!
//! ```text
//! BLESS_GOLDEN=1 cargo test --test golden
//! git diff rust/tests/golden/   # review the drift!
//! ```
//!
//! Fixtures store numbers as shortest-round-trip decimals
//! ([`scale_sim::util::json`]), so parsed values compare bit-exactly
//! against freshly computed ones. The comparison is **strict**: a
//! fixture entry missing an expected key, carrying an unknown key, or
//! drifting on any value is an error — BLESS drift cannot hide behind
//! `None == None`.

use std::path::PathBuf;

use scale_sim::config::{workloads, Topology};
use scale_sim::dram::DramConfig;
use scale_sim::engine::multi::{MultiArrayConfig, MultiOpts, Partition, NODE_DIM};
use scale_sim::engine::{BackendKind, Engine, FabricConfig, FabricKind, DEFAULT_LINK_BW};
use scale_sim::memory::stall::stalled_runtime;
use scale_sim::util::json::Json;
use scale_sim::Dataflow;

/// Array shape the timing fixtures pin (32x32: small enough that the
/// trace and RTL backends stay fast, large enough to fold every pinned
/// layer).
const ARRAY: u64 = 32;

/// DRAM bandwidth (bytes/cycle) for the pinned stall counts — a power of
/// two so the stall model's `bytes / bw` division is exact.
const STALL_BW: f64 = 16.0;

/// Layers pinned per workload.
const LAYERS: usize = 3;

/// Node counts the scaleout fixture pins (8x8 nodes each).
const SCALEOUT_NODES: [u64; 3] = [4, 16, 64];

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden").join(name)
}

/// The pinned workloads: two conv suites + one GEMM suite.
fn cases() -> Vec<(&'static str, Topology)> {
    vec![
        ("resnet50", workloads::builtin("resnet50").unwrap()),
        ("alexnet", workloads::builtin("alexnet").unwrap()),
        ("mlp", workloads::builtin_gemm("mlp").unwrap().lower().unwrap()),
    ]
}

// ------------------------------------------------------------ strict checker

/// Key schema of one fixture family. Every entry must carry exactly
/// these keys — no more, no fewer.
struct FixtureSpec {
    str_keys: &'static [&'static str],
    u64_keys: &'static [&'static str],
    f64_keys: &'static [&'static str],
}

impl FixtureSpec {
    fn knows(&self, key: &str) -> bool {
        self.str_keys.contains(&key)
            || self.u64_keys.contains(&key)
            || self.f64_keys.contains(&key)
    }
}

/// Compare computed entries against pinned ones under a strict schema.
/// Returns the first problem found (entry-count drift, unknown or
/// missing keys on either side, or any value drift) — the caller panics
/// with it; negative tests assert on it directly.
fn check_entries(
    computed: &[Json],
    pinned: &[Json],
    spec: &FixtureSpec,
) -> Result<(), String> {
    if computed.len() != pinned.len() {
        return Err(format!(
            "fixture entry count drifted: computed {} vs pinned {} — BLESS_GOLDEN=1 after \
             reviewing why",
            computed.len(),
            pinned.len()
        ));
    }
    for (got, want) in computed.iter().zip(pinned) {
        let ctx: Vec<&str> =
            spec.str_keys.iter().filter_map(|&k| got.str_field(k)).collect();
        let ctx = ctx.join("/");
        let Json::Obj(fields) = want else {
            return Err(format!("[{ctx}] fixture entry is not an object"));
        };
        for (k, _) in fields {
            if !spec.knows(k) {
                return Err(format!(
                    "[{ctx}] fixture carries unknown key {k:?} — corrupted or stale \
                     fixture; regenerate with BLESS_GOLDEN=1 cargo test --test golden"
                ));
            }
        }
        let missing = |side: &str, k: &str| {
            format!(
                "[{ctx}] {side} entry is missing key {k:?} — fixture schema drifted; \
                 BLESS_GOLDEN=1 after reviewing why"
            )
        };
        for &k in spec.str_keys {
            let g = got.str_field(k).ok_or_else(|| missing("computed", k))?;
            let w = want.str_field(k).ok_or_else(|| missing("fixture", k))?;
            if g != w {
                return Err(format!(
                    "[{ctx}] fixture order drifted on {k:?}: computed {g:?}, golden {w:?}"
                ));
            }
        }
        for &k in spec.u64_keys {
            let g = got.u64_field(k).ok_or_else(|| missing("computed", k))?;
            let w = want.u64_field(k).ok_or_else(|| missing("fixture", k))?;
            if g != w {
                return Err(format!(
                    "[{ctx}] drift on {k:?} (got {g}, golden {w}) — if intentional, \
                     BLESS_GOLDEN=1 cargo test --test golden"
                ));
            }
        }
        for &k in spec.f64_keys {
            let g = got.f64_field(k).ok_or_else(|| missing("computed", k))?;
            let w = want.f64_field(k).ok_or_else(|| missing("fixture", k))?;
            if g.to_bits() != w.to_bits() {
                return Err(format!(
                    "[{ctx}] {k} drifted bit-exactly: got {g}, golden {w}"
                ));
            }
        }
    }
    Ok(())
}

fn write_fixture(name: &str, entries: &[Json]) {
    let path = fixture_path(name);
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    let mut text = String::from("{\"entries\":[\n");
    for (i, e) in entries.iter().enumerate() {
        text.push_str(&e.to_string());
        if i + 1 < entries.len() {
            text.push(',');
        }
        text.push('\n');
    }
    text.push_str("]}\n");
    std::fs::write(&path, text).unwrap();
}

fn read_fixture(name: &str) -> Vec<Json> {
    let text = std::fs::read_to_string(fixture_path(name)).unwrap_or_else(|e| {
        panic!(
            "golden fixture {:?} unreadable ({e}); regenerate with BLESS_GOLDEN=1 \
             cargo test --test golden",
            fixture_path(name)
        )
    });
    let fixture = Json::parse(text.trim()).expect("golden fixture must be valid JSON");
    fixture
        .get("entries")
        .and_then(Json::as_arr)
        .expect("fixture entries array")
        .to_vec()
}

fn blessing() -> bool {
    std::env::var("BLESS_GOLDEN").is_ok_and(|v| v == "1")
}

// ----------------------------------------------------------- timing fixture

const TIMINGS_SPEC: FixtureSpec = FixtureSpec {
    str_keys: &["workload", "layer", "backend", "dataflow"],
    u64_keys: &[
        "cycles",
        "row_folds",
        "col_folds",
        "sram_reads_ifmap",
        "sram_reads_filter",
        "sram_writes_ofmap",
        "sram_reads_ofmap",
        "stall_cycles_bw16",
    ],
    f64_keys: &["utilization", "mapping_efficiency"],
};

/// Compute every timing entry, in the fixture's canonical order.
fn compute_entries() -> Vec<Json> {
    let mut out = Vec::new();
    for (wname, topo) in cases() {
        for layer in topo.layers.iter().take(LAYERS) {
            for kind in BackendKind::ALL {
                for df in Dataflow::ALL {
                    let engine = Engine::builder()
                        .array(ARRAY, ARRAY)
                        .dataflow(df)
                        .backend(kind)
                        .build()
                        .unwrap();
                    let t = engine.run_layer(layer).timing;
                    let stall =
                        stalled_runtime(df, layer, engine.cfg(), STALL_BW).stall_cycles;
                    out.push(Json::obj(vec![
                        ("workload", Json::str(wname)),
                        ("layer", Json::str(layer.name.clone())),
                        ("backend", Json::str(kind.name())),
                        ("dataflow", Json::str(df.name())),
                        ("cycles", Json::u64(t.cycles)),
                        ("row_folds", Json::u64(t.row_folds)),
                        ("col_folds", Json::u64(t.col_folds)),
                        ("utilization", Json::f64(t.utilization)),
                        ("mapping_efficiency", Json::f64(t.mapping_efficiency)),
                        ("sram_reads_ifmap", Json::u64(t.sram_reads_ifmap)),
                        ("sram_reads_filter", Json::u64(t.sram_reads_filter)),
                        ("sram_writes_ofmap", Json::u64(t.sram_writes_ofmap)),
                        ("sram_reads_ofmap", Json::u64(t.sram_reads_ofmap)),
                        ("stall_cycles_bw16", Json::u64(stall)),
                    ]));
                }
            }
        }
    }
    out
}

#[test]
fn timings_match_the_golden_fixture() {
    let entries = compute_entries();
    assert_eq!(entries.len(), 3 * LAYERS * 3 * 3, "3 workloads x 3 layers x 3 backends x 3 dataflows");

    if blessing() {
        write_fixture("timings.json", &entries);
        eprintln!("golden: blessed {} timing entries", entries.len());
        return;
    }

    let pinned = read_fixture("timings.json");
    if let Err(e) = check_entries(&entries, &pinned, &TIMINGS_SPEC) {
        panic!("timings.json: {e}");
    }
}

#[test]
fn blessing_is_idempotent_in_memory() {
    // two computations of the entry set must agree exactly — the
    // regeneration path cannot be flaky
    let a = compute_entries();
    let b = compute_entries();
    assert_eq!(a, b);
}

// --------------------------------------------------------- scaleout fixture

const SCALEOUT_SPEC: FixtureSpec = FixtureSpec {
    str_keys: &["workload", "layer", "partition"],
    u64_keys: &[
        "nodes",
        "used_nodes",
        "node_cycles",
        "cycles",
        "stall_cycles_bw16",
        "dram_bytes",
    ],
    f64_keys: &["interconnect_avg_bw", "interconnect_peak_bw"],
};

/// Compute every scaleout entry: the engine's multi-array path on 8x8
/// nodes under the OS dataflow, shared-DRAM stalls at [`STALL_BW`].
fn compute_scaleout_entries() -> Vec<Json> {
    let engine = Engine::builder().dataflow(Dataflow::Os).build().unwrap();
    let mut out = Vec::new();
    for (wname, topo) in cases() {
        for layer in topo.layers.iter().take(LAYERS) {
            for &nodes in &SCALEOUT_NODES {
                for partition in Partition::ALL {
                    let multi = MultiArrayConfig::new(nodes, NODE_DIM, NODE_DIM, partition);
                    let m = engine.run_multi_layer_with(
                        engine.cfg(),
                        layer,
                        &multi,
                        Some(STALL_BW),
                    );
                    out.push(Json::obj(vec![
                        ("workload", Json::str(wname)),
                        ("layer", Json::str(layer.name.clone())),
                        ("partition", Json::str(partition.name())),
                        ("nodes", Json::u64(nodes)),
                        ("used_nodes", Json::u64(m.used_nodes)),
                        ("node_cycles", Json::u64(m.node_report.timing.cycles)),
                        ("cycles", Json::u64(m.cycles)),
                        ("stall_cycles_bw16", Json::u64(m.stall_cycles)),
                        ("dram_bytes", Json::u64(m.dram().total())),
                        ("interconnect_avg_bw", Json::f64(m.avg_bw())),
                        ("interconnect_peak_bw", Json::f64(m.peak_bw())),
                    ]));
                }
            }
        }
    }
    out
}

#[test]
fn scaleout_matches_the_golden_fixture() {
    let entries = compute_scaleout_entries();
    assert_eq!(
        entries.len(),
        3 * LAYERS * SCALEOUT_NODES.len() * 3,
        "3 workloads x 3 layers x 3 node counts x 3 partitions"
    );

    if blessing() {
        write_fixture("scaleout.json", &entries);
        eprintln!("golden: blessed {} scaleout entries", entries.len());
        return;
    }

    let pinned = read_fixture("scaleout.json");
    if let Err(e) = check_entries(&entries, &pinned, &SCALEOUT_SPEC) {
        panic!("scaleout.json: {e}");
    }
}

#[test]
fn scaleout_blessing_is_idempotent_in_memory() {
    assert_eq!(compute_scaleout_entries(), compute_scaleout_entries());
}

// ----------------------------------------------------------- fabric fixture

/// Node counts the fabric fixture pins.
const FABRIC_NODES: [u64; 2] = [4, 16];

/// Topologies the fabric fixture pins (`Flat` is the legacy path and
/// carries no per-link data).
const FABRIC_KINDS: [FabricKind; 3] = [FabricKind::Line, FabricKind::Ring, FabricKind::Mesh];

const FABRIC_SPEC: FixtureSpec = FixtureSpec {
    str_keys: &["workload", "fabric"],
    u64_keys: &[
        "nodes",
        "stall_cycles",
        "hop_bytes",
        "dram_requests",
        "dram_row_hits",
        "dram_row_conflicts",
        "dram_cold_misses",
        "dram_total_latency_cycles",
        "dram_queue_wait_cycles",
        "dram_max_latency_cycles",
    ],
    f64_keys: &["max_link_peak_bw", "max_link_avg_bw"],
};

/// Compute every fabric entry: the route-aware contention model plus
/// the banked tick-driven DRAM replay, aggregated over the first
/// [`LAYERS`] layers of the two conv suites (channels partitioning, 8x8
/// nodes, OS dataflow, shared DRAM at [`STALL_BW`], links at
/// [`DEFAULT_LINK_BW`]).
fn compute_fabric_entries() -> Vec<Json> {
    let engine = Engine::builder().dataflow(Dataflow::Os).build().unwrap();
    let mut out = Vec::new();
    for (wname, topo) in cases().into_iter().take(2) {
        for kind in FABRIC_KINDS {
            for &nodes in &FABRIC_NODES {
                let multi =
                    MultiArrayConfig::new(nodes, NODE_DIM, NODE_DIM, Partition::OutputChannels);
                let opts = MultiOpts {
                    shared_dram_bw: Some(STALL_BW),
                    fabric: Some(FabricConfig::new(kind, DEFAULT_LINK_BW)),
                    dram: Some(DramConfig::default()),
                };
                let mut stall = 0u64;
                let mut hop = 0u64;
                let (mut peak, mut avg) = (0.0f64, 0.0f64);
                let (mut requests, mut hits, mut conflicts, mut cold) = (0u64, 0u64, 0u64, 0u64);
                let (mut latency, mut queue_wait, mut max_latency) = (0u64, 0u64, 0u64);
                for layer in topo.layers.iter().take(LAYERS) {
                    let m = engine.run_multi_layer_opts(engine.cfg(), layer, &multi, &opts);
                    let f = m.fabric.as_ref().expect("fabric enabled");
                    stall += m.stall_cycles;
                    hop += f.hop_bytes;
                    peak = peak.max(f.max_link_peak_bw());
                    avg = avg.max(f.max_link_avg_bw());
                    let d = f.dram.expect("banked dram enabled");
                    requests += d.requests;
                    hits += d.row_hits;
                    conflicts += d.row_conflicts;
                    cold += d.cold_misses;
                    latency += d.total_latency_cycles;
                    queue_wait += d.queue_wait_cycles;
                    max_latency = max_latency.max(d.max_latency_cycles);
                }
                out.push(Json::obj(vec![
                    ("workload", Json::str(wname)),
                    ("fabric", Json::str(kind.name())),
                    ("nodes", Json::u64(nodes)),
                    ("stall_cycles", Json::u64(stall)),
                    ("hop_bytes", Json::u64(hop)),
                    ("max_link_peak_bw", Json::f64(peak)),
                    ("max_link_avg_bw", Json::f64(avg)),
                    ("dram_requests", Json::u64(requests)),
                    ("dram_row_hits", Json::u64(hits)),
                    ("dram_row_conflicts", Json::u64(conflicts)),
                    ("dram_cold_misses", Json::u64(cold)),
                    ("dram_total_latency_cycles", Json::u64(latency)),
                    ("dram_queue_wait_cycles", Json::u64(queue_wait)),
                    ("dram_max_latency_cycles", Json::u64(max_latency)),
                ]));
            }
        }
    }
    out
}

#[test]
fn fabric_matches_the_golden_fixture() {
    let entries = compute_fabric_entries();
    assert_eq!(
        entries.len(),
        2 * FABRIC_KINDS.len() * FABRIC_NODES.len(),
        "2 workloads x 3 fabrics x 2 node counts"
    );

    if blessing() {
        write_fixture("fabric.json", &entries);
        eprintln!("golden: blessed {} fabric entries", entries.len());
        return;
    }

    let pinned = read_fixture("fabric.json");
    if let Err(e) = check_entries(&entries, &pinned, &FABRIC_SPEC) {
        panic!("fabric.json: {e}");
    }
}

#[test]
fn fabric_blessing_is_idempotent_in_memory() {
    assert_eq!(compute_fabric_entries(), compute_fabric_entries());
}

// ------------------------------------------------- corrupted-fixture guards

/// Build a tiny synthetic entry carrying the full timing schema.
fn synthetic_entry(cycles: u64) -> Json {
    Json::obj(vec![
        ("workload", Json::str("w")),
        ("layer", Json::str("l")),
        ("backend", Json::str("analytical")),
        ("dataflow", Json::str("os")),
        ("cycles", Json::u64(cycles)),
        ("row_folds", Json::u64(1)),
        ("col_folds", Json::u64(2)),
        ("utilization", Json::f64(0.5)),
        ("mapping_efficiency", Json::f64(1.0)),
        ("sram_reads_ifmap", Json::u64(3)),
        ("sram_reads_filter", Json::u64(4)),
        ("sram_writes_ofmap", Json::u64(5)),
        ("sram_reads_ofmap", Json::u64(0)),
        ("stall_cycles_bw16", Json::u64(7)),
    ])
}

/// Return the entry with `key` dropped.
fn without_key(entry: &Json, key: &str) -> Json {
    let Json::Obj(fields) = entry else { panic!("entry must be an object") };
    Json::Obj(fields.iter().filter(|(k, _)| k != key).cloned().collect())
}

/// Return the entry with an extra unknown key appended.
fn with_unknown_key(entry: &Json) -> Json {
    let Json::Obj(fields) = entry else { panic!("entry must be an object") };
    let mut fields = fields.clone();
    fields.push(("mystery_metric".to_string(), Json::u64(9)));
    Json::Obj(fields)
}

#[test]
fn corrupted_fixtures_fail_instead_of_passing_silently() {
    let computed = vec![synthetic_entry(100)];

    // intact fixture passes
    check_entries(&computed, &[synthetic_entry(100)], &TIMINGS_SPEC).unwrap();

    // a fixture entry MISSING an expected key must error, not compare
    // None == None and pass — this is the regression this test pins
    let err = check_entries(
        &computed,
        &[without_key(&synthetic_entry(100), "stall_cycles_bw16")],
        &TIMINGS_SPEC,
    )
    .unwrap_err();
    assert!(err.contains("missing key \"stall_cycles_bw16\""), "{err}");

    // a computed entry missing a schema key (checker/key-list drift)
    let err = check_entries(
        &[without_key(&synthetic_entry(100), "cycles")],
        &[synthetic_entry(100)],
        &TIMINGS_SPEC,
    )
    .unwrap_err();
    assert!(err.contains("computed entry is missing key \"cycles\""), "{err}");

    // an unknown key in the fixture is corruption, not noise
    let err = check_entries(
        &computed,
        &[with_unknown_key(&synthetic_entry(100))],
        &TIMINGS_SPEC,
    )
    .unwrap_err();
    assert!(err.contains("unknown key \"mystery_metric\""), "{err}");

    // value drift names the entry and field
    let err =
        check_entries(&computed, &[synthetic_entry(101)], &TIMINGS_SPEC).unwrap_err();
    assert!(err.contains("drift on \"cycles\"") && err.contains("[w/l/analytical/os]"), "{err}");

    // entry-count drift
    let err = check_entries(&computed, &[], &TIMINGS_SPEC).unwrap_err();
    assert!(err.contains("entry count drifted"), "{err}");

    // a wrong-typed value (string where a number belongs) reads as missing
    let Json::Obj(mut fields) = synthetic_entry(100) else { unreachable!() };
    for f in fields.iter_mut() {
        if f.0 == "cycles" {
            f.1 = Json::str("fast");
        }
    }
    let err = check_entries(&computed, &[Json::Obj(fields)], &TIMINGS_SPEC).unwrap_err();
    assert!(err.contains("missing key \"cycles\""), "{err}");
}

#[test]
fn checked_in_fixtures_have_no_schema_drift() {
    // even before value comparison, the checked-in fixtures must carry
    // exactly the expected keys on every entry (guards hand-edits)
    for (name, spec, len) in [
        ("timings.json", &TIMINGS_SPEC, 3 * LAYERS * 3 * 3),
        ("scaleout.json", &SCALEOUT_SPEC, 3 * LAYERS * SCALEOUT_NODES.len() * 3),
        ("fabric.json", &FABRIC_SPEC, 2 * FABRIC_KINDS.len() * FABRIC_NODES.len()),
    ] {
        if blessing() {
            continue; // fixtures may be mid-regeneration
        }
        let pinned = read_fixture(name);
        assert_eq!(pinned.len(), len, "{name} entry count");
        for e in &pinned {
            let Json::Obj(fields) = e else { panic!("{name}: entry is not an object") };
            for (k, _) in fields {
                assert!(spec.knows(k), "{name}: unknown key {k:?}");
            }
            let total = spec.str_keys.len() + spec.u64_keys.len() + spec.f64_keys.len();
            assert_eq!(fields.len(), total, "{name}: entry key count");
        }
    }
}
