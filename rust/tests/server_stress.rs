//! Server stress + dse-over-serve suite: concurrent clients against a
//! bounded queue must never deadlock (a full queue sheds with a
//! structured `busy` event and closed-loop clients retry), a mid-flight
//! shutdown must drain every admitted job, batch envelopes must
//! interleave sub-job streams (one slow job never blocks its siblings),
//! a federated instance must fail over to local compute when its peer
//! dies, and a dse campaign must produce bit-identical frontiers
//! whether it runs locally, sharded over a server, over a federated
//! fleet, or is killed and resumed across executors.

use std::path::PathBuf;
use std::time::Duration;

use scale_sim::dse::{self, Campaign, Exec, RunOpts};
use scale_sim::engine::{BackendKind, Partition};
use scale_sim::server::{start, Client, ServeOpts};
use scale_sim::util::json::Json;
use scale_sim::{Dataflow, LayerShape};

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("scale_sim_stress_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn run_request(id: u64) -> String {
    let layers = Json::Arr(vec![scale_sim::server::proto::layer_shape_to_json(
        &LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
    )]);
    Json::obj(vec![
        ("req", Json::str("run")),
        ("id", Json::u64(id)),
        ("workload", Json::str("stress")),
        ("layers", layers),
        ("array", Json::str("16x16")),
    ])
    .to_string()
}

fn tiny_campaign() -> Campaign {
    Campaign {
        name: "stress".into(),
        workloads: vec!["ncf".into()],
        dataflows: vec![Dataflow::Os, Dataflow::Ws],
        arrays: vec![(16, 16), (32, 32)],
        nodes: vec![1],
        partitions: vec![Partition::default()],
        sram_kb: vec![64],
        dram_bw: vec![4.0, 16.0],
        topologies: vec![scale_sim::engine::FabricKind::Flat],
        link_bw: vec![scale_sim::engine::DEFAULT_LINK_BW],
        energy: "28nm".into(),
    }
}

fn local(threads: usize) -> RunOpts {
    RunOpts { exec: Exec::Local { threads }, ..RunOpts::default() }
}

#[test]
fn eight_clients_against_a_tiny_queue_never_deadlock() {
    // queue_cap 2 << clients 8: a full queue sheds with a terminal
    // `busy` event (never blocks the accepting thread); a closed-loop
    // client retries until admitted, and every job must complete
    let handle = start(ServeOpts { workers: 2, queue_cap: 2, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr();
    const CLIENTS: usize = 8;
    const ROUNDS: usize = 4;

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|ci| {
                s.spawn(move || {
                    let mut c = Client::connect(addr).expect("connect");
                    let mut done = 0usize;
                    for r in 0..ROUNDS {
                        let id = (ci * 100 + r) as u64;
                        let last = loop {
                            let events = c.request(&run_request(id)).expect("request");
                            let last = events.last().unwrap().clone();
                            if last.str_field("event") == Some("busy") {
                                assert_eq!(last.u64_field("id"), Some(id), "{last}");
                                std::thread::sleep(Duration::from_millis(2));
                                continue;
                            }
                            break last;
                        };
                        assert_eq!(last.str_field("event"), Some("done"), "{last}");
                        assert_eq!(last.u64_field("id"), Some(id));
                        done += 1;
                    }
                    done
                })
            })
            .collect();
        let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, CLIENTS * ROUNDS);
    });

    let stats = handle.stats();
    assert_eq!(stats.completed, (CLIENTS * ROUNDS) as u64);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(stats.in_flight, 0);
    // one shared cache: the repeated inline layer simulates once
    assert_eq!(stats.memo.layer_sims, 1, "{:?}", stats.memo);
    handle.shutdown();
}

#[test]
fn midflight_shutdown_drains_admitted_jobs_cleanly() {
    let handle = start(ServeOpts { workers: 1, queue_cap: 4, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr();

    // pipeline several jobs without reading responses, so some are
    // queued when the shutdown lands
    let mut submitter = Client::connect(addr).unwrap();
    const JOBS: u64 = 4;
    for id in 0..JOBS {
        submitter.send(&run_request(id)).unwrap();
    }
    let mut killer = Client::connect(addr).unwrap();
    let bye = killer.request(r#"{"req":"shutdown"}"#).unwrap();
    assert_eq!(bye[0].str_field("event"), Some("shutting_down"));

    // every pipelined job must reach a terminal event: `done` for jobs
    // admitted before the close, an error for ones rejected after — and
    // the stream must terminate rather than hang
    let mut terminals = 0;
    let mut dones = 0;
    while terminals < JOBS {
        match submitter.recv() {
            Ok(ev) => {
                if scale_sim::server::proto::is_terminal_event(&ev) {
                    terminals += 1;
                    if ev.str_field("event") == Some("done") {
                        dones += 1;
                    }
                }
            }
            Err(e) => panic!("response stream broke after {terminals} terminals: {e}"),
        }
    }
    handle.join();
    assert!(dones >= 1, "at least the in-flight job must have drained");
}

/// A run request whose single layer's shape depends on `id`, so every
/// job is a distinct memo key (no cache hit or in-flight dedup can make
/// the worker artificially fast).
fn sized_run_request(id: u64) -> String {
    let layers = Json::Arr(vec![scale_sim::server::proto::layer_shape_to_json(
        &LayerShape::conv("c1", 12 + id, 12 + id, 3, 3, 4, 8, 1),
    )]);
    Json::obj(vec![
        ("req", Json::str("run")),
        ("id", Json::u64(id)),
        ("workload", Json::str("stress")),
        ("layers", layers),
        ("array", Json::str("16x16")),
    ])
    .to_string()
}

#[test]
fn full_queue_sheds_with_a_pinned_busy_event() {
    // the rtl backend makes every distinct job slow relative to the
    // admission loop, so pipelining 8 jobs into a 1-worker/1-slot
    // server must shed some of them — with a structured `busy` event,
    // never by blocking the accepting thread (the old wedge)
    const JOBS: u64 = 8;
    let handle = start(ServeOpts {
        workers: 1,
        queue_cap: 1,
        backend: BackendKind::Rtl,
        ..ServeOpts::default()
    })
    .unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();
    for id in 1..=JOBS {
        c.send(&sized_run_request(id)).unwrap();
    }

    let mut terminals = std::collections::BTreeMap::new();
    while terminals.len() < JOBS as usize {
        let ev = c.recv().unwrap();
        if scale_sim::server::proto::is_terminal_event(&ev) {
            terminals.insert(ev.u64_field("id").unwrap(), ev);
        }
    }
    let shed: Vec<u64> = terminals
        .iter()
        .filter(|(_, ev)| ev.str_field("event") == Some("busy"))
        .map(|(id, _)| *id)
        .collect();
    let dones =
        terminals.values().filter(|ev| ev.str_field("event") == Some("done")).count();
    assert_eq!(dones + shed.len(), JOBS as usize, "every job gets exactly one terminal");
    assert!(dones >= 1, "the first job lands in an empty queue and must run");
    assert!(!shed.is_empty(), "an overfull queue must shed");
    // the wire shape is pinned: the event is exactly what proto builds
    assert_eq!(terminals[&shed[0]].to_string(), scale_sim::server::proto::busy_line(shed[0]));

    // busy is transient, not an error: every shed job resubmits to done
    for id in shed {
        loop {
            let events = c.request(&sized_run_request(id)).unwrap();
            match events.last().unwrap().str_field("event") {
                Some("busy") => std::thread::sleep(Duration::from_millis(2)),
                Some("done") => break,
                other => panic!("job {id}: unexpected terminal {other:?}"),
            }
        }
    }
    let stats = handle.stats();
    assert_eq!(stats.completed, JOBS);
    assert_eq!(stats.failed, 0);
    handle.shutdown();
}

#[test]
fn one_slow_batch_job_never_blocks_its_siblings() {
    // workers >= 2 is what makes this a regression test: batch sub-jobs
    // are admitted as independent pool entries, so a slow sweep in slot
    // one must not delay the fast run's events (an envelope executed as
    // one serialized job would emit them in submission order)
    let handle = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();

    // pre-warm the fast job's key so its latency is a cache hit
    let warm = c.request(&run_request(90)).unwrap();
    assert_eq!(warm.last().unwrap().str_field("event"), Some("done"));

    let slow_sweep = Json::obj(vec![
        ("req", Json::str("sweep")),
        ("id", Json::u64(1)),
        ("kind", Json::str("memory")),
        ("workload", Json::str("resnet50")),
    ]);
    let fast_run = Json::parse(&run_request(2)).unwrap();
    let batch = Json::obj(vec![
        ("req", Json::str("batch")),
        ("id", Json::u64(7)),
        ("jobs", Json::Arr(vec![slow_sweep, fast_run])),
    ])
    .to_string();

    let events = c.request_batch(&batch).unwrap();
    let pos = |pred: &dyn Fn(&Json) -> bool| events.iter().position(|e| pred(e));
    let fast_done = pos(&|e| e.str_field("event") == Some("done") && e.u64_field("id") == Some(2))
        .expect("fast sub-job must complete");
    let slow_done = pos(&|e| e.str_field("event") == Some("done") && e.u64_field("id") == Some(1))
        .expect("slow sub-job must complete");
    assert!(
        fast_done < slow_done,
        "the fast job's done (index {fast_done}) must not wait for the slow sweep (index {slow_done})"
    );

    let last = events.last().unwrap();
    assert_eq!(last.str_field("event"), Some("batch_done"), "{last}");
    assert_eq!(last.u64_field("id"), Some(7));
    assert_eq!(last.u64_field("jobs"), Some(2));
    assert_eq!(last.u64_field("shed"), Some(0));
    assert_eq!(handle.stats().failed, 0);
    handle.shutdown();
}

#[test]
fn overfull_batch_sheds_per_sub_job_and_tallies_in_batch_done() {
    // 6 distinct slow sub-jobs against 1 worker and 1 queue slot: the
    // overflow sheds per sub-id, and the batch_done tallies conserve
    let handle = start(ServeOpts {
        workers: 1,
        queue_cap: 1,
        backend: BackendKind::Rtl,
        ..ServeOpts::default()
    })
    .unwrap();
    let mut c = Client::connect(handle.addr()).unwrap();

    const SUBS: u64 = 6;
    let jobs: Vec<Json> =
        (1..=SUBS).map(|id| Json::parse(&sized_run_request(id)).unwrap()).collect();
    let batch = Json::obj(vec![
        ("req", Json::str("batch")),
        ("id", Json::u64(99)),
        ("jobs", Json::Arr(jobs)),
    ])
    .to_string();

    let events = c.request_batch(&batch).unwrap();
    let last = events.last().unwrap();
    assert_eq!(last.str_field("event"), Some("batch_done"), "{last}");
    let (jobs_ran, jobs_shed) =
        (last.u64_field("jobs").unwrap(), last.u64_field("shed").unwrap());
    assert_eq!(jobs_ran + jobs_shed, SUBS, "tallies must conserve: {last}");
    assert!(jobs_ran >= 1, "an empty queue must admit the first sub-job");
    assert!(jobs_shed >= 1, "1 worker + 1 slot cannot hold 6 slow sub-jobs");

    // per-sub-id terminals match the tallies exactly
    let busy = events.iter().filter(|e| e.str_field("event") == Some("busy")).count() as u64;
    let done = events.iter().filter(|e| e.str_field("event") == Some("done")).count() as u64;
    assert_eq!((done, busy), (jobs_ran, jobs_shed));
    assert_eq!(handle.stats().failed, 0);
    handle.shutdown();
}

#[test]
fn peer_death_mid_campaign_fails_over_to_local_compute() {
    let reference = dse::run_campaign(tiny_campaign(), &local(2)).unwrap();
    assert!(reference.is_complete());

    // a fleet of two: A answers only, B routes its peer-owned memo
    // keys to A over the wire
    let a = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
    let b = start(ServeOpts {
        workers: 2,
        peers: vec![a.addr().to_string()],
        ..ServeOpts::default()
    })
    .unwrap();

    // a 26-layer workload spreads keys across the ring: some must
    // reach A as peer-fetch jobs (all-local odds are ~2^-26)
    let mut probe = Client::connect(b.addr()).unwrap();
    let events = probe
        .request(r#"{"req":"run","id":1,"workload":"resnet50"}"#)
        .unwrap();
    assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
    assert!(a.stats().completed >= 1, "no keys routed to the peer");

    // half the campaign with the peer alive...
    let dir = tmp_dir("peer_down");
    let cut = dse::run_campaign(
        tiny_campaign(),
        &RunOpts {
            exec: Exec::Serve { addr: b.addr().to_string(), shards: 2 },
            state_dir: Some(dir.clone()),
            max_points: Some(4),
            ..RunOpts::default()
        },
    )
    .unwrap();
    assert!(!cut.is_complete());

    // ...then the peer dies mid-campaign
    a.shutdown();

    // the rest fails over to B-local compute: zero failed jobs, and
    // the frontier is bit-identical to the unfederated local reference
    // (federation routes keys, never values — docs/INVARIANTS.md §11)
    let resumed = dse::resume_campaign(
        &dir,
        &RunOpts {
            exec: Exec::Serve { addr: b.addr().to_string(), shards: 2 },
            ..RunOpts::default()
        },
    )
    .unwrap();
    assert!(resumed.is_complete());
    assert_eq!(resumed.completed, reference.completed, "federation must never change results");
    assert_eq!(resumed.frontier_runtime_energy, reference.frontier_runtime_energy);
    assert_eq!(resumed.frontier_runtime_bw, reference.frontier_runtime_bw);
    assert_eq!(b.stats().failed, 0, "peer death must fail over, not fail jobs");
    b.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dse_sharded_over_serve_matches_local_bit_for_bit() {
    let reference = dse::run_campaign(tiny_campaign(), &local(2)).unwrap();
    assert!(reference.is_complete());

    let handle = start(ServeOpts { workers: 3, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr().to_string();
    let dir = tmp_dir("shard");
    let out = dse::run_campaign(
        tiny_campaign(),
        &RunOpts {
            exec: Exec::Serve { addr: addr.clone(), shards: 3 },
            state_dir: Some(dir.clone()),
            ..RunOpts::default()
        },
    )
    .unwrap();
    assert!(out.is_complete());
    assert_eq!(out.completed, reference.completed, "sharded metrics must be bit-identical");
    assert_eq!(out.frontier_runtime_energy, reference.frontier_runtime_energy);
    assert_eq!(out.frontier_runtime_bw, reference.frontier_runtime_bw);

    // the shards shared the server's process-wide memo cache: across 8
    // points only the distinct (config, layer-shape) pairs simulated
    let stats = handle.stats();
    assert!(
        stats.memo.cache_hits > stats.memo.layer_sims,
        "shards must share the cache: {:?}",
        stats.memo
    );
    handle.shutdown();

    // the journal a serve-execution wrote resumes like any other
    let report = dse::report_campaign(&dir).unwrap();
    assert_eq!(report.completed, reference.completed);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn killed_serve_campaign_resumes_locally_to_an_identical_frontier() {
    let reference = dse::run_campaign(tiny_campaign(), &local(2)).unwrap();

    let handle = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr().to_string();
    let dir = tmp_dir("kill_resume");
    // "kill" the campaign after 5 of 8 points, executed over the server
    let cut = dse::run_campaign(
        tiny_campaign(),
        &RunOpts {
            exec: Exec::Serve { addr, shards: 2 },
            state_dir: Some(dir.clone()),
            max_points: Some(5),
            ..RunOpts::default()
        },
    )
    .unwrap();
    assert!(!cut.is_complete());
    handle.shutdown(); // the server dies with the campaign

    // resume on a plain local pool: executor change must not change bits
    let resumed = dse::resume_campaign(&dir, &local(2)).unwrap();
    assert!(resumed.is_complete());
    assert_eq!(resumed.ran, 3);
    assert_eq!(resumed.restored, 5);
    assert_eq!(resumed.completed, reference.completed);
    assert_eq!(resumed.frontier_runtime_energy, reference.frontier_runtime_energy);
    assert_eq!(resumed.frontier_runtime_bw, reference.frontier_runtime_bw);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The §IV-E acceptance campaign: multi-array axes over two workloads.
fn multi_campaign() -> Campaign {
    Campaign {
        name: "stress-multi".into(),
        workloads: vec!["ncf".into(), "mlp".into()],
        dataflows: vec![Dataflow::Os],
        arrays: vec![(8, 8)],
        nodes: vec![1, 4, 16, 64],
        partitions: Partition::ALL.to_vec(),
        sram_kb: vec![64],
        dram_bw: vec![4.0, 16.0],
        topologies: vec![scale_sim::engine::FabricKind::Flat],
        link_bw: vec![scale_sim::engine::DEFAULT_LINK_BW],
        energy: "28nm".into(),
    }
}

#[test]
fn multi_array_dse_over_serve_matches_local_with_cross_node_cache_hits() {
    // 2 workloads x {1,4,16,64} nodes x 3 partitions x 2 bandwidths
    let campaign = multi_campaign();
    assert_eq!(campaign.len(), 48);
    let reference = dse::run_campaign(campaign.clone(), &local(2)).unwrap();
    assert!(reference.is_complete());
    // the memoized engine must be exercised hard by the multi axes:
    // bandwidth twins share configs, single-node partition triplets
    // coincide, and Auto re-reads both fixed strategies' sub-shapes
    assert!(
        reference.stats.hit_rate() >= 0.5,
        "multi-array campaign hit rate {:.3} < 0.5 ({:?})",
        reference.stats.hit_rate(),
        reference.stats.memo
    );

    let handle = start(ServeOpts { workers: 3, ..ServeOpts::default() }).unwrap();
    let addr = handle.addr().to_string();
    let dir = tmp_dir("multi_shard");
    let out = dse::run_campaign(
        campaign,
        &RunOpts {
            exec: Exec::Serve { addr, shards: 2 },
            state_dir: Some(dir.clone()),
            ..RunOpts::default()
        },
    )
    .unwrap();
    assert!(out.is_complete());
    assert_eq!(out.completed, reference.completed, "sharded multi-array metrics must be bit-identical");
    assert_eq!(out.frontier_runtime_energy, reference.frontier_runtime_energy);
    assert_eq!(out.frontier_runtime_bw, reference.frontier_runtime_bw);

    // cross-node + cross-shard sharing through the server's ONE memo
    // table: identical sub-shapes across nodes and shards hit, so hits
    // outnumber distinct simulations
    let stats = handle.stats();
    assert!(stats.memo.cache_hits > 0, "no cross-node cache hits: {:?}", stats.memo);
    assert!(
        stats.memo.cache_hits > stats.memo.layer_sims,
        "shards must share the cache: {:?}",
        stats.memo
    );
    handle.shutdown();

    // the journal written over serve reports the same frontier
    let report = dse::report_campaign(&dir).unwrap();
    assert_eq!(report.completed, reference.completed);
    std::fs::remove_dir_all(&dir).unwrap();

    // ...and the campaign survives a kill + resume with a bit-identical
    // frontier: stop after half the grid, resume locally
    let cut_dir = tmp_dir("multi_cut");
    let cut = dse::run_campaign(
        multi_campaign(),
        &RunOpts {
            state_dir: Some(cut_dir.clone()),
            max_points: Some(24),
            ..local(2)
        },
    )
    .unwrap();
    assert!(!cut.is_complete());
    let resumed = dse::resume_campaign(&cut_dir, &local(2)).unwrap();
    assert!(resumed.is_complete());
    assert_eq!((resumed.ran, resumed.restored), (24, 24));
    assert_eq!(resumed.completed, reference.completed);
    assert_eq!(resumed.frontier_runtime_energy, reference.frontier_runtime_energy);
    assert_eq!(resumed.frontier_runtime_bw, reference.frontier_runtime_bw);
    std::fs::remove_dir_all(&cut_dir).unwrap();
}

#[test]
fn garbage_bytes_on_the_wire_get_error_lines_and_the_connection_survives() {
    use std::io::{BufRead, BufReader, Write};

    let handle = start(ServeOpts::default()).unwrap();
    let addr = handle.addr();

    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    let mut lines = BufReader::new(raw.try_clone().unwrap());
    let next_event = |lines: &mut BufReader<std::net::TcpStream>| {
        let mut line = String::new();
        lines.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // non-UTF-8 garbage: an error event, and the connection stays open
    raw.write_all(&[0xff, 0xfe, 0x80, 0x00, b'\n']).unwrap();
    let ev = next_event(&mut lines);
    assert_eq!(ev.str_field("event"), Some("error"), "{ev}");

    // valid UTF-8 that is not a protocol request: another error event
    raw.write_all(b"this is not json\n").unwrap();
    let ev = next_event(&mut lines);
    assert_eq!(ev.str_field("event"), Some("error"), "{ev}");

    // ...and the SAME connection still executes a real job afterwards
    raw.write_all(run_request(7).as_bytes()).unwrap();
    raw.write_all(b"\n").unwrap();
    loop {
        let ev = next_event(&mut lines);
        if scale_sim::server::proto::is_terminal_event(&ev) {
            assert_eq!(ev.str_field("event"), Some("done"), "{ev}");
            assert_eq!(ev.u64_field("id"), Some(7));
            break;
        }
    }
    drop(raw);

    // the server as a whole is unharmed: fresh clients round-trip and
    // no worker died digesting the garbage
    let stats = handle.stats();
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.completed, 1);
    handle.shutdown();
}

#[test]
fn non_positive_dram_bandwidth_is_rejected_without_killing_the_worker() {
    use std::io::{BufRead, BufReader, Write};

    let handle = start(ServeOpts::default()).unwrap();
    let addr = handle.addr();

    let mut raw = std::net::TcpStream::connect(addr).unwrap();
    let mut lines = BufReader::new(raw.try_clone().unwrap());
    let next_event = |lines: &mut BufReader<std::net::TcpStream>| {
        let mut line = String::new();
        lines.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    };

    // a zero shared-DRAM bandwidth used to reach the stall replay's
    // positive-bandwidth assert inside a worker; it must be refused at
    // admission with a structured error event instead
    for req in [
        r#"{"req":"run","id":1,"workload":"ncf","nodes":4,"dram_bw":0}"#,
        r#"{"req":"run","id":2,"workload":"ncf","nodes":4,"dram_bw":-3.5}"#,
        r#"{"req":"run","id":3,"workload":"ncf","nodes":4,"fabric":"line","link_bw":0}"#,
    ] {
        raw.write_all(req.as_bytes()).unwrap();
        raw.write_all(b"\n").unwrap();
        let ev = next_event(&mut lines);
        assert_eq!(ev.str_field("event"), Some("error"), "{req} -> {ev}");
    }

    // the SAME connection then runs a real fabric job to completion
    let good =
        r#"{"req":"run","id":9,"workload":"ncf","nodes":4,"dram_bw":16,"fabric":"mesh","link_bw":8}"#;
    raw.write_all(good.as_bytes()).unwrap();
    raw.write_all(b"\n").unwrap();
    loop {
        let ev = next_event(&mut lines);
        if scale_sim::server::proto::is_terminal_event(&ev) {
            assert_eq!(ev.str_field("event"), Some("done"), "{ev}");
            assert_eq!(ev.u64_field("id"), Some(9));
            break;
        }
    }
    drop(raw);

    // no worker died digesting the bad bandwidths
    let stats = handle.stats();
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.completed, 1);
    handle.shutdown();
}

#[test]
fn truncated_results_store_never_blocks_startup() {
    let dir = tmp_dir("trunc_store");

    // populate a store: run one job, shut down (the supervisor flushes)
    let h1 = start(ServeOpts { state_dir: Some(dir.clone()), ..ServeOpts::default() }).unwrap();
    let mut c = Client::connect(h1.addr()).unwrap();
    let events = c.request(&run_request(1)).unwrap();
    assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
    drop(c);
    h1.shutdown();

    // simulate a kill mid-flush: a truncated trailing line
    let path = dir.join("results.jsonl");
    let mut text = std::fs::read_to_string(&path).unwrap();
    assert!(!text.is_empty(), "shutdown must have flushed the store");
    text.push_str("{\"key\":{\"backend\":\"analytical\",\"arr");
    std::fs::write(&path, text).unwrap();

    // restart on the damaged store: starts, pre-warms the intact lines,
    // and serves — the corrupt tail costs a re-simulation, not a crash
    let h2 = start(ServeOpts { state_dir: Some(dir.clone()), ..ServeOpts::default() }).unwrap();
    let mut c = Client::connect(h2.addr()).unwrap();
    let stats = c.stats().unwrap();
    assert!(stats.warm.entries >= 1, "intact lines must pre-warm: {:?}", stats.warm);
    let events = c.request(&run_request(2)).unwrap();
    assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
    let stats = c.stats().unwrap();
    assert!(stats.warm.hits >= 1, "the rerun job must hit the warm entry: {:?}", stats.warm);
    drop(c);
    h2.shutdown();
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dse_over_serve_rejects_foreign_energy_and_csv_paths() {
    let handle = start(ServeOpts::default()).unwrap();
    let addr = handle.addr().to_string();

    let mut c = tiny_campaign();
    c.energy = "7nm".into(); // server engines price at the default 28nm
    let err = dse::run_campaign(
        c,
        &RunOpts { exec: Exec::Serve { addr: addr.clone(), shards: 1 }, ..RunOpts::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("energy"), "{err}");

    let mut c = tiny_campaign();
    c.workloads = vec!["topologies/ncf.csv".into()];
    let err = dse::run_campaign(
        c,
        &RunOpts { exec: Exec::Serve { addr, shards: 1 }, ..RunOpts::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("built-in"), "{err}");
    handle.shutdown();
}
