//! Concurrency property/stress suite for the lock-striped memo cache,
//! driven entirely through the public [`Engine`] facade (the cache type
//! itself is crate-private; if these properties hold at the facade they
//! hold for every caller).
//!
//! Pinned properties (docs/INVARIANTS.md §11):
//! * hit/miss/in-flight accounting conserves exactly under N-thread
//!   hammering — every lookup is a sim or a hit, never both or neither;
//! * concurrent cold misses on one key compute once per key (per-stripe
//!   in-flight dedup), proven by a call-counting custom backend;
//! * a panicking compute releases its claim — the key stays computable
//!   and concurrent waiters recover;
//! * a replayed deterministic schedule produces bit-identical reports
//!   and identical counter totals at 1 stripe (the historical
//!   single-mutex table) and at 16 stripes;
//! * a shared [`Engine::cache_handle`] spans engines without splitting
//!   the striped table or its counters.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

use scale_sim::config;
use scale_sim::dataflow::Timing;
use scale_sim::engine::{Analytical, Backend, BackendKind};
use scale_sim::{ArchConfig, Engine, LayerShape};

fn shape(i: usize) -> LayerShape {
    let i = i as u64;
    LayerShape::conv(&format!("k{i}"), 8 + i, 8 + i, 3, 3, 4, 8, 1)
}

#[test]
fn hammering_striped_keys_conserves_accounting_exactly() {
    const THREADS: usize = 8;
    const KEYS: usize = 24;
    const REPS: usize = 30; // >= KEYS so every thread's walk covers all keys
    let engine = Engine::builder()
        .config(config::paper_default())
        .cache_stripes(16)
        .build()
        .unwrap();
    let shapes: Vec<LayerShape> = (0..KEYS).map(shape).collect();
    let barrier = Barrier::new(THREADS);

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let (engine, shapes, barrier) = (&engine, &shapes, &barrier);
            s.spawn(move || {
                barrier.wait();
                for r in 0..REPS {
                    // gcd(11, KEYS) == 1: each thread visits every key,
                    // offset so threads collide on different keys at
                    // different times
                    let l = &shapes[(t * 7 + r * 11) % KEYS];
                    engine.run_layer(l);
                }
            });
        }
    });

    let s = engine.cache_stats();
    assert_eq!(
        s.lookups(),
        (THREADS * REPS) as u64,
        "every lookup must be counted exactly once (sim xor hit): {s:?}"
    );
    assert_eq!(
        s.layer_sims, KEYS as u64,
        "each distinct key must be simulated exactly once: {s:?}"
    );
    assert_eq!(s.cache_hits, (THREADS * REPS - KEYS) as u64);
    assert_eq!(engine.cache_entries(), KEYS);
    assert!(
        s.inflight_waits <= s.cache_hits,
        "in-flight waits are a subset of hits: {s:?}"
    );
}

/// Backend that counts how many times the timing model actually runs —
/// the dedup oracle: with in-flight claims working, concurrent misses
/// on one key reach the backend exactly once.
struct Counting {
    calls: Arc<AtomicUsize>,
}

impl Backend for Counting {
    fn kind(&self) -> BackendKind {
        BackendKind::Custom
    }

    fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> Timing {
        self.calls.fetch_add(1, Ordering::SeqCst);
        // widen the window so racing threads genuinely overlap the
        // in-flight claim instead of serializing by accident
        std::thread::sleep(Duration::from_millis(15));
        Analytical.timing(cfg, layer)
    }
}

#[test]
fn concurrent_cold_misses_compute_once_per_key() {
    const THREADS: usize = 8;
    let calls = Arc::new(AtomicUsize::new(0));
    let engine = Engine::builder()
        .config(config::paper_default())
        .custom_backend(Box::new(Counting { calls: Arc::clone(&calls) }))
        .cache_stripes(8)
        .build()
        .unwrap();

    for (round, l) in [shape(0), shape(1)].iter().enumerate() {
        let barrier = Barrier::new(THREADS);
        std::thread::scope(|s| {
            let reports: Vec<_> = (0..THREADS)
                .map(|_| {
                    let (engine, barrier) = (&engine, &barrier);
                    s.spawn(move || {
                        barrier.wait(); // everyone races the same cold key
                        engine.run_layer(l)
                    })
                })
                .collect();
            let reports: Vec<_> = reports.into_iter().map(|h| h.join().unwrap()).collect();
            for r in &reports[1..] {
                assert_eq!(r.timing, reports[0].timing, "waiters must reuse the one result");
            }
        });
        assert_eq!(
            calls.load(Ordering::SeqCst),
            round + 1,
            "backend must have run exactly once per distinct key"
        );
    }

    let s = engine.cache_stats();
    assert_eq!(s.layer_sims, 2);
    assert_eq!(s.cache_hits, (2 * (THREADS - 1)) as u64);
}

/// Backend with an injected-failure budget: the first `failures` timing
/// calls panic, later ones delegate to the analytical model.
struct FailFirst {
    failures: Arc<AtomicUsize>,
}

impl Backend for FailFirst {
    fn kind(&self) -> BackendKind {
        BackendKind::Custom
    }

    fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> Timing {
        if self
            .failures
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |n| n.checked_sub(1))
            .is_ok()
        {
            panic!("injected backend failure");
        }
        Analytical.timing(cfg, layer)
    }
}

#[test]
fn panicking_compute_releases_its_claim() {
    let failures = Arc::new(AtomicUsize::new(1));
    let engine = Engine::builder()
        .config(config::paper_default())
        .custom_backend(Box::new(FailFirst { failures }))
        .cache_stripes(8)
        .build()
        .unwrap();
    let l = shape(3);

    let first = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| engine.run_layer(&l)));
    assert!(first.is_err(), "the injected failure must propagate");
    assert_eq!(engine.cache_entries(), 0, "the failed claim must be withdrawn");
    assert_eq!(engine.cache_stats().layer_sims, 0, "a panicked compute is not a sim");

    // the key is computable again afterwards
    let r = engine.run_layer(&l);
    assert_eq!(r.layer.name, "k3");
    assert_eq!(engine.cache_stats().layer_sims, 1);
    assert_eq!(engine.cache_entries(), 1);
}

#[test]
fn waiter_on_a_panicking_compute_recovers() {
    const THREADS: usize = 4;
    let failures = Arc::new(AtomicUsize::new(1));
    let engine = Engine::builder()
        .config(config::paper_default())
        .custom_backend(Box::new(FailFirst { failures }))
        .cache_stripes(8)
        .build()
        .unwrap();
    let l = shape(4);
    let barrier = Barrier::new(THREADS);

    let outcomes: Vec<bool> = std::thread::scope(|s| {
        (0..THREADS)
            .map(|_| {
                let (engine, l, barrier) = (&engine, &l, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        engine.run_layer(l)
                    }))
                    .is_ok()
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().unwrap())
            .collect()
    });

    // exactly the thread that drew the injected failure panics; every
    // other thread — including any that was blocked on the doomed
    // claim — must retry and come back with a real report
    assert_eq!(outcomes.iter().filter(|ok| !**ok).count(), 1, "{outcomes:?}");
    assert_eq!(outcomes.iter().filter(|ok| **ok).count(), THREADS - 1);
    assert_eq!(engine.cache_entries(), 1);
    assert_eq!(engine.cache_stats().layer_sims, 1);
}

#[test]
fn sharded_totals_match_single_stripe_on_a_replayed_schedule() {
    // the historical single-mutex table is exactly `with 1 stripe`; a
    // fixed lookup schedule replayed against both layouts must agree on
    // every report byte and every counter
    const KEYS: usize = 10;
    let single = Engine::builder().config(config::paper_default()).cache_stripes(1).build().unwrap();
    let striped =
        Engine::builder().config(config::paper_default()).cache_stripes(16).build().unwrap();
    assert_eq!(single.cache_stripe_count(), 1);
    assert_eq!(striped.cache_stripe_count(), 16);

    let schedule: Vec<usize> = (0..200).map(|i| (i * 13 + i / 7) % KEYS).collect();
    for &i in &schedule {
        let l = shape(i);
        let a = single.run_layer(&l);
        let b = striped.run_layer(&l);
        assert_eq!(a, b, "stripe count changed the report for key {i}");
    }
    assert_eq!(single.cache_stats(), striped.cache_stats());
    assert_eq!(single.cache_entries(), striped.cache_entries());
    assert_eq!(single.cache_stats().lookups(), schedule.len() as u64);
}

#[test]
fn shared_cache_handle_spans_engines_without_splitting_the_table() {
    let a = Engine::builder().config(config::paper_default()).cache_stripes(4).build().unwrap();
    let b = Engine::builder()
        .config(config::paper_default())
        .shared_cache(a.cache_handle())
        .build()
        .unwrap();
    assert_eq!(b.cache_stripe_count(), 4, "the handle must carry the striped table whole");

    let l = shape(5);
    let ra = a.run_layer(&l);
    let rb = b.run_layer(&l); // must hit a's entry through the shared table
    assert_eq!(ra, rb);
    let (sa, sb) = (a.cache_stats(), b.cache_stats());
    assert_eq!(sa, sb, "counters are a property of the shared table, not the engine");
    assert_eq!((sa.layer_sims, sa.cache_hits), (1, 1));
    assert_eq!(a.cache_entries(), 1);
    assert_eq!(b.cache_entries(), 1);
}
