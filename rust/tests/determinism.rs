//! Cross-process determinism: the R1 lint rule's end-to-end witness.
//!
//! The dse subsystem promises bit-exact artifacts — journal headers
//! carry a campaign fingerprint, `dse report` output is golden-diffable,
//! resume restores bit-identical metrics. Hash-ordered containers
//! anywhere on those paths would break the promise *across processes*
//! while looking fine within one (std's SipHash keys are per-process).
//! So: run the same campaign in two separate child processes and demand
//! byte-identical journals and reports.
//!
//! Both children run multi-threaded through the engine's default
//! 16-stripe memo cache, so this suite is also the cross-process
//! witness for docs/INVARIANTS.md §11: lock striping (stripes > 1)
//! never perturbs a single output byte.

use std::path::{Path, PathBuf};
use std::process::Command;

const BIN: &str = env!("CARGO_BIN_EXE_scale-sim");

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("scale_sim_det_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

const SPEC: &str = r#"{"name":"det","workloads":["ncf","mlp"],"dataflows":["os","ws"],"arrays":["16x16","32x32"]}"#;

/// Run the campaign in a fresh child process; return the report bytes
/// and the journal header line.
fn run_in_child(work: &Path, tag: &str) -> (String, String) {
    let spec = work.join("campaign.json");
    std::fs::write(&spec, SPEC).unwrap();
    let state = work.join(format!("state_{tag}"));
    let bench = work.join(format!("bench_{tag}.json"));

    let run = Command::new(BIN)
        .current_dir(work)
        .args(["dse", "run", "--threads", "2"])
        .arg("--spec")
        .arg(&spec)
        .arg("--state-dir")
        .arg(&state)
        .arg("--bench")
        .arg(&bench)
        .output()
        .expect("spawn scale-sim dse run");
    assert!(
        run.status.success(),
        "dse run failed: {}",
        String::from_utf8_lossy(&run.stderr)
    );

    let report = Command::new(BIN)
        .current_dir(work)
        .args(["dse", "report", "--state-dir"])
        .arg(&state)
        .output()
        .expect("spawn scale-sim dse report");
    assert!(
        report.status.success(),
        "dse report failed: {}",
        String::from_utf8_lossy(&report.stderr)
    );
    let stdout = String::from_utf8(report.stdout).expect("report output is UTF-8");

    let journal = std::fs::read_to_string(state.join("campaign.jsonl")).unwrap();
    let header = journal.lines().next().expect("journal has a header").to_string();
    (stdout, header)
}

#[test]
fn dse_report_and_journal_fingerprint_are_byte_identical_across_processes() {
    let work = tmp_dir("two_proc");

    // two completely separate OS processes: any per-process hash seed
    // leaking into enumeration order, fingerprints, or report text
    // diverges here
    let (report_a, header_a) = run_in_child(&work, "a");
    let (report_b, header_b) = run_in_child(&work, "b");

    assert!(!report_a.is_empty());
    assert!(report_a.contains("Pareto frontier"), "{report_a}");
    assert_eq!(report_a, report_b, "dse report must be byte-identical across processes");
    assert!(header_a.contains("\"fingerprint\""), "{header_a}");
    assert_eq!(header_a, header_b, "journal headers (spec + fingerprint) must match");

    std::fs::remove_dir_all(&work).unwrap();
}

/// The observability artifacts keep the same promise: `profile`'s trace
/// JSON and deterministic-class metrics snapshot are byte-identical
/// across processes (span cycles derive from simulated time, metric
/// values from event counts — wall time only ever lands in the bench
/// file, which is exempt from the byte comparison).
#[test]
fn profile_trace_and_metrics_are_byte_identical_across_processes() {
    use scale_sim::util::json::Json;

    let work = tmp_dir("profile");
    let run = |tag: &str| -> (String, String) {
        let trace = work.join(format!("trace_{tag}.json"));
        let metrics = work.join(format!("metrics_{tag}.prom"));
        let bench = work.join(format!("bench_{tag}.json"));
        let out = Command::new(BIN)
            .current_dir(&work)
            .args(["profile", "-t", "ncf", "--dram-bw", "16"])
            .arg("--trace-out")
            .arg(&trace)
            .arg("--metrics-out")
            .arg(&metrics)
            .arg("--bench")
            .arg(&bench)
            .output()
            .expect("spawn scale-sim profile");
        assert!(
            out.status.success(),
            "profile failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        (
            std::fs::read_to_string(&trace).unwrap(),
            std::fs::read_to_string(&metrics).unwrap(),
        )
    };

    let (trace_a, metrics_a) = run("a");
    let (trace_b, metrics_b) = run("b");
    assert_eq!(trace_a, trace_b, "trace JSON must be byte-identical across processes");
    assert_eq!(metrics_a, metrics_b, "metrics snapshot must be byte-identical across processes");

    // the trace file is one line of JSON that util::json round-trips
    let line = trace_a.strip_suffix('\n').expect("trace file ends with a newline");
    let parsed = Json::parse(line).expect("trace file parses as JSON");
    assert_eq!(parsed.to_string(), line, "trace JSON must round-trip exactly");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "trace has events");
    for needle in ["scale_sim_cache_misses_total", "scale_sim_cache_hits_total"] {
        assert!(metrics_a.contains(needle), "missing {needle} in:\n{metrics_a}");
    }

    std::fs::remove_dir_all(&work).unwrap();
}

#[test]
fn unknown_cfg_key_diagnostic_is_deterministic() {
    // Config::from_map used to report an arbitrary hash-ordered unknown
    // key; with BTreeMap it must always name the lexicographically first
    use scale_sim::ArchConfig;
    let cfg = "zzz_late: 1\naaa_early: 2\nmmm_mid: 3\n";
    let msgs: Vec<String> = (0..4)
        .map(|_| ArchConfig::parse(cfg).unwrap_err().to_string())
        .collect();
    assert!(msgs[0].contains("\"aaa_early\""), "{}", msgs[0]);
    assert!(msgs.iter().all(|m| m == &msgs[0]), "{msgs:?}");
}
