//! Seeded R6 (half 2): acquires `b` then `a` — opposite order, so the
//! two files together close a lock-order cycle.
fn ba(a: &Mutex<u64>, b: &Mutex<u64>) -> u64 {
    let g = b.lock().unwrap();
    let h = a.lock().unwrap();
    *g + *h
}
