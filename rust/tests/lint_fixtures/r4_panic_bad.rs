//! seeded R4 violations: library code that can take the process down
pub fn panicky(x: Option<u32>) -> u32 {
    if x.is_none() {
        panic!("boom");
    }
    x.unwrap() + Some(1).expect("one")
}
