//! Seeded R8: `Orphan` has no handler anywhere outside proto.rs.
pub enum Request {
    Ping,
    Simulate { id: u64 },
    Orphan,
}
