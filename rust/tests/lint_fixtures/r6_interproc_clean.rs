//! Clean twin: the snapshot is taken and the guard dropped before the
//! re-acquiring call, so no lock is held across `helper`.
pub struct Shared { inner: Mutex<u64> }
impl Shared {
    fn helper(&self) -> u64 { *self.inner.lock().unwrap() }
    fn outer(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        let snapshot = *g;
        drop(g);
        snapshot + self.helper()
    }
}
