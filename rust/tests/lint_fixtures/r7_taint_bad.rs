//! Seeded R7: simulated cycles meeting wall-clock quantities.
fn mix(total_cycles: u64, elapsed_secs: u64) -> u64 {
    total_cycles + elapsed_secs
}
fn observe(reg: &Registry, drained_cycles: u64) {
    reg.observe_seconds("simulate", drained_cycles as f64);
}
