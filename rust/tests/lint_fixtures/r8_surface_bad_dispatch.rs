//! Handles every variant but `Orphan`; also carries a dead pub fn.
fn dispatch(req: Request) {
    match req {
        Request::Ping => {}
        Request::Simulate { id } => run(id),
        _ => {}
    }
}
fn run(_id: u64) {}
pub fn forgotten_helper() -> u64 { 7 }
