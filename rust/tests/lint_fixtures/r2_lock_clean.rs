//! clean twin: copy the data out, drop the guard, then do I/O
use std::io::Write;
use std::sync::Mutex;

pub fn good(m: &Mutex<Vec<u8>>, n: &Mutex<u8>, w: &mut std::net::TcpStream) {
    let data = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner).clone();
    w.write_all(&data).ok();
    let g = n.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    drop(g);
    w.flush().ok();
}
