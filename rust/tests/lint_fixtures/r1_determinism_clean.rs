//! clean twin: ordered containers, no wall clock
use std::collections::BTreeMap;

pub fn ordered() -> BTreeMap<u32, u32> {
    BTreeMap::new()
}
