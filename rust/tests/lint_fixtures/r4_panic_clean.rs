//! clean twin: errors surface as Results; #[cfg(test)] may unwrap
pub fn graceful(x: Option<u32>) -> Result<u32, String> {
    x.ok_or_else(|| "missing".to_string())
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_here() {
        assert_eq!(super::graceful(Some(2)).unwrap(), 2);
    }
}
