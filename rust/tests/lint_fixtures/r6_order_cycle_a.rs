//! Seeded R6 (half 1): acquires `a` then `b`.
fn ab(a: &Mutex<u64>, b: &Mutex<u64>) -> u64 {
    let g = a.lock().unwrap();
    let h = b.lock().unwrap();
    *g + *h
}
