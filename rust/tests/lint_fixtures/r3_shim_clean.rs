//! clean twin: routes through the engine facade; non-deprecated sweep
//! infrastructure (the thread pool) stays legal
pub fn engine_era() {
    let _ = crate::engine::Engine::builder();
    let _ = crate::sweep::default_threads();
}
