//! Clean twin: same-family arithmetic, a wall value in the wall sink,
//! and a rate name that legitimately spans both timelines.
fn total(fill_cycles: u64, drain_cycles: u64) -> u64 {
    fill_cycles + drain_cycles
}
fn observe(reg: &Registry, wall_secs: f64, cycles_per_sec: f64) {
    reg.observe_seconds("simulate", wall_secs + cycles_per_sec * 0.0);
}
