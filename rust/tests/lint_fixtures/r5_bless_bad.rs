//! seeded R5 violation: the bless hook outside the golden suite
pub fn bless() -> bool {
    std::env::var("BLESS_GOLDEN").is_ok()
}
