//! Seeded R6 helper: a callee that performs guarded I/O.
pub(crate) fn send_all(w: &mut TcpStream, b: &[u8]) {
    w.write_all(b).ok();
}
