//! Seeded R6: the guard in `outer` is held across `helper`, which
//! re-acquires the same mutex — invisible to R2's same-function scan.
pub struct Shared { inner: Mutex<u64> }
impl Shared {
    fn helper(&self) -> u64 { *self.inner.lock().unwrap() }
    fn outer(&self) -> u64 {
        let g = self.inner.lock().unwrap();
        *g + self.helper()
    }
}
