//! Seeded R6: guard held across a call into a function that does I/O
//! two files away — R2's same-function scan cannot see it.
use crate::net::send_all;
fn tick(m: &Mutex<Vec<u8>>, w: &mut TcpStream) {
    let g = m.lock().unwrap();
    send_all(w, &g);
}
