//! seeded R2 violations: guard held across I/O, then a second lock
use std::io::Write;
use std::sync::Mutex;

pub fn bad(m: &Mutex<Vec<u8>>, n: &Mutex<u8>, w: &mut std::net::TcpStream) {
    let g = m.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    w.write_all(&g).ok();
    let _h = n.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
}
