//! seeded R1 violations (fixture corpus — excluded from the repo walk)
use std::collections::HashMap;

pub fn wall_clock_and_hash() -> HashMap<u32, u32> {
    let _ = std::time::SystemTime::now();
    HashMap::new()
}
