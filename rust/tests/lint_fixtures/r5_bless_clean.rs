//! clean twin: talks about blessing without wiring the hook
pub fn describe() -> &'static str {
    "golden fixtures are blessed only by the golden suite"
}
