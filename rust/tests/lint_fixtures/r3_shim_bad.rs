//! seeded R3 violations: engine-era code reaching for the shims
pub fn call_shims() {
    let _ = crate::sweep::dataflow_sweep();
    let _ = crate::sim::Simulator::new();
}
