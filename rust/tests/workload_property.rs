//! Property tests over the typed workload IR: randomized operator
//! graphs — including depthwise, grouped and dilated convolutions —
//! must always lower without panicking, the lowered tiles must satisfy
//! the closed-form output-dimension and MAC-count invariants, and
//! `lower()` must be deterministic.

use scale_sim::util::rng::Rng;
use scale_sim::workload::{Conv2d, Op, OpNode, Workload};
use scale_sim::LayerShape;

/// A random *valid* Conv2d, biased to exercise the special lowerings:
/// pointwise, depthwise, grouped, dilated, strided.
fn random_conv(rng: &mut Rng) -> Conv2d {
    let flavor = rng.range(0, 4);
    let (groups, in_channels, out_channels) = match flavor {
        // depthwise: groups == Cin == Cout
        0 => {
            let c = rng.range(1, 16);
            (c, c, c)
        }
        // grouped: groups divides both channel counts
        1 => {
            let g = rng.range(2, 4);
            (g, g * rng.range(1, 6), g * rng.range(1, 6))
        }
        // dense (flavors 2/3 double the weight of the common case)
        _ => (1, rng.range(1, 24), rng.range(1, 24)),
    };
    let kernel_h = rng.range(1, 4);
    let kernel_w = rng.range(1, 4);
    let dilation = rng.range(1, 3);
    let ekh = (kernel_h - 1) * dilation + 1;
    let ekw = (kernel_w - 1) * dilation + 1;
    Conv2d {
        ifmap_h: ekh + rng.range(0, 20),
        ifmap_w: ekw + rng.range(0, 20),
        in_channels,
        out_channels,
        kernel_h,
        kernel_w,
        stride: rng.range(1, 3),
        dilation,
        groups,
    }
}

fn random_op(rng: &mut Rng) -> Op {
    match rng.range(0, 5) {
        0 | 1 => Op::Conv2d(random_conv(rng)),
        2 => Op::Gemm { m: rng.range(1, 64), k: rng.range(1, 96), n: rng.range(1, 64) },
        3 => Op::FullyConnected {
            batch: rng.range(1, 8),
            in_features: rng.range(1, 128),
            out_features: rng.range(1, 64),
        },
        _ => {
            let window_h = rng.range(1, 3);
            let window_w = rng.range(1, 3);
            Op::Pool {
                ifmap_h: window_h + rng.range(0, 16),
                ifmap_w: window_w + rng.range(0, 16),
                channels: rng.range(1, 32),
                window_h,
                window_w,
                stride: rng.range(1, 3),
            }
        }
    }
}

fn random_workload(rng: &mut Rng, tag: u64) -> Workload {
    let n = rng.range(1, 6) as usize;
    let nodes = (0..n)
        .map(|i| OpNode::new(&format!("op{tag}_{i}"), random_op(rng)))
        .collect();
    Workload::new(&format!("w{tag}"), nodes)
}

/// Closed-form MAC count for one op (the lowering must preserve it).
fn expected_macs(op: &Op) -> u64 {
    match op {
        Op::Conv2d(c) => {
            let (ekh, ekw) = c.effective_kernel();
            let ofh = (c.ifmap_h - ekh) / c.stride + 1;
            let ofw = (c.ifmap_w - ekw) / c.stride + 1;
            ofh * ofw * c.kernel_h * c.kernel_w * c.in_channels * c.out_channels / c.groups
        }
        Op::Gemm { m, k, n } => m * k * n,
        Op::FullyConnected { batch, in_features, out_features } => {
            batch * in_features * out_features
        }
        Op::Pool { ifmap_h, ifmap_w, channels, window_h, window_w, stride } => {
            let ofh = (ifmap_h - window_h) / stride + 1;
            let ofw = (ifmap_w - window_w) / stride + 1;
            ofh * ofw * window_h * window_w * channels
        }
        Op::TableII(l) => l.macs(),
    }
}

/// Closed-form per-tile OFMAP dims for a conv op (dilation folded).
fn expected_ofmap(c: &Conv2d) -> (u64, u64) {
    let (ekh, ekw) = c.effective_kernel();
    ((c.ifmap_h - ekh) / c.stride + 1, (c.ifmap_w - ekw) / c.stride + 1)
}

const CASES: u64 = 300;

#[test]
fn random_graphs_lower_without_panic_and_validate() {
    let mut rng = Rng::new(0xA11CE);
    for case in 0..CASES {
        let w = random_workload(&mut rng, case);
        let topo = w.lower().unwrap_or_else(|e| panic!("case {case}: valid graph failed to lower: {e}"));
        assert!(!topo.layers.is_empty(), "case {case}");
        for tile in &topo.layers {
            tile.validate().unwrap_or_else(|e| panic!("case {case}: invalid tile {}: {e}", tile.name));
        }
    }
}

#[test]
fn lowered_tiles_satisfy_mac_and_dimension_invariants() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        let op = random_op(&mut rng);
        let name = format!("p{case}");
        let tiles = op.lower(&name).unwrap();
        let macs: u64 = tiles.iter().map(LayerShape::macs).sum();
        assert_eq!(macs, expected_macs(&op), "case {case}: MAC drift on {op:?}");

        if let Op::Conv2d(c) = &op {
            let (ofh, ofw) = expected_ofmap(c);
            if c.is_pointwise() {
                // canonical GEMM tile: M = H*W, K = Cin, N = Cout
                assert_eq!(tiles.len(), 1);
                assert_eq!(
                    tiles[0].gemm_view(),
                    (c.ifmap_h * c.ifmap_w, c.in_channels, c.out_channels),
                    "case {case}"
                );
            } else {
                let expect_tiles =
                    if c.groups > 1 && !c.is_depthwise() { c.groups } else { 1 };
                assert_eq!(tiles.len() as u64, expect_tiles, "case {case}: {op:?}");
                for tile in &tiles {
                    assert_eq!(
                        (tile.ofmap_h(), tile.ofmap_w()),
                        (ofh, ofw),
                        "case {case}: OFMAP dims drift (dilation folding) on {op:?}"
                    );
                    // dilation must not change the window tap count
                    assert_eq!(
                        tile.filt_h * tile.filt_w,
                        c.kernel_h * c.kernel_w,
                        "case {case}"
                    );
                }
            }
        }
        if let Op::Pool { ifmap_h, ifmap_w, window_h, window_w, stride, .. } = &op {
            let ofh = (ifmap_h - window_h) / stride + 1;
            let ofw = (ifmap_w - window_w) / stride + 1;
            assert_eq!(tiles.len(), 1);
            assert_eq!(tiles[0].npx(), ofh * ofw, "case {case}");
            assert_eq!(tiles[0].num_filters, 1, "case {case}");
        }
    }
}

#[test]
fn lowering_is_deterministic() {
    let mut rng = Rng::new(0xD00D);
    for case in 0..CASES {
        let w = random_workload(&mut rng, case);
        let a = w.lower().unwrap();
        let b = w.lower().unwrap();
        assert_eq!(a, b, "case {case}: lower() must be deterministic");
        // and insensitive to an intervening clone
        assert_eq!(w.clone().lower().unwrap(), a, "case {case}");
    }
}

#[test]
fn invalid_ops_error_instead_of_panicking() {
    let mut rng = Rng::new(0xFA11);
    for case in 0..CASES {
        let op = random_op(&mut rng);
        // break one invariant; every mutation must produce Err, never panic
        let broken: Vec<Op> = match &op {
            Op::Conv2d(c) => vec![
                Op::Conv2d(Conv2d { in_channels: 0, ..c.clone() }),
                Op::Conv2d(Conv2d { stride: 0, ..c.clone() }),
                Op::Conv2d(Conv2d { kernel_h: c.ifmap_h + c.dilation, ..c.clone() }),
                Op::Conv2d(Conv2d {
                    groups: c.in_channels + 1,
                    in_channels: c.in_channels + 2,
                    ..c.clone()
                }),
            ],
            Op::Gemm { k, n, .. } => vec![Op::Gemm { m: 0, k: *k, n: *n }],
            Op::FullyConnected { in_features, out_features, .. } => vec![Op::FullyConnected {
                batch: 0,
                in_features: *in_features,
                out_features: *out_features,
            }],
            Op::Pool { ifmap_h, ifmap_w, channels, window_w, stride, .. } => vec![Op::Pool {
                ifmap_h: *ifmap_h,
                ifmap_w: *ifmap_w,
                channels: *channels,
                window_h: ifmap_h + 1,
                window_w: *window_w,
                stride: *stride,
            }],
            Op::TableII(_) => Vec::new(),
        };
        for bad in broken {
            assert!(
                bad.lower("bad").is_err(),
                "case {case}: {bad:?} must be rejected, not lowered"
            );
        }
    }
}
