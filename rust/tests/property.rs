//! Property-based tests over randomized layer shapes and array
//! geometries — the L3 coordinator invariants (routing of operands into
//! folds, batching of folds into schedules, memory state).

use scale_sim::config::{self, ArchConfig};
use scale_sim::dataflow::Dataflow;
use scale_sim::memory;
use scale_sim::trace;
use scale_sim::util::prop::{forall, Shrink};
use scale_sim::util::rng::Rng;
use scale_sim::LayerShape;

/// Random-but-valid layer + array geometry for property tests.
#[derive(Clone, Debug)]
struct Case {
    layer: LayerShape,
    rows: u64,
    cols: u64,
}

impl Shrink for Case {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        let l = &self.layer;
        // shrink each dimension toward 1 while keeping validity
        let mut push = |layer: LayerShape, rows, cols| {
            if layer.validate().is_ok() {
                out.push(Case { layer, rows, cols });
            }
        };
        if l.ifmap_h > l.filt_h {
            push(LayerShape { ifmap_h: l.ifmap_h - 1, ..l.clone() }, self.rows, self.cols);
        }
        if l.ifmap_w > l.filt_w {
            push(LayerShape { ifmap_w: l.ifmap_w - 1, ..l.clone() }, self.rows, self.cols);
        }
        if l.channels > 1 {
            push(LayerShape { channels: l.channels / 2, ..l.clone() }, self.rows, self.cols);
        }
        if l.num_filters > 1 {
            push(LayerShape { num_filters: l.num_filters / 2, ..l.clone() }, self.rows, self.cols);
        }
        if self.rows > 1 {
            push(l.clone(), self.rows / 2, self.cols);
        }
        if self.cols > 1 {
            push(l.clone(), self.rows, self.cols / 2);
        }
        out
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let filt_h = rng.range(1, 5);
    let filt_w = rng.range(1, 5);
    let layer = LayerShape {
        name: "prop".into(),
        ifmap_h: filt_h + rng.range(0, 12),
        ifmap_w: filt_w + rng.range(0, 12),
        filt_h,
        filt_w,
        channels: rng.range(1, 8),
        num_filters: rng.range(1, 24),
        stride: rng.range(1, 3),
    };
    Case { layer, rows: rng.range(1, 20), cols: rng.range(1, 20) }
}

fn cfg_for(case: &Case) -> ArchConfig {
    ArchConfig { array_h: case.rows, array_w: case.cols, ..config::paper_default() }
}

#[test]
fn prop_trace_runtime_equals_analytical_all_dataflows() {
    for df in Dataflow::ALL {
        forall(0xA11CE + df as u64, 60, gen_case, |case| {
            let t = df.timing(&case.layer, case.rows, case.cols);
            let s = trace::summarize(df, &case.layer, &cfg_for(case));
            s.cycles() == t.cycles
                && s.ifmap_reads == t.sram_reads_ifmap
                && s.filter_reads == t.sram_reads_filter
                && s.ofmap_writes == t.sram_writes_ofmap
                && s.ofmap_reads == t.sram_reads_ofmap
        });
    }
}

#[test]
fn prop_utilization_in_unit_interval() {
    for df in Dataflow::ALL {
        forall(0xB0B + df as u64, 150, gen_case, |case| {
            let t = df.timing(&case.layer, case.rows, case.cols);
            t.utilization > 0.0
                && t.utilization <= 1.0 + 1e-12
                && t.mapping_efficiency > 0.0
                && t.mapping_efficiency <= 1.0 + 1e-12
        });
    }
}

#[test]
fn prop_cycles_lower_bounded_by_ideal() {
    // runtime >= macs / PEs (no array computes faster than one MAC per
    // PE per cycle)
    for df in Dataflow::ALL {
        forall(0xDEAD + df as u64, 150, gen_case, |case| {
            let t = df.timing(&case.layer, case.rows, case.cols);
            t.cycles as u128 * (case.rows * case.cols) as u128 >= case.layer.macs() as u128
        });
    }
}

#[test]
fn prop_bigger_array_never_slower() {
    // doubling both array dims never increases runtime
    for df in Dataflow::ALL {
        forall(0xF00D + df as u64, 80, gen_case, |case| {
            let t1 = df.timing(&case.layer, case.rows, case.cols).cycles;
            let t2 = df.timing(&case.layer, case.rows * 2, case.cols * 2).cycles;
            t2 <= t1
        });
    }
}

#[test]
fn prop_dram_traffic_monotone_in_sram() {
    for df in Dataflow::ALL {
        forall(0xCAFE + df as u64, 40, gen_case, |case| {
            let mut last = u64::MAX;
            for kb in [1u64, 8, 64, 512] {
                let cfg = ArchConfig {
                    ifmap_sram_kb: kb,
                    filter_sram_kb: kb,
                    ofmap_sram_kb: kb,
                    ..cfg_for(case)
                };
                let t = memory::simulate(df, &case.layer, &cfg).0.total();
                if t > last {
                    return false;
                }
                last = t;
            }
            true
        });
    }
}

#[test]
fn prop_dram_traffic_at_least_compulsory() {
    // DRAM fetches can never be below each operand's compulsory
    // footprint. The ifmap's compulsory set is its *touched row span*
    // (strides > filter dims skip rows, and trailing rows beyond the
    // last window are never needed).
    for df in Dataflow::ALL {
        forall(0x5EED + df as u64, 80, gen_case, |case| {
            let (t, _) = memory::simulate(df, &case.layer, &cfg_for(case));
            let l = &case.layer;
            // distinct ifmap rows touched: windows overlap when
            // stride < filt_h, and skip rows entirely when stride > filt_h
            let touched_rows = if l.stride >= l.filt_h {
                l.ofmap_h() * l.filt_h
            } else {
                (l.ofmap_h() - 1) * l.stride + l.filt_h
            };
            let ifmap_min = match df {
                // OS fetches whole rows of the touched span
                Dataflow::Os => touched_rows * l.ifmap_w * l.channels,
                // WS streams element-slices summing to the whole ifmap
                Dataflow::Ws => l.ifmap_elems(),
                // IS pins per-window regions (proportional slices) —
                // only positivity is universally guaranteed
                Dataflow::Is => 1,
            };
            t.ifmap_bytes >= ifmap_min
                && t.filter_bytes >= l.filter_elems()
                && t.ofmap_bytes >= l.ofmap_elems()
        });
    }
}

#[test]
fn prop_streamed_operand_reads_cover_macs() {
    // The *streamed* operand (ifmap for OS/WS, filters for IS) enters
    // the array edge once per reuse width: its edge-read count times the
    // array dimension it broadcasts across must cover all MACs. The
    // *pinned* operand is reused temporally and carries no such bound.
    for df in Dataflow::ALL {
        forall(0x1CE + df as u64, 100, gen_case, |case| {
            let t = df.timing(&case.layer, case.rows, case.cols);
            let macs = case.layer.macs() as u128;
            match df {
                Dataflow::Os => {
                    // both operands stream under OS
                    (t.sram_reads_ifmap as u128) * (case.cols as u128) >= macs
                        && (t.sram_reads_filter as u128) * (case.rows as u128) >= macs
                }
                Dataflow::Ws => (t.sram_reads_ifmap as u128) * (case.cols as u128) >= macs,
                Dataflow::Is => (t.sram_reads_filter as u128) * (case.cols as u128) >= macs,
            }
        });
    }
}

#[test]
fn prop_fold_schedule_partitions_work() {
    for df in Dataflow::ALL {
        forall(0xFA1D + df as u64, 100, gen_case, |case| {
            let (npx, k, nf) = case.layer.gemm_view();
            let (tr, tc) = match df {
                Dataflow::Os => (npx, nf),
                Dataflow::Ws => (k, nf),
                Dataflow::Is => (k, npx),
            };
            let mut area = 0u64;
            let mut cycle = 0u64;
            for f in trace::fold_schedule(df, &case.layer, case.rows, case.cols) {
                if f.start != cycle {
                    return false; // folds must be contiguous
                }
                cycle += f.cycles;
                area += f.r_used * f.c_used;
            }
            area == tr * tc
        });
    }
}

// ---------------------------------------------------------------------
// util::json round-trip fuzz (the wire format the serve protocol and the
// persistent result store depend on)

use scale_sim::util::json::{Json, MAX_DEPTH};

/// Characters chosen to stress the escaper: quotes, backslashes, every
/// short escape, a control char that needs \u00xx, '/', and multi-byte
/// UTF-8 (incl. a non-BMP scalar that encoders may surrogate-escape).
const STRING_POOL: &[char] = &[
    'a', 'Z', '9', ' ', '"', '\\', '\n', '\r', '\t', '\u{8}', '\u{c}', '\u{1}', '\u{1f}', '/',
    'é', '\u{2603}', '\u{1f600}',
];

fn random_string(rng: &mut Rng) -> String {
    let len = rng.range(0, 12) as usize;
    (0..len).map(|_| *rng.pick(STRING_POOL)).collect()
}

/// A random JSON document; `depth` bounds container nesting.
fn random_json(rng: &mut Rng, depth: u64) -> Json {
    // range is inclusive: 0..=4 are scalars; 5 (only when depth
    // remains) recurses into a container
    let top = if depth == 0 { 4 } else { 5 };
    match rng.range(0, top) {
        0 => Json::Null,
        1 => Json::Bool(rng.next_u64() % 2 == 0),
        2 => Json::u64(rng.next_u64()),
        3 => {
            // finite f64 via a ratio of draws (never NaN/Inf)
            let num = rng.range(0, 1 << 20) as f64 - (1 << 19) as f64;
            let den = rng.range(1, 1 << 10) as f64;
            Json::f64(num / den)
        }
        4 => Json::Str(random_string(rng)),
        _ => {
            let n = rng.range(0, 4) as usize;
            if rng.next_u64() % 2 == 0 {
                Json::Arr((0..n).map(|_| random_json(rng, depth - 1)).collect())
            } else {
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("{}{i}", random_string(rng)), random_json(rng, depth - 1)))
                        .collect(),
                )
            }
        }
    }
}

#[test]
fn prop_json_parse_write_parse_is_identity() {
    forall(0x150u64, 400, |r: &mut Rng| r.next_u64(), |&seed: &u64| {
        let mut rng = Rng::new(seed);
        let doc = random_json(&mut rng, 5);
        let text = doc.to_string();
        let Ok(parsed) = Json::parse(&text) else { return false };
        // value identity AND textual fixpoint: write(parse(write(v)))
        // must equal write(v), or persisted stores would churn
        parsed == doc && parsed.to_string() == text
    });
}

#[test]
fn prop_json_depth_cap_is_exact() {
    // parse succeeds exactly up to MAX_DEPTH, whatever mix of [ and {
    forall(0xDEEPu64, 80, |r: &mut Rng| r.range(1, (MAX_DEPTH + 8) as u64), |&d: &u64| {
        let mut open = String::new();
        let mut close = String::new();
        for i in 0..d {
            if i % 2 == 0 {
                open.push('[');
                close.insert(0, ']');
            } else {
                open.push_str("{\"k\":");
                close.insert(0, '}');
            }
        }
        open.push_str("null");
        open.push_str(&close);
        Json::parse(&open).is_ok() == (d as usize <= MAX_DEPTH)
    });
}

#[test]
fn prop_json_string_escapes_round_trip() {
    forall(0xE5Cu64, 300, |r: &mut Rng| r.next_u64(), |&seed: &u64| {
        let mut rng = Rng::new(seed);
        let s = random_string(&mut rng);
        let doc = Json::Str(s.clone());
        match Json::parse(&doc.to_string()) {
            Ok(back) => back.as_str() == Some(s.as_str()),
            Err(_) => false,
        }
    });
}

#[test]
fn prop_json_numbers_round_trip_bit_exactly() {
    forall(0xF64u64, 500, |r: &mut Rng| r.next_u64(), |&seed: &u64| {
        let mut rng = Rng::new(seed);
        // u64 path
        let u = rng.next_u64();
        if Json::parse(&Json::u64(u).to_string()).ok().and_then(|j| j.as_u64()) != Some(u) {
            return false;
        }
        // finite f64 path: compare bit patterns after the round trip
        let x = f64::from_bits(rng.next_u64());
        if !x.is_finite() {
            return true; // JSON carries finite values only
        }
        match Json::parse(&Json::f64(x).to_string()).ok().and_then(|j| j.as_f64()) {
            Some(back) => back.to_bits() == x.to_bits(),
            None => false,
        }
    });
}
