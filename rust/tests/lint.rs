//! Regression suite for the in-tree static-analysis pass
//! (`rust/src/analysis`, surfaced as `scale-sim lint`).
//!
//! Three layers:
//!
//! 1. **Fixture corpus** (`rust/tests/lint_fixtures/`): one seeded
//!    violation per rule plus a clean twin, asserted down to the exact
//!    `file:line` + rule id. The corpus directory is excluded from the
//!    repo walk, so the seeded violations never reach the CI gate.
//! 2. **Baseline ratchet**: the checked-in `lint.baseline` parses,
//!    records the pre-PR finding count, and round-trips bit-exactly.
//! 3. **Self-clean**: linting the repo's own sources produces exactly
//!    the baselined findings — no drift — both through the library API
//!    and through the `scale-sim lint` CLI that ci.sh gates on.

use std::path::Path;
use std::process::Command;

use scale_sim::analysis::{self, Baseline, RuleId};

const ROOT: &str = env!("CARGO_MANIFEST_DIR");
const BIN: &str = env!("CARGO_BIN_EXE_scale-sim");

/// Lint fixture text under a pretend repo-relative path.
fn hits(rel: &str, src: &str) -> Vec<(RuleId, u32)> {
    analysis::lint_source(rel, src).into_iter().map(|f| (f.rule, f.line)).collect()
}

// ------------------------------------------------------ fixture corpus

#[test]
fn r1_fixture_flags_hash_containers_and_wall_clock_exactly() {
    let bad = include_str!("lint_fixtures/r1_determinism_bad.rs");
    // under a determinism-critical path: both halves of the rule fire
    assert_eq!(
        hits("rust/src/dse/fixture.rs", bad),
        vec![(RuleId::R1, 2), (RuleId::R1, 4), (RuleId::R1, 5), (RuleId::R1, 6)]
    );
    // under a non-critical path the HashMaps are legal; the wall clock
    // is not (only util::bench / util::rng may touch it)
    assert_eq!(hits("rust/src/arch/fixture.rs", bad), vec![(RuleId::R1, 5)]);

    let clean = include_str!("lint_fixtures/r1_determinism_clean.rs");
    assert_eq!(hits("rust/src/dse/fixture.rs", clean), vec![]);
}

#[test]
fn r2_fixture_flags_io_and_second_lock_under_a_held_guard() {
    let bad = include_str!("lint_fixtures/r2_lock_bad.rs");
    assert_eq!(
        hits("rust/src/server/fixture.rs", bad),
        vec![(RuleId::R2, 7), (RuleId::R2, 8)],
        "line 7: write_all under the guard; line 8: nested lock()"
    );
    let clean = include_str!("lint_fixtures/r2_lock_clean.rs");
    assert_eq!(hits("rust/src/server/fixture.rs", clean), vec![]);
}

#[test]
fn r3_fixture_flags_shim_calls_only_inside_the_protected_scope() {
    let bad = include_str!("lint_fixtures/r3_shim_bad.rs");
    assert_eq!(
        hits("rust/src/engine/fixture.rs", bad),
        vec![(RuleId::R3, 3), (RuleId::R3, 4)]
    );
    // shims may reference each other: the same text under sim/ is legal
    assert_eq!(hits("rust/src/sim/fixture.rs", bad), vec![]);

    let clean = include_str!("lint_fixtures/r3_shim_clean.rs");
    assert_eq!(hits("rust/src/engine/fixture.rs", clean), vec![]);
}

#[test]
fn r4_fixture_flags_panics_in_lib_code_but_not_in_main_or_tests() {
    let bad = include_str!("lint_fixtures/r4_panic_bad.rs");
    assert_eq!(
        hits("rust/src/util/fixture.rs", bad),
        vec![(RuleId::R4, 4), (RuleId::R4, 6), (RuleId::R4, 6)],
        "panic! on 4; unwrap and expect on 6"
    );
    // the CLI binary may panic on broken invariants
    assert_eq!(hits("rust/src/main.rs", bad), vec![]);

    let clean = include_str!("lint_fixtures/r4_panic_clean.rs");
    assert_eq!(
        hits("rust/src/util/fixture.rs", clean),
        vec![],
        "#[cfg(test)] regions may unwrap"
    );
}

#[test]
fn r5_fixture_flags_the_bless_hook_everywhere_but_the_golden_suite() {
    let bad = include_str!("lint_fixtures/r5_bless_bad.rs");
    assert_eq!(hits("rust/src/util/fixture.rs", bad), vec![(RuleId::R5, 3)]);
    // unlike R1-R4, R5 applies to test code too...
    assert_eq!(hits("rust/tests/other.rs", bad), vec![(RuleId::R5, 3)]);
    // ...except the golden suite itself, whose job is blessing
    assert_eq!(hits("rust/tests/golden_helpers.rs", bad), vec![]);

    let clean = include_str!("lint_fixtures/r5_bless_clean.rs");
    assert_eq!(hits("rust/src/util/fixture.rs", clean), vec![]);
}

#[test]
fn diagnostics_render_as_clickable_file_line_rule() {
    let bad = include_str!("lint_fixtures/r4_panic_bad.rs");
    let findings = analysis::lint_source("rust/src/util/fixture.rs", bad);
    assert_eq!(
        findings[0].render(),
        "rust/src/util/fixture.rs:4: R4[panic-hygiene]: `panic!` in library code — \
         a poisoned lock or malformed input must surface as an Error (or recover \
         via PoisonError::into_inner), not take the process down"
    );
}

// ---------------------------------------------------- baseline ratchet

#[test]
fn checked_in_baseline_parses_and_records_the_ratchet_floor() {
    let text = std::fs::read_to_string(Path::new(ROOT).join("lint.baseline")).unwrap();
    let b = Baseline::parse(&text).unwrap();
    assert_eq!(
        b.pre_pr_violations,
        Some(66),
        "the tree before the lint pass landed carried 66 findings"
    );
    assert!(
        b.total() < 66,
        "the ratchet requires the baseline to sit strictly below the pre-PR count, \
         got {}",
        b.total()
    );
}

#[test]
fn baseline_round_trips_and_detects_both_drift_directions() {
    let bad = include_str!("lint_fixtures/r4_panic_bad.rs");
    let findings = analysis::lint_source("rust/src/util/fixture.rs", bad);
    assert_eq!(findings.len(), 3);

    // render -> parse -> check: the exact finding set is clean
    let mut b = Baseline::from_findings(&findings);
    b.pre_pr_violations = Some(10);
    let back = Baseline::parse(&b.render()).unwrap();
    assert_eq!(back, b);
    assert!(back.check(&findings).is_empty());

    // one extra finding: New drift. One fixed finding: Stale drift.
    assert_eq!(Baseline::from_findings(&findings[..2]).check(&findings).len(), 1);
    assert_eq!(back.check(&findings[..2]).len(), 1);
}

// ------------------------------------------------------- self-clean

#[test]
fn the_repo_lints_clean_against_its_checked_in_baseline() {
    let root = Path::new(ROOT);
    let findings = analysis::lint_root(root).unwrap();
    let baseline = analysis::load_baseline(&analysis::default_baseline_path(root)).unwrap();
    let drift = baseline.check(&findings);
    assert!(
        drift.is_empty(),
        "lint drift against lint.baseline:\n{}",
        scale_sim::analysis::report::render_drift(&drift, &findings)
    );
    // the pass lints itself
    let files = analysis::collect_sources(root).unwrap();
    assert!(files.iter().any(|f| f == "rust/src/analysis/rules.rs"));
    assert!(files.iter().all(|f| !f.contains("lint_fixtures")));
}

#[test]
fn the_cli_gate_passes_and_fails_like_the_library() {
    // the exact invocation ci.sh gates on
    let ok = Command::new(BIN).args(["lint", "--root", ROOT]).output().unwrap();
    assert!(
        ok.status.success(),
        "scale-sim lint failed:\n{}{}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );
    let stdout = String::from_utf8_lossy(&ok.stdout);
    assert!(stdout.contains("clean"), "{stdout}");

    // with the ratchet disabled the baselined findings become failures:
    // the gate actually bites
    let strict = Command::new(BIN)
        .args(["lint", "--root", ROOT, "--no-baseline", "--list"])
        .output()
        .unwrap();
    assert!(!strict.status.success(), "--no-baseline must fail while findings remain");
    let listing = String::from_utf8_lossy(&strict.stdout);
    assert!(listing.contains("R2[lock-discipline]"), "{listing}");
    assert!(listing.contains("rust/src/dse/journal.rs"), "{listing}");
}
