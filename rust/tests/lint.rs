//! Regression suite for the in-tree static-analysis pass
//! (`rust/src/analysis`, surfaced as `scale-sim lint`).
//!
//! Four layers:
//!
//! 1. **Fixture corpus** (`rust/tests/lint_fixtures/`): one seeded
//!    violation per rule plus a clean twin, asserted down to the exact
//!    `file:line` + rule id — including the interprocedural families
//!    (R6–R8), whose fixtures are multi-file crates fed through the
//!    call graph. The corpus directory is excluded from the repo walk,
//!    so the seeded violations never reach the CI gate.
//! 2. **Baseline ratchet**: the checked-in `lint.baseline` parses,
//!    records the pre-PR finding count, and — now that the R1–R5 debt
//!    is fully burned down — may only carry interprocedural entries.
//! 3. **Self-clean**: linting the repo's own sources produces exactly
//!    the baselined findings — no drift — both through the library API
//!    and through the `scale-sim lint` CLI that ci.sh gates on.
//! 4. **Gate bite**: seeded violation trees (a lock-order cycle, a
//!    cycles-into-wall-histogram mix) must *fail* the CLI, and
//!    `--format json` output must be byte-deterministic and round-trip.

use std::path::{Path, PathBuf};
use std::process::Command;

use scale_sim::analysis::{self, Baseline, Finding, RuleId};

const ROOT: &str = env!("CARGO_MANIFEST_DIR");
const BIN: &str = env!("CARGO_BIN_EXE_scale-sim");

/// Lint fixture text under a pretend repo-relative path (R1–R5).
fn hits(rel: &str, src: &str) -> Vec<(RuleId, u32)> {
    analysis::lint_source(rel, src).into_iter().map(|f| (f.rule, f.line)).collect()
}

/// Run the interprocedural families (R6–R8) over a pretend crate.
fn interp(files: &[(&str, &str)]) -> Vec<Finding> {
    let sources: Vec<(String, String)> =
        files.iter().map(|(a, b)| (a.to_string(), b.to_string())).collect();
    scale_sim::analysis::rules::lint_interprocedural(&sources)
}

/// Materialize a pretend repo tree under a unique temp dir.
fn seed_tree(tag: &str, files: &[(&str, &str)]) -> PathBuf {
    let root =
        std::env::temp_dir().join(format!("scale_sim_lint_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    for (rel, text) in files {
        let p = root.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(&p, text).unwrap();
    }
    root
}

// ------------------------------------------------------ fixture corpus

#[test]
fn r1_fixture_flags_hash_containers_and_wall_clock_exactly() {
    let bad = include_str!("lint_fixtures/r1_determinism_bad.rs");
    // under a determinism-critical path: both halves of the rule fire
    assert_eq!(
        hits("rust/src/dse/fixture.rs", bad),
        vec![(RuleId::R1, 2), (RuleId::R1, 4), (RuleId::R1, 5), (RuleId::R1, 6)]
    );
    // under a non-critical path the HashMaps are legal; the wall clock
    // is not (only util::bench / util::rng may touch it)
    assert_eq!(hits("rust/src/arch/fixture.rs", bad), vec![(RuleId::R1, 5)]);

    let clean = include_str!("lint_fixtures/r1_determinism_clean.rs");
    assert_eq!(hits("rust/src/dse/fixture.rs", clean), vec![]);
}

#[test]
fn r2_fixture_flags_io_and_second_lock_under_a_held_guard() {
    let bad = include_str!("lint_fixtures/r2_lock_bad.rs");
    assert_eq!(
        hits("rust/src/server/fixture.rs", bad),
        vec![(RuleId::R2, 7), (RuleId::R2, 8)],
        "line 7: write_all under the guard; line 8: nested lock()"
    );
    let clean = include_str!("lint_fixtures/r2_lock_clean.rs");
    assert_eq!(hits("rust/src/server/fixture.rs", clean), vec![]);
}

#[test]
fn r3_fixture_flags_shim_calls_only_inside_the_protected_scope() {
    let bad = include_str!("lint_fixtures/r3_shim_bad.rs");
    assert_eq!(
        hits("rust/src/engine/fixture.rs", bad),
        vec![(RuleId::R3, 3), (RuleId::R3, 4)]
    );
    // shims may reference each other: the same text under sim/ is legal
    assert_eq!(hits("rust/src/sim/fixture.rs", bad), vec![]);

    let clean = include_str!("lint_fixtures/r3_shim_clean.rs");
    assert_eq!(hits("rust/src/engine/fixture.rs", clean), vec![]);
}

#[test]
fn r4_fixture_flags_panics_in_lib_code_but_not_in_main_or_tests() {
    let bad = include_str!("lint_fixtures/r4_panic_bad.rs");
    assert_eq!(
        hits("rust/src/util/fixture.rs", bad),
        vec![(RuleId::R4, 4), (RuleId::R4, 6), (RuleId::R4, 6)],
        "panic! on 4; unwrap and expect on 6"
    );
    // the CLI binary may panic on broken invariants
    assert_eq!(hits("rust/src/main.rs", bad), vec![]);

    let clean = include_str!("lint_fixtures/r4_panic_clean.rs");
    assert_eq!(
        hits("rust/src/util/fixture.rs", clean),
        vec![],
        "#[cfg(test)] regions may unwrap"
    );
}

#[test]
fn r5_fixture_flags_the_bless_hook_everywhere_but_the_golden_suite() {
    let bad = include_str!("lint_fixtures/r5_bless_bad.rs");
    assert_eq!(hits("rust/src/util/fixture.rs", bad), vec![(RuleId::R5, 3)]);
    // unlike R1-R4, R5 applies to test code too...
    assert_eq!(hits("rust/tests/other.rs", bad), vec![(RuleId::R5, 3)]);
    // ...except the golden suite itself, whose job is blessing
    assert_eq!(hits("rust/tests/golden_helpers.rs", bad), vec![]);

    let clean = include_str!("lint_fixtures/r5_bless_clean.rs");
    assert_eq!(hits("rust/src/util/fixture.rs", clean), vec![]);
}

#[test]
fn r6_fixture_cross_function_double_lock_that_r2_provably_misses() {
    let bad = include_str!("lint_fixtures/r6_interproc_bad.rs");
    // the same-function scan (R2) sees nothing wrong in `outer`...
    assert!(
        hits("rust/src/engine/fixture.rs", bad).iter().all(|(r, _)| *r != RuleId::R2),
        "R2 must be blind to the cross-function re-acquisition"
    );
    // ...but the call graph catches the guard held across a callee that
    // re-acquires the same mutex
    let found = interp(&[("rust/src/engine/fixture.rs", bad)]);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(
        (found[0].rule, found[0].file.as_str(), found[0].line),
        (RuleId::R6, "rust/src/engine/fixture.rs", 8)
    );
    assert!(found[0].message.contains("Shared.inner"), "{}", found[0].message);

    let clean = include_str!("lint_fixtures/r6_interproc_clean.rs");
    assert_eq!(interp(&[("rust/src/engine/fixture.rs", clean)]), vec![]);
}

#[test]
fn r6_fixture_two_file_lock_order_cycle() {
    let a = include_str!("lint_fixtures/r6_order_cycle_a.rs");
    let b = include_str!("lint_fixtures/r6_order_cycle_b.rs");
    // each half alone fixes an order — only together do they conflict
    assert_eq!(interp(&[("rust/src/order_a.rs", a)]), vec![]);
    assert_eq!(interp(&[("rust/src/order_b.rs", b)]), vec![]);
    let found = interp(&[("rust/src/order_a.rs", a), ("rust/src/order_b.rs", b)]);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(
        (found[0].rule, found[0].file.as_str(), found[0].line),
        (RuleId::R6, "rust/src/order_a.rs", 4),
        "anchored at the lexicographically smallest edge site"
    );
    assert!(found[0].message.contains("lock-order cycle"), "{}", found[0].message);
    assert!(found[0].message.contains("a -> b -> a"), "{}", found[0].message);
}

#[test]
fn r6_fixture_guard_held_across_callee_that_does_io_two_files_away() {
    let callee = include_str!("lint_fixtures/r6_io_callee.rs");
    let caller = include_str!("lint_fixtures/r6_io_caller.rs");
    let found = interp(&[("rust/src/net.rs", callee), ("rust/src/svc.rs", caller)]);
    assert_eq!(found.len(), 1, "{found:?}");
    assert_eq!(
        (found[0].rule, found[0].file.as_str(), found[0].line),
        (RuleId::R6, "rust/src/svc.rs", 6)
    );
    assert!(found[0].message.contains("performs I/O"), "{}", found[0].message);
    assert!(found[0].message.contains("net::send_all"), "{}", found[0].message);
}

#[test]
fn r7_fixture_flags_cross_timeline_arithmetic_and_the_wall_sink() {
    let bad = include_str!("lint_fixtures/r7_taint_bad.rs");
    let found = interp(&[("rust/src/obs/fixture.rs", bad)]);
    let pins: Vec<(RuleId, u32)> = found.iter().map(|f| (f.rule, f.line)).collect();
    assert_eq!(pins, vec![(RuleId::R7, 3), (RuleId::R7, 6)], "{found:?}");
    assert!(found[1].message.contains("wall-time sink"), "{}", found[1].message);

    let clean = include_str!("lint_fixtures/r7_taint_clean.rs");
    assert_eq!(interp(&[("rust/src/obs/fixture.rs", clean)]), vec![]);
    // tests and the documented trace exemption are out of scope
    assert_eq!(interp(&[("rust/tests/fixture.rs", bad)]), vec![]);
    assert_eq!(interp(&[("rust/src/obs/trace.rs", bad)]), vec![]);
}

#[test]
fn r8_fixture_unhandled_proto_variant_and_dead_pub_fn() {
    let proto = include_str!("lint_fixtures/r8_surface_bad_proto.rs");
    let dispatch = include_str!("lint_fixtures/r8_surface_bad_dispatch.rs");
    let found = interp(&[
        ("rust/src/server/proto.rs", proto),
        ("rust/src/server/mod.rs", dispatch),
    ]);
    let pins: Vec<(RuleId, &str, u32)> =
        found.iter().map(|f| (f.rule, f.file.as_str(), f.line)).collect();
    assert!(
        pins.contains(&(RuleId::R8, "rust/src/server/proto.rs", 5)),
        "Orphan variant unhandled: {found:?}"
    );
    assert!(
        pins.contains(&(RuleId::R8, "rust/src/server/mod.rs", 10)),
        "forgotten_helper is dead surface: {found:?}"
    );
    assert_eq!(found.len(), 2, "{found:?}");
}

#[test]
fn diagnostics_render_as_clickable_file_line_rule() {
    let bad = include_str!("lint_fixtures/r4_panic_bad.rs");
    let findings = analysis::lint_source("rust/src/util/fixture.rs", bad);
    assert_eq!(
        findings[0].render(),
        "rust/src/util/fixture.rs:4: R4[panic-hygiene]: `panic!` in library code — \
         a poisoned lock or malformed input must surface as an Error (or recover \
         via PoisonError::into_inner), not take the process down"
    );
}

// ---------------------------------------------------- baseline ratchet

#[test]
fn checked_in_baseline_parses_and_records_the_ratchet_floor() {
    let text = std::fs::read_to_string(Path::new(ROOT).join("lint.baseline")).unwrap();
    let b = Baseline::parse(&text).unwrap();
    assert_eq!(
        b.pre_pr_violations,
        Some(66),
        "the tree before the lint pass landed carried 66 findings"
    );
    assert!(
        b.total() < 66,
        "the ratchet requires the baseline to sit strictly below the pre-PR count, \
         got {}",
        b.total()
    );
    // the R1–R5 debt is fully burned down: only the interprocedural
    // families may carry accepted findings from here on
    assert!(
        b.counts.keys().all(|(r, _)| matches!(r, RuleId::R6 | RuleId::R7 | RuleId::R8)),
        "R1–R5 baseline sections must stay empty, got {:?}",
        b.counts
    );
}

#[test]
fn baseline_round_trips_and_detects_both_drift_directions() {
    let bad = include_str!("lint_fixtures/r4_panic_bad.rs");
    let findings = analysis::lint_source("rust/src/util/fixture.rs", bad);
    assert_eq!(findings.len(), 3);

    // render -> parse -> check: the exact finding set is clean
    let mut b = Baseline::from_findings(&findings);
    b.pre_pr_violations = Some(10);
    let back = Baseline::parse(&b.render()).unwrap();
    assert_eq!(back, b);
    assert!(back.check(&findings).is_empty());

    // one extra finding: New drift. One fixed finding: Stale drift.
    assert_eq!(Baseline::from_findings(&findings[..2]).check(&findings).len(), 1);
    assert_eq!(back.check(&findings[..2]).len(), 1);
}

// ------------------------------------------------------- self-clean

#[test]
fn the_repo_lints_clean_against_its_checked_in_baseline() {
    let root = Path::new(ROOT);
    let findings = analysis::lint_root(root).unwrap();
    let baseline = analysis::load_baseline(&analysis::default_baseline_path(root)).unwrap();
    let drift = baseline.check(&findings);
    assert!(
        drift.is_empty(),
        "lint drift against lint.baseline:\n{}",
        scale_sim::analysis::report::render_drift(&drift, &findings)
    );
    // the pass lints itself — including the interprocedural modules
    let files = analysis::collect_sources(root).unwrap();
    assert!(files.iter().any(|f| f == "rust/src/analysis/rules.rs"));
    assert!(files.iter().any(|f| f == "rust/src/analysis/callgraph.rs"));
    assert!(files.iter().all(|f| !f.contains("lint_fixtures")));
}

#[test]
fn the_cli_gate_passes_and_fails_like_the_library() {
    // the exact invocation ci.sh gates on
    let ok = Command::new(BIN).args(["lint", "--root", ROOT]).output().unwrap();
    assert!(
        ok.status.success(),
        "scale-sim lint failed:\n{}{}",
        String::from_utf8_lossy(&ok.stdout),
        String::from_utf8_lossy(&ok.stderr)
    );
    let stdout = String::from_utf8_lossy(&ok.stdout);
    assert!(stdout.contains("clean"), "{stdout}");

    // with the ratchet disabled the baselined findings become failures:
    // the gate actually bites (the one remaining accepted finding is
    // R8's dead-surface entry for the deprecated scaleout shim)
    let strict = Command::new(BIN)
        .args(["lint", "--root", ROOT, "--no-baseline", "--list"])
        .output()
        .unwrap();
    assert!(!strict.status.success(), "--no-baseline must fail while findings remain");
    let listing = String::from_utf8_lossy(&strict.stdout);
    assert!(listing.contains("R8[dead-surface]"), "{listing}");
    assert!(listing.contains("rust/src/scaleout/mod.rs"), "{listing}");
}

// ------------------------------------------------------- gate bite

#[test]
fn the_cli_gate_fails_on_a_seeded_lock_order_cycle() {
    let root = seed_tree(
        "cycle",
        &[
            (
                "rust/src/x.rs",
                "fn ab(a: &Mutex<u64>, b: &Mutex<u64>) {\n    let g = a.lock();\n    \
                 let h = b.lock();\n    drop(h);\n    drop(g);\n}\n",
            ),
            (
                "rust/src/y.rs",
                "fn ba(a: &Mutex<u64>, b: &Mutex<u64>) {\n    let g = b.lock();\n    \
                 let h = a.lock();\n    drop(h);\n    drop(g);\n}\n",
            ),
        ],
    );
    let out = Command::new(BIN)
        .args(["lint", "--root", root.to_str().unwrap(), "--list"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "a seeded lock-order cycle must fail the gate");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("R6[lock-order]"), "{text}");
    assert!(text.contains("lock-order cycle"), "{text}");
    assert!(text.contains("rust/src/x.rs:3"), "anchored deterministically: {text}");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn the_cli_gate_fails_on_cycles_fed_into_a_wall_histogram() {
    let root = seed_tree(
        "taint",
        &[(
            "rust/src/m.rs",
            "fn observe(reg: &Registry, sim_cycles: u64) {\n    \
             reg.observe_seconds(\"simulate\", sim_cycles as f64);\n}\n",
        )],
    );
    let out = Command::new(BIN)
        .args(["lint", "--root", root.to_str().unwrap(), "--list"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "a seeded timeline mix must fail the gate");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("R7[unit-taint]"), "{text}");
    assert!(text.contains("wall-time sink"), "{text}");
    assert!(text.contains("rust/src/m.rs:2"), "{text}");
    std::fs::remove_dir_all(&root).unwrap();
}

#[test]
fn cli_json_format_is_byte_deterministic_and_round_trips() {
    let run = || {
        Command::new(BIN)
            .args(["lint", "--root", ROOT, "--format", "json"])
            .output()
            .unwrap()
    };
    let one = run();
    assert!(one.status.success(), "{}", String::from_utf8_lossy(&one.stderr));
    let two = run();
    assert_eq!(one.stdout, two.stdout, "same sources must give identical bytes");

    let text = String::from_utf8(one.stdout).unwrap();
    let parsed = scale_sim::analysis::report::findings_from_json(&text).unwrap();
    let lib = analysis::lint_root(Path::new(ROOT)).unwrap();
    assert_eq!(parsed, lib, "the JSON document carries exactly the library findings");

    // unknown formats are rejected up front
    let bad = Command::new(BIN)
        .args(["lint", "--root", ROOT, "--format", "yaml"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
}
