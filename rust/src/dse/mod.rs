//! Resumable design-space-exploration campaigns — the `scale-sim dse`
//! subsystem.
//!
//! The paper's headline contribution is not one simulation but the §IV
//! sweeps: bandwidth, dataflow and array aspect ratio explored across
//! vision/speech/text/game workloads, reported as runtime **and energy**
//! trade-offs. [`crate::engine::SweepGrid`] runs cartesian grids, but a
//! grid run is ephemeral — nothing survives a crash, nothing prunes the
//! dominated points, nothing distributes the work. This module adds the
//! campaign layer on top of the engine:
//!
//! * [`Campaign`] — a declarative spec of the axes (workloads x dataflow
//!   x array shape x node count x partition x scratchpad KB x DRAM
//!   bytes/cycle), buildable in code or parsed from a small JSON file.
//!   The `nodes`/`partitions` axes sweep §IV-E multi-array scale-out
//!   systems ([`crate::engine::multi`]) next to the single-array axes —
//!   Pareto frontiers over array count come for free. Points are
//!   enumerated in a fixed nested order (workload outer, bandwidth
//!   innermost), so every point has a stable index — the unit of
//!   checkpointing and sharding.
//! * [`evaluate_point`] — the objective extractor: stall-free runtime
//!   from the engine's memoized [`crate::engine::Engine::run_layer_with`]
//!   path, stall cycles from the finite-bandwidth replay
//!   ([`crate::memory::stall`]) at the point's DRAM bandwidth, energy
//!   from [`crate::energy`], the stall-free peak/avg DRAM bandwidth
//!   requirement, and row-hit statistics from the banked DRAM substrate
//!   ([`crate::dram`]).
//! * [`pareto::pareto_front`] — dominated-point pruning; the campaign
//!   reports the runtime-vs-energy and runtime-vs-peak-bandwidth
//!   frontiers (Fig 6/7-style conclusions, but as frontiers rather than
//!   single curves).
//! * [`journal::Journal`] — a checkpoint/resume log: with a state
//!   directory every completed point is appended (and fsync-flushed) to
//!   `campaign.jsonl`; a killed campaign restarts with `dse resume` and
//!   re-simulates **only** the unfinished points, and because
//!   [`crate::util::json`] round-trips every number exactly, the
//!   resumed frontier is bit-identical to an uninterrupted run's.
//! * [`exec`] — pluggable execution: a local
//!   [`crate::sweep::parallel_map`] pool over one memoizing engine, or
//!   shards submitted as jobs to a running `scale-sim serve`, where the
//!   server's ONE process-wide memo cache is shared across all shards.
//!
//! ```text
//! let campaign = Campaign::paper();              // §IV axes
//! let out = dse::run_campaign(campaign, &RunOpts::default())?;
//! for &i in &out.frontier_runtime_energy {
//!     let p = &out.completed[i];
//!     println!("{} {} {}x{}: {} cycles, {} mJ", ...);
//! }
//! ```

pub mod exec;
pub mod journal;
pub mod pareto;

pub use exec::{
    frontiers, report_campaign, resume_campaign, run_campaign, CampaignOutcome, Exec, RunOpts,
};
pub use journal::Journal;
pub use pareto::pareto_front;

use std::collections::BTreeMap;

use crate::config::{workloads, ArchConfig, Topology};
use crate::dataflow::Dataflow;
use crate::dram::{self, DramConfig};
use crate::energy::EnergyModel;
use crate::engine::{
    Engine, FabricConfig, FabricKind, MultiArrayConfig, MultiOpts, Partition, DEFAULT_LINK_BW,
};
use crate::memory::stall;
use crate::util::json::Json;
use crate::{Error, Result};

/// A declarative campaign: the cartesian axes of one design-space
/// exploration. Point `index` decodes in nested order — workload
/// outermost, then dataflow, array shape, node count, partition,
/// scratchpad size, and DRAM bandwidth innermost — so consecutive
/// indices share their architecture configuration and therefore their
/// memo-cache entries.
#[derive(Clone, Debug, PartialEq)]
pub struct Campaign {
    pub name: String,
    /// Workload specs: built-in names (conv or GEMM family) or csv
    /// paths. Shard-over-serve execution accepts built-in names only
    /// (the server has no access to client files).
    pub workloads: Vec<String>,
    pub dataflows: Vec<Dataflow>,
    /// Array shapes `(rows, cols)` — the Fig 8 aspect-ratio axis.
    pub arrays: Vec<(u64, u64)>,
    /// Multi-array node counts (§IV-E scale-out axis): each value `n`
    /// simulates `n` replicas of the point's array shape. `[1]` (the
    /// default) keeps the campaign single-array.
    pub nodes: Vec<u64>,
    /// Partition strategies for multi-array points.
    pub partitions: Vec<Partition>,
    /// Scratchpad sizes in KB, applied to the IFMAP and filter
    /// partitions in lockstep (the Fig 7 convention).
    pub sram_kb: Vec<u64>,
    /// DRAM read bandwidths in bytes/cycle — the stall-model axis.
    pub dram_bw: Vec<f64>,
    /// Interconnect topologies for multi-array points
    /// ([`crate::engine::fabric`]): `[Flat]` (the default) keeps the
    /// legacy equal-split contention; `Line`/`Ring`/`Mesh` route the
    /// shared-DRAM traffic hop by hop.
    pub topologies: Vec<FabricKind>,
    /// Per-link bandwidths in bytes/cycle for the fabric axis.
    pub link_bw: Vec<f64>,
    /// Energy-model preset name (see [`EnergyModel::preset`]).
    pub energy: String,
}

impl Campaign {
    /// The paper's §IV axes: bandwidth x dataflow x aspect ratio over a
    /// game workload (AlphaGoZero, W1) and a recommendation workload
    /// (NCF, W4), with the Fig 7 scratchpad ladder.
    pub fn paper() -> Campaign {
        Campaign {
            name: "paper".into(),
            workloads: vec!["alphagozero".into(), "ncf".into()],
            dataflows: Dataflow::ALL.to_vec(),
            arrays: vec![(32, 512), (64, 256), (128, 128), (256, 64), (512, 32)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            sram_kb: vec![64, 256, 1024],
            dram_bw: vec![10.0, 40.0],
            topologies: vec![FabricKind::Flat],
            link_bw: vec![DEFAULT_LINK_BW],
            energy: "28nm".into(),
        }
    }

    /// The §IV-E scale-out study as a campaign: 8x8 nodes swept over the
    /// paper's PE budgets under all three partition strategies.
    pub fn paper_scaleout() -> Campaign {
        Campaign {
            name: "paper-scaleout".into(),
            workloads: vec!["alphagozero".into(), "ncf".into()],
            dataflows: vec![Dataflow::Os],
            arrays: vec![(crate::engine::multi::NODE_DIM, crate::engine::multi::NODE_DIM)],
            nodes: vec![1, 4, 16, 64, 256],
            partitions: Partition::ALL.to_vec(),
            sram_kb: vec![512],
            dram_bw: vec![10.0, 40.0],
            topologies: vec![FabricKind::Flat],
            link_bw: vec![DEFAULT_LINK_BW],
            energy: "28nm".into(),
        }
    }

    /// Number of grid points (product of the axis lengths).
    pub fn len(&self) -> usize {
        self.workloads.len()
            * self.dataflows.len()
            * self.arrays.len()
            * self.nodes.len()
            * self.partitions.len()
            * self.sram_kb.len()
            * self.dram_bw.len()
            * self.topologies.len()
            * self.link_bw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Check axis invariants (non-empty axes, positive dimensions,
    /// finite positive bandwidths, resolvable energy preset).
    pub fn validate(&self) -> Result<()> {
        let bad = |m: String| Err(Error::Dse(format!("campaign {:?}: {m}", self.name)));
        if self.workloads.is_empty() {
            return bad("no workloads".into());
        }
        if self.dataflows.is_empty()
            || self.arrays.is_empty()
            || self.sram_kb.is_empty()
            || self.dram_bw.is_empty()
        {
            return bad("every axis needs at least one value".into());
        }
        if self.arrays.iter().any(|&(h, w)| h == 0 || w == 0) {
            return bad("array dimensions must be positive".into());
        }
        if self.nodes.is_empty() || self.partitions.is_empty() {
            return bad("nodes and partitions axes need at least one value".into());
        }
        if self.nodes.iter().any(|&n| n == 0) {
            return bad("node counts must be positive".into());
        }
        if self.sram_kb.iter().any(|&kb| kb == 0) {
            return bad("sram_kb entries must be positive".into());
        }
        if self.dram_bw.iter().any(|&bw| !bw.is_finite() || bw <= 0.0) {
            return bad("dram_bw entries must be finite and positive".into());
        }
        if self.topologies.is_empty() || self.link_bw.is_empty() {
            return bad("topologies and link_bw axes need at least one value".into());
        }
        if self.link_bw.iter().any(|&bw| !bw.is_finite() || bw <= 0.0) {
            return bad("link_bw entries must be finite and positive".into());
        }
        if EnergyModel::preset(&self.energy).is_none() {
            return bad(format!("unknown energy preset {:?} (28nm|45nm|7nm)", self.energy));
        }
        Ok(())
    }

    /// The campaign's energy model (validated preset).
    pub fn energy_model(&self) -> Result<EnergyModel> {
        EnergyModel::preset(&self.energy).ok_or_else(|| {
            Error::Dse(format!("unknown energy preset {:?} (28nm|45nm|7nm)", self.energy))
        })
    }

    /// Decode one grid point by its stable index (panics when out of
    /// range — callers iterate `0..len()`).
    pub fn point(&self, index: usize) -> CampaignPoint {
        assert!(index < self.len(), "point index {index} out of {}", self.len());
        let mut i = index;
        let link_bw = self.link_bw[i % self.link_bw.len()];
        i /= self.link_bw.len();
        let topology = self.topologies[i % self.topologies.len()];
        i /= self.topologies.len();
        let dram_bw = self.dram_bw[i % self.dram_bw.len()];
        i /= self.dram_bw.len();
        let sram_kb = self.sram_kb[i % self.sram_kb.len()];
        i /= self.sram_kb.len();
        let partition = self.partitions[i % self.partitions.len()];
        i /= self.partitions.len();
        let nodes = self.nodes[i % self.nodes.len()];
        i /= self.nodes.len();
        let (array_h, array_w) = self.arrays[i % self.arrays.len()];
        i /= self.arrays.len();
        let dataflow = self.dataflows[i % self.dataflows.len()];
        i /= self.dataflows.len();
        CampaignPoint {
            index,
            workload: self.workloads[i].clone(),
            dataflow,
            array_h,
            array_w,
            nodes,
            partition,
            sram_kb,
            dram_bw,
            topology,
            link_bw,
        }
    }

    /// Every grid point in index order.
    pub fn points(&self) -> Vec<CampaignPoint> {
        (0..self.len()).map(|i| self.point(i)).collect()
    }

    /// Resolve each workload spec to its lowered topology. With
    /// `builtin_only` (the serve path) csv paths are rejected — the
    /// server never reads client-named files.
    pub fn resolve_workloads(&self, builtin_only: bool) -> Result<BTreeMap<String, Topology>> {
        let mut map = BTreeMap::new();
        for spec in &self.workloads {
            if map.contains_key(spec) {
                continue;
            }
            let topo = match workloads::builtin_workload(spec) {
                Some(w) => w.lower()?,
                None if builtin_only => {
                    return Err(Error::Dse(format!(
                        "unknown built-in workload {spec:?} (dse-over-serve accepts \
                         built-in names only; see `scale-sim workloads`)"
                    )))
                }
                None => crate::workload::Workload::from_file(std::path::Path::new(spec))?
                    .lower()?,
            };
            map.insert(spec.clone(), topo);
        }
        Ok(map)
    }

    /// Canonical JSON form (stable field order). The multi-array axes
    /// are emitted only when they deviate from their single-array
    /// defaults (`[1]` / `["channels"]`), so a single-array campaign's
    /// canonical form — and therefore its [`Campaign::fingerprint`] —
    /// is identical to what pre-multi-array builds wrote: their
    /// journals keep resuming.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(self.name.clone())),
            (
                "workloads",
                Json::Arr(self.workloads.iter().map(|w| Json::Str(w.clone())).collect()),
            ),
            (
                "dataflows",
                Json::Arr(self.dataflows.iter().map(|d| Json::str(d.name())).collect()),
            ),
            (
                "arrays",
                Json::Arr(
                    self.arrays.iter().map(|&(h, w)| Json::str(format!("{h}x{w}"))).collect(),
                ),
            ),
        ];
        if self.nodes != [1] {
            fields.push((
                "nodes",
                Json::Arr(self.nodes.iter().map(|&n| Json::u64(n)).collect()),
            ));
        }
        if self.partitions != [Partition::OutputChannels] {
            fields.push((
                "partitions",
                Json::Arr(self.partitions.iter().map(|p| Json::str(p.name())).collect()),
            ));
        }
        fields.push((
            "sram_kb",
            Json::Arr(self.sram_kb.iter().map(|&kb| Json::u64(kb)).collect()),
        ));
        fields.push((
            "dram_bw",
            Json::Arr(self.dram_bw.iter().map(|&bw| Json::f64(bw)).collect()),
        ));
        // fabric axes: same omit-when-default convention as
        // nodes/partitions, so pre-fabric fingerprints keep resuming
        if self.topologies != [FabricKind::Flat] {
            fields.push((
                "topologies",
                Json::Arr(self.topologies.iter().map(|t| Json::str(t.name())).collect()),
            ));
        }
        if self.link_bw != [DEFAULT_LINK_BW] {
            fields.push((
                "link_bw",
                Json::Arr(self.link_bw.iter().map(|&bw| Json::f64(bw)).collect()),
            ));
        }
        fields.push(("energy", Json::str(self.energy.clone())));
        Json::obj(fields)
    }

    /// Parse the JSON form. Missing axes default to a single value
    /// (array 128x128, 1 node, channels partition, sram 512 KB,
    /// bandwidth 64 B/cycle, all three dataflows, 28 nm energy);
    /// `workloads` is required.
    pub fn from_json(j: &Json) -> std::result::Result<Campaign, String> {
        let name = j.str_field("name").unwrap_or("campaign").to_string();
        let workloads = match j.get("workloads").and_then(Json::as_arr) {
            Some(a) if !a.is_empty() => a
                .iter()
                .map(|v| {
                    v.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| "\"workloads\" entries must be strings".to_string())
                })
                .collect::<std::result::Result<Vec<_>, String>>()?,
            _ => return Err("campaign needs a non-empty \"workloads\" array".into()),
        };
        let dataflows = match j.get("dataflows") {
            None => Dataflow::ALL.to_vec(),
            Some(v) => {
                let a = v.as_arr().ok_or("\"dataflows\" must be an array")?;
                a.iter()
                    .map(|d| {
                        let s = d.as_str().ok_or("\"dataflows\" entries must be strings")?;
                        Dataflow::parse(s).map_err(|e| e.to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let arrays = match j.get("arrays") {
            None => vec![(128, 128)],
            Some(v) => {
                let a = v.as_arr().ok_or("\"arrays\" must be an array")?;
                a.iter()
                    .map(|s| -> std::result::Result<(u64, u64), String> {
                        let s = s
                            .as_str()
                            .ok_or_else(|| "\"arrays\" entries must be \"RxC\" strings".to_string())?;
                        let (r, c) = s
                            .split_once('x')
                            .ok_or_else(|| format!("bad array shape {s:?} (RxC)"))?;
                        Ok((
                            r.parse().map_err(|_| format!("bad array rows {r:?}"))?,
                            c.parse().map_err(|_| format!("bad array cols {c:?}"))?,
                        ))
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let nodes = match j.get("nodes") {
            None => vec![1],
            Some(v) => {
                let a = v.as_arr().ok_or("\"nodes\" must be an array")?;
                a.iter()
                    .map(|x| {
                        x.as_u64().ok_or_else(|| "\"nodes\" entries must be u64".to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let partitions = match j.get("partitions") {
            None => vec![Partition::default()],
            Some(v) => {
                let a = v.as_arr().ok_or("\"partitions\" must be an array")?;
                a.iter()
                    .map(|p| {
                        let s =
                            p.as_str().ok_or("\"partitions\" entries must be strings")?;
                        Partition::parse(s).map_err(|e| e.to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let sram_kb = match j.get("sram_kb") {
            None => vec![512],
            Some(v) => {
                let a = v.as_arr().ok_or("\"sram_kb\" must be an array")?;
                a.iter()
                    .map(|x| {
                        x.as_u64().ok_or_else(|| "\"sram_kb\" entries must be u64".to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let dram_bw = match j.get("dram_bw") {
            None => vec![64.0],
            Some(v) => {
                let a = v.as_arr().ok_or("\"dram_bw\" must be an array")?;
                a.iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| "\"dram_bw\" entries must be numbers".to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let topologies = match j.get("topologies") {
            None => vec![FabricKind::Flat],
            Some(v) => {
                let a = v.as_arr().ok_or("\"topologies\" must be an array")?;
                a.iter()
                    .map(|t| {
                        let s =
                            t.as_str().ok_or("\"topologies\" entries must be strings")?;
                        FabricKind::parse(s).map_err(|e| e.to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let link_bw = match j.get("link_bw") {
            None => vec![DEFAULT_LINK_BW],
            Some(v) => {
                let a = v.as_arr().ok_or("\"link_bw\" must be an array")?;
                a.iter()
                    .map(|x| {
                        x.as_f64()
                            .ok_or_else(|| "\"link_bw\" entries must be numbers".to_string())
                    })
                    .collect::<std::result::Result<Vec<_>, String>>()?
            }
        };
        let energy = j.str_field("energy").unwrap_or("28nm").to_string();
        Ok(Campaign {
            name,
            workloads,
            dataflows,
            arrays,
            nodes,
            partitions,
            sram_kb,
            dram_bw,
            topologies,
            link_bw,
            energy,
        })
    }

    /// Stable hash of the canonical JSON form — the journal's identity
    /// check: `dse resume` refuses a state dir whose journal was written
    /// for a different campaign.
    pub fn fingerprint(&self) -> String {
        let text = self.to_json().to_string();
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in text.as_bytes() {
            h ^= u64::from(*b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        format!("{h:016x}")
    }
}

/// One decoded grid point: the campaign coordinates plus its stable
/// enumeration index.
#[derive(Clone, Debug, PartialEq)]
pub struct CampaignPoint {
    pub index: usize,
    pub workload: String,
    pub dataflow: Dataflow,
    /// Per-node array shape (the whole array when `nodes == 1`).
    pub array_h: u64,
    pub array_w: u64,
    /// Multi-array coordinates: `nodes` replicas of the array shape,
    /// split by `partition` ([`crate::engine::multi`]).
    pub nodes: u64,
    pub partition: Partition,
    /// IFMAP and filter partition size (lockstep, Fig 7 convention).
    pub sram_kb: u64,
    /// Modeled DRAM read bandwidth in bytes/cycle (shared across nodes).
    pub dram_bw: f64,
    /// Interconnect topology for multi-array points (`Flat` = legacy
    /// equal-split contention, no fabric model).
    pub topology: FabricKind,
    /// Per-link bandwidth in bytes/cycle (only meaningful with a
    /// non-`Flat` topology).
    pub link_bw: f64,
}

impl CampaignPoint {
    /// The point's effective per-node architecture: engine base +
    /// coordinates.
    pub fn config(&self, base: &ArchConfig) -> ArchConfig {
        ArchConfig {
            array_h: self.array_h,
            array_w: self.array_w,
            dataflow: self.dataflow,
            ifmap_sram_kb: self.sram_kb,
            filter_sram_kb: self.sram_kb,
            ..base.clone()
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("index", Json::u64(self.index as u64)),
            ("workload", Json::str(self.workload.clone())),
            ("dataflow", Json::str(self.dataflow.name())),
            ("array_h", Json::u64(self.array_h)),
            ("array_w", Json::u64(self.array_w)),
            ("nodes", Json::u64(self.nodes)),
            ("partition", Json::str(self.partition.name())),
            ("sram_kb", Json::u64(self.sram_kb)),
            ("dram_bw", Json::f64(self.dram_bw)),
            ("topology", Json::str(self.topology.name())),
            ("link_bw", Json::f64(self.link_bw)),
        ])
    }

    pub fn from_json(j: &Json) -> std::result::Result<CampaignPoint, String> {
        Ok(CampaignPoint {
            index: need_u64(j, "index")? as usize,
            workload: j.str_field("workload").ok_or("missing \"workload\"")?.to_string(),
            dataflow: Dataflow::parse(
                j.str_field("dataflow").ok_or("missing \"dataflow\"")?,
            )
            .map_err(|e| e.to_string())?,
            array_h: need_u64(j, "array_h")?,
            array_w: need_u64(j, "array_w")?,
            // absent in pre-multi-array journals: single-array defaults
            nodes: match j.get("nodes") {
                None => 1,
                Some(_) => need_u64(j, "nodes")?,
            },
            partition: match j.str_field("partition") {
                None => Partition::default(),
                Some(s) => Partition::parse(s).map_err(|e| e.to_string())?,
            },
            sram_kb: need_u64(j, "sram_kb")?,
            dram_bw: need_f64(j, "dram_bw")?,
            // absent in pre-fabric journals: flat-interconnect defaults
            topology: match j.str_field("topology") {
                None => FabricKind::Flat,
                Some(s) => FabricKind::parse(s).map_err(|e| e.to_string())?,
            },
            link_bw: match j.get("link_bw") {
                None => DEFAULT_LINK_BW,
                Some(_) => need_f64(j, "link_bw")?,
            },
        })
    }
}

/// The objectives extracted at one grid point. Every field is a
/// deterministic function of the point alone, so local, sharded and
/// resumed executions produce bit-identical values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PointMetrics {
    /// Stall-free runtime (the engine's cycle-exact timing).
    pub ideal_cycles: u64,
    /// Idle cycles under the point's finite DRAM bandwidth
    /// ([`crate::memory::stall`]).
    pub stall_cycles: u64,
    /// Total energy in mJ ([`crate::energy`]).
    pub energy_mj: f64,
    /// Stall-free peak DRAM read-bandwidth requirement (bytes/cycle).
    pub peak_dram_bw: f64,
    /// Average DRAM read bandwidth over the stall-free runtime.
    pub avg_dram_bw: f64,
    /// Total DRAM traffic in bytes.
    pub dram_bytes: u64,
    /// Row-buffer hit rate of the read stream replayed through the
    /// banked DRAM substrate ([`crate::dram`]).
    pub dram_row_hit_rate: f64,
    /// Runtime-weighted array utilization.
    pub utilization: f64,
}

impl PointMetrics {
    /// Bandwidth-aware runtime: stall-free cycles plus stalls.
    pub fn total_cycles(&self) -> u64 {
        self.ideal_cycles + self.stall_cycles
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ideal_cycles", Json::u64(self.ideal_cycles)),
            ("stall_cycles", Json::u64(self.stall_cycles)),
            ("energy_mj", Json::f64(self.energy_mj)),
            ("peak_dram_bw", Json::f64(self.peak_dram_bw)),
            ("avg_dram_bw", Json::f64(self.avg_dram_bw)),
            ("dram_bytes", Json::u64(self.dram_bytes)),
            ("dram_row_hit_rate", Json::f64(self.dram_row_hit_rate)),
            ("utilization", Json::f64(self.utilization)),
        ])
    }

    pub fn from_json(j: &Json) -> std::result::Result<PointMetrics, String> {
        Ok(PointMetrics {
            ideal_cycles: need_u64(j, "ideal_cycles")?,
            stall_cycles: need_u64(j, "stall_cycles")?,
            energy_mj: need_f64(j, "energy_mj")?,
            peak_dram_bw: need_f64(j, "peak_dram_bw")?,
            avg_dram_bw: need_f64(j, "avg_dram_bw")?,
            dram_bytes: need_u64(j, "dram_bytes")?,
            dram_row_hit_rate: need_f64(j, "dram_row_hit_rate")?,
            utilization: need_f64(j, "utilization")?,
        })
    }
}

/// One journaled result: the point plus its extracted objectives.
#[derive(Clone, Debug, PartialEq)]
pub struct CompletedPoint {
    pub point: CampaignPoint,
    pub metrics: PointMetrics,
}

impl CompletedPoint {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![("point", self.point.to_json()), ("metrics", self.metrics.to_json())])
    }

    /// Parse from any object carrying `point`/`metrics` fields (journal
    /// lines and serve `dse_point` events share the shape).
    pub fn from_json(j: &Json) -> std::result::Result<CompletedPoint, String> {
        let p = j.get("point").ok_or("missing \"point\"")?;
        let m = j.get("metrics").ok_or("missing \"metrics\"")?;
        Ok(CompletedPoint {
            point: CampaignPoint::from_json(p)?,
            metrics: PointMetrics::from_json(m)?,
        })
    }
}

pub(crate) fn need_u64(j: &Json, k: &str) -> std::result::Result<u64, String> {
    j.u64_field(k).ok_or_else(|| format!("missing/invalid u64 field {k:?}"))
}

pub(crate) fn need_f64(j: &Json, k: &str) -> std::result::Result<f64, String> {
    j.f64_field(k).ok_or_else(|| format!("missing/invalid number field {k:?}"))
}

/// The banked-DRAM substrate replay is independent of the campaign's
/// bandwidth axis (the innermost one), so consecutive points differing
/// only in `dram_bw` would redo identical replays; this process-wide
/// memo absorbs that (values are deterministic, so memoization cannot
/// change results — only wall-clock).
fn substrate_replay(cfg: &ArchConfig, layer: &crate::arch::LayerShape) -> (u64, u64) {
    use std::collections::BTreeMap as Map;
    use std::sync::{Mutex, OnceLock};
    type Key = (Dataflow, u64, u64, u64, u64, u64, u64, (u64, u64, u64, u64, u64, u64, u64));
    static CACHE: OnceLock<Mutex<Map<Key, (u64, u64)>>> = OnceLock::new();
    let key = (
        cfg.dataflow,
        cfg.array_h,
        cfg.array_w,
        cfg.ifmap_sram_kb,
        cfg.filter_sram_kb,
        cfg.ofmap_sram_kb,
        cfg.word_bytes,
        (
            layer.ifmap_h,
            layer.ifmap_w,
            layer.filt_h,
            layer.filt_w,
            layer.channels,
            layer.num_filters,
            layer.stride,
        ),
    );
    let cache = CACHE.get_or_init(|| Mutex::new(Map::new()));
    let poisoned = std::sync::PoisonError::into_inner;
    if let Some(&hit) = cache.lock().unwrap_or_else(poisoned).get(&key) {
        return hit;
    }
    let s = dram::replay_layer(cfg.dataflow, layer, cfg, DramConfig::default());
    let value = (s.requests, s.row_hits);
    cache.lock().unwrap_or_else(poisoned).insert(key, value);
    value
}

/// Extract every objective at one grid point. The stall-free report
/// comes from the engine's memo cache (shared across points differing
/// only in bandwidth, and across shards on a server); the stall replay
/// is a cheap fold-level pass computed fresh, and the DRAM-substrate
/// replay is memoized per (config, layer-shape).
///
/// A multi-array point (`nodes > 1`) runs each per-node sub-shape
/// through the same memoized path and composes the system-level
/// objectives: slowest-node runtimes, shared-DRAM stalls (the point's
/// bandwidth split across busy nodes), aggregate energy/traffic, and
/// the summed interconnect bandwidth demand.
pub fn evaluate_point(engine: &Engine, topo: &Topology, point: &CampaignPoint) -> PointMetrics {
    crate::obs::metrics::count_dse_point();
    let cfg = point.config(engine.cfg());
    if point.nodes > 1 {
        return evaluate_multi_point(engine, topo, point, &cfg);
    }
    let report = engine.run_topology_with(&cfg, topo);
    let mut stall_cycles = 0u64;
    let mut dram_requests = 0u64;
    let mut dram_row_hits = 0u64;
    for layer in &topo.layers {
        stall_cycles +=
            stall::stalled_runtime(cfg.dataflow, layer, &cfg, point.dram_bw).stall_cycles;
        let (requests, row_hits) = substrate_replay(&cfg, layer);
        dram_requests += requests;
        dram_row_hits += row_hits;
    }
    PointMetrics {
        ideal_cycles: report.total_cycles(),
        stall_cycles,
        energy_mj: report.total_energy().total_mj(),
        peak_dram_bw: report.peak_dram_read_bw(),
        avg_dram_bw: report.avg_dram_read_bw(),
        dram_bytes: report.total_dram().total(),
        dram_row_hit_rate: if dram_requests == 0 {
            0.0
        } else {
            dram_row_hits as f64 / dram_requests as f64
        },
        utilization: report.overall_utilization(cfg.total_pes()),
    }
}

/// The multi-array arm of [`evaluate_point`].
fn evaluate_multi_point(
    engine: &Engine,
    topo: &Topology,
    point: &CampaignPoint,
    cfg: &ArchConfig,
) -> PointMetrics {
    let multi = MultiArrayConfig::new(point.nodes, cfg.array_h, cfg.array_w, point.partition);
    let opts = MultiOpts {
        shared_dram_bw: Some(point.dram_bw),
        fabric: (point.topology != FabricKind::Flat)
            .then(|| FabricConfig::new(point.topology, point.link_bw)),
        dram: None,
    };
    let report = engine.run_multi_opts(cfg, topo, &multi, &opts);
    // row-hit statistics: replay each distinct per-node sub-shape once
    // (memoized) and weight by how many nodes stream it
    let mut dram_requests = 0u64;
    let mut dram_row_hits = 0u64;
    for ml in &report.layers {
        let (requests, row_hits) = substrate_replay(cfg, &ml.node_report.layer);
        dram_requests += requests * ml.node_count;
        dram_row_hits += row_hits * ml.node_count;
        if let Some(r) = &ml.remainder {
            let (requests, row_hits) = substrate_replay(cfg, &r.layer);
            dram_requests += requests;
            dram_row_hits += row_hits;
        }
    }
    PointMetrics {
        ideal_cycles: report.total_cycles(),
        stall_cycles: report.total_stall_cycles(),
        energy_mj: report.total_energy().total_mj(),
        peak_dram_bw: report.peak_interconnect_bw(),
        avg_dram_bw: report.avg_interconnect_bw(),
        dram_bytes: report.total_dram().total(),
        dram_row_hit_rate: if dram_requests == 0 {
            0.0
        } else {
            dram_row_hits as f64 / dram_requests as f64
        },
        utilization: report.utilization(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn tiny() -> Campaign {
        Campaign {
            name: "t".into(),
            workloads: vec!["ncf".into()],
            dataflows: vec![Dataflow::Os, Dataflow::Ws],
            arrays: vec![(16, 16), (32, 32)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            sram_kb: vec![64],
            dram_bw: vec![4.0, 16.0],
            topologies: vec![FabricKind::Flat],
            link_bw: vec![DEFAULT_LINK_BW],
            energy: "28nm".into(),
        }
    }

    #[test]
    fn enumeration_is_nested_with_bandwidth_innermost() {
        let c = tiny();
        assert_eq!(c.len(), 8);
        let p0 = c.point(0);
        let p1 = c.point(1);
        // consecutive indices differ only in bandwidth => shared config
        assert_eq!((p0.dataflow, p0.array_h, p0.sram_kb), (Dataflow::Os, 16, 64));
        assert_eq!(p0.config(&config::paper_default()), p1.config(&config::paper_default()));
        assert_eq!((p0.dram_bw, p1.dram_bw), (4.0, 16.0));
        // array advances next, dataflow after that
        assert_eq!(c.point(2).array_h, 32);
        assert_eq!(c.point(4).dataflow, Dataflow::Ws);
        assert_eq!(c.points().len(), 8);
        for (i, p) in c.points().iter().enumerate() {
            assert_eq!(p.index, i);
        }
    }

    #[test]
    fn campaign_json_round_trips_with_stable_fingerprint() {
        let c = tiny();
        let wire = c.to_json().to_string();
        let back = Campaign::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.fingerprint(), c.fingerprint());
        // a changed axis changes the fingerprint
        let mut other = c.clone();
        other.dram_bw = vec![4.0];
        assert_ne!(other.fingerprint(), c.fingerprint());
    }

    #[test]
    fn from_json_defaults_missing_axes() {
        let j = Json::parse(r#"{"workloads":["ncf"]}"#).unwrap();
        let c = Campaign::from_json(&j).unwrap();
        assert_eq!(c.dataflows, Dataflow::ALL.to_vec());
        assert_eq!(c.arrays, vec![(128, 128)]);
        assert_eq!(c.sram_kb, vec![512]);
        assert_eq!(c.dram_bw, vec![64.0]);
        assert_eq!(c.energy, "28nm");
        c.validate().unwrap();
        assert!(Campaign::from_json(&Json::parse(r#"{"name":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn validate_rejects_bad_axes() {
        let mut c = tiny();
        c.dram_bw = vec![0.0];
        assert!(c.validate().is_err());
        let mut c = tiny();
        c.arrays = vec![(0, 8)];
        assert!(c.validate().is_err());
        let mut c = tiny();
        c.energy = "3nm".into();
        assert!(c.validate().is_err());
        let mut c = tiny();
        c.workloads.clear();
        assert!(c.validate().is_err());
    }

    #[test]
    fn point_and_metrics_json_round_trip_exactly() {
        let c = tiny();
        let topos = c.resolve_workloads(true).unwrap();
        let engine = Engine::new(config::paper_default());
        let p = c.point(3);
        let m = evaluate_point(&engine, &topos["ncf"], &p);
        let cp = CompletedPoint { point: p, metrics: m };
        let wire = cp.to_json().to_string();
        let back = CompletedPoint::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, cp, "journal round trip must be bit-identical");
    }

    #[test]
    fn evaluate_is_deterministic_and_consistent_with_the_engine() {
        let c = tiny();
        let topos = c.resolve_workloads(false).unwrap();
        let engine = Engine::new(config::paper_default());
        let p = c.point(0);
        let a = evaluate_point(&engine, &topos["ncf"], &p);
        let b = evaluate_point(&engine, &topos["ncf"], &p);
        assert_eq!(a, b);
        let report = engine.run_topology_with(&p.config(engine.cfg()), &topos["ncf"]);
        assert_eq!(a.ideal_cycles, report.total_cycles());
        assert_eq!(a.total_cycles(), a.ideal_cycles + a.stall_cycles);
        assert!(a.energy_mj > 0.0 && a.peak_dram_bw > 0.0);
        // 4 B/cycle starves a 16x16 array; the wider-bandwidth twin
        // stalls no more than the narrow one
        let wide = evaluate_point(&engine, &topos["ncf"], &c.point(1));
        assert!(wide.stall_cycles <= a.stall_cycles);
        assert_eq!(wide.ideal_cycles, a.ideal_cycles, "bandwidth only moves stalls");
    }

    #[test]
    fn builtin_only_resolution_rejects_paths() {
        let mut c = tiny();
        c.workloads = vec!["topologies/ncf.csv".into()];
        assert!(c.resolve_workloads(true).is_err());
    }

    #[test]
    fn paper_scaleout_campaign_validates_and_spans_the_pe_sweep() {
        // the `dse run --scaleout` preset: 8x8 nodes over the paper's
        // PE budgets (64..16384) under every partition strategy
        let c = Campaign::paper_scaleout();
        c.validate().unwrap();
        assert_eq!(c.len(), 2 * 5 * 3 * 2);
        assert_eq!(c.nodes, vec![1, 4, 16, 64, 256]);
        let last = c.point(c.len() - 1);
        assert_eq!((last.nodes, last.partition), (256, Partition::Auto));
        assert_eq!(
            (last.array_h, last.array_w),
            (crate::engine::multi::NODE_DIM, crate::engine::multi::NODE_DIM)
        );
        // the multi axes are explicit in its canonical form
        let wire = c.to_json().to_string();
        assert!(wire.contains("\"nodes\"") && wire.contains("\"partitions\""), "{wire}");
    }

    #[test]
    fn single_array_fingerprints_match_pre_multi_journals() {
        // a journal header written before the nodes/partitions axes
        // existed must still resume: the canonical form (and so the
        // fingerprint) of a single-array campaign is unchanged
        let c = tiny();
        let legacy_wire = r#"{"name":"t","workloads":["ncf"],"dataflows":["os","ws"],"arrays":["16x16","32x32"],"sram_kb":[64],"dram_bw":[4,16],"energy":"28nm"}"#;
        let legacy = Campaign::from_json(&Json::parse(legacy_wire).unwrap()).unwrap();
        assert_eq!(legacy, c);
        assert_eq!(
            c.to_json().to_string(),
            legacy_wire,
            "canonical form must omit the default multi-array axes"
        );
        assert_eq!(legacy.fingerprint(), c.fingerprint());
    }

    fn tiny_multi() -> Campaign {
        Campaign {
            name: "tm".into(),
            workloads: vec!["ncf".into()],
            dataflows: vec![Dataflow::Os],
            arrays: vec![(8, 8)],
            nodes: vec![1, 4],
            partitions: vec![Partition::OutputChannels, Partition::Auto],
            sram_kb: vec![64],
            dram_bw: vec![4.0, 16.0],
            topologies: vec![FabricKind::Flat],
            link_bw: vec![DEFAULT_LINK_BW],
            energy: "28nm".into(),
        }
    }

    #[test]
    fn multi_axes_enumerate_between_array_and_sram() {
        let c = tiny_multi();
        assert_eq!(c.len(), 8);
        c.validate().unwrap();
        // bandwidth innermost, then partition, then nodes
        assert_eq!((c.point(0).nodes, c.point(0).partition), (1, Partition::OutputChannels));
        assert_eq!(c.point(1).dram_bw, 16.0);
        assert_eq!(c.point(2).partition, Partition::Auto);
        assert_eq!(c.point(4).nodes, 4);
        // round trip keeps the new axes and shifts the fingerprint
        let back = Campaign::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        let mut single = c.clone();
        single.nodes = vec![1];
        assert_ne!(single.fingerprint(), c.fingerprint());
        // zero node counts are rejected
        let mut bad = c;
        bad.nodes = vec![0];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn multi_points_round_trip_and_default_on_legacy_journals() {
        let c = tiny_multi();
        let topos = c.resolve_workloads(true).unwrap();
        let engine = Engine::new(config::paper_default());
        let p = c.point(6); // 4 nodes, auto partition
        assert_eq!((p.nodes, p.partition), (4, Partition::Auto));
        let cp = CompletedPoint { metrics: evaluate_point(&engine, &topos["ncf"], &p), point: p };
        let back =
            CompletedPoint::from_json(&Json::parse(&cp.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, cp, "multi-array journal round trip must be bit-identical");
        // a pre-multi-array journal line (no nodes/partition) still parses
        let legacy = Json::parse(
            r#"{"index":0,"workload":"ncf","dataflow":"os","array_h":8,"array_w":8,"sram_kb":64,"dram_bw":4}"#,
        )
        .unwrap();
        let lp = CampaignPoint::from_json(&legacy).unwrap();
        assert_eq!((lp.nodes, lp.partition), (1, Partition::OutputChannels));
    }

    #[test]
    fn fabric_axes_enumerate_innermost_and_round_trip() {
        let mut c = tiny_multi();
        c.topologies = vec![FabricKind::Flat, FabricKind::Line];
        c.link_bw = vec![DEFAULT_LINK_BW, 4.0];
        c.validate().unwrap();
        assert_eq!(c.len(), 8 * 4);
        // link_bw is the innermost axis, topology next
        assert_eq!((c.point(0).topology, c.point(0).link_bw), (FabricKind::Flat, DEFAULT_LINK_BW));
        assert_eq!(c.point(1).link_bw, 4.0);
        assert_eq!(c.point(2).topology, FabricKind::Line);
        assert_eq!(c.point(4).dram_bw, 16.0, "dram_bw advances after the fabric axes");
        // canonical form keeps the axes; defaults are omitted
        let back = Campaign::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
        assert_ne!(c.fingerprint(), tiny_multi().fingerprint());
        let flat_wire = tiny_multi().to_json().to_string();
        assert!(
            !flat_wire.contains("topologies") && !flat_wire.contains("link_bw"),
            "{flat_wire}"
        );
        // zero / non-finite link bandwidths are rejected at validation
        let mut bad = c.clone();
        bad.link_bw = vec![0.0];
        assert!(bad.validate().is_err());
        bad.link_bw = vec![f64::INFINITY];
        assert!(bad.validate().is_err());
        // a fabric point evaluates deterministically and journals exactly
        let topos = c.resolve_workloads(true).unwrap();
        let engine = Engine::new(config::paper_default());
        let p = c.point(c.len() - 2); // 4 nodes, auto, line fabric
        assert_eq!((p.nodes, p.topology), (4, FabricKind::Line));
        let m = evaluate_point(&engine, &topos["ncf"], &p);
        assert_eq!(m, evaluate_point(&engine, &topos["ncf"], &p));
        let cp = CompletedPoint { point: p, metrics: m };
        let rt = CompletedPoint::from_json(&Json::parse(&cp.to_json().to_string()).unwrap());
        assert_eq!(rt.unwrap(), cp);
        // a pre-fabric journal line still parses with flat defaults
        let legacy = Json::parse(
            r#"{"index":0,"workload":"ncf","dataflow":"os","array_h":8,"array_w":8,"sram_kb":64,"dram_bw":4}"#,
        )
        .unwrap();
        let lp = CampaignPoint::from_json(&legacy).unwrap();
        assert_eq!((lp.topology, lp.link_bw), (FabricKind::Flat, DEFAULT_LINK_BW));
    }

    #[test]
    fn multi_point_metrics_compose_the_scaleout_system() {
        let c = tiny_multi();
        let topos = c.resolve_workloads(true).unwrap();
        let engine = Engine::new(config::paper_default());
        let single = evaluate_point(&engine, &topos["ncf"], &c.point(0));
        let multi = evaluate_point(&engine, &topos["ncf"], &c.point(4)); // 4 nodes, channels
        assert_eq!(multi, evaluate_point(&engine, &topos["ncf"], &c.point(4)), "deterministic");
        // partitioned nodes run in parallel: never slower than one node
        assert!(multi.ideal_cycles <= single.ideal_cycles);
        // the report view agrees with the metrics
        let mc = MultiArrayConfig::new(4, 8, 8, Partition::OutputChannels);
        let report = engine.run_multi_with(
            &c.point(4).config(engine.cfg()),
            &topos["ncf"],
            &mc,
            Some(c.point(4).dram_bw),
        );
        assert_eq!(multi.ideal_cycles, report.total_cycles());
        assert_eq!(multi.stall_cycles, report.total_stall_cycles());
        assert_eq!(multi.dram_bytes, report.total_dram().total());
        assert!(multi.energy_mj > 0.0 && multi.peak_dram_bw > 0.0);
        assert!(multi.utilization > 0.0 && multi.utilization <= 1.0);
    }
}
