//! The campaign checkpoint/resume journal.
//!
//! Layout under a state directory: `campaign.jsonl`, one JSON line per
//! record. The first line is the header,
//!
//! ```text
//! {"campaign":{...canonical spec...},"fingerprint":"9f2c..."}
//! ```
//!
//! and every subsequent line is one completed point,
//!
//! ```text
//! {"point":{"index":17,...},"metrics":{...}}
//! ```
//!
//! appended (one `O_APPEND` write per line) as soon as the point
//! finishes, so a killed campaign loses at most the points that were
//! still in flight. On
//! resume the header's fingerprint must match the spec it carries
//! (refusing a journal whose spec was edited), completed lines are
//! restored — numbers round-trip exactly ([`crate::util::json`]), so
//! restored metrics are bit-identical to freshly computed ones — and
//! only the missing indices re-simulate. A truncated trailing line
//! (the kill arrived mid-write) is skipped, costing one re-simulation,
//! never a failed resume.

use std::collections::BTreeSet;
use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use crate::{Error, Result};

use super::{Campaign, CompletedPoint};

/// Journal file name inside a campaign state directory.
pub const JOURNAL_FILE: &str = "campaign.jsonl";

/// Append-only campaign journal (thread-safe: workers append completed
/// points concurrently; order on disk is completion order, identity is
/// the point index). Appends go through a fresh `O_APPEND` handle per
/// line rather than a shared locked file, so no lock guard is ever held
/// across I/O (R2) and concurrent appenders serialize in the kernel.
pub struct Journal {
    path: PathBuf,
}

impl Journal {
    /// Start a fresh journal under `dir` (creating the directory).
    /// Refuses to overwrite an existing journal — `dse resume` continues
    /// one, deleting the file starts over.
    pub fn create(dir: &Path, campaign: &Campaign) -> Result<Journal> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(JOURNAL_FILE);
        if path.exists() {
            return Err(Error::Dse(format!(
                "{} already holds a campaign journal; continue it with `scale-sim dse \
                 resume --state-dir {}` or remove the file to start over",
                path.display(),
                dir.display()
            )));
        }
        let mut file = OpenOptions::new().create_new(true).append(true).open(&path)?;
        let header = Json::obj(vec![
            ("campaign", campaign.to_json()),
            ("fingerprint", Json::str(campaign.fingerprint())),
        ]);
        let mut line = header.to_string();
        line.push('\n');
        file.write_all(line.as_bytes())?;
        Ok(Journal { path })
    }

    /// Open an existing journal: returns the journal (in append mode),
    /// the campaign its header carries, and every restorable completed
    /// point (deduplicated by index; lines that fail to parse or do not
    /// match the campaign's enumeration are skipped — they cost a
    /// re-simulation, not a failure).
    pub fn resume(dir: &Path) -> Result<(Journal, Campaign, Vec<CompletedPoint>)> {
        let path = dir.join(JOURNAL_FILE);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(Error::Dse(format!(
                    "no campaign journal under {} — start one with `scale-sim dse run \
                     --state-dir {}`",
                    dir.display(),
                    dir.display()
                )))
            }
            Err(e) => return Err(e.into()),
        };
        let mut lines = text.lines();
        let header = lines
            .next()
            .ok_or_else(|| Error::Dse(format!("{}: empty journal", path.display())))?;
        let hj = Json::parse(header)
            .map_err(|e| Error::Dse(format!("{}: bad journal header: {e}", path.display())))?;
        let campaign = Campaign::from_json(
            hj.get("campaign")
                .ok_or_else(|| Error::Dse(format!("{}: header lacks \"campaign\"", path.display())))?,
        )
        .map_err(|e| Error::Dse(format!("{}: bad campaign spec: {e}", path.display())))?;
        if hj.str_field("fingerprint") != Some(campaign.fingerprint().as_str()) {
            return Err(Error::Dse(format!(
                "{}: fingerprint mismatch — the journal belongs to a different campaign",
                path.display()
            )));
        }
        campaign.validate()?;

        let total = campaign.len();
        let mut done = Vec::new();
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        for line in lines {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Ok(j) = Json::parse(line) else {
                continue; // truncated tail from a kill: re-simulate that point
            };
            let Ok(cp) = CompletedPoint::from_json(&j) else {
                continue;
            };
            // the entry must be the campaign's own enumeration of its index
            if cp.point.index >= total || campaign.point(cp.point.index) != cp.point {
                continue;
            }
            if seen.insert(cp.point.index) {
                done.push(cp);
            }
        }
        // probe appendability now so a read-only journal fails at resume
        // time with a clear error, not on the first completed point
        drop(OpenOptions::new().append(true).open(&path)?);
        Ok((Journal { path }, campaign, done))
    }

    /// Append one completed point: one line, one `write_all` on a fresh
    /// `O_APPEND` handle. The kernel serializes same-file appends, so
    /// concurrent workers interleave whole lines without any lock; a
    /// worker killed mid-write at worst leaves a truncated tail, which
    /// resume already skips.
    pub fn append(&self, cp: &CompletedPoint) -> Result<()> {
        let mut line = cp.to_json().to_string();
        line.push('\n');
        let mut f = OpenOptions::new().append(true).open(&self.path)?;
        f.write_all(line.as_bytes())?;
        Ok(())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::dse::evaluate_point;
    use crate::engine::{Engine, Partition};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("scale_sim_dse_journal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn campaign() -> Campaign {
        Campaign {
            name: "j".into(),
            workloads: vec!["ncf".into()],
            dataflows: vec![crate::Dataflow::Os],
            arrays: vec![(16, 16)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            sram_kb: vec![64],
            dram_bw: vec![4.0, 16.0],
            topologies: vec![crate::engine::FabricKind::Flat],
            link_bw: vec![crate::engine::DEFAULT_LINK_BW],
            energy: "28nm".into(),
        }
    }

    fn completed(c: &Campaign, idx: usize) -> CompletedPoint {
        let topos = c.resolve_workloads(true).unwrap();
        let engine = Engine::new(config::paper_default());
        let point = c.point(idx);
        let metrics = evaluate_point(&engine, &topos["ncf"], &point);
        CompletedPoint { point, metrics }
    }

    #[test]
    fn create_append_resume_round_trips() {
        let dir = tmp_dir("roundtrip");
        let c = campaign();
        let j = Journal::create(&dir, &c).unwrap();
        let cp = completed(&c, 1);
        j.append(&cp).unwrap();
        drop(j);

        let (j2, back, done) = Journal::resume(&dir).unwrap();
        assert_eq!(back, c);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0], cp, "restored point must be bit-identical");
        // the reopened journal still appends
        j2.append(&completed(&c, 0)).unwrap();
        drop(j2);
        let (_, _, done) = Journal::resume(&dir).unwrap();
        assert_eq!(done.len(), 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_refuses_an_existing_journal() {
        let dir = tmp_dir("refuse");
        let c = campaign();
        Journal::create(&dir, &c).unwrap();
        let err = Journal::create(&dir, &c).unwrap_err();
        assert!(err.to_string().contains("resume"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_skips_truncated_tail_and_duplicates() {
        let dir = tmp_dir("truncated");
        let c = campaign();
        let j = Journal::create(&dir, &c).unwrap();
        let cp = completed(&c, 0);
        j.append(&cp).unwrap();
        j.append(&cp).unwrap(); // duplicate index: restored once
        drop(j);
        // simulate a kill mid-write: a partial trailing line
        let mut text = std::fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        text.push_str("{\"point\":{\"index\":1,\"work");
        std::fs::write(dir.join(JOURNAL_FILE), text).unwrap();

        let (_, _, done) = Journal::resume(&dir).unwrap();
        assert_eq!(done.len(), 1, "duplicate deduped, truncated tail skipped");
        assert_eq!(done[0].point.index, 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_rejects_missing_dir_and_edited_header() {
        let missing = tmp_dir("missing");
        assert!(Journal::resume(&missing).is_err());

        let dir = tmp_dir("edited");
        let c = campaign();
        Journal::create(&dir, &c).unwrap();
        // edit the spec inside the header without updating the fingerprint
        let text = std::fs::read_to_string(dir.join(JOURNAL_FILE)).unwrap();
        let edited = text.replace("\"ncf\"", "\"resnet50\"");
        assert_ne!(edited, text);
        std::fs::write(dir.join(JOURNAL_FILE), edited).unwrap();
        let err = Journal::resume(&dir).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resume_drops_entries_from_a_different_enumeration() {
        let dir = tmp_dir("foreign");
        let c = campaign();
        let j = Journal::create(&dir, &c).unwrap();
        // a forged entry whose coordinates disagree with point(0)
        let mut forged = completed(&c, 0);
        forged.point.array_h = 99;
        j.append(&forged).unwrap();
        j.append(&completed(&c, 1)).unwrap();
        drop(j);
        let (_, _, done) = Journal::resume(&dir).unwrap();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].point.index, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
