//! Campaign execution — local pool or sharded over `scale-sim serve` —
//! plus frontier assembly and the `BENCH_dse.json` writer.
//!
//! Both executors evaluate the same [`super::evaluate_point`] function
//! over the same enumeration, journal every completed point as it
//! finishes, and compute the final frontier from the full (restored +
//! fresh) point set, so local, sharded, interrupted and resumed
//! campaigns all converge to **bit-identical** frontiers:
//!
//! * **Local** — [`crate::sweep::parallel_map`] over one memoizing
//!   engine; with a state dir the engine additionally warm-starts from
//!   (and flushes to) a [`crate::server::store::ResultStore`], so a
//!   resumed campaign re-enters with the killed run's cache warmth.
//! * **Serve** — the pending indices split round-robin into shards,
//!   each submitted as a `{"req":"dse"}` job to a running server
//!   ([`crate::server::proto`]); every shard streams its points back
//!   while the server's ONE process-wide memo cache de-duplicates
//!   layer simulations *across* shards.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use crate::engine::{BackendKind, Engine, MemoStats, SweepStats};
use crate::server::store::ResultStore;
use crate::sweep::parallel_map;
use crate::util::bench::write_json;
use crate::util::json::Json;
use crate::{Error, Result};

use super::journal::Journal;
use super::pareto::pareto_front;
use super::{evaluate_point, Campaign, CampaignPoint, CompletedPoint};

/// Marker file recording the energy preset the state dir's result store
/// was priced under (absent = the default "28nm").
const ENERGY_MARKER: &str = "energy.preset";

/// How a campaign's pending points execute.
#[derive(Clone, Debug)]
pub enum Exec {
    /// In-process worker pool over one memoizing engine.
    Local { threads: usize },
    /// Round-robin shards submitted to a running `scale-sim serve`.
    Serve { addr: String, shards: usize },
}

/// Execution options shared by `run` and `resume`.
#[derive(Clone, Debug)]
pub struct RunOpts {
    pub exec: Exec,
    /// Journal (and result-store) directory; `None` runs in memory.
    pub state_dir: Option<PathBuf>,
    /// Stop after this many evaluated points (the campaign stays
    /// incomplete and resumable) — the deterministic stand-in for a
    /// mid-campaign kill in tests and CI.
    pub max_points: Option<usize>,
    /// Fidelity backend for local execution (cycle-exact with every
    /// other backend, so the frontier is backend-independent).
    pub backend: BackendKind,
}

impl Default for RunOpts {
    fn default() -> Self {
        RunOpts {
            exec: Exec::Local { threads: crate::sweep::default_threads() },
            state_dir: None,
            max_points: None,
            backend: BackendKind::Analytical,
        }
    }
}

/// Result of one campaign invocation.
#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    pub campaign: Campaign,
    /// Every known completed point, sorted by index (restored + fresh).
    pub completed: Vec<CompletedPoint>,
    /// Points evaluated by this invocation.
    pub ran: usize,
    /// Points restored from the journal.
    pub restored: usize,
    /// Execution statistics for this invocation only (`points == ran`;
    /// memo counters are zero for serve execution — the cache lives in
    /// the server process, visible via `scale-sim client stats`).
    pub stats: SweepStats,
    /// Positions into `completed` of the runtime-vs-energy frontier.
    pub frontier_runtime_energy: Vec<usize>,
    /// Positions into `completed` of the runtime-vs-peak-DRAM-bandwidth
    /// frontier.
    pub frontier_runtime_bw: Vec<usize>,
}

impl CampaignOutcome {
    /// True when every grid point has been evaluated.
    pub fn is_complete(&self) -> bool {
        self.completed.len() == self.campaign.len()
    }

    /// Write the `BENCH_dse.json` artifact: campaign coverage, frontier
    /// sizes, and the shared sweep-stat fields (wall clock, memoization
    /// counters, cache hit rate — the hit rate of *this* invocation,
    /// which for a resumed campaign is the resumed half alone).
    pub fn write_bench_json(&self, path: &Path) -> std::io::Result<()> {
        let mut fields: Vec<(&str, f64)> = vec![
            ("points_total", self.campaign.len() as f64),
            ("points_run", self.ran as f64),
            ("points_restored", self.restored as f64),
            ("frontier_runtime_energy", self.frontier_runtime_energy.len() as f64),
            ("frontier_runtime_bw", self.frontier_runtime_bw.len() as f64),
        ];
        for f in self.stats.bench_fields() {
            // "points" would duplicate points_run under an ambiguous name
            if f.0 != "points" {
                fields.push(f);
            }
        }
        write_json(path, &fields)
    }
}

/// The two campaign frontiers over a completed-point set: positions of
/// the non-dominated points under (total cycles, energy) and
/// (total cycles, stall-free peak DRAM bandwidth), both minimized.
pub fn frontiers(completed: &[CompletedPoint]) -> (Vec<usize>, Vec<usize>) {
    let runtime_energy: Vec<(f64, f64)> = completed
        .iter()
        .map(|c| (c.metrics.total_cycles() as f64, c.metrics.energy_mj))
        .collect();
    let runtime_bw: Vec<(f64, f64)> = completed
        .iter()
        .map(|c| (c.metrics.total_cycles() as f64, c.metrics.peak_dram_bw))
        .collect();
    (pareto_front(&runtime_energy), pareto_front(&runtime_bw))
}

/// Start a campaign from scratch. With a state dir a fresh journal is
/// created (an existing one is refused — use [`resume_campaign`]).
pub fn run_campaign(campaign: Campaign, opts: &RunOpts) -> Result<CampaignOutcome> {
    campaign.validate()?;
    let journal = match &opts.state_dir {
        Some(dir) => Some(Journal::create(dir, &campaign)?),
        None => None,
    };
    let store_dir = opts.state_dir.clone();
    execute(campaign, journal, Vec::new(), opts, store_dir)
}

/// Continue a journaled campaign: restore completed points, evaluate
/// only the missing ones. The journal's directory doubles as the
/// result-store directory (cache warmth), regardless of
/// `opts.state_dir`.
pub fn resume_campaign(state_dir: &Path, opts: &RunOpts) -> Result<CampaignOutcome> {
    let (journal, campaign, done) = Journal::resume(state_dir)?;
    execute(campaign, Some(journal), done, opts, Some(state_dir.to_path_buf()))
}

/// Read a journal without simulating anything — the `dse report` path.
pub fn report_campaign(state_dir: &Path) -> Result<CampaignOutcome> {
    let (_, campaign, done) = Journal::resume(state_dir)?;
    Ok(assemble(campaign, done, 0, SweepStats {
        points: 0,
        wall: std::time::Duration::ZERO,
        memo: MemoStats::default(),
    }))
}

fn execute(
    campaign: Campaign,
    journal: Option<Journal>,
    done: Vec<CompletedPoint>,
    opts: &RunOpts,
    store_dir: Option<PathBuf>,
) -> Result<CampaignOutcome> {
    let done_idx: BTreeSet<usize> = done.iter().map(|c| c.point.index).collect();
    let mut pending: Vec<CampaignPoint> = (0..campaign.len())
        .filter(|i| !done_idx.contains(i))
        .map(|i| campaign.point(i))
        .collect();
    if let Some(cap) = opts.max_points {
        pending.truncate(cap);
    }

    let t0 = Instant::now();
    let (fresh, memo) = match &opts.exec {
        Exec::Local { threads } => {
            let topos = campaign.resolve_workloads(false)?;
            let engine = Engine::builder()
                .backend(opts.backend)
                .energy_model(campaign.energy_model()?)
                .build()?;
            // Warm-start from the state dir's result store, but ONLY
            // when it was written under this campaign's energy preset:
            // cached reports embed energy numbers and the model is not
            // part of the cache key, so a foreign store (different
            // preset, or a serve dir priced at the default) would
            // silently corrupt the energy frontier. A marker file
            // records the pricing model; absent means the default
            // ("28nm" — what `scale-sim serve` always prices at).
            let store = match &store_dir {
                Some(dir) => {
                    let s = ResultStore::open(dir)?;
                    let priced_at = std::fs::read_to_string(dir.join(ENERGY_MARKER))
                        .map(|t| t.trim().to_string())
                        .unwrap_or_else(|_| "28nm".to_string());
                    if priced_at == campaign.energy {
                        s.load_into(&engine)?;
                    }
                    Some(s)
                }
                None => None,
            };
            let before = engine.cache_stats();
            let journal = journal.as_ref();
            let fresh: Vec<CompletedPoint> =
                parallel_map(&pending, (*threads).max(1), |p| {
                    let cp = CompletedPoint {
                        point: p.clone(),
                        metrics: evaluate_point(&engine, &topos[&p.workload], p),
                    };
                    if let Some(j) = journal {
                        if let Err(e) = j.append(&cp) {
                            eprintln!("dse: journal append failed: {e}");
                        }
                    }
                    cp
                });
            let memo = engine.cache_stats().since(&before);
            if let Some(s) = &store {
                // persist cache warmth so a resumed campaign re-enters warm
                let _ = s.flush_from(&engine);
                if let Some(dir) = &store_dir {
                    let _ = std::fs::write(dir.join(ENERGY_MARKER), &campaign.energy);
                }
            }
            (fresh, memo)
        }
        Exec::Serve { addr, shards } => {
            let fresh = serve_exec(&campaign, &pending, addr, *shards, journal.as_ref())?;
            (fresh, MemoStats::default())
        }
    };
    let stats = SweepStats { points: fresh.len(), wall: t0.elapsed(), memo };

    let ran = fresh.len();
    let mut completed = done;
    completed.extend(fresh);
    Ok(assemble(campaign, completed, ran, stats))
}

fn assemble(
    campaign: Campaign,
    mut completed: Vec<CompletedPoint>,
    ran: usize,
    stats: SweepStats,
) -> CampaignOutcome {
    completed.sort_by_key(|c| c.point.index);
    let restored = completed.len() - ran;
    let (frontier_runtime_energy, frontier_runtime_bw) = frontiers(&completed);
    CampaignOutcome {
        campaign,
        completed,
        ran,
        restored,
        stats,
        frontier_runtime_energy,
        frontier_runtime_bw,
    }
}

/// Submit the pending points to a running server as round-robin shards,
/// one connection per shard, and collect the streamed results.
fn serve_exec(
    campaign: &Campaign,
    pending: &[CampaignPoint],
    addr: &str,
    shards: usize,
    journal: Option<&Journal>,
) -> Result<Vec<CompletedPoint>> {
    if pending.is_empty() {
        return Ok(Vec::new());
    }
    let shards = shards.clamp(1, pending.len());
    let spec = campaign.to_json();
    let mut parts: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for (i, p) in pending.iter().enumerate() {
        parts[i % shards].push(p.index);
    }

    let results: Mutex<Vec<CompletedPoint>> = Mutex::new(Vec::with_capacity(pending.len()));
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for (si, indices) in parts.iter().enumerate() {
            let (spec, results, errors) = (&spec, &results, &errors);
            s.spawn(move || {
                let outcome = run_shard(spec, si, indices, addr, journal);
                match outcome {
                    Ok(mut v) => results
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .append(&mut v),
                    Err(e) => errors
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner)
                        .push(format!("shard {si}: {e}")),
                }
            });
        }
    });
    let errors = errors.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    if !errors.is_empty() {
        let hint = if journal.is_some() {
            "; completed points are journaled — `dse resume` picks up from them"
        } else {
            "; no --state-dir, so completed points were not preserved"
        };
        return Err(Error::Dse(format!(
            "dse-over-serve failed ({}){hint}",
            errors.join("; ")
        )));
    }
    Ok(results.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner))
}

/// Submissions a shard makes before giving up on a server that answers
/// `busy` every time.
const BUSY_RETRIES: usize = 32;

fn run_shard(
    spec: &Json,
    shard: usize,
    indices: &[usize],
    addr: &str,
    journal: Option<&Journal>,
) -> std::result::Result<Vec<CompletedPoint>, String> {
    let mut client =
        crate::server::Client::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    let req = Json::obj(vec![
        ("req", Json::str("dse")),
        ("id", Json::u64(shard as u64)),
        ("campaign", spec.clone()),
        ("indices", Json::Arr(indices.iter().map(|&i| Json::u64(i as u64)).collect())),
    ])
    .to_string();
    // a full queue sheds the job with a `busy` event (nothing admitted,
    // no partial stream) — back off and resubmit on the same connection
    let mut backoff = std::time::Duration::from_millis(20);
    'attempts: for _ in 0..BUSY_RETRIES {
        client.send(&req).map_err(|e| e.to_string())?;
        let mut out = Vec::with_capacity(indices.len());
        loop {
            let ev = client.recv().map_err(|e| e.to_string())?;
            match ev.str_field("event") {
                Some("dse_point") => {
                    let cp = CompletedPoint::from_json(&ev)?;
                    if let Some(j) = journal {
                        if let Err(e) = j.append(&cp) {
                            eprintln!("dse: journal append failed: {e}");
                        }
                    }
                    out.push(cp);
                }
                Some("done") => return Ok(out),
                Some("busy") => {
                    std::thread::sleep(backoff);
                    backoff = (backoff * 2).min(std::time::Duration::from_millis(500));
                    continue 'attempts;
                }
                Some("error") => {
                    return Err(ev.str_field("error").unwrap_or("server error").to_string())
                }
                _ => return Err(format!("unexpected server event: {ev}")),
            }
        }
    }
    Err(format!("server stayed busy through {BUSY_RETRIES} submissions"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Partition;
    use crate::Dataflow;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("scale_sim_dse_exec_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn tiny() -> Campaign {
        Campaign {
            name: "t".into(),
            workloads: vec!["ncf".into()],
            dataflows: vec![Dataflow::Os, Dataflow::Ws],
            arrays: vec![(16, 16), (32, 32)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            sram_kb: vec![64],
            dram_bw: vec![4.0, 16.0],
            topologies: vec![crate::engine::FabricKind::Flat],
            link_bw: vec![crate::engine::DEFAULT_LINK_BW],
            energy: "28nm".into(),
        }
    }

    fn local(threads: usize) -> RunOpts {
        RunOpts { exec: Exec::Local { threads }, ..RunOpts::default() }
    }

    #[test]
    fn in_memory_run_completes_with_nonempty_frontiers() {
        let out = run_campaign(tiny(), &local(2)).unwrap();
        assert!(out.is_complete());
        assert_eq!(out.completed.len(), 8);
        assert_eq!((out.ran, out.restored), (8, 0));
        assert!(!out.frontier_runtime_energy.is_empty());
        assert!(!out.frontier_runtime_bw.is_empty());
        // completed is index-sorted, so frontier positions == indices
        for w in out.completed.windows(2) {
            assert!(w[0].point.index < w[1].point.index);
        }
        // ncf repeats a layer shape and the bandwidth axis shares configs:
        // the memoizing engine must see hits
        assert!(out.stats.memo.cache_hits > 0);
    }

    #[test]
    fn interrupted_then_resumed_matches_uninterrupted_bit_for_bit() {
        let full_dir = tmp_dir("full");
        let cut_dir = tmp_dir("cut");

        let full = run_campaign(
            tiny(),
            &RunOpts { state_dir: Some(full_dir.clone()), ..local(2) },
        )
        .unwrap();
        assert!(full.is_complete());

        // "kill" after 3 points, then resume
        let cut = run_campaign(
            tiny(),
            &RunOpts {
                state_dir: Some(cut_dir.clone()),
                max_points: Some(3),
                ..local(2)
            },
        )
        .unwrap();
        assert!(!cut.is_complete());
        assert_eq!(cut.ran, 3);

        let resumed = resume_campaign(&cut_dir, &local(2)).unwrap();
        assert!(resumed.is_complete());
        assert_eq!((resumed.ran, resumed.restored), (5, 3));
        assert_eq!(resumed.completed, full.completed, "point metrics must be bit-identical");
        assert_eq!(resumed.frontier_runtime_energy, full.frontier_runtime_energy);
        assert_eq!(resumed.frontier_runtime_bw, full.frontier_runtime_bw);

        // report reads the same frontier without simulating
        let report = report_campaign(&cut_dir).unwrap();
        assert_eq!(report.completed, full.completed);
        assert_eq!((report.ran, report.restored), (0, 8));

        std::fs::remove_dir_all(&full_dir).unwrap();
        std::fs::remove_dir_all(&cut_dir).unwrap();
    }

    #[test]
    fn run_refuses_to_restart_a_journaled_campaign() {
        let dir = tmp_dir("refuse");
        let opts = RunOpts { state_dir: Some(dir.clone()), ..local(1) };
        run_campaign(tiny(), &opts).unwrap();
        assert!(run_campaign(tiny(), &opts).is_err(), "run must not clobber a journal");
        // but resume on a complete campaign is a no-op that still reports
        let resumed = resume_campaign(&dir, &local(1)).unwrap();
        assert_eq!(resumed.ran, 0);
        assert!(resumed.is_complete());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bench_json_carries_coverage_and_frontier_sizes() {
        let dir = tmp_dir("bench");
        let out = run_campaign(tiny(), &local(1)).unwrap();
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_dse.json");
        out.write_bench_json(&path).unwrap();
        let j = Json::parse(std::fs::read_to_string(&path).unwrap().trim()).unwrap();
        assert_eq!(j.u64_field("points_total"), Some(8));
        assert_eq!(j.u64_field("points_run"), Some(8));
        assert_eq!(j.u64_field("points_restored"), Some(0));
        assert!(j.f64_field("cache_hit_rate").is_some());
        assert!(j.u64_field("frontier_runtime_energy").unwrap() >= 1);
        assert!(j.get("points").is_none(), "ambiguous duplicate of points_run");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_store_is_skipped_across_energy_presets() {
        // a state dir whose result store was priced under a different
        // energy model must cold-start (cached reports embed energy and
        // the model is not keyed) — the frontier must match a fresh run
        let dir = tmp_dir("energy_guard");
        let mut c = tiny();
        run_campaign(c.clone(), &RunOpts { state_dir: Some(dir.clone()), ..local(1) })
            .unwrap();
        // same axes, different pricing: journal must go, store may stay
        std::fs::remove_file(dir.join(crate::dse::journal::JOURNAL_FILE)).unwrap();
        c.energy = "7nm".into();
        let guarded = run_campaign(
            c.clone(),
            &RunOpts { state_dir: Some(dir.clone()), ..local(1) },
        )
        .unwrap();
        let fresh = run_campaign(c, &local(1)).unwrap();
        assert_eq!(guarded.completed, fresh.completed, "28nm-priced warm entries leaked");
        assert_eq!(
            guarded.stats.memo.layer_sims, fresh.stats.memo.layer_sims,
            "mismatched store must not pre-warm"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn resumed_half_is_served_from_shared_and_warm_caches() {
        // the CI smoke's >=50% assertion, as a unit test: run half the
        // campaign, resume, and require a >=50% hit rate on the rest
        let dir = tmp_dir("hitrate");
        let opts =
            RunOpts { state_dir: Some(dir.clone()), max_points: Some(4), ..local(2) };
        run_campaign(tiny(), &opts).unwrap();
        let resumed = resume_campaign(&dir, &local(2)).unwrap();
        assert!(resumed.is_complete());
        assert!(
            resumed.stats.hit_rate() >= 0.5,
            "resumed half hit rate {:.3} < 0.5 ({:?})",
            resumed.stats.hit_rate(),
            resumed.stats.memo
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
