//! Pareto-frontier computation: dominated-point pruning over two
//! minimized objectives (runtime vs energy, runtime vs bandwidth — the
//! trade-off views the paper's §IV sweeps chart one curve at a time).
//!
//! Point `a` *dominates* `b` when `a` is no worse on both coordinates
//! and strictly better on at least one. The frontier is every point not
//! dominated by any other; exact duplicates are all kept (neither
//! dominates the other), so resumed campaigns that journal identical
//! points reproduce identical frontiers.

/// True when `a` dominates `b` under minimization of both coordinates.
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 <= b.0 && a.1 <= b.1 && (a.0 < b.0 || a.1 < b.1)
}

/// Indices of the non-dominated points of `pts` (both coordinates
/// minimized), ordered by ascending `(x, y, index)` — a deterministic
/// sweep in O(n log n).
pub fn pareto_front(pts: &[(f64, f64)]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..pts.len()).collect();
    // total_cmp: finite metrics order exactly as partial_cmp, and a NaN
    // (which the objective extractors never emit) sorts deterministically
    // last instead of panicking
    order.sort_by(|&a, &b| {
        pts[a]
            .0
            .total_cmp(&pts[b].0)
            .then(pts[a].1.total_cmp(&pts[b].1))
            .then(a.cmp(&b))
    });
    let mut front: Vec<usize> = Vec::new();
    for &i in &order {
        // In sorted order the last kept point has the lowest y seen so
        // far (and the lowest x among points with that y), so dominance
        // against it alone is equivalent to dominance against all
        // earlier points (dominance is transitive).
        let dominated = front.last().is_some_and(|&j| dominates(pts[j], pts[i]));
        if !dominated {
            front.push(i);
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// O(n²) reference: a point is on the frontier iff nothing dominates it.
    fn brute_force(pts: &[(f64, f64)]) -> Vec<usize> {
        let mut out: Vec<usize> = (0..pts.len())
            .filter(|&i| !pts.iter().any(|&q| dominates(q, pts[i])))
            .collect();
        out.sort_by(|&a, &b| {
            pts[a]
                .0
                .partial_cmp(&pts[b].0)
                .unwrap()
                .then(pts[a].1.partial_cmp(&pts[b].1).unwrap())
                .then(a.cmp(&b))
        });
        out
    }

    #[test]
    fn dominance_definition() {
        assert!(dominates((1.0, 1.0), (2.0, 2.0)));
        assert!(dominates((1.0, 2.0), (1.0, 3.0)));
        assert!(!dominates((1.0, 1.0), (1.0, 1.0)), "equal points do not dominate");
        assert!(!dominates((1.0, 3.0), (2.0, 2.0)), "trade-off points do not dominate");
    }

    #[test]
    fn staircase_is_fully_kept_and_interior_pruned() {
        //   y
        //   4 .        (staircase 0,1,2 is the frontier; 3 is interior)
        let pts = [(1.0, 4.0), (2.0, 2.0), (4.0, 1.0), (3.0, 3.0)];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn duplicates_are_all_kept() {
        let pts = [(1.0, 2.0), (1.0, 2.0), (3.0, 1.0)];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
        // ... but a strictly better point prunes both copies
        let pts = [(1.0, 2.0), (1.0, 2.0), (1.0, 1.0)];
        assert_eq!(pareto_front(&pts), vec![2]);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(pareto_front(&[]).is_empty());
        assert_eq!(pareto_front(&[(5.0, 5.0)]), vec![0]);
        // a single best corner dominates everything else
        let pts = [(2.0, 2.0), (1.0, 1.0), (3.0, 3.0)];
        assert_eq!(pareto_front(&pts), vec![1]);
    }

    #[test]
    fn sweep_matches_brute_force_on_random_clouds() {
        let mut rng = Rng::new(0xD5E_9E37);
        for case in 0..200 {
            let n = (rng.range(1, 40)) as usize;
            let pts: Vec<(f64, f64)> = (0..n)
                .map(|_| (rng.range(0, 12) as f64, rng.range(0, 12) as f64))
                .collect();
            assert_eq!(pareto_front(&pts), brute_force(&pts), "case {case}: {pts:?}");
        }
    }

    #[test]
    fn frontier_is_sorted_and_non_dominated() {
        let mut rng = Rng::new(7);
        let pts: Vec<(f64, f64)> =
            (0..100).map(|_| (rng.range(0, 1000) as f64, rng.range(0, 1000) as f64)).collect();
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(pts[w[0]].0 <= pts[w[1]].0, "frontier must ascend in x");
        }
        for &i in &front {
            assert!(!pts.iter().any(|&q| dominates(q, pts[i])));
        }
    }
}
