//! Cycle-level "RTL" systolic-array simulator — the validation reference
//! of Fig 4 ("we validate SCALE-SIM against an in-house RTL model for a
//! systolic array implementing OS dataflow", §III-E).
//!
//! Unlike the analytical/trace model, nothing here knows the closed-form
//! cycle counts: every register is simulated explicitly, one cycle at a
//! time —
//!
//! * operand registers shift right (ifmap/A) and down (filter/B) one hop
//!   per cycle, store-and-forward (§III-A);
//! * each PE multiplies the two operands it latched this cycle and
//!   accumulates in place (output stationary);
//! * finished accumulators drain down the column, one value per column
//!   port per cycle.
//!
//! `run_matmul` returns both the cycle count *and* the numeric product,
//! so validation is two-sided: timing against [`crate::dataflow::os`]
//! and numerics against a software matmul (and, in the e2e example,
//! against the PJRT-executed Pallas artifact).

mod pinned;

pub use pinned::run_pinned_stream;

use crate::util::rng::Rng;

/// One processing element: MAC unit + operand registers.
#[derive(Clone, Debug, Default)]
struct Pe {
    acc: f32,
    macs_done: u32,
}

/// Result of one RTL run.
#[derive(Clone, Debug)]
pub struct RtlResult {
    /// Total cycles until the last output left the array.
    pub cycles: u64,
    /// The computed `A @ B`, row-major `r x c`.
    pub product: Vec<f32>,
}

/// Cycle-level OS-dataflow matmul: `A (r x k) @ B (k x c)` on an
/// `r x c` PE grid (one output element per PE — a single OS fold).
///
/// Panics if the shapes are inconsistent or empty.
pub fn run_matmul(a: &[f32], b: &[f32], r: usize, k: usize, c: usize) -> RtlResult {
    assert!(r > 0 && k > 0 && c > 0, "empty matmul");
    assert_eq!(a.len(), r * k, "A shape");
    assert_eq!(b.len(), k * c, "B shape");

    let mut pes = vec![Pe::default(); r * c];
    // operand register planes: value latched at each PE this cycle
    let mut a_plane: Vec<Option<f32>> = vec![None; r * c];
    let mut b_plane: Vec<Option<f32>> = vec![None; r * c];

    let mut product = vec![0f32; r * c];
    let mut emitted = 0usize;
    // Drain chain: once a column's *bottom* PE retires (it is always the
    // last of its column to finish: row r-1 has the largest skew), the
    // whole column shifts down in lockstep, one value out of the bottom
    // port per cycle, bottom row first. `drain_start[j]` is the first
    // emission cycle of column j; None while the column still computes.
    let mut drain_start: Vec<Option<u64>> = vec![None; c];

    let mut cycle: u64 = 0;
    let safety = (2 * r + c + k + 8) as u64 * 4; // generous upper bound

    while emitted < r * c {
        assert!(cycle < safety, "RTL did not converge: emitted {emitted}/{}", r * c);

        // --- drain step: active columns emit one value, bottom-first ----
        for j in 0..c {
            if let Some(start) = drain_start[j] {
                if cycle >= start {
                    let m = (cycle - start) as usize; // values already out
                    if m < r {
                        let src_row = r - 1 - m;
                        // the shift chain reaches this PE only after it
                        // has retired — invariant of the OS skew
                        debug_assert_eq!(pes[src_row * c + j].macs_done as usize, k);
                        product[src_row * c + j] = pes[src_row * c + j].acc;
                        emitted += 1;
                    }
                }
            }
        }

        // --- operand propagation: shift planes in place, feed edges -----
        // (right/down shifts walk high-to-low index, so no scratch plane
        // or per-cycle allocation is needed — §Perf iteration 2)
        for i in 0..r {
            for j in (1..c).rev() {
                a_plane[i * c + j] = a_plane[i * c + j - 1];
            }
            let t = cycle as i64 - i as i64;
            a_plane[i * c] = (t >= 0 && (t as usize) < k).then(|| a[i * k + t as usize]);
        }
        for i in (1..r).rev() {
            for j in 0..c {
                b_plane[i * c + j] = b_plane[(i - 1) * c + j];
            }
        }
        for j in 0..c {
            let t = cycle as i64 - j as i64;
            b_plane[j] = (t >= 0 && (t as usize) < k).then(|| b[(t as usize) * c + j]);
        }

        // --- MAC step ----------------------------------------------------
        for i in 0..r {
            for j in 0..c {
                if let (Some(av), Some(bv)) = (a_plane[i * c + j], b_plane[i * c + j]) {
                    let pe = &mut pes[i * c + j];
                    pe.acc += av * bv;
                    pe.macs_done += 1;
                    if pe.macs_done as usize == k && i == r - 1 {
                        // bottom PE retired: the column's shift chain
                        // starts emitting next cycle
                        drain_start[j] = Some(cycle + 1);
                    }
                }
            }
        }

        cycle += 1;
    }
    RtlResult { cycles: cycle, product }
}

/// Random-stimulus helper used by tests, benches and the Fig-4 harness.
pub fn random_matrices(r: usize, k: usize, c: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let a = (0..r * k).map(|_| rng.normal_f32()).collect();
    let b = (0..k * c).map(|_| rng.normal_f32()).collect();
    (a, b)
}

/// Software reference matmul for numeric validation.
pub fn matmul_ref(a: &[f32], b: &[f32], r: usize, k: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0f32; r * c];
    for i in 0..r {
        for kk in 0..k {
            let av = a[i * k + kk];
            for j in 0..c {
                out[i * c + j] += av * b[kk * c + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::dataflow::Dataflow;
    use crate::util::prop::forall;

    fn check(r: usize, k: usize, c: usize, seed: u64) {
        let (a, b) = random_matrices(r, k, c, seed);
        let rtl = run_matmul(&a, &b, r, k, c);
        // numerics: exact same op order differences are within f32 eps
        let sw = matmul_ref(&a, &b, r, k, c);
        for (i, (x, y)) in rtl.product.iter().zip(&sw).enumerate() {
            assert!((x - y).abs() <= 1e-3 * (1.0 + y.abs()), "elem {i}: {x} vs {y}");
        }
        // timing: must equal the analytical OS model exactly (Fig 4)
        let layer = LayerShape::gemm("mm", r as u64, k as u64, c as u64);
        let t = Dataflow::Os.timing(&layer, r as u64, c as u64);
        assert_eq!(rtl.cycles, t.cycles, "{r}x{k}x{c}");
    }

    #[test]
    fn square_sizes_match_analytical_and_numerics() {
        for &n in &[1usize, 2, 4, 8, 16, 32] {
            check(n, n, n, n as u64);
        }
    }

    #[test]
    fn rectangular_shapes() {
        check(4, 16, 2, 1);
        check(2, 3, 8, 2);
        check(16, 1, 16, 3); // K = 1 edge case
        check(1, 7, 1, 4); // single PE
    }

    #[test]
    fn property_rtl_equals_analytical() {
        forall(
            0xC0FFEE,
            25,
            |rng| (rng.range(1, 12), rng.range(1, 24), rng.range(1, 12)),
            |&(r, k, c)| {
                let (a, b) = random_matrices(r as usize, k as usize, c as usize, r * 31 + c);
                let rtl = run_matmul(&a, &b, r as usize, k as usize, c as usize);
                let layer = LayerShape::gemm("mm", r, k, c);
                rtl.cycles == Dataflow::Os.timing(&layer, r, c).cycles
            },
        );
    }

    #[test]
    fn drain_is_one_output_per_column_per_cycle() {
        // 1-column array: outputs must take r extra cycles to drain
        let (a, b) = random_matrices(4, 4, 1, 9);
        let rtl = run_matmul(&a, &b, 4, 4, 1);
        // T = 2*4 + 1 + 4 - 2 = 11
        assert_eq!(rtl.cycles, 11);
    }

    #[test]
    #[should_panic(expected = "A shape")]
    fn shape_mismatch_panics() {
        run_matmul(&[1.0; 3], &[1.0; 4], 2, 2, 2);
    }
}
