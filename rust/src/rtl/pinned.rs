//! Cycle-level *stationary-operand* systolic array (WS and IS, Fig 2b/c)
//! — the second half of the RTL validation substrate.
//!
//! WS and IS share one datapath: a `r x c` block of the stationary
//! operand is first streamed down from the top edge (`r` fill cycles,
//! store-and-forward), then the moving operand streams from the left
//! edge, skewed one cycle per row; partial sums flow *down* each column,
//! accumulating one term per row, and exit at the bottom port — exactly
//! the §III-B description ("reduction takes place by communicating the
//! partial sums across the MAC units... over the given column").
//!
//! The timing invariant this must (and does, see tests) reproduce:
//! stream row `s` of the moving operand exits column `j` at cycle
//! `2r + s + j`, so a fold occupies `2r + c + S - 1` cycles — the
//! closed form in [`crate::dataflow::ws`] / [`crate::dataflow::is`].

use super::RtlResult;

/// Run one stationary fold: `streamed (S x r) @ pinned (r x c)`,
/// returning the `S x c` product and the cycle count.
///
/// * WS: `pinned` = weight block `(K x N)`, `streamed` = im2col windows
///   `(Npx x K)` → product = OFMAP `(Npx x N)`.
/// * IS: `pinned` = im2col block transposed `(K x Npx)`, `streamed` =
///   filters `(Nf x K)` → product = OFMAP-transposed `(Nf x Npx)`.
pub fn run_pinned_stream(streamed: &[f32], pinned: &[f32], s: usize, r: usize, c: usize) -> RtlResult {
    assert!(s > 0 && r > 0 && c > 0, "empty fold");
    assert_eq!(streamed.len(), s * r, "streamed shape");
    assert_eq!(pinned.len(), r * c, "pinned shape");

    // --- phase 1: fill — pinned operand shifts down from the top edge --
    // weight registers per PE; bottom row's value is injected first
    let mut wreg = vec![0f32; r * c];
    // shift pipeline: one register per PE in the same grid
    let mut pipe: Vec<Option<f32>> = vec![None; r * c];
    let mut cycle: u64 = 0;
    for t in 0..r {
        // shift down (bottom-up scan preserves one-hop-per-cycle)
        for i in (0..r - 1).rev() {
            for j in 0..c {
                if let Some(v) = pipe[i * c + j].take() {
                    pipe[(i + 1) * c + j] = Some(v);
                }
            }
        }
        // inject row (r-1-t)'s values at the top
        for j in 0..c {
            debug_assert!(pipe[j].is_none());
            pipe[j] = Some(pinned[(r - 1 - t) * c + j]);
        }
        // values that have travelled to their home row latch into wreg:
        // value for row i was injected at t' = r-1-i and needs i hops,
        // arriving at t = r-1-i + i = r-1 ... latch everything at the
        // end of fill instead (store-and-forward semantics identical)
        cycle += 1;
    }
    // after r cycles the value injected at t for row (r-1-t) has made
    // t' = r-1-t... latch: the pipeline now holds row i's value at
    // grid position i
    for i in 0..r {
        for j in 0..c {
            debug_assert!(pipe[i * c + j].is_some(), "fill must populate every PE");
            wreg[i * c + j] = pipe[i * c + j].take().unwrap_or(0.0);
        }
    }

    // --- phase 2: stream + column reduction ----------------------------
    // a_plane: moving operand value latched at each PE this cycle
    let mut a_plane: Vec<Option<f32>> = vec![None; r * c];
    // psum[i][j]: partial sum leaving PE(i,j) at the end of this cycle
    let mut psum: Vec<Option<f32>> = vec![None; r * c];
    let mut product = vec![0f32; s * c];
    let mut emitted = 0usize;
    let fill_end = cycle; // == r

    let safety = (2 * r + c + s + 8) as u64 * 4;
    while emitted < s * c {
        assert!(cycle < safety, "pinned-stream RTL did not converge");
        let t = cycle - fill_end; // cycles since streaming began

        // emit from bottom ports: PE(r-1, j)'s psum computed last cycle
        for j in 0..c {
            if let Some(v) = psum[(r - 1) * c + j].take() {
                // stream row index: exits at t = s_idx + (r-1) + j + 1
                let s_idx = (t as i64) - 1 - (r as i64 - 1) - j as i64;
                debug_assert!(s_idx >= 0, "early emission");
                product[s_idx as usize * c + j] = v;
                emitted += 1;
            }
        }

        // shift operand plane right, feed left edge skewed
        let mut new_a = vec![None; r * c];
        for i in 0..r {
            for j in 0..c {
                new_a[i * c + j] = if j == 0 {
                    let idx = t as i64 - i as i64;
                    (idx >= 0 && (idx as usize) < s)
                        .then(|| streamed[idx as usize * r + i])
                } else {
                    a_plane[i * c + j - 1]
                };
            }
        }
        a_plane = new_a;

        // MAC + psum propagation (top-down: PE(i) consumes psum emitted
        // by PE(i-1) last cycle)
        let mut new_psum = vec![None; r * c];
        for i in 0..r {
            for j in 0..c {
                if let Some(a) = a_plane[i * c + j] {
                    let upstream = if i == 0 { Some(0.0) } else { psum[(i - 1) * c + j] };
                    if let Some(up) = upstream {
                        new_psum[i * c + j] = Some(up + a * wreg[i * c + j]);
                    }
                }
            }
        }
        psum = new_psum;
        cycle += 1;
    }
    RtlResult { cycles: cycle, product }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::dataflow::Dataflow;
    use crate::rtl::{matmul_ref, random_matrices};
    use crate::util::prop::forall;

    /// WS fold: X (S x K) @ W (K x N) on a K x N grid.
    fn check_ws(s: usize, k: usize, n: usize, seed: u64) {
        let (x, w) = random_matrices(s, k, n, seed);
        let rtl = run_pinned_stream(&x, &w, s, k, n);
        let want = matmul_ref(&x, &w, s, k, n);
        for (i, (a, b)) in rtl.product.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()), "elem {i}: {a} vs {b}");
        }
        // timing must equal the analytical WS model for a layer whose
        // gemm view is (Npx=s, window=k, filters=n) on a k x n array
        let layer = LayerShape::gemm("ws", s as u64, k as u64, n as u64);
        let t = Dataflow::Ws.timing(&layer, k as u64, n as u64);
        assert_eq!(rtl.cycles, t.cycles, "ws {s}x{k}x{n}");
    }

    /// IS fold: W (M x K) @ Xt (K x P) on a K x P grid.
    fn check_is(m: usize, k: usize, p: usize, seed: u64) {
        let (w, xt) = random_matrices(m, k, p, seed);
        let rtl = run_pinned_stream(&w, &xt, m, k, p);
        let want = matmul_ref(&w, &xt, m, k, p);
        for (a, b) in rtl.product.iter().zip(&want) {
            assert!((a - b).abs() <= 1e-3 * (1.0 + b.abs()));
        }
        // layer gemm view: (Npx=p, window=k, filters=m) on a k x p array
        let layer = LayerShape::gemm("is", p as u64, k as u64, m as u64);
        let t = Dataflow::Is.timing(&layer, k as u64, p as u64);
        assert_eq!(rtl.cycles, t.cycles, "is {m}x{k}x{p}");
    }

    #[test]
    fn ws_square_folds_match() {
        for &n in &[1usize, 2, 4, 8, 16] {
            check_ws(n, n, n, n as u64);
        }
    }

    #[test]
    fn ws_rectangular_folds() {
        check_ws(10, 4, 6, 1);
        check_ws(1, 8, 3, 2); // single streamed row
        check_ws(30, 2, 2, 3); // long stream, tiny array
        check_ws(5, 1, 7, 4); // K = 1
    }

    #[test]
    fn is_square_and_rect() {
        for &n in &[1usize, 2, 8] {
            check_is(n, n, n, 100 + n as u64);
        }
        check_is(7, 3, 9, 5);
        check_is(1, 6, 2, 6);
    }

    #[test]
    fn property_ws_rtl_equals_analytical() {
        forall(
            0xB5,
            20,
            |rng| (rng.range(1, 10), rng.range(1, 10), rng.range(1, 10)),
            |&(s, k, n)| {
                let (x, w) = random_matrices(s as usize, k as usize, n as usize, s * 7 + n);
                let rtl = run_pinned_stream(&x, &w, s as usize, k as usize, n as usize);
                let layer = LayerShape::gemm("ws", s, k, n);
                rtl.cycles == Dataflow::Ws.timing(&layer, k, n).cycles
            },
        );
    }

    #[test]
    #[should_panic(expected = "streamed shape")]
    fn shape_mismatch_panics() {
        run_pinned_stream(&[1.0; 3], &[1.0; 4], 2, 2, 2);
    }
}
