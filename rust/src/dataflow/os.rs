//! Output Stationary dataflow (§III-B, Fig 2a).
//!
//! Each PE is pinned to one OFMAP pixel: array rows map to output pixels
//! (adjacent pixels of one channel down a column), array columns map to
//! filters (output channels). IFMAP operands stream from the left edge,
//! filter operands from the top edge, both skewed; each PE accumulates
//! its pixel over `K = window` cycles, then accumulators drain down the
//! columns (one value per column port per cycle).
//!
//! Per-fold timeline for a fold using `r x c` PEs (base cycle `b`):
//!
//! ```text
//! read:  row i streams its K ifmap words on cycles  b+i .. b+i+K-1
//!        col j streams its K filter words on cycles b+j .. b+j+K-1
//! mac:   PE(i,j) performs its k-th MAC at            b+i+j+k
//! drain: PE(i,j)'s pixel exits the column at         b+j+K-1+(r-1)+(r-i)
//! ```
//!
//! so the fold occupies `2r + c + K - 2` cycles and folds run
//! back-to-back: `T = Σ_folds (2r_u + c_u + K - 2)`.

use crate::arch::LayerShape;
use crate::util::ceil_div;

use super::{for_fold_shapes, mapping_efficiency, Timing};

/// Per-fold cycle cost (`r`,`c` PEs used, window `k`).
#[inline]
pub fn fold_cycles(r: u64, c: u64, k: u64) -> u64 {
    2 * r + c + k - 2
}

/// Analytical timing for one layer under OS on a `rows x cols` array.
pub fn timing(layer: &LayerShape, rows: u64, cols: u64) -> Timing {
    let (npx, k, nf) = layer.gemm_view();
    let row_folds = ceil_div(npx, rows);
    let col_folds = ceil_div(nf, cols);

    let mut cycles = 0u64;
    for_fold_shapes(npx, rows, nf, cols, |n, r, c| {
        cycles += n * fold_cycles(r, c, k);
    });

    // Every fold streams K ifmap words per used row and K filter words per
    // used column; Σ r_u over the whole grid is Npx * col_folds, and
    // Σ c_u is Nf * row_folds.
    let sram_reads_ifmap = k * npx * col_folds;
    let sram_reads_filter = k * nf * row_folds;
    // every output pixel is produced exactly once, fully reduced in-PE
    let sram_writes_ofmap = npx * nf;

    let total_pes = rows * cols;
    Timing {
        cycles,
        row_folds,
        col_folds,
        utilization: layer.macs() as f64 / (total_pes * cycles) as f64,
        mapping_efficiency: mapping_efficiency(npx, rows, nf, cols),
        sram_reads_ifmap,
        sram_reads_filter,
        sram_writes_ofmap,
        sram_reads_ofmap: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;

    #[test]
    fn single_fold_matmul_matches_hand_count() {
        // 8x8 array, GEMM 8x8x8: one fold, K=8 => 2*8 + 8 + 8 - 2 = 30.
        let l = LayerShape::gemm("mm", 8, 8, 8);
        let t = timing(&l, 8, 8);
        assert_eq!((t.row_folds, t.col_folds), (1, 1));
        assert_eq!(t.cycles, 30);
        assert_eq!(t.sram_reads_ifmap, 8 * 8);
        assert_eq!(t.sram_reads_filter, 8 * 8);
        assert_eq!(t.sram_writes_ofmap, 64);
        assert_eq!(t.sram_reads_ofmap, 0);
    }

    #[test]
    fn folds_multiply_cycles() {
        let l = LayerShape::gemm("mm", 16, 8, 16); // 2x2 folds on 8x8
        let t = timing(&l, 8, 8);
        assert_eq!((t.row_folds, t.col_folds), (2, 2));
        assert_eq!(t.cycles, 4 * 30);
    }

    #[test]
    fn residual_folds_cost_less() {
        let l = LayerShape::gemm("mm", 9, 8, 8); // residual row fold of 1
        let t = timing(&l, 8, 8);
        // full fold 30 + residual fold 2*1+8+8-2 = 16
        assert_eq!(t.cycles, 30 + 16);
    }

    #[test]
    fn ofmap_writes_are_exact() {
        let l = LayerShape::conv("c", 12, 12, 3, 3, 4, 10, 1);
        let t = timing(&l, 8, 8);
        assert_eq!(t.sram_writes_ofmap, l.npx() * 10);
    }

    #[test]
    fn utilization_bounded() {
        let l = LayerShape::conv("c", 56, 56, 3, 3, 64, 64, 1);
        for &(r, c) in &[(8, 8), (32, 32), (128, 128), (8, 2048)] {
            let t = timing(&l, r, c);
            assert!(t.utilization > 0.0 && t.utilization <= 1.0, "{r}x{c}: {}", t.utilization);
        }
    }

    #[test]
    fn ifmap_reads_scale_with_column_folds() {
        // doubling filters past the array width re-streams the ifmap
        let l1 = LayerShape::gemm("a", 8, 8, 8);
        let l2 = LayerShape::gemm("b", 8, 8, 16);
        assert_eq!(
            timing(&l2, 8, 8).sram_reads_ifmap,
            2 * timing(&l1, 8, 8).sram_reads_ifmap
        );
    }
}
