//! Input Stationary dataflow (§III-B, Fig 2c).
//!
//! The mirror of WS: each array column pins one *convolution window* (the
//! set of IFMAP pixels producing one OFMAP pixel, §III-B), rows map to
//! window elements. A fold first streams the window block from the top
//! edge (`r` cycles), then streams all `num_filters` weight vectors from
//! the left edge; partial sums reduce down each column.
//!
//! Per-fold cost mirrors WS with the moving operand count `Npx -> Nf`:
//! `2r + c + Nf - 1`, over `⌈K/rows⌉ x ⌈Npx/cols⌉` folds.
//!
//! "The cost and runtime compared to WS varies by workload" (§III-B): IS
//! wins exactly when the weight matrix outnumbers the output pixels —
//! asserted in `ws.rs` tests from the paper's §IV-B claim.

use crate::arch::LayerShape;
use crate::util::ceil_div;

use super::{for_fold_shapes, mapping_efficiency, Timing};

/// Per-fold cycle cost (`r`,`c` PEs used, `nf` filters streamed).
#[inline]
pub fn fold_cycles(r: u64, c: u64, nf: u64) -> u64 {
    2 * r + c + nf - 1
}

/// Analytical timing for one layer under IS on a `rows x cols` array.
pub fn timing(layer: &LayerShape, rows: u64, cols: u64) -> Timing {
    let (npx, k, nf) = layer.gemm_view();
    let row_folds = ceil_div(k, rows); // window-element folds
    let col_folds = ceil_div(npx, cols); // convolution-window folds

    let mut cycles = 0u64;
    for_fold_shapes(k, rows, npx, cols, |n, r, c| {
        cycles += n * fold_cycles(r, c, nf);
    });

    // Fill loads each im2col element once: K elements per window, Npx
    // windows (adjacent-window overlap is an SRAM-level reuse, so the
    // *SRAM* is still read per element pinned).
    let sram_reads_ifmap = k * npx;
    // Each fold streams Nf filter rows of r_u elements; Σ r_u = K*col_folds.
    let sram_reads_filter = nf * k * col_folds;
    // One (partial) output per filter per window per window-fold.
    let sram_writes_ofmap = npx * nf * row_folds;
    let sram_reads_ofmap = npx * nf * (row_folds - 1);

    let total_pes = rows * cols;
    Timing {
        cycles,
        row_folds,
        col_folds,
        utilization: layer.macs() as f64 / (total_pes * cycles) as f64,
        mapping_efficiency: mapping_efficiency(k, rows, npx, cols),
        sram_reads_ifmap,
        sram_reads_filter,
        sram_writes_ofmap,
        sram_reads_ofmap,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::dataflow::{ws, Dataflow};

    #[test]
    fn single_fold_matmul_matches_hand_count() {
        let l = LayerShape::gemm("mm", 8, 8, 8);
        let t = timing(&l, 8, 8);
        assert_eq!((t.row_folds, t.col_folds), (1, 1));
        assert_eq!(t.cycles, 31); // 2*8 + 8 + 8 - 1
        assert_eq!(t.sram_reads_ifmap, 64);
        assert_eq!(t.sram_reads_filter, 64);
    }

    #[test]
    fn is_and_ws_are_duals_on_square_gemm() {
        // symmetric GEMM (M == N) => identical runtime
        let l = LayerShape::gemm("mm", 24, 40, 24);
        assert_eq!(timing(&l, 8, 8).cycles, ws::timing(&l, 8, 8).cycles);
    }

    #[test]
    fn ifmap_loaded_once_per_im2col_element() {
        let l = LayerShape::conv("c", 10, 10, 3, 3, 4, 7, 1);
        let t = timing(&l, 8, 8);
        assert_eq!(t.sram_reads_ifmap, l.window() * l.npx());
    }

    #[test]
    fn partial_sum_traffic_on_window_folds() {
        let l = LayerShape::gemm("mm", 8, 20, 8); // K=20 on 8 rows => 3 folds
        let t = timing(&l, 8, 8);
        assert_eq!(t.row_folds, 3);
        assert_eq!(t.sram_reads_ofmap, 2 * 64);
    }

    #[test]
    fn dispatch_through_enum_matches() {
        let l = LayerShape::conv("c", 12, 12, 3, 3, 8, 8, 1);
        let direct = timing(&l, 16, 16);
        let via = Dataflow::Is.timing(&l, 16, 16);
        assert_eq!(direct, via);
    }

    #[test]
    fn utilization_bounded() {
        let l = LayerShape::fc("fc", 1, 4096, 4096);
        let t = timing(&l, 128, 128);
        assert!(t.utilization > 0.0 && t.utilization <= 1.0);
    }
}
