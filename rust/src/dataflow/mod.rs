//! Dataflow models (§III-B): *Output Stationary*, *Weight Stationary*,
//! *Input Stationary*, using Eyeriss's nomenclature as the paper does.
//!
//! Each dataflow schedules the layer's GEMM view
//! `(M, K, N) = (Npx, window, num_filters)` onto a `rows x cols` array in
//! *folds* (time-multiplexed mappings of the stationary operand), and
//! yields a [`Timing`]: stall-free runtime in cycles, fold counts, PE
//! utilization and exact SRAM access counts. The closed forms here are
//! validated three ways:
//!
//! 1. against the cycle-accurate address traces in [`crate::trace`]
//!    (`cycles` == last trace event + 1; access counts match exactly),
//! 2. against the RTL-level PE-grid simulator in [`crate::rtl`] (Fig 4),
//! 3. by property tests over random layer shapes.
//!
//! Per-fold durations (`r`,`c` = rows/cols actually mapped in the fold):
//!
//! | dataflow | folds | per-fold cycles |
//! |----------|-------|-----------------|
//! | OS | `⌈Npx/rows⌉ x ⌈N/cols⌉` | `2r + c + K - 2` |
//! | WS | `⌈K/rows⌉ x ⌈N/cols⌉` | `2r + c + Npx - 1` |
//! | IS | `⌈K/rows⌉ x ⌈Npx/cols⌉` | `2r + c + N - 1` |
//!
//! (OS: `r-1` skew fill + `K` stream + `c-1` column skew + `r` drain;
//! WS/IS: `r` pin + skewed stream of the moving operand + column
//! reduction + drain.) Folds execute back-to-back — the paper's model
//! assumes outputs drain without stalling compute (§III-B) but does *not*
//! overlap one fold's drain with the next fold's fill, matching the
//! original tool's serialized fold schedule.

pub mod is;
pub mod os;
pub mod ws;

use crate::arch::LayerShape;
use crate::{Error, Result};

/// Mapping strategy (Table I `Dataflow`: legal values `os`, `ws`, `is`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Dataflow {
    Os,
    Ws,
    Is,
}

impl Dataflow {
    pub const ALL: [Dataflow; 3] = [Dataflow::Os, Dataflow::Ws, Dataflow::Is];

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_lowercase().as_str() {
            "os" | "output_stationary" => Ok(Dataflow::Os),
            "ws" | "weight_stationary" => Ok(Dataflow::Ws),
            "is" | "input_stationary" => Ok(Dataflow::Is),
            other => Err(Error::Config(format!(
                "unknown dataflow {other:?} (legal: os, ws, is)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dataflow::Os => "os",
            Dataflow::Ws => "ws",
            Dataflow::Is => "is",
        }
    }

    /// Stall-free timing + SRAM access counts for one layer.
    pub fn timing(&self, layer: &LayerShape, rows: u64, cols: u64) -> Timing {
        match self {
            Dataflow::Os => os::timing(layer, rows, cols),
            Dataflow::Ws => ws::timing(layer, rows, cols),
            Dataflow::Is => is::timing(layer, rows, cols),
        }
    }
}

impl std::fmt::Display for Dataflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Result of scheduling one layer under one dataflow on one array shape.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Timing {
    /// Stall-free runtime in cycles (== last trace event cycle + 1).
    pub cycles: u64,
    /// Folds along the array-rows dimension (OS: output px; WS/IS: window).
    pub row_folds: u64,
    /// Folds along the array-cols dimension (OS/WS: filters; IS: output px).
    pub col_folds: u64,
    /// Overall array utilization: `macs / (rows*cols*cycles)` in [0,1].
    pub utilization: f64,
    /// Average fraction of PEs mapped with useful work across folds.
    pub mapping_efficiency: f64,
    /// Exact SRAM access counts (words).
    pub sram_reads_ifmap: u64,
    pub sram_reads_filter: u64,
    pub sram_writes_ofmap: u64,
    /// Partial-sum re-reads when the window dimension folds (WS/IS only).
    pub sram_reads_ofmap: u64,
}

impl Timing {
    /// Total stationary-operand remaps — the paper's §IV-B cost driver.
    pub fn remaps(&self) -> u64 {
        self.row_folds * self.col_folds
    }

    /// Total SRAM traffic in words.
    pub fn sram_total(&self) -> u64 {
        self.sram_reads_ifmap
            + self.sram_reads_filter
            + self.sram_writes_ofmap
            + self.sram_reads_ofmap
    }
}

/// Iterate the (full + residual) fold grid analytically.
///
/// The fold grid over `(total_r / rows, total_c / cols)` has at most four
/// distinct fold shapes: (rows,cols), (rows,resid_c), (resid_r,cols),
/// (resid_r,resid_c). `f(count, r_used, c_used)` is invoked once per
/// distinct shape with its multiplicity — O(1) instead of O(folds).
pub(crate) fn for_fold_shapes(
    total_r: u64,
    rows: u64,
    total_c: u64,
    cols: u64,
    mut f: impl FnMut(u64, u64, u64),
) {
    let full_r = total_r / rows;
    let resid_r = total_r % rows;
    let full_c = total_c / cols;
    let resid_c = total_c % cols;
    if full_r > 0 && full_c > 0 {
        f(full_r * full_c, rows, cols);
    }
    if resid_r > 0 && full_c > 0 {
        f(full_c, resid_r, cols);
    }
    if full_r > 0 && resid_c > 0 {
        f(full_r, rows, resid_c);
    }
    if resid_r > 0 && resid_c > 0 {
        f(1, resid_r, resid_c);
    }
}

/// Shared mapping-efficiency computation over the fold grid.
pub(crate) fn mapping_efficiency(total_r: u64, rows: u64, total_c: u64, cols: u64) -> f64 {
    let mut mapped = 0u64;
    let mut nfolds = 0u64;
    for_fold_shapes(total_r, rows, total_c, cols, |n, r, c| {
        mapped += n * r * c;
        nfolds += n;
    });
    mapped as f64 / (rows * cols * nfolds) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_long_and_short_names() {
        assert_eq!(Dataflow::parse("OS").unwrap(), Dataflow::Os);
        assert_eq!(Dataflow::parse("weight_stationary").unwrap(), Dataflow::Ws);
        assert_eq!(Dataflow::parse(" is ").unwrap(), Dataflow::Is);
        assert!(Dataflow::parse("rs").is_err()); // row stationary unsupported (§III-B)
    }

    #[test]
    fn fold_shapes_partition_the_grid() {
        // sum of count*r*c must equal total_r*total_c for any split
        for &(tr, r, tc, c) in &[
            (10u64, 4u64, 7u64, 3u64),
            (8, 8, 8, 8),
            (1, 128, 1, 128),
            (129, 64, 300, 7),
        ] {
            let mut area = 0;
            for_fold_shapes(tr, r, tc, c, |n, ru, cu| area += n * ru * cu);
            assert_eq!(area, tr * tc, "({tr},{r},{tc},{c})");
        }
    }

    #[test]
    fn fold_shapes_count_matches_ceil() {
        let mut folds = 0;
        for_fold_shapes(10, 4, 7, 3, |n, _, _| folds += n);
        assert_eq!(folds, 3 * 3); // ceil(10/4)*ceil(7/3)
    }

    #[test]
    fn mapping_efficiency_is_one_when_exact() {
        assert_eq!(mapping_efficiency(16, 8, 24, 8), 1.0);
    }

    #[test]
    fn mapping_efficiency_below_one_with_residue() {
        let e = mapping_efficiency(9, 8, 8, 8);
        assert!(e < 1.0 && e > 0.0);
    }

    #[test]
    fn remaps_counts_the_full_fold_grid() {
        // 14x14 ofmap (196 px) on 64 rows → 4 row folds; 8 filters on
        // 3 cols → 3 col folds (OS mapping): one remap per fold pair.
        let l = crate::arch::LayerShape::conv("c", 16, 16, 3, 3, 4, 8, 1);
        let t = Dataflow::Os.timing(&l, 64, 3);
        assert_eq!((t.row_folds, t.col_folds), (4, 3));
        assert_eq!(t.remaps(), 12);
    }

    #[test]
    fn os_wins_when_folds_favor_it_like_fig5() {
        // Fig 5's glance: OS outperforms the other two. OS fold count is
        // ∝ Npx·Nf while WS/IS is ∝ K·(Nf|Npx); with K > Npx (deep conv,
        // AlphaGoZero-like) OS strictly wins on every square array.
        let l = crate::arch::LayerShape::conv("c", 19, 19, 3, 3, 256, 256, 1);
        assert!(l.window() > l.npx());
        for &n in &[8u64, 16, 32, 64, 128] {
            let os = Dataflow::Os.timing(&l, n, n).cycles;
            let ws = Dataflow::Ws.timing(&l, n, n).cycles;
            let is = Dataflow::Is.timing(&l, n, n).cycles;
            assert!(os <= ws && os <= is, "{n}x{n}: os={os} ws={ws} is={is}");
        }
    }

    #[test]
    fn dataflow_gap_is_modest_like_fig5() {
        // §IV-B answer 3: "fixating to a given dataflow might not lead to
        // significant losses" — on a busy conv layer all three dataflows
        // land within ~2x of each other.
        let l = crate::arch::LayerShape::conv("c", 28, 28, 3, 3, 64, 64, 1);
        let t: Vec<u64> = Dataflow::ALL
            .iter()
            .map(|d| d.timing(&l, 32, 32).cycles)
            .collect();
        let (min, max) = (*t.iter().min().unwrap(), *t.iter().max().unwrap());
        assert!(max < 3 * min, "spread too wide: {t:?}");
    }
}
