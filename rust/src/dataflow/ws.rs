//! Weight Stationary dataflow (§III-B, Fig 2b).
//!
//! Each PE pins one weight element: array rows map to convolution-window
//! elements (`K = R*S*C`), array columns map to filters. A fold first
//! streams the `r x c` weight block down from the top edge (`r` cycles),
//! then streams all `Npx` convolution windows from the left edge, skewed;
//! partial sums reduce down each column and exit at the bottom.
//!
//! Per-fold timeline (base `b`, `r x c` PEs used):
//!
//! ```text
//! fill:   c filter words per cycle on               b .. b+r-1
//! stream: window t enters row i at                  b+r+t+i
//! exit:   window t's partial sum leaves column j at b+2r+t+j
//! ```
//!
//! so a fold occupies `2r + c + Npx - 1` cycles and
//! `T = Σ_folds (2r_u + c_u + Npx - 1)`.
//!
//! When `K > rows` the window dimension folds (`⌈K/rows⌉`), and each
//! OFMAP pixel is written once per window-fold: later folds re-read the
//! partial sum from the OFMAP SRAM and accumulate (the §III-C reason the
//! output partition "stores the partial sums" for WS/IS).

use crate::arch::LayerShape;
use crate::util::ceil_div;

use super::{for_fold_shapes, mapping_efficiency, Timing};

/// Per-fold cycle cost (`r`,`c` PEs used, `npx` windows streamed).
#[inline]
pub fn fold_cycles(r: u64, c: u64, npx: u64) -> u64 {
    2 * r + c + npx - 1
}

/// Analytical timing for one layer under WS on a `rows x cols` array.
pub fn timing(layer: &LayerShape, rows: u64, cols: u64) -> Timing {
    let (npx, k, nf) = layer.gemm_view();
    let row_folds = ceil_div(k, rows); // window folds
    let col_folds = ceil_div(nf, cols); // filter folds

    let mut cycles = 0u64;
    for_fold_shapes(k, rows, nf, cols, |n, r, c| {
        cycles += n * fold_cycles(r, c, npx);
    });

    // Fill reads each weight exactly once over the whole schedule.
    let sram_reads_filter = k * nf;
    // Each fold streams Npx windows of r_u elements; Σ r_u = K * col_folds.
    let sram_reads_ifmap = npx * k * col_folds;
    // One (partial) output per window per column per fold.
    let sram_writes_ofmap = npx * nf * row_folds;
    // Re-read partial sums for accumulation on all but the first window fold.
    let sram_reads_ofmap = npx * nf * (row_folds - 1);

    let total_pes = rows * cols;
    Timing {
        cycles,
        row_folds,
        col_folds,
        utilization: layer.macs() as f64 / (total_pes * cycles) as f64,
        mapping_efficiency: mapping_efficiency(k, rows, nf, cols),
        sram_reads_ifmap,
        sram_reads_filter,
        sram_writes_ofmap,
        sram_reads_ofmap,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;

    #[test]
    fn single_fold_matmul_matches_hand_count() {
        // 8x8 array, GEMM 8x8x8: K=8 fits rows, 8 filters fit cols.
        // fold = 2*8 + 8 + 8 - 1 = 31.
        let l = LayerShape::gemm("mm", 8, 8, 8);
        let t = timing(&l, 8, 8);
        assert_eq!((t.row_folds, t.col_folds), (1, 1));
        assert_eq!(t.cycles, 31);
        assert_eq!(t.sram_reads_filter, 64); // each weight once
        assert_eq!(t.sram_reads_ifmap, 64);
        assert_eq!(t.sram_writes_ofmap, 64);
        assert_eq!(t.sram_reads_ofmap, 0);
    }

    #[test]
    fn window_fold_causes_partial_sum_traffic() {
        // K = 16 on 8 rows: two window folds.
        let l = LayerShape::gemm("mm", 8, 16, 8);
        let t = timing(&l, 8, 8);
        assert_eq!(t.row_folds, 2);
        assert_eq!(t.sram_writes_ofmap, 2 * 64);
        assert_eq!(t.sram_reads_ofmap, 64);
    }

    #[test]
    fn weights_read_exactly_once() {
        let l = LayerShape::conv("c", 14, 14, 3, 3, 32, 48, 1);
        let t = timing(&l, 16, 16);
        assert_eq!(t.sram_reads_filter, l.filter_elems());
    }

    #[test]
    fn streaming_cost_dominated_by_npx() {
        // Npx >> everything: cycles ≈ folds * Npx
        let l = LayerShape::conv("c", 112, 112, 1, 1, 8, 8, 1);
        let t = timing(&l, 8, 8);
        assert_eq!((t.row_folds, t.col_folds), (1, 1));
        assert_eq!(t.cycles, fold_cycles(8, 8, l.npx()));
    }

    #[test]
    fn ws_beats_is_when_pixels_exceed_weights() {
        // paper §IV-B: "if output pixels > weights, WS outperforms IS"
        let l = LayerShape::conv("c", 64, 64, 3, 3, 8, 8, 1); // Npx=3844 >> K*Nf=576
        let ws = timing(&l, 16, 16).cycles;
        let is = super::super::is::timing(&l, 16, 16).cycles;
        assert!(ws < is, "ws={ws} is={is}");
    }

    #[test]
    fn is_beats_ws_when_weights_exceed_pixels() {
        let l = LayerShape::fc("fc", 4, 2048, 1024); // Npx=4 << weights
        let ws = timing(&l, 16, 16).cycles;
        let is = super::super::is::timing(&l, 16, 16).cycles;
        assert!(is < ws, "ws={ws} is={is}");
    }
}
