//! Workload geometry: one DNN layer as SCALE-Sim sees it (Table II).
//!
//! A layer is a convolution; matrix-matrix (MM), matrix-vector (MV) and
//! vector-vector (VV) products are encoded as conv special cases exactly
//! as §III-A describes (fully-connected / RNN layers become MV). The
//! canonical GEMM encoding used throughout (and mirrored by the Python
//! side's im2col view) is:
//!
//! ```text
//! (M,K) @ (K,N)  ==  conv( ifmap = M x 1 x K, filter = 1 x 1 x K, N filters )
//! ```
//!
//! so `Npx = M`, `window = K`, `num_filters = N`.

use crate::{Error, Result};

/// One DNN layer's hyper-parameters (Table II row).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerShape {
    /// User-defined tag ("Layer Name").
    pub name: String,
    /// IFMAP height / width (pixels).
    pub ifmap_h: u64,
    pub ifmap_w: u64,
    /// Filter height / width (pixels).
    pub filt_h: u64,
    pub filt_w: u64,
    /// Input channels.
    pub channels: u64,
    /// Number of filters == OFMAP channels.
    pub num_filters: u64,
    /// Convolution stride (same in both dims, as in the original tool).
    pub stride: u64,
}

impl LayerShape {
    /// Plain convolution layer.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: &str,
        ifmap_h: u64,
        ifmap_w: u64,
        filt_h: u64,
        filt_w: u64,
        channels: u64,
        num_filters: u64,
        stride: u64,
    ) -> Self {
        LayerShape {
            name: name.to_string(),
            ifmap_h,
            ifmap_w,
            filt_h,
            filt_w,
            channels,
            num_filters,
            stride,
        }
    }

    /// GEMM `(m,k) @ (k,n)` encoded as a conv layer (§III-A).
    pub fn gemm(name: &str, m: u64, k: u64, n: u64) -> Self {
        LayerShape::conv(name, m, 1, 1, 1, k, n, 1)
    }

    /// Fully-connected layer: batch x in_features -> out_features (MV/MM).
    pub fn fc(name: &str, batch: u64, in_features: u64, out_features: u64) -> Self {
        LayerShape::gemm(name, batch, in_features, out_features)
    }

    /// Validate invariants; call after parsing user input.
    pub fn validate(&self) -> Result<()> {
        let bad = |reason: &str| Error::InvalidLayer {
            name: self.name.clone(),
            reason: reason.to_string(),
        };
        if self.ifmap_h == 0
            || self.ifmap_w == 0
            || self.filt_h == 0
            || self.filt_w == 0
            || self.channels == 0
            || self.num_filters == 0
        {
            return Err(bad("all dimensions must be positive"));
        }
        if self.stride == 0 {
            return Err(bad("stride must be positive"));
        }
        if self.filt_h > self.ifmap_h || self.filt_w > self.ifmap_w {
            return Err(bad("filter larger than ifmap (valid padding assumed)"));
        }
        Ok(())
    }

    /// OFMAP height: `(H - R)/stride + 1` (valid padding).
    pub fn ofmap_h(&self) -> u64 {
        (self.ifmap_h - self.filt_h) / self.stride + 1
    }

    /// OFMAP width.
    pub fn ofmap_w(&self) -> u64 {
        (self.ifmap_w - self.filt_w) / self.stride + 1
    }

    /// Output pixels per OFMAP channel (`Npx = Eh * Ew`).
    pub fn npx(&self) -> u64 {
        self.ofmap_h() * self.ofmap_w()
    }

    /// Convolution-window size `K = R*S*C` — MACs per output pixel, and
    /// the contraction dimension of the GEMM view.
    pub fn window(&self) -> u64 {
        self.filt_h * self.filt_w * self.channels
    }

    /// Total MAC operations in the layer.
    pub fn macs(&self) -> u64 {
        self.npx() * self.window() * self.num_filters
    }

    /// Unique IFMAP elements (= words; 1 byte/word by default config).
    pub fn ifmap_elems(&self) -> u64 {
        self.ifmap_h * self.ifmap_w * self.channels
    }

    /// Unique filter elements across all filters.
    pub fn filter_elems(&self) -> u64 {
        self.window() * self.num_filters
    }

    /// Unique OFMAP elements.
    pub fn ofmap_elems(&self) -> u64 {
        self.npx() * self.num_filters
    }

    /// GEMM view `(M, K, N) = (Npx, window, num_filters)` — the operand
    /// matrix dimensions every dataflow schedules.
    pub fn gemm_view(&self) -> (u64, u64, u64) {
        (self.npx(), self.window(), self.num_filters)
    }

    /// True if this layer is a pure GEMM encoding (1x1 filter, W=1).
    pub fn is_gemm(&self) -> bool {
        self.filt_h == 1 && self.filt_w == 1 && self.ifmap_w == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resnet_conv1() -> LayerShape {
        LayerShape::conv("conv1", 224, 224, 7, 7, 3, 64, 2)
    }

    #[test]
    fn ofmap_dims_valid_padding() {
        let l = resnet_conv1();
        assert_eq!(l.ofmap_h(), 109); // (224-7)/2+1
        assert_eq!(l.ofmap_w(), 109);
        assert_eq!(l.npx(), 109 * 109);
    }

    #[test]
    fn window_and_macs() {
        let l = resnet_conv1();
        assert_eq!(l.window(), 7 * 7 * 3);
        assert_eq!(l.macs(), 109 * 109 * 147 * 64);
    }

    #[test]
    fn gemm_encoding_round_trips() {
        let g = LayerShape::gemm("g", 32, 147, 64);
        assert!(g.is_gemm());
        assert_eq!(g.gemm_view(), (32, 147, 64));
        assert_eq!(g.macs(), 32 * 147 * 64);
        assert_eq!(g.npx(), 32);
        g.validate().unwrap();
    }

    #[test]
    fn fc_is_mv_when_batch_one() {
        let f = LayerShape::fc("fc", 1, 2048, 1000);
        assert_eq!(f.gemm_view(), (1, 2048, 1000));
    }

    #[test]
    fn operand_footprints() {
        let l = LayerShape::conv("c", 8, 8, 3, 3, 4, 16, 1);
        assert_eq!(l.ifmap_elems(), 8 * 8 * 4);
        assert_eq!(l.filter_elems(), 3 * 3 * 4 * 16);
        assert_eq!(l.ofmap_elems(), 36 * 16);
    }

    #[test]
    fn validate_rejects_zero_dims() {
        let mut l = resnet_conv1();
        l.channels = 0;
        assert!(l.validate().is_err());
    }

    #[test]
    fn validate_rejects_filter_bigger_than_ifmap() {
        let l = LayerShape::conv("c", 4, 4, 5, 5, 1, 1, 1);
        assert!(l.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_stride() {
        let mut l = resnet_conv1();
        l.stride = 0;
        assert!(l.validate().is_err());
    }

    #[test]
    fn stride_equal_filter_nonoverlapping() {
        let l = LayerShape::conv("pool-ish", 8, 8, 2, 2, 1, 1, 2);
        assert_eq!(l.npx(), 16);
    }
}
