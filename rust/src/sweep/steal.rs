//! Work-stealing deques: the crate's scheduling primitive.
//!
//! Each worker owns one double-ended lane. The owner pushes and pops at
//! the **back** (LIFO — the hot end: freshly spawned work is cache-warm
//! and, for batch envelopes, the most recently split task), while idle
//! workers **steal from the front** of other lanes (FIFO — the oldest,
//! coarsest work migrates, which keeps steal traffic low). This is the
//! classic Cilk/Arora-Blumofe-Plaxton discipline, implemented std-only:
//! one `Mutex<VecDeque>` per lane instead of a lock-free Chase-Lev
//! array, because every task in this crate is a whole layer/job
//! simulation — microseconds to milliseconds — so the scheduler's job
//! is load balance, not nanosecond push/pop latency.
//!
//! Used by [`super::parallel_map`] (sweeps, dse local exec, engine
//! runs). Steal counts are wall-class observability (scheduling
//! artifacts, never part of deterministic output).

use std::collections::VecDeque;
use std::sync::{Mutex, MutexGuard};

/// A set of per-worker double-ended task lanes (see module docs).
pub struct Deques<T> {
    lanes: Vec<Mutex<VecDeque<T>>>,
}

impl<T> Deques<T> {
    /// Build `lanes` empty lanes (clamped to >= 1).
    pub fn new(lanes: usize) -> Self {
        Deques { lanes: (0..lanes.max(1)).map(|_| Mutex::new(VecDeque::new())).collect() }
    }

    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    fn lane(&self, i: usize) -> MutexGuard<'_, VecDeque<T>> {
        self.lanes[i].lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Owner push: append to the back of `lane`'s deque.
    pub fn push(&self, lane: usize, item: T) {
        self.lane(lane % self.lanes.len()).push_back(item);
    }

    /// Owner pop: take the newest item from own lane (LIFO).
    pub fn pop(&self, lane: usize) -> Option<T> {
        self.lane(lane % self.lanes.len()).pop_back()
    }

    /// Thief pop: scan the other lanes round-robin starting after
    /// `thief`, taking the **oldest** item of the first non-empty one
    /// (FIFO). Returns `None` only when every other lane was observed
    /// empty during the scan.
    pub fn steal(&self, thief: usize) -> Option<T> {
        let n = self.lanes.len();
        let thief = thief % n;
        for step in 1..n {
            let victim = (thief + step) % n;
            if let Some(item) = self.lane(victim).pop_front() {
                return Some(item);
            }
        }
        None
    }

    /// True when every lane was observed empty (racy by nature: only
    /// meaningful once producers have stopped pushing).
    pub fn is_empty(&self) -> bool {
        (0..self.lanes.len()).all(|i| self.lane(i).is_empty())
    }

    /// Total queued items across lanes (racy snapshot, same caveat).
    pub fn len(&self) -> usize {
        (0..self.lanes.len()).map(|i| self.lane(i).len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn owner_is_lifo_thief_is_fifo() {
        let d: Deques<u32> = Deques::new(2);
        for v in [1, 2, 3] {
            d.push(0, v);
        }
        // thief (lane 1) sees the oldest first
        assert_eq!(d.steal(1), Some(1));
        // owner sees the newest first
        assert_eq!(d.pop(0), Some(3));
        assert_eq!(d.pop(0), Some(2));
        assert_eq!(d.pop(0), None);
        assert!(d.is_empty());
    }

    #[test]
    fn steal_scans_round_robin_and_skips_own_lane() {
        let d: Deques<u32> = Deques::new(3);
        d.push(2, 42);
        // lane 0's thief must reach lane 2 even with lane 1 empty
        assert_eq!(d.steal(0), Some(42));
        // a thief never steals from itself: only lane 1 has work now
        d.push(1, 7);
        assert_eq!(d.steal(1), None);
        assert_eq!(d.pop(1), Some(7));
    }

    #[test]
    fn lane_count_clamps_and_indices_wrap() {
        let d: Deques<u8> = Deques::new(0);
        assert_eq!(d.lanes(), 1);
        d.push(5, 9); // wraps onto lane 0
        assert_eq!(d.pop(0), Some(9));
        // single lane: nothing to steal, ever
        d.push(0, 1);
        assert_eq!(d.steal(0), None);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn no_task_lost_none_run_twice_under_contention() {
        const TASKS: usize = 2000;
        const WORKERS: usize = 8;
        let d: Deques<usize> = Deques::new(WORKERS);
        for i in 0..TASKS {
            d.push(i % WORKERS, i);
        }
        let runs: Vec<AtomicUsize> = (0..TASKS).map(|_| AtomicUsize::new(0)).collect();
        let barrier = Barrier::new(WORKERS);
        std::thread::scope(|s| {
            for w in 0..WORKERS {
                let (d, runs, barrier) = (&d, &runs, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    while let Some(i) = d.pop(w).or_else(|| d.steal(w)) {
                        runs[i].fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        for (i, r) in runs.iter().enumerate() {
            assert_eq!(r.load(Ordering::SeqCst), 1, "task {i} ran a wrong number of times");
        }
        assert!(d.is_empty());
    }

    #[test]
    fn starved_workers_drain_a_loaded_lane_by_stealing() {
        // every task lands on lane 0; the other workers have nothing
        // and must steal to contribute
        const TASKS: usize = 400;
        const WORKERS: usize = 4;
        let d: Deques<usize> = Deques::new(WORKERS);
        for i in 0..TASKS {
            d.push(0, i);
        }
        let done = AtomicUsize::new(0);
        let stolen = AtomicUsize::new(0);
        let barrier = Barrier::new(WORKERS);
        std::thread::scope(|s| {
            for w in 0..WORKERS {
                let (d, done, stolen, barrier) = (&d, &done, &stolen, &barrier);
                s.spawn(move || {
                    barrier.wait();
                    loop {
                        let own = d.pop(w);
                        let task = match own {
                            Some(t) => Some(t),
                            None => {
                                let t = d.steal(w);
                                if t.is_some() {
                                    stolen.fetch_add(1, Ordering::SeqCst);
                                }
                                t
                            }
                        };
                        match task {
                            Some(_) => {
                                // simulate real work so thieves overlap
                                std::thread::sleep(std::time::Duration::from_micros(100));
                                done.fetch_add(1, Ordering::SeqCst);
                            }
                            None => break,
                        }
                    }
                });
            }
        });
        assert_eq!(done.load(Ordering::SeqCst), TASKS, "every task must complete");
        assert!(
            stolen.load(Ordering::SeqCst) > 0,
            "starved workers must have stolen from the loaded lane"
        );
        assert!(d.is_empty());
    }
}
