//! Thread pool + legacy design-space sweep shims (§IV methodology).
//!
//! [`parallel_map`] is a work-stealing scheduler over
//! `std::thread::scope` (tokio/rayon are unavailable offline): tasks are
//! distributed round-robin onto per-worker deques ([`steal::Deques`]),
//! each worker drains its own lane LIFO and steals the oldest task from
//! a loaded peer when idle, so a skewed load (one huge layer next to
//! many small ones) keeps every core busy. It is the execution substrate
//! for the legacy functions here, the engine's
//! [`crate::engine::SweepGrid`], [`crate::engine::Engine::run`], and dse
//! local execution. (The serve pool gets its concurrency from the
//! shared job queue instead: batch envelopes are split into
//! independently-admitted queue entries, [`crate::server`].)
//!
//! The typed sweep functions (`dataflow_sweep` / `memory_sweep` /
//! `shape_sweep`) are retained as **deprecated shims** over the engine's
//! memoizing grid: they produce byte-identical point lists to their
//! historical implementations (asserted by the equivalence suite) while
//! sharing layer simulations through the engine cache. New code should
//! build grids directly:
//!
//! ```text
//! Engine::new(base).sweep()
//!     .workloads(&topos).dataflows(&Dataflow::ALL)
//!     .square_arrays(&[128, 64, 32, 16, 8])
//!     .run()
//! ```

pub mod steal;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::{ArchConfig, Topology};
use crate::dataflow::Dataflow;
use crate::engine::Engine;

/// Map `f` over `items` on `threads` OS threads, preserving order.
///
/// Tasks start round-robin on per-worker deques; a worker that drains
/// its own lane steals the oldest task from a peer (module docs), so a
/// skewed cost distribution cannot strand work behind one slow lane.
/// The result order — and therefore every downstream report — is
/// independent of the steal schedule: results are keyed by input index
/// and reassembled in order. Steal counts feed a wall-class metric.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let deques: steal::Deques<usize> = steal::Deques::new(threads);
    for i in 0..n {
        deques.push(i % threads, i);
    }
    let collected = std::sync::Mutex::new(Vec::with_capacity(n));
    let steals = AtomicU64::new(0);
    std::thread::scope(|s| {
        for w in 0..threads {
            let deques = &deques;
            let f = &f;
            let collected = &collected;
            let steals = &steals;
            s.spawn(move || {
                let mut local = Vec::new();
                let mut local_steals = 0u64;
                loop {
                    let task = deques.pop(w).or_else(|| {
                        let t = deques.steal(w);
                        if t.is_some() {
                            local_steals += 1;
                        }
                        t
                    });
                    match task {
                        Some(i) => local.push((i, f(&items[i]))),
                        None => break,
                    }
                }
                if local_steals > 0 {
                    steals.fetch_add(local_steals, Ordering::Relaxed);
                }
                collected
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .extend(local);
            });
        }
    });
    let stolen = steals.load(Ordering::Relaxed);
    if stolen > 0 {
        crate::obs::metrics::count_steals(stolen);
    }
    let mut pairs = collected.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
    pairs.sort_by_key(|(i, _)| *i);
    debug_assert_eq!(pairs.len(), n);
    pairs.into_iter().map(|(_, r)| r).collect()
}

/// Default worker count: physical parallelism minus one, at least 1.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(1)
}

/// One point of the Fig 5/6 sweep: workload x dataflow x square array.
#[derive(Clone, Debug)]
pub struct DataflowPoint {
    pub workload: String,
    pub dataflow: Dataflow,
    pub array: u64,
    pub cycles: u64,
    pub utilization: f64,
    pub energy_compute_mj: f64,
    pub energy_memory_mj: f64,
}

/// Fig 5 + Fig 6 sweep: every workload under every dataflow on square
/// arrays of the given dimensions.
#[deprecated(
    since = "0.2.0",
    note = "use Engine::sweep().workloads(..).dataflows(..).square_arrays(..).run()"
)]
pub fn dataflow_sweep(
    base: &ArchConfig,
    topos: &[Topology],
    arrays: &[u64],
    threads: usize,
) -> Vec<DataflowPoint> {
    let engine = Engine::new(base.clone());
    let out = engine
        .sweep()
        .workloads(topos)
        .dataflows(&Dataflow::ALL)
        .square_arrays(arrays)
        .threads(threads)
        .run();
    out.points
        .into_iter()
        .map(|p| {
            let e = p.report.total_energy();
            DataflowPoint {
                workload: p.workload,
                dataflow: p.dataflow,
                array: p.array_h,
                cycles: p.report.total_cycles(),
                utilization: p.report.overall_utilization(p.array_h * p.array_w),
                energy_compute_mj: e.compute_mj,
                energy_memory_mj: e.memory_mj(),
            }
        })
        .collect()
}

/// One point of the Fig 7 sweep: workload x scratchpad size.
#[derive(Clone, Debug)]
pub struct MemoryPoint {
    pub workload: String,
    pub sram_kb: u64,
    pub avg_read_bw: f64,
    pub dram_bytes: u64,
}

/// Fig 7 sweep: DRAM bandwidth requirement vs per-operand scratchpad
/// size (the paper sweeps 32KB..2048KB for each of filter+IFMAP).
#[deprecated(
    since = "0.2.0",
    note = "use Engine::sweep().workloads(..).sram_sizes_kb(..).run()"
)]
pub fn memory_sweep(
    base: &ArchConfig,
    topos: &[Topology],
    sram_kbs: &[u64],
    threads: usize,
) -> Vec<MemoryPoint> {
    let engine = Engine::new(base.clone());
    let out = engine
        .sweep()
        .workloads(topos)
        .sram_sizes_kb(sram_kbs)
        .threads(threads)
        .run();
    out.points
        .into_iter()
        .map(|p| MemoryPoint {
            workload: p.workload,
            sram_kb: p.ifmap_sram_kb,
            avg_read_bw: p.report.avg_dram_read_bw(),
            dram_bytes: p.report.total_dram().total(),
        })
        .collect()
}

/// One point of the Fig 8 sweep: workload x dataflow x aspect ratio.
#[derive(Clone, Debug)]
pub struct ShapePoint {
    pub workload: String,
    pub dataflow: Dataflow,
    pub rows: u64,
    pub cols: u64,
    pub cycles: u64,
}

/// Fig 8 sweep: fixed PE count, shapes from tall to wide.
#[deprecated(
    since = "0.2.0",
    note = "use Engine::sweep().workloads(..).dataflows(..).array_shapes(..).run()"
)]
pub fn shape_sweep(
    base: &ArchConfig,
    topos: &[Topology],
    shapes: &[(u64, u64)],
    threads: usize,
) -> Vec<ShapePoint> {
    let engine = Engine::new(base.clone());
    let out = engine
        .sweep()
        .workloads(topos)
        .dataflows(&Dataflow::ALL)
        .array_shapes(shapes)
        .threads(threads)
        .run();
    out.points
        .into_iter()
        .map(|p| ShapePoint {
            workload: p.workload,
            dataflow: p.dataflow,
            rows: p.array_h,
            cols: p.array_w,
            cycles: p.report.total_cycles(),
        })
        .collect()
}

/// The paper's Fig 8 shape ladder: 8x2048 .. 2048x8 (16384 PEs).
pub fn fig8_shapes() -> Vec<(u64, u64)> {
    let mut v = Vec::new();
    let mut r = 8u64;
    while r <= 2048 {
        v.push((r, 16384 / r));
        r *= 2;
    }
    v
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config;

    fn topo() -> Topology {
        Topology::new("t", vec![LayerShape::conv("c", 16, 16, 3, 3, 4, 8, 1)])
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<u64> = (0..1000).collect();
        let out = parallel_map(&items, 8, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_handles_empty_and_single() {
        assert!(parallel_map::<u64, u64, _>(&[], 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u64], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn parallel_map_survives_a_skewed_load() {
        // one expensive item among many cheap ones: stealing must not
        // lose, duplicate, or reorder results
        let items: Vec<u64> = (0..64).collect();
        let out = parallel_map(&items, 4, |&x| {
            if x == 0 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            x * 3
        });
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_matches_serial() {
        let base = config::paper_default();
        let topos = vec![topo()];
        let serial = dataflow_sweep(&base, &topos, &[8, 16], 1);
        let par = dataflow_sweep(&base, &topos, &[8, 16], 4);
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.dataflow, b.dataflow);
        }
    }

    #[test]
    fn fig8_shapes_conserve_pes() {
        let shapes = fig8_shapes();
        assert_eq!(shapes.first(), Some(&(8, 2048)));
        assert_eq!(shapes.last(), Some(&(2048, 8)));
        assert!(shapes.iter().all(|&(r, c)| r * c == 16384));
        assert_eq!(shapes.len(), 9);
    }

    #[test]
    fn memory_sweep_bw_nonincreasing() {
        let base = config::paper_default();
        let topos = vec![topo()];
        let pts = memory_sweep(&base, &topos, &[1, 8, 64, 512], 2);
        for w in pts.windows(2) {
            assert!(w[1].dram_bytes <= w[0].dram_bytes);
        }
    }
}
