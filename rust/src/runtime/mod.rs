//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) from Rust — the Layer-3 side of the
//! three-layer architecture. Python never runs here; `make artifacts`
//! produced HLO text once, and this module compiles it on the embedded
//! PJRT CPU client and executes it on the request path.
//!
//! The interchange format is HLO *text* (not serialized protos): jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects,
//! while the text parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).
//!
//! The flagship entry point is [`Runtime::tiled_gemm`]: execute an
//! arbitrary GEMM by scheduling the AOT'd array-sized systolic kernel
//! tile-by-tile in **the same fold order the simulator timed** — the
//! functional counterpart of [`crate::trace::fold_schedule`], used by the
//! e2e example and the `--functional` CLI mode to prove the mapping the
//! simulator models computes the right numbers.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

fn rt_err<E: std::fmt::Display>(ctx: &str) -> impl FnOnce(E) -> Error + '_ {
    move |e| Error::Runtime(format!("{ctx}: {e}"))
}

/// A loaded, compiled artifact.
struct LoadedExe {
    exe: xla::PjRtLoadedExecutable,
}

/// PJRT CPU client + compiled artifact cache.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, LoadedExe>,
    dir: PathBuf,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(rt_err("PjRtClient::cpu"))?;
        Ok(Runtime { client, exes: HashMap::new(), dir: artifact_dir.to_path_buf() })
    }

    /// Platform string of the underlying PJRT client (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` from the artifact dir (cached).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {path:?} missing — run `make artifacts` first"
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(rt_err("parse HLO text"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(rt_err("compile"))?;
        self.exes.insert(name.to_string(), LoadedExe { exe });
        Ok(())
    }

    /// Names of artifacts present on disk (sorted).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|n| n.strip_suffix(".hlo.txt").map(str::to_string))
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    /// Execute a loaded artifact on f32 inputs; returns the flattened
    /// first element of the (1-tuple) result.
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let le = self
            .exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact {name} not loaded")))?;
        let mut lits = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(shape)
                .map_err(rt_err("reshape input"))?;
            lits.push(lit);
        }
        let result = le.exe.execute::<xla::Literal>(&lits).map_err(rt_err("execute"))?[0][0]
            .to_literal_sync()
            .map_err(rt_err("to_literal"))?;
        // aot.py lowers with return_tuple=True => 1-tuple
        let out = result.to_tuple1().map_err(rt_err("untuple"))?;
        out.to_vec::<f32>().map_err(rt_err("to_vec"))
    }

    /// Execute the array-sized systolic GEMM artifact once:
    /// `(t x t) @ (t x t)` for tile size `t` in {8, 32, 128}.
    /// Loads (and caches) the artifact on first use.
    pub fn gemm_tile(&mut self, tile: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let name = format!("systolic_gemm_{tile}");
        self.load(&name)?;
        let t = tile as i64;
        self.execute_f32(&name, &[(a, &[t, t]), (b, &[t, t])])
    }

    /// Arbitrary `(m,k) @ (k,n)` GEMM executed tile-by-tile through the
    /// AOT'd systolic kernel, following the simulator's OS fold schedule
    /// (row folds outer, col folds inner, K streamed per fold).
    pub fn tiled_gemm(
        &mut self,
        tile: usize,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Vec<f32>> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        let name = format!("systolic_gemm_{tile}");
        self.load(&name)?;

        let fm = m.div_ceil(tile);
        let fn_ = n.div_ceil(tile);
        let fk = k.div_ceil(tile);
        let mut out = vec![0f32; m * n];
        let mut atile = vec![0f32; tile * tile];
        let mut btile = vec![0f32; tile * tile];

        // OS fold schedule: output tile (i,j) stationary, K streamed.
        for i in 0..fm {
            for j in 0..fn_ {
                let mut acc = vec![0f32; tile * tile];
                for kk in 0..fk {
                    // gather (zero-padded) operand tiles
                    atile.iter_mut().for_each(|x| *x = 0.0);
                    btile.iter_mut().for_each(|x| *x = 0.0);
                    for r in 0..tile.min(m - i * tile) {
                        for c in 0..tile.min(k - kk * tile) {
                            atile[r * tile + c] = a[(i * tile + r) * k + kk * tile + c];
                        }
                    }
                    for r in 0..tile.min(k - kk * tile) {
                        for c in 0..tile.min(n - j * tile) {
                            btile[r * tile + c] = b[(kk * tile + r) * n + j * tile + c];
                        }
                    }
                    let prod = self.gemm_tile(tile, &atile, &btile)?;
                    for (x, p) in acc.iter_mut().zip(&prod) {
                        *x += p;
                    }
                }
                for r in 0..tile.min(m - i * tile) {
                    for c in 0..tile.min(n - j * tile) {
                        out[(i * tile + r) * n + j * tile + c] = acc[r * tile + c];
                    }
                }
            }
        }
        Ok(out)
    }

    /// Execute an AOT conv artifact (NHWC x HWIO), returning NHWC out.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        name: &str,
        ifmap: &[f32],
        ifmap_shape: &[i64],
        filt: &[f32],
        filt_shape: &[i64],
    ) -> Result<Vec<f32>> {
        self.load(name)?;
        self.execute_f32(name, &[(ifmap, ifmap_shape), (filt, filt_shape)])
    }
}

/// Default artifact directory: `$SCALE_SIM_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("SCALE_SIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests here stay artifact-independent (integration tests in
    // rust/tests/runtime_integration.rs exercise real artifacts, and
    // skip with a notice when `make artifacts` has not run).

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let dir = std::env::temp_dir().join("scale_sim_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).expect("CPU client");
        let err = rt.load("systolic_gemm_8").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn available_lists_hlo_files_only() {
        let dir = std::env::temp_dir().join(format!("scale_sim_avail_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("b.json"), "x").unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.available(), vec!["a".to_string()]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn platform_is_cpu() {
        let rt = Runtime::new(Path::new(".")).unwrap();
        assert_eq!(rt.platform().to_lowercase(), "cpu");
    }
}
