//! Functional runtime for the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) — the Layer-3 side of the three-layer
//! architecture. Python never runs here: `make artifacts` produced HLO
//! text once, and this module executes the corresponding computations on
//! the request path.
//!
//! ## Offline software fallback
//!
//! The original implementation compiled the HLO text on an embedded PJRT
//! CPU client (`xla` crate). That dependency cannot be resolved in the
//! offline build, so this module ships a **software executor** with the
//! exact same interface contract: artifacts must exist on disk and be
//! `load`ed before execution (missing files produce the same
//! "make artifacts" error), and execution computes the same function the
//! artifact encodes — the array-sized systolic GEMM and the NHWC x HWIO
//! convolutions — via the validated in-repo references. Numerics are
//! checked against the RTL PE grid and an independent conv reference in
//! `rust/tests/runtime_integration.rs`, exactly as the PJRT path was.
//!
//! The flagship entry point is [`Runtime::tiled_gemm`]: execute an
//! arbitrary GEMM by scheduling the array-sized systolic kernel
//! tile-by-tile in **the same fold order the simulator timed** — the
//! functional counterpart of [`crate::trace::fold_schedule`], used by the
//! e2e example and the `--functional` CLI mode to prove the mapping the
//! simulator models computes the right numbers.

use std::collections::HashSet;
use std::path::{Path, PathBuf};

use crate::{Error, Result};

/// Artifact executor: directory of AOT artifacts + loaded-artifact cache.
pub struct Runtime {
    loaded: HashSet<String>,
    dir: PathBuf,
}

impl Runtime {
    /// Create an executor rooted at an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        Ok(Runtime { loaded: HashSet::new(), dir: artifact_dir.to_path_buf() })
    }

    /// Platform string of the underlying executor (always a CPU here).
    pub fn platform(&self) -> String {
        "cpu".to_string()
    }

    /// Load `<name>.hlo.txt` from the artifact dir (cached). The
    /// software fallback does not parse the HLO — presence of the
    /// artifact is the contract that `make artifacts` ran and the
    /// kernel's semantics are the ones this executor reproduces.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.loaded.contains(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {path:?} missing — run `make artifacts` first"
            )));
        }
        self.loaded.insert(name.to_string());
        Ok(())
    }

    /// Names of artifacts present on disk (sorted).
    pub fn available(&self) -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(&self.dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        e.file_name()
                            .to_str()
                            .and_then(|n| n.strip_suffix(".hlo.txt").map(str::to_string))
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    /// Execute a loaded artifact on f32 inputs; returns the flattened
    /// result. Supported artifact families: `systolic_gemm_<t>`
    /// (two `[t, t]` operands) and `conv_*` (NHWC ifmap x HWIO filter).
    pub fn execute_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        if !self.loaded.contains(name) {
            return Err(Error::Runtime(format!("artifact {name} not loaded")));
        }
        if let Some(tile) = name.strip_prefix("systolic_gemm_") {
            let [(a, ash), (b, bsh)] = inputs else {
                return Err(Error::Runtime(format!("{name}: expected 2 inputs")));
            };
            // the artifact is compiled for exactly (t x t) @ (t x t) —
            // enforce that, as the PJRT executable did
            let t: usize = tile
                .parse()
                .map_err(|_| Error::Runtime(format!("{name}: bad tile size")))?;
            let want = [t as i64, t as i64];
            if ash[..] != want[..] || bsh[..] != want[..] || a.len() != t * t || b.len() != t * t {
                return Err(Error::Runtime(format!(
                    "{name}: operands must be [{t}, {t}] x [{t}, {t}] (got {ash:?} x {bsh:?})"
                )));
            }
            return Ok(crate::rtl::matmul_ref(a, b, t, t, t));
        }
        if name.starts_with("conv") {
            let [(x, xsh), (f, fsh)] = inputs else {
                return Err(Error::Runtime(format!("{name}: expected 2 inputs")));
            };
            return conv_nhwc_hwio(x, xsh, f, fsh);
        }
        Err(Error::Runtime(format!(
            "software fallback cannot execute artifact {name:?} (gemm/conv only)"
        )))
    }

    /// Execute the array-sized systolic GEMM artifact once:
    /// `(t x t) @ (t x t)` for tile size `t` in {8, 32, 128}.
    /// Loads (and caches) the artifact on first use.
    pub fn gemm_tile(&mut self, tile: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let name = format!("systolic_gemm_{tile}");
        self.load(&name)?;
        let t = tile as i64;
        self.execute_f32(&name, &[(a, &[t, t]), (b, &[t, t])])
    }

    /// Arbitrary `(m,k) @ (k,n)` GEMM executed tile-by-tile through the
    /// systolic kernel, following the simulator's OS fold schedule
    /// (row folds outer, col folds inner, K streamed per fold).
    pub fn tiled_gemm(
        &mut self,
        tile: usize,
        a: &[f32],
        b: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) -> Result<Vec<f32>> {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        let name = format!("systolic_gemm_{tile}");
        self.load(&name)?;

        let fm = m.div_ceil(tile);
        let fn_ = n.div_ceil(tile);
        let fk = k.div_ceil(tile);
        let mut out = vec![0f32; m * n];
        let mut atile = vec![0f32; tile * tile];
        let mut btile = vec![0f32; tile * tile];

        // OS fold schedule: output tile (i,j) stationary, K streamed.
        for i in 0..fm {
            for j in 0..fn_ {
                let mut acc = vec![0f32; tile * tile];
                for kk in 0..fk {
                    // gather (zero-padded) operand tiles
                    atile.iter_mut().for_each(|x| *x = 0.0);
                    btile.iter_mut().for_each(|x| *x = 0.0);
                    for r in 0..tile.min(m - i * tile) {
                        for c in 0..tile.min(k - kk * tile) {
                            atile[r * tile + c] = a[(i * tile + r) * k + kk * tile + c];
                        }
                    }
                    for r in 0..tile.min(k - kk * tile) {
                        for c in 0..tile.min(n - j * tile) {
                            btile[r * tile + c] = b[(kk * tile + r) * n + j * tile + c];
                        }
                    }
                    let prod = self.gemm_tile(tile, &atile, &btile)?;
                    for (x, p) in acc.iter_mut().zip(&prod) {
                        *x += p;
                    }
                }
                for r in 0..tile.min(m - i * tile) {
                    for c in 0..tile.min(n - j * tile) {
                        out[(i * tile + r) * n + j * tile + c] = acc[r * tile + c];
                    }
                }
            }
        }
        Ok(out)
    }

    /// Execute an AOT conv artifact (NHWC x HWIO), returning NHWC out.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        &mut self,
        name: &str,
        ifmap: &[f32],
        ifmap_shape: &[i64],
        filt: &[f32],
        filt_shape: &[i64],
    ) -> Result<Vec<f32>> {
        self.load(name)?;
        self.execute_f32(name, &[(ifmap, ifmap_shape), (filt, filt_shape)])
    }
}

/// Valid-padding, stride-1 NHWC x HWIO convolution (the semantics the
/// conv artifacts were lowered with).
fn conv_nhwc_hwio(x: &[f32], xsh: &[i64], f: &[f32], fsh: &[i64]) -> Result<Vec<f32>> {
    if xsh.len() != 4 || fsh.len() != 4 || xsh[0] != 1 {
        return Err(Error::Runtime("conv expects NHWC [1,h,w,c] x HWIO [r,s,c,m]".into()));
    }
    let (h, w, c) = (xsh[1] as usize, xsh[2] as usize, xsh[3] as usize);
    let (r, s, fc, m) = (fsh[0] as usize, fsh[1] as usize, fsh[2] as usize, fsh[3] as usize);
    if fc != c || x.len() != h * w * c || f.len() != r * s * c * m || r > h || s > w {
        return Err(Error::Runtime("conv operand shape mismatch".into()));
    }
    let (eh, ew) = (h - r + 1, w - s + 1);
    let mut out = vec![0f32; eh * ew * m];
    for oy in 0..eh {
        for ox in 0..ew {
            for dr in 0..r {
                for ds in 0..s {
                    for ch in 0..c {
                        let xv = x[((oy + dr) * w + ox + ds) * c + ch];
                        let fbase = ((dr * s + ds) * c + ch) * m;
                        let obase = (oy * ew + ox) * m;
                        for dm in 0..m {
                            out[obase + dm] += xv * f[fbase + dm];
                        }
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Default artifact directory: `$SCALE_SIM_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("SCALE_SIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Unit tests here stay artifact-independent (integration tests in
    // rust/tests/runtime_integration.rs exercise real artifacts, and
    // skip with a notice when `make artifacts` has not run).

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let dir = std::env::temp_dir().join("scale_sim_no_artifacts");
        std::fs::create_dir_all(&dir).unwrap();
        let mut rt = Runtime::new(&dir).expect("CPU client");
        let err = rt.load("systolic_gemm_8").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn available_lists_hlo_files_only() {
        let dir = std::env::temp_dir().join(format!("scale_sim_avail_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("b.json"), "x").unwrap();
        let rt = Runtime::new(&dir).unwrap();
        assert_eq!(rt.available(), vec!["a".to_string()]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn platform_is_cpu() {
        let rt = Runtime::new(Path::new(".")).unwrap();
        assert_eq!(rt.platform().to_lowercase(), "cpu");
    }

    #[test]
    fn executing_unloaded_artifact_errors() {
        let rt = Runtime::new(Path::new(".")).unwrap();
        assert!(rt.execute_f32("systolic_gemm_8", &[]).is_err());
    }

    #[test]
    fn software_gemm_matches_reference_when_artifact_present() {
        let dir = std::env::temp_dir().join(format!("scale_sim_sw_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("systolic_gemm_8.hlo.txt"), "HloModule stub").unwrap();
        let mut rt = Runtime::new(&dir).unwrap();
        let (a, b) = crate::rtl::random_matrices(8, 8, 8, 1);
        let got = rt.gemm_tile(8, &a, &b).unwrap();
        let want = crate::rtl::matmul_ref(&a, &b, 8, 8, 8);
        assert_eq!(got, want);
        // the artifact only accepts its compiled [8,8]x[8,8] shape
        assert!(rt.execute_f32("systolic_gemm_8", &[(&a, &[4, 16]), (&b, &[16, 4])]).is_err());
        // ragged tiled gemm through the same kernel
        let (a, b) = crate::rtl::random_matrices(5, 11, 7, 2);
        let got = rt.tiled_gemm(8, &a, &b, 5, 11, 7).unwrap();
        let want = crate::rtl::matmul_ref(&a, &b, 5, 11, 7);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-4);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
