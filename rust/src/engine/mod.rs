//! The unified simulation engine — **the** public entry point for every
//! simulation the crate performs (single runs, design-space sweeps, and
//! validation), introduced to replace the three historical entry points
//! (`sim::Simulator`, `coordinator::run`, the `sweep::*_sweep`
//! functions), which remain as thin deprecated shims over this module.
//!
//! Three pieces compose:
//!
//! * [`EngineBuilder`] — fluent configuration: architecture overrides,
//!   worker threads, output directory/trace dumping, functional
//!   cross-checking, energy model, and the fidelity [`Backend`].
//! * [`Backend`] — pluggable per-layer timing models (analytical closed
//!   forms, cycle-accurate trace generation, cycle-level RTL), all
//!   cycle-exact with each other; see [`backend`] for the contract.
//! * [`SweepGrid`] — cartesian design-space sweeps with engine-lifetime
//!   **memoization of per-(config, layer-shape) results** (see [`cache`]
//!   for the key semantics): grid points sharing layers never
//!   re-simulate, which is a direct wall-clock win on the paper's
//!   Fig 5-8 suites where repeated ResNet/AlphaGoZero/Transformer block
//!   shapes dominate (>50% hit rates).
//!
//! ```text
//! let engine = Engine::builder()
//!     .dataflow(Dataflow::Os)
//!     .array(128, 128)
//!     .build()?;
//! let outcome = engine.sweep()
//!     .workloads(&workloads::mlperf_suite())
//!     .dataflows(&Dataflow::ALL)
//!     .square_arrays(&[128, 64, 32, 16, 8])
//!     .run();
//! println!("hit rate {:.0}%", outcome.stats.hit_rate() * 100.0);
//! ```

pub mod backend;
pub(crate) mod cache;
pub mod fabric;
pub mod grid;
pub mod multi;

pub use backend::{Analytical, Backend, BackendKind, Rtl, TraceDriven};
pub use cache::{MemoStats, WarmStats};
pub use fabric::{FabricConfig, FabricKind, FabricLayerReport, DEFAULT_LINK_BW};
pub use grid::{SweepGrid, SweepOutcome, SweepPoint, SweepStats};
pub use multi::{
    MultiArrayConfig, MultiLayerReport, MultiOpts, MultiWorkloadReport, Partition,
    ScaleComparison,
};

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::arch::LayerShape;
use crate::config::{ArchConfig, Topology};
use crate::energy::EnergyModel;
use crate::memory;
use crate::report;
use crate::sim::flex::{FlexLayer, FlexReport};
use crate::sim::{LayerReport, WorkloadReport};
use crate::sweep::parallel_map;
use crate::trace::{self, Access};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::{Dataflow, Error, Result};

use cache::{CacheKey, LayerCache};

/// Outcome of one coordinated run ([`Engine::run`]): the report plus
/// whatever side artifacts were requested.
#[derive(Debug)]
pub struct RunOutcome {
    pub report: WorkloadReport,
    /// (layer, max abs error) per functionally-checked layer.
    pub functional: Vec<(String, f32)>,
    pub files_written: Vec<PathBuf>,
}

/// Opaque, cloneable (`Arc`-based) handle to a memo table. Obtained from
/// [`Engine::cache_handle`] and installable into another engine via
/// [`EngineBuilder::shared_cache`], so several engines — or a long-lived
/// server and the engine it rebuilds after a config reload — share one
/// table of (config, layer-shape) results. The handle only exposes
/// read-side statistics; mutation goes through an owning engine.
///
/// The handle remembers the owning engine's [`EnergyModel`]: cached
/// reports embed energy numbers, and the energy model is deliberately
/// *not* part of the cache key, so `build()` rejects sharing across
/// engines with different energy models.
#[derive(Clone)]
pub struct CacheHandle {
    cache: Arc<LayerCache>,
    energy: EnergyModel,
    /// Owner used a custom backend: all custom backends key as
    /// [`BackendKind::Custom`], so sharing across them would collide.
    custom: bool,
}

impl CacheHandle {
    /// Lifetime memoization counters of the shared table.
    pub fn stats(&self) -> MemoStats {
        self.cache.stats()
    }

    /// Warm-start accounting (prewarmed entries + hits they served).
    pub fn warm_stats(&self) -> WarmStats {
        self.cache.warm_stats()
    }

    /// Distinct ready entries in the shared table.
    pub fn entries(&self) -> usize {
        self.cache.entries()
    }
}

/// Federation hook: consulted **before** the local memo path on every
/// layer lookup. A router may serve the report from somewhere else (a
/// peer serve instance that owns the key's hash range); returning `None`
/// falls through to the normal local cache/compute path, which is also
/// the failover when a peer is unreachable. Implementations receive the
/// key's deterministic [`cache::memo_hash`] so every process in a fleet
/// agrees on ownership without coordination.
///
/// Routed reports are **never inserted into the local cache** — the
/// router routes keys, not values (docs/INVARIANTS.md §11) — so local
/// memo statistics count only local work.
pub trait LayerRouter: Send + Sync {
    fn route(&self, key_hash: u64, cfg: &ArchConfig, layer: &LayerShape) -> Option<LayerReport>;
}

/// The simulation engine: one base architecture + energy model + fidelity
/// backend + memo cache, shared across runs and sweeps.
pub struct Engine {
    cfg: ArchConfig,
    energy_model: EnergyModel,
    kind: BackendKind,
    backend: Box<dyn Backend>,
    threads: usize,
    out_dir: Option<PathBuf>,
    dump_traces: bool,
    trace_limit: u64,
    functional_tile: Option<usize>,
    cache: Arc<LayerCache>,
    router: Option<Arc<dyn LayerRouter>>,
}

impl Engine {
    /// Analytical engine over `cfg` with every option at its default —
    /// the drop-in equivalent of the old `Simulator::new`.
    pub fn new(cfg: ArchConfig) -> Engine {
        EngineBuilder::default().config(cfg).build_unchecked()
    }

    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Wrap the engine for concurrent shared use (`Engine` is `Sync`;
    /// backends are `Send + Sync` by trait bound). This is what the
    /// serve subsystem hands its worker pool: every worker simulates
    /// through the same engine, so every request shares one memo table.
    pub fn shared(self) -> Arc<Engine> {
        Arc::new(self)
    }

    /// Cloneable handle to this engine's memo table — installable into a
    /// future engine via [`EngineBuilder::shared_cache`].
    pub fn cache_handle(&self) -> CacheHandle {
        CacheHandle {
            cache: Arc::clone(&self.cache),
            energy: self.energy_model,
            custom: self.kind == BackendKind::Custom,
        }
    }

    pub fn cfg(&self) -> &ArchConfig {
        &self.cfg
    }

    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn energy_model(&self) -> &EnergyModel {
        &self.energy_model
    }

    /// Engine-lifetime memoization counters.
    pub fn cache_stats(&self) -> MemoStats {
        self.cache.stats()
    }

    /// Distinct (config, layer-shape) entries currently cached.
    pub fn cache_entries(&self) -> usize {
        self.cache.entries()
    }

    /// Warm-start accounting: entries preloaded from a persistent store
    /// and the hits they have served (see [`crate::server::store`]).
    pub fn warm_stats(&self) -> WarmStats {
        self.cache.warm_stats()
    }

    /// Crate-internal access for the server's result store (prewarm on
    /// startup, export on shutdown).
    pub(crate) fn layer_cache(&self) -> &LayerCache {
        &self.cache
    }

    /// Stripe count of the memo table (a lock-layout detail; it can
    /// never change results — docs/INVARIANTS.md §11).
    pub fn cache_stripe_count(&self) -> usize {
        self.cache.stripe_count()
    }

    /// Times a memo-table stripe lock was contended (wall-class).
    pub fn cache_contention(&self) -> u64 {
        self.cache.contention()
    }

    /// Simulate one layer under an arbitrary configuration (the grid's
    /// inner loop). Memoized; see [`cache`] for the key semantics. When
    /// a [`LayerRouter`] is installed it is consulted first — a routed
    /// report bypasses the local table entirely (keys a peer owns are
    /// never cached locally), and a `None` answer (self-owned key, or
    /// peer failover) takes the normal memoized path.
    pub fn run_layer_with(&self, cfg: &ArchConfig, layer: &LayerShape) -> LayerReport {
        let key = CacheKey::new(self.kind, cfg, layer);
        if let Some(router) = &self.router {
            if let Some(report) = router.route(cache::memo_hash(&key), cfg, layer) {
                return report;
            }
        }
        self.cache.get_or_compute(key, &layer.name, || {
            // wall-clock the miss path only (through the sanctioned
            // bench clock) and feed the per-backend latency histogram
            let (report, elapsed) = crate::util::bench::time(|| {
                let timing = self.backend.timing(cfg, layer);
                let (dram, bandwidth) = memory::simulate(cfg.dataflow, layer, cfg);
                let energy =
                    self.energy_model
                        .layer_energy(layer.macs(), &timing, &dram, cfg.word_bytes);
                LayerReport { layer: layer.clone(), timing, dram, bandwidth, energy }
            });
            crate::obs::metrics::observe_simulate_latency(self.kind.name(), elapsed);
            report
        })
    }

    /// Simulate one layer under the engine's base configuration.
    pub fn run_layer(&self, layer: &LayerShape) -> LayerReport {
        self.run_layer_with(&self.cfg, layer)
    }

    /// Simulate every layer of a topology in file order under an
    /// arbitrary configuration (§III-F: parallel branches serialize).
    pub fn run_topology_with(&self, cfg: &ArchConfig, topo: &Topology) -> WorkloadReport {
        WorkloadReport {
            workload: topo.name.clone(),
            layers: topo.layers.iter().map(|l| self.run_layer_with(cfg, l)).collect(),
        }
    }

    /// Simulate a topology under the engine's base configuration.
    pub fn run_topology(&self, topo: &Topology) -> WorkloadReport {
        self.run_topology_with(&self.cfg, topo)
    }

    /// Full coordinated run: parallel layer simulation, report files,
    /// optional cycle-accurate trace dumps, optional functional
    /// validation through the AOT artifacts — the engine-native form of
    /// the old `coordinator::run`.
    pub fn run(&self, topo: &Topology) -> Result<RunOutcome> {
        self.cfg.validate()?;
        let layers: Vec<LayerReport> =
            parallel_map(&topo.layers, self.threads, |l| self.run_layer(l));
        let report = WorkloadReport { workload: topo.name.clone(), layers };

        let mut files = Vec::new();
        if let Some(dir) = &self.out_dir {
            report::write_all(dir, &report, self.cfg.total_pes())?;
            for f in [
                "compute_report.csv",
                "sram_report.csv",
                "dram_report.csv",
                "energy_report.csv",
                "summary.md",
            ] {
                files.push(dir.join(f));
            }
            if self.dump_traces {
                files.extend(self.dump_traces_to(topo, dir)?);
            }
        }

        let functional = match self.functional_tile {
            Some(tile) => self.functional_check(topo, tile)?,
            None => Vec::new(),
        };

        Ok(RunOutcome { report, functional, files_written: files })
    }

    /// Lower a typed workload (operator IR, [`crate::workload`]) and run
    /// it end-to-end — the front-end form of [`Engine::run`].
    pub fn run_workload(&self, workload: &crate::workload::Workload) -> Result<RunOutcome> {
        self.run(&workload.lower()?)
    }

    /// Start building a memoizing design-space sweep over this engine.
    pub fn sweep(&self) -> SweepGrid<'_> {
        SweepGrid::new(self)
    }

    /// Flexible-dataflow study (§IV-B question 3) through the engine:
    /// every layer under all three dataflows, memoized.
    pub fn flexible_study(&self, topo: &Topology) -> FlexReport {
        let cfgs: Vec<ArchConfig> = Dataflow::ALL
            .iter()
            .map(|&df| ArchConfig { dataflow: df, ..self.cfg.clone() })
            .collect();
        let mut layers = Vec::with_capacity(topo.layers.len());
        let mut fixed = [0u64; 3];
        let mut flexible = 0u64;
        for layer in &topo.layers {
            let cycles: Vec<u64> = cfgs
                .iter()
                .map(|c| self.run_layer_with(c, layer).timing.cycles)
                .collect();
            let cycles = [cycles[0], cycles[1], cycles[2]];
            for (f, c) in fixed.iter_mut().zip(cycles) {
                *f += c;
            }
            // manual scan (no unwrap, R4): `<=` keeps min_by_key's
            // last-minimum tie-break, so `best` dataflows are unchanged
            let mut best_i = 0;
            for i in 1..3 {
                if cycles[i] <= cycles[best_i] {
                    best_i = i;
                }
            }
            flexible += cycles[best_i];
            layers.push(FlexLayer { name: layer.name.clone(), best: Dataflow::ALL[best_i], cycles });
        }
        FlexReport {
            workload: topo.name.clone(),
            layers,
            fixed_cycles: fixed,
            flexible_cycles: flexible,
        }
    }

    /// Write per-layer cycle-accurate SRAM traces: both the event-list
    /// form (`cycle,kind,addr`) and the original tool's per-port csv
    /// format (`<layer>_sram_read.csv` / `<layer>_sram_write.csv`,
    /// §III-F).
    fn dump_traces_to(&self, topo: &Topology, dir: &Path) -> Result<Vec<PathBuf>> {
        let tdir = dir.join("traces");
        std::fs::create_dir_all(&tdir)?;
        let mut out = Vec::new();
        for layer in &topo.layers {
            let mut w = CsvWriter::new(&["cycle", "kind", "address"]);
            let mut n = 0u64;
            trace::generate(self.cfg.dataflow, layer, &self.cfg, |cycle, access, addr| {
                if n >= self.trace_limit {
                    return;
                }
                n += 1;
                let kind = match access {
                    Access::IfmapRead => "ifmap_read",
                    Access::FilterRead => "filter_read",
                    Access::OfmapWrite => "ofmap_write",
                    Access::OfmapRead => "ofmap_read",
                };
                w.row(&[cycle.to_string(), kind.to_string(), addr.to_string()]);
            });
            let base = sanitize(&layer.name);
            let path = tdir.join(format!("{base}_sram_trace.csv"));
            w.write_to(&path)?;
            out.push(path);

            // original per-port format, bounded by the same event budget
            let max_cycles =
                (self.trace_limit / (self.cfg.array_h + self.cfg.array_w).max(1)) as usize;
            let pt = trace::port_trace(self.cfg.dataflow, layer, &self.cfg, max_cycles.max(1));
            let rd = tdir.join(format!("{base}_sram_read.csv"));
            std::fs::write(&rd, pt.sram_read_csv())?;
            out.push(rd);
            let wr = tdir.join(format!("{base}_sram_write.csv"));
            std::fs::write(&wr, pt.sram_write_csv())?;
            out.push(wr);
        }
        Ok(out)
    }

    /// Execute each layer's GEMM view through the AOT systolic artifact
    /// and compare against a Rust reference — proving the timed mapping
    /// computes correct numerics. Layers larger than a budget are
    /// subsampled to keep execution tractable.
    fn functional_check(&self, topo: &Topology, tile: usize) -> Result<Vec<(String, f32)>> {
        let mut rt = crate::runtime::Runtime::new(&crate::runtime::default_artifact_dir())?;
        let mut results = Vec::new();
        let mut rng = Rng::new(0x5CA1E);
        for layer in &topo.layers {
            let (m, k, n) = layer.gemm_view();
            // cap the functional GEMM so the check stays fast;
            // correctness of the tiling is shape-independent
            let (m, k, n) = (m.min(96) as usize, k.min(96) as usize, n.min(96) as usize);
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let got = rt.tiled_gemm(tile, &a, &b, m, k, n)?;
            let want = crate::rtl::matmul_ref(&a, &b, m, k, n);
            let mut max_err = 0f32;
            for (g, w) in got.iter().zip(&want) {
                max_err = max_err.max((g - w).abs() / (1.0 + w.abs()));
            }
            if max_err > 1e-3 {
                return Err(Error::Runtime(format!(
                    "functional check failed on {}: max rel err {max_err}",
                    layer.name
                )));
            }
            results.push((layer.name.clone(), max_err));
        }
        Ok(results)
    }
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_alphanumeric() || c == '_' { c } else { '_' }).collect()
}

/// Fluent engine construction. Every setter is optional; `build`
/// validates the final configuration.
pub struct EngineBuilder {
    cfg: ArchConfig,
    energy_model: EnergyModel,
    kind: BackendKind,
    custom: Option<Box<dyn Backend>>,
    threads: usize,
    out_dir: Option<PathBuf>,
    dump_traces: bool,
    trace_limit: u64,
    functional_tile: Option<usize>,
    cache: Option<CacheHandle>,
    cache_stripes: Option<usize>,
    router: Option<Arc<dyn LayerRouter>>,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            cfg: ArchConfig::default(),
            energy_model: EnergyModel::default(),
            kind: BackendKind::Analytical,
            custom: None,
            threads: crate::sweep::default_threads(),
            out_dir: None,
            dump_traces: false,
            trace_limit: 2_000_000,
            functional_tile: None,
            cache: None,
            cache_stripes: None,
            router: None,
        }
    }
}

impl EngineBuilder {
    /// Replace the whole base configuration.
    pub fn config(mut self, cfg: ArchConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Load the base configuration from a Table-I `.cfg` file.
    pub fn config_file(mut self, path: &Path) -> Result<Self> {
        self.cfg = ArchConfig::from_file(path)?;
        Ok(self)
    }

    pub fn dataflow(mut self, df: Dataflow) -> Self {
        self.cfg.dataflow = df;
        self
    }

    pub fn array(mut self, rows: u64, cols: u64) -> Self {
        self.cfg.array_h = rows;
        self.cfg.array_w = cols;
        self
    }

    /// Per-operand scratchpad sizes in KB (ifmap, filter, ofmap).
    pub fn sram_kb(mut self, ifmap: u64, filter: u64, ofmap: u64) -> Self {
        self.cfg.ifmap_sram_kb = ifmap;
        self.cfg.filter_sram_kb = filter;
        self.cfg.ofmap_sram_kb = ofmap;
        self
    }

    pub fn word_bytes(mut self, b: u64) -> Self {
        self.cfg.word_bytes = b;
        self
    }

    /// Select a built-in fidelity backend (default: analytical).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.kind = kind;
        self
    }

    /// Install an out-of-crate [`Backend`] implementation — the
    /// extension seam for future fidelity levels. The engine reports
    /// [`BackendKind::Custom`]; the cache is engine-local, so a custom
    /// backend never shares entries with another engine's.
    pub fn custom_backend(mut self, backend: Box<dyn Backend>) -> Self {
        self.kind = BackendKind::Custom;
        self.custom = Some(backend);
        self
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Directory for report files (and traces); created on demand.
    pub fn out_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.out_dir = Some(dir.into());
        self
    }

    pub fn dump_traces(mut self, yes: bool) -> Self {
        self.dump_traces = yes;
        self
    }

    /// Per-layer event budget for trace dumps.
    pub fn trace_limit(mut self, events: u64) -> Self {
        self.trace_limit = events;
        self
    }

    /// Cross-check layer numerics through the AOT artifact with this
    /// tile size.
    pub fn functional_tile(mut self, tile: usize) -> Self {
        self.functional_tile = Some(tile);
        self
    }

    pub fn energy_model(mut self, m: EnergyModel) -> Self {
        self.energy_model = m;
        self
    }

    /// Share another engine's memo table instead of starting cold —
    /// results already cached there are visible to this engine. Keys
    /// carry the backend kind and every value-affecting *config* field;
    /// the energy model is engine-fixed and NOT part of the key, so
    /// `build()` rejects the handle if this engine's energy model
    /// differs from the owning engine's.
    pub fn shared_cache(mut self, handle: CacheHandle) -> Self {
        self.cache = Some(handle);
        self
    }

    /// Lock-stripe count for a freshly built memo table (clamped to
    /// >= 1; default [`cache::DEFAULT_STRIPES`]). Purely a contention
    /// knob: any stripe count yields bit-identical results
    /// (docs/INVARIANTS.md §11). Ignored when [`shared_cache`] installs
    /// an existing table — the owning engine fixed its layout.
    ///
    /// [`shared_cache`]: EngineBuilder::shared_cache
    pub fn cache_stripes(mut self, n: usize) -> Self {
        self.cache_stripes = Some(n);
        self
    }

    /// Install a [`LayerRouter`] consulted before the local memo path —
    /// the serve subsystem's federation seam.
    pub fn layer_router(mut self, router: Arc<dyn LayerRouter>) -> Self {
        self.router = Some(router);
        self
    }

    /// Validate the configuration and construct the engine.
    pub fn build(self) -> Result<Engine> {
        self.cfg.validate()?;
        if self.kind == BackendKind::Custom && self.custom.is_none() {
            return Err(Error::Config(
                "BackendKind::Custom requires custom_backend(..)".into(),
            ));
        }
        if let Some(h) = &self.cache {
            if h.energy != self.energy_model {
                return Err(Error::Config(
                    "shared_cache requires the owning engine's energy model: cached \
                     reports embed energy numbers and the model is not part of the key"
                        .into(),
                ));
            }
            if h.custom || self.kind == BackendKind::Custom {
                return Err(Error::Config(
                    "shared_cache cannot involve a custom backend: every custom backend \
                     keys as BackendKind::Custom, so distinct implementations would \
                     collide in the shared table"
                        .into(),
                ));
            }
        }
        Ok(self.build_unchecked())
    }

    fn build_unchecked(self) -> Engine {
        let backend = match self.custom {
            Some(b) => b,
            // `build` rejects Custom-without-custom_backend, so the only
            // error instantiate can return is unreachable here; fall back
            // to the default fidelity rather than panic
            None => self.kind.instantiate().unwrap_or_else(|_| Box::new(backend::Analytical)),
        };
        // the backend object is the source of truth for its identity
        let kind = backend.kind();
        Engine {
            backend,
            cfg: self.cfg,
            energy_model: self.energy_model,
            kind,
            threads: self.threads,
            out_dir: self.out_dir,
            dump_traces: self.dump_traces,
            trace_limit: self.trace_limit,
            functional_tile: self.functional_tile,
            cache: match self.cache {
                Some(h) => h.cache,
                None => Arc::new(match self.cache_stripes {
                    Some(n) => LayerCache::with_stripes(n),
                    None => LayerCache::new(),
                }),
            },
            router: self.router,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sim::Simulator;

    fn topo() -> Topology {
        Topology::new(
            "t",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::conv("c2", 14, 14, 3, 3, 8, 16, 1),
                LayerShape::fc("fc", 1, 256, 10),
            ],
        )
    }

    #[test]
    fn builder_overrides_and_validates() {
        let e = Engine::builder()
            .dataflow(Dataflow::Ws)
            .array(32, 16)
            .sram_kb(64, 64, 32)
            .threads(2)
            .build()
            .unwrap();
        assert_eq!(e.cfg().dataflow, Dataflow::Ws);
        assert_eq!((e.cfg().array_h, e.cfg().array_w), (32, 16));
        assert_eq!(e.threads(), 2);
        assert!(Engine::builder().array(0, 8).build().is_err());
    }

    #[test]
    fn builder_config_file_loads_table_i_presets() {
        let dir = std::env::temp_dir()
            .join(format!("scale_sim_builder_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.cfg");
        std::fs::write(
            &path,
            "[architecture_presets]\nArrayHeight: 32\nArrayWidth: 16\nDataflow: ws\n",
        )
        .unwrap();
        let e = Engine::builder().config_file(&path).unwrap().build().unwrap();
        assert_eq!((e.cfg().array_h, e.cfg().array_w), (32, 16));
        assert_eq!(e.cfg().dataflow, Dataflow::Ws);
        assert!(Engine::builder().config_file(&dir.join("missing.cfg")).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn engine_layer_reports_are_bit_identical_to_simulator() {
        let cfg = ArchConfig { array_h: 16, array_w: 16, ..config::paper_default() };
        let engine = Engine::new(cfg.clone());
        let sim = Simulator::new(cfg);
        for layer in &topo().layers {
            assert_eq!(engine.run_layer(layer), sim.run_layer(layer));
        }
        assert_eq!(engine.run_topology(&topo()), sim.run_topology(&topo()));
    }

    #[test]
    fn run_workload_lowers_and_matches_run() {
        use crate::workload::{Conv2d, Workload};
        let wl = Workload::builder("w")
            .conv2d(
                "c1",
                Conv2d {
                    ifmap_h: 16,
                    ifmap_w: 16,
                    in_channels: 4,
                    out_channels: 8,
                    kernel_h: 3,
                    kernel_w: 3,
                    ..Conv2d::default()
                },
            )
            .gemm("g", 32, 64, 16)
            .build()
            .unwrap();
        let e = Engine::builder().array(16, 16).build().unwrap();
        let out = e.run_workload(&wl).unwrap();
        assert_eq!(out.report, e.run(&wl.lower().unwrap()).unwrap().report);
        assert_eq!(out.report.layers.len(), 2);
    }

    #[test]
    fn run_without_outputs() {
        let e = Engine::builder()
            .config(config::paper_default())
            .array(16, 16)
            .build()
            .unwrap();
        let out = e.run(&topo()).unwrap();
        assert_eq!(out.report.layers.len(), 3);
        assert!(out.files_written.is_empty());
        assert!(out.functional.is_empty());
    }

    #[test]
    fn run_writes_reports() {
        let dir = std::env::temp_dir().join(format!("scale_sim_engine_{}", std::process::id()));
        let e = Engine::builder()
            .array(16, 16)
            .out_dir(&dir)
            .dump_traces(true)
            .build()
            .unwrap();
        let out = e.run(&topo()).unwrap();
        assert!(out.files_written.iter().all(|f| f.exists()));
        assert!(dir.join("traces/c1_sram_trace.csv").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repeated_runs_hit_the_cache() {
        let e = Engine::new(config::paper_default());
        let t = topo();
        let a = e.run_topology(&t);
        let sims_after_first = e.cache_stats().layer_sims;
        let b = e.run_topology(&t);
        assert_eq!(a, b);
        assert_eq!(e.cache_stats().layer_sims, sims_after_first, "no new sims");
        assert_eq!(e.cache_stats().cache_hits, t.layers.len() as u64);
        assert_eq!(e.cache_entries(), t.layers.len());
    }

    #[test]
    fn shared_cache_handle_spans_engines() {
        let a = Engine::new(config::paper_default());
        let t = topo();
        a.run_topology(&t);
        let sims = a.cache_stats().layer_sims;
        let b = Engine::builder()
            .config(config::paper_default())
            .shared_cache(a.cache_handle())
            .build()
            .unwrap();
        let r = b.run_topology(&t);
        assert_eq!(b.cache_stats().layer_sims, sims, "no new sims through the shared table");
        assert_eq!(r, a.run_topology(&t));
        assert_eq!(a.cache_handle().entries(), b.cache_entries());
    }

    #[test]
    fn shared_cache_rejects_a_different_energy_model() {
        // cached reports embed energy numbers; the model is not keyed
        let a = Engine::new(config::paper_default());
        let err = Engine::builder()
            .config(config::paper_default())
            .energy_model(crate::energy::EnergyModel::NODE_7NM)
            .shared_cache(a.cache_handle())
            .build();
        assert!(err.is_err());
        // same model is fine
        assert!(Engine::builder()
            .config(config::paper_default())
            .shared_cache(a.cache_handle())
            .build()
            .is_ok());
    }

    #[test]
    fn shared_cache_rejects_custom_backends() {
        struct Echo;
        impl crate::engine::Backend for Echo {
            fn kind(&self) -> BackendKind {
                BackendKind::Custom
            }
            fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> crate::dataflow::Timing {
                cfg.dataflow.timing(layer, cfg.array_h, cfg.array_w)
            }
        }
        // custom consumer of a standard cache: rejected
        let a = Engine::new(config::paper_default());
        assert!(Engine::builder()
            .custom_backend(Box::new(Echo))
            .shared_cache(a.cache_handle())
            .build()
            .is_err());
        // standard consumer of a custom engine's cache: rejected too
        let c = Engine::builder().custom_backend(Box::new(Echo)).build().unwrap();
        assert!(Engine::builder().shared_cache(c.cache_handle()).build().is_err());
    }

    #[test]
    fn flexible_study_matches_legacy() {
        let cfg = ArchConfig { array_h: 16, array_w: 16, ..config::paper_default() };
        let e = Engine::new(cfg.clone());
        let ours = e.flexible_study(&topo());
        let legacy = crate::sim::flex::flexible_study(&cfg, &topo());
        assert_eq!(ours.fixed_cycles, legacy.fixed_cycles);
        assert_eq!(ours.flexible_cycles, legacy.flexible_cycles);
        for (a, b) in ours.layers.iter().zip(&legacy.layers) {
            assert_eq!(a.best, b.best);
            assert_eq!(a.cycles, b.cycles);
        }
    }

    #[test]
    fn custom_backend_plugs_in_through_the_builder() {
        /// An out-of-module backend: analytical timing with a marker kind.
        struct Doubleway;
        impl crate::engine::Backend for Doubleway {
            fn kind(&self) -> BackendKind {
                BackendKind::Custom
            }
            fn timing(
                &self,
                cfg: &ArchConfig,
                layer: &LayerShape,
            ) -> crate::dataflow::Timing {
                cfg.dataflow.timing(layer, cfg.array_h, cfg.array_w)
            }
        }
        let e = Engine::builder()
            .array(16, 16)
            .custom_backend(Box::new(Doubleway))
            .build()
            .unwrap();
        assert_eq!(e.backend_kind(), BackendKind::Custom);
        let reference = Engine::builder().array(16, 16).build().unwrap();
        for layer in &topo().layers {
            assert_eq!(e.run_layer(layer), reference.run_layer(layer));
        }
        // Custom kind without an implementation is rejected
        assert!(Engine::builder().backend(BackendKind::Custom).build().is_err());
    }

    #[test]
    fn cache_stripes_never_change_results() {
        // §11: the stripe count is a lock-layout knob, results are
        // bit-identical at any setting (including the historical
        // single-mutex layout, stripes = 1)
        let t = topo();
        let base = Engine::builder().array(16, 16).build().unwrap().run_topology(&t);
        for stripes in [1usize, 2, 16, 64] {
            let e = Engine::builder().array(16, 16).cache_stripes(stripes).build().unwrap();
            assert_eq!(e.cache_stripe_count(), stripes.max(1));
            assert_eq!(e.run_topology(&t), base, "stripes={stripes} changed a report");
            // a second pass is served from the cache, still identical
            assert_eq!(e.run_topology(&t), base);
            assert_eq!(e.cache_stats().layer_sims, t.layers.len() as u64);
        }
    }

    #[test]
    fn layer_router_intercepts_and_falls_back() {
        use std::sync::atomic::{AtomicUsize, Ordering};

        /// Routes every odd hash to a canned "remote" result; even
        /// hashes fall through to the local path (peer failover shape).
        struct OddRouter {
            asked: AtomicUsize,
            served: AtomicUsize,
        }
        impl LayerRouter for OddRouter {
            fn route(
                &self,
                key_hash: u64,
                cfg: &ArchConfig,
                layer: &LayerShape,
            ) -> Option<LayerReport> {
                self.asked.fetch_add(1, Ordering::SeqCst);
                if key_hash % 2 == 1 {
                    self.served.fetch_add(1, Ordering::SeqCst);
                    // a "peer" computes the same deterministic result
                    Some(Simulator::new(cfg.clone()).run_layer(layer))
                } else {
                    None
                }
            }
        }

        let router = Arc::new(OddRouter { asked: AtomicUsize::new(0), served: AtomicUsize::new(0) });
        let e = Engine::builder()
            .array(16, 16)
            .layer_router(Arc::clone(&router) as Arc<dyn LayerRouter>)
            .build()
            .unwrap();
        let plain = Engine::builder().array(16, 16).build().unwrap();
        let t = topo();
        assert_eq!(e.run_topology(&t), plain.run_topology(&t), "routing must not change results");
        let asked = router.asked.load(Ordering::SeqCst);
        let served = router.served.load(Ordering::SeqCst);
        assert_eq!(asked, t.layers.len(), "router consulted once per layer");
        // routed layers bypass the local table; fall-throughs hit it
        assert_eq!(
            e.cache_stats().layer_sims,
            (asked - served) as u64,
            "peer-served keys must never enter the local table"
        );
    }

    #[test]
    fn backends_agree_through_the_engine() {
        for kind in BackendKind::ALL {
            let e = Engine::builder().array(8, 8).backend(kind).build().unwrap();
            let a = Engine::builder().array(8, 8).build().unwrap();
            for layer in &topo().layers {
                assert_eq!(
                    e.run_layer(layer),
                    a.run_layer(layer),
                    "{kind} deviates on {}",
                    layer.name
                );
            }
        }
    }
}
