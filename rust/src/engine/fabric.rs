//! Route-aware interconnect fabric for the multi-array system (§IV-E).
//!
//! The paper tabulates the interconnect bandwidth a scale-out system
//! *demands* but never models what the interconnect *delivers*. This
//! module turns that column into a simulated quantity: nodes sit on a
//! [`Topology`] ([`Line`] / [`Ring`] / [`Mesh`]) with the memory
//! controller at node 0, every node's read traffic is routed hop by hop
//! toward it, and per-link loads plus a demand-proportional DRAM share
//! decide each node's *effective* fetch bandwidth:
//!
//! * DRAM side: the shared interface serves the nodes' aggregate demand
//!   `D` at `dram_bw`, so draining takes `D / dram_bw` cycles — each
//!   node's share is proportional to its own demand (`bw * d_j / D`).
//! * Fabric side: a flow from node `j` crosses every link on its route
//!   and is stored-and-forwarded behind the other flows sharing those
//!   links, so its path drains in `Σ_route load_l / link_bw` cycles.
//!
//! Whichever is slower binds: the node's effective bandwidth is its own
//! demand over that time, and its fold/fetch schedule replays against it
//! through [`crate::memory::stall`]. The model is deliberately
//! closed-form per layer (no RNG, no wall clock): reports are
//! byte-identical across runs and machines, so fabric metrics join the
//! deterministic class pinned by the golden suite.
//!
//! Two structural facts the property suite pins:
//!
//! * **Flow conservation** — `Σ link_bytes == Σ d_j * hops_j`
//!   ([`FabricLayerReport::hop_bytes`]): every byte is accounted on
//!   every link it crosses, no more, no less.
//! * **Mesh never slower than Line** at equal link bandwidth: every
//!   mesh route's link loads embed termwise into the line's (the line's
//!   first link carries the whole non-root demand), so per-node
//!   effective bandwidth can only improve.

use crate::util::isqrt;
use crate::{Error, Result};

/// Interconnect topology selector. `Flat` is the legacy contention
/// model (even bandwidth split, no routed fabric) and the default, so
/// every pre-fabric surface keeps its exact behavior.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum FabricKind {
    #[default]
    Flat,
    Line,
    Ring,
    Mesh,
}

impl FabricKind {
    pub const ALL: [FabricKind; 4] =
        [FabricKind::Flat, FabricKind::Line, FabricKind::Ring, FabricKind::Mesh];

    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::Flat => "flat",
            FabricKind::Line => "line",
            FabricKind::Ring => "ring",
            FabricKind::Mesh => "mesh",
        }
    }

    /// Parse the wire/CLI spelling (the `name()` strings).
    pub fn parse(s: &str) -> Result<FabricKind> {
        match s {
            "flat" => Ok(FabricKind::Flat),
            "line" => Ok(FabricKind::Line),
            "ring" => Ok(FabricKind::Ring),
            "mesh" => Ok(FabricKind::Mesh),
            other => Err(Error::Config(format!(
                "unknown fabric {other:?} (flat|line|ring|mesh)"
            ))),
        }
    }
}

/// A routed fabric: topology kind plus per-link bandwidth in
/// bytes/cycle (every link is provisioned identically).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FabricConfig {
    pub kind: FabricKind,
    pub link_bw: f64,
}

/// Default per-link bandwidth (bytes/cycle) when a surface enables a
/// fabric without provisioning one — matches the shared-DRAM bandwidth
/// the scaleout study uses.
pub const DEFAULT_LINK_BW: f64 = 16.0;

impl FabricConfig {
    pub fn new(kind: FabricKind, link_bw: f64) -> Self {
        FabricConfig { kind, link_bw }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.link_bw.is_finite() || self.link_bw <= 0.0 {
            return Err(Error::Config(format!(
                "link bandwidth must be positive and finite, got {}",
                self.link_bw
            )));
        }
        Ok(())
    }
}

/// A node-to-memory-controller routed interconnect over `nodes` nodes.
/// Node 0 hosts the memory controller (and is also a compute node, with
/// a zero-hop route); links are bidirectional and identified by a dense
/// index in `0..link_count()`.
pub trait Topology {
    /// Stable display name (`"line"` / `"ring"` / `"mesh"`).
    fn name(&self) -> &'static str;

    /// Number of links in the fabric.
    fn link_count(&self) -> usize;

    /// Links node `j`'s traffic crosses toward node 0, in traversal
    /// order starting at the node. Node 0 returns an empty route.
    fn route(&self, node: u64) -> Vec<usize>;
}

/// Nodes in a row: `i -- i+1`; link `i` joins nodes `i` and `i+1`.
/// Everything funnels through link 0, the classic worst case.
pub struct Line {
    pub nodes: u64,
}

impl Topology for Line {
    fn name(&self) -> &'static str {
        "line"
    }

    fn link_count(&self) -> usize {
        self.nodes.saturating_sub(1) as usize
    }

    fn route(&self, node: u64) -> Vec<usize> {
        (0..node as usize).rev().collect()
    }
}

/// Nodes in a cycle: link `i` joins nodes `i` and `(i+1) % nodes`; each
/// node takes the shorter direction to node 0 (ties go clockwise, i.e.
/// through decreasing node indices).
pub struct Ring {
    pub nodes: u64,
}

impl Topology for Ring {
    fn name(&self) -> &'static str {
        "ring"
    }

    fn link_count(&self) -> usize {
        if self.nodes < 2 {
            0
        } else {
            self.nodes as usize
        }
    }

    fn route(&self, node: u64) -> Vec<usize> {
        if node == 0 || self.nodes < 2 {
            return Vec::new();
        }
        let down = node; // hops via node-1, ..., 0
        let up = self.nodes - node; // hops via node+1, ..., n-1, 0
        if down <= up {
            (0..node as usize).rev().collect()
        } else {
            (node as usize..self.nodes as usize).collect()
        }
    }
}

/// Nodes row-major on a `side x side` grid (`side = ceil(sqrt(nodes))`,
/// trailing positions vacant), XY-routed: along the row to column 0,
/// then up column 0 to the controller at (0, 0). Horizontal links come
/// first in the index space, then vertical ones.
pub struct Mesh {
    pub nodes: u64,
    side: u64,
}

impl Mesh {
    pub fn new(nodes: u64) -> Self {
        let s = isqrt(nodes);
        let side = if s * s == nodes { s } else { s + 1 };
        Mesh { nodes, side: side.max(1) }
    }

    /// Grid side length.
    pub fn side(&self) -> u64 {
        self.side
    }

    /// Horizontal link between `(row, col)` and `(row, col - 1)`.
    fn h_link(&self, row: u64, col: u64) -> usize {
        (row * (self.side - 1) + (col - 1)) as usize
    }

    /// Vertical link between `(row, col)` and `(row - 1, col)`.
    fn v_link(&self, row: u64, col: u64) -> usize {
        (self.side * (self.side - 1) + col * (self.side - 1) + (row - 1)) as usize
    }
}

impl Topology for Mesh {
    fn name(&self) -> &'static str {
        "mesh"
    }

    fn link_count(&self) -> usize {
        if self.nodes < 2 {
            0
        } else {
            (2 * self.side * (self.side - 1)) as usize
        }
    }

    fn route(&self, node: u64) -> Vec<usize> {
        if node == 0 || self.nodes < 2 {
            return Vec::new();
        }
        let (row, col) = (node / self.side, node % self.side);
        let mut links = Vec::with_capacity((row + col) as usize);
        for c in (1..=col).rev() {
            links.push(self.h_link(row, c));
        }
        for r in (1..=row).rev() {
            links.push(self.v_link(r, 0));
        }
        links
    }
}

/// Instantiate the topology for `kind` over `nodes` placed nodes.
/// `Flat` has no routed fabric and returns `None`.
pub fn topology(kind: FabricKind, nodes: u64) -> Option<Box<dyn Topology>> {
    match kind {
        FabricKind::Flat => None,
        FabricKind::Line => Some(Box::new(Line { nodes })),
        FabricKind::Ring => Some(Box::new(Ring { nodes })),
        FabricKind::Mesh => Some(Box::new(Mesh::new(nodes))),
    }
}

/// Per-node outcome of routing one layer's flows over a fabric.
pub(crate) struct Contention {
    /// Effective fetch bandwidth per node (bytes/cycle); `None` means
    /// unconstrained (no DRAM bandwidth modeled and an empty route, or
    /// a node with zero demand).
    pub eff_bw: Vec<Option<f64>>,
    /// Total bytes crossing each link.
    pub link_bytes: Vec<u64>,
    /// Route of each node (link ids in traversal order).
    pub routes: Vec<Vec<usize>>,
    /// `Σ d_j * hops_j`: every byte counted on every link it crosses.
    pub hop_bytes: u64,
}

/// Route per-node read demands (bytes) over the fabric and resolve the
/// contention model described in the module docs. `demands[j]` is node
/// `j`'s read traffic; node 0 co-locates with the memory controller.
pub(crate) fn contention(
    topo: &dyn Topology,
    link_bw: f64,
    dram_bw: Option<f64>,
    demands: &[u64],
) -> Contention {
    let routes: Vec<Vec<usize>> = (0..demands.len() as u64).map(|j| topo.route(j)).collect();
    let mut link_bytes = vec![0u64; topo.link_count()];
    let mut hop_bytes = 0u64;
    for (j, route) in routes.iter().enumerate() {
        for &l in route {
            if let Some(b) = link_bytes.get_mut(l) {
                *b += demands[j];
            }
        }
        hop_bytes += demands[j] * route.len() as u64;
    }
    let total_demand: u64 = demands.iter().sum();
    let dram_time = match dram_bw {
        Some(bw) => total_demand as f64 / bw,
        None => 0.0,
    };
    let eff_bw = demands
        .iter()
        .zip(&routes)
        .map(|(&d, route)| {
            if d == 0 {
                return None;
            }
            let mut path_time = 0.0f64;
            for &l in route {
                path_time += link_bytes[l] as f64 / link_bw;
            }
            if path_time > dram_time {
                // link-bound: the node's bytes drain behind every flow
                // sharing its route, hop by hop
                Some(d as f64 / path_time)
            } else {
                // DRAM-bound: demand-proportional share of the
                // interface (a single node gets the full bandwidth,
                // bit-for-bit)
                dram_bw.map(|bw| bw * (d as f64 / total_demand as f64))
            }
        })
        .collect();
    Contention { eff_bw, link_bytes, routes, hop_bytes }
}

/// Per-layer fabric accounting attached to a
/// [`crate::engine::MultiLayerReport`] when a fabric is enabled.
#[derive(Clone, Debug, PartialEq)]
pub struct FabricLayerReport {
    pub kind: FabricKind,
    pub link_bw: f64,
    /// Nodes placed on the fabric for this layer (busy nodes).
    pub placed_nodes: u64,
    /// Total bytes crossing each link over the layer.
    pub link_bytes: Vec<u64>,
    /// Per-link average throughput over the layer's total (stalled)
    /// runtime.
    pub link_avg_bw: Vec<f64>,
    /// Per-link offered peak: the per-flow burst peaks of every flow
    /// crossing the link, summed (nodes burst concurrently).
    pub link_peak_bw: Vec<f64>,
    /// `Σ demand_j * hops_j` — the in-flight message-hop total; equals
    /// the sum of `link_bytes` (flow conservation).
    pub hop_bytes: u64,
    /// Stalled completion time of each placed node (main-share nodes
    /// first, the remainder node last); the layer finishes with the
    /// maximum.
    pub node_total_cycles: Vec<u64>,
    /// Banked-DRAM replay of the slowest share's request stream, when
    /// the banked memory model is enabled alongside the fabric.
    pub dram: Option<crate::dram::BankedStats>,
}

impl FabricLayerReport {
    /// Busiest link by average throughput.
    pub fn max_link_avg_bw(&self) -> f64 {
        self.link_avg_bw.iter().copied().fold(0.0, f64::max)
    }

    /// Busiest link by offered peak.
    pub fn max_link_peak_bw(&self) -> f64 {
        self.link_peak_bw.iter().copied().fold(0.0, f64::max)
    }

    /// Total bytes crossing any link (== `hop_bytes`).
    pub fn total_link_bytes(&self) -> u64 {
        self.link_bytes.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_routes_funnel_through_link_zero() {
        let line = Line { nodes: 4 };
        assert_eq!(line.link_count(), 3);
        assert_eq!(line.route(0), Vec::<usize>::new());
        assert_eq!(line.route(1), vec![0]);
        assert_eq!(line.route(3), vec![2, 1, 0]);
    }

    #[test]
    fn ring_takes_the_shorter_direction() {
        let ring = Ring { nodes: 6 };
        assert_eq!(ring.link_count(), 6);
        assert_eq!(ring.route(1), vec![0]);
        // tie at n/2 goes clockwise (down through decreasing indices)
        assert_eq!(ring.route(3), vec![2, 1, 0]);
        assert_eq!(ring.route(4), vec![4, 5]);
        assert_eq!(ring.route(5), vec![5]);
    }

    #[test]
    fn mesh_xy_routes_go_row_first_then_column_zero() {
        let mesh = Mesh::new(16);
        assert_eq!(mesh.side(), 4);
        assert_eq!(mesh.link_count(), 24);
        assert_eq!(mesh.route(0), Vec::<usize>::new());
        // node 5 = (1, 1): one hop left, one hop up
        assert_eq!(mesh.route(5), vec![mesh.h_link(1, 1), mesh.v_link(1, 0)]);
        // node 15 = (3, 3): three left, three up
        assert_eq!(
            mesh.route(15),
            vec![
                mesh.h_link(3, 3),
                mesh.h_link(3, 2),
                mesh.h_link(3, 1),
                mesh.v_link(3, 0),
                mesh.v_link(2, 0),
                mesh.v_link(1, 0),
            ]
        );
    }

    #[test]
    fn mesh_covers_non_square_node_counts() {
        let mesh = Mesh::new(6); // 3x3 grid, positions 6..9 vacant
        assert_eq!(mesh.side(), 3);
        for j in 0..6 {
            for l in mesh.route(j) {
                assert!(l < mesh.link_count(), "node {j} link {l}");
            }
        }
    }

    #[test]
    fn link_loads_conserve_flow() {
        for kind in [FabricKind::Line, FabricKind::Ring, FabricKind::Mesh] {
            let topo = topology(kind, 7).unwrap();
            let demands = [5u64, 11, 0, 3, 9, 2, 7];
            let c = contention(topo.as_ref(), 4.0, Some(16.0), &demands);
            let linked: u64 = c.link_bytes.iter().sum();
            assert_eq!(linked, c.hop_bytes, "{}", kind.name());
            let by_route: u64 = demands
                .iter()
                .zip(&c.routes)
                .map(|(d, r)| d * r.len() as u64)
                .sum();
            assert_eq!(linked, by_route, "{}", kind.name());
        }
    }

    #[test]
    fn single_node_contention_is_the_plain_dram_bandwidth() {
        let topo = topology(FabricKind::Mesh, 1).unwrap();
        let c = contention(topo.as_ref(), 4.0, Some(16.0), &[1234]);
        // bit-for-bit the configured bandwidth: d/D == 1.0 exactly
        assert_eq!(c.eff_bw, vec![Some(16.0)]);
        assert_eq!(c.hop_bytes, 0);
        // and with no DRAM bandwidth either, fully unconstrained
        let c = contention(topo.as_ref(), 4.0, None, &[1234]);
        assert_eq!(c.eff_bw, vec![None]);
    }

    #[test]
    fn farther_line_nodes_get_less_effective_bandwidth() {
        let topo = topology(FabricKind::Line, 4).unwrap();
        let c = contention(topo.as_ref(), 8.0, None, &[10, 10, 10, 10]);
        let bw: Vec<f64> = c.eff_bw.iter().map(|b| b.unwrap_or(f64::INFINITY)).collect();
        assert!(bw[0].is_infinite(), "root node is link-free");
        assert!(bw[1] > bw[2] && bw[2] > bw[3], "{bw:?}");
    }

    #[test]
    fn mesh_effective_bandwidth_dominates_line_per_node() {
        let demands = [7u64, 13, 5, 11, 3, 9, 6, 2, 8];
        let line = topology(FabricKind::Line, 9).unwrap();
        let mesh = topology(FabricKind::Mesh, 9).unwrap();
        let cl = contention(line.as_ref(), 2.0, Some(16.0), &demands);
        let cm = contention(mesh.as_ref(), 2.0, Some(16.0), &demands);
        for j in 0..demands.len() {
            let l = cl.eff_bw[j].unwrap_or(f64::INFINITY);
            let m = cm.eff_bw[j].unwrap_or(f64::INFINITY);
            assert!(m >= l, "node {j}: mesh {m} < line {l}");
        }
    }

    #[test]
    fn kind_parse_roundtrips() {
        for kind in FabricKind::ALL {
            assert_eq!(FabricKind::parse(kind.name()).ok(), Some(kind));
        }
        assert!(FabricKind::parse("torus").is_err());
        assert_eq!(FabricKind::default(), FabricKind::Flat);
    }

    #[test]
    fn link_bw_validation_rejects_non_positive() {
        assert!(FabricConfig::new(FabricKind::Line, 16.0).validate().is_ok());
        for bad in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            assert!(
                FabricConfig::new(FabricKind::Line, bad).validate().is_err(),
                "{bad}"
            );
        }
    }
}
