//! The memoizing sweep grid — design-space exploration as a cartesian
//! product of axes (workloads x dataflows x array shapes x scratchpad
//! sizes), executed on the [`crate::sweep::parallel_map`] pool through
//! the engine's shared layer cache.
//!
//! Axis order is part of the contract: points are produced in
//! `workload -> dataflow -> array -> sram` nested order, which is
//! exactly the order the legacy `sweep::{dataflow,memory,shape}_sweep`
//! functions produced, so their shim wrappers emit identical tables.

use std::time::{Duration, Instant};

use crate::config::{ArchConfig, Topology};
use crate::dataflow::Dataflow;
use crate::sim::WorkloadReport;
use crate::sweep::parallel_map;

use super::cache::MemoStats;
use super::fabric::{FabricConfig, FabricKind, DEFAULT_LINK_BW};
use super::multi::{MultiArrayConfig, MultiOpts, Partition};
use super::Engine;

/// One evaluated grid point: the config coordinates plus the full
/// workload report (callers project whatever metric they chart).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub workload: String,
    pub dataflow: Dataflow,
    pub array_h: u64,
    pub array_w: u64,
    pub ifmap_sram_kb: u64,
    pub filter_sram_kb: u64,
    /// Multi-array coordinates: `nodes` arrays of `array_h x array_w`
    /// each, split by `partition`. `nodes == 1` is the plain
    /// single-array point (bit-identical to a grid without the axes).
    pub nodes: u64,
    pub partition: Partition,
    /// Interconnect coordinates: the route-aware fabric this point's
    /// multi-array system was simulated under (`Flat` = the legacy
    /// contention-free interconnect) and its per-link bandwidth.
    pub fabric: FabricKind,
    pub link_bw: f64,
    /// Link-contention stall cycles summed over the workload (always 0
    /// on single-array and `Flat` points — the grid models no shared
    /// DRAM bandwidth).
    pub stall_cycles: u64,
    pub report: WorkloadReport,
}

impl SweepPoint {
    /// The config this point was simulated under (engine base + axis
    /// coordinates).
    pub fn config(&self, base: &ArchConfig) -> ArchConfig {
        ArchConfig {
            array_h: self.array_h,
            array_w: self.array_w,
            dataflow: self.dataflow,
            ifmap_sram_kb: self.ifmap_sram_kb,
            filter_sram_kb: self.filter_sram_kb,
            ..base.clone()
        }
    }

    /// PEs across the whole (possibly multi-array) system.
    pub fn total_pes(&self) -> u64 {
        self.array_h * self.array_w * self.nodes
    }
}

/// Execution statistics for one grid run.
#[derive(Clone, Copy, Debug)]
pub struct SweepStats {
    /// Grid points evaluated.
    pub points: usize,
    /// Wall-clock time of the grid execution.
    pub wall: Duration,
    /// Memoization counters for this run only (delta, not engine-lifetime).
    pub memo: MemoStats,
}

impl SweepStats {
    pub fn hit_rate(&self) -> f64 {
        self.memo.hit_rate()
    }

    /// The canonical BENCH field set (wall-clock + memoization
    /// counters) — the single definition of the names, shared by
    /// [`SweepStats::write_bench_json`] (the CLI and fig benches) and
    /// the dse campaign's `BENCH_dse.json` writer.
    pub fn bench_fields(&self) -> [(&'static str, f64); 5] {
        [
            ("sweep_wall_ms", self.wall.as_secs_f64() * 1e3),
            ("points", self.points as f64),
            ("layer_sims", self.memo.layer_sims as f64),
            ("cache_hits", self.memo.cache_hits as f64),
            ("cache_hit_rate", self.hit_rate()),
        ]
    }

    /// Write the canonical `BENCH_sweep.json` record for this run.
    pub fn write_bench_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::util::bench::write_json(path, &self.bench_fields())
    }
}

/// Result of [`SweepGrid::run`].
#[derive(Clone, Debug)]
pub struct SweepOutcome {
    pub points: Vec<SweepPoint>,
    pub stats: SweepStats,
}

impl SweepOutcome {
    /// Find one point by its (workload name, dataflow, array shape)
    /// coordinates. Returns `None` when the coordinates are ambiguous —
    /// i.e. the grid also swept an SRAM, node-count or partition axis,
    /// so several points share them — rather than silently returning an
    /// arbitrary one; use [`SweepOutcome::find_sram`] (or filter on
    /// `nodes`/`partition` directly) on such grids.
    pub fn find(&self, workload: &str, df: Dataflow, h: u64, w: u64) -> Option<&SweepPoint> {
        let mut it = self.points.iter().filter(|p| {
            p.workload == workload && p.dataflow == df && p.array_h == h && p.array_w == w
        });
        let first = it.next()?;
        if it.next().is_some() {
            return None; // ambiguous: SRAM axis differentiates the matches
        }
        Some(first)
    }

    /// Find one point on a grid that swept the scratchpad axis. Like
    /// [`SweepOutcome::find`], returns `None` when the coordinates are
    /// still ambiguous (the grid also swept the node-count/partition
    /// axes) rather than silently picking an arbitrary match.
    pub fn find_sram(
        &self,
        workload: &str,
        df: Dataflow,
        h: u64,
        w: u64,
        ifmap_sram_kb: u64,
    ) -> Option<&SweepPoint> {
        let mut it = self.points.iter().filter(|p| {
            p.workload == workload
                && p.dataflow == df
                && p.array_h == h
                && p.array_w == w
                && p.ifmap_sram_kb == ifmap_sram_kb
        });
        let first = it.next()?;
        if it.next().is_some() {
            return None; // ambiguous: nodes/partition axes differentiate
        }
        Some(first)
    }
}

/// Builder for one grid execution; obtained from [`Engine::sweep`].
/// Every axis defaults to the engine's base configuration (a
/// single-point "sweep"), so callers only name the axes they explore.
pub struct SweepGrid<'e> {
    engine: &'e Engine,
    workloads: Vec<Topology>,
    dataflows: Vec<Dataflow>,
    arrays: Vec<(u64, u64)>,
    sram_kb: Vec<(u64, u64)>,
    nodes: Vec<u64>,
    partitions: Vec<Partition>,
    fabrics: Vec<FabricKind>,
    link_bws: Vec<f64>,
    threads: usize,
}

impl<'e> SweepGrid<'e> {
    pub(crate) fn new(engine: &'e Engine) -> Self {
        let cfg = engine.cfg();
        SweepGrid {
            engine,
            workloads: Vec::new(),
            dataflows: vec![cfg.dataflow],
            arrays: vec![(cfg.array_h, cfg.array_w)],
            sram_kb: vec![(cfg.ifmap_sram_kb, cfg.filter_sram_kb)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            fabrics: vec![FabricKind::Flat],
            link_bws: vec![DEFAULT_LINK_BW],
            threads: engine.threads(),
        }
    }

    /// Workload axis (required: an empty grid evaluates no points).
    /// Replaces the axis; combine with [`SweepGrid::workload_specs`] to
    /// append typed-IR workloads.
    pub fn workloads(mut self, topos: &[Topology]) -> Self {
        self.workloads = topos.to_vec();
        self
    }

    /// Single-workload convenience.
    pub fn workload(mut self, topo: &Topology) -> Self {
        self.workloads = vec![topo.clone()];
        self
    }

    /// Append typed-IR workloads ([`crate::workload::Workload`]) to the
    /// workload axis, lowering each onto the engine's tiles. Lowered
    /// tiles that coincide with tiles of other workloads on the grid —
    /// e.g. a GEMM workload re-encoding a conv workload's FC layers —
    /// share memo-cache entries across the whole sweep.
    pub fn workload_specs(
        mut self,
        specs: &[crate::workload::Workload],
    ) -> crate::Result<Self> {
        for spec in specs {
            self.workloads.push(spec.lower()?);
        }
        Ok(self)
    }

    /// Dataflow axis (default: the engine's configured dataflow).
    pub fn dataflows(mut self, dfs: &[Dataflow]) -> Self {
        self.dataflows = dfs.to_vec();
        self
    }

    /// Square-array axis: `n` -> `n x n` (Fig 5/6 style).
    pub fn square_arrays(mut self, dims: &[u64]) -> Self {
        self.arrays = dims.iter().map(|&n| (n, n)).collect();
        self
    }

    /// Arbitrary array-shape axis (Fig 8 style aspect-ratio ladders).
    pub fn array_shapes(mut self, shapes: &[(u64, u64)]) -> Self {
        self.arrays = shapes.to_vec();
        self
    }

    /// Scratchpad axis: each size applies to both the IFMAP and filter
    /// partitions (Fig 7 style; the paper sweeps them in lockstep).
    pub fn sram_sizes_kb(mut self, kbs: &[u64]) -> Self {
        self.sram_kb = kbs.iter().map(|&kb| (kb, kb)).collect();
        self
    }

    /// Multi-array node-count axis (§IV-E scale-out): each value `n`
    /// simulates `n` replicas of the point's array shape, split by the
    /// partition axis. `1` (the default) is the plain single array.
    /// Panics on a zero node count.
    pub fn nodes(mut self, counts: &[u64]) -> Self {
        assert!(counts.iter().all(|&n| n > 0), "node counts must be positive");
        self.nodes = counts.to_vec();
        self
    }

    /// Partition-strategy axis for multi-array points (ignored at
    /// `nodes == 1`, where every strategy is the whole layer).
    pub fn partitions(mut self, ps: &[Partition]) -> Self {
        self.partitions = ps.to_vec();
        self
    }

    /// Interconnect-topology axis for multi-array points
    /// ([`crate::engine::fabric`]). `Flat` (the default) keeps the
    /// contention-free legacy interconnect; `Line`/`Ring`/`Mesh` route
    /// every node's DRAM traffic hop by hop and report link-bound
    /// stalls in [`SweepPoint::stall_cycles`].
    pub fn fabrics(mut self, kinds: &[FabricKind]) -> Self {
        self.fabrics = kinds.to_vec();
        self
    }

    /// Per-link bandwidth axis (bytes/cycle) for the fabric axis.
    /// Panics on non-finite or non-positive bandwidths.
    pub fn link_bws(mut self, bws: &[f64]) -> Self {
        assert!(
            bws.iter().all(|bw| bw.is_finite() && *bw > 0.0),
            "link bandwidths must be finite and positive"
        );
        self.link_bws = bws.to_vec();
        self
    }

    /// Worker-thread override (default: the engine's thread count).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Number of points this grid will evaluate.
    pub fn len(&self) -> usize {
        self.workloads.len()
            * self.dataflows.len()
            * self.arrays.len()
            * self.sram_kb.len()
            * self.nodes.len()
            * self.partitions.len()
            * self.fabrics.len()
            * self.link_bws.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Execute every point. Points sharing (config, layer-shape) pairs —
    /// within one point's topology or across concurrent points — are
    /// simulated once and served from the engine's memo cache after.
    pub fn run(self) -> SweepOutcome {
        let engine = self.engine;
        let base = engine.cfg();
        type Job<'t> =
            (&'t Topology, Dataflow, (u64, u64), (u64, u64), u64, Partition, FabricKind, f64);
        let mut jobs: Vec<Job<'_>> = Vec::new();
        for topo in &self.workloads {
            for &df in &self.dataflows {
                for &arr in &self.arrays {
                    for &sram in &self.sram_kb {
                        for &n in &self.nodes {
                            for &p in &self.partitions {
                                for &fk in &self.fabrics {
                                    for &lbw in &self.link_bws {
                                        jobs.push((topo, df, arr, sram, n, p, fk, lbw));
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }

        let before = engine.cache_stats();
        let t0 = Instant::now();
        let points = parallel_map(
            &jobs,
            self.threads,
            |&(topo, df, (h, w), (ikb, fkb), n, p, fk, lbw)| {
                let cfg = ArchConfig {
                    array_h: h,
                    array_w: w,
                    dataflow: df,
                    ifmap_sram_kb: ikb,
                    filter_sram_kb: fkb,
                    ..base.clone()
                };
                let (report, stall_cycles) = if n == 1 {
                    (engine.run_topology_with(&cfg, topo), 0)
                } else {
                    let multi = MultiArrayConfig::new(n, h, w, p);
                    let opts = MultiOpts {
                        shared_dram_bw: None,
                        fabric: (fk != FabricKind::Flat)
                            .then(|| FabricConfig::new(fk, lbw)),
                        dram: None,
                    };
                    let r = engine.run_multi_opts(&cfg, topo, &multi, &opts);
                    (r.to_workload_report(), r.total_stall_cycles())
                };
                SweepPoint {
                    workload: topo.name.clone(),
                    dataflow: df,
                    array_h: h,
                    array_w: w,
                    ifmap_sram_kb: ikb,
                    filter_sram_kb: fkb,
                    nodes: n,
                    partition: p,
                    fabric: fk,
                    link_bw: lbw,
                    stall_cycles,
                    report,
                }
            },
        );
        let wall = t0.elapsed();
        let memo = engine.cache_stats().since(&before);
        SweepOutcome { points, stats: SweepStats { points: jobs.len(), wall, memo } }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config;

    fn topo(name: &str) -> Topology {
        Topology::new(
            name,
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::conv("c2", 16, 16, 3, 3, 4, 8, 1), // repeat of c1's shape
                LayerShape::fc("fc", 1, 64, 10),
            ],
        )
    }

    fn engine() -> Engine {
        Engine::new(config::paper_default())
    }

    #[test]
    fn grid_is_the_full_cartesian_product_in_order() {
        let e = engine();
        let out = e
            .sweep()
            .workloads(&[topo("a"), topo("b")])
            .dataflows(&Dataflow::ALL)
            .square_arrays(&[16, 8])
            .run();
        assert_eq!(out.points.len(), 2 * 3 * 2);
        assert_eq!(out.stats.points, 12);
        // nested order: workload outer, then dataflow, then array
        assert_eq!(out.points[0].workload, "a");
        assert_eq!(out.points[0].dataflow, Dataflow::Os);
        assert_eq!((out.points[0].array_h, out.points[1].array_h), (16, 8));
        assert_eq!(out.points[2].dataflow, Dataflow::Ws);
        assert_eq!(out.points[6].workload, "b");
    }

    #[test]
    fn repeated_layer_shapes_hit_the_cache() {
        let e = engine();
        let out = e.sweep().workload(&topo("t")).square_arrays(&[16]).run();
        // c1/c2 share a shape: 2 distinct sims, 1 hit
        assert_eq!(out.stats.memo.layer_sims, 2);
        assert_eq!(out.stats.memo.cache_hits, 1);
        assert!(out.stats.hit_rate() > 0.3);
        // reports still name both layers
        let r = &out.points[0].report;
        assert_eq!(r.layers[1].name(), "c2");
        assert_eq!(r.layers[0].timing, r.layers[1].timing);
    }

    #[test]
    fn rerunning_the_same_grid_is_fully_cached() {
        let e = engine();
        let first = e.sweep().workload(&topo("t")).square_arrays(&[16, 8]).run();
        let second = e.sweep().workload(&topo("t")).square_arrays(&[16, 8]).run();
        assert_eq!(second.stats.memo.layer_sims, 0, "second run must be 100% cached");
        assert!(second.stats.hit_rate() > 0.999);
        for (a, b) in first.points.iter().zip(&second.points) {
            assert_eq!(a.report, b.report);
        }
    }

    #[test]
    fn single_point_grid_defaults_to_engine_config() {
        let e = engine();
        let out = e.sweep().workload(&topo("t")).run();
        assert_eq!(out.points.len(), 1);
        let p = &out.points[0];
        assert_eq!((p.array_h, p.array_w), (128, 128));
        assert_eq!(p.dataflow, Dataflow::Os);
        assert_eq!(p.config(e.cfg()), *e.cfg());
    }

    #[test]
    fn workload_specs_lower_onto_the_grid_and_share_the_cache() {
        use crate::config::workloads;
        let e = engine();
        let out = e
            .sweep()
            .workloads(&[workloads::builtin("ncf").unwrap()])
            .workload_specs(&[workloads::builtin_gemm("ncf_gemm").unwrap()])
            .unwrap()
            .square_arrays(&[16])
            .run();
        assert_eq!(out.points.len(), 2);
        // ncf_gemm lowers to the exact tiles of conv-encoded ncf: the
        // second workload must be served entirely from the memo cache
        // (ncf itself repeats one shape, so only 4 distinct sims exist)
        assert_eq!(out.stats.memo.layer_sims, 4);
        assert!(out.stats.memo.cache_hits >= 6, "{:?}", out.stats.memo);
        assert_eq!(out.points[0].report.layers.len(), out.points[1].report.layers.len());
        for (a, b) in out.points[0].report.layers.iter().zip(&out.points[1].report.layers) {
            assert_eq!(a, b, "conv- and GEMM-encoded reports must be bit-identical");
        }
    }

    #[test]
    fn node_axis_multiplies_the_grid_and_single_node_matches_plain() {
        let e = engine();
        let t = topo("t");
        let plain = e.sweep().workload(&t).square_arrays(&[8]).run();
        let multi = e
            .sweep()
            .workload(&t)
            .square_arrays(&[8])
            .nodes(&[1, 4])
            .partitions(&[Partition::OutputChannels, Partition::Auto])
            .run();
        assert_eq!(multi.points.len(), 4);
        // nodes outer, partition inner, appended after the legacy axes
        assert_eq!(multi.points[0].nodes, 1);
        assert_eq!(multi.points[1].partition, Partition::Auto);
        assert_eq!(multi.points[2].nodes, 4);
        // single-node points are bit-identical to the plain grid
        assert_eq!(multi.points[0].report, plain.points[0].report);
        assert_eq!(multi.points[1].report, plain.points[0].report);
        assert_eq!(multi.points[0].total_pes(), 64);
        assert_eq!(multi.points[2].total_pes(), 256);
        // 4-node points really partitioned: aggregate DRAM differs from
        // one node's
        assert_ne!(multi.points[2].report.total_dram(), plain.points[0].report.total_dram());
    }

    #[test]
    fn fabric_axis_reports_link_bound_stalls() {
        let e = engine();
        let t = topo("t");
        let out = e
            .sweep()
            .workload(&t)
            .square_arrays(&[8])
            .nodes(&[16])
            .fabrics(&[FabricKind::Flat, FabricKind::Line])
            .link_bws(&[0.25])
            .run();
        assert_eq!(out.points.len(), 2);
        let (flat, line) = (&out.points[0], &out.points[1]);
        assert_eq!((flat.fabric, line.fabric), (FabricKind::Flat, FabricKind::Line));
        assert_eq!(line.link_bw, 0.25);
        // the grid models no shared DRAM bandwidth, so the flat point
        // cannot stall; the starved line fabric must
        assert_eq!(flat.stall_cycles, 0);
        assert!(line.stall_cycles > 0, "0.25 B/cycle links must starve 16 nodes");
        // fabric contention never changes the stall-free report
        assert_eq!(flat.report, line.report);
    }

    #[test]
    fn find_locates_points() {
        let e = engine();
        let out = e.sweep().workload(&topo("t")).square_arrays(&[16, 8]).run();
        assert!(out.find("t", Dataflow::Os, 8, 8).is_some());
        assert!(out.find("t", Dataflow::Ws, 8, 8).is_none());
    }

    #[test]
    fn find_sram_disambiguates_the_scratchpad_axis() {
        let e = engine();
        let out = e
            .sweep()
            .workload(&topo("t"))
            .square_arrays(&[16])
            .sram_sizes_kb(&[32, 64])
            .run();
        // the sram axis makes plain find() ambiguous...
        assert!(out.find("t", Dataflow::Os, 16, 16).is_none());
        // ...and find_sram pins the exact point
        let p = out.find_sram("t", Dataflow::Os, 16, 16, 64).unwrap();
        assert_eq!((p.ifmap_sram_kb, p.filter_sram_kb), (64, 64));
        assert!(out.find_sram("t", Dataflow::Os, 16, 16, 128).is_none());
    }
}
