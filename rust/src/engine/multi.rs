//! Multi-array scale-out as a first-class engine citizen (§IV-E,
//! Figs 9 & 10).
//!
//! The paper's scale-up vs scale-out study compares one big `√P x √P`
//! array against `P/64` replicated 8x8 nodes with the workload
//! partitioned across them. The original `scaleout` module computed that
//! comparison with hand-rolled closed forms — no memoization, no DSE
//! axis, no server path. This module promotes the multi-array system
//! into the engine:
//!
//! * [`MultiArrayConfig`] — `nodes` x `node_shape` arrays plus a
//!   [`Partition`] strategy. Each lowered [`LayerShape`] is split into
//!   per-node sub-shapes by [`split_layer`], **conserving MACs and OFMAP
//!   pixels exactly** (the trailing node takes the remainder share
//!   instead of rounding up).
//! * Every sub-shape runs through the engine's memoized
//!   [`Engine::run_layer_with`] path, so identical sub-shapes across
//!   nodes, sweep points, dse campaigns and `serve` clients share ONE
//!   memo table — an `Auto` partition point after its two fixed-strategy
//!   siblings is served entirely from cache.
//! * Node timings compose under the parallel-node model (slowest node
//!   bounds the layer; layers serialize), and a shared-DRAM contention
//!   model splits a finite DRAM bandwidth across the busy nodes and
//!   feeds each share through [`crate::memory::stall`] — the aggregate
//!   per-node demand the paper only tabulates is reported as the
//!   required interconnect bandwidth ([`MultiLayerReport::avg_bw`] /
//!   [`MultiLayerReport::peak_bw`]).
//!
//! The legacy `scaleout::compare_topology` closed forms survive as
//! bit-identical deprecated shims over [`Engine::compare_scaling_with`]
//! (pinned by the equivalence suite): the shim derives the legacy
//! quantities — full-share node cycles, full-share filter bytes times
//! used nodes — from the [`MultiLayerReport`] rather than recomputing
//! them.

use std::collections::BTreeMap;

use crate::arch::LayerShape;
use crate::config::{ArchConfig, Topology};
use crate::dram::DramConfig;
use crate::energy::EnergyBreakdown;
use crate::memory::{stall, BandwidthReport, DramTraffic};
use crate::sim::{LayerReport, WorkloadReport};
use crate::util::{ceil_div, isqrt};
use crate::{Error, Result};

use super::fabric::{self, FabricConfig, FabricLayerReport};
use super::Engine;

/// Scale-out node geometry used in the paper's study (8x8 tensor-core
/// style nodes).
pub const NODE_DIM: u64 = 8;
pub const NODE_PES: u64 = NODE_DIM * NODE_DIM;

/// The paper's PE-budget sweep: 64 PEs to 16384 PEs, x4 per step.
pub const PE_SWEEP: [u64; 5] = [64, 256, 1024, 4096, 16384];

/// Workload partitioning strategy across the nodes of a multi-array
/// system.
///
/// The paper's study uses output-channel partitioning but notes that
/// "alternate partitioning strategies exist, and in fact the best
/// strategy may differ from layer to layer depending on the number of
/// filters vs channels" (§IV-E).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Partition {
    /// Split filters across nodes (the paper's choice): each node
    /// produces different output channels.
    #[default]
    OutputChannels,
    /// Split output pixels (ofmap rows) across nodes: each node produces
    /// all channels for a horizontal stripe of the OFMAP. Every node
    /// fetches the FULL filter set — weight duplication is the price.
    Pixels,
    /// Per layer, pick whichever fixed strategy is faster — by total
    /// runtime including shared-DRAM stalls when a bandwidth is
    /// modeled, by stall-free cycles otherwise (ties go to
    /// `OutputChannels`, matching the legacy closed forms, which never
    /// model a shared bandwidth).
    Auto,
}

impl Partition {
    pub const ALL: [Partition; 3] =
        [Partition::OutputChannels, Partition::Pixels, Partition::Auto];

    pub fn name(&self) -> &'static str {
        match self {
            Partition::OutputChannels => "channels",
            Partition::Pixels => "pixels",
            Partition::Auto => "auto",
        }
    }

    /// Parse the wire/CLI spelling (the `name()` strings).
    pub fn parse(s: &str) -> Result<Partition> {
        match s {
            "channels" => Ok(Partition::OutputChannels),
            "pixels" => Ok(Partition::Pixels),
            "auto" => Ok(Partition::Auto),
            other => Err(Error::Config(format!(
                "unknown partition {other:?} (channels|pixels|auto)"
            ))),
        }
    }
}

/// A partitioned multi-array system: `nodes` replicas of a
/// `node_shape.0 x node_shape.1` array, each keeping the base config's
/// scratchpad sizes (as in the paper), with layers split across nodes by
/// `partition`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MultiArrayConfig {
    pub nodes: u64,
    pub node_shape: (u64, u64),
    pub partition: Partition,
}

impl MultiArrayConfig {
    pub fn new(nodes: u64, node_h: u64, node_w: u64, partition: Partition) -> Self {
        MultiArrayConfig { nodes, node_shape: (node_h, node_w), partition }
    }

    /// The paper's scale-out side for one PE budget: `budget/64` nodes
    /// of 8x8, output-channel partitioning.
    pub fn paper(pe_budget: u64) -> Self {
        MultiArrayConfig::new(pe_budget / NODE_PES, NODE_DIM, NODE_DIM, Partition::default())
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 {
            return Err(Error::Config("multi-array config needs >= 1 node".into()));
        }
        if self.node_shape.0 == 0 || self.node_shape.1 == 0 {
            return Err(Error::Config("node array dimensions must be positive".into()));
        }
        Ok(())
    }

    /// One node's architecture: the base config with the node's array
    /// shape (scratchpads and word size stay per-node, as in the paper).
    pub fn node_cfg(&self, base: &ArchConfig) -> ArchConfig {
        ArchConfig { array_h: self.node_shape.0, array_w: self.node_shape.1, ..base.clone() }
    }

    /// PEs across the whole system.
    pub fn total_pes(&self) -> u64 {
        self.nodes * self.node_shape.0 * self.node_shape.1
    }
}

/// Options for a multi-array run beyond the partitioning itself.
///
/// `MultiOpts::default()` is the legacy analytical model — no
/// shared-bandwidth stalls, no fabric, no banked DRAM — and reproduces
/// every pre-fabric code path bit-for-bit. The route-aware fabric and
/// the tick-driven banked DRAM substrate are strictly opt-in.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MultiOpts {
    /// Finite shared DRAM read bandwidth (bytes/cycle); `None` simulates
    /// stall-free. Without a fabric the bandwidth splits equally across
    /// the busy nodes; with one it splits demand-proportionally and
    /// competes with per-link contention.
    pub shared_dram_bw: Option<f64>,
    /// Route-aware interconnect model; `None` (or `FabricKind::Flat`)
    /// keeps the legacy equal-split contention.
    pub fabric: Option<FabricConfig>,
    /// Banked tick-driven DRAM replay attached to the fabric report
    /// (only consulted when `fabric` selects a real topology).
    pub dram: Option<DramConfig>,
}

impl MultiOpts {
    /// The legacy surface: only the equal-split shared bandwidth.
    pub fn with_shared_bw(shared_dram_bw: Option<f64>) -> Self {
        MultiOpts { shared_dram_bw, ..MultiOpts::default() }
    }
}

/// One node-group of a partitioned layer: `count` nodes each running the
/// same per-node sub-shape.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeShare {
    pub layer: LayerShape,
    pub count: u64,
}

/// Split one layer across `nodes` nodes under a **fixed** strategy
/// (`Auto` is resolved by the engine, which can compare timings).
///
/// Returns 1 or 2 groups: the maximal share (first, on `count` nodes)
/// and, when the axis does not divide evenly, one trailing remainder
/// share — so the groups conserve total MACs and OFMAP pixels *exactly*,
/// and every returned share is non-empty. Nodes beyond the returned
/// counts are explicitly idle (`used < nodes`).
///
/// Panics on `nodes == 0` or `partition == Auto`.
pub fn split_layer(layer: &LayerShape, nodes: u64, partition: Partition) -> Vec<NodeShare> {
    assert!(nodes > 0, "split_layer needs >= 1 node");
    if nodes == 1 {
        // the single node runs the layer exactly as a plain engine
        // would — in particular, a pixel "stripe" of the whole OFMAP
        // must not trim stride-unreachable bottom ifmap rows, or a
        // 1-node system would deviate from the single-array model
        return vec![NodeShare { layer: layer.clone(), count: 1 }];
    }
    match partition {
        Partition::OutputChannels => {
            let per = ceil_div(layer.num_filters, nodes);
            let full = layer.num_filters / per;
            let rem = layer.num_filters % per;
            let mut out = vec![NodeShare {
                layer: LayerShape { num_filters: per, ..layer.clone() },
                count: full,
            }];
            if rem > 0 {
                out.push(NodeShare {
                    layer: LayerShape { num_filters: rem, ..layer.clone() },
                    count: 1,
                });
            }
            out
        }
        Partition::Pixels => {
            let rows = layer.ofmap_h();
            let per = ceil_div(rows, nodes);
            let full = rows / per;
            let rem = rows % per;
            // a stripe of `r` output rows needs (r-1)*stride + filt_h
            // ifmap rows (valid padding)
            let stripe = |r: u64| LayerShape {
                ifmap_h: (r - 1) * layer.stride + layer.filt_h,
                ..layer.clone()
            };
            let mut out = vec![NodeShare { layer: stripe(per), count: full }];
            if rem > 0 {
                out.push(NodeShare { layer: stripe(rem), count: 1 });
            }
            out
        }
        Partition::Auto => unreachable!("Auto must be resolved before split_layer"),
    }
}

/// One layer simulated across a multi-array system.
#[derive(Clone, Debug, PartialEq)]
pub struct MultiLayerReport {
    /// The original (unsplit) layer.
    pub layer: LayerShape,
    /// The strategy actually used (`Auto` resolves to a fixed one).
    pub partition: Partition,
    /// Nodes that received work / sat idle.
    pub used_nodes: u64,
    pub idle_nodes: u64,
    /// Engine report of the maximal per-node share (bounds the runtime;
    /// `node_count` nodes run it).
    pub node_report: LayerReport,
    pub node_count: u64,
    /// The trailing smaller share, when the partition axis does not
    /// divide evenly (always on exactly one node).
    pub remainder: Option<LayerReport>,
    /// Stall-free layer runtime: the slowest node (nodes run in
    /// parallel).
    pub cycles: u64,
    /// Extra cycles until the last node finishes under the shared DRAM
    /// bandwidth / fabric contention, beyond the stall-free runtime
    /// (0 when simulated without a bandwidth).
    pub stall_cycles: u64,
    /// Per-link traffic report when the route-aware fabric model ran
    /// (`None` on the legacy equal-split path).
    pub fabric: Option<FabricLayerReport>,
}

impl MultiLayerReport {
    /// Aggregate DRAM traffic across every node (exact remainder
    /// accounting — unlike the legacy closed forms, the trailing node
    /// only fetches its own share).
    pub fn dram(&self) -> DramTraffic {
        let mut t = DramTraffic {
            ifmap_bytes: self.node_report.dram.ifmap_bytes * self.node_count,
            filter_bytes: self.node_report.dram.filter_bytes * self.node_count,
            ofmap_bytes: self.node_report.dram.ofmap_bytes * self.node_count,
        };
        if let Some(r) = &self.remainder {
            t.ifmap_bytes += r.dram.ifmap_bytes;
            t.filter_bytes += r.dram.filter_bytes;
            t.ofmap_bytes += r.dram.ofmap_bytes;
        }
        t
    }

    /// Aggregate energy across every node.
    pub fn energy(&self) -> EnergyBreakdown {
        let n = self.node_count as f64;
        let mut e = EnergyBreakdown {
            compute_mj: self.node_report.energy.compute_mj * n,
            sram_mj: self.node_report.energy.sram_mj * n,
            dram_mj: self.node_report.energy.dram_mj * n,
        };
        if let Some(r) = &self.remainder {
            e.compute_mj += r.energy.compute_mj;
            e.sram_mj += r.energy.sram_mj;
            e.dram_mj += r.energy.dram_mj;
        }
        e
    }

    /// Average interconnect (shared-DRAM) read bandwidth this layer
    /// demands: aggregate read bytes over the layer's runtime —
    /// the quantity the paper tabulates but never models.
    pub fn avg_bw(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.dram().read_bytes() as f64 / self.cycles as f64
    }

    /// Peak interconnect read bandwidth: every node bursts its own peak
    /// concurrently, so the per-node peaks sum.
    pub fn peak_bw(&self) -> f64 {
        let mut bw = self.node_report.bandwidth.peak_read_bw * self.node_count as f64;
        if let Some(r) = &self.remainder {
            bw += r.bandwidth.peak_read_bw;
        }
        bw
    }

    /// Total runtime including shared-DRAM stalls.
    pub fn total_cycles(&self) -> u64 {
        self.cycles + self.stall_cycles
    }
}

/// A whole topology simulated across a multi-array system.
#[derive(Clone, Debug, PartialEq)]
pub struct MultiWorkloadReport {
    pub workload: String,
    pub multi: MultiArrayConfig,
    pub layers: Vec<MultiLayerReport>,
}

impl MultiWorkloadReport {
    /// Stall-free runtime: per-layer slowest nodes, layers serialized.
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_stall_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.stall_cycles).sum()
    }

    pub fn total_dram(&self) -> DramTraffic {
        let mut t = DramTraffic::default();
        for l in &self.layers {
            let d = l.dram();
            t.ifmap_bytes += d.ifmap_bytes;
            t.filter_bytes += d.filter_bytes;
            t.ofmap_bytes += d.ofmap_bytes;
        }
        t
    }

    pub fn total_energy(&self) -> EnergyBreakdown {
        let mut e = EnergyBreakdown::default();
        for l in &self.layers {
            let le = l.energy();
            e.compute_mj += le.compute_mj;
            e.sram_mj += le.sram_mj;
            e.dram_mj += le.dram_mj;
        }
        e
    }

    /// Average required interconnect read bandwidth over the whole run.
    pub fn avg_interconnect_bw(&self) -> f64 {
        let cycles = self.total_cycles();
        if cycles == 0 {
            return 0.0;
        }
        self.total_dram().read_bytes() as f64 / cycles as f64
    }

    /// Worst per-layer interconnect burst across the run.
    pub fn peak_interconnect_bw(&self) -> f64 {
        self.layers.iter().map(MultiLayerReport::peak_bw).fold(0.0, f64::max)
    }

    /// System-level utilization: MACs over `total PEs x runtime` (idle
    /// nodes count against it, exactly like idle rows of a big array).
    pub fn utilization(&self) -> f64 {
        let denom = self.multi.total_pes() * self.total_cycles();
        if denom == 0 {
            return 0.0;
        }
        let macs: u64 = self.layers.iter().map(|l| l.layer.macs()).sum();
        macs as f64 / denom as f64
    }

    /// Collapse into the single-array report shape (what the sweep grid,
    /// the serve protocol and the CLI tables carry): per layer the
    /// slowest node's timing, aggregate DRAM traffic/energy, and the
    /// summed interconnect bandwidths. A single-node system returns the
    /// plain engine report bit-for-bit.
    pub fn to_workload_report(&self) -> WorkloadReport {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                if l.used_nodes == 1 && l.remainder.is_none() && l.node_report.layer == l.layer
                {
                    return l.node_report.clone();
                }
                let dram = l.dram();
                let slowest = match &l.remainder {
                    Some(r) if r.timing.cycles > l.node_report.timing.cycles => &r.timing,
                    _ => &l.node_report.timing,
                };
                LayerReport {
                    layer: l.layer.clone(),
                    timing: slowest.clone(),
                    dram,
                    bandwidth: BandwidthReport {
                        avg_read_bw: if l.cycles == 0 {
                            0.0
                        } else {
                            dram.read_bytes() as f64 / l.cycles as f64
                        },
                        avg_write_bw: if l.cycles == 0 {
                            0.0
                        } else {
                            dram.ofmap_bytes as f64 / l.cycles as f64
                        },
                        peak_read_bw: l.peak_bw(),
                    },
                    energy: l.energy(),
                }
            })
            .collect();
        WorkloadReport { workload: self.workload.clone(), layers }
    }
}

impl Engine {
    /// Simulate one layer across a partitioned multi-array system under
    /// an arbitrary base configuration. Every per-node sub-shape goes
    /// through the memoized [`Engine::run_layer_with`] path; with
    /// `shared_dram_bw` the finite bandwidth is split equally across the
    /// busy nodes (per-node demands sum against the shared interface)
    /// and the slowest node's share replays through
    /// [`crate::memory::stall`].
    pub fn run_multi_layer_with(
        &self,
        cfg: &ArchConfig,
        layer: &LayerShape,
        multi: &MultiArrayConfig,
        shared_dram_bw: Option<f64>,
    ) -> MultiLayerReport {
        self.run_multi_layer_opts(cfg, layer, multi, &MultiOpts::with_shared_bw(shared_dram_bw))
    }

    /// [`Engine::run_multi_layer_with`] with the full option surface:
    /// route-aware fabric contention and the banked DRAM replay.
    pub fn run_multi_layer_opts(
        &self,
        cfg: &ArchConfig,
        layer: &LayerShape,
        multi: &MultiArrayConfig,
        opts: &MultiOpts,
    ) -> MultiLayerReport {
        assert!(multi.nodes > 0, "multi-array config needs >= 1 node");
        let node_cfg = multi.node_cfg(cfg);
        match multi.partition {
            Partition::Auto => {
                let a = self.multi_fixed(
                    &node_cfg,
                    layer,
                    multi.nodes,
                    Partition::OutputChannels,
                    opts,
                );
                let b = self.multi_fixed(&node_cfg, layer, multi.nodes, Partition::Pixels, opts);
                // compare total runtime (== stall-free cycles when no
                // shared bandwidth is modeled, so the legacy closed
                // forms — which never model one — stay bit-identical);
                // ties go to channels, matching them too
                if b.total_cycles() < a.total_cycles() {
                    b
                } else {
                    a
                }
            }
            p => self.multi_fixed(&node_cfg, layer, multi.nodes, p, opts),
        }
    }

    fn multi_fixed(
        &self,
        node_cfg: &ArchConfig,
        layer: &LayerShape,
        nodes: u64,
        partition: Partition,
        opts: &MultiOpts,
    ) -> MultiLayerReport {
        let shares = split_layer(layer, nodes, partition);
        let node_report = self.run_layer_with(node_cfg, &shares[0].layer);
        let node_count = shares[0].count;
        let remainder = shares.get(1).map(|s| self.run_layer_with(node_cfg, &s.layer));
        let used_nodes = node_count + remainder.is_some() as u64;
        let cycles = match &remainder {
            Some(r) => node_report.timing.cycles.max(r.timing.cycles),
            None => node_report.timing.cycles,
        };
        let route_aware =
            opts.fabric.and_then(|fc| fabric::topology(fc.kind, used_nodes).map(|t| (fc, t)));
        let (stall_cycles, fabric) = match route_aware {
            Some((fc, topo)) => {
                let (stall, report) = self.fabric_stalls(
                    node_cfg,
                    &shares,
                    &node_report,
                    remainder.as_ref(),
                    cycles,
                    fc,
                    opts,
                    topo.as_ref(),
                );
                (stall, Some(report))
            }
            None => {
                // shared DRAM: the busy nodes' demands sum against one
                // interface, so each gets an equal share; every share's
                // fold/fetch schedule replays against it and the layer
                // stalls with whichever node finishes LAST — not
                // unconditionally the maximal share (under an equal
                // split the maximal share provably dominates, but the
                // selection must not bake that assumption in)
                let stall = match opts.shared_dram_bw {
                    Some(bw) => {
                        let share_bw = bw / used_nodes as f64;
                        let df = node_cfg.dataflow;
                        let mut completion =
                            stall::stalled_runtime(df, &shares[0].layer, node_cfg, share_bw)
                                .total_cycles();
                        if let Some(s) = shares.get(1) {
                            completion = completion.max(
                                stall::stalled_runtime(df, &s.layer, node_cfg, share_bw)
                                    .total_cycles(),
                            );
                        }
                        completion.saturating_sub(cycles)
                    }
                    None => 0,
                };
                (stall, None)
            }
        };
        MultiLayerReport {
            layer: layer.clone(),
            partition,
            used_nodes,
            idle_nodes: nodes - used_nodes,
            node_report,
            node_count,
            remainder,
            cycles,
            stall_cycles,
            fabric,
        }
    }

    /// Route-aware contention: place the `count` main-share nodes on
    /// fabric nodes `0..count` (nearest the memory controller at node 0)
    /// and the remainder share on the farthest node, derive each node's
    /// effective read bandwidth from the per-link loads, replay every
    /// distinct (share, bandwidth) pair through the stall model, and
    /// report per-link traffic. Returns the layer's stall cycles (the
    /// slowest stalled completion minus the stall-free runtime) plus the
    /// fabric report.
    #[allow(clippy::too_many_arguments)]
    fn fabric_stalls(
        &self,
        node_cfg: &ArchConfig,
        shares: &[NodeShare],
        node_report: &LayerReport,
        remainder: Option<&LayerReport>,
        cycles: u64,
        fc: FabricConfig,
        opts: &MultiOpts,
        topo: &dyn fabric::Topology,
    ) -> (u64, FabricLayerReport) {
        let node_count = shares[0].count as usize;
        let mut demands = vec![node_report.dram.read_bytes(); node_count];
        let mut ideal_cycles = vec![node_report.timing.cycles; node_count];
        let mut peaks = vec![node_report.bandwidth.peak_read_bw; node_count];
        if let Some(r) = remainder {
            demands.push(r.dram.read_bytes());
            ideal_cycles.push(r.timing.cycles);
            peaks.push(r.bandwidth.peak_read_bw);
        }
        let cont = fabric::contention(topo, fc.link_bw, opts.shared_dram_bw, &demands);
        // replay each node's fold/fetch schedule at its effective
        // bandwidth; identical (share, bandwidth) pairs replay once
        let mut memo: BTreeMap<(bool, u64), u64> = BTreeMap::new();
        let mut node_total_cycles = Vec::with_capacity(cont.eff_bw.len());
        let mut completion = 0u64;
        let mut slowest = 0usize;
        for (j, eff) in cont.eff_bw.iter().enumerate() {
            let is_rem = j >= node_count;
            let total = match eff {
                Some(b) => *memo.entry((is_rem, b.to_bits())).or_insert_with(|| {
                    let l = if is_rem { &shares[1].layer } else { &shares[0].layer };
                    stall::stalled_runtime(node_cfg.dataflow, l, node_cfg, *b).total_cycles()
                }),
                None => *ideal_cycles.get(j).unwrap_or(&0),
            };
            node_total_cycles.push(total);
            if total > completion {
                completion = total;
                slowest = j;
            }
        }
        let stall_cycles = completion.saturating_sub(cycles);
        let total_cycles = cycles + stall_cycles;
        let link_avg_bw = cont
            .link_bytes
            .iter()
            .map(|&b| if total_cycles == 0 { 0.0 } else { b as f64 / total_cycles as f64 })
            .collect();
        // peak per link: every flow crossing it bursts its node's peak
        // concurrently
        let mut link_peak_bw = vec![0.0f64; cont.link_bytes.len()];
        for (j, route) in cont.routes.iter().enumerate() {
            for &l in route {
                if let Some(p) = link_peak_bw.get_mut(l) {
                    *p += peaks[j];
                }
            }
        }
        // banked tick-driven DRAM replay of the slowest node's share
        let dram = opts.dram.map(|dcfg| {
            let l = if slowest >= node_count && shares.len() > 1 {
                &shares[1].layer
            } else {
                &shares[0].layer
            };
            crate::dram::banked_replay_layer(
                node_cfg.dataflow,
                l,
                node_cfg,
                dcfg,
                crate::dram::DEFAULT_QUEUE_CAP,
            )
        });
        crate::obs::metrics::count_fabric_layer();
        let report = FabricLayerReport {
            kind: fc.kind,
            link_bw: fc.link_bw,
            placed_nodes: demands.len() as u64,
            link_bytes: cont.link_bytes,
            link_avg_bw,
            link_peak_bw,
            hop_bytes: cont.hop_bytes,
            node_total_cycles,
            dram,
        };
        (stall_cycles, report)
    }

    /// Simulate a whole topology across a multi-array system under an
    /// arbitrary base configuration.
    pub fn run_multi_with(
        &self,
        cfg: &ArchConfig,
        topo: &Topology,
        multi: &MultiArrayConfig,
        shared_dram_bw: Option<f64>,
    ) -> MultiWorkloadReport {
        self.run_multi_opts(cfg, topo, multi, &MultiOpts::with_shared_bw(shared_dram_bw))
    }

    /// [`Engine::run_multi_with`] with the full option surface (fabric
    /// contention, banked DRAM replay).
    pub fn run_multi_opts(
        &self,
        cfg: &ArchConfig,
        topo: &Topology,
        multi: &MultiArrayConfig,
        opts: &MultiOpts,
    ) -> MultiWorkloadReport {
        MultiWorkloadReport {
            workload: topo.name.clone(),
            multi: *multi,
            layers: topo
                .layers
                .iter()
                .map(|l| self.run_multi_layer_opts(cfg, l, multi, opts))
                .collect(),
        }
    }

    /// Simulate a topology across a multi-array system under the
    /// engine's base configuration (no shared-bandwidth stall model).
    pub fn run_multi(&self, topo: &Topology, multi: &MultiArrayConfig) -> MultiWorkloadReport {
        self.run_multi_with(&self.cfg, topo, multi, None)
    }

    /// Lower a typed workload ([`crate::workload::Workload`]) and run it
    /// across a multi-array system — the front-end form of
    /// [`Engine::run_multi`].
    pub fn run_multi_workload(
        &self,
        workload: &crate::workload::Workload,
        multi: &MultiArrayConfig,
    ) -> Result<MultiWorkloadReport> {
        Ok(self.run_multi(&workload.lower()?, multi))
    }

    /// Scale-up vs scale-out comparison (§IV-E, Figs 9/10) under the
    /// engine's base configuration and a chosen partition strategy: one
    /// `√budget x √budget` array vs `budget/64` 8x8 nodes. Preserves the
    /// legacy closed forms' arithmetic exactly (full-share node cycles;
    /// full-share filter bytes times used nodes), so the deprecated
    /// `scaleout` shims stay bit-identical.
    pub fn compare_scaling_with(
        &self,
        layers: &[LayerShape],
        pe_budget: u64,
        partition: Partition,
    ) -> ScaleComparison {
        assert!(pe_budget >= NODE_PES, "budget below one node");
        let up_cfg = scale_up_cfg(&self.cfg, pe_budget);
        let multi = MultiArrayConfig::paper(pe_budget);
        let mut up_cycles = 0u64;
        let mut out_cycles = 0u64;
        let mut up_weight_bytes = 0f64;
        let mut out_weight_bytes = 0f64;
        for layer in layers {
            let up = self.run_layer_with(&up_cfg, layer);
            let m = self.run_multi_layer_with(
                &self.cfg,
                layer,
                &MultiArrayConfig { partition, ..multi },
                None,
            );
            // the legacy view: every used node fetches (and runs) the
            // full per-node share
            let out_c = m.node_report.timing.cycles;
            let out_bytes = m.node_report.dram.filter_bytes * m.used_nodes;
            let up_weight_bw = up.dram.filter_bytes as f64 / up.timing.cycles as f64;
            let out_weight_bw = out_bytes as f64 / out_c as f64;
            up_cycles += up.timing.cycles;
            out_cycles += out_c;
            up_weight_bytes += up_weight_bw * up.timing.cycles as f64;
            out_weight_bytes += out_weight_bw * out_c as f64;
        }
        ScaleComparison {
            pe_budget,
            nodes: multi.nodes,
            up_cycles,
            out_cycles,
            up_weight_bw: up_weight_bytes / up_cycles as f64,
            out_weight_bw: out_weight_bytes / out_cycles as f64,
        }
    }

    /// The paper's comparison: output-channel partitioning.
    pub fn compare_scaling(&self, layers: &[LayerShape], pe_budget: u64) -> ScaleComparison {
        self.compare_scaling_with(layers, pe_budget, Partition::OutputChannels)
    }
}

/// Scale-up configuration: one square array of `pe_budget` PEs.
///
/// Panics if `pe_budget` is not a perfect square (the paper's sweep uses
/// 64 * 4^i, always square).
pub fn scale_up_cfg(base: &ArchConfig, pe_budget: u64) -> ArchConfig {
    let dim = isqrt(pe_budget);
    assert_eq!(dim * dim, pe_budget, "PE budget {pe_budget} is not square");
    ArchConfig { array_h: dim, array_w: dim, ..base.clone() }
}

/// Result of one scale-up vs scale-out comparison point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleComparison {
    pub pe_budget: u64,
    pub nodes: u64,
    /// Runtime on the single big array.
    pub up_cycles: u64,
    /// Runtime of the slowest node (nodes run in parallel).
    pub out_cycles: u64,
    /// DRAM bandwidth demanded for *weights*, bytes/cycle (Fig 10).
    pub up_weight_bw: f64,
    pub out_weight_bw: f64,
}

impl ScaleComparison {
    /// Fig 9's y-axis: runtime(scale-up) / runtime(scale-out);
    /// < 1 means scale-up wins.
    pub fn runtime_ratio(&self) -> f64 {
        self.up_cycles as f64 / self.out_cycles as f64
    }

    /// Fig 10's y-axis: weight-bandwidth(up) / weight-bandwidth(out).
    pub fn weight_bw_ratio(&self) -> f64 {
        self.up_weight_bw / self.out_weight_bw
    }
}

/// One `scale-sim scaleout` table row: the Fig 9/10 comparison plus the
/// interconnect-bandwidth numbers only the engine path can report.
#[derive(Clone, Debug)]
pub struct ScaleoutPoint {
    pub workload: String,
    pub partition: Partition,
    pub comparison: ScaleComparison,
    /// Required interconnect read bandwidth of the scale-out side
    /// (aggregate across nodes), average over the run and worst layer
    /// burst.
    pub interconnect_avg_bw: f64,
    pub interconnect_peak_bw: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::Dataflow;

    fn engine(df: Dataflow) -> Engine {
        Engine::new(ArchConfig { dataflow: df, ..config::paper_default() })
    }

    #[test]
    fn split_conserves_macs_and_ofmap_pixels_exactly() {
        let l = LayerShape::conv("c", 30, 30, 3, 3, 8, 100, 1);
        for nodes in [1u64, 2, 3, 7, 16, 64, 1000] {
            for p in [Partition::OutputChannels, Partition::Pixels] {
                let shares = split_layer(&l, nodes, p);
                let macs: u64 = shares.iter().map(|s| s.count * s.layer.macs()).sum();
                let ofmap: u64 =
                    shares.iter().map(|s| s.count * s.layer.ofmap_elems()).sum();
                assert_eq!(macs, l.macs(), "{p:?} nodes={nodes}");
                assert_eq!(ofmap, l.ofmap_elems(), "{p:?} nodes={nodes}");
                let used: u64 = shares.iter().map(|s| s.count).sum();
                assert!(used <= nodes);
                assert!(shares.iter().all(|s| s.count >= 1));
            }
        }
    }

    #[test]
    fn uneven_split_puts_the_remainder_on_one_node() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 100, 1);
        let shares = split_layer(&l, 16, Partition::OutputChannels);
        assert_eq!(shares.len(), 2);
        assert_eq!((shares[0].layer.num_filters, shares[0].count), (7, 14));
        assert_eq!((shares[1].layer.num_filters, shares[1].count), (2, 1));
    }

    #[test]
    fn single_node_multi_is_the_plain_engine_bit_for_bit() {
        let e = engine(Dataflow::Os);
        let l = LayerShape::conv("c", 28, 28, 3, 3, 16, 32, 1);
        for p in Partition::ALL {
            let multi = MultiArrayConfig::new(1, 16, 16, p);
            let m = e.run_multi_layer_with(e.cfg(), &l, &multi, None);
            let plain =
                e.run_layer_with(&ArchConfig { array_h: 16, array_w: 16, ..e.cfg().clone() }, &l);
            assert_eq!(m.node_report, plain, "{p:?}");
            assert_eq!(m.cycles, plain.timing.cycles);
            assert_eq!((m.used_nodes, m.idle_nodes), (1, 0));
            assert!(m.remainder.is_none());
            assert_eq!(m.dram(), plain.dram);
        }
        let topo = Topology::new("t", vec![l]);
        let multi = MultiArrayConfig::new(1, 16, 16, Partition::Auto);
        let wr = e.run_multi(&topo, &multi).to_workload_report();
        let plain = e.run_topology_with(
            &ArchConfig { array_h: 16, array_w: 16, ..e.cfg().clone() },
            &topo,
        );
        assert_eq!(wr, plain);
    }

    #[test]
    fn run_multi_workload_lowers_and_matches_run_multi() {
        use crate::workload::{Conv2d, Workload};
        let wl = Workload::builder("w")
            .conv2d(
                "c1",
                Conv2d {
                    ifmap_h: 16,
                    ifmap_w: 16,
                    in_channels: 4,
                    out_channels: 8,
                    kernel_h: 3,
                    kernel_w: 3,
                    ..Conv2d::default()
                },
            )
            .build()
            .unwrap();
        let e = engine(Dataflow::Os);
        let multi = MultiArrayConfig::new(4, 16, 16, Partition::OutputChannels);
        let out = e.run_multi_workload(&wl, &multi).unwrap();
        assert_eq!(out, e.run_multi(&wl.lower().unwrap(), &multi));
    }

    #[test]
    fn auto_resolves_to_the_faster_fixed_strategy() {
        let e = engine(Dataflow::Os);
        for l in [
            LayerShape::conv("fewfilt", 64, 64, 3, 3, 32, 8, 1),
            LayerShape::conv("deep", 19, 19, 3, 3, 256, 256, 1),
            LayerShape::fc("fc", 4, 512, 512),
        ] {
            let mk = |p| MultiArrayConfig::new(64, NODE_DIM, NODE_DIM, p);
            let auto = e.run_multi_layer_with(e.cfg(), &l, &mk(Partition::Auto), None);
            let ch = e.run_multi_layer_with(e.cfg(), &l, &mk(Partition::OutputChannels), None);
            let px = e.run_multi_layer_with(e.cfg(), &l, &mk(Partition::Pixels), None);
            assert_eq!(auto.cycles, ch.cycles.min(px.cycles), "{}", l.name);
            assert_ne!(auto.partition, Partition::Auto, "Auto must resolve");
        }
    }

    #[test]
    fn auto_under_shared_dram_picks_the_faster_total_runtime() {
        // pixel partitioning duplicates the filter set on every node, so
        // under a tight shared bandwidth its stalls can outweigh a small
        // stall-free advantage — Auto must rank by TOTAL runtime
        let e = engine(Dataflow::Os);
        for l in [
            LayerShape::conv("fewfilt", 64, 64, 3, 3, 32, 8, 1),
            LayerShape::conv("deep", 19, 19, 3, 3, 256, 256, 1),
            LayerShape::conv("wide", 60, 60, 3, 3, 24, 100, 1),
        ] {
            for bw in [2.0, 16.0] {
                let mk = |p| MultiArrayConfig::new(64, NODE_DIM, NODE_DIM, p);
                let auto =
                    e.run_multi_layer_with(e.cfg(), &l, &mk(Partition::Auto), Some(bw));
                let ch = e.run_multi_layer_with(
                    e.cfg(),
                    &l,
                    &mk(Partition::OutputChannels),
                    Some(bw),
                );
                let px =
                    e.run_multi_layer_with(e.cfg(), &l, &mk(Partition::Pixels), Some(bw));
                assert_eq!(
                    auto.total_cycles(),
                    ch.total_cycles().min(px.total_cycles()),
                    "{} bw={bw}",
                    l.name
                );
            }
        }
    }

    #[test]
    fn shared_dram_contention_stalls_grow_with_node_count() {
        // the same total bandwidth split across more busy nodes starves
        // each node harder
        let e = engine(Dataflow::Os);
        let l = LayerShape::conv("c", 64, 64, 3, 3, 32, 256, 1);
        let mut last = 0u64;
        for nodes in [4u64, 16, 64] {
            let multi = MultiArrayConfig::new(nodes, NODE_DIM, NODE_DIM, Partition::Pixels);
            let m = e.run_multi_layer_with(e.cfg(), &l, &multi, Some(16.0));
            assert!(m.stall_cycles >= last, "nodes={nodes}");
            last = m.stall_cycles;
        }
        assert!(last > 0, "64 nodes on 16 B/cyc must stall");
        // and without a bandwidth there are no stalls
        let multi = MultiArrayConfig::new(64, NODE_DIM, NODE_DIM, Partition::Pixels);
        assert_eq!(e.run_multi_layer_with(e.cfg(), &l, &multi, None).stall_cycles, 0);
    }

    #[test]
    fn identical_shares_across_nodes_hit_the_memo_cache() {
        let e = engine(Dataflow::Os);
        let l = LayerShape::conv("c", 30, 30, 3, 3, 16, 64, 1);
        let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::OutputChannels);
        let _ = e.run_multi_layer_with(e.cfg(), &l, &multi, None);
        let sims = e.cache_stats().layer_sims;
        // an even 64/16 split = one distinct sub-shape
        assert_eq!(sims, 1);
        // auto re-uses the channels entry and only adds the pixels one
        let auto = MultiArrayConfig { partition: Partition::Auto, ..multi };
        let _ = e.run_multi_layer_with(e.cfg(), &l, &auto, None);
        let stats = e.cache_stats();
        assert_eq!(stats.layer_sims, 2, "{stats:?}");
        assert!(stats.cache_hits >= 1, "{stats:?}");
    }

    #[test]
    fn aggregate_dram_accounts_the_remainder_exactly() {
        let e = engine(Dataflow::Os);
        // 100 filters over 16 nodes: 14 full nodes + 1 remainder node
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 100, 1);
        let multi = MultiArrayConfig::new(16, NODE_DIM, NODE_DIM, Partition::OutputChannels);
        let m = e.run_multi_layer_with(e.cfg(), &l, &multi, None);
        assert_eq!(m.used_nodes, 15);
        assert_eq!(m.idle_nodes, 1);
        let r = m.remainder.as_ref().unwrap();
        assert_eq!(
            m.dram().filter_bytes,
            m.node_report.dram.filter_bytes * 14 + r.dram.filter_bytes
        );
        // exact accounting is strictly below the legacy full-node
        // approximation
        assert!(m.dram().filter_bytes < m.node_report.dram.filter_bytes * 15);
    }

    #[test]
    fn compare_scaling_matches_across_partitions_and_budgets() {
        let topo = Topology::new(
            "t",
            vec![
                LayerShape::conv("a", 32, 32, 3, 3, 32, 64, 1),
                LayerShape::fc("fc", 4, 512, 512),
            ],
        );
        for df in Dataflow::ALL {
            let e = engine(df);
            for &pe in &PE_SWEEP {
                for p in Partition::ALL {
                    let c = e.compare_scaling_with(&topo.layers, pe, p);
                    assert!(c.up_cycles > 0 && c.out_cycles > 0);
                    assert!(c.runtime_ratio() > 0.0 && c.weight_bw_ratio() > 0.0);
                    assert_eq!(c.nodes, pe / NODE_PES);
                }
            }
        }
    }

    #[test]
    fn multi_config_validates() {
        assert!(MultiArrayConfig::new(0, 8, 8, Partition::Auto).validate().is_err());
        assert!(MultiArrayConfig::new(4, 0, 8, Partition::Auto).validate().is_err());
        assert!(MultiArrayConfig::new(4, 8, 8, Partition::Auto).validate().is_ok());
        assert_eq!(MultiArrayConfig::paper(1024).nodes, 16);
        assert_eq!(MultiArrayConfig::paper(1024).total_pes(), 1024);
        assert_eq!(Partition::parse("pixels").unwrap(), Partition::Pixels);
        assert!(Partition::parse("diag").is_err());
        for p in Partition::ALL {
            assert_eq!(Partition::parse(p.name()).unwrap(), p);
        }
    }
}
