//! Pluggable simulation backends — the fidelity axis of the engine.
//!
//! ## The `Backend` contract
//!
//! A backend derives the per-layer [`Timing`] under one architecture
//! configuration. Implementations MUST be cycle-exact with each other:
//! for any valid `(cfg, layer)` pair, every backend returns the same
//! `Timing` (the repo's validation story, Fig 4, extended to all three
//! dataflows). What differs is *how* the number is obtained — and
//! therefore the cost and the evidence level:
//!
//! * [`Analytical`] — closed-form fold arithmetic (§III-B tables),
//!   O(1) per layer. The default; what sweeps use.
//! * [`TraceDriven`] — streams the full cycle-accurate SRAM address
//!   trace (§III-E) through a counting sink, O(#SRAM events). The
//!   runtime and access counts are *measured from the trace*, not
//!   computed in closed form.
//! * [`Rtl`] — drives the register-level PE-grid simulators
//!   ([`crate::rtl`]) fold-shape by fold-shape, O(PEs x cycles) per
//!   distinct fold shape. Used by `scale-sim validate` and the
//!   equivalence suite.
//!
//! Backends must also be `Send + Sync`: the sweep grid calls them from
//! worker threads.
//!
//! Every backend consumes **lowered tiles** ([`LayerShape`], the
//! Table-II GEMM-tile encoding that [`crate::workload`]'s lowering pass
//! emits) — the IR's op vocabulary (Conv2d/Gemm/FC/Pool, dilation,
//! groups) never reaches a backend, which is why one IR drives all
//! three fidelity levels unchanged.
//!
//! DRAM traffic, bandwidth and energy are *not* part of the trait: they
//! are schedule-level properties shared by all fidelity levels, and the
//! engine derives them once from the common memory/energy models.

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::{self, Dataflow, Timing};
use crate::rtl;
use crate::util::ceil_div;
use crate::{Error, Result};

/// Which backend implementation an engine dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Closed-form analytical model (default).
    Analytical,
    /// Cycle-accurate SRAM trace generation + parsing.
    TraceDriven,
    /// Cycle-level PE-grid (RTL) simulation.
    Rtl,
    /// An out-of-crate `Backend` installed via
    /// `EngineBuilder::custom_backend` (the extension seam for future
    /// fidelity levels, e.g. banked-DRAM timing).
    Custom,
}

impl BackendKind {
    /// The built-in, CLI-selectable kinds (excludes [`BackendKind::Custom`]).
    pub const ALL: [BackendKind; 3] =
        [BackendKind::Analytical, BackendKind::TraceDriven, BackendKind::Rtl];

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_lowercase().as_str() {
            "analytical" | "analytic" | "model" => Ok(BackendKind::Analytical),
            "trace" | "trace_driven" | "trace-driven" => Ok(BackendKind::TraceDriven),
            "rtl" | "cycle" | "cycle_level" => Ok(BackendKind::Rtl),
            other => Err(Error::Config(format!(
                "unknown backend {other:?} (legal: analytical, trace, rtl)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Analytical => "analytical",
            BackendKind::TraceDriven => "trace",
            BackendKind::Rtl => "rtl",
            BackendKind::Custom => "custom",
        }
    }

    /// Instantiate the built-in implementation for this kind.
    ///
    /// Errors on [`BackendKind::Custom`]: it has no built-in
    /// implementation — supply the object via
    /// `EngineBuilder::custom_backend` instead (`build` rejects the
    /// kind without one, so the builder never reaches this error).
    pub fn instantiate(&self) -> Result<Box<dyn Backend>> {
        match self {
            BackendKind::Analytical => Ok(Box::new(Analytical)),
            BackendKind::TraceDriven => Ok(Box::new(TraceDriven)),
            BackendKind::Rtl => Ok(Box::new(Rtl::default())),
            BackendKind::Custom => Err(Error::Config(
                "BackendKind::Custom has no built-in implementation; use \
                 EngineBuilder::custom_backend"
                    .into(),
            )),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// A per-layer timing model at one fidelity level. See the module docs
/// for the cycle-exactness contract.
pub trait Backend: Send + Sync {
    /// Self-reported kind. The engine derives its `backend_kind()` and
    /// cache-key discriminant from this at build time, so it is the
    /// single source of truth for the backend's identity.
    fn kind(&self) -> BackendKind;

    /// Runtime + SRAM access counts for `layer` under `cfg`'s dataflow
    /// on `cfg`'s array.
    fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> Timing;
}

/// Closed-form analytical backend (§III-B).
#[derive(Clone, Copy, Debug, Default)]
pub struct Analytical;

impl Backend for Analytical {
    fn kind(&self) -> BackendKind {
        BackendKind::Analytical
    }

    fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> Timing {
        cfg.dataflow.timing(layer, cfg.array_h, cfg.array_w)
    }
}

/// Trace-driven backend: measure cycles and SRAM access counts from the
/// cycle-accurate address trace (§III-E step 2); fold geometry and
/// utilization derive from the measured runtime.
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceDriven;

impl Backend for TraceDriven {
    fn kind(&self) -> BackendKind {
        BackendKind::TraceDriven
    }

    fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> Timing {
        let (rows, cols) = (cfg.array_h, cfg.array_w);
        let s = crate::trace::summarize(cfg.dataflow, layer, cfg);
        let (npx, k, nf) = layer.gemm_view();
        let (total_r, total_c) = fold_dims(cfg.dataflow, npx, k, nf);
        let cycles = s.cycles();
        Timing {
            cycles,
            row_folds: ceil_div(total_r, rows),
            col_folds: ceil_div(total_c, cols),
            utilization: layer.macs() as f64 / (rows * cols * cycles) as f64,
            mapping_efficiency: dataflow::mapping_efficiency(total_r, rows, total_c, cols),
            sram_reads_ifmap: s.ifmap_reads,
            sram_reads_filter: s.filter_reads,
            sram_writes_ofmap: s.ofmap_writes,
            sram_reads_ofmap: s.ofmap_reads,
        }
    }
}

/// RTL backend: obtain per-fold cycle counts from the register-level PE
/// grids in [`crate::rtl`] instead of the closed forms.
///
/// A layer's fold grid has at most four *distinct* fold shapes
/// ([`dataflow::for_fold_shapes`]); each distinct shape is RTL-simulated
/// once and weighted by its multiplicity. Folds whose streamed dimension
/// exceeds `stream_budget` are simulated at the budget and extended by
/// the exact unit-slope law (one extra streamed element costs exactly
/// one extra cycle in both grid datapaths — asserted against full RTL
/// runs in this module's tests), keeping validation runs cheap without
/// giving up cycle-exactness.
#[derive(Clone, Copy, Debug)]
pub struct Rtl {
    pub stream_budget: u64,
}

impl Default for Rtl {
    fn default() -> Self {
        // Large enough to cover Fig-4's array-sized matmuls entirely.
        Rtl { stream_budget: 256 }
    }
}

impl Rtl {
    /// Cycle-level cost of one `r x c` fold streaming `stream` elements.
    fn fold_cycles(&self, df: Dataflow, r: u64, c: u64, stream: u64) -> u64 {
        let s0 = stream.min(self.stream_budget).max(1);
        let cycles = match df {
            Dataflow::Os => {
                // one OS fold == an r x c matmul with K = stream
                let (a, b) =
                    rtl::random_matrices(r as usize, s0 as usize, c as usize, r * 131 + c);
                rtl::run_matmul(&a, &b, r as usize, s0 as usize, c as usize).cycles
            }
            Dataflow::Ws | Dataflow::Is => {
                // one WS/IS fold == s0 rows streamed against an r x c
                // pinned block
                let (x, w) =
                    rtl::random_matrices(s0 as usize, r as usize, c as usize, r * 137 + c);
                rtl::run_pinned_stream(&x, &w, s0 as usize, r as usize, c as usize).cycles
            }
        };
        cycles + (stream - s0)
    }
}

impl Backend for Rtl {
    fn kind(&self) -> BackendKind {
        BackendKind::Rtl
    }

    fn timing(&self, cfg: &ArchConfig, layer: &LayerShape) -> Timing {
        let (rows, cols) = (cfg.array_h, cfg.array_w);
        let df = cfg.dataflow;
        let (npx, k, nf) = layer.gemm_view();
        let (total_r, total_c) = fold_dims(df, npx, k, nf);
        let stream = match df {
            Dataflow::Os => k,
            Dataflow::Ws => npx,
            Dataflow::Is => nf,
        };
        let mut cycles = 0u64;
        dataflow::for_fold_shapes(total_r, rows, total_c, cols, |n, r, c| {
            cycles += n * self.fold_cycles(df, r, c, stream);
        });
        // SRAM access counts are schedule-level invariants (identical
        // across fidelity levels); take them from the closed forms and
        // recompute the utilization against the RTL-measured runtime.
        let analytic = df.timing(layer, rows, cols);
        Timing {
            cycles,
            utilization: layer.macs() as f64 / (rows * cols * cycles) as f64,
            ..analytic
        }
    }
}

/// Fold-grid extents per dataflow (rows dim, cols dim).
fn fold_dims(df: Dataflow, npx: u64, k: u64, nf: u64) -> (u64, u64) {
    match df {
        Dataflow::Os => (npx, nf),
        Dataflow::Ws => (k, nf),
        Dataflow::Is => (k, npx),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;

    fn cfg(df: Dataflow, rows: u64, cols: u64) -> ArchConfig {
        ArchConfig { array_h: rows, array_w: cols, dataflow: df, ..config::paper_default() }
    }

    fn layers() -> Vec<LayerShape> {
        vec![
            LayerShape::gemm("mm8", 8, 8, 8),
            LayerShape::gemm("mm_resid", 9, 10, 11),
            LayerShape::conv("conv", 8, 8, 3, 3, 4, 6, 1),
            LayerShape::fc("fc", 1, 40, 12),
        ]
    }

    #[test]
    fn trace_backend_matches_analytical_exactly() {
        for l in layers() {
            for df in Dataflow::ALL {
                let c = cfg(df, 8, 8);
                assert_eq!(TraceDriven.timing(&c, &l), Analytical.timing(&c, &l), "{df} {}", l.name);
            }
        }
    }

    #[test]
    fn rtl_backend_matches_analytical_exactly() {
        let rtl = Rtl::default();
        for l in layers() {
            for df in Dataflow::ALL {
                let c = cfg(df, 8, 8);
                assert_eq!(rtl.timing(&c, &l), Analytical.timing(&c, &l), "{df} {}", l.name);
            }
        }
    }

    #[test]
    fn rtl_stream_extrapolation_is_exact() {
        // a fold whose streamed dimension exceeds the budget must still
        // be cycle-exact thanks to the unit-slope law
        let tight = Rtl { stream_budget: 16 };
        let l = LayerShape::gemm("long", 8, 300, 8); // OS streams K=300
        let c = cfg(Dataflow::Os, 8, 8);
        assert_eq!(tight.timing(&c, &l).cycles, Analytical.timing(&c, &l).cycles);
        let l2 = LayerShape::gemm("px", 300, 8, 8); // WS streams Npx=300
        let c2 = cfg(Dataflow::Ws, 8, 8);
        assert_eq!(tight.timing(&c2, &l2).cycles, Analytical.timing(&c2, &l2).cycles);
    }

    #[test]
    fn kind_parse_round_trips() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::parse(k.name()).unwrap(), k);
        }
        assert!(BackendKind::parse("fpga").is_err());
    }
}
