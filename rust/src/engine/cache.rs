//! Per-(configuration, layer-shape) memoization of layer simulations.
//!
//! ## Key semantics
//!
//! A cached [`LayerReport`] is keyed on **exactly the inputs that can
//! change its value**:
//!
//! * the backend kind (fidelity levels are cycle-exact by contract, but
//!   keyed separately so a backend bug cannot poison another's results),
//! * the architecture fields the timing/memory/energy models read:
//!   array dimensions, dataflow, the three SRAM partition sizes, and the
//!   word size,
//! * the layer's *shape* (Table II fields) — NOT its name. Two layers
//!   with different names but identical hyper-parameters (e.g. repeated
//!   ResNet bottleneck blocks) share one cache entry; the report's layer
//!   name is re-stamped on retrieval so callers see their own layer.
//!
//! Address-space offsets are deliberately excluded: they relocate trace
//! addresses but do not affect any reported metric. The energy model is
//! engine-fixed (one cache per engine), so it is not part of the key.
//!
//! The cache is engine-lifetime and thread-safe; the sweep grid threads
//! share it, which is where the Fig 5-8 suites win their >50% hit rates
//! (repeated layer shapes within and across workloads).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;
use crate::sim::LayerReport;

use super::backend::BackendKind;

/// Cache key: see the module docs for what is (and is not) included.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub(crate) struct CacheKey {
    backend: BackendKind,
    array_h: u64,
    array_w: u64,
    dataflow: Dataflow,
    ifmap_sram_kb: u64,
    filter_sram_kb: u64,
    ofmap_sram_kb: u64,
    word_bytes: u64,
    layer: LayerKey,
}

/// The Table-II shape fields, without the user-facing name.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct LayerKey {
    ifmap_h: u64,
    ifmap_w: u64,
    filt_h: u64,
    filt_w: u64,
    channels: u64,
    num_filters: u64,
    stride: u64,
}

impl CacheKey {
    pub(crate) fn new(backend: BackendKind, cfg: &ArchConfig, layer: &LayerShape) -> Self {
        CacheKey {
            backend,
            array_h: cfg.array_h,
            array_w: cfg.array_w,
            dataflow: cfg.dataflow,
            ifmap_sram_kb: cfg.ifmap_sram_kb,
            filter_sram_kb: cfg.filter_sram_kb,
            ofmap_sram_kb: cfg.ofmap_sram_kb,
            word_bytes: cfg.word_bytes,
            layer: LayerKey {
                ifmap_h: layer.ifmap_h,
                ifmap_w: layer.ifmap_w,
                filt_h: layer.filt_h,
                filt_w: layer.filt_w,
                channels: layer.channels,
                num_filters: layer.num_filters,
                stride: layer.stride,
            },
        }
    }
}

/// Cumulative memoization counters (monotone over an engine's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Layer simulations actually executed (cache misses).
    pub layer_sims: u64,
    /// Lookups served from the cache.
    pub cache_hits: u64,
}

impl MemoStats {
    pub fn lookups(&self) -> u64 {
        self.layer_sims + self.cache_hits
    }

    /// Fraction of lookups served from the cache (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / n as f64
    }

    /// Counter delta since an earlier snapshot.
    pub fn since(&self, earlier: &MemoStats) -> MemoStats {
        MemoStats {
            layer_sims: self.layer_sims - earlier.layer_sims,
            cache_hits: self.cache_hits - earlier.cache_hits,
        }
    }
}

/// Thread-safe memo table. Entries are `Arc`ed so a hit only clones a
/// pointer while the lock is held; the (deep) per-caller copy happens
/// outside the critical section, keeping warm sweeps parallel.
pub(crate) struct LayerCache {
    map: Mutex<HashMap<CacheKey, Arc<LayerReport>>>,
    sims: AtomicU64,
    hits: AtomicU64,
}

impl LayerCache {
    pub(crate) fn new() -> Self {
        LayerCache {
            map: Mutex::new(HashMap::new()),
            sims: AtomicU64::new(0),
            hits: AtomicU64::new(0),
        }
    }

    /// Fetch the report for `key`, computing (outside the lock) on miss.
    /// The returned report carries `name` regardless of which layer
    /// first populated the entry.
    pub(crate) fn get_or_compute(
        &self,
        key: CacheKey,
        name: &str,
        compute: impl FnOnce() -> LayerReport,
    ) -> LayerReport {
        let cached: Option<Arc<LayerReport>> =
            self.map.lock().unwrap().get(&key).map(Arc::clone);
        if let Some(hit) = cached {
            self.hits.fetch_add(1, Ordering::Relaxed);
            let mut r = (*hit).clone();
            if r.layer.name != name {
                r.layer.name = name.to_string();
            }
            return r;
        }
        // Compute outside the lock. Concurrent duplicate computes are
        // benign (results are deterministic); the loser of the insert
        // race is counted as a HIT, so layer_sims always equals the
        // number of distinct cache entries and the reported hit rate is
        // reproducible regardless of thread count.
        let report = compute();
        match self.map.lock().unwrap().entry(key) {
            std::collections::hash_map::Entry::Occupied(_) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(Arc::new(report.clone()));
                self.sims.fetch_add(1, Ordering::Relaxed);
            }
        }
        report
    }

    pub(crate) fn stats(&self) -> MemoStats {
        MemoStats {
            layer_sims: self.sims.load(Ordering::Relaxed),
            cache_hits: self.hits.load(Ordering::Relaxed),
        }
    }

    pub(crate) fn entries(&self) -> usize {
        self.map.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sim::Simulator;

    fn report(name: &str) -> LayerReport {
        let sim = Simulator::new(config::paper_default());
        sim.run_layer(&LayerShape::conv(name, 12, 12, 3, 3, 4, 8, 1))
    }

    #[test]
    fn hit_restamps_name_and_counts() {
        let cache = LayerCache::new();
        let cfg = config::paper_default();
        let a = LayerShape::conv("a", 12, 12, 3, 3, 4, 8, 1);
        let b = LayerShape::conv("b", 12, 12, 3, 3, 4, 8, 1); // same shape
        let ka = CacheKey::new(BackendKind::Analytical, &cfg, &a);
        let kb = CacheKey::new(BackendKind::Analytical, &cfg, &b);
        assert_eq!(ka, kb, "name must not participate in the key");

        let r1 = cache.get_or_compute(ka, "a", || report("a"));
        let r2 = cache.get_or_compute(kb, "b", || panic!("must hit"));
        assert_eq!(r1.layer.name, "a");
        assert_eq!(r2.layer.name, "b");
        assert_eq!(r1.timing, r2.timing);
        let s = cache.stats();
        assert_eq!((s.layer_sims, s.cache_hits), (1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(cache.entries(), 1);
    }

    #[test]
    fn distinct_configs_do_not_collide() {
        let cfg = config::paper_default();
        let mut cfg2 = cfg.clone();
        cfg2.array_h = 64;
        let l = LayerShape::conv("c", 12, 12, 3, 3, 4, 8, 1);
        assert_ne!(
            CacheKey::new(BackendKind::Analytical, &cfg, &l),
            CacheKey::new(BackendKind::Analytical, &cfg2, &l)
        );
        assert_ne!(
            CacheKey::new(BackendKind::Analytical, &cfg, &l),
            CacheKey::new(BackendKind::Rtl, &cfg, &l)
        );
    }

    #[test]
    fn offsets_do_not_split_entries() {
        let cfg = config::paper_default();
        let mut moved = cfg.clone();
        moved.ifmap_offset = 42;
        let l = LayerShape::conv("c", 12, 12, 3, 3, 4, 8, 1);
        assert_eq!(
            CacheKey::new(BackendKind::Analytical, &cfg, &l),
            CacheKey::new(BackendKind::Analytical, &moved, &l)
        );
    }

    #[test]
    fn stats_delta() {
        let a = MemoStats { layer_sims: 10, cache_hits: 30 };
        let b = MemoStats { layer_sims: 4, cache_hits: 10 };
        let d = a.since(&b);
        assert_eq!((d.layer_sims, d.cache_hits), (6, 20));
        assert_eq!(MemoStats::default().hit_rate(), 0.0);
    }
}
