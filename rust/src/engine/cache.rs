//! Per-(configuration, layer-shape) memoization of layer simulations.
//!
//! ## Key semantics
//!
//! A cached [`LayerReport`] is keyed on **exactly the inputs that can
//! change its value**:
//!
//! * the backend kind (fidelity levels are cycle-exact by contract, but
//!   keyed separately so a backend bug cannot poison another's results),
//! * the architecture fields the timing/memory/energy models read:
//!   array dimensions, dataflow, the three SRAM partition sizes, and the
//!   word size,
//! * the layer's **lowered tile shape** (Table II fields) — NOT its
//!   name. Two layers with different names but identical
//!   hyper-parameters (e.g. repeated ResNet bottleneck blocks) share one
//!   cache entry; the report's layer name is re-stamped on retrieval so
//!   callers see their own layer. Because the workload IR
//!   ([`crate::workload`]) canonicalizes GEMM-equivalent ops before the
//!   engine ever sees them — a `Gemm`/`FullyConnected` op, a pointwise
//!   `Conv2d`, and a legacy gemm-encoded csv row all lower to the same
//!   `(M, 1, 1, 1, K, N, 1)` tile — a conv and its equivalent GEMM share
//!   one entry across sweeps and the server's shared cache.
//!
//! Address-space offsets are deliberately excluded: they relocate trace
//! addresses but do not affect any reported metric. The energy model is
//! engine-fixed (one cache per engine), so it is not part of the key.
//!
//! ## Concurrency: lock striping + in-flight deduplication
//!
//! The table is split into N **stripes**, each its own mutex + condvar
//! over a disjoint key range selected by [`memo_hash`] — a deterministic
//! FNV-1a over the key's canonical field encoding (NOT the std
//! `DefaultHasher`, whose per-process random seed would make stripe
//! placement — and therefore contention behaviour — unreproducible).
//! Concurrent misses on *different* keys land on different stripes with
//! high probability and never contend; the stripe count can only change
//! which lock a key hashes to, never what is stored under the key, so
//! results are bit-identical at any stripe count (docs/INVARIANTS.md
//! §11). Stripe-lock contention is tallied (wall-class — it depends on
//! scheduling, not on the workload) for `scale-sim serve` metrics.
//!
//! Within a stripe the table is duplicate-compute free: a miss claims
//! the key with an [`Slot::InFlight`] marker before computing outside
//! the lock, so a second thread that misses on the same key **waits on
//! the stripe's condvar and reuses the first thread's result** instead
//! of running the backend again (counted as a cache hit — the work was
//! shared). This is load-bearing for the serve subsystem, where many
//! concurrent clients submit overlapping workloads, and a straight win
//! for wide sweeps that previously burned duplicate simulations in the
//! insert race. If a compute panics, its claim is withdrawn and waiters
//! retry, so a poisoned job cannot wedge the table.
//!
//! Entries loaded from a persistent store ([`LayerCache::insert_prewarmed`])
//! are tagged *warm*; hits on them are tallied separately ([`WarmStats`])
//! so `scale-sim serve --state-dir` restarts can prove their cache
//! survived the restart.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, TryLockError};

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::dataflow::Dataflow;
use crate::sim::LayerReport;

use super::backend::BackendKind;

/// Default stripe count: enough to make cross-key contention rare on
/// any realistic core count without bloating tiny caches.
pub(crate) const DEFAULT_STRIPES: usize = 16;

/// Cache key: see the module docs for what is (and is not) included.
/// Fields are crate-visible so the server's result store can persist and
/// reload entries.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub(crate) struct CacheKey {
    pub(crate) backend: BackendKind,
    pub(crate) array_h: u64,
    pub(crate) array_w: u64,
    pub(crate) dataflow: Dataflow,
    pub(crate) ifmap_sram_kb: u64,
    pub(crate) filter_sram_kb: u64,
    pub(crate) ofmap_sram_kb: u64,
    pub(crate) word_bytes: u64,
    pub(crate) layer: LayerKey,
}

/// The lowered tile's Table-II shape fields, without the user-facing
/// name (GEMM-equivalent ops are already canonicalized by the workload
/// IR's lowering pass — see the module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub(crate) struct LayerKey {
    pub(crate) ifmap_h: u64,
    pub(crate) ifmap_w: u64,
    pub(crate) filt_h: u64,
    pub(crate) filt_w: u64,
    pub(crate) channels: u64,
    pub(crate) num_filters: u64,
    pub(crate) stride: u64,
}

impl CacheKey {
    pub(crate) fn new(backend: BackendKind, cfg: &ArchConfig, layer: &LayerShape) -> Self {
        CacheKey {
            backend,
            array_h: cfg.array_h,
            array_w: cfg.array_w,
            dataflow: cfg.dataflow,
            ifmap_sram_kb: cfg.ifmap_sram_kb,
            filter_sram_kb: cfg.filter_sram_kb,
            ofmap_sram_kb: cfg.ofmap_sram_kb,
            word_bytes: cfg.word_bytes,
            layer: LayerKey {
                ifmap_h: layer.ifmap_h,
                ifmap_w: layer.ifmap_w,
                filt_h: layer.filt_h,
                filt_w: layer.filt_w,
                channels: layer.channels,
                num_filters: layer.num_filters,
                stride: layer.stride,
            },
        }
    }
}

/// Deterministic FNV-1a hash of a [`CacheKey`]'s canonical encoding.
///
/// Used for stripe selection *and* for routing keys across federated
/// serve peers (`server::peers`): every process — any build, any run —
/// must map a given key to the same u64, so the enum fields go in via
/// their stable `name()` tags and the numeric fields in a fixed order.
pub(crate) fn memo_hash(key: &CacheKey) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    };
    eat(key.backend.name().as_bytes());
    eat(&[0]); // field separator: tags must not concatenate ambiguously
    eat(key.dataflow.name().as_bytes());
    eat(&[0]);
    for v in [
        key.array_h,
        key.array_w,
        key.ifmap_sram_kb,
        key.filter_sram_kb,
        key.ofmap_sram_kb,
        key.word_bytes,
        key.layer.ifmap_h,
        key.layer.ifmap_w,
        key.layer.filt_h,
        key.layer.filt_w,
        key.layer.channels,
        key.layer.num_filters,
        key.layer.stride,
    ] {
        eat(&v.to_le_bytes());
    }
    h
}

/// Cumulative memoization counters (monotone over an engine's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MemoStats {
    /// Layer simulations actually executed (cache misses).
    pub layer_sims: u64,
    /// Lookups served from the cache (including lookups that waited on
    /// an in-flight computation and reused its result).
    pub cache_hits: u64,
    /// Lookups that blocked on another thread's in-flight computation of
    /// the same key before being served (a subset of `cache_hits`; each
    /// wait episode counts once, however many spurious wakes it sees).
    pub inflight_waits: u64,
}

impl MemoStats {
    pub fn lookups(&self) -> u64 {
        self.layer_sims + self.cache_hits
    }

    /// Fraction of lookups served from the cache (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let n = self.lookups();
        if n == 0 {
            return 0.0;
        }
        self.cache_hits as f64 / n as f64
    }

    /// Counter delta since an earlier snapshot. Saturates at zero per
    /// counter when `earlier` is ahead — a snapshot taken before a cache
    /// reset (e.g. a server restart swapped in a fresh engine) yields
    /// zeros rather than a panic/wraparound.
    pub fn since(&self, earlier: &MemoStats) -> MemoStats {
        MemoStats {
            layer_sims: self.layer_sims.saturating_sub(earlier.layer_sims),
            cache_hits: self.cache_hits.saturating_sub(earlier.cache_hits),
            inflight_waits: self.inflight_waits.saturating_sub(earlier.inflight_waits),
        }
    }
}

/// Warm-start accounting: entries pre-loaded from a persistent store and
/// the hits they have served this process.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmStats {
    /// Entries inserted by [`LayerCache::insert_prewarmed`].
    pub entries: u64,
    /// Cache hits served by prewarmed entries.
    pub hits: u64,
}

/// One table slot: a finished report, or a claim by the thread currently
/// computing it.
enum Slot {
    InFlight,
    Ready { report: Arc<LayerReport>, warm: bool },
}

/// One lock-striped shard of the memo table: a disjoint key range with
/// its own mutex and wake-up channel for in-flight waiters.
struct Stripe {
    map: Mutex<HashMap<CacheKey, Slot>>,
    ready: Condvar,
}

impl Stripe {
    fn new() -> Self {
        Stripe { map: Mutex::new(HashMap::new()), ready: Condvar::new() }
    }

    /// Lock this stripe's table, recovering from poisoning: entries are
    /// only ever inserted whole (`Slot` values are moved in, never
    /// mutated in place), so a panicking computer cannot leave a torn
    /// entry — and the `InFlightGuard` below already withdraws its claim
    /// on panic. A failed opportunistic `try_lock` bumps the shared
    /// contention counter before falling back to a blocking lock.
    fn table(&self, contended: &AtomicU64) -> MutexGuard<'_, HashMap<CacheKey, Slot>> {
        match self.map.try_lock() {
            Ok(guard) => guard,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                contended.fetch_add(1, Ordering::Relaxed);
                self.map.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
            }
        }
    }
}

/// Thread-safe, lock-striped memo table with per-stripe in-flight
/// deduplication (module docs). Ready entries are `Arc`ed so a hit only
/// clones a pointer while the stripe lock is held; the (deep) per-caller
/// copy happens outside the critical section, keeping warm sweeps
/// parallel. The cumulative counters are global atomics — they are
/// stripe-agnostic by construction, so sharded totals equal what the
/// old single-mutex table would have counted.
pub(crate) struct LayerCache {
    stripes: Vec<Stripe>,
    sims: AtomicU64,
    hits: AtomicU64,
    inflight_waits: AtomicU64,
    warm_entries: AtomicU64,
    warm_hits: AtomicU64,
    contended: AtomicU64,
}

impl LayerCache {
    pub(crate) fn new() -> Self {
        Self::with_stripes(DEFAULT_STRIPES)
    }

    /// Build a cache with an explicit stripe count (clamped to >= 1).
    /// `with_stripes(1)` reproduces the historical single-mutex table
    /// exactly; larger counts only spread keys across locks.
    pub(crate) fn with_stripes(n: usize) -> Self {
        let n = n.max(1);
        LayerCache {
            stripes: (0..n).map(|_| Stripe::new()).collect(),
            sims: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            inflight_waits: AtomicU64::new(0),
            warm_entries: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            contended: AtomicU64::new(0),
        }
    }

    pub(crate) fn stripe_count(&self) -> usize {
        self.stripes.len()
    }

    /// Times a stripe lock was found held by another thread (wall-class:
    /// a scheduling artifact, never part of deterministic output).
    pub(crate) fn contention(&self) -> u64 {
        self.contended.load(Ordering::Relaxed)
    }

    fn stripe_for(&self, key: &CacheKey) -> &Stripe {
        let idx = (memo_hash(key) % self.stripes.len() as u64) as usize;
        &self.stripes[idx]
    }

    /// Fetch the report for `key`, computing (outside the lock) on miss.
    /// Concurrent callers that miss on the same key compute it **once**:
    /// the first claims the key, the rest block until the result lands
    /// and are counted as hits. The returned report carries `name`
    /// regardless of which layer first populated the entry.
    pub(crate) fn get_or_compute(
        &self,
        key: CacheKey,
        name: &str,
        compute: impl FnOnce() -> LayerReport,
    ) -> LayerReport {
        enum Found {
            Ready(Arc<LayerReport>, bool),
            InFlight,
            Absent,
        }
        let stripe = self.stripe_for(&key);
        {
            let mut map = stripe.table(&self.contended);
            let mut waited = false;
            loop {
                // resolve the slot to an owned view first, so no borrow
                // of `map` is live when we hand the guard to the condvar
                let found = match map.get(&key) {
                    Some(Slot::Ready { report, warm }) => Found::Ready(Arc::clone(report), *warm),
                    Some(Slot::InFlight) => Found::InFlight,
                    None => Found::Absent,
                };
                match found {
                    Found::Ready(hit, warm) => {
                        self.hits.fetch_add(1, Ordering::Relaxed);
                        if warm {
                            self.warm_hits.fetch_add(1, Ordering::Relaxed);
                        }
                        drop(map);
                        return restamp(&hit, name);
                    }
                    Found::InFlight => {
                        if !waited {
                            waited = true;
                            self.inflight_waits.fetch_add(1, Ordering::Relaxed);
                        }
                        map = stripe
                            .ready
                            .wait(map)
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                    }
                    Found::Absent => {
                        map.insert(key.clone(), Slot::InFlight);
                        break;
                    }
                }
            }
        }

        // Compute outside the lock, holding the in-flight claim. The
        // guard withdraws the claim (and wakes waiters to retry) if the
        // compute panics, so the table cannot wedge.
        let mut guard = InFlightGuard { cache: self, key: Some(key) };
        let report = compute();
        // disarm: with the key taken, the guard's Drop is a no-op
        // (`key` is Some by construction — the claim is taken exactly here)
        if let Some(key) = guard.key.take() {
            let mut map = stripe.table(&self.contended);
            map.insert(key, Slot::Ready { report: Arc::new(report.clone()), warm: false });
        }
        self.sims.fetch_add(1, Ordering::Relaxed);
        stripe.ready.notify_all();
        report
    }

    /// Seed a `Ready` entry from a persistent store (server warm start).
    /// No-op (returns `false`) when the key is already present; never
    /// counts as a layer sim.
    pub(crate) fn insert_prewarmed(&self, key: CacheKey, report: LayerReport) -> bool {
        let stripe = self.stripe_for(&key);
        let mut map = stripe.table(&self.contended);
        if map.contains_key(&key) {
            return false;
        }
        map.insert(key, Slot::Ready { report: Arc::new(report), warm: true });
        self.warm_entries.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Snapshot every ready entry (in-flight computations are skipped) —
    /// the server's shutdown flush. Stripes are visited in index order;
    /// within a stripe the iteration order is the map's.
    pub(crate) fn export(&self) -> Vec<(CacheKey, Arc<LayerReport>)> {
        let mut out = Vec::new();
        for stripe in &self.stripes {
            let map = stripe.table(&self.contended);
            out.extend(map.iter().filter_map(|(k, slot)| match slot {
                Slot::Ready { report, .. } => Some((k.clone(), Arc::clone(report))),
                Slot::InFlight => None,
            }));
        }
        out
    }

    pub(crate) fn stats(&self) -> MemoStats {
        MemoStats {
            layer_sims: self.sims.load(Ordering::Relaxed),
            cache_hits: self.hits.load(Ordering::Relaxed),
            inflight_waits: self.inflight_waits.load(Ordering::Relaxed),
        }
    }

    pub(crate) fn warm_stats(&self) -> WarmStats {
        WarmStats {
            entries: self.warm_entries.load(Ordering::Relaxed),
            hits: self.warm_hits.load(Ordering::Relaxed),
        }
    }

    pub(crate) fn entries(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| {
                s.table(&self.contended)
                    .values()
                    .filter(|slot| matches!(slot, Slot::Ready { .. }))
                    .count()
            })
            .sum()
    }
}

fn restamp(report: &LayerReport, name: &str) -> LayerReport {
    let mut r = report.clone();
    if r.layer.name != name {
        r.layer.name = name.to_string();
    }
    r
}

/// Withdraws an in-flight claim if the computing closure panics.
struct InFlightGuard<'a> {
    cache: &'a LayerCache,
    key: Option<CacheKey>,
}

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        if let Some(key) = self.key.take() {
            let stripe = self.cache.stripe_for(&key);
            stripe.table(&self.cache.contended).remove(&key);
            stripe.ready.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sim::Simulator;

    fn report(name: &str) -> LayerReport {
        let sim = Simulator::new(config::paper_default());
        sim.run_layer(&LayerShape::conv(name, 12, 12, 3, 3, 4, 8, 1))
    }

    #[test]
    fn hit_restamps_name_and_counts() {
        let cache = LayerCache::new();
        let cfg = config::paper_default();
        let a = LayerShape::conv("a", 12, 12, 3, 3, 4, 8, 1);
        let b = LayerShape::conv("b", 12, 12, 3, 3, 4, 8, 1); // same shape
        let ka = CacheKey::new(BackendKind::Analytical, &cfg, &a);
        let kb = CacheKey::new(BackendKind::Analytical, &cfg, &b);
        assert_eq!(ka, kb, "name must not participate in the key");

        let r1 = cache.get_or_compute(ka, "a", || report("a"));
        let r2 = cache.get_or_compute(kb, "b", || panic!("must hit"));
        assert_eq!(r1.layer.name, "a");
        assert_eq!(r2.layer.name, "b");
        assert_eq!(r1.timing, r2.timing);
        let s = cache.stats();
        assert_eq!((s.layer_sims, s.cache_hits), (1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(cache.entries(), 1);
    }

    #[test]
    fn pointwise_conv_and_equivalent_gemm_share_one_key() {
        use crate::workload::{Conv2d, Op};
        let conv = Op::Conv2d(Conv2d {
            ifmap_h: 14,
            ifmap_w: 14,
            in_channels: 64,
            out_channels: 128,
            ..Conv2d::default()
        })
        .lower("pw")
        .unwrap();
        let gemm = Op::Gemm { m: 14 * 14, k: 64, n: 128 }.lower("g").unwrap();
        let cfg = config::paper_default();
        assert_eq!(
            CacheKey::new(BackendKind::Analytical, &cfg, &conv[0]),
            CacheKey::new(BackendKind::Analytical, &cfg, &gemm[0]),
            "lowering must canonicalize the pointwise conv onto the GEMM tile"
        );
    }

    #[test]
    fn distinct_configs_do_not_collide() {
        let cfg = config::paper_default();
        let mut cfg2 = cfg.clone();
        cfg2.array_h = 64;
        let l = LayerShape::conv("c", 12, 12, 3, 3, 4, 8, 1);
        assert_ne!(
            CacheKey::new(BackendKind::Analytical, &cfg, &l),
            CacheKey::new(BackendKind::Analytical, &cfg2, &l)
        );
        assert_ne!(
            CacheKey::new(BackendKind::Analytical, &cfg, &l),
            CacheKey::new(BackendKind::Rtl, &cfg, &l)
        );
    }

    #[test]
    fn offsets_do_not_split_entries() {
        let cfg = config::paper_default();
        let mut moved = cfg.clone();
        moved.ifmap_offset = 42;
        let l = LayerShape::conv("c", 12, 12, 3, 3, 4, 8, 1);
        assert_eq!(
            CacheKey::new(BackendKind::Analytical, &cfg, &l),
            CacheKey::new(BackendKind::Analytical, &moved, &l)
        );
    }

    #[test]
    fn stats_delta() {
        let a = MemoStats { layer_sims: 10, cache_hits: 30, inflight_waits: 5 };
        let b = MemoStats { layer_sims: 4, cache_hits: 10, inflight_waits: 2 };
        let d = a.since(&b);
        assert_eq!((d.layer_sims, d.cache_hits, d.inflight_waits), (6, 20, 3));
        assert_eq!(MemoStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn memo_hash_is_stable_and_field_sensitive() {
        let cfg = config::paper_default();
        let l = LayerShape::conv("h", 12, 12, 3, 3, 4, 8, 1);
        let k = CacheKey::new(BackendKind::Analytical, &cfg, &l);
        // same key hashes identically (the whole point: cross-process
        // stripe/peer routing must agree without a shared seed)
        assert_eq!(memo_hash(&k), memo_hash(&k.clone()));
        // every field class perturbs the hash
        let mut cfg2 = cfg.clone();
        cfg2.array_w = 64;
        assert_ne!(
            memo_hash(&k),
            memo_hash(&CacheKey::new(BackendKind::Analytical, &cfg2, &l))
        );
        assert_ne!(memo_hash(&k), memo_hash(&CacheKey::new(BackendKind::Rtl, &cfg, &l)));
        let l2 = LayerShape::conv("h", 12, 12, 3, 3, 4, 9, 1);
        assert_ne!(memo_hash(&k), memo_hash(&CacheKey::new(BackendKind::Analytical, &cfg, &l2)));
    }

    #[test]
    fn stripe_count_clamps_and_reports() {
        assert_eq!(LayerCache::with_stripes(0).stripe_count(), 1);
        assert_eq!(LayerCache::with_stripes(1).stripe_count(), 1);
        assert_eq!(LayerCache::with_stripes(8).stripe_count(), 8);
        assert_eq!(LayerCache::new().stripe_count(), DEFAULT_STRIPES);
    }

    #[test]
    fn concurrent_misses_compute_once() {
        use std::sync::atomic::AtomicUsize;
        use std::sync::Barrier;

        let cache = LayerCache::new();
        let cfg = config::paper_default();
        let l = LayerShape::conv("x", 12, 12, 3, 3, 4, 8, 1);
        let computes = AtomicUsize::new(0);
        const THREADS: usize = 8;
        let barrier = Barrier::new(THREADS);

        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for i in 0..THREADS {
                let (cache, cfg, l, computes, barrier) = (&cache, &cfg, &l, &computes, &barrier);
                handles.push(s.spawn(move || {
                    barrier.wait(); // all threads race the same cold key
                    let key = CacheKey::new(BackendKind::Analytical, cfg, l);
                    cache.get_or_compute(key, &format!("t{i}"), || {
                        computes.fetch_add(1, Ordering::SeqCst);
                        // widen the window so waiters actually overlap
                        std::thread::sleep(std::time::Duration::from_millis(20));
                        report("x")
                    })
                }));
            }
            let reports: Vec<LayerReport> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            for (i, r) in reports.iter().enumerate() {
                assert_eq!(r.layer.name, format!("t{i}"));
                assert_eq!(r.timing, reports[0].timing);
            }
        });

        assert_eq!(computes.load(Ordering::SeqCst), 1, "backend must run once");
        let s = cache.stats();
        assert_eq!(s.layer_sims, 1);
        assert_eq!(s.cache_hits, (THREADS - 1) as u64);
        assert!(
            s.inflight_waits <= s.cache_hits,
            "waiters are a subset of hits: {s:?}"
        );
        assert_eq!(cache.entries(), 1);
    }

    #[test]
    fn panicking_compute_releases_the_claim() {
        let cache = LayerCache::new();
        let cfg = config::paper_default();
        let l = LayerShape::conv("p", 12, 12, 3, 3, 4, 8, 1);
        let key = CacheKey::new(BackendKind::Analytical, &cfg, &l);

        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            cache.get_or_compute(key.clone(), "p", || panic!("backend blew up"));
        }));
        assert!(poisoned.is_err());
        assert_eq!(cache.entries(), 0, "failed claim must be withdrawn");

        // the key is computable again afterwards
        let r = cache.get_or_compute(key, "p", || report("p"));
        assert_eq!(r.layer.name, "p");
        assert_eq!(cache.stats().layer_sims, 1);
    }

    #[test]
    fn prewarm_inserts_once_and_tags_warm_hits() {
        let cache = LayerCache::new();
        let cfg = config::paper_default();
        let l = LayerShape::conv("w", 12, 12, 3, 3, 4, 8, 1);
        let key = CacheKey::new(BackendKind::Analytical, &cfg, &l);

        assert!(cache.insert_prewarmed(key.clone(), report("w")));
        assert!(!cache.insert_prewarmed(key.clone(), report("w")), "duplicate prewarm is a no-op");
        assert_eq!(cache.warm_stats(), WarmStats { entries: 1, hits: 0 });
        assert_eq!(cache.stats().layer_sims, 0, "prewarm is not a sim");

        let r = cache.get_or_compute(key, "renamed", || panic!("must hit warm entry"));
        assert_eq!(r.layer.name, "renamed");
        assert_eq!(cache.warm_stats(), WarmStats { entries: 1, hits: 1 });
        assert_eq!(cache.stats().cache_hits, 1);
    }

    #[test]
    fn export_round_trips_ready_entries() {
        let cache = LayerCache::new();
        let cfg = config::paper_default();
        let l = LayerShape::conv("e", 12, 12, 3, 3, 4, 8, 1);
        let key = CacheKey::new(BackendKind::Analytical, &cfg, &l);
        let r = cache.get_or_compute(key.clone(), "e", || report("e"));

        let dump = cache.export();
        assert_eq!(dump.len(), 1);
        assert_eq!(dump[0].0, key);
        assert_eq!(*dump[0].1, r);
    }

    #[test]
    fn striped_and_single_stripe_agree_on_a_key_spread() {
        // the same lookup schedule against 1 stripe and 16 stripes must
        // produce identical reports and identical counter totals —
        // stripe count is a lock-layout choice, never a semantic one
        let single = LayerCache::with_stripes(1);
        let striped = LayerCache::with_stripes(16);
        let cfg = config::paper_default();
        let shapes: Vec<LayerShape> = (0..12)
            .map(|i| LayerShape::conv(&format!("k{i}"), 8 + i, 8 + i, 3, 3, 4, 8, 1))
            .collect();
        for pass in 0..2 {
            for (i, l) in shapes.iter().enumerate() {
                let name = format!("p{pass}_k{i}");
                let key = CacheKey::new(BackendKind::Analytical, &cfg, l);
                let a = single.get_or_compute(key.clone(), &name, || {
                    Simulator::new(cfg.clone()).run_layer(l)
                });
                let b = striped.get_or_compute(key, &name, || {
                    Simulator::new(cfg.clone()).run_layer(l)
                });
                assert_eq!(a, b, "stripe count changed a report for {name}");
            }
        }
        assert_eq!(single.stats(), striped.stats());
        assert_eq!(single.entries(), striped.entries());
    }

    #[test]
    fn since_saturates_across_a_reset() {
        // a fresh engine's counters restart at zero; a stale snapshot
        // from before the reset must yield zeros, not underflow
        let before_reset = MemoStats { layer_sims: 100, cache_hits: 400, inflight_waits: 9 };
        let after_reset = MemoStats { layer_sims: 3, cache_hits: 1, inflight_waits: 0 };
        let d = after_reset.since(&before_reset);
        assert_eq!((d.layer_sims, d.cache_hits), (0, 0));
        assert_eq!(d.hit_rate(), 0.0);
    }
}
