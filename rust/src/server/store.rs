//! Persistent result store: the engine's memo cache, flattened to disk
//! so cache warmth survives server restarts.
//!
//! Layout: one JSON line per cached entry under
//! `<state_dir>/results.jsonl`,
//!
//! ```text
//! {"key":{"backend":"analytical","array_h":128,...,"layer":{...}},"report":{...}}
//! ```
//!
//! where `key` carries exactly the [`CacheKey`] fields (backend kind +
//! value-affecting config fields + Table-II layer shape, no layer name)
//! and `report` is the [`crate::server::proto`] layer-report shape.
//! Numbers round-trip exactly ([`crate::util::json`]), so a reloaded
//! report is bit-identical to the one originally computed.
//!
//! * [`ResultStore::load_into`] pre-warms an engine's cache on startup
//!   (entries tagged *warm*; hits on them surface as `warm_hits` in the
//!   serve `stats` event). Lines that fail to parse — truncated flush,
//!   foreign schema — are skipped, never fatal: the store is a cache,
//!   losing an entry only costs a re-simulation.
//! * [`ResultStore::flush_from`] snapshots every ready cache entry and
//!   atomically replaces the file (write-tmp-then-rename), sorted by
//!   line text so consecutive flushes of the same cache are
//!   byte-identical and diffable.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::engine::backend::BackendKind;
use crate::engine::cache::{CacheKey, LayerKey};
use crate::engine::Engine;
use crate::sim::LayerReport;
use crate::util::json::Json;
use crate::Result;

use super::proto;

/// Handle to one on-disk store directory.
pub struct ResultStore {
    path: PathBuf,
}

impl ResultStore {
    /// Open (creating the directory if needed) the store under `dir`.
    pub fn open(dir: &Path) -> Result<ResultStore> {
        std::fs::create_dir_all(dir)?;
        Ok(ResultStore { path: dir.join("results.jsonl") })
    }

    /// The backing file (exists only after the first flush).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Pre-warm `engine`'s cache with every parseable stored entry.
    /// Returns the number of entries inserted (duplicates and malformed
    /// lines are skipped).
    pub fn load_into(&self, engine: &Engine) -> Result<usize> {
        let text = match std::fs::read_to_string(&self.path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(0),
            Err(e) => return Err(e.into()),
        };
        let mut loaded = 0;
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let Ok((key, report)) = parse_entry(line) else {
                continue; // stale/corrupt line: costs one re-simulation, not a crash
            };
            if engine.layer_cache().insert_prewarmed(key, report) {
                loaded += 1;
            }
        }
        Ok(loaded)
    }

    /// Write every ready cache entry of `engine` to disk, atomically
    /// replacing any previous snapshot. Returns the entry count.
    pub fn flush_from(&self, engine: &Engine) -> Result<usize> {
        let mut lines: Vec<String> = engine
            .layer_cache()
            .export()
            .into_iter()
            .map(|(key, report)| entry_line(&key, &report))
            .collect();
        lines.sort();
        let n = lines.len();
        let mut body = lines.join("\n");
        if !body.is_empty() {
            body.push('\n');
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        std::fs::write(&tmp, body)?;
        std::fs::rename(&tmp, &self.path)?;
        Ok(n)
    }
}

fn entry_line(key: &CacheKey, report: &Arc<LayerReport>) -> String {
    Json::obj(vec![
        ("key", key_to_json(key)),
        ("report", proto::layer_report_to_json(report)),
    ])
    .to_string()
}

fn parse_entry(line: &str) -> std::result::Result<(CacheKey, LayerReport), String> {
    let j = Json::parse(line)?;
    let key = key_from_json(j.get("key").ok_or("missing \"key\"")?)?;
    let report =
        proto::layer_report_from_json(j.get("report").ok_or("missing \"report\"")?)?;
    Ok((key, report))
}

fn key_to_json(k: &CacheKey) -> Json {
    Json::obj(vec![
        ("backend", Json::str(k.backend.name())),
        ("array_h", Json::u64(k.array_h)),
        ("array_w", Json::u64(k.array_w)),
        ("dataflow", Json::str(k.dataflow.name())),
        ("ifmap_sram_kb", Json::u64(k.ifmap_sram_kb)),
        ("filter_sram_kb", Json::u64(k.filter_sram_kb)),
        ("ofmap_sram_kb", Json::u64(k.ofmap_sram_kb)),
        ("word_bytes", Json::u64(k.word_bytes)),
        (
            "layer",
            Json::obj(vec![
                ("ifmap_h", Json::u64(k.layer.ifmap_h)),
                ("ifmap_w", Json::u64(k.layer.ifmap_w)),
                ("filt_h", Json::u64(k.layer.filt_h)),
                ("filt_w", Json::u64(k.layer.filt_w)),
                ("channels", Json::u64(k.layer.channels)),
                ("num_filters", Json::u64(k.layer.num_filters)),
                ("stride", Json::u64(k.layer.stride)),
            ]),
        ),
    ])
}

fn key_from_json(j: &Json) -> std::result::Result<CacheKey, String> {
    let need = |k: &str| j.u64_field(k).ok_or_else(|| format!("bad key field {k:?}"));
    let layer = j.get("layer").ok_or("missing key.layer")?;
    let lneed =
        |k: &str| layer.u64_field(k).ok_or_else(|| format!("bad key.layer field {k:?}"));
    Ok(CacheKey {
        backend: BackendKind::parse(j.str_field("backend").ok_or("missing key.backend")?)
            .map_err(|e| e.to_string())?,
        array_h: need("array_h")?,
        array_w: need("array_w")?,
        dataflow: crate::dataflow::Dataflow::parse(
            j.str_field("dataflow").ok_or("missing key.dataflow")?,
        )
        .map_err(|e| e.to_string())?,
        ifmap_sram_kb: need("ifmap_sram_kb")?,
        filter_sram_kb: need("filter_sram_kb")?,
        ofmap_sram_kb: need("ofmap_sram_kb")?,
        word_bytes: need("word_bytes")?,
        layer: LayerKey {
            ifmap_h: lneed("ifmap_h")?,
            ifmap_w: lneed("ifmap_w")?,
            filt_h: lneed("filt_h")?,
            filt_w: lneed("filt_w")?,
            channels: lneed("channels")?,
            num_filters: lneed("num_filters")?,
            stride: lneed("stride")?,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config::{self, Topology};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("scale_sim_store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn topo() -> Topology {
        Topology::new(
            "t",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::fc("fc", 1, 128, 10),
            ],
        )
    }

    #[test]
    fn flush_then_load_is_bit_identical_and_warm() {
        let dir = tmp_dir("roundtrip");
        let store = ResultStore::open(&dir).unwrap();

        let hot = Engine::new(config::paper_default());
        let first = hot.run_topology(&topo());
        assert_eq!(store.flush_from(&hot).unwrap(), hot.cache_entries());

        // fresh engine, warm-started from disk
        let cold = Engine::new(config::paper_default());
        let loaded = store.load_into(&cold).unwrap();
        assert_eq!(loaded, hot.cache_entries());
        assert_eq!(cold.warm_stats().entries, loaded as u64);

        let replay = cold.run_topology(&topo());
        assert_eq!(replay, first, "warm-started reports must be bit-identical");
        assert_eq!(cold.cache_stats().layer_sims, 0, "no re-simulation after warm start");
        assert_eq!(cold.warm_stats().hits, topo().layers.len() as u64);

        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_file_loads_zero_and_corrupt_lines_are_skipped() {
        let dir = tmp_dir("corrupt");
        let store = ResultStore::open(&dir).unwrap();
        let e = Engine::new(config::paper_default());
        assert_eq!(store.load_into(&e).unwrap(), 0, "no file yet");

        // one good line sandwiched by garbage
        e.run_layer(&LayerShape::conv("c", 12, 12, 3, 3, 4, 8, 1));
        store.flush_from(&e).unwrap();
        let good = std::fs::read_to_string(store.path()).unwrap();
        std::fs::write(
            store.path(),
            format!("not json\n{good}{{\"key\":{{}},\"report\":{{}}}}\n"),
        )
        .unwrap();

        let cold = Engine::new(config::paper_default());
        assert_eq!(store.load_into(&cold).unwrap(), 1, "only the valid line loads");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flushes_are_deterministic() {
        let dir = tmp_dir("determ");
        let store = ResultStore::open(&dir).unwrap();
        let e = Engine::new(config::paper_default());
        e.run_topology(&topo());
        store.flush_from(&e).unwrap();
        let a = std::fs::read_to_string(store.path()).unwrap();
        store.flush_from(&e).unwrap();
        let b = std::fs::read_to_string(store.path()).unwrap();
        assert_eq!(a, b, "same cache -> byte-identical snapshot");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
