//! Simulation-as-a-service: the `scale-sim serve` subsystem (std-only:
//! `std::net::TcpListener` + threads; no async runtime, no framework).
//!
//! The paper's case studies are hundreds of `(config, workload)` points,
//! and one-shot CLI runs pay the cold-start price every time — cache
//! warmth dies with the process. This module turns the memoizing
//! [`Engine`] into a long-running service many clients share:
//!
//! ```text
//!            conn thread per client            worker pool (N threads)
//! client A ──> parse JSON line ──┐   bounded    ┌─> Engine::run_topology_with
//! client B ──> parse JSON line ──┼─> JobQueue ──┼─> Engine::sweep().run()
//! client C ──> parse JSON line ──┘  (full queue └─> ...
//!                                    sheds with            │
//!                                    a `busy` event) one shared Arc<Engine>
//!                                                   => one process-wide memo
//!                                                      cache + in-flight dedup
//! ```
//!
//! * **One engine, one cache**: every worker simulates through the same
//!   [`Engine`], so repeated layer shapes from *different* clients hit
//!   the lock-striped memo table ([`crate::engine::cache`]) — and two
//!   clients racing on the same cold key compute it once (per-stripe
//!   in-flight deduplication).
//! * **Bounded queue, shed don't wedge**: admission uses
//!   [`queue::JobQueue::try_push`]; a full queue answers a structured
//!   `busy` event instead of blocking the accepting thread forever, so
//!   the connection keeps reading and clients retry with backoff.
//!   Every *admitted* job still runs — zero drops after admission,
//!   including through shutdown draining.
//! * **Batch envelopes**: a `{"req":"batch"}` request carries several
//!   run/sweep jobs; each sub-job is admitted as an independent queue
//!   entry, so the pool executes them concurrently and one slow job
//!   never delays the others' events. The last finisher emits
//!   `batch_done` (see [`proto`]).
//! * **Federation**: with `--peers`, memo keys are routed across a
//!   fleet of instances by consistent hashing ([`peers`]) — each key
//!   has one owner, so the fleet shares one logical cache. A down peer
//!   fails over to local compute; federation routes *keys*, never
//!   cached values (`docs/INVARIANTS.md` §11).
//! * **Persistent warmth**: with a `--state-dir`, [`store::ResultStore`]
//!   pre-warms the cache on startup and snapshots it on shutdown, so a
//!   restarted server answers from disk-warmed entries (`warm_hits` in
//!   the `stats` event proves it).
//!
//! Wire protocol: see [`proto`]. Entry points: [`start`] (returns a
//! [`ServerHandle`]), [`Client`] (blocking JSON-lines client used by
//! `scale-sim client`, `scale-sim bench-serve`, and the loopback tests).

pub mod peers;
pub mod proto;
pub mod queue;
pub mod store;

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::config::{ArchConfig, Topology};
use crate::engine::{BackendKind, Engine};
use crate::util::json::Json;
use crate::{Dataflow, Result};

use proto::{Request, ServerStats, SweepKind};
use queue::JobQueue;
use store::ResultStore;

/// Server configuration (all fields have serviceable defaults).
pub struct ServeOpts {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`ServerHandle::addr`] for the resolved one).
    pub addr: String,
    /// Worker pool size (default: available parallelism minus one).
    pub workers: usize,
    /// Max jobs waiting in the queue before producers block.
    pub queue_cap: usize,
    /// Max simultaneous client connections (one thread each); excess
    /// connects are refused with an error line. Bounds the only
    /// otherwise-unbounded per-client resource.
    pub max_conns: usize,
    /// Result-store directory; `None` disables persistence.
    pub state_dir: Option<PathBuf>,
    /// Base architecture; per-request overrides apply on top.
    pub cfg: ArchConfig,
    /// Fidelity backend every job runs under.
    pub backend: BackendKind,
    /// Peer instances (`host:port`) forming a federated fleet: memo
    /// keys are routed across members by consistent hashing (see
    /// [`peers`]). Every member must be started with the same fleet —
    /// its own advertised address spelled exactly as the others name it
    /// in their peer lists — and the same base config/backend. Empty =
    /// standalone.
    pub peers: Vec<String>,
    /// Memo-cache stripe count override; `None` uses the engine
    /// default. Stripe count never changes results (`docs/INVARIANTS.md`
    /// §11), only contention.
    pub cache_stripes: Option<usize>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            addr: "127.0.0.1:0".into(),
            workers: crate::sweep::default_threads(),
            queue_cap: 64,
            max_conns: 256,
            state_dir: None,
            cfg: ArchConfig::default(),
            backend: BackendKind::Analytical,
            peers: Vec::new(),
            cache_stripes: None,
        }
    }
}

/// One admitted job: the parsed work plus the connection to stream
/// responses to. Batch sub-jobs additionally carry their envelope's
/// countdown tracker.
struct Job {
    id: u64,
    kind: JobKind,
    writer: ConnWriter,
    batch: Option<Arc<BatchTracker>>,
}

/// Countdown for a batch envelope: whoever performs the final decrement
/// — the worker finishing the last admitted sub-job, or the admitting
/// connection thread when everything was shed — emits `batch_done`.
///
/// `remaining` starts at sub-job count + 1: the extra claim is held by
/// the admitting thread until the `jobs`/`shed` tallies are final, so
/// an early-finishing worker can never emit `batch_done` with counts
/// still being accumulated.
struct BatchTracker {
    id: u64,
    jobs: AtomicUsize,
    shed: AtomicUsize,
    remaining: AtomicUsize,
    writer: ConnWriter,
}

impl BatchTracker {
    fn finish_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.writer.send_line(&proto::batch_done_line(
                self.id,
                self.jobs.load(Ordering::Acquire),
                self.shed.load(Ordering::Acquire),
            ));
        }
    }
}

enum JobKind {
    Run { topo: Topology, cfg: ArchConfig, multi: Option<proto::MultiReq> },
    Sweep {
        kind: SweepKind,
        topos: Vec<Topology>,
        cfg: ArchConfig,
        multi: Option<proto::MultiReq>,
    },
    /// One dse campaign shard: the points named by `indices`, evaluated
    /// through the shared engine (so concurrent shards de-duplicate
    /// layer simulations in the process-wide memo cache).
    Dse {
        campaign: crate::dse::Campaign,
        topos: std::collections::BTreeMap<String, Topology>,
        indices: Vec<usize>,
    },
}

/// State shared by the accept loop, connection threads, and workers.
struct Shared {
    engine: Arc<Engine>,
    queue: JobQueue<Job>,
    workers: usize,
    /// Workers currently inside `run_job` (worker-utilization gauge for
    /// the `stats`/`metrics` surfaces).
    busy: AtomicUsize,
    stopping: AtomicBool,
    addr: SocketAddr,
    conns: AtomicUsize,
    max_conns: usize,
}

impl Shared {
    fn stats(&self) -> ServerStats {
        let q = self.queue.stats();
        ServerStats {
            queue_depth: q.depth,
            in_flight: q.in_flight,
            completed: q.completed,
            failed: q.failed,
            submitted: q.submitted,
            workers: self.workers,
            workers_busy: self.busy.load(Ordering::SeqCst),
            cache_entries: self.engine.cache_entries(),
            memo: self.engine.cache_stats(),
            warm: self.engine.warm_stats(),
        }
    }

    /// Idempotent: stop admissions, wake the accept loop, let workers
    /// drain. Callable from a connection thread (protocol `shutdown`)
    /// or from [`ServerHandle::shutdown`].
    fn begin_shutdown(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.close();
        // Unblock the blocking accept with a throwaway connection. A
        // wildcard bind address (0.0.0.0 / ::) is not connectable —
        // rewrite it to the matching loopback.
        let mut target = self.addr;
        if target.ip().is_unspecified() {
            target.set_ip(match target.ip() {
                std::net::IpAddr::V4(_) => std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST),
                std::net::IpAddr::V6(_) => std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST),
            });
        }
        let _ = TcpStream::connect(target);
    }
}

/// Running server. Dropping the handle shuts the server down (drain +
/// store flush); prefer the explicit [`ServerHandle::shutdown`] /
/// [`ServerHandle::join`] in real callers.
pub struct ServerHandle {
    shared: Arc<Shared>,
    supervisor: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The resolved bind address (meaningful with ephemeral ports).
    pub fn addr(&self) -> SocketAddr {
        self.shared.addr
    }

    /// Server-side statistics snapshot (same data as the `stats` event).
    pub fn stats(&self) -> ServerStats {
        self.shared.stats()
    }

    /// Initiate shutdown and block until the queue is drained, workers
    /// exited, and the result store flushed.
    pub fn shutdown(mut self) {
        self.shared.begin_shutdown();
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }

    /// Block until the server stops (e.g. a client sent `shutdown`).
    pub fn join(mut self) {
        if let Some(h) = self.supervisor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        if let Some(h) = self.supervisor.take() {
            self.shared.begin_shutdown();
            let _ = h.join();
        }
    }
}

/// Start the service: bind, warm-start from the result store (if any),
/// spawn the worker pool and accept loop, return immediately.
pub fn start(opts: ServeOpts) -> Result<ServerHandle> {
    // bind before building the engine: a federated ring needs the
    // resolved address as this instance's identity (ephemeral ports)
    let listener = TcpListener::bind(opts.addr.as_str())?;
    let addr = listener.local_addr()?;

    // workers parallelize across jobs; each job simulates single-threaded
    let mut builder = Engine::builder()
        .config(opts.cfg)
        .backend(opts.backend)
        .threads(1);
    if let Some(n) = opts.cache_stripes {
        builder = builder.cache_stripes(n);
    }
    if !opts.peers.is_empty() {
        let self_addr =
            if opts.addr.ends_with(":0") { addr.to_string() } else { opts.addr.clone() };
        let ring = peers::PeerRing::new(&self_addr, &opts.peers)?;
        builder = builder.layer_router(Arc::new(peers::PeerRouter::new(ring)));
    }
    let engine = builder.build()?.shared();

    let store = match &opts.state_dir {
        Some(dir) => {
            let s = ResultStore::open(dir)?;
            s.load_into(&engine)?;
            Some(s)
        }
        None => None,
    };
    let shared = Arc::new(Shared {
        engine,
        queue: JobQueue::bounded(opts.queue_cap),
        workers: opts.workers.max(1),
        busy: AtomicUsize::new(0),
        stopping: AtomicBool::new(false),
        addr,
        conns: AtomicUsize::new(0),
        max_conns: opts.max_conns.max(1),
    });

    let accept = {
        let sh = Arc::clone(&shared);
        std::thread::spawn(move || accept_loop(&sh, listener))
    };
    let workers: Vec<JoinHandle<()>> = (0..shared.workers)
        .map(|_| {
            let sh = Arc::clone(&shared);
            std::thread::spawn(move || worker_loop(&sh))
        })
        .collect();

    let supervisor = {
        let sh = Arc::clone(&shared);
        std::thread::spawn(move || {
            let _ = accept.join();
            for w in workers {
                let _ = w.join();
            }
            if let Some(store) = store {
                let _ = store.flush_from(&sh.engine);
            }
        })
    };

    Ok(ServerHandle { shared, supervisor: Some(supervisor) })
}

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    for stream in listener.incoming() {
        if shared.stopping.load(Ordering::SeqCst) {
            break;
        }
        let Ok(mut stream) = stream else { continue };
        if shared.conns.fetch_add(1, Ordering::SeqCst) >= shared.max_conns {
            shared.conns.fetch_sub(1, Ordering::SeqCst);
            let line = proto::error_line(0, "connection limit reached");
            let _ = stream
                .write_all(line.as_bytes())
                .and_then(|()| stream.write_all(b"\n"));
            continue; // dropping the stream closes it
        }
        let sh = Arc::clone(shared);
        // connection threads are detached; they exit when the client
        // disconnects or the queue rejects their next submission
        std::thread::spawn(move || {
            handle_conn(&sh, stream);
            sh.conns.fetch_sub(1, Ordering::SeqCst);
        });
    }
}

/// A request line larger than this drops the connection — bounds server
/// memory against a client that streams bytes without a newline.
/// (Inline topologies are small: resnet50 is ~8 KiB.)
const MAX_LINE_BYTES: usize = 4 << 20;

/// Responses time out rather than block a worker forever on a client
/// that submits jobs and then stops reading (full TCP send buffer).
const WRITE_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

fn handle_conn(shared: &Shared, stream: TcpStream) {
    let Ok(write_half) = stream.try_clone() else { return };
    let _ = write_half.set_write_timeout(Some(WRITE_TIMEOUT));
    let writer = ConnWriter::spawn(write_half);
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        // read one line with a hard cap: `take` stops at cap+1, so an
        // over-long line is detectable without buffering it all
        let n = match (&mut reader)
            .take(MAX_LINE_BYTES as u64 + 1)
            .read_until(b'\n', &mut buf)
        {
            Ok(0) => break, // client closed the connection
            Ok(n) => n,
            Err(_) => break,
        };
        if n > MAX_LINE_BYTES {
            writer.send_line(&proto::error_line(0, "request line exceeds 4 MiB"));
            break; // mid-line: cannot resync, drop the connection
        }
        let Ok(text) = std::str::from_utf8(&buf) else {
            writer.send_line(&proto::error_line(0, "request is not UTF-8"));
            continue;
        };
        let line = text.trim();
        if line.is_empty() {
            continue;
        }
        match proto::parse_request(line) {
            Err(e) => {
                // best-effort id echo so clients can pair the error
                let id = Json::parse(line).ok().and_then(|j| j.u64_field("id")).unwrap_or(0);
                writer.send_line(&proto::error_line(id, &e));
            }
            // stats answers inline from the connection thread — never
            // queued, so it observes queue depth rather than adding to it
            Ok(Request::Stats) => {
                writer.send_line(&shared.stats().to_json().to_string());
            }
            // metrics is the same snapshot in Prometheus text clothing,
            // likewise answered inline from the connection thread. The
            // deterministic section comes first; wall-class series
            // (stripe contention, steals, peer fetch/failover tallies)
            // are appended after, so two idle scrapes still agree on
            // everything above the wall section.
            Ok(Request::Metrics) => {
                crate::obs::metrics::record_stripe_contention(shared.engine.cache_contention());
                let mut text = crate::obs::metrics::server_exposition(&shared.stats());
                text.push_str(&crate::obs::metrics::global().render_wall_only());
                writer.send_line(&proto::metrics_line(&text));
            }
            Ok(Request::Shutdown) => {
                writer.send_line(&proto::shutting_down_line());
                shared.begin_shutdown();
                break;
            }
            Ok(Request::Run { id, topo, overrides, multi }) => {
                let cfg = overrides.apply(shared.engine.cfg());
                submit(
                    shared,
                    &writer,
                    id,
                    cfg.validate().map(|()| JobKind::Run { topo, cfg, multi }),
                );
            }
            Ok(Request::Sweep { id, kind, topos, overrides, multi }) => {
                let cfg = overrides.apply(shared.engine.cfg());
                submit(
                    shared,
                    &writer,
                    id,
                    cfg.validate().map(|()| JobKind::Sweep { kind, topos, cfg, multi }),
                );
            }
            Ok(Request::Dse { id, campaign, indices }) => {
                // the campaign's energy preset must match the server's
                // engine: cached reports embed energy numbers and the
                // model is not part of the cache key
                let job = if shared.engine.energy_model().preset_name()
                    != Some(campaign.energy.as_str())
                {
                    Err(crate::Error::Dse(format!(
                        "campaign energy preset {:?} does not match the server's energy \
                         model",
                        campaign.energy
                    )))
                } else {
                    // resolve at admission so unknown names error here,
                    // not inside a worker
                    campaign
                        .resolve_workloads(true)
                        .map(|topos| JobKind::Dse { campaign, topos, indices })
                };
                submit(shared, &writer, id, job);
            }
            Ok(Request::Batch { id, jobs }) => submit_batch(shared, &writer, id, jobs),
        }
    }
}

/// Queue a validated job, or report why it cannot run: a full queue
/// sheds with a `busy` event (transient — retry), a closed queue
/// answers a shutdown error (terminal).
fn submit(shared: &Shared, writer: &ConnWriter, id: u64, kind: Result<JobKind>) {
    match kind {
        Err(e) => writer.send_line(&proto::error_line(id, &e.to_string())),
        Ok(kind) => {
            let job = Job { id, kind, writer: writer.clone(), batch: None };
            match shared.queue.try_push(job) {
                queue::PushOutcome::Admitted => {}
                queue::PushOutcome::Busy => writer.send_line(&proto::busy_line(id)),
                queue::PushOutcome::Closed => {
                    writer.send_line(&proto::error_line(id, "server is shutting down"));
                }
            }
        }
    }
}

/// Admit a batch envelope: every sub-job becomes an independent queue
/// entry (the pool executes them concurrently — one slow job never
/// delays the others' events), shed sub-jobs answer per-id `busy`
/// events, and the envelope's `batch_done` follows the last admitted
/// sub-job's terminal event.
fn submit_batch(shared: &Shared, writer: &ConnWriter, id: u64, jobs: Vec<Request>) {
    // build (= validate) every sub-job before admitting any: an
    // envelope with an invalid member is rejected wholly, mirroring the
    // all-or-nothing parse-time checks
    let mut built: Vec<(u64, JobKind)> = Vec::with_capacity(jobs.len());
    for (n, sub) in jobs.into_iter().enumerate() {
        let job = match sub {
            Request::Run { id: sid, topo, overrides, multi } => {
                let cfg = overrides.apply(shared.engine.cfg());
                cfg.validate().map(|()| (sid, JobKind::Run { topo, cfg, multi }))
            }
            Request::Sweep { id: sid, kind, topos, overrides, multi } => {
                let cfg = overrides.apply(shared.engine.cfg());
                cfg.validate().map(|()| (sid, JobKind::Sweep { kind, topos, cfg, multi }))
            }
            // parse_request admits only run/sweep into an envelope
            _ => {
                writer.send_line(&proto::error_line(
                    id,
                    &format!("batch job {n}: only run/sweep jobs can ride in a batch"),
                ));
                return;
            }
        };
        match job {
            Ok(v) => built.push(v),
            Err(e) => {
                writer.send_line(&proto::error_line(id, &format!("batch job {n}: {e}")));
                return;
            }
        }
    }

    let tracker = Arc::new(BatchTracker {
        id,
        jobs: AtomicUsize::new(0),
        shed: AtomicUsize::new(0),
        // +1: the admission claim, released below once tallies are final
        remaining: AtomicUsize::new(built.len() + 1),
        writer: writer.clone(),
    });
    let (mut admitted, mut shed) = (0usize, 0usize);
    let mut closed = false;
    for (sid, kind) in built {
        if closed {
            writer.send_line(&proto::error_line(sid, "server is shutting down"));
            tracker.finish_one();
            continue;
        }
        let job = Job { id: sid, kind, writer: writer.clone(), batch: Some(Arc::clone(&tracker)) };
        match shared.queue.try_push(job) {
            queue::PushOutcome::Admitted => admitted += 1,
            queue::PushOutcome::Busy => {
                shed += 1;
                writer.send_line(&proto::busy_line(sid));
                tracker.finish_one();
            }
            queue::PushOutcome::Closed => {
                closed = true;
                writer.send_line(&proto::error_line(sid, "server is shutting down"));
                tracker.finish_one();
            }
        }
    }
    tracker.jobs.store(admitted, Ordering::Release);
    tracker.shed.store(shed, Ordering::Release);
    tracker.finish_one(); // release the admission claim
}

fn worker_loop(shared: &Shared) {
    while let Some(job) = shared.queue.pop() {
        let t0 = Instant::now();
        // a panicking job must not kill the worker or hang the client
        shared.busy.fetch_add(1, Ordering::SeqCst);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_job(&shared.engine, &job)
        }));
        shared.busy.fetch_sub(1, Ordering::SeqCst);
        // count the job done BEFORE emitting the terminal event, so a
        // client that sees `done` and immediately asks for `stats`
        // observes its job in `completed` (panics land in `failed`)
        shared.queue.job_done(outcome.is_ok());
        match outcome {
            Ok(points) => {
                job.writer.send_line(&proto::done_line(job.id, ms_since(t0), points));
            }
            Err(_) => {
                job.writer.send_line(&proto::error_line(job.id, "internal error: job panicked"));
            }
        }
        // after the sub-job's own terminal event, so `batch_done` is
        // always the envelope's last line on the wire
        if let Some(tracker) = &job.batch {
            tracker.finish_one();
        }
    }
}

/// Execute the job, streaming non-terminal events; the worker loop emits
/// the terminal `done`. Returns the point count for sweep jobs.
fn run_job(engine: &Engine, job: &Job) -> Option<usize> {
    match &job.kind {
        JobKind::Run { topo, cfg, multi } => {
            let report = match multi {
                None => engine.run_topology_with(cfg, topo),
                // multi-array run: the composed system view (slowest-node
                // timings, aggregate traffic/energy, summed interconnect
                // bandwidth) in the same wire shape
                Some(m) => {
                    let mc = crate::engine::MultiArrayConfig::new(
                        m.nodes,
                        cfg.array_h,
                        cfg.array_w,
                        m.partition,
                    );
                    engine.run_multi_opts(cfg, topo, &mc, &m.opts()).to_workload_report()
                }
            };
            job.writer.send_line(&proto::result_line(job.id, &report));
            None
        }
        JobKind::Sweep { kind, topos, cfg, multi } => {
            let (nodes, partitions, fabrics, link_bws) = match multi {
                None => (
                    vec![1],
                    vec![crate::engine::Partition::default()],
                    vec![crate::engine::FabricKind::Flat],
                    vec![crate::engine::DEFAULT_LINK_BW],
                ),
                Some(m) => (
                    vec![m.nodes],
                    vec![m.partition],
                    vec![m.fabric.unwrap_or_default()],
                    vec![m.link_bw.unwrap_or(crate::engine::DEFAULT_LINK_BW)],
                ),
            };
            let grid = match kind {
                SweepKind::Dataflow => engine
                    .sweep()
                    .workloads(topos)
                    .dataflows(&Dataflow::ALL)
                    .square_arrays(&[128, 64, 32, 16, 8]),
                SweepKind::Memory => engine
                    .sweep()
                    .workloads(topos)
                    .dataflows(&[cfg.dataflow])
                    .array_shapes(&[(cfg.array_h, cfg.array_w)])
                    .sram_sizes_kb(&[32, 64, 128, 256, 512, 1024, 2048]),
                SweepKind::Shape => engine
                    .sweep()
                    .workloads(topos)
                    .dataflows(&Dataflow::ALL)
                    .array_shapes(&crate::sweep::fig8_shapes()),
            };
            let out = grid
                .nodes(&nodes)
                .partitions(&partitions)
                .fabrics(&fabrics)
                .link_bws(&link_bws)
                .run();
            for p in &out.points {
                job.writer.send_line(&proto::point_line(job.id, p));
            }
            Some(out.points.len())
        }
        JobKind::Dse { campaign, topos, indices } => {
            for &i in indices {
                let point = campaign.point(i);
                let topo = &topos[&point.workload];
                let cp = crate::dse::CompletedPoint {
                    metrics: crate::dse::evaluate_point(engine, topo, &point),
                    point,
                };
                job.writer.send_line(&proto::dse_point_line(job.id, &cp));
            }
            Some(indices.len())
        }
    }
}

fn ms_since(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e3
}

/// Lines queued per connection before senders block (backpressure to
/// the worker, mirroring the queue's blocking-push discipline).
const WRITE_QUEUE_LINES: usize = 1024;

/// Per-connection response writer: a bounded channel feeding one
/// dedicated writer thread, so response serialization never holds a
/// lock across socket I/O (the old `Mutex<TcpStream>` was the single
/// accepted R2 finding). Clones share the channel; the writer thread
/// exits when the last clone drops, after delivering anything queued —
/// the same lifetime a job's `Arc` clone used to provide.
#[derive(Clone)]
struct ConnWriter {
    tx: std::sync::mpsc::SyncSender<String>,
}

impl ConnWriter {
    fn spawn(stream: TcpStream) -> ConnWriter {
        let (tx, rx) = std::sync::mpsc::sync_channel::<String>(WRITE_QUEUE_LINES);
        std::thread::spawn(move || {
            let mut stream = stream;
            let mut dead = false;
            for line in rx {
                if dead {
                    continue; // keep draining so senders never block on a dead peer
                }
                let outcome = stream
                    .write_all(line.as_bytes())
                    .and_then(|()| stream.write_all(b"\n"))
                    .and_then(|()| stream.flush());
                dead = outcome.is_err();
            }
        });
        ConnWriter { tx }
    }

    /// Queue one response line; errors (client hung up) are swallowed —
    /// the job still completes and populates the shared cache.
    fn send_line(&self, line: &str) {
        let _ = self.tx.send(line.to_string());
    }
}

/// Blocking JSON-lines client for the serve protocol — what
/// `scale-sim client`, `scale-sim bench-serve`, and the loopback tests
/// speak through.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs) -> std::io::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client { reader: BufReader::new(stream), writer })
    }

    /// Send one raw request line.
    pub fn send(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()
    }

    /// Read one response line as JSON.
    pub fn recv(&mut self) -> std::io::Result<Json> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.reader.read_line(&mut line)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed the connection",
                ));
            }
            if !line.trim().is_empty() {
                break;
            }
        }
        Json::parse(line.trim())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    /// Send one request and collect its full response stream, terminal
    /// event included.
    pub fn request(&mut self, line: &str) -> std::io::Result<Vec<Json>> {
        self.send(line)?;
        let mut out = Vec::new();
        loop {
            let j = self.recv()?;
            let terminal = proto::is_terminal_event(&j);
            out.push(j);
            if terminal {
                return Ok(out);
            }
        }
    }

    /// Send a batch envelope and collect every interleaved event until
    /// the *envelope's* terminal: `batch_done`, or an `error`/`busy`
    /// carrying the envelope id. Sub-job terminal events (`done`,
    /// per-sub-id `busy`/`error`) are collected, not terminal — demux
    /// them by their `id` field.
    pub fn request_batch(&mut self, line: &str) -> std::io::Result<Vec<Json>> {
        let envelope_id =
            Json::parse(line).ok().and_then(|j| j.u64_field("id")).unwrap_or(0);
        self.send(line)?;
        let mut out = Vec::new();
        loop {
            let j = self.recv()?;
            let terminal = match j.str_field("event") {
                Some("batch_done") | Some("shutting_down") => true,
                Some("error") | Some("busy") => j.u64_field("id") == Some(envelope_id),
                _ => false,
            };
            out.push(j);
            if terminal {
                return Ok(out);
            }
        }
    }

    /// Convenience: fetch and parse the server statistics.
    pub fn stats(&mut self) -> std::io::Result<ServerStats> {
        let events = self.request(r#"{"req":"stats"}"#)?;
        let last = events.last().ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "stats request returned no events",
            )
        })?;
        ServerStats::from_json(last)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    /// Convenience: fetch the Prometheus text exposition (the `metrics`
    /// event's `"text"` payload).
    pub fn metrics(&mut self) -> std::io::Result<String> {
        let events = self.request(r#"{"req":"metrics"}"#)?;
        let last = events.last().ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "metrics request returned no events",
            )
        })?;
        last.str_field("text").map(str::to_string).ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "metrics event is missing its \"text\" field",
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;

    fn inline_run_request(id: u64) -> String {
        let layers = Json::Arr(vec![
            proto::layer_shape_to_json(&LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1)),
            proto::layer_shape_to_json(&LayerShape::fc("fc", 1, 128, 10)),
        ]);
        Json::obj(vec![
            ("req", Json::str("run")),
            ("id", Json::u64(id)),
            ("workload", Json::str("inline-t")),
            ("layers", layers),
            ("array", Json::str("16x16")),
        ])
        .to_string()
    }

    #[test]
    fn run_job_round_trips_and_shuts_down_cleanly() {
        let handle = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
        let addr = handle.addr();

        let mut c = Client::connect(addr).unwrap();
        let events = c.request(&inline_run_request(42)).unwrap();
        assert_eq!(events.len(), 2, "result + done");
        assert_eq!(events[0].str_field("event"), Some("result"));
        assert_eq!(events[0].u64_field("id"), Some(42));
        let report =
            proto::workload_report_from_json(events[0].get("report").unwrap()).unwrap();
        assert_eq!(report.layers.len(), 2);
        assert_eq!(events[1].str_field("event"), Some("done"));

        let stats = c.stats().unwrap();
        assert_eq!(stats.completed, 1);
        assert_eq!(stats.workers, 2);
        assert_eq!(stats.memo.layer_sims, 2);

        // protocol-initiated shutdown
        let bye = c.request(r#"{"req":"shutdown"}"#).unwrap();
        assert_eq!(bye[0].str_field("event"), Some("shutting_down"));
        handle.join();
    }

    #[test]
    fn bad_requests_get_error_events_not_disconnects() {
        let handle = start(ServeOpts::default()).unwrap();
        let mut c = Client::connect(handle.addr()).unwrap();

        let e = c.request("definitely not json").unwrap();
        assert_eq!(e[0].str_field("event"), Some("error"));

        let e = c.request(r#"{"req":"run","id":5,"workload":"no_such_net"}"#).unwrap();
        assert_eq!(e[0].u64_field("id"), Some(5));
        assert!(e[0].str_field("error").unwrap().contains("no_such_net"));

        // invalid override caught at admission, not in a worker
        let e = c.request(r#"{"req":"run","id":6,"workload":"ncf","array":"0x8"}"#).unwrap();
        assert_eq!(e[0].str_field("event"), Some("error"));

        // the connection is still usable afterwards
        let ok = c.request(&inline_run_request(7)).unwrap();
        assert_eq!(ok.last().unwrap().str_field("event"), Some("done"));
        handle.shutdown();
    }

    #[test]
    fn multi_array_run_reports_the_composed_system() {
        let handle = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
        let mut c = Client::connect(handle.addr()).unwrap();

        // 4 nodes of 16x16, channel partition, inline layers
        let layers = Json::Arr(vec![proto::layer_shape_to_json(&LayerShape::conv(
            "c1", 16, 16, 3, 3, 4, 8, 1,
        ))]);
        let req = Json::obj(vec![
            ("req", Json::str("run")),
            ("id", Json::u64(11)),
            ("workload", Json::str("multi")),
            ("layers", layers),
            ("array", Json::str("16x16")),
            ("nodes", Json::u64(4)),
            ("partition", Json::str("channels")),
        ])
        .to_string();
        let events = c.request(&req).unwrap();
        assert_eq!(events.last().unwrap().str_field("event"), Some("done"));
        let report =
            proto::workload_report_from_json(events[0].get("report").unwrap()).unwrap();
        // the wire report is the engine's composed multi view, bit-identical
        let engine = crate::engine::Engine::new(ArchConfig {
            array_h: 16,
            array_w: 16,
            ..ArchConfig::default()
        });
        let topo = Topology::new("multi", vec![LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1)]);
        let mc = crate::engine::MultiArrayConfig::new(
            4,
            16,
            16,
            crate::engine::Partition::OutputChannels,
        );
        let want = engine.run_multi(&topo, &mc).to_workload_report();
        assert_eq!(report, want);

        // partition without nodes is rejected at parse time
        let bad = c
            .request(r#"{"req":"run","workload":"ncf","partition":"pixels"}"#)
            .unwrap();
        assert_eq!(bad[0].str_field("event"), Some("error"));
        handle.shutdown();
    }

    #[test]
    fn batch_envelope_interleaves_jobs_and_ends_with_batch_done() {
        let handle = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
        let mut c = Client::connect(handle.addr()).unwrap();
        let req = format!(
            r#"{{"req":"batch","id":99,"jobs":[{},{}]}}"#,
            inline_run_request(1),
            inline_run_request(2)
        );
        let events = c.request_batch(&req).unwrap();
        let last = events.last().unwrap();
        assert_eq!(last.str_field("event"), Some("batch_done"));
        assert_eq!(last.u64_field("id"), Some(99));
        assert_eq!(last.u64_field("jobs"), Some(2));
        assert_eq!(last.u64_field("shed"), Some(0));
        // each sub-job produced its own result + done, demuxable by id
        for sid in [1u64, 2] {
            for ev in ["result", "done"] {
                assert!(
                    events.iter().any(|j| j.u64_field("id") == Some(sid)
                        && j.str_field("event") == Some(ev)),
                    "missing {ev} for sub-job {sid}"
                );
            }
        }
        // an envelope with a bad sub-job is rejected wholly
        let bad = c
            .request(r#"{"req":"batch","id":5,"jobs":[{"req":"run","id":1,"workload":"nope"}]}"#)
            .unwrap();
        assert_eq!(bad[0].str_field("event"), Some("error"));
        handle.shutdown();
    }

    #[test]
    fn sweep_job_streams_points() {
        let handle = start(ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
        let mut c = Client::connect(handle.addr()).unwrap();
        let events = c
            .request(r#"{"req":"sweep","id":9,"kind":"memory","workload":"ncf","array":"32x32"}"#)
            .unwrap();
        let done = events.last().unwrap();
        assert_eq!(done.str_field("event"), Some("done"));
        assert_eq!(done.u64_field("points"), Some(7), "7 SRAM sizes");
        assert_eq!(events.len(), 8, "7 point events + done");
        assert_eq!(events[0].str_field("event"), Some("point"));
        assert_eq!(events[0].u64_field("array_h"), Some(32), "array override honored");
        handle.shutdown();
    }
}
