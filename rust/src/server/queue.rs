//! Bounded MPMC job queue with blocking backpressure — the admission
//! control between connection threads (producers) and the worker pool
//! (consumers).
//!
//! * [`JobQueue::push`] **blocks while the queue is full**. A connection
//!   thread that blocks here stops reading its socket, so TCP flow
//!   control propagates the pressure all the way back to the client —
//!   jobs are never dropped, they are admitted late.
//! * [`JobQueue::try_push`] is the non-blocking variant the serve
//!   admission path uses: a full queue **sheds** the job immediately
//!   ([`PushOutcome::Busy`]) so the connection thread can answer with a
//!   structured `busy` event and keep reading its socket instead of
//!   wedging behind a saturated worker pool.
//! * [`JobQueue::pop`] blocks while empty. After [`JobQueue::close`] it
//!   keeps draining whatever was admitted (accepted jobs always run;
//!   zero dropped jobs on shutdown) and returns `None` only once the
//!   queue is both closed and empty.
//! * Occupancy counters ([`JobQueue::stats`]) feed the serve protocol's
//!   `stats` event: depth, in-flight, completed, submitted.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

/// Queue occupancy snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Jobs admitted but not yet claimed by a worker.
    pub depth: usize,
    /// Jobs claimed by workers and still executing.
    pub in_flight: usize,
    /// Jobs fully executed.
    pub completed: u64,
    /// Jobs that ended abnormally (executor panicked).
    pub failed: u64,
    /// Jobs ever admitted (`depth + in_flight + completed + failed` at
    /// rest).
    pub submitted: u64,
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    in_flight: usize,
    completed: u64,
    failed: u64,
    submitted: u64,
}

/// Result of a non-blocking admission attempt ([`JobQueue::try_push`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// The job is in the queue.
    Admitted,
    /// The queue is at capacity; the job was shed (transient).
    Busy,
    /// The queue is closed; the job was shed (terminal).
    Closed,
}

/// Bounded blocking queue (module docs). `T` is the job payload.
pub struct JobQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

impl<T> JobQueue<T> {
    /// Lock the queue state, recovering from poisoning: a panicking
    /// worker at worst leaves a counter stale, never a torn queue
    /// structure (every mutation below is a single push/pop/store), so
    /// cascading the panic into every producer and consumer would turn
    /// one bad job into a dead server.
    fn state(&self) -> std::sync::MutexGuard<'_, Inner<T>> {
        self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// A queue admitting at most `cap >= 1` waiting jobs.
    pub fn bounded(cap: usize) -> Self {
        JobQueue {
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
                in_flight: 0,
                completed: 0,
                failed: 0,
                submitted: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Admit a job, blocking while the queue is at capacity
    /// (backpressure). Returns `false` — job handed back untouched is
    /// not possible, the job is dropped — when the queue has been
    /// closed; callers should then report the rejection to the client.
    pub fn push(&self, job: T) -> bool {
        let mut inner = self.state();
        while !inner.closed && inner.q.len() >= self.cap {
            inner = self
                .not_full
                .wait(inner)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        if inner.closed {
            return false;
        }
        inner.q.push_back(job);
        inner.submitted += 1;
        drop(inner);
        self.not_empty.notify_one();
        true
    }

    /// Admit a job without blocking. Distinguishes the two rejection
    /// causes so the caller can answer with the right protocol event:
    /// a full queue is transient (`Busy` — retry later), a closed queue
    /// is terminal (`Closed` — the server is shutting down).
    pub fn try_push(&self, job: T) -> PushOutcome {
        let mut inner = self.state();
        if inner.closed {
            return PushOutcome::Closed;
        }
        if inner.q.len() >= self.cap {
            return PushOutcome::Busy;
        }
        inner.q.push_back(job);
        inner.submitted += 1;
        drop(inner);
        self.not_empty.notify_one();
        PushOutcome::Admitted
    }

    /// Claim the next job, blocking while the queue is empty. Returns
    /// `None` once the queue is closed **and** drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.state();
        loop {
            if let Some(job) = inner.q.pop_front() {
                inner.in_flight += 1;
                drop(inner);
                self.not_full.notify_one();
                return Some(job);
            }
            if inner.closed {
                return None;
            }
            inner = self
                .not_empty
                .wait(inner)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    /// Mark one claimed job finished (worker calls after executing);
    /// `ok = false` records an abnormal end (counted in `failed`, not
    /// `completed`).
    pub fn job_done(&self, ok: bool) {
        let mut inner = self.state();
        debug_assert!(inner.in_flight > 0, "job_done without a matching pop");
        inner.in_flight = inner.in_flight.saturating_sub(1);
        if ok {
            inner.completed += 1;
        } else {
            inner.failed += 1;
        }
    }

    /// Stop admitting jobs and wake every blocked producer/consumer.
    /// Already-admitted jobs continue to drain through [`JobQueue::pop`].
    pub fn close(&self) {
        self.state().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state().closed
    }

    pub fn stats(&self) -> QueueStats {
        let inner = self.state();
        QueueStats {
            depth: inner.q.len(),
            in_flight: inner.in_flight,
            completed: inner.completed,
            failed: inner.failed,
            submitted: inner.submitted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::time::Duration;

    #[test]
    fn fifo_order_and_counters() {
        let q = JobQueue::bounded(8);
        assert!(q.push(1) && q.push(2) && q.push(3));
        assert_eq!(
            q.stats(),
            QueueStats { depth: 3, in_flight: 0, completed: 0, failed: 0, submitted: 3 }
        );
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.stats().in_flight, 1);
        q.job_done(true);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        q.job_done(true);
        q.job_done(false); // abnormal end: failed, not completed
        assert_eq!(
            q.stats(),
            QueueStats { depth: 0, in_flight: 0, completed: 2, failed: 1, submitted: 3 }
        );
    }

    #[test]
    fn full_queue_blocks_until_a_pop_frees_a_slot() {
        let q = JobQueue::bounded(1);
        assert!(q.push(10));
        let unblocked = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                assert!(q.push(11)); // must block: capacity 1, occupied
                unblocked.store(true, Ordering::SeqCst);
            });
            std::thread::sleep(Duration::from_millis(50));
            assert!(!unblocked.load(Ordering::SeqCst), "push must backpressure");
            assert_eq!(q.pop(), Some(10));
            // the blocked producer now completes
            std::thread::sleep(Duration::from_millis(50));
            assert!(unblocked.load(Ordering::SeqCst));
            assert_eq!(q.pop(), Some(11));
        });
    }

    #[test]
    fn try_push_sheds_on_full_and_distinguishes_closed() {
        let q = JobQueue::bounded(2);
        assert_eq!(q.try_push(1), PushOutcome::Admitted);
        assert_eq!(q.try_push(2), PushOutcome::Admitted);
        // at capacity: shed, counters untouched by the rejected job
        assert_eq!(q.try_push(3), PushOutcome::Busy);
        assert_eq!(q.stats().submitted, 2);
        assert_eq!(q.stats().depth, 2);
        // a pop frees a slot and admission resumes
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(4), PushOutcome::Admitted);
        q.close();
        assert_eq!(q.try_push(5), PushOutcome::Closed, "closed beats busy");
        // admitted jobs still drain in order
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_drains_admitted_jobs_then_stops() {
        let q = JobQueue::bounded(4);
        assert!(q.push("a"));
        assert!(q.push("b"));
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
        assert!(!q.push("c"), "closed queue admits nothing");
        assert_eq!(q.pop(), Some("a"), "admitted jobs still drain");
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
        assert_eq!(q.stats().submitted, 2);
    }

    #[test]
    fn close_wakes_blocked_consumers() {
        let q: JobQueue<u32> = JobQueue::bounded(4);
        std::thread::scope(|s| {
            let h = s.spawn(|| q.pop());
            std::thread::sleep(Duration::from_millis(30));
            q.close();
            assert_eq!(h.join().unwrap(), None);
        });
    }

    #[test]
    fn close_wakes_blocked_producers() {
        let q = JobQueue::bounded(1);
        assert!(q.push(1));
        std::thread::scope(|s| {
            let h = s.spawn(|| q.push(2));
            std::thread::sleep(Duration::from_millis(30));
            q.close();
            assert!(!h.join().unwrap(), "blocked producer must observe the close");
        });
    }
}
