//! Peer federation: one logical memo cache across a fleet of serve
//! instances.
//!
//! `scale-sim serve --peers a:p,b:p` places every fleet member (self
//! included) on a consistent-hash ring of [`VNODES`] virtual nodes per
//! member. Each memo key hash has exactly one owner; a non-self owner
//! is asked for the layer report over the ordinary wire protocol (a
//! one-layer `run` request pinning the full override set), so the
//! owner's memo cache — not ours — fills and serves that key. The
//! fleet therefore shares one logical cache without any replication
//! protocol: **federation routes keys, never values**
//! (`docs/INVARIANTS.md` §11) — a routed report is returned to the
//! caller but never inserted into the local table, and a failed fetch
//! (peer down, timeout, refusal, `busy`) silently fails over to local
//! compute, changing only *where* the simulation runs, never its
//! result.
//!
//! Ring agreement is by construction: every member sorts the same
//! member-address strings, so owners match fleet-wide as long as each
//! instance is started with the same addresses (its own spelled exactly
//! as peers name it) and the same base config/backend. Peer fetch and
//! failover tallies are wall-class metrics
//! ([`crate::obs::metrics::count_peer_fetch`]).

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::engine::LayerRouter;
use crate::sim::LayerReport;
use crate::util::json::Json;
use crate::{Error, Result};

use super::proto;

/// Virtual nodes per member: enough that two-member fleets split keys
/// close to evenly, few enough that ring construction stays trivial.
const VNODES: usize = 64;

/// Establishing a connection to a peer; short, so a down peer costs one
/// quick failure per routed key rather than a stall.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(1000);

/// Per-fetch socket read/write budget; a peer that exceeds it is
/// treated as down (failover to local compute).
const IO_TIMEOUT: Duration = Duration::from_secs(15);

/// FNV-1a over raw bytes — the same deterministic hash family the memo
/// cache uses for stripe selection ([`crate::engine::cache`]); std's
/// `DefaultHasher` is per-process seeded and would break fleet-wide
/// ring agreement.
fn fnv1a(bytes: &[u8]) -> u64 {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// The fleet's consistent-hash ring (module docs). Construction is a
/// pure function of the sorted member-address set, so every member
/// that was given the same fleet computes identical ownership.
pub struct PeerRing {
    /// Sorted, deduplicated member addresses.
    members: Vec<String>,
    /// Index into `members` of this instance.
    self_idx: usize,
    /// `(vnode hash, member index)` sorted by hash then index.
    ring: Vec<(u64, usize)>,
}

impl PeerRing {
    /// Build the ring from this instance's advertised address plus its
    /// peer list. Rejects empty addresses; duplicates collapse.
    pub fn new(self_addr: &str, peers: &[String]) -> Result<PeerRing> {
        let self_addr = self_addr.trim();
        if self_addr.is_empty() {
            return Err(Error::Config("federation: empty self address".into()));
        }
        let mut members: Vec<String> = vec![self_addr.to_string()];
        for p in peers {
            let p = p.trim();
            if p.is_empty() {
                return Err(Error::Config("federation: empty peer address".into()));
            }
            members.push(p.to_string());
        }
        members.sort();
        members.dedup();
        let self_idx = members
            .iter()
            .position(|m| m == self_addr)
            .unwrap_or_default(); // unreachable: self_addr was inserted
        let mut ring = Vec::with_capacity(members.len() * VNODES);
        for (i, m) in members.iter().enumerate() {
            for v in 0..VNODES {
                let mut bytes = m.as_bytes().to_vec();
                bytes.push(0);
                bytes.extend_from_slice(&(v as u64).to_le_bytes());
                ring.push((fnv1a(&bytes), i));
            }
        }
        ring.sort();
        Ok(PeerRing { members, self_idx, ring })
    }

    pub fn members(&self) -> &[String] {
        &self.members
    }

    /// The member index owning `key_hash`: the first vnode at or after
    /// the hash, wrapping to the ring's start.
    pub fn owner(&self, key_hash: u64) -> usize {
        let i = self.ring.partition_point(|&(h, _)| h < key_hash);
        let (_, member) = self.ring[i % self.ring.len()];
        member
    }

    pub fn is_self(&self, member: usize) -> bool {
        member == self.self_idx
    }

    pub fn member(&self, i: usize) -> &str {
        &self.members[i]
    }
}

/// [`LayerRouter`] over a [`PeerRing`]: self-owned keys take the local
/// memoized path (`None`); peer-owned keys are fetched from their
/// owner, failing over to local compute on any error.
pub struct PeerRouter {
    ring: PeerRing,
}

impl PeerRouter {
    pub fn new(ring: PeerRing) -> PeerRouter {
        PeerRouter { ring }
    }
}

impl LayerRouter for PeerRouter {
    fn route(&self, key_hash: u64, cfg: &ArchConfig, layer: &LayerShape) -> Option<LayerReport> {
        let owner = self.ring.owner(key_hash);
        if self.ring.is_self(owner) {
            return None;
        }
        match fetch_layer(self.ring.member(owner), cfg, layer) {
            Ok(report) => {
                crate::obs::metrics::count_peer_fetch();
                Some(report)
            }
            Err(_) => {
                crate::obs::metrics::count_peer_failover();
                None
            }
        }
    }
}

/// One peer fetch: a single-layer `run` request pinning every
/// cache-key-relevant override, answered by the owner's memoized
/// engine. Any failure — connect, timeout, protocol, `busy`, `error` —
/// is returned for the caller to fail over on.
fn fetch_layer(addr: &str, cfg: &ArchConfig, layer: &LayerShape) -> std::result::Result<LayerReport, String> {
    let sa = addr
        .to_socket_addrs()
        .map_err(|e| e.to_string())?
        .next()
        .ok_or_else(|| format!("unresolvable peer address {addr:?}"))?;
    let stream = TcpStream::connect_timeout(&sa, CONNECT_TIMEOUT).map_err(|e| e.to_string())?;
    stream.set_read_timeout(Some(IO_TIMEOUT)).map_err(|e| e.to_string())?;
    stream.set_write_timeout(Some(IO_TIMEOUT)).map_err(|e| e.to_string())?;
    let writer = stream.try_clone().map_err(|e| e.to_string())?;
    let mut client = super::Client { reader: BufReader::new(stream), writer };

    let req = Json::obj(vec![
        ("req", Json::str("run")),
        ("id", Json::u64(0)),
        ("workload", Json::str("peer-fetch")),
        ("layers", Json::Arr(vec![proto::layer_shape_to_json(layer)])),
        ("dataflow", Json::str(cfg.dataflow.name())),
        ("array", Json::str(format!("{}x{}", cfg.array_h, cfg.array_w))),
        (
            "sram_kb",
            Json::Arr(vec![
                Json::u64(cfg.ifmap_sram_kb),
                Json::u64(cfg.filter_sram_kb),
                Json::u64(cfg.ofmap_sram_kb),
            ]),
        ),
        ("word_bytes", Json::u64(cfg.word_bytes)),
    ])
    .to_string();

    let events = client.request(&req).map_err(|e| e.to_string())?;
    let last = events.last().ok_or_else(|| "peer sent no events".to_string())?;
    if last.str_field("event") != Some("done") {
        return Err(format!("peer answered {:?}", last.str_field("event")));
    }
    let result = events
        .iter()
        .find(|j| j.str_field("event") == Some("result"))
        .ok_or_else(|| "peer sent no result event".to_string())?;
    let report = proto::workload_report_from_json(
        result.get("report").ok_or_else(|| "result event missing report".to_string())?,
    )?;
    report.layers.into_iter().next().ok_or_else(|| "peer report has no layers".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_agreement_is_independent_of_listing_order() {
        // the same fleet, seen from two different members with peers
        // listed in different orders, must agree on every owner
        let a = PeerRing::new("10.0.0.1:7433", &["10.0.0.2:7433".into(), "10.0.0.3:7433".into()])
            .unwrap();
        let b = PeerRing::new("10.0.0.3:7433", &["10.0.0.1:7433".into(), "10.0.0.2:7433".into()])
            .unwrap();
        assert_eq!(a.members(), b.members());
        for h in (0..10_000u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)) {
            assert_eq!(a.member(a.owner(h)), b.member(b.owner(h)), "owner disagrees at {h:#x}");
        }
    }

    #[test]
    fn single_member_ring_owns_every_key() {
        let r = PeerRing::new("127.0.0.1:7433", &[]).unwrap();
        for h in [0u64, 1, u64::MAX, 0xdead_beef] {
            assert!(r.is_self(r.owner(h)));
        }
    }

    #[test]
    fn two_member_ring_splits_keys_between_both() {
        let r = PeerRing::new("127.0.0.1:7001", &["127.0.0.1:7002".into()]).unwrap();
        let mut counts = [0usize; 2];
        for h in (0..4096u64).map(|i| i.wrapping_mul(0x9e37_79b9_7f4a_7c15)) {
            counts[r.owner(h)] += 1;
        }
        assert!(counts[0] > 0 && counts[1] > 0, "both members must own keys: {counts:?}");
        // vnodes keep the split from degenerating
        assert!(counts[0] > 512 && counts[1] > 512, "split too skewed: {counts:?}");
    }

    #[test]
    fn duplicate_and_self_listing_peers_collapse() {
        let r = PeerRing::new(
            "127.0.0.1:7001",
            &["127.0.0.1:7002".into(), "127.0.0.1:7001".into(), "127.0.0.1:7002".into()],
        )
        .unwrap();
        assert_eq!(r.members().len(), 2);
        assert!(PeerRing::new("", &[]).is_err());
        assert!(PeerRing::new("127.0.0.1:7001", &["  ".into()]).is_err());
    }

    #[test]
    fn fnv_vnode_placement_is_stable() {
        // pin a few hashes so an accidental constant change cannot
        // silently re-shard a deployed fleet
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        let r1 = PeerRing::new("a:1", &["b:2".into()]).unwrap();
        let r2 = PeerRing::new("a:1", &["b:2".into()]).unwrap();
        for h in [7u64, 1 << 40, u64::MAX / 3] {
            assert_eq!(r1.owner(h), r2.owner(h));
        }
    }
}
