//! Wire protocol for `scale-sim serve` — hand-rolled **JSON lines** over
//! TCP (serde/tonic are unavailable offline; every message is one JSON
//! object on one `\n`-terminated line, UTF-8).
//!
//! ## Requests (client -> server)
//!
//! | shape | meaning |
//! |---|---|
//! | `{"req":"run","id":1,"workload":"resnet50"}` | simulate one workload (built-in name — conv or GEMM family — or `W1`..`W7` tag) |
//! | `{"req":"run","id":2,"workload":"mine","layers":[{...layer...},..]}` | simulate an inline topology (lowered Table-II layer objects, shape below) |
//! | `{"req":"run","id":3,"workload":"mine","ops":[{...op...},..]}` | simulate an inline **typed workload** (operator IR, lowered server-side; op shape below) |
//! | `{"req":"sweep","id":4,"kind":"dataflow","workload":"ncf"}` | run a paper sweep (`dataflow`\|`memory`\|`shape`); omit `workload` for the full MLPerf suite; `layers`/`ops` are accepted here too |
//! | `{"req":"dse","id":5,"campaign":{...},"indices":[0,4,8]}` | evaluate one shard of a dse campaign ([`crate::dse::Campaign`] JSON spec; built-in workload names only). `indices` selects the campaign points to evaluate (omitted = all). Shards from concurrent clients share the server's ONE memo cache. The campaign's `energy` preset must match the server engine's model, and non-axis config fields (ofmap SRAM, word size) come from the server's base config — run the server on defaults for bit-identity with local execution |
//! | `{"req":"batch","id":6,"jobs":[{...run...},{...sweep...},..]}` | submit several run/sweep jobs in one envelope. Each entry is a complete run/sweep request object with its **own distinct `id`**; the jobs execute concurrently on the worker pool (batch sub-jobs are split across workers via the work-stealing deques), so their event streams interleave — demultiplex by `id`. The envelope's own `id` tags the final `batch_done` |
//! | `{"req":"stats"}` | server/queue/cache statistics (answered inline, never queued) |
//! | `{"req":"metrics"}` | Prometheus text exposition of the same statistics (answered inline; see [`crate::obs::metrics`]) |
//! | `{"req":"shutdown"}` | drain the queue, flush the result store, stop |
//!
//! `run` accepts optional architecture overrides applied on top of the
//! server's base config: `"dataflow":"os|ws|is"`, `"array":"RxC"`,
//! `"sram_kb":[ifmap,filter,ofmap]`, `"word_bytes":N`. `sweep` accepts
//! `dataflow`/`array` for `"kind":"memory"` only (they pin the
//! non-swept axes); any override a sweep would have to ignore is
//! rejected with an error rather than silently dropped. `id` is an
//! arbitrary client-chosen `u64` echoed on every response line for that
//! job (default 0).
//!
//! **Multi-array fields** (§IV-E scale-out, [`crate::engine::multi`]):
//! `run` and `sweep` accept `"nodes":N` (> 0) plus an optional
//! `"partition":"channels|pixels|auto"` — the job then simulates `N`
//! replicas of the (per-node) array shape with the workload partitioned
//! across them, reporting the composed system view (slowest-node
//! timings, aggregate traffic/energy, summed interconnect bandwidth).
//! `"partition"` without `"nodes"` is rejected. Three more multi-array
//! fields refine the memory system: `"dram_bw":B` (finite positive
//! bytes/cycle) models shared-DRAM stalls, `"fabric":"flat|line|ring|
//! mesh"` selects the route-aware interconnect, and `"link_bw":B`
//! (finite positive; requires `"fabric"`, default 16) sets its per-link
//! bandwidth — all validated at admission, so a bad bandwidth is an
//! `error` event, never a worker panic. `dse` campaigns carry their own
//! `"nodes"`/`"partitions"`/`"topologies"`/`"link_bw"` axes inside the
//! campaign spec.
//!
//! A layer object is the Table-II row:
//! `{"name":"c1","ifmap_h":16,"ifmap_w":16,"filt_h":3,"filt_w":3,
//!   "channels":4,"num_filters":8,"stride":1}`.
//!
//! An op object is the typed IR's wire form
//! ([`crate::workload::OpNode::from_json`]), discriminated by `"type"`:
//! `{"type":"conv2d","name":"c1","ifmap_h":16,"ifmap_w":16,
//!   "in_channels":4,"out_channels":8,"kernel_h":3,"stride":1,
//!   "dilation":1,"groups":1}` (trailing three optional, default 1;
//! `kernel_w` defaults to `kernel_h`), `{"type":"gemm","m":..,"k":..,
//! "n":..}`, `{"type":"fc","batch":..,"in_features":..,
//! "out_features":..}`, `{"type":"pool",...}`, or `{"type":"layer",...}`
//! (raw Table-II fields). `"ops"` and `"layers"` are mutually exclusive;
//! ops are lowered onto engine tiles before queueing, so conv- and
//! GEMM-encoded submissions share the server's memo cache.
//!
//! ## Responses (server -> client)
//!
//! Job responses stream; every line carries the job's `id` and an
//! `event` discriminator, ending with a terminal event:
//!
//! | event | payload |
//! |---|---|
//! | `result` | `"report"`: the full workload report (shape below) — `run` jobs |
//! | `point` | one sweep grid point: coordinates + headline metrics — `sweep` jobs |
//! | `dse_point` | one campaign point: `"point"` coordinates + `"metrics"` objectives ([`crate::dse::CompletedPoint`] shape) — `dse` jobs |
//! | `done` | **terminal**; `"ms"` wall-clock, plus `"points"` for sweeps |
//! | `error` | **terminal**; `"error"` message (bad request, queue closed, …) |
//! | `busy` | **terminal**; the bounded queue was full at admission, so the job was **shed** — nothing was queued, nothing will arrive later. Back off and retry. (The blocking alternative would wedge the connection thread behind a saturated pool; shedding keeps admission responsive and lets the client decide.) |
//! | `batch_done` | **terminal** for a `batch` envelope; carries the envelope `id`, `"jobs"` (sub-jobs admitted) and `"shed"` (sub-jobs answered `busy`). Emitted after every admitted sub-job has ended; the sub-jobs' own `result`/`point`/`done`/`error`/`busy` lines stream before it, interleaved |
//! | `stats` | **terminal**; see [`ServerStats`] field list |
//! | `metrics` | **terminal**; `"text"`: Prometheus text exposition (cache/queue/worker series) |
//! | `shutting_down` | **terminal**; acknowledges a shutdown request |
//!
//! `done`/`error`/`busy` are terminal **per job id**: a batch envelope's
//! sub-jobs each end with one of them, and the envelope itself ends with
//! `batch_done` — clients reading a batch response must collect until
//! `batch_done` (or an envelope-`id` `error`), not until the first
//! sub-job terminal (see [`crate::server::Client::request_batch`]).
//!
//! The workload report is
//! `{"workload":"...","layers":[{"layer":{...},"timing":{...},
//! "dram":{...},"bandwidth":{...},"energy":{...}},..]}` with field names
//! exactly matching the `LayerReport` structs. Numbers are emitted as
//! shortest-round-trip decimals and parsed back exactly
//! ([`crate::util::json`]), so a report that crosses the wire (or the
//! result store) is **bit-identical** on both ends — asserted by the
//! loopback round-trip suite.

use crate::arch::LayerShape;
use crate::config::{workloads, ArchConfig, Topology};
use crate::dataflow::{Dataflow, Timing};
use crate::energy::EnergyBreakdown;
use crate::engine::{
    FabricConfig, FabricKind, MemoStats, MultiOpts, Partition, WarmStats, DEFAULT_LINK_BW,
};
use crate::memory::{BandwidthReport, DramTraffic};
use crate::sim::{LayerReport, WorkloadReport};
use crate::util::json::Json;

/// Multi-array coordinates of a run/sweep job (node shape = the job's
/// effective array shape).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MultiReq {
    pub nodes: u64,
    pub partition: Partition,
    /// Shared DRAM read bandwidth in bytes/cycle to model stalls
    /// against. Validated finite and positive at parse time — a
    /// non-positive bandwidth is an admission error, never a worker
    /// panic.
    pub dram_bw: Option<f64>,
    /// Route-aware interconnect topology ([`crate::engine::fabric`]);
    /// `flat` (or absent) keeps the legacy contention model.
    pub fabric: Option<FabricKind>,
    /// Per-link bandwidth in bytes/cycle (requires `fabric`; default
    /// [`DEFAULT_LINK_BW`]). Validated finite and positive.
    pub link_bw: Option<f64>,
}

impl MultiReq {
    /// The engine-side run options this request selects.
    pub fn opts(&self) -> MultiOpts {
        MultiOpts {
            shared_dram_bw: self.dram_bw,
            fabric: self
                .fabric
                .map(|kind| FabricConfig::new(kind, self.link_bw.unwrap_or(DEFAULT_LINK_BW))),
            dram: None,
        }
    }
}

/// One parsed client request.
#[derive(Debug)]
pub enum Request {
    Run { id: u64, topo: Topology, overrides: Overrides, multi: Option<MultiReq> },
    Sweep {
        id: u64,
        kind: SweepKind,
        topos: Vec<Topology>,
        overrides: Overrides,
        multi: Option<MultiReq>,
    },
    /// One shard of a dse campaign: the indices of the campaign points
    /// this job evaluates (see [`crate::dse::Campaign::point`]).
    Dse { id: u64, campaign: crate::dse::Campaign, indices: Vec<usize> },
    /// A batch envelope: several run/sweep jobs admitted together and
    /// executed concurrently (module docs). `jobs` holds only
    /// [`Request::Run`] / [`Request::Sweep`] variants — enforced at
    /// parse time — each with a distinct non-envelope id.
    Batch { id: u64, jobs: Vec<Request> },
    Stats,
    /// Prometheus text exposition of the server statistics (answered
    /// inline, never queued — same data as `Stats`, different surface).
    Metrics,
    Shutdown,
}

/// Which paper sweep a `sweep` job runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SweepKind {
    Dataflow,
    Memory,
    Shape,
}

impl SweepKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "dataflow" => Ok(SweepKind::Dataflow),
            "memory" => Ok(SweepKind::Memory),
            "shape" => Ok(SweepKind::Shape),
            other => Err(format!("unknown sweep kind {other:?} (dataflow|memory|shape)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SweepKind::Dataflow => "dataflow",
            SweepKind::Memory => "memory",
            SweepKind::Shape => "shape",
        }
    }
}

/// Optional per-request architecture overrides.
#[derive(Clone, Debug, Default)]
pub struct Overrides {
    pub dataflow: Option<Dataflow>,
    pub array: Option<(u64, u64)>,
    pub sram_kb: Option<(u64, u64, u64)>,
    pub word_bytes: Option<u64>,
}

impl Overrides {
    /// The request's effective config: server base + overrides.
    pub fn apply(&self, base: &ArchConfig) -> ArchConfig {
        let mut cfg = base.clone();
        if let Some(df) = self.dataflow {
            cfg.dataflow = df;
        }
        if let Some((h, w)) = self.array {
            cfg.array_h = h;
            cfg.array_w = w;
        }
        if let Some((i, f, o)) = self.sram_kb {
            cfg.ifmap_sram_kb = i;
            cfg.filter_sram_kb = f;
            cfg.ofmap_sram_kb = o;
        }
        if let Some(wb) = self.word_bytes {
            cfg.word_bytes = wb;
        }
        cfg
    }
}

/// Server-side statistics reported by the `stats` event: bounded-queue
/// occupancy, worker activity, and the shared memo cache counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub queue_depth: usize,
    pub in_flight: usize,
    pub completed: u64,
    /// Jobs that ended abnormally (worker panicked); disjoint from
    /// `completed`.
    pub failed: u64,
    pub submitted: u64,
    pub workers: usize,
    /// Workers currently executing a job (`<= workers`; `in_flight`
    /// counts jobs accepted but not yet finished, which also covers
    /// queued hand-off time).
    pub workers_busy: usize,
    pub cache_entries: usize,
    pub memo: MemoStats,
    pub warm: WarmStats,
}

impl ServerStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("event", Json::str("stats")),
            ("queue_depth", Json::u64(self.queue_depth as u64)),
            ("in_flight", Json::u64(self.in_flight as u64)),
            ("completed", Json::u64(self.completed)),
            ("failed", Json::u64(self.failed)),
            ("submitted", Json::u64(self.submitted)),
            ("workers", Json::u64(self.workers as u64)),
            ("workers_busy", Json::u64(self.workers_busy as u64)),
            ("cache_entries", Json::u64(self.cache_entries as u64)),
            ("layer_sims", Json::u64(self.memo.layer_sims)),
            ("cache_hits", Json::u64(self.memo.cache_hits)),
            ("inflight_waits", Json::u64(self.memo.inflight_waits)),
            ("hit_rate", Json::f64(self.memo.hit_rate())),
            ("warm_entries", Json::u64(self.warm.entries)),
            ("warm_hits", Json::u64(self.warm.hits)),
        ])
    }

    /// Parse a `stats` event line back (client side).
    pub fn from_json(j: &Json) -> Result<ServerStats, String> {
        Ok(ServerStats {
            queue_depth: need_u64(j, "queue_depth")? as usize,
            in_flight: need_u64(j, "in_flight")? as usize,
            completed: need_u64(j, "completed")?,
            failed: need_u64(j, "failed")?,
            submitted: need_u64(j, "submitted")?,
            workers: need_u64(j, "workers")? as usize,
            workers_busy: need_u64(j, "workers_busy")? as usize,
            cache_entries: need_u64(j, "cache_entries")? as usize,
            memo: MemoStats {
                layer_sims: need_u64(j, "layer_sims")?,
                cache_hits: need_u64(j, "cache_hits")?,
                inflight_waits: need_u64(j, "inflight_waits")?,
            },
            warm: WarmStats {
                entries: need_u64(j, "warm_entries")?,
                hits: need_u64(j, "warm_hits")?,
            },
        })
    }
}

// ---------------------------------------------------------------- requests

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let j = Json::parse(line)?;
    let id = j.u64_field("id").unwrap_or(0);
    match j.str_field("req") {
        Some("run") => {
            let topo = request_topology(&j)?
                .ok_or("run request needs \"workload\" (built-in name) or \"layers\"")?;
            Ok(Request::Run {
                id,
                topo,
                overrides: parse_overrides(&j)?,
                multi: parse_multi(&j)?,
            })
        }
        Some("sweep") => {
            let kind =
                SweepKind::parse(j.str_field("kind").ok_or("sweep request needs \"kind\"")?)?;
            let overrides = parse_overrides(&j)?;
            // reject overrides the sweep would silently ignore: the grid
            // takes un-swept axes from the server's base config, and the
            // swept axes from its own ladder
            if overrides.word_bytes.is_some() {
                return Err("sweep jobs do not support a word_bytes override".into());
            }
            if overrides.sram_kb.is_some() {
                return Err(
                    "sweep jobs do not support an sram_kb override (the memory sweep \
                     explores that axis)"
                        .into(),
                );
            }
            if kind != SweepKind::Memory
                && (overrides.dataflow.is_some() || overrides.array.is_some())
            {
                return Err(format!(
                    "{} sweeps explore the dataflow/array axes themselves; only memory \
                     sweeps accept dataflow/array overrides",
                    kind.name()
                ));
            }
            let topos = match request_topology(&j)? {
                Some(t) => vec![t],
                None => workloads::mlperf_suite(),
            };
            let multi = parse_multi(&j)?;
            if multi.as_ref().is_some_and(|m| m.dram_bw.is_some()) {
                return Err(
                    "sweep jobs do not support \"dram_bw\" (the grid models no shared \
                     DRAM bandwidth; use a dse campaign's dram_bw axis)"
                        .into(),
                );
            }
            Ok(Request::Sweep { id, kind, topos, overrides, multi })
        }
        Some("dse") => {
            let cj = j.get("campaign").ok_or("dse request needs a \"campaign\" spec")?;
            let campaign = crate::dse::Campaign::from_json(cj)?;
            campaign.validate().map_err(|e| e.to_string())?;
            let total = campaign.len();
            let indices: Vec<usize> = match j.get("indices") {
                None => (0..total).collect(),
                Some(v) => {
                    let arr = v.as_arr().ok_or("\"indices\" must be an array")?;
                    let mut out = Vec::with_capacity(arr.len());
                    for x in arr {
                        let i = x.as_u64().ok_or("\"indices\" entries must be u64")? as usize;
                        if i >= total {
                            return Err(format!(
                                "campaign point index {i} out of range ({total} points)"
                            ));
                        }
                        out.push(i);
                    }
                    out
                }
            };
            if indices.is_empty() {
                return Err("\"indices\" must not be empty".into());
            }
            Ok(Request::Dse { id, campaign, indices })
        }
        Some("batch") => {
            let jobs_json = j.get("jobs").ok_or("batch request needs a \"jobs\" array")?;
            let entries = jobs_json.as_arr().ok_or("\"jobs\" must be an array")?;
            if entries.is_empty() {
                return Err("\"jobs\" must not be empty".into());
            }
            let mut jobs = Vec::with_capacity(entries.len());
            let mut seen_ids = Vec::with_capacity(entries.len());
            for (n, entry) in entries.iter().enumerate() {
                // each entry is a complete request object; reuse the
                // top-level parser so sub-jobs get full validation
                let sub = parse_request(&entry.to_string())
                    .map_err(|e| format!("batch job {n}: {e}"))?;
                let sub_id = match &sub {
                    Request::Run { id: sid, .. } | Request::Sweep { id: sid, .. } => *sid,
                    _ => {
                        return Err(format!(
                            "batch job {n}: only run/sweep jobs can ride in a batch"
                        ))
                    }
                };
                if sub_id == id {
                    return Err(format!(
                        "batch job {n}: sub-job id {sub_id} collides with the envelope id"
                    ));
                }
                if seen_ids.contains(&sub_id) {
                    return Err(format!(
                        "batch job {n}: duplicate sub-job id {sub_id} (event streams \
                         interleave; ids must be distinct to demultiplex)"
                    ));
                }
                seen_ids.push(sub_id);
                jobs.push(sub);
            }
            Ok(Request::Batch { id, jobs })
        }
        Some("stats") => Ok(Request::Stats),
        Some("metrics") => Ok(Request::Metrics),
        Some("shutdown") => Ok(Request::Shutdown),
        Some(other) => {
            Err(format!("unknown req {other:?} (run|sweep|dse|batch|stats|metrics|shutdown)"))
        }
        None => Err("request needs a \"req\" field".into()),
    }
}

/// Resolve the request's topology: inline `ops` (typed IR, lowered
/// here) or inline `layers` win, else a built-in `workload` name (conv
/// or GEMM family), else `None` (sweeps default to the whole suite).
fn request_topology(j: &Json) -> Result<Option<Topology>, String> {
    let name = j.str_field("workload");
    if let Some(ops) = j.get("ops") {
        if j.get("layers").is_some() {
            return Err("\"ops\" and \"layers\" are mutually exclusive".into());
        }
        let items = ops.as_arr().ok_or("\"ops\" must be an array")?;
        if items.is_empty() {
            return Err("\"ops\" must not be empty".into());
        }
        let mut nodes = Vec::with_capacity(items.len());
        for item in items {
            nodes.push(crate::workload::OpNode::from_json(item)?);
        }
        let workload = crate::workload::Workload::new(name.unwrap_or("inline"), nodes);
        return workload.lower().map(Some).map_err(|e| e.to_string());
    }
    if let Some(layers) = j.get("layers") {
        let items = layers.as_arr().ok_or("\"layers\" must be an array")?;
        if items.is_empty() {
            return Err("\"layers\" must not be empty".into());
        }
        let mut shapes = Vec::with_capacity(items.len());
        for item in items {
            let l = layer_shape_from_json(item)?;
            l.validate().map_err(|e| e.to_string())?;
            shapes.push(l);
        }
        return Ok(Some(Topology::new(name.unwrap_or("inline"), shapes)));
    }
    match name {
        Some(n) => match workloads::builtin_workload(n) {
            Some(w) => w.lower().map(Some).map_err(|e| e.to_string()),
            None => Err(format!("unknown workload {n:?} (see `scale-sim workloads`)")),
        },
        None => Ok(None),
    }
}

fn parse_overrides(j: &Json) -> Result<Overrides, String> {
    let mut o = Overrides::default();
    if let Some(df) = j.str_field("dataflow") {
        o.dataflow = Some(Dataflow::parse(df).map_err(|e| e.to_string())?);
    }
    if let Some(arr) = j.str_field("array") {
        let (r, c) = arr.split_once('x').ok_or("\"array\" expects \"RxC\"")?;
        o.array = Some((
            r.parse().map_err(|_| format!("bad array rows {r:?}"))?,
            c.parse().map_err(|_| format!("bad array cols {c:?}"))?,
        ));
    }
    if let Some(kb) = j.get("sram_kb") {
        let a = kb.as_arr().ok_or("\"sram_kb\" expects [ifmap,filter,ofmap]")?;
        if a.len() != 3 {
            return Err("\"sram_kb\" expects exactly 3 sizes".into());
        }
        let v: Vec<u64> = a
            .iter()
            .map(|x| x.as_u64().ok_or("\"sram_kb\" entries must be u64"))
            .collect::<Result<_, _>>()?;
        o.sram_kb = Some((v[0], v[1], v[2]));
    }
    if let Some(wb) = j.get("word_bytes") {
        o.word_bytes = Some(wb.as_u64().ok_or("\"word_bytes\" must be u64")?);
    }
    Ok(o)
}

/// Parse the multi-array fields: `"nodes":N` activates multi-array
/// execution; `"partition"`, `"dram_bw"`, `"fabric"` and `"link_bw"`
/// refine it. Every bandwidth is validated here, at admission — the
/// stall replay's positive-bandwidth precondition must never be reached
/// by wire input.
fn parse_multi(j: &Json) -> Result<Option<MultiReq>, String> {
    let nodes = match j.get("nodes") {
        None => {
            for k in ["partition", "dram_bw", "fabric", "link_bw"] {
                if j.get(k).is_some() {
                    return Err(format!("{k:?} requires \"nodes\""));
                }
            }
            return Ok(None);
        }
        Some(v) => v.as_u64().ok_or("\"nodes\" must be u64")?,
    };
    if nodes == 0 {
        return Err("\"nodes\" must be positive".into());
    }
    let partition = match j.str_field("partition") {
        None => Partition::default(),
        Some(s) => Partition::parse(s).map_err(|e| e.to_string())?,
    };
    let positive = |k: &str, v: &Json| -> Result<f64, String> {
        let bw = v.as_f64().ok_or_else(|| format!("{k:?} must be a number"))?;
        if !bw.is_finite() || bw <= 0.0 {
            return Err(format!("{k:?} must be finite and positive (got {v})"));
        }
        Ok(bw)
    };
    let dram_bw = match j.get("dram_bw") {
        None => None,
        Some(v) => Some(positive("dram_bw", v)?),
    };
    let fabric = match j.str_field("fabric") {
        None => None,
        Some(s) => Some(FabricKind::parse(s).map_err(|e| e.to_string())?),
    };
    let link_bw = match j.get("link_bw") {
        None => None,
        Some(v) => {
            if fabric.is_none() {
                return Err("\"link_bw\" requires \"fabric\"".into());
            }
            Some(positive("link_bw", v)?)
        }
    };
    Ok(Some(MultiReq { nodes, partition, dram_bw, fabric, link_bw }))
}

// ---------------------------------------------------------------- responses

pub fn result_line(id: u64, report: &WorkloadReport) -> String {
    Json::obj(vec![
        ("id", Json::u64(id)),
        ("event", Json::str("result")),
        ("report", workload_report_to_json(report)),
    ])
    .to_string()
}

/// One streamed sweep grid point (coordinates + headline metrics). The
/// fabric coordinates appear only on points simulated under a real
/// (non-`Flat`) topology, so pre-fabric clients see unchanged lines.
pub fn point_line(id: u64, p: &crate::engine::SweepPoint) -> String {
    let mut fields = vec![
        ("id", Json::u64(id)),
        ("event", Json::str("point")),
        ("workload", Json::str(&p.workload)),
        ("dataflow", Json::str(p.dataflow.name())),
        ("array_h", Json::u64(p.array_h)),
        ("array_w", Json::u64(p.array_w)),
        ("ifmap_sram_kb", Json::u64(p.ifmap_sram_kb)),
        ("nodes", Json::u64(p.nodes)),
        ("partition", Json::str(p.partition.name())),
    ];
    if p.fabric != FabricKind::Flat {
        fields.push(("fabric", Json::str(p.fabric.name())));
        fields.push(("link_bw", Json::f64(p.link_bw)));
        fields.push(("stall_cycles", Json::u64(p.stall_cycles)));
    }
    fields.extend([
        ("cycles", Json::u64(p.report.total_cycles())),
        ("utilization", Json::f64(p.report.overall_utilization(p.total_pes()))),
        ("dram_bytes", Json::u64(p.report.total_dram().total())),
        ("energy_mj", Json::f64(p.report.total_energy().total_mj())),
    ]);
    Json::obj(fields).to_string()
}

/// One streamed dse campaign point (coordinates + extracted objectives).
pub fn dse_point_line(id: u64, cp: &crate::dse::CompletedPoint) -> String {
    Json::obj(vec![
        ("id", Json::u64(id)),
        ("event", Json::str("dse_point")),
        ("point", cp.point.to_json()),
        ("metrics", cp.metrics.to_json()),
    ])
    .to_string()
}

pub fn done_line(id: u64, ms: f64, points: Option<usize>) -> String {
    let mut fields = vec![
        ("id", Json::u64(id)),
        ("event", Json::str("done")),
        ("ms", Json::f64(ms)),
    ];
    if let Some(n) = points {
        fields.push(("points", Json::u64(n as u64)));
    }
    Json::obj(fields).to_string()
}

pub fn error_line(id: u64, msg: &str) -> String {
    Json::obj(vec![
        ("id", Json::u64(id)),
        ("event", Json::str("error")),
        ("error", Json::str(msg)),
    ])
    .to_string()
}

pub fn shutting_down_line() -> String {
    Json::obj(vec![("event", Json::str("shutting_down"))]).to_string()
}

/// The `busy` event: admission shed the job because the bounded queue
/// was full. Terminal for the shed id; nothing was queued, the client
/// should back off and retry.
pub fn busy_line(id: u64) -> String {
    Json::obj(vec![("id", Json::u64(id)), ("event", Json::str("busy"))]).to_string()
}

/// The `batch_done` event: every admitted sub-job of the envelope has
/// emitted its own terminal event. `jobs` counts admitted sub-jobs,
/// `shed` counts sub-jobs that answered `busy` at admission.
pub fn batch_done_line(id: u64, jobs: usize, shed: usize) -> String {
    Json::obj(vec![
        ("id", Json::u64(id)),
        ("event", Json::str("batch_done")),
        ("jobs", Json::u64(jobs as u64)),
        ("shed", Json::u64(shed as u64)),
    ])
    .to_string()
}

/// The `metrics` event: Prometheus text exposition as one JSON string
/// field (the newline-heavy body rides safely inside the JSON-lines
/// framing).
pub fn metrics_line(text: &str) -> String {
    Json::obj(vec![("event", Json::str("metrics")), ("text", Json::str(text))]).to_string()
}

/// True for the events that end a request's response stream. For a
/// batch envelope only `batch_done` (or an `error`/`busy` carrying the
/// envelope id) is terminal — sub-job `done` lines are not; see
/// [`crate::server::Client::request_batch`].
pub fn is_terminal_event(j: &Json) -> bool {
    matches!(
        j.str_field("event"),
        Some("done")
            | Some("error")
            | Some("busy")
            | Some("batch_done")
            | Some("stats")
            | Some("metrics")
            | Some("shutting_down")
    )
}

// ------------------------------------------------- report (de)serialization

fn need(j: &Json, k: &str) -> Result<Json, String> {
    j.get(k).cloned().ok_or_else(|| format!("missing field {k:?}"))
}

fn need_u64(j: &Json, k: &str) -> Result<u64, String> {
    j.u64_field(k).ok_or_else(|| format!("missing/invalid u64 field {k:?}"))
}

fn need_f64(j: &Json, k: &str) -> Result<f64, String> {
    j.f64_field(k).ok_or_else(|| format!("missing/invalid number field {k:?}"))
}

pub fn layer_shape_to_json(l: &LayerShape) -> Json {
    Json::obj(vec![
        ("name", Json::str(&l.name)),
        ("ifmap_h", Json::u64(l.ifmap_h)),
        ("ifmap_w", Json::u64(l.ifmap_w)),
        ("filt_h", Json::u64(l.filt_h)),
        ("filt_w", Json::u64(l.filt_w)),
        ("channels", Json::u64(l.channels)),
        ("num_filters", Json::u64(l.num_filters)),
        ("stride", Json::u64(l.stride)),
    ])
}

pub fn layer_shape_from_json(j: &Json) -> Result<LayerShape, String> {
    Ok(LayerShape {
        name: j.str_field("name").unwrap_or("layer").to_string(),
        ifmap_h: need_u64(j, "ifmap_h")?,
        ifmap_w: need_u64(j, "ifmap_w")?,
        filt_h: need_u64(j, "filt_h")?,
        filt_w: need_u64(j, "filt_w")?,
        channels: need_u64(j, "channels")?,
        num_filters: need_u64(j, "num_filters")?,
        stride: need_u64(j, "stride")?,
    })
}

fn timing_to_json(t: &Timing) -> Json {
    Json::obj(vec![
        ("cycles", Json::u64(t.cycles)),
        ("row_folds", Json::u64(t.row_folds)),
        ("col_folds", Json::u64(t.col_folds)),
        ("utilization", Json::f64(t.utilization)),
        ("mapping_efficiency", Json::f64(t.mapping_efficiency)),
        ("sram_reads_ifmap", Json::u64(t.sram_reads_ifmap)),
        ("sram_reads_filter", Json::u64(t.sram_reads_filter)),
        ("sram_writes_ofmap", Json::u64(t.sram_writes_ofmap)),
        ("sram_reads_ofmap", Json::u64(t.sram_reads_ofmap)),
    ])
}

fn timing_from_json(j: &Json) -> Result<Timing, String> {
    Ok(Timing {
        cycles: need_u64(j, "cycles")?,
        row_folds: need_u64(j, "row_folds")?,
        col_folds: need_u64(j, "col_folds")?,
        utilization: need_f64(j, "utilization")?,
        mapping_efficiency: need_f64(j, "mapping_efficiency")?,
        sram_reads_ifmap: need_u64(j, "sram_reads_ifmap")?,
        sram_reads_filter: need_u64(j, "sram_reads_filter")?,
        sram_writes_ofmap: need_u64(j, "sram_writes_ofmap")?,
        sram_reads_ofmap: need_u64(j, "sram_reads_ofmap")?,
    })
}

pub fn layer_report_to_json(r: &LayerReport) -> Json {
    Json::obj(vec![
        ("layer", layer_shape_to_json(&r.layer)),
        ("timing", timing_to_json(&r.timing)),
        (
            "dram",
            Json::obj(vec![
                ("ifmap_bytes", Json::u64(r.dram.ifmap_bytes)),
                ("filter_bytes", Json::u64(r.dram.filter_bytes)),
                ("ofmap_bytes", Json::u64(r.dram.ofmap_bytes)),
            ]),
        ),
        (
            "bandwidth",
            Json::obj(vec![
                ("avg_read_bw", Json::f64(r.bandwidth.avg_read_bw)),
                ("avg_write_bw", Json::f64(r.bandwidth.avg_write_bw)),
                ("peak_read_bw", Json::f64(r.bandwidth.peak_read_bw)),
            ]),
        ),
        (
            "energy",
            Json::obj(vec![
                ("compute_mj", Json::f64(r.energy.compute_mj)),
                ("sram_mj", Json::f64(r.energy.sram_mj)),
                ("dram_mj", Json::f64(r.energy.dram_mj)),
            ]),
        ),
    ])
}

pub fn layer_report_from_json(j: &Json) -> Result<LayerReport, String> {
    let dram = need(j, "dram")?;
    let bw = need(j, "bandwidth")?;
    let energy = need(j, "energy")?;
    Ok(LayerReport {
        layer: layer_shape_from_json(&need(j, "layer")?)?,
        timing: timing_from_json(&need(j, "timing")?)?,
        dram: DramTraffic {
            ifmap_bytes: need_u64(&dram, "ifmap_bytes")?,
            filter_bytes: need_u64(&dram, "filter_bytes")?,
            ofmap_bytes: need_u64(&dram, "ofmap_bytes")?,
        },
        bandwidth: BandwidthReport {
            avg_read_bw: need_f64(&bw, "avg_read_bw")?,
            avg_write_bw: need_f64(&bw, "avg_write_bw")?,
            peak_read_bw: need_f64(&bw, "peak_read_bw")?,
        },
        energy: EnergyBreakdown {
            compute_mj: need_f64(&energy, "compute_mj")?,
            sram_mj: need_f64(&energy, "sram_mj")?,
            dram_mj: need_f64(&energy, "dram_mj")?,
        },
    })
}

pub fn workload_report_to_json(r: &WorkloadReport) -> Json {
    Json::obj(vec![
        ("workload", Json::str(&r.workload)),
        ("layers", Json::Arr(r.layers.iter().map(layer_report_to_json).collect())),
    ])
}

pub fn workload_report_from_json(j: &Json) -> Result<WorkloadReport, String> {
    let layers = need(j, "layers")?;
    let layers = layers.as_arr().ok_or("\"layers\" must be an array")?;
    Ok(WorkloadReport {
        workload: j.str_field("workload").ok_or("missing \"workload\"")?.to_string(),
        layers: layers.iter().map(layer_report_from_json).collect::<Result<_, _>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::sim::Simulator;

    fn sample_report() -> WorkloadReport {
        let sim = Simulator::new(ArchConfig { array_h: 16, array_w: 16, ..config::paper_default() });
        sim.run_topology(&Topology::new(
            "t",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::fc("fc", 1, 256, 10),
            ],
        ))
    }

    #[test]
    fn workload_report_round_trips_bit_identically() {
        let r = sample_report();
        let wire = workload_report_to_json(&r).to_string();
        let back = workload_report_from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, r); // PartialEq over every f64/u64 field
    }

    #[test]
    fn run_request_with_builtin_workload() {
        let r = parse_request(r#"{"req":"run","id":7,"workload":"ncf","dataflow":"ws","array":"32x16"}"#)
            .unwrap();
        match r {
            Request::Run { id, topo, overrides, multi } => {
                assert_eq!(multi, None);
                assert_eq!(id, 7);
                assert!(!topo.layers.is_empty());
                assert_eq!(overrides.dataflow, Some(Dataflow::Ws));
                assert_eq!(overrides.array, Some((32, 16)));
                let cfg = overrides.apply(&ArchConfig::default());
                assert_eq!((cfg.array_h, cfg.array_w, cfg.dataflow), (32, 16, Dataflow::Ws));
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn multi_array_fields_parse_and_validate() {
        match parse_request(r#"{"req":"run","workload":"ncf","nodes":16}"#).unwrap() {
            Request::Run { multi, .. } => {
                assert_eq!(
                    multi,
                    Some(MultiReq {
                        nodes: 16,
                        partition: Partition::OutputChannels,
                        dram_bw: None,
                        fabric: None,
                        link_bw: None,
                    })
                );
            }
            other => panic!("wrong request {other:?}"),
        }
        match parse_request(
            r#"{"req":"sweep","kind":"memory","workload":"ncf","nodes":4,"partition":"auto"}"#,
        )
        .unwrap()
        {
            Request::Sweep { multi, .. } => {
                assert_eq!(
                    multi,
                    Some(MultiReq {
                        nodes: 4,
                        partition: Partition::Auto,
                        dram_bw: None,
                        fabric: None,
                        link_bw: None,
                    })
                );
            }
            other => panic!("wrong request {other:?}"),
        }
        // partition without nodes, zero nodes, unknown strategy: rejected
        let e = parse_request(r#"{"req":"run","workload":"ncf","partition":"pixels"}"#);
        assert!(e.unwrap_err().contains("nodes"));
        assert!(parse_request(r#"{"req":"run","workload":"ncf","nodes":0}"#).is_err());
        assert!(
            parse_request(r#"{"req":"run","workload":"ncf","nodes":4,"partition":"diag"}"#)
                .is_err()
        );
    }

    #[test]
    fn fabric_and_bandwidth_fields_parse_and_validate() {
        let line = r#"{"req":"run","workload":"ncf","nodes":16,"dram_bw":16,"fabric":"mesh","link_bw":8}"#;
        match parse_request(line).unwrap() {
            Request::Run { multi, .. } => {
                let m = multi.unwrap();
                assert_eq!(
                    (m.dram_bw, m.fabric, m.link_bw),
                    (Some(16.0), Some(FabricKind::Mesh), Some(8.0))
                );
                let opts = m.opts();
                assert_eq!(opts.shared_dram_bw, Some(16.0));
                assert_eq!(opts.fabric, Some(FabricConfig::new(FabricKind::Mesh, 8.0)));
                assert_eq!(opts.dram, None);
            }
            other => panic!("wrong request {other:?}"),
        }
        // an omitted link_bw falls back to the default at opts() time
        match parse_request(r#"{"req":"run","workload":"ncf","nodes":4,"fabric":"line"}"#)
            .unwrap()
        {
            Request::Run { multi, .. } => {
                let opts = multi.unwrap().opts();
                assert_eq!(
                    opts.fabric,
                    Some(FabricConfig::new(FabricKind::Line, DEFAULT_LINK_BW))
                );
                assert_eq!(opts.shared_dram_bw, None);
            }
            other => panic!("wrong request {other:?}"),
        }
        // non-positive or non-finite bandwidths are admission errors —
        // they must never reach the stall replay's assert
        for bad in [
            r#"{"req":"run","workload":"ncf","nodes":4,"dram_bw":0}"#,
            r#"{"req":"run","workload":"ncf","nodes":4,"dram_bw":-2}"#,
            r#"{"req":"run","workload":"ncf","nodes":4,"dram_bw":"wide"}"#,
            r#"{"req":"run","workload":"ncf","nodes":4,"fabric":"line","link_bw":0}"#,
            r#"{"req":"run","workload":"ncf","nodes":4,"fabric":"torus"}"#,
            // link_bw without a fabric, and multi fields without nodes
            r#"{"req":"run","workload":"ncf","nodes":4,"link_bw":8}"#,
            r#"{"req":"run","workload":"ncf","dram_bw":16}"#,
            r#"{"req":"run","workload":"ncf","fabric":"mesh"}"#,
        ] {
            assert!(parse_request(bad).is_err(), "{bad}");
        }
        // sweeps accept the fabric fields but reject dram_bw (the grid
        // models no shared DRAM bandwidth — reject, don't drop)
        match parse_request(
            r#"{"req":"sweep","kind":"memory","workload":"ncf","nodes":4,"fabric":"ring","link_bw":2}"#,
        )
        .unwrap()
        {
            Request::Sweep { multi, .. } => {
                assert_eq!(multi.unwrap().fabric, Some(FabricKind::Ring));
            }
            other => panic!("wrong request {other:?}"),
        }
        let e = parse_request(
            r#"{"req":"sweep","kind":"memory","workload":"ncf","nodes":4,"dram_bw":8}"#,
        );
        assert!(e.unwrap_err().contains("dram_bw"));
    }

    #[test]
    fn run_request_with_inline_layers() {
        let line = r#"{"req":"run","workload":"mine","layers":[
            {"name":"c1","ifmap_h":16,"ifmap_w":16,"filt_h":3,"filt_w":3,"channels":4,"num_filters":8,"stride":1}
        ]}"#
        .replace('\n', " ");
        match parse_request(&line).unwrap() {
            Request::Run { id, topo, .. } => {
                assert_eq!(id, 0);
                assert_eq!(topo.name, "mine");
                assert_eq!(topo.layers.len(), 1);
                assert_eq!(topo.layers[0].name, "c1");
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn run_request_with_inline_ops_lowers_server_side() {
        let line = r#"{"req":"run","id":4,"workload":"typed","ops":[
            {"type":"gemm","name":"g","m":32,"k":64,"n":16},
            {"type":"conv2d","name":"pw","ifmap_h":8,"ifmap_w":8,"in_channels":4,"out_channels":8,"kernel_h":1}
        ]}"#
        .replace('\n', " ");
        match parse_request(&line).unwrap() {
            Request::Run { id, topo, .. } => {
                assert_eq!(id, 4);
                assert_eq!(topo.name, "typed");
                assert_eq!(topo.layers.len(), 2);
                assert_eq!(topo.layers[0], LayerShape::gemm("g", 32, 64, 16));
                // pointwise conv canonicalizes onto the GEMM tile
                assert_eq!(topo.layers[1], LayerShape::gemm("pw", 64, 4, 8));
            }
            other => panic!("wrong request {other:?}"),
        }
        // ops and layers cannot be mixed
        let both = r#"{"req":"run","ops":[{"type":"gemm","m":1,"k":1,"n":1}],"layers":[]}"#;
        assert!(parse_request(both).unwrap_err().contains("mutually exclusive"));
        // invalid op geometry is rejected at parse time
        let bad = r#"{"req":"run","ops":[{"type":"gemm","name":"z","m":0,"k":1,"n":1}]}"#;
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn run_request_with_builtin_gemm_workload() {
        match parse_request(r#"{"req":"run","id":8,"workload":"attention"}"#).unwrap() {
            Request::Run { topo, .. } => {
                assert_eq!(topo.name, "attention");
                assert!(topo.layers.iter().all(|l| l.is_gemm()));
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn dse_request_parses_and_validates() {
        let line = r#"{"req":"dse","id":3,"campaign":{"workloads":["ncf"],"dataflows":["os"],"arrays":["16x16"],"sram_kb":[64],"dram_bw":[8]},"indices":[0]}"#;
        match parse_request(line).unwrap() {
            Request::Dse { id, campaign, indices } => {
                assert_eq!(id, 3);
                assert_eq!(campaign.len(), 1);
                assert_eq!(indices, vec![0]);
                assert_eq!(campaign.point(0).dram_bw, 8.0);
            }
            other => panic!("wrong request {other:?}"),
        }
        // omitted indices default to the whole grid
        let line = r#"{"req":"dse","campaign":{"workloads":["ncf"],"dram_bw":[4,8]}}"#;
        match parse_request(line).unwrap() {
            Request::Dse { indices, campaign, .. } => {
                assert_eq!(indices.len(), campaign.len())
            }
            other => panic!("wrong request {other:?}"),
        }
        // out-of-range index, invalid axis, missing spec: parse-time errors
        let oob = r#"{"req":"dse","campaign":{"workloads":["ncf"]},"indices":[999]}"#;
        assert!(parse_request(oob).unwrap_err().contains("out of range"));
        let bad_bw = r#"{"req":"dse","campaign":{"workloads":["ncf"],"dram_bw":[0]}}"#;
        assert!(parse_request(bad_bw).is_err());
        assert!(parse_request(r#"{"req":"dse"}"#).is_err());
    }

    #[test]
    fn dse_point_line_round_trips() {
        use crate::dse::{evaluate_point, Campaign, CompletedPoint};
        let campaign = Campaign {
            name: "p".into(),
            workloads: vec!["ncf".into()],
            dataflows: vec![Dataflow::Os],
            arrays: vec![(16, 16)],
            nodes: vec![1],
            partitions: vec![Partition::default()],
            sram_kb: vec![64],
            dram_bw: vec![8.0],
            topologies: vec![crate::engine::FabricKind::Flat],
            link_bw: vec![crate::engine::DEFAULT_LINK_BW],
            energy: "28nm".into(),
        };
        let topos = campaign.resolve_workloads(true).unwrap();
        let engine = crate::engine::Engine::new(config::paper_default());
        let point = campaign.point(0);
        let cp = CompletedPoint {
            metrics: evaluate_point(&engine, &topos["ncf"], &point),
            point,
        };
        let line = dse_point_line(9, &cp);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.u64_field("id"), Some(9));
        assert_eq!(j.str_field("event"), Some("dse_point"));
        assert!(!is_terminal_event(&j));
        assert_eq!(CompletedPoint::from_json(&j).unwrap(), cp);
    }

    #[test]
    fn sweep_request_defaults_to_suite() {
        match parse_request(r#"{"req":"sweep","kind":"memory"}"#).unwrap() {
            Request::Sweep { kind, topos, .. } => {
                assert_eq!(kind, SweepKind::Memory);
                assert_eq!(topos.len(), workloads::mlperf_suite().len());
            }
            other => panic!("wrong request {other:?}"),
        }
    }

    #[test]
    fn bad_requests_are_rejected_with_context() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"req":"warp"}"#).unwrap_err().contains("warp"));
        assert!(parse_request(r#"{"req":"run"}"#).unwrap_err().contains("workload"));
        assert!(parse_request(r#"{"req":"run","workload":"nope9"}"#).unwrap_err().contains("nope9"));
        assert!(parse_request(r#"{"req":"sweep","kind":"banana"}"#).unwrap_err().contains("banana"));
        // overrides a sweep would ignore are rejected, not dropped
        assert!(parse_request(r#"{"req":"sweep","kind":"dataflow","array":"8x8"}"#).is_err());
        assert!(parse_request(r#"{"req":"sweep","kind":"memory","word_bytes":4}"#).is_err());
        assert!(parse_request(r#"{"req":"sweep","kind":"shape","sram_kb":[1,2,3]}"#).is_err());
        // memory sweeps may pin the non-swept axes
        assert!(parse_request(r#"{"req":"sweep","kind":"memory","array":"8x8","dataflow":"ws"}"#).is_ok());
        assert!(parse_request(r#"{"req":"run","workload":"ncf","sram_kb":[1,2]}"#).is_err());
        // invalid inline layer (zero dim) is rejected by validation
        let bad = r#"{"req":"run","layers":[{"name":"z","ifmap_h":0,"ifmap_w":1,"filt_h":1,"filt_w":1,"channels":1,"num_filters":1,"stride":1}]}"#;
        assert!(parse_request(bad).is_err());
    }

    #[test]
    fn batch_request_parses_and_validates() {
        let line = r#"{"req":"batch","id":6,"jobs":[
            {"req":"run","id":1,"workload":"ncf"},
            {"req":"sweep","id":2,"kind":"memory","workloads":["ncf"]}
        ]}"#
        .replace('\n', " ");
        match parse_request(&line).unwrap() {
            Request::Batch { id, jobs } => {
                assert_eq!(id, 6);
                assert_eq!(jobs.len(), 2);
                assert!(matches!(jobs[0], Request::Run { id: 1, .. }));
                assert!(matches!(jobs[1], Request::Sweep { id: 2, .. }));
            }
            other => panic!("wrong request {other:?}"),
        }
        // envelope-level shape errors
        assert!(parse_request(r#"{"req":"batch","id":6}"#).unwrap_err().contains("jobs"));
        assert!(parse_request(r#"{"req":"batch","id":6,"jobs":[]}"#)
            .unwrap_err()
            .contains("empty"));
        // only run/sweep jobs can ride in a batch (rejects dse and
        // nested batches alike)
        let dse = r#"{"req":"batch","id":6,"jobs":[{"req":"dse","id":1,"campaign":{"workloads":["ncf"]}}]}"#;
        assert!(parse_request(dse).unwrap_err().contains("only run/sweep"));
        let nested = r#"{"req":"batch","id":6,"jobs":[{"req":"batch","id":1,"jobs":[{"req":"run","id":2,"workload":"ncf"}]}]}"#;
        assert!(parse_request(nested).unwrap_err().contains("only run/sweep"));
        // ids must be distinct from each other and from the envelope
        let dup = r#"{"req":"batch","id":6,"jobs":[{"req":"run","id":1,"workload":"ncf"},{"req":"run","id":1,"workload":"ncf"}]}"#;
        assert!(parse_request(dup).unwrap_err().contains("duplicate"));
        let clash = r#"{"req":"batch","id":6,"jobs":[{"req":"run","id":6,"workload":"ncf"}]}"#;
        assert!(parse_request(clash).unwrap_err().contains("envelope id"));
        // a bad sub-job surfaces with its position in the envelope
        let bad = r#"{"req":"batch","id":6,"jobs":[{"req":"run","id":1,"workload":"nope9"}]}"#;
        let err = parse_request(bad).unwrap_err();
        assert!(err.contains("batch job 0") && err.contains("nope9"), "{err}");
    }

    #[test]
    fn busy_and_batch_done_lines_parse_and_terminate() {
        let busy = Json::parse(&busy_line(4)).unwrap();
        assert_eq!(busy.u64_field("id"), Some(4));
        assert_eq!(busy.str_field("event"), Some("busy"));
        assert!(is_terminal_event(&busy));

        let bd = Json::parse(&batch_done_line(6, 3, 1)).unwrap();
        assert_eq!(bd.u64_field("id"), Some(6));
        assert_eq!(bd.str_field("event"), Some("batch_done"));
        assert_eq!(bd.u64_field("jobs"), Some(3));
        assert_eq!(bd.u64_field("shed"), Some(1));
        assert!(is_terminal_event(&bd));
    }

    #[test]
    fn response_lines_parse_and_terminate() {
        let r = sample_report();
        let result = Json::parse(&result_line(3, &r)).unwrap();
        assert_eq!(result.u64_field("id"), Some(3));
        assert!(!is_terminal_event(&result));
        let report = workload_report_from_json(result.get("report").unwrap()).unwrap();
        assert_eq!(report, r);

        for line in [
            done_line(3, 1.5, None),
            done_line(3, 1.5, Some(12)),
            error_line(9, "boom"),
            busy_line(9),
            batch_done_line(9, 2, 0),
            shutting_down_line(),
            metrics_line("# HELP x\n"),
            ServerStats::default().to_json().to_string(),
        ] {
            assert!(is_terminal_event(&Json::parse(&line).unwrap()), "{line}");
        }
    }

    #[test]
    fn stats_round_trip() {
        let s = ServerStats {
            queue_depth: 3,
            in_flight: 2,
            completed: 40,
            failed: 1,
            submitted: 45,
            workers: 8,
            workers_busy: 2,
            cache_entries: 17,
            memo: MemoStats { layer_sims: 10, cache_hits: 30, inflight_waits: 6 },
            warm: WarmStats { entries: 5, hits: 4 },
        };
        let j = Json::parse(&s.to_json().to_string()).unwrap();
        let back = ServerStats::from_json(&j).unwrap();
        assert_eq!(back.queue_depth, 3);
        assert_eq!(back.failed, 1);
        assert_eq!(back.memo, s.memo);
        assert_eq!(back.warm, s.warm);
        assert!((j.f64_field("hit_rate").unwrap() - 0.75).abs() < 1e-12);
    }
}
