//! **Deprecated shim** over [`crate::engine::multi`] — the scale-up vs
//! scale-out study (§IV-E, Figs 9 & 10) as closed-form free functions.
//!
//! Multi-array simulation is now a first-class engine citizen: the
//! partition geometry, the per-node engine runs (memoized), the
//! shared-DRAM contention model and the comparison arithmetic all live
//! in [`crate::engine::multi`], surfaced as [`Engine::run_multi`],
//! [`Engine::compare_scaling_with`], the sweep grid's `nodes`/
//! `partitions` axes, the dse campaign's `nodes`/`partitions` axes, the
//! serve protocol's multi-array fields, and `scale-sim scaleout`.
//!
//! The functions here reproduce the original closed forms
//! **bit-identically** (pinned by the equivalence suite): they derive
//! the legacy quantities — full-share node cycles, full-share filter
//! bytes times used nodes — from the engine's [`MultiLayerReport`].
//!
//! [`Engine::run_multi`]: crate::engine::Engine::run_multi
//! [`Engine::compare_scaling_with`]: crate::engine::Engine::compare_scaling_with
//! [`MultiLayerReport`]: crate::engine::MultiLayerReport

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::engine::multi::MultiArrayConfig;
use crate::engine::Engine;

pub use crate::engine::multi::{
    scale_up_cfg, Partition, ScaleComparison, NODE_DIM, NODE_PES, PE_SWEEP,
};

/// One node's share of a layer under output-channel partitioning across
/// `nodes` nodes: the (maximal) per-node filter count, and how many nodes
/// actually receive filters.
#[deprecated(note = "use engine::multi::split_layer")]
pub fn partition_filters(layer: &LayerShape, nodes: u64) -> (u64, u64) {
    let shares = crate::engine::multi::split_layer(layer, nodes, Partition::OutputChannels);
    let used: u64 = shares.iter().map(|s| s.count).sum();
    (shares[0].layer.num_filters, used)
}

/// The per-node sub-layer (same geometry, fewer output channels).
#[deprecated(note = "use engine::multi::split_layer")]
pub fn node_layer(layer: &LayerShape, per_node_filters: u64) -> LayerShape {
    LayerShape { num_filters: per_node_filters, ..layer.clone() }
}

/// Pixel partitioning: each node computes a horizontal stripe of the
/// OFMAP (all channels). Returns the (maximal) per-node sub-layer and
/// the number of nodes that receive work.
///
/// Kept as the exact legacy closed form (a stripe's ifmap is always
/// `(rows-1)*stride + filt_h` tall, trimming stride-unreachable bottom
/// rows even at `nodes == 1`); `engine::multi::split_layer` instead
/// returns the unchanged layer for a single node so a 1-node system
/// matches the plain engine bit-for-bit.
#[deprecated(note = "use engine::multi::split_layer")]
pub fn node_layer_pixels(layer: &LayerShape, nodes: u64) -> (LayerShape, u64) {
    let rows = layer.ofmap_h();
    let per = crate::util::ceil_div(rows, nodes);
    let used = crate::util::ceil_div(rows, per);
    let ifmap_h = (per - 1) * layer.stride + layer.filt_h;
    (LayerShape { ifmap_h, ..layer.clone() }, used)
}

/// One scale-out design point: slowest-node runtime + aggregate weight
/// DRAM bytes, under a given partition strategy. Legacy accounting:
/// every used node is charged the full per-node share.
#[deprecated(note = "use Engine::run_multi_layer_with")]
pub fn scale_out_point(
    base: &ArchConfig,
    layer: &LayerShape,
    nodes: u64,
    partition: Partition,
) -> (u64, u64) {
    let engine = Engine::new(base.clone());
    let multi = MultiArrayConfig::new(nodes, NODE_DIM, NODE_DIM, partition);
    let m = engine.run_multi_layer_with(base, layer, &multi, None);
    (m.node_report.timing.cycles, m.node_report.dram.filter_bytes * m.used_nodes)
}

/// Compare scale-up vs scale-out for one layer at one PE budget under a
/// given scale-out partition strategy.
///
/// `base` fixes dataflow, scratchpad sizes and word size for both sides;
/// scale-out nodes are 8x8 copies of `base`.
#[deprecated(note = "use Engine::compare_scaling_with")]
pub fn compare_layer_with(
    base: &ArchConfig,
    layer: &LayerShape,
    pe_budget: u64,
    partition: Partition,
) -> ScaleComparison {
    Engine::new(base.clone()).compare_scaling_with(
        std::slice::from_ref(layer),
        pe_budget,
        partition,
    )
}

/// The paper's comparison: output-channel partitioning (§IV-E).
#[deprecated(note = "use Engine::compare_scaling")]
pub fn compare_layer(base: &ArchConfig, layer: &LayerShape, pe_budget: u64) -> ScaleComparison {
    Engine::new(base.clone()).compare_scaling(std::slice::from_ref(layer), pe_budget)
}

/// Whole-topology comparison: layer runtimes sum (layers serialize),
/// weight bandwidths aggregate per layer then average runtime-weighted.
#[deprecated(note = "use Engine::compare_scaling")]
pub fn compare_topology(
    base: &ArchConfig,
    layers: &[LayerShape],
    pe_budget: u64,
) -> ScaleComparison {
    Engine::new(base.clone()).compare_scaling(layers, pe_budget)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::config;
    use crate::dataflow::Dataflow;

    fn base(df: Dataflow) -> ArchConfig {
        ArchConfig { dataflow: df, ..config::paper_default() }
    }

    #[test]
    fn partition_covers_all_filters() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 100, 1);
        for nodes in [1u64, 2, 3, 7, 16, 200] {
            let (per, used) = partition_filters(&l, nodes);
            assert!(per * used >= 100);
            assert!(per * (used - 1) < 100);
            assert!(used <= nodes);
        }
    }

    #[test]
    fn more_filters_than_nodes_uses_all_nodes() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 256, 1);
        let (per, used) = partition_filters(&l, 16);
        assert_eq!((per, used), (16, 16));
    }

    #[test]
    fn fewer_filters_than_nodes_leaves_nodes_idle() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 4, 1);
        let (per, used) = partition_filters(&l, 16);
        assert_eq!((per, used), (1, 4));
    }

    #[test]
    fn scale_up_cfg_is_square() {
        let c = scale_up_cfg(&base(Dataflow::Os), 1024);
        assert_eq!((c.array_h, c.array_w), (32, 32));
    }

    #[test]
    #[should_panic(expected = "not square")]
    fn non_square_budget_panics() {
        scale_up_cfg(&base(Dataflow::Os), 100 * 64 + 1);
    }

    #[test]
    fn both_sides_finish_and_ratio_positive() {
        let l = LayerShape::conv("c", 32, 32, 3, 3, 32, 64, 1);
        for df in Dataflow::ALL {
            for &pe in &PE_SWEEP {
                let c = compare_layer(&base(df), &l, pe);
                assert!(c.up_cycles > 0 && c.out_cycles > 0);
                assert!(c.runtime_ratio() > 0.0);
                assert!(c.weight_bw_ratio() > 0.0, "{df} {pe}");
            }
        }
    }

    #[test]
    fn poor_row_fit_favors_scale_out() {
        // The paper's §IV-E mechanism ("scaling decision is tied to
        // workloads"): when Npx barely spills the big array's rows
        // (129 px on 128 rows => half-empty residual fold) but filters
        // are plentiful, 8x8 nodes stay nearly fully mapped and
        // scale-out wins.
        let l = LayerShape::gemm("spill", 129, 64, 2048);
        let c = compare_layer(&base(Dataflow::Os), &l, 16384);
        assert!(
            c.runtime_ratio() > 1.0,
            "expected scale-out win: up={} out={}",
            c.up_cycles,
            c.out_cycles
        );
    }

    #[test]
    fn deep_windows_favor_scale_up() {
        // ...and the converse: K-dominated layers with few filters per
        // node leave scale-out columns idle.
        let l = LayerShape::conv("w1", 19, 19, 3, 3, 256, 256, 1);
        let c = compare_layer(&base(Dataflow::Os), &l, 16384);
        assert!(c.runtime_ratio() < 1.0, "up={} out={}", c.up_cycles, c.out_cycles);
    }

    #[test]
    fn pixel_partition_covers_all_output_rows() {
        let l = LayerShape::conv("c", 30, 30, 3, 3, 8, 16, 1);
        for nodes in [1u64, 2, 4, 7, 28, 100] {
            let (nl, used) = node_layer_pixels(&l, nodes);
            let rows_per_node = nl.ofmap_h();
            assert!(rows_per_node * used >= l.ofmap_h(), "nodes={nodes}");
            assert!(rows_per_node * (used - 1) < l.ofmap_h());
            // stripe geometry preserves width/channels/filters
            assert_eq!((nl.ifmap_w, nl.channels, nl.num_filters), (30, 8, 16));
        }
    }

    #[test]
    fn pixel_partition_duplicates_weights() {
        // with pixel partitioning every node fetches the full filter
        // set: aggregate weight traffic must exceed channel partitioning
        let l = LayerShape::conv("c", 64, 64, 3, 3, 32, 64, 1);
        let b = base(Dataflow::Os);
        let (_, w_chan) = scale_out_point(&b, &l, 16, Partition::OutputChannels);
        let (_, w_px) = scale_out_point(&b, &l, 16, Partition::Pixels);
        assert!(w_px > w_chan, "px={w_px} chan={w_chan}");
    }

    #[test]
    fn auto_partition_never_slower_than_either() {
        let b = base(Dataflow::Os);
        for l in [
            LayerShape::conv("convish", 64, 64, 3, 3, 32, 8, 1), // few filters
            LayerShape::conv("deep", 19, 19, 3, 3, 256, 256, 1), // many filters
            LayerShape::fc("fc", 4, 512, 512),
        ] {
            let (c_auto, _) = scale_out_point(&b, &l, 64, Partition::Auto);
            let (c_ch, _) = scale_out_point(&b, &l, 64, Partition::OutputChannels);
            let (c_px, _) = scale_out_point(&b, &l, 64, Partition::Pixels);
            assert_eq!(c_auto, c_ch.min(c_px), "{}", l.name);
        }
    }

    #[test]
    fn few_filters_prefer_pixel_partition() {
        // §IV-E: "the best strategy may differ from layer to layer
        // depending on the number of filters vs channels" — with 8
        // filters over 64 nodes, channel partitioning idles 56 nodes
        let l = LayerShape::conv("fewfilt", 64, 64, 3, 3, 32, 8, 1);
        let b = base(Dataflow::Os);
        let (c_ch, _) = scale_out_point(&b, &l, 64, Partition::OutputChannels);
        let (c_px, _) = scale_out_point(&b, &l, 64, Partition::Pixels);
        assert!(c_px < c_ch, "px={c_px} ch={c_ch}");
    }

    #[test]
    fn topology_comparison_accumulates() {
        let layers = vec![
            LayerShape::conv("a", 16, 16, 3, 3, 8, 32, 1),
            LayerShape::conv("b", 14, 14, 3, 3, 32, 64, 1),
        ];
        let b = base(Dataflow::Os);
        let t = compare_topology(&b, &layers, 1024);
        let s: u64 = layers.iter().map(|l| compare_layer(&b, l, 1024).up_cycles).sum();
        assert_eq!(t.up_cycles, s);
    }
}
