//! Scaling-up vs scaling-out (§IV-E, Figs 9 & 10).
//!
//! *Scale-up* grows one array (the TPU approach): a PE budget `P` becomes
//! one `√P x √P` array. *Scale-out* replicates 8x8 arrays (the
//! tensor-core approach): `P/64` nodes, with the workload partitioned
//! along output channels — "the different filters are assigned to
//! different nodes, thus different nodes generating different output
//! channels". Each node keeps its own scratchpad configuration; as in
//! the paper, the inter-node interconnect is not arbitrated — its
//! required bandwidth is *reported* (from SRAM/DRAM interface numbers),
//! not modeled as a constraint.

use crate::arch::LayerShape;
use crate::config::ArchConfig;
use crate::memory;
use crate::util::{ceil_div, isqrt};

/// Scale-out node geometry used in the paper's study.
pub const NODE_DIM: u64 = 8;
pub const NODE_PES: u64 = NODE_DIM * NODE_DIM;

/// Workload partitioning strategy across scale-out nodes.
///
/// The paper's study uses output-channel partitioning but notes that
/// "alternate partitioning strategies exist, and in fact the best
/// strategy may differ from layer to layer depending on the number of
/// filters vs channels" (§IV-E) — implemented here as an extension and
/// ablated in `rust/benches/` / `examples/`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Partition {
    /// Split filters across nodes (the paper's choice): each node
    /// produces different output channels.
    #[default]
    OutputChannels,
    /// Split output pixels (ifmap rows) across nodes: each node produces
    /// all channels for a horizontal stripe of the OFMAP.
    Pixels,
    /// Per layer, pick whichever of the two is faster (the paper's
    /// "best strategy may differ from layer to layer").
    Auto,
}

impl Partition {
    pub const ALL: [Partition; 3] =
        [Partition::OutputChannels, Partition::Pixels, Partition::Auto];

    pub fn name(&self) -> &'static str {
        match self {
            Partition::OutputChannels => "channels",
            Partition::Pixels => "pixels",
            Partition::Auto => "auto",
        }
    }
}

/// Scale-up configuration: one square array of `pe_budget` PEs.
///
/// Panics if `pe_budget` is not a perfect square (the paper's sweep uses
/// 64 * 4^i, always square).
pub fn scale_up_cfg(base: &ArchConfig, pe_budget: u64) -> ArchConfig {
    let dim = isqrt(pe_budget);
    assert_eq!(dim * dim, pe_budget, "PE budget {pe_budget} is not square");
    ArchConfig { array_h: dim, array_w: dim, ..base.clone() }
}

/// One node's share of a layer under output-channel partitioning across
/// `nodes` nodes: the (maximal) per-node filter count, and how many nodes
/// actually receive filters.
pub fn partition_filters(layer: &LayerShape, nodes: u64) -> (u64, u64) {
    let per_node = ceil_div(layer.num_filters, nodes);
    let used = ceil_div(layer.num_filters, per_node);
    (per_node, used)
}

/// The per-node sub-layer (same geometry, fewer output channels).
pub fn node_layer(layer: &LayerShape, per_node_filters: u64) -> LayerShape {
    LayerShape { num_filters: per_node_filters, ..layer.clone() }
}

/// Pixel partitioning: each node computes a horizontal stripe of the
/// OFMAP (all channels). Returns the per-node sub-layer and the number
/// of nodes that receive work.
pub fn node_layer_pixels(layer: &LayerShape, nodes: u64) -> (LayerShape, u64) {
    let eh = layer.ofmap_h();
    let rows_per_node = ceil_div(eh, nodes);
    let used = ceil_div(eh, rows_per_node);
    // a stripe of `rows_per_node` output rows needs this many ifmap rows
    let ifmap_h = (rows_per_node - 1) * layer.stride + layer.filt_h;
    (LayerShape { ifmap_h, ..layer.clone() }, used)
}

/// Result of one scale-up vs scale-out comparison point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScaleComparison {
    pub pe_budget: u64,
    pub nodes: u64,
    /// Runtime on the single big array.
    pub up_cycles: u64,
    /// Runtime of the slowest node (nodes run in parallel).
    pub out_cycles: u64,
    /// DRAM bandwidth demanded for *weights*, bytes/cycle (Fig 10).
    pub up_weight_bw: f64,
    pub out_weight_bw: f64,
}

impl ScaleComparison {
    /// Fig 9's y-axis: runtime(scale-up) / runtime(scale-out);
    /// < 1 means scale-up wins.
    pub fn runtime_ratio(&self) -> f64 {
        self.up_cycles as f64 / self.out_cycles as f64
    }

    /// Fig 10's y-axis: weight-bandwidth(up) / weight-bandwidth(out).
    pub fn weight_bw_ratio(&self) -> f64 {
        self.up_weight_bw / self.out_weight_bw
    }
}

/// One scale-out design point: slowest-node runtime + aggregate weight
/// DRAM bytes, under a given partition strategy.
pub fn scale_out_point(
    base: &ArchConfig,
    layer: &LayerShape,
    nodes: u64,
    partition: Partition,
) -> (u64, u64) {
    let df = base.dataflow;
    let node_cfg = ArchConfig { array_h: NODE_DIM, array_w: NODE_DIM, ..base.clone() };
    match partition {
        Partition::OutputChannels => {
            let (per_node, used_nodes) = partition_filters(layer, nodes);
            let nl = node_layer(layer, per_node);
            // all busy nodes run the same-shaped sub-layer; the slowest
            // (= any full node) bounds runtime
            let cycles = df.timing(&nl, NODE_DIM, NODE_DIM).cycles;
            let (node_dram, _) = memory::simulate(df, &nl, &node_cfg);
            // no duplication: each node fetches distinct filters
            (cycles, node_dram.filter_bytes * used_nodes)
        }
        Partition::Pixels => {
            let (nl, used_nodes) = node_layer_pixels(layer, nodes);
            let cycles = df.timing(&nl, NODE_DIM, NODE_DIM).cycles;
            let (node_dram, _) = memory::simulate(df, &nl, &node_cfg);
            // every node needs the FULL filter set — weight duplication
            // is the price of pixel partitioning
            (cycles, node_dram.filter_bytes * used_nodes)
        }
        Partition::Auto => {
            let a = scale_out_point(base, layer, nodes, Partition::OutputChannels);
            let b = scale_out_point(base, layer, nodes, Partition::Pixels);
            if b.0 < a.0 { b } else { a }
        }
    }
}

/// Compare scale-up vs scale-out for one layer at one PE budget under a
/// given scale-out partition strategy.
///
/// `base` fixes dataflow, scratchpad sizes and word size for both sides;
/// scale-out nodes are 8x8 copies of `base`.
pub fn compare_layer_with(
    base: &ArchConfig,
    layer: &LayerShape,
    pe_budget: u64,
    partition: Partition,
) -> ScaleComparison {
    assert!(pe_budget >= NODE_PES, "budget below one node");
    let df = base.dataflow;

    // --- scale-up ---------------------------------------------------------
    let up = scale_up_cfg(base, pe_budget);
    let up_cycles = df.timing(layer, up.array_h, up.array_w).cycles;
    let (up_dram, _) = memory::simulate(df, layer, &up);
    let up_weight_bw = up_dram.filter_bytes as f64 / up_cycles as f64;

    // --- scale-out --------------------------------------------------------
    let nodes = pe_budget / NODE_PES;
    let (out_cycles, out_weight_bytes) = scale_out_point(base, layer, nodes, partition);
    let out_weight_bw = out_weight_bytes as f64 / out_cycles as f64;

    ScaleComparison {
        pe_budget,
        nodes,
        up_cycles,
        out_cycles,
        up_weight_bw,
        out_weight_bw,
    }
}

/// The paper's comparison: output-channel partitioning (§IV-E).
pub fn compare_layer(base: &ArchConfig, layer: &LayerShape, pe_budget: u64) -> ScaleComparison {
    compare_layer_with(base, layer, pe_budget, Partition::OutputChannels)
}

/// Whole-topology comparison: layer runtimes sum (layers serialize),
/// weight bandwidths aggregate per layer then average runtime-weighted.
pub fn compare_topology(
    base: &ArchConfig,
    layers: &[LayerShape],
    pe_budget: u64,
) -> ScaleComparison {
    let mut up_cycles = 0;
    let mut out_cycles = 0;
    let mut up_weight_bytes = 0f64;
    let mut out_weight_bytes = 0f64;
    let mut nodes = 0;
    for layer in layers {
        let c = compare_layer(base, layer, pe_budget);
        up_cycles += c.up_cycles;
        out_cycles += c.out_cycles;
        up_weight_bytes += c.up_weight_bw * c.up_cycles as f64;
        out_weight_bytes += c.out_weight_bw * c.out_cycles as f64;
        nodes = c.nodes;
    }
    ScaleComparison {
        pe_budget,
        nodes,
        up_cycles,
        out_cycles,
        up_weight_bw: up_weight_bytes / up_cycles as f64,
        out_weight_bw: out_weight_bytes / out_cycles as f64,
    }
}

/// The paper's sweep: 64 PEs to 16384 PEs, x4 per step.
pub const PE_SWEEP: [u64; 5] = [64, 256, 1024, 4096, 16384];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::dataflow::Dataflow;

    fn base(df: Dataflow) -> ArchConfig {
        ArchConfig { dataflow: df, ..config::paper_default() }
    }

    #[test]
    fn partition_covers_all_filters() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 100, 1);
        for nodes in [1u64, 2, 3, 7, 16, 200] {
            let (per, used) = partition_filters(&l, nodes);
            assert!(per * used >= 100);
            assert!(per * (used - 1) < 100);
            assert!(used <= nodes);
        }
    }

    #[test]
    fn more_filters_than_nodes_uses_all_nodes() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 256, 1);
        let (per, used) = partition_filters(&l, 16);
        assert_eq!((per, used), (16, 16));
    }

    #[test]
    fn fewer_filters_than_nodes_leaves_nodes_idle() {
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 4, 1);
        let (per, used) = partition_filters(&l, 16);
        assert_eq!((per, used), (1, 4));
    }

    #[test]
    fn scale_up_cfg_is_square() {
        let c = scale_up_cfg(&base(Dataflow::Os), 1024);
        assert_eq!((c.array_h, c.array_w), (32, 32));
    }

    #[test]
    #[should_panic(expected = "not square")]
    fn non_square_budget_panics() {
        scale_up_cfg(&base(Dataflow::Os), 100 * 64 + 1);
    }

    #[test]
    fn both_sides_finish_and_ratio_positive() {
        let l = LayerShape::conv("c", 32, 32, 3, 3, 32, 64, 1);
        for df in Dataflow::ALL {
            for &pe in &PE_SWEEP {
                let c = compare_layer(&base(df), &l, pe);
                assert!(c.up_cycles > 0 && c.out_cycles > 0);
                assert!(c.runtime_ratio() > 0.0);
                assert!(c.weight_bw_ratio() > 0.0, "{df} {pe}");
            }
        }
    }

    #[test]
    fn poor_row_fit_favors_scale_out() {
        // The paper's §IV-E mechanism ("scaling decision is tied to
        // workloads"): when Npx barely spills the big array's rows
        // (129 px on 128 rows => half-empty residual fold) but filters
        // are plentiful, 8x8 nodes stay nearly fully mapped and
        // scale-out wins.
        let l = LayerShape::gemm("spill", 129, 64, 2048);
        let c = compare_layer(&base(Dataflow::Os), &l, 16384);
        assert!(
            c.runtime_ratio() > 1.0,
            "expected scale-out win: up={} out={}",
            c.up_cycles,
            c.out_cycles
        );
    }

    #[test]
    fn deep_windows_favor_scale_up() {
        // ...and the converse: K-dominated layers with few filters per
        // node leave scale-out columns idle.
        let l = LayerShape::conv("w1", 19, 19, 3, 3, 256, 256, 1);
        let c = compare_layer(&base(Dataflow::Os), &l, 16384);
        assert!(c.runtime_ratio() < 1.0, "up={} out={}", c.up_cycles, c.out_cycles);
    }

    #[test]
    fn pixel_partition_covers_all_output_rows() {
        let l = LayerShape::conv("c", 30, 30, 3, 3, 8, 16, 1);
        for nodes in [1u64, 2, 4, 7, 28, 100] {
            let (nl, used) = node_layer_pixels(&l, nodes);
            let rows_per_node = nl.ofmap_h();
            assert!(rows_per_node * used >= l.ofmap_h(), "nodes={nodes}");
            assert!(rows_per_node * (used - 1) < l.ofmap_h());
            // stripe geometry preserves width/channels/filters
            assert_eq!((nl.ifmap_w, nl.channels, nl.num_filters), (30, 8, 16));
        }
    }

    #[test]
    fn pixel_partition_duplicates_weights() {
        // with pixel partitioning every node fetches the full filter
        // set: aggregate weight traffic must exceed channel partitioning
        let l = LayerShape::conv("c", 64, 64, 3, 3, 32, 64, 1);
        let b = base(Dataflow::Os);
        let (_, w_chan) = scale_out_point(&b, &l, 16, Partition::OutputChannels);
        let (_, w_px) = scale_out_point(&b, &l, 16, Partition::Pixels);
        assert!(w_px > w_chan, "px={w_px} chan={w_chan}");
    }

    #[test]
    fn auto_partition_never_slower_than_either() {
        let b = base(Dataflow::Os);
        for l in [
            LayerShape::conv("convish", 64, 64, 3, 3, 32, 8, 1), // few filters
            LayerShape::conv("deep", 19, 19, 3, 3, 256, 256, 1), // many filters
            LayerShape::fc("fc", 4, 512, 512),
        ] {
            let (c_auto, _) = scale_out_point(&b, &l, 64, Partition::Auto);
            let (c_ch, _) = scale_out_point(&b, &l, 64, Partition::OutputChannels);
            let (c_px, _) = scale_out_point(&b, &l, 64, Partition::Pixels);
            assert_eq!(c_auto, c_ch.min(c_px), "{}", l.name);
        }
    }

    #[test]
    fn few_filters_prefer_pixel_partition() {
        // §IV-E: "the best strategy may differ from layer to layer
        // depending on the number of filters vs channels" — with 8
        // filters over 64 nodes, channel partitioning idles 56 nodes
        let l = LayerShape::conv("fewfilt", 64, 64, 3, 3, 32, 8, 1);
        let b = base(Dataflow::Os);
        let (c_ch, _) = scale_out_point(&b, &l, 64, Partition::OutputChannels);
        let (c_px, _) = scale_out_point(&b, &l, 64, Partition::Pixels);
        assert!(c_px < c_ch, "px={c_px} ch={c_ch}");
    }

    #[test]
    fn topology_comparison_accumulates() {
        let layers = vec![
            LayerShape::conv("a", 16, 16, 3, 3, 8, 32, 1),
            LayerShape::conv("b", 14, 14, 3, 3, 32, 64, 1),
        ];
        let b = base(Dataflow::Os);
        let t = compare_topology(&b, &layers, 1024);
        let s: u64 = layers.iter().map(|l| compare_layer(&b, l, 1024).up_cycles).sum();
        assert_eq!(t.up_cycles, s);
    }
}
