//! `.cfg` parser — Table I of the paper, INI-style:
//!
//! ```text
//! [general]
//! run_name = my_run
//!
//! [architecture_presets]
//! ArrayHeight:    32
//! ArrayWidth:     32
//! IfmapSramSz:    512
//! FilterSramSz:   512
//! OfmapSramSz:    256
//! IfmapOffset:    0
//! FilterOffset:   10000000
//! OfmapOffset:    20000000
//! Dataflow:       os
//! Topology:       topologies/resnet50.csv
//! ```
//!
//! Both `key: value` and `key = value` are accepted; keys are
//! case-insensitive; unknown keys are an error (typo protection).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::dataflow::Dataflow;
use crate::{Error, Result};

/// Architecture + run parameters (Table I).
#[derive(Clone, Debug, PartialEq)]
pub struct ArchConfig {
    pub run_name: String,
    /// Rows of the MAC systolic array.
    pub array_h: u64,
    /// Columns of the MAC systolic array.
    pub array_w: u64,
    /// Working-set SRAM sizes in KB (each is one half of a double buffer).
    pub ifmap_sram_kb: u64,
    pub filter_sram_kb: u64,
    pub ofmap_sram_kb: u64,
    /// Address-space offsets for generated traces.
    pub ifmap_offset: u64,
    pub filter_offset: u64,
    pub ofmap_offset: u64,
    /// Mapping strategy: os / ws / is.
    pub dataflow: Dataflow,
    /// Bytes per operand word (paper: 1 for int8 inference).
    pub word_bytes: u64,
    /// Path to the topology csv (optional; CLI may supply it).
    pub topology_path: Option<PathBuf>,
}

impl Default for ArchConfig {
    fn default() -> Self {
        super::paper_default()
    }
}

impl ArchConfig {
    pub fn total_pes(&self) -> u64 {
        self.array_h * self.array_w
    }

    pub fn ifmap_sram_bytes(&self) -> u64 {
        self.ifmap_sram_kb * 1024
    }

    pub fn filter_sram_bytes(&self) -> u64 {
        self.filter_sram_kb * 1024
    }

    pub fn ofmap_sram_bytes(&self) -> u64 {
        self.ofmap_sram_kb * 1024
    }

    /// Validate invariants; call after parsing user input.
    pub fn validate(&self) -> Result<()> {
        if self.array_h == 0 || self.array_w == 0 {
            return Err(Error::Config("array dimensions must be positive".into()));
        }
        if self.word_bytes == 0 {
            return Err(Error::Config("word_bytes must be positive".into()));
        }
        if self.ifmap_sram_kb == 0 || self.filter_sram_kb == 0 || self.ofmap_sram_kb == 0 {
            return Err(Error::Config("SRAM sizes must be positive".into()));
        }
        // offsets must keep the three address spaces disjoint in traces;
        // we only require they differ.
        if self.ifmap_offset == self.filter_offset
            || self.filter_offset == self.ofmap_offset
            || self.ifmap_offset == self.ofmap_offset
        {
            return Err(Error::Config("address offsets must be distinct".into()));
        }
        Ok(())
    }

    /// Parse the cfg text format.
    pub fn parse(text: &str) -> Result<Self> {
        let mut kv: BTreeMap<String, String> = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if line.starts_with('[') && line.ends_with(']') {
                continue; // section headers are decorative
            }
            let (k, v) = line
                .split_once('=')
                .or_else(|| line.split_once(':'))
                .ok_or_else(|| {
                    Error::Config(format!("line {}: expected key=value: {line:?}", lineno + 1))
                })?;
            kv.insert(k.trim().to_lowercase(), v.trim().to_string());
        }
        Self::from_map(kv)
    }

    // BTreeMap keeps the unknown-key diagnostic deterministic (first
    // offending key in lexicographic order, not hash order).
    fn from_map(mut kv: BTreeMap<String, String>) -> Result<Self> {
        let mut cfg = ArchConfig::default();
        let mut take = |k: &str| kv.remove(k);

        fn num(k: &str, v: &str) -> Result<u64> {
            v.parse::<u64>()
                .map_err(|_| Error::Config(format!("{k}: not a number: {v:?}")))
        }

        if let Some(v) = take("run_name") {
            cfg.run_name = v;
        }
        if let Some(v) = take("arrayheight") {
            cfg.array_h = num("ArrayHeight", &v)?;
        }
        if let Some(v) = take("arraywidth") {
            cfg.array_w = num("ArrayWidth", &v)?;
        }
        if let Some(v) = take("ifmapsramsz") {
            cfg.ifmap_sram_kb = num("IfmapSramSz", &v)?;
        }
        if let Some(v) = take("filtersramsz") {
            cfg.filter_sram_kb = num("FilterSramSz", &v)?;
        }
        if let Some(v) = take("ofmapsramsz") {
            cfg.ofmap_sram_kb = num("OfmapSramSz", &v)?;
        }
        if let Some(v) = take("ifmapoffset") {
            cfg.ifmap_offset = num("IfmapOffset", &v)?;
        }
        if let Some(v) = take("filteroffset") {
            cfg.filter_offset = num("FilterOffset", &v)?;
        }
        if let Some(v) = take("ofmapoffset") {
            cfg.ofmap_offset = num("OfmapOffset", &v)?;
        }
        if let Some(v) = take("wordbytes") {
            cfg.word_bytes = num("WordBytes", &v)?;
        }
        if let Some(v) = take("dataflow") {
            cfg.dataflow = Dataflow::parse(&v)?;
        }
        if let Some(v) = take("topology") {
            cfg.topology_path = Some(PathBuf::from(v));
        }
        if let Some(k) = kv.keys().next() {
            return Err(Error::Config(format!("unknown key {k:?} (Table I lists the legal keys)")));
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Read and parse a cfg file.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut cfg = Self::parse(&text)?;
        // topology path is relative to the cfg file's directory
        if let (Some(tp), Some(dir)) = (&cfg.topology_path, path.parent()) {
            if tp.is_relative() {
                cfg.topology_path = Some(dir.join(tp));
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "
[general]
run_name = sweep1

[architecture_presets]
ArrayHeight: 32
ArrayWidth : 64
IfmapSramSz = 256
FilterSramSz: 256
OfmapSramSz:  128
IfmapOffset:  0
FilterOffset: 10000000
OfmapOffset:  20000000
Dataflow:     ws
Topology:     topologies/test.csv
";

    #[test]
    fn parses_sample() {
        let c = ArchConfig::parse(SAMPLE).unwrap();
        assert_eq!(c.run_name, "sweep1");
        assert_eq!((c.array_h, c.array_w), (32, 64));
        assert_eq!(c.ifmap_sram_kb, 256);
        assert_eq!(c.dataflow, Dataflow::Ws);
        assert_eq!(
            c.topology_path.unwrap().to_str().unwrap(),
            "topologies/test.csv"
        );
    }

    #[test]
    fn defaults_fill_missing_keys() {
        let c = ArchConfig::parse("ArrayHeight: 8\nArrayWidth: 8\n").unwrap();
        assert_eq!(c.ifmap_sram_kb, 512); // paper default
        assert_eq!(c.dataflow, Dataflow::Os);
    }

    #[test]
    fn unknown_key_is_error() {
        let err = ArchConfig::parse("ArayHeight: 8\n").unwrap_err();
        assert!(err.to_string().contains("unknown key"));
    }

    #[test]
    fn bad_number_is_error() {
        assert!(ArchConfig::parse("ArrayHeight: eight\n").is_err());
    }

    #[test]
    fn bad_dataflow_is_error() {
        assert!(ArchConfig::parse("Dataflow: rs\n").is_err());
    }

    #[test]
    fn zero_array_rejected() {
        assert!(ArchConfig::parse("ArrayHeight: 0\n").is_err());
    }

    #[test]
    fn equal_offsets_rejected() {
        assert!(ArchConfig::parse("IfmapOffset: 5\nFilterOffset: 5\n").is_err());
    }

    #[test]
    fn comments_and_sections_ignored() {
        let c = ArchConfig::parse("# c\n; c2\n[sec]\nArrayHeight: 16\n").unwrap();
        assert_eq!(c.array_h, 16);
    }
}
