//! Front end: the `.cfg` architecture file (Table I) and the lowered
//! workload form ([`Topology`], Table II), format-compatible with the
//! original SCALE-Sim where practical. Workload *authoring* moved to the
//! typed operator IR in [`crate::workload`]; `Topology`'s csv entry
//! points are deprecated shims routed through it.

mod cfg;
mod topology;
pub mod workloads;

pub use cfg::ArchConfig;
pub use topology::Topology;

use crate::dataflow::Dataflow;

/// Built-in default matching the paper's methodology (§IV-A): TPUv3-sized
/// 128x128 array, 1 byte/word, 1024 KB operand scratchpad split 512/512
/// between IFMAP and filters.
pub fn paper_default() -> ArchConfig {
    ArchConfig {
        run_name: "paper_default".into(),
        array_h: 128,
        array_w: 128,
        ifmap_sram_kb: 512,
        filter_sram_kb: 512,
        ofmap_sram_kb: 256,
        ifmap_offset: 0,
        filter_offset: 10_000_000,
        ofmap_offset: 20_000_000,
        dataflow: Dataflow::Os,
        word_bytes: 1,
        topology_path: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_matches_methodology() {
        let c = paper_default();
        assert_eq!((c.array_h, c.array_w), (128, 128));
        assert_eq!(c.ifmap_sram_kb + c.filter_sram_kb, 1024);
        assert_eq!(c.word_bytes, 1);
        assert_eq!(c.dataflow, Dataflow::Os);
    }
}
