//! Built-in MLPerf workloads (Table III) — W1..W7, embedded at compile
//! time from `topologies/*.csv` so every binary, test and bench can load
//! them without caring about the working directory.
//!
//! Layer hyper-parameters are reconstructed from the cited source papers
//! (see DESIGN.md §6): only the Table-II fields matter to the simulator.
//! RNN/FC/attention layers are encoded as GEMMs per §III-A (MV/MM as
//! 1x1-filter convolutions).

use super::Topology;
use crate::workload::Workload;

/// Workload tags in the paper's Table III order.
pub const TAGS: [(&str, &str); 7] = [
    ("W1", "alphagozero"),
    ("W2", "deepspeech2"),
    ("W3", "fasterrcnn"),
    ("W4", "ncf"),
    ("W5", "resnet50"),
    ("W6", "sentimentcnn"),
    ("W7", "transformer"),
];

macro_rules! embedded {
    ($name:literal) => {
        ($name, include_str!(concat!("../../../topologies/", $name, ".csv")))
    };
}

const SOURCES: [(&str, &str); 9] = [
    embedded!("alphagozero"),
    embedded!("deepspeech2"),
    embedded!("fasterrcnn"),
    embedded!("ncf"),
    embedded!("resnet50"),
    embedded!("sentimentcnn"),
    embedded!("transformer"),
    // extras beyond Table III (classic edge/vision networks, useful for
    // the design-space examples and regression coverage)
    embedded!("alexnet"),
    embedded!("mobilenetv1"),
];

macro_rules! embedded_gemm {
    ($name:literal) => {
        ($name, include_str!(concat!("../../../topologies/gemm/", $name, ".csv")))
    };
}

/// Built-in GEMM workloads (SCALE-Sim-v2 style `M, N, K` csv) — MLP,
/// attention-projection and LSTM-cell shapes, plus `ncf_gemm` (the exact
/// GEMM re-encoding of W4, used to demonstrate conv <-> GEMM memo-cache
/// sharing).
const GEMM_SOURCES: [(&str, &str); 4] = [
    embedded_gemm!("mlp"),
    embedded_gemm!("attention"),
    embedded_gemm!("lstm"),
    embedded_gemm!("ncf_gemm"),
];

/// Load one built-in conv workload by name ("resnet50") or tag ("W5"),
/// in lowered form.
pub fn builtin(name: &str) -> Option<Topology> {
    let lname = name.to_lowercase();
    let resolved = TAGS
        .iter()
        .find(|(tag, _)| tag.eq_ignore_ascii_case(&lname))
        .map(|(_, n)| *n)
        .unwrap_or(lname.as_str());
    // embedded csvs are pinned by the suite tests, so a parse failure
    // here means a corrupted build — surface it as "not found"
    SOURCES
        .iter()
        .find(|(n, _)| *n == resolved)
        .and_then(|(n, text)| Workload::parse_conv_csv(n, n, text).and_then(|w| w.lower()).ok())
}

/// Load one built-in GEMM workload by name ("mlp", or "gemm/mlp" as the
/// csv lives under `topologies/gemm/`).
pub fn builtin_gemm(name: &str) -> Option<Workload> {
    let lname = name.to_lowercase();
    let resolved = lname.strip_prefix("gemm/").unwrap_or(&lname);
    GEMM_SOURCES
        .iter()
        .find(|(n, _)| *n == resolved)
        .and_then(|(n, text)| Workload::parse_gemm_csv(n, n, text).ok())
}

/// Resolve any built-in name as a typed [`Workload`]: conv builtins wrap
/// as raw Table-II ops, GEMM builtins parse as `Gemm` ops.
pub fn builtin_workload(name: &str) -> Option<Workload> {
    if let Some(t) = builtin(name) {
        return Some(Workload::from_topology(&t));
    }
    builtin_gemm(name)
}

/// All seven MLPerf workloads in Table III order.
pub fn mlperf_suite() -> Vec<Topology> {
    // filter_map keeps this panic-free; the suite-length tests pin that
    // nothing is silently dropped
    TAGS.iter().filter_map(|(_, n)| builtin(n)).collect()
}

/// All built-in GEMM workloads, as typed IR specs.
pub fn gemm_suite() -> Vec<Workload> {
    GEMM_SOURCES.iter().filter_map(|(n, _)| builtin_gemm(n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_seven_parse() {
        let suite = mlperf_suite();
        assert_eq!(suite.len(), 7);
        for t in &suite {
            assert!(!t.layers.is_empty(), "{}", t.name);
            assert!(t.total_macs() > 0, "{}", t.name);
        }
    }

    #[test]
    fn tags_resolve() {
        assert_eq!(builtin("W5").unwrap().name, "resnet50");
        assert_eq!(builtin("w1").unwrap().name, "alphagozero");
        assert_eq!(builtin("transformer").unwrap().name, "transformer");
        assert!(builtin("nope").is_none());
    }

    #[test]
    fn resnet50_has_54_layers() {
        assert_eq!(builtin("resnet50").unwrap().layers.len(), 54);
    }

    #[test]
    fn resnet50_conv1_matches_reference() {
        let t = builtin("resnet50").unwrap();
        let c1 = &t.layers[0];
        assert_eq!((c1.filt_h, c1.channels, c1.num_filters, c1.stride), (7, 3, 64, 2));
        assert_eq!(c1.ofmap_h(), 112); // (230-7)/2+1
    }

    #[test]
    fn workload_scale_sanity() {
        // ResNet-50 is ~4 GMACs; our valid-padding reconstruction should
        // land within 2x of that.
        let macs = builtin("resnet50").unwrap().total_macs();
        assert!(macs > 2_000_000_000 && macs < 8_000_000_000, "{macs}");
        // NCF is tiny by comparison (the paper's Fig 7c knee argument)
        assert!(builtin("ncf").unwrap().total_macs() < 100_000_000);
    }

    #[test]
    fn extra_workloads_parse() {
        for name in ["alexnet", "mobilenetv1"] {
            let t = builtin(name).unwrap();
            assert!(t.total_macs() > 0, "{name}");
        }
        // AlexNet ~0.7 GMAC single inference (valid-padding reconstruction)
        let a = builtin("alexnet").unwrap();
        assert!(a.total_macs() > 400_000_000 && a.total_macs() < 1_500_000_000);
        // depthwise layers encode as single-filter convs
        let m = builtin("mobilenetv1").unwrap();
        assert!(m.layers.iter().any(|l| l.num_filters == 1 && l.filt_h == 3));
    }

    #[test]
    fn gemm_builtins_parse_and_lower() {
        for (name, _) in GEMM_SOURCES {
            let w = builtin_gemm(name).unwrap();
            let t = w.lower().unwrap();
            assert!(!t.layers.is_empty(), "{name}");
            assert!(t.layers.iter().all(|l| l.is_gemm()), "{name}: all tiles are GEMMs");
        }
        assert!(builtin_gemm("gemm/mlp").is_some(), "gemm/ prefix resolves");
        assert!(builtin_gemm("nope").is_none());
    }

    #[test]
    fn ncf_gemm_re_encodes_ncf_exactly() {
        // the conv <-> GEMM cache-sharing demo depends on this: every
        // ncf_gemm tile must equal its conv-encoded ncf twin (names too)
        let conv = builtin("ncf").unwrap();
        let gemm = builtin_gemm("ncf_gemm").unwrap().lower().unwrap();
        assert_eq!(conv.layers, gemm.layers);
    }

    #[test]
    fn builtin_workload_resolves_both_families() {
        let w5 = builtin_workload("W5").unwrap();
        assert_eq!(w5.lower().unwrap(), builtin("resnet50").unwrap());
        assert_eq!(builtin_workload("attention").unwrap().name, "attention");
        assert!(builtin_workload("nope").is_none());
    }

    #[test]
    fn transformer_weights_dwarf_pixels() {
        // the §IV-B claim driving "IS wins on W7": weights >> output px
        for l in &builtin("transformer").unwrap().layers {
            assert!(l.filter_elems() > l.npx(), "{}", l.name);
        }
    }

    #[test]
    fn deepspeech_pixels_dwarf_weights_in_convs() {
        // ...and "WS wins on W2": the dominant conv1 has px >> weights
        let t = builtin("deepspeech2").unwrap();
        let c1 = &t.layers[0];
        assert!(c1.npx() > c1.filter_elems(), "{}", c1.name);
    }
}
