//! `Topology` — the **lowered** workload form (an ordered list of
//! Table-II [`LayerShape`] tiles) that the engine consumes, plus a
//! deprecated csv-parsing shim.
//!
//! Workloads are now authored through the typed operator IR
//! ([`crate::workload::Workload`]): a graph of `Conv2d`/`Gemm`/`FC`/`Pool`
//! ops whose [`lower`](crate::workload::Workload::lower) pass produces a
//! `Topology`. The legacy Table-II csv entry points here
//! ([`Topology::parse`], [`Topology::from_file`]) remain as shims that
//! route through that IR (`Op::TableII` nodes, lowered verbatim) and are
//! **bit-identical** to the pre-IR parser — pinned by the equivalence
//! suite — with one improvement: rows are strictly arity-checked and
//! parse errors carry `file:line`.
//!
//! Legacy format (header optional; trailing commas and `#` comments
//! tolerated; layers run in file order, §III-F):
//!
//! ```text
//! Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width,
//! Channels, Num Filter, Strides,
//! Conv1, 224, 224, 7, 7, 3, 64, 2,
//! ```

use std::path::Path;

use crate::arch::LayerShape;
use crate::Result;

/// A named workload in lowered form: ordered list of engine tiles.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub name: String,
    pub layers: Vec<LayerShape>,
}

impl Topology {
    pub fn new(name: &str, layers: Vec<LayerShape>) -> Self {
        Topology { name: name.to_string(), layers }
    }

    /// Parse legacy Table-II topology csv text (shim: routes through the
    /// workload IR and lowers, bit-identical to the pre-IR parser).
    #[deprecated(
        since = "0.3.0",
        note = "use workload::Workload::parse_conv_csv(..)?.lower() — or \
                Workload::from_file, which also reads GEMM csvs"
    )]
    pub fn parse(name: &str, text: &str) -> Result<Self> {
        crate::workload::Workload::parse_conv_csv(name, name, text)?.lower()
    }

    /// Read and parse a legacy topology file; name = file stem (shim,
    /// see [`Topology::parse`]).
    #[deprecated(
        since = "0.3.0",
        note = "use workload::Workload::from_file(path)?.lower()"
    )]
    pub fn from_file(path: &Path) -> Result<Self> {
        crate::workload::Workload::from_file(path)?.lower()
    }

    /// Total MACs over all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Serialize back to Table-II csv (round-trip tested).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,\n",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{}, {}, {}, {}, {}, {}, {}, {},\n",
                l.name, l.ifmap_h, l.ifmap_w, l.filt_h, l.filt_w, l.channels,
                l.num_filters, l.stride
            ));
        }
        out
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,
Conv1, 224, 224, 7, 7, 3, 64, 2,
FC, 1, 1, 1, 1, 2048, 1000, 1,
";

    #[test]
    fn parses_with_header() {
        let t = Topology::parse("sample", SAMPLE).unwrap();
        assert_eq!(t.layers.len(), 2);
        assert_eq!(t.layers[0].name, "Conv1");
        assert_eq!(t.layers[0].num_filters, 64);
        assert_eq!(t.layers[1].channels, 2048);
    }

    #[test]
    fn parses_without_header() {
        let t = Topology::parse("nh", "C1, 8, 8, 3, 3, 4, 16, 1,\n").unwrap();
        assert_eq!(t.layers.len(), 1);
    }

    #[test]
    fn round_trips_through_to_csv() {
        let t = Topology::parse("sample", SAMPLE).unwrap();
        let t2 = Topology::parse("sample", &t.to_csv()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn shim_matches_workload_ir_lowering() {
        let direct = Topology::parse("sample", SAMPLE).unwrap();
        let via_ir = crate::workload::Workload::parse_conv_csv("sample", "sample", SAMPLE)
            .unwrap()
            .lower()
            .unwrap();
        assert_eq!(direct, via_ir);
    }

    #[test]
    fn wrong_cell_count_is_error_with_line() {
        let err = Topology::parse("bad", "C1, 8, 8, 3, 3, 4, 16,\n").unwrap_err();
        assert!(err.to_string().contains("bad:1"), "{err}");
    }

    #[test]
    fn non_numeric_cell_is_error() {
        assert!(Topology::parse("bad", "C1, 8, x, 3, 3, 4, 16, 1,\n").is_err());
    }

    #[test]
    fn empty_file_is_error() {
        assert!(Topology::parse("empty", "# only comments\n").is_err());
    }

    #[test]
    fn invalid_layer_geometry_is_error() {
        // filter 5x5 on 4x4 ifmap
        assert!(Topology::parse("bad", "C1, 4, 4, 5, 5, 1, 1, 1,\n").is_err());
    }

    #[test]
    fn total_macs_sums_layers() {
        let t = Topology::parse("nh", "C1, 4, 4, 1, 1, 2, 3, 1,\nC2, 4, 4, 1, 1, 3, 2, 1,\n").unwrap();
        assert_eq!(t.total_macs(), 16 * 2 * 3 + 16 * 3 * 2);
    }
}
