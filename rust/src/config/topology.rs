//! Topology `.csv` parser — Table II of the paper.
//!
//! Format (header optional, detected by non-numeric second cell):
//!
//! ```text
//! Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width,
//! Channels, Num Filter, Strides,
//! Conv1, 224, 224, 7, 7, 3, 64, 2,
//! ```
//!
//! Trailing commas and `#` comments are tolerated (the original tool's
//! files carry trailing commas). Layers run in file order; parallel
//! branches of modern cells are serialized in listed order (§III-F).

use std::path::Path;

use crate::arch::LayerShape;
use crate::util::csv;
use crate::{Error, Result};

/// A named workload: ordered list of layers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    pub name: String,
    pub layers: Vec<LayerShape>,
}

impl Topology {
    pub fn new(name: &str, layers: Vec<LayerShape>) -> Self {
        Topology { name: name.to_string(), layers }
    }

    /// Parse topology csv text.
    pub fn parse(name: &str, text: &str) -> Result<Self> {
        let rows = csv::parse(text);
        let mut layers = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            if i == 0 && looks_like_header(row) {
                continue;
            }
            layers.push(parse_row(row, i)?);
        }
        if layers.is_empty() {
            return Err(Error::Topology(format!("{name}: no layers found")));
        }
        let t = Topology::new(name, layers);
        for l in &t.layers {
            l.validate()?;
        }
        Ok(t)
    }

    /// Read and parse a topology file; name = file stem.
    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("topology");
        Self::parse(name, &text)
    }

    /// Total MACs over all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Serialize back to Table-II csv (round-trip tested).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,\n",
        );
        for l in &self.layers {
            out.push_str(&format!(
                "{}, {}, {}, {}, {}, {}, {}, {},\n",
                l.name, l.ifmap_h, l.ifmap_w, l.filt_h, l.filt_w, l.channels,
                l.num_filters, l.stride
            ));
        }
        out
    }
}

fn looks_like_header(row: &[String]) -> bool {
    row.len() >= 2 && row[1].parse::<u64>().is_err()
}

fn parse_row(row: &[String], lineno: usize) -> Result<LayerShape> {
    if row.len() != 8 {
        return Err(Error::Topology(format!(
            "row {}: expected 8 cells (Table II), got {}: {row:?}",
            lineno + 1,
            row.len()
        )));
    }
    let num = |i: usize| -> Result<u64> {
        row[i].parse::<u64>().map_err(|_| {
            Error::Topology(format!("row {}: cell {i} not a number: {:?}", lineno + 1, row[i]))
        })
    };
    Ok(LayerShape {
        name: row[0].clone(),
        ifmap_h: num(1)?,
        ifmap_w: num(2)?,
        filt_h: num(3)?,
        filt_w: num(4)?,
        channels: num(5)?,
        num_filters: num(6)?,
        stride: num(7)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,
Conv1, 224, 224, 7, 7, 3, 64, 2,
FC, 1, 1, 1, 1, 2048, 1000, 1,
";

    #[test]
    fn parses_with_header() {
        let t = Topology::parse("sample", SAMPLE).unwrap();
        assert_eq!(t.layers.len(), 2);
        assert_eq!(t.layers[0].name, "Conv1");
        assert_eq!(t.layers[0].num_filters, 64);
        assert_eq!(t.layers[1].channels, 2048);
    }

    #[test]
    fn parses_without_header() {
        let t = Topology::parse("nh", "C1, 8, 8, 3, 3, 4, 16, 1,\n").unwrap();
        assert_eq!(t.layers.len(), 1);
    }

    #[test]
    fn round_trips_through_to_csv() {
        let t = Topology::parse("sample", SAMPLE).unwrap();
        let t2 = Topology::parse("sample", &t.to_csv()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn wrong_cell_count_is_error() {
        assert!(Topology::parse("bad", "C1, 8, 8, 3, 3, 4, 16,\n").is_err());
    }

    #[test]
    fn non_numeric_cell_is_error() {
        assert!(Topology::parse("bad", "C1, 8, x, 3, 3, 4, 16, 1,\n").is_err());
    }

    #[test]
    fn empty_file_is_error() {
        assert!(Topology::parse("empty", "# only comments\n").is_err());
    }

    #[test]
    fn invalid_layer_geometry_is_error() {
        // filter 5x5 on 4x4 ifmap
        assert!(Topology::parse("bad", "C1, 4, 4, 5, 5, 1, 1, 1,\n").is_err());
    }

    #[test]
    fn total_macs_sums_layers() {
        let t = Topology::parse("nh", "C1, 4, 4, 1, 1, 2, 3, 1,\nC2, 4, 4, 1, 1, 3, 2, 1,\n").unwrap();
        assert_eq!(t.total_macs(), 16 * 2 * 3 + 16 * 3 * 2);
    }
}
