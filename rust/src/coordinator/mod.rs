//! Legacy run coordination — the pre-engine "tool" wrapper around the
//! library (Fig 1): a [`RunSpec`] bundles config + topology + output
//! options, and [`run`] executes it.
//!
//! This module is now a thin shim over [`crate::engine`]: `run`
//! translates the spec into an [`crate::engine::EngineBuilder`] and
//! delegates, so its behavior (parallel layer fan-out, report files,
//! trace dumps, functional validation) is exactly [`Engine::run`]'s.
//! External callers should migrate:
//!
//! ```text
//! // before                            // after
//! let mut spec = RunSpec::new(c, t);   let engine = Engine::builder()
//! spec.out_dir = Some(dir);                .config(c)
//! spec.dump_traces = true;                 .out_dir(dir)
//! let out = run(&spec)?;                   .dump_traces(true)
//!                                          .build()?;
//!                                      let out = engine.run(&t)?;
//! ```

use std::path::PathBuf;

use crate::config::{ArchConfig, Topology};
use crate::engine::Engine;
use crate::Result;

pub use crate::engine::RunOutcome;

/// A full simulation run request (legacy form; see the module docs).
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub cfg: ArchConfig,
    pub topology: Topology,
    /// Output directory; created if missing. None = no files written.
    pub out_dir: Option<PathBuf>,
    /// Dump per-layer cycle-accurate SRAM traces (bounded; large layers
    /// truncate at `trace_limit` events per layer).
    pub dump_traces: bool,
    pub trace_limit: u64,
    /// Cross-check layer numerics through the AOT artifact with this
    /// tile size (requires `make artifacts`).
    pub functional_tile: Option<usize>,
    pub threads: usize,
}

impl RunSpec {
    pub fn new(cfg: ArchConfig, topology: Topology) -> Self {
        RunSpec {
            cfg,
            topology,
            out_dir: None,
            dump_traces: false,
            trace_limit: 2_000_000,
            functional_tile: None,
            threads: crate::sweep::default_threads(),
        }
    }

    /// Build the equivalent engine for this spec.
    pub fn to_engine(&self) -> Result<Engine> {
        let mut b = Engine::builder()
            .config(self.cfg.clone())
            .dump_traces(self.dump_traces)
            .trace_limit(self.trace_limit)
            .threads(self.threads);
        if let Some(dir) = &self.out_dir {
            b = b.out_dir(dir.clone());
        }
        if let Some(tile) = self.functional_tile {
            b = b.functional_tile(tile);
        }
        b.build()
    }
}

/// Execute a run: parallel layer simulation, reports, optional traces,
/// optional functional validation.
#[deprecated(since = "0.2.0", note = "use engine::Engine::builder()...build()?.run(&topology)")]
pub fn run(spec: &RunSpec) -> Result<RunOutcome> {
    spec.to_engine()?.run(&spec.topology)
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config;

    fn spec() -> RunSpec {
        let topo = Topology::new(
            "mini",
            vec![
                LayerShape::conv("c1", 12, 12, 3, 3, 4, 8, 1),
                LayerShape::fc("fc", 1, 64, 10),
            ],
        );
        let mut cfg = config::paper_default();
        cfg.array_h = 16;
        cfg.array_w = 16;
        RunSpec::new(cfg, topo)
    }

    #[test]
    fn run_without_outputs() {
        let out = run(&spec()).unwrap();
        assert_eq!(out.report.layers.len(), 2);
        assert!(out.files_written.is_empty());
        assert!(out.functional.is_empty());
    }

    #[test]
    fn run_writes_reports_and_traces() {
        let mut s = spec();
        let dir = std::env::temp_dir().join(format!("scale_sim_run_{}", std::process::id()));
        s.out_dir = Some(dir.clone());
        s.dump_traces = true;
        let out = run(&s).unwrap();
        assert!(out.files_written.iter().all(|f| f.exists()));
        // trace files exist per layer
        assert!(dir.join("traces/c1_sram_trace.csv").exists());
        let text = std::fs::read_to_string(dir.join("traces/c1_sram_trace.csv")).unwrap();
        assert!(text.starts_with("cycle,kind,address\n"));
        assert!(text.lines().count() > 10);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trace_limit_truncates() {
        let mut s = spec();
        let dir = std::env::temp_dir().join(format!("scale_sim_trunc_{}", std::process::id()));
        s.out_dir = Some(dir.clone());
        s.dump_traces = true;
        s.trace_limit = 5;
        run(&s).unwrap();
        let text = std::fs::read_to_string(dir.join("traces/c1_sram_trace.csv")).unwrap();
        assert_eq!(text.lines().count(), 6); // header + 5
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn invalid_cfg_rejected() {
        let mut s = spec();
        s.cfg.array_h = 0;
        assert!(run(&s).is_err());
    }

    #[test]
    fn parallel_and_serial_agree() {
        let mut s = spec();
        s.threads = 1;
        let a = run(&s).unwrap();
        s.threads = 8;
        let b = run(&s).unwrap();
        assert_eq!(a.report, b.report);
    }

    #[test]
    fn shim_equals_direct_engine_use() {
        let s = spec();
        let via_shim = run(&s).unwrap();
        let engine = s.to_engine().unwrap();
        let direct = engine.run(&s.topology).unwrap();
        assert_eq!(via_shim.report, direct.report);
    }
}
