//! Run coordination — the Fig-1 "tool" wrapper around the library: takes
//! a config + topology, fans layer simulations out over worker threads,
//! writes the output file set (summary csvs, optional cycle-accurate
//! trace csvs), and optionally cross-checks the mapping *functionally*
//! by executing the layer's GEMM through the AOT Pallas artifact on the
//! PJRT runtime.

use std::path::{Path, PathBuf};

use crate::config::{ArchConfig, Topology};
use crate::report;
use crate::runtime::Runtime;
use crate::sim::{LayerReport, Simulator, WorkloadReport};
use crate::sweep::parallel_map;
use crate::trace::{self, Access};
use crate::util::csv::CsvWriter;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// A full simulation run request.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub cfg: ArchConfig,
    pub topology: Topology,
    /// Output directory; created if missing. None = no files written.
    pub out_dir: Option<PathBuf>,
    /// Dump per-layer cycle-accurate SRAM traces (bounded; large layers
    /// truncate at `trace_limit` events per layer).
    pub dump_traces: bool,
    pub trace_limit: u64,
    /// Cross-check layer numerics through the PJRT artifact with this
    /// tile size (requires `make artifacts`).
    pub functional_tile: Option<usize>,
    pub threads: usize,
}

impl RunSpec {
    pub fn new(cfg: ArchConfig, topology: Topology) -> Self {
        RunSpec {
            cfg,
            topology,
            out_dir: None,
            dump_traces: false,
            trace_limit: 2_000_000,
            functional_tile: None,
            threads: crate::sweep::default_threads(),
        }
    }
}

/// Outcome of one coordinated run.
#[derive(Debug)]
pub struct RunOutcome {
    pub report: WorkloadReport,
    /// (layer, max abs error) per functionally-checked layer.
    pub functional: Vec<(String, f32)>,
    pub files_written: Vec<PathBuf>,
}

/// Execute a run: parallel layer simulation, reports, optional traces,
/// optional functional validation.
pub fn run(spec: &RunSpec) -> Result<RunOutcome> {
    spec.cfg.validate()?;
    let sim = Simulator::new(spec.cfg.clone());
    let layers: Vec<LayerReport> =
        parallel_map(&spec.topology.layers, spec.threads, |l| sim.run_layer(l));
    let report = WorkloadReport { workload: spec.topology.name.clone(), layers };

    let mut files = Vec::new();
    if let Some(dir) = &spec.out_dir {
        report::write_all(dir, &report, spec.cfg.total_pes())?;
        for f in [
            "compute_report.csv",
            "sram_report.csv",
            "dram_report.csv",
            "energy_report.csv",
            "summary.md",
        ] {
            files.push(dir.join(f));
        }
        if spec.dump_traces {
            files.extend(dump_traces(spec, dir)?);
        }
    }

    let functional = match spec.functional_tile {
        Some(tile) => functional_check(spec, tile)?,
        None => Vec::new(),
    };

    Ok(RunOutcome { report, functional, files_written: files })
}

/// Write per-layer cycle-accurate SRAM traces: both the event-list form
/// (`cycle,kind,addr`) and the original tool's per-port csv format
/// (`<layer>_sram_read.csv` / `<layer>_sram_write.csv`, §III-F).
fn dump_traces(spec: &RunSpec, dir: &Path) -> Result<Vec<PathBuf>> {
    let tdir = dir.join("traces");
    std::fs::create_dir_all(&tdir)?;
    let mut out = Vec::new();
    for layer in &spec.topology.layers {
        let mut w = CsvWriter::new(&["cycle", "kind", "address"]);
        let mut n = 0u64;
        trace::generate(spec.cfg.dataflow, layer, &spec.cfg, |cycle, access, addr| {
            if n >= spec.trace_limit {
                return;
            }
            n += 1;
            let kind = match access {
                Access::IfmapRead => "ifmap_read",
                Access::FilterRead => "filter_read",
                Access::OfmapWrite => "ofmap_write",
                Access::OfmapRead => "ofmap_read",
            };
            w.row(&[cycle.to_string(), kind.to_string(), addr.to_string()]);
        });
        let base = sanitize(&layer.name);
        let path = tdir.join(format!("{base}_sram_trace.csv"));
        w.write_to(&path)?;
        out.push(path);

        // original per-port format, bounded by the same event budget
        let max_cycles =
            (spec.trace_limit / (spec.cfg.array_h + spec.cfg.array_w).max(1)) as usize;
        let pt = trace::port_trace(spec.cfg.dataflow, layer, &spec.cfg, max_cycles.max(1));
        let rd = tdir.join(format!("{base}_sram_read.csv"));
        std::fs::write(&rd, pt.sram_read_csv())?;
        out.push(rd);
        let wr = tdir.join(format!("{base}_sram_write.csv"));
        std::fs::write(&wr, pt.sram_write_csv())?;
        out.push(wr);
    }
    Ok(out)
}

fn sanitize(name: &str) -> String {
    name.chars().map(|c| if c.is_alphanumeric() || c == '_' { c } else { '_' }).collect()
}

/// Execute each layer's GEMM view through the AOT systolic artifact and
/// compare against a Rust reference — proving the timed mapping computes
/// correct numerics. Layers larger than a budget are subsampled to keep
/// interpret-mode CPU execution tractable.
fn functional_check(spec: &RunSpec, tile: usize) -> Result<Vec<(String, f32)>> {
    let mut rt = Runtime::new(&crate::runtime::default_artifact_dir())?;
    let mut results = Vec::new();
    let mut rng = Rng::new(0x5CA1E);
    for layer in &spec.topology.layers {
        let (m, k, n) = layer.gemm_view();
        // cap the functional GEMM so the check stays fast; correctness
        // of the tiling is shape-independent
        let (m, k, n) = (m.min(96) as usize, k.min(96) as usize, n.min(96) as usize);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let got = rt.tiled_gemm(tile, &a, &b, m, k, n)?;
        let want = crate::rtl::matmul_ref(&a, &b, m, k, n);
        let mut max_err = 0f32;
        for (g, w) in got.iter().zip(&want) {
            max_err = max_err.max((g - w).abs() / (1.0 + w.abs()));
        }
        if max_err > 1e-3 {
            return Err(Error::Runtime(format!(
                "functional check failed on {}: max rel err {max_err}",
                layer.name
            )));
        }
        results.push((layer.name.clone(), max_err));
    }
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config;

    fn spec() -> RunSpec {
        let topo = Topology::new(
            "mini",
            vec![
                LayerShape::conv("c1", 12, 12, 3, 3, 4, 8, 1),
                LayerShape::fc("fc", 1, 64, 10),
            ],
        );
        let mut cfg = config::paper_default();
        cfg.array_h = 16;
        cfg.array_w = 16;
        RunSpec::new(cfg, topo)
    }

    #[test]
    fn run_without_outputs() {
        let out = run(&spec()).unwrap();
        assert_eq!(out.report.layers.len(), 2);
        assert!(out.files_written.is_empty());
        assert!(out.functional.is_empty());
    }

    #[test]
    fn run_writes_reports_and_traces() {
        let mut s = spec();
        let dir = std::env::temp_dir().join(format!("scale_sim_run_{}", std::process::id()));
        s.out_dir = Some(dir.clone());
        s.dump_traces = true;
        let out = run(&s).unwrap();
        assert!(out.files_written.iter().all(|f| f.exists()));
        // trace files exist per layer
        assert!(dir.join("traces/c1_sram_trace.csv").exists());
        let text = std::fs::read_to_string(dir.join("traces/c1_sram_trace.csv")).unwrap();
        assert!(text.starts_with("cycle,kind,address\n"));
        assert!(text.lines().count() > 10);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trace_limit_truncates() {
        let mut s = spec();
        let dir = std::env::temp_dir().join(format!("scale_sim_trunc_{}", std::process::id()));
        s.out_dir = Some(dir.clone());
        s.dump_traces = true;
        s.trace_limit = 5;
        run(&s).unwrap();
        let text = std::fs::read_to_string(dir.join("traces/c1_sram_trace.csv")).unwrap();
        assert_eq!(text.lines().count(), 6); // header + 5
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn invalid_cfg_rejected() {
        let mut s = spec();
        s.cfg.array_h = 0;
        assert!(run(&s).is_err());
    }

    #[test]
    fn parallel_and_serial_agree() {
        let mut s = spec();
        s.threads = 1;
        let a = run(&s).unwrap();
        s.threads = 8;
        let b = run(&s).unwrap();
        assert_eq!(a.report, b.report);
    }
}
