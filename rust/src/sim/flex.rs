//! Flexible-dataflow study (§IV-B question 3: "Are we missing out a lot
//! by employing fixed dataflows?").
//!
//! FlexFlow argues fixed dataflows waste energy/performance; the paper
//! uses SCALE-Sim to test that for systolic arrays and concludes the
//! loss is usually modest. This module quantifies it: simulate every
//! layer under all three dataflows, report the per-layer winner and the
//! topology-level saving of a (hypothetical, reconfiguration-free)
//! flexible accelerator over each fixed choice.

use crate::config::{ArchConfig, Topology};
use crate::dataflow::Dataflow;

use super::{LayerReport, Simulator};

/// Per-layer best-dataflow pick.
#[derive(Clone, Debug)]
pub struct FlexLayer {
    pub name: String,
    pub best: Dataflow,
    /// cycles under [os, ws, is].
    pub cycles: [u64; 3],
}

/// Whole-topology flexible-vs-fixed comparison.
#[derive(Clone, Debug)]
pub struct FlexReport {
    pub workload: String,
    pub layers: Vec<FlexLayer>,
    /// Total cycles under each fixed dataflow [os, ws, is].
    pub fixed_cycles: [u64; 3],
    /// Total cycles picking the best dataflow per layer.
    pub flexible_cycles: u64,
}

impl FlexReport {
    /// Speedup of per-layer flexibility over the best *fixed* dataflow —
    /// the paper's §IV-B answer ("might not lead to significant losses")
    /// predicts this stays small.
    pub fn speedup_over_best_fixed(&self) -> f64 {
        let best_fixed = self.fixed_cycles.iter().copied().min().unwrap_or(0);
        best_fixed as f64 / self.flexible_cycles as f64
    }

    /// Speedup over the *worst* fixed dataflow — the risk of freezing
    /// the wrong one.
    pub fn speedup_over_worst_fixed(&self) -> f64 {
        let worst = self.fixed_cycles.iter().copied().max().unwrap_or(0);
        worst as f64 / self.flexible_cycles as f64
    }

    /// How many layers each dataflow wins: [os, ws, is].
    pub fn wins(&self) -> [usize; 3] {
        let mut w = [0usize; 3];
        for l in &self.layers {
            w[l.best as usize] += 1;
        }
        w
    }
}

/// Run the flexible-dataflow study for one topology on one array config
/// (the config's own `dataflow` field is ignored — all three run).
pub fn flexible_study(cfg: &ArchConfig, topo: &Topology) -> FlexReport {
    let sims: Vec<Simulator> = Dataflow::ALL
        .iter()
        .map(|&df| Simulator::new(ArchConfig { dataflow: df, ..cfg.clone() }))
        .collect();
    let mut layers = Vec::with_capacity(topo.layers.len());
    let mut fixed = [0u64; 3];
    let mut flexible = 0u64;
    for layer in &topo.layers {
        let reports: Vec<LayerReport> = sims.iter().map(|s| s.run_layer(layer)).collect();
        let cycles = [
            reports[0].timing.cycles,
            reports[1].timing.cycles,
            reports[2].timing.cycles,
        ];
        for (f, c) in fixed.iter_mut().zip(cycles) {
            *f += c;
        }
        let best_i = (0..3).min_by_key(|&i| cycles[i]).unwrap_or(0);
        flexible += cycles[best_i];
        layers.push(FlexLayer {
            name: layer.name.clone(),
            best: Dataflow::ALL[best_i],
            cycles,
        });
    }
    FlexReport { workload: topo.name.clone(), layers, fixed_cycles: fixed, flexible_cycles: flexible }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::LayerShape;
    use crate::config;

    fn topo() -> Topology {
        Topology::new(
            "mix",
            vec![
                // WS-friendly: huge Npx, small weights
                LayerShape::conv("px_heavy", 64, 64, 1, 1, 8, 8, 1),
                // IS-friendly: tiny Npx, huge weights
                LayerShape::fc("w_heavy", 2, 1024, 1024),
                // OS-friendly: deep window
                LayerShape::conv("k_heavy", 12, 12, 3, 3, 128, 64, 1),
            ],
        )
    }

    #[test]
    fn flexible_never_slower_than_any_fixed() {
        let r = flexible_study(&config::paper_default(), &topo());
        for f in r.fixed_cycles {
            assert!(r.flexible_cycles <= f);
        }
        assert!(r.speedup_over_best_fixed() >= 1.0);
        assert!(r.speedup_over_worst_fixed() >= r.speedup_over_best_fixed());
    }

    #[test]
    fn per_layer_winners_are_minima() {
        let r = flexible_study(&config::paper_default(), &topo());
        for l in &r.layers {
            let min = *l.cycles.iter().min().unwrap();
            assert_eq!(l.cycles[l.best as usize], min, "{}", l.name);
        }
    }

    #[test]
    fn mixed_topology_has_multiple_winners() {
        // the constructed topology exercises at least two dataflows
        let cfg = ArchConfig { array_h: 16, array_w: 16, ..config::paper_default() };
        let r = flexible_study(&cfg, &topo());
        let distinct = r.wins().iter().filter(|&&w| w > 0).count();
        assert!(distinct >= 2, "wins={:?}", r.wins());
    }

    #[test]
    fn fixed_totals_sum_layer_cycles() {
        let r = flexible_study(&config::paper_default(), &topo());
        for i in 0..3 {
            let s: u64 = r.layers.iter().map(|l| l.cycles[i]).sum();
            assert_eq!(s, r.fixed_cycles[i]);
        }
    }
}
