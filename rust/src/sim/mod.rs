//! Per-layer / per-workload simulation orchestration — ties the dataflow
//! timing, memory system and energy model into the reports SCALE-Sim's
//! output files carry (Fig 1: "cycle accurate traffic traces and
//! simulation summary").

pub mod flex;

use crate::arch::LayerShape;
use crate::config::{ArchConfig, Topology};
use crate::dataflow::Timing;
use crate::energy::{EnergyBreakdown, EnergyModel};
use crate::memory::{self, BandwidthReport, DramTraffic};

/// Everything SCALE-Sim reports for one layer (§I: "latency, array
/// utilization, SRAM accesses, DRAM accesses, DRAM bandwidth").
#[derive(Clone, Debug, PartialEq)]
pub struct LayerReport {
    pub layer: LayerShape,
    pub timing: Timing,
    pub dram: DramTraffic,
    pub bandwidth: BandwidthReport,
    pub energy: EnergyBreakdown,
}

impl LayerReport {
    pub fn name(&self) -> &str {
        &self.layer.name
    }
}

/// Aggregated report for a whole topology.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadReport {
    pub workload: String,
    pub layers: Vec<LayerReport>,
}

impl WorkloadReport {
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.timing.cycles).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.layer.macs()).sum()
    }

    /// Runtime-weighted overall array utilization. Returns `0.0` (not
    /// NaN) for degenerate inputs: an empty/zero-cycle topology or a
    /// zero-PE array.
    pub fn overall_utilization(&self, total_pes: u64) -> f64 {
        let denom = total_pes * self.total_cycles();
        if denom == 0 {
            return 0.0;
        }
        self.total_macs() as f64 / denom as f64
    }

    pub fn total_dram(&self) -> DramTraffic {
        let mut t = DramTraffic::default();
        for l in &self.layers {
            t.ifmap_bytes += l.dram.ifmap_bytes;
            t.filter_bytes += l.dram.filter_bytes;
            t.ofmap_bytes += l.dram.ofmap_bytes;
        }
        t
    }

    pub fn total_energy(&self) -> EnergyBreakdown {
        let mut e = EnergyBreakdown::default();
        for l in &self.layers {
            e.compute_mj += l.energy.compute_mj;
            e.sram_mj += l.energy.sram_mj;
            e.dram_mj += l.energy.dram_mj;
        }
        e
    }

    /// Workload-level average DRAM read bandwidth (bytes/cycle) — the
    /// quantity Fig 7 sweeps against scratchpad size. Returns `0.0`
    /// (not NaN) for an empty/zero-cycle topology.
    pub fn avg_dram_read_bw(&self) -> f64 {
        let cycles = self.total_cycles();
        if cycles == 0 {
            return 0.0;
        }
        self.total_dram().read_bytes() as f64 / cycles as f64
    }

    /// Peak per-layer stall-free read bandwidth across the workload.
    pub fn peak_dram_read_bw(&self) -> f64 {
        self.layers
            .iter()
            .map(|l| l.bandwidth.peak_read_bw)
            .fold(0.0, f64::max)
    }
}

/// The **legacy** simulator facade: one architecture configuration,
/// reused across layers / topologies. Cheap to clone (configs are plain
/// data).
///
/// New code should prefer [`crate::engine::Engine`], which produces
/// bit-identical [`LayerReport`]s (asserted by the equivalence suite)
/// while adding pluggable fidelity backends and memoization. `Simulator`
/// is retained as the direct, cache-free analytical reference the engine
/// is validated against.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub cfg: ArchConfig,
    pub energy_model: EnergyModel,
}

impl Simulator {
    pub fn new(cfg: ArchConfig) -> Self {
        Simulator { cfg, energy_model: EnergyModel::default() }
    }

    /// Simulate one layer under the configured dataflow.
    pub fn run_layer(&self, layer: &LayerShape) -> LayerReport {
        let df = self.cfg.dataflow;
        let timing = df.timing(layer, self.cfg.array_h, self.cfg.array_w);
        let (dram, bandwidth) = memory::simulate(df, layer, &self.cfg);
        let energy =
            self.energy_model
                .layer_energy(layer.macs(), &timing, &dram, self.cfg.word_bytes);
        LayerReport { layer: layer.clone(), timing, dram, bandwidth, energy }
    }

    /// Simulate every layer of a topology in file order (§III-F:
    /// parallel branches serialize in listed order).
    pub fn run_topology(&self, topo: &Topology) -> WorkloadReport {
        WorkloadReport {
            workload: topo.name.clone(),
            layers: topo.layers.iter().map(|l| self.run_layer(l)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config;
    use crate::dataflow::Dataflow;

    fn sim(df: Dataflow) -> Simulator {
        let mut cfg = config::paper_default();
        cfg.dataflow = df;
        cfg.array_h = 16;
        cfg.array_w = 16;
        Simulator::new(cfg)
    }

    fn topo() -> Topology {
        Topology::new(
            "t",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::conv("c2", 14, 14, 3, 3, 8, 16, 1),
                LayerShape::fc("fc", 1, 256, 10),
            ],
        )
    }

    #[test]
    fn workload_totals_sum_layers() {
        let s = sim(Dataflow::Os);
        let r = s.run_topology(&topo());
        assert_eq!(r.layers.len(), 3);
        let cyc: u64 = r.layers.iter().map(|l| l.timing.cycles).sum();
        assert_eq!(r.total_cycles(), cyc);
        assert_eq!(r.total_macs(), topo().total_macs());
    }

    #[test]
    fn utilization_in_unit_interval() {
        for df in Dataflow::ALL {
            let s = sim(df);
            let r = s.run_topology(&topo());
            let u = r.overall_utilization(s.cfg.total_pes());
            assert!(u > 0.0 && u <= 1.0, "{df}: {u}");
        }
    }

    #[test]
    fn energy_totals_consistent() {
        let s = sim(Dataflow::Ws);
        let r = s.run_topology(&topo());
        let sum: f64 = r.layers.iter().map(|l| l.energy.total_mj()).sum();
        assert!((r.total_energy().total_mj() - sum).abs() < 1e-12);
    }

    #[test]
    fn layer_report_matches_direct_calls() {
        let s = sim(Dataflow::Is);
        let l = LayerShape::conv("c", 12, 12, 3, 3, 4, 4, 1);
        let rep = s.run_layer(&l);
        assert_eq!(rep.timing, Dataflow::Is.timing(&l, 16, 16));
        assert_eq!(rep.dram, memory::simulate(Dataflow::Is, &l, &s.cfg).0);
    }

    #[test]
    fn avg_bw_definition() {
        let s = sim(Dataflow::Os);
        let r = s.run_topology(&topo());
        let expect = r.total_dram().read_bytes() as f64 / r.total_cycles() as f64;
        assert!((r.avg_dram_read_bw() - expect).abs() < 1e-12);
    }

    #[test]
    fn empty_topology_reports_zero_not_nan() {
        // regression: these divided by zero (NaN) before the guard
        let r = WorkloadReport { workload: "empty".into(), layers: Vec::new() };
        assert_eq!(r.total_cycles(), 0);
        assert_eq!(r.overall_utilization(128 * 128), 0.0);
        assert_eq!(r.avg_dram_read_bw(), 0.0);
        assert_eq!(r.peak_dram_read_bw(), 0.0);
        assert!(!r.overall_utilization(0).is_nan());
    }

    #[test]
    fn zero_pes_reports_zero_not_nan() {
        let s = sim(Dataflow::Os);
        let r = s.run_topology(&topo());
        let u = r.overall_utilization(0);
        assert_eq!(u, 0.0);
        assert!(!u.is_nan());
    }
}
