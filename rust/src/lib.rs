//! # SCALE-Sim (Rust reproduction)
//!
//! A cycle-accurate, configurable systolic-array DNN accelerator simulator
//! reproducing *SCALE-Sim: Systolic CNN Accelerator Simulator* (Samajdar
//! et al., 2018), built as the Layer-3 coordinator of a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`).
//!
//! The simulator follows the paper's inside-out methodology (§III-E):
//! dataflows emit cycle-stamped SRAM read/write address traces for a
//! never-stalling array; traces are parsed into runtime, utilization and
//! SRAM traffic; the double-buffered scratchpad model derives DRAM traffic
//! and the stall-free bandwidth requirement; the energy model prices the
//! access counts.
//!
//! Module map (paper section in parens):
//!
//! * [`arch`]     — layer geometry / workload shapes (Table II)
//! * [`config`]   — `.cfg` + topology `.csv` front end (Table I, II)
//! * [`dataflow`] — OS / WS / IS analytical cycle models (§III-B)
//! * [`trace`]    — cycle-accurate SRAM address trace generators (§III-E)
//! * [`memory`]   — double-buffered scratchpads, DRAM traffic + bandwidth (§III-C)
//! * [`dram`]     — banked DRAM timing substrate (DRAMSim2 stand-in, §III-D)
//! * [`energy`]   — access-cost energy model (Fig 6)
//! * [`rtl`]      — cycle-level PE-grid simulator used for validation (Fig 4)
//! * [`scaleout`] — scale-up vs scale-out study engine (§IV-E)
//! * [`sim`]      — per-layer simulation -> [`sim::LayerReport`]
//! * [`sweep`]    — multi-threaded design-space sweeps (§IV)
//! * [`report`]   — csv / markdown output writers (§III-F)
//! * [`runtime`]  — PJRT client executing the AOT Pallas/JAX artifacts
//! * [`coordinator`] — run orchestration: jobs, workers, output dirs
//! * [`util`]     — rng, mini property-test harness, bench timing, csv

pub mod arch;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod dram;
pub mod energy;
pub mod memory;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod scaleout;
pub mod sim;
pub mod sweep;
pub mod trace;
pub mod util;

pub use arch::LayerShape;
pub use config::{ArchConfig, Topology};
pub use dataflow::Dataflow;
pub use sim::{LayerReport, Simulator, WorkloadReport};

/// Library-level error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("config parse error: {0}")]
    Config(String),
    #[error("topology parse error: {0}")]
    Topology(String),
    #[error("invalid layer {name}: {reason}")]
    InvalidLayer { name: String, reason: String },
    #[error("runtime error: {0}")]
    Runtime(String),
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, Error>;
