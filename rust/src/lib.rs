//! # SCALE-Sim (Rust reproduction)
//!
//! A cycle-accurate, configurable systolic-array DNN accelerator simulator
//! reproducing *SCALE-Sim: Systolic CNN Accelerator Simulator* (Samajdar
//! et al., 2018), built as the Layer-3 coordinator of a three-layer
//! Rust + JAX + Pallas stack (see `DESIGN.md`).
//!
//! The simulator follows the paper's inside-out methodology (§III-E):
//! dataflows emit cycle-stamped SRAM read/write address traces for a
//! never-stalling array; traces are parsed into runtime, utilization and
//! SRAM traffic; the double-buffered scratchpad model derives DRAM traffic
//! and the stall-free bandwidth requirement; the energy model prices the
//! access counts.
//!
//! ## Entry point: the `engine` façade
//!
//! All simulation — single runs, design-space sweeps, validation — goes
//! through [`engine::Engine`], built with the fluent
//! [`engine::EngineBuilder`]:
//!
//! ```text
//! let engine = Engine::builder()
//!     .dataflow(Dataflow::Ws)
//!     .array(32, 32)
//!     .backend(BackendKind::Analytical)   // or TraceDriven / Rtl
//!     .build()?;
//! let report = engine.run_topology(&topo);          // one workload
//! let sweep  = engine.sweep()                       // memoized grid
//!     .workloads(&topos)
//!     .dataflows(&Dataflow::ALL)
//!     .square_arrays(&[128, 64, 32, 16, 8])
//!     .run();
//! ```
//!
//! The engine dispatches per-layer simulation to a pluggable
//! [`engine::Backend`] (analytical closed forms, cycle-accurate trace
//! generation, or the cycle-level RTL grid — all cycle-exact with each
//! other) and memoizes per-(config, layer-shape) results — with
//! in-flight deduplication, so concurrent misses on one key compute it
//! once — so sweep grid points sharing layers never re-simulate. The
//! pre-engine entry points ([`sim::Simulator`], [`coordinator::run`],
//! the `sweep::*_sweep` functions) remain as thin deprecated shims.
//!
//! ## Workloads: the typed operator IR
//!
//! Workloads enter through [`workload::Workload`] — a typed operator
//! graph (`Conv2d` with stride/dilation/groups, `Gemm`, `FullyConnected`,
//! `Pool`) built fluently or parsed from csv (legacy Table-II conv
//! format *or* SCALE-Sim-v2 style `M, N, K` GEMM format, sniffed by
//! [`workload::Workload::from_file`]). [`workload::Workload::lower`]
//! maps every op onto the engine's [`LayerShape`] GEMM tiles (im2col
//! view for convs, direct for GEMM/FC), so one IR drives all three
//! backends unchanged, and the memo cache keys on the lowered tile —
//! a pointwise conv and its equivalent GEMM share one entry.
//! [`config::Topology`] remains as the lowered form (and its csv parse
//! as a deprecated shim routed through the IR, bit-identical).
//!
//! ## Simulation as a service: the `server` subsystem
//!
//! [`server`] runs the engine as a long-lived TCP service
//! (`scale-sim serve`): a JSON-lines protocol ([`server::proto`]), a
//! bounded job queue with blocking backpressure ([`server::queue`]), a
//! worker pool sharing **one** process-wide memo cache, and a
//! persistent result store ([`server::store`]) that pre-warms the cache
//! across restarts. `scale-sim client` submits jobs; `scale-sim
//! bench-serve` is the closed-loop load generator (`BENCH_serve.json`).
//!
//! Module map (paper section in parens):
//!
//! * [`arch`]     — layer geometry / lowered workload tiles (Table II)
//! * [`workload`] — **typed operator IR**: `Conv2d`/`Gemm`/`FC`/`Pool`
//!   graphs built fluently or parsed from conv/GEMM csv, lowered onto
//!   the engine's Table-II GEMM tiles
//! * [`config`]   — `.cfg` front end (Table I) + the deprecated
//!   `Topology` csv shim (now routed through [`workload`])
//! * [`dataflow`] — OS / WS / IS analytical cycle models (§III-B)
//! * [`engine`]   — **the public façade**: builder, pluggable fidelity
//!   backends, memoizing sweep grid (§IV methodology)
//! * [`trace`]    — cycle-accurate SRAM address trace generators (§III-E)
//! * [`memory`]   — double-buffered scratchpads, DRAM traffic + bandwidth (§III-C)
//! * [`obs`]      — **two-timeline observability**: cycle-stamped span
//!   traces (Chrome trace-event JSON) + a metrics registry with
//!   Prometheus text exposition (`scale-sim profile`, `client metrics`)
//! * [`dram`]     — banked DRAM timing substrate (DRAMSim2 stand-in, §III-D)
//! * [`dse`]      — **resumable DSE campaigns** (`scale-sim dse`): axis
//!   specs, objective extraction, Pareto frontiers, checkpoint/resume
//!   journal, local or shard-over-serve execution (§IV as a product)
//! * [`energy`]   — access-cost energy model (Fig 6)
//! * [`rtl`]      — cycle-level PE-grid simulator used for validation (Fig 4)
//! * [`scaleout`] — scale-up vs scale-out study engine (§IV-E)
//! * [`server`]   — `scale-sim serve`: TCP job server, worker pool,
//!   shared memo cache, persistent result store
//! * [`sim`]      — legacy per-layer facade -> [`sim::LayerReport`] (shim)
//! * [`sweep`]    — thread pool + deprecated sweep shims (§IV)
//! * [`report`]   — csv / markdown output writers (§III-F)
//! * [`runtime`]  — functional executor for the AOT Pallas/JAX artifacts
//! * [`coordinator`] — legacy run orchestration (shim over `engine`)
//! * [`analysis`] — **in-tree static analysis** (`scale-sim lint`):
//!   determinism / lock-discipline / shim-boundary / panic-hygiene /
//!   golden-bless rules over the repo's own sources, ratcheted through
//!   the checked-in `lint.baseline`
//! * [`util`]     — rng, mini property-test harness, bench timing, csv

pub mod analysis;
pub mod arch;
pub mod config;
pub mod coordinator;
pub mod dataflow;
pub mod dram;
pub mod dse;
pub mod energy;
pub mod engine;
pub mod memory;
pub mod obs;
pub mod report;
pub mod rtl;
pub mod runtime;
pub mod scaleout;
pub mod server;
pub mod sim;
pub mod sweep;
pub mod trace;
pub mod util;
pub mod workload;

pub use arch::LayerShape;
pub use config::{ArchConfig, Topology};
pub use dataflow::Dataflow;
pub use engine::{Backend, BackendKind, Engine, EngineBuilder};
pub use sim::{LayerReport, Simulator, WorkloadReport};
pub use workload::{Op, Workload};

/// Library-level error type (hand-rolled: `thiserror` is unavailable in
/// the offline build).
#[derive(Debug)]
pub enum Error {
    Config(String),
    Topology(String),
    InvalidLayer { name: String, reason: String },
    Workload(String),
    Runtime(String),
    Dse(String),
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Config(m) => write!(f, "config parse error: {m}"),
            Error::Topology(m) => write!(f, "topology parse error: {m}"),
            Error::InvalidLayer { name, reason } => {
                write!(f, "invalid layer {name}: {reason}")
            }
            Error::Workload(m) => write!(f, "workload error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Dse(m) => write!(f, "dse error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_matches_thiserror_era_format() {
        assert_eq!(Error::Config("x".into()).to_string(), "config parse error: x");
        assert_eq!(
            Error::InvalidLayer { name: "c1".into(), reason: "bad".into() }.to_string(),
            "invalid layer c1: bad"
        );
        let io: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(io.to_string().starts_with("io error:"));
    }
}
