//! Typed workload IR — the operator-level front end that replaced the
//! raw Table-II conv csv as the way workloads enter the simulator.
//!
//! The paper's case studies span vision, speech, text and games, but the
//! original front end could only express one thing: a convolution row.
//! Users encoded everything else (FC, RNN, attention) by hand as conv
//! special cases (§III-A). This module makes those encodings an internal
//! *lowering* concern instead of a user-facing one:
//!
//! * [`Op`] — a typed operator: `Conv2d` (with stride / dilation /
//!   groups, so depthwise and grouped convs are first-class), `Gemm`,
//!   `FullyConnected`, `Pool`, plus `TableII` for raw legacy rows.
//! * [`Workload`] — a named, ordered operator graph (§III-F: parallel
//!   branches serialize in listed order), built fluently with
//!   [`Workload::builder`] or parsed from csv ([`Workload::from_file`]
//!   sniffs Table-II conv csv vs SCALE-Sim-v2 style GEMM csv).
//! * [`Workload::lower`] — the lowering pass: every op maps onto the
//!   engine's [`LayerShape`] GEMM tiles (im2col view for convs, direct
//!   `(M, K, N)` for GEMM/FC), producing the [`Topology`] all three
//!   engine backends consume unchanged.
//!
//! ## Lowering rules (and what they guarantee)
//!
//! | op | lowered tile(s) |
//! |---|---|
//! | `Conv2d` (groups=1) | one Table-II conv tile; dilation shrinks the ifmap by the dilation slack so OFMAP dims and the window tap count stay exact |
//! | `Conv2d` 1x1, stride 1 | **canonical GEMM tile** `(H*W, C, F)` — im2col of a pointwise conv is a pure reshape, so it lowers to the same encoding as an equivalent [`Op::Gemm`] and *shares its memo-cache entry* |
//! | `Conv2d` depthwise (groups = Cin = Cout) | one tile with `channels = C`, `num_filters = 1` — the Table-II depthwise convention the legacy csvs use (MAC count exact; per-channel OFMAP footprint approximated as one channel) |
//! | `Conv2d` grouped | one conv tile per group (`C/g` in, `F/g` out), serialized; identical groups share one memo-cache entry |
//! | `Gemm {m,k,n}` / `FullyConnected` | the canonical GEMM tile `conv(ifmap = M x 1 x K, 1x1 filter, N filters)` |
//! | `Pool` | single-filter window-reduction tile (`channels = C`, `num_filters = 1`), the same convention as depthwise |
//! | `TableII` | verbatim — **bit-identical** to the pre-IR parser, pinned by the equivalence suite |
//!
//! Because the engine's memo cache keys on the *lowered* tile (see
//! [`crate::engine`]'s cache docs), a pointwise conv and its equivalent
//! GEMM — or a legacy gemm-encoded csv row and a GEMM-csv row — hit the
//! same cache entry across sweeps and the server's shared cache.
//!
//! ```text
//! let wl = Workload::builder("attn_block")
//!     .gemm("qkv", 128, 512, 1536)
//!     .conv2d("pw", Conv2d { ifmap_h: 14, ifmap_w: 14, in_channels: 64,
//!                            out_channels: 128, ..Conv2d::default() })
//!     .pool("p", 14, 14, 128, 2, 2)
//!     .build()?;
//! let report = engine.run_workload(&wl)?;   // = engine.run(&wl.lower()?)
//! ```

mod csv;

use crate::arch::LayerShape;
use crate::config::Topology;
use crate::util::json::Json;
use crate::{Error, Result};

/// A 2-D convolution operator. Construct with struct-update syntax over
/// [`Conv2d::default`] (kernel 1x1, stride/dilation/groups all 1):
///
/// ```text
/// Conv2d { ifmap_h: 224, ifmap_w: 224, in_channels: 3, out_channels: 64,
///          kernel_h: 7, kernel_w: 7, stride: 2, ..Conv2d::default() }
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Conv2d {
    pub ifmap_h: u64,
    pub ifmap_w: u64,
    pub in_channels: u64,
    pub out_channels: u64,
    pub kernel_h: u64,
    pub kernel_w: u64,
    /// Stride, same in both dims (as in the original tool).
    pub stride: u64,
    /// Kernel dilation; lowered by shrinking the ifmap by the dilation
    /// slack `(k-1)(d-1)` so OFMAP dims and MAC count stay exact.
    pub dilation: u64,
    /// Channel groups. `groups == in_channels == out_channels` is
    /// depthwise; other values split the conv into independent
    /// per-group tiles.
    pub groups: u64,
}

impl Default for Conv2d {
    fn default() -> Self {
        Conv2d {
            ifmap_h: 1,
            ifmap_w: 1,
            in_channels: 1,
            out_channels: 1,
            kernel_h: 1,
            kernel_w: 1,
            stride: 1,
            dilation: 1,
            groups: 1,
        }
    }
}

impl Conv2d {
    /// Dilated kernel extent.
    pub fn effective_kernel(&self) -> (u64, u64) {
        (
            (self.kernel_h - 1) * self.dilation + 1,
            (self.kernel_w - 1) * self.dilation + 1,
        )
    }

    /// True when this conv is a pointwise (1x1, stride 1, dense) conv
    /// whose im2col is a pure reshape — lowered to the canonical GEMM
    /// encoding.
    pub fn is_pointwise(&self) -> bool {
        self.kernel_h == 1
            && self.kernel_w == 1
            && self.stride == 1
            && self.dilation == 1
            && self.groups == 1
    }

    /// True for the depthwise case (one filter per input channel).
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.groups == self.in_channels && self.out_channels == self.in_channels
    }
}

/// One typed operator.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Op {
    Conv2d(Conv2d),
    /// Dense matrix product `(m x k) @ (k x n)`.
    Gemm { m: u64, k: u64, n: u64 },
    /// `batch x in_features -> out_features` (MV when `batch == 1`).
    FullyConnected { batch: u64, in_features: u64, out_features: u64 },
    /// Window reduction (max/avg pool — the timing model does not
    /// distinguish the reduction operator).
    Pool { ifmap_h: u64, ifmap_w: u64, channels: u64, window_h: u64, window_w: u64, stride: u64 },
    /// A raw legacy Table-II row, lowered verbatim (the compatibility
    /// path `Topology::parse` routes through).
    TableII(LayerShape),
}

impl Op {
    /// Short kind tag (also the `"type"` discriminator on the wire).
    pub fn kind(&self) -> &'static str {
        match self {
            Op::Conv2d(_) => "conv2d",
            Op::Gemm { .. } => "gemm",
            Op::FullyConnected { .. } => "fc",
            Op::Pool { .. } => "pool",
            Op::TableII(_) => "layer",
        }
    }

    /// Check the op's own invariants (dimension positivity, divisibility,
    /// kernel-fits-ifmap). Lowered tiles are additionally checked by
    /// [`LayerShape::validate`].
    pub fn validate(&self, name: &str) -> Result<()> {
        let bad = |reason: String| {
            Error::Workload(format!("op {name:?} ({}): {reason}", self.kind()))
        };
        match self {
            Op::Conv2d(c) => {
                if c.ifmap_h == 0
                    || c.ifmap_w == 0
                    || c.in_channels == 0
                    || c.out_channels == 0
                    || c.kernel_h == 0
                    || c.kernel_w == 0
                {
                    return Err(bad("all dimensions must be positive".into()));
                }
                if c.stride == 0 || c.dilation == 0 || c.groups == 0 {
                    return Err(bad("stride/dilation/groups must be positive".into()));
                }
                if c.in_channels % c.groups != 0 || c.out_channels % c.groups != 0 {
                    return Err(bad(format!(
                        "groups {} must divide in_channels {} and out_channels {}",
                        c.groups, c.in_channels, c.out_channels
                    )));
                }
                let (ekh, ekw) = c.effective_kernel();
                if ekh > c.ifmap_h || ekw > c.ifmap_w {
                    return Err(bad(format!(
                        "effective kernel {ekh}x{ekw} (dilation {}) larger than ifmap {}x{}",
                        c.dilation, c.ifmap_h, c.ifmap_w
                    )));
                }
                Ok(())
            }
            Op::Gemm { m, k, n } => {
                if *m == 0 || *k == 0 || *n == 0 {
                    return Err(bad("m, k, n must be positive".into()));
                }
                Ok(())
            }
            Op::FullyConnected { batch, in_features, out_features } => {
                if *batch == 0 || *in_features == 0 || *out_features == 0 {
                    return Err(bad("batch/in_features/out_features must be positive".into()));
                }
                Ok(())
            }
            Op::Pool { ifmap_h, ifmap_w, channels, window_h, window_w, stride } => {
                if *ifmap_h == 0 || *ifmap_w == 0 || *channels == 0 || *window_h == 0 || *window_w == 0
                {
                    return Err(bad("all dimensions must be positive".into()));
                }
                if *stride == 0 {
                    return Err(bad("stride must be positive".into()));
                }
                if window_h > ifmap_h || window_w > ifmap_w {
                    return Err(bad(format!(
                        "window {window_h}x{window_w} larger than ifmap {ifmap_h}x{ifmap_w}"
                    )));
                }
                Ok(())
            }
            Op::TableII(l) => l.validate(),
        }
    }

    /// Lower this op to its engine tiles (see the module docs for the
    /// per-op rules). Validates the op and every produced tile.
    pub fn lower(&self, name: &str) -> Result<Vec<LayerShape>> {
        self.validate(name)?;
        let tiles = match self {
            Op::Conv2d(c) => {
                if c.is_pointwise() {
                    // im2col of a 1x1/stride-1 conv is a pure reshape:
                    // lower straight to the canonical GEMM tile so it
                    // shares a memo-cache entry with an equivalent Gemm
                    vec![LayerShape::gemm(
                        name,
                        c.ifmap_h * c.ifmap_w,
                        c.in_channels,
                        c.out_channels,
                    )]
                } else {
                    // fold dilation into the ifmap extent: the Table-II
                    // encoding has no dilation field, but shrinking the
                    // ifmap by the slack keeps OFMAP dims and the window
                    // tap count (hence MACs) exact
                    let (ekh, ekw) = c.effective_kernel();
                    let ifh = c.ifmap_h - (ekh - c.kernel_h);
                    let ifw = c.ifmap_w - (ekw - c.kernel_w);
                    if c.groups == 1 {
                        vec![LayerShape::conv(
                            name,
                            ifh,
                            ifw,
                            c.kernel_h,
                            c.kernel_w,
                            c.in_channels,
                            c.out_channels,
                            c.stride,
                        )]
                    } else if c.is_depthwise() {
                        // Table-II depthwise convention (what the legacy
                        // mobilenet csv rows use): all channels in one
                        // tile, a single filter
                        vec![LayerShape::conv(
                            name,
                            ifh,
                            ifw,
                            c.kernel_h,
                            c.kernel_w,
                            c.in_channels,
                            1,
                            c.stride,
                        )]
                    } else {
                        // grouped conv: independent per-group tiles,
                        // serialized (§III-F); identical shapes share
                        // one memo-cache entry
                        (0..c.groups)
                            .map(|g| {
                                LayerShape::conv(
                                    &format!("{name}.g{g}"),
                                    ifh,
                                    ifw,
                                    c.kernel_h,
                                    c.kernel_w,
                                    c.in_channels / c.groups,
                                    c.out_channels / c.groups,
                                    c.stride,
                                )
                            })
                            .collect()
                    }
                }
            }
            Op::Gemm { m, k, n } => vec![LayerShape::gemm(name, *m, *k, *n)],
            Op::FullyConnected { batch, in_features, out_features } => {
                vec![LayerShape::gemm(name, *batch, *in_features, *out_features)]
            }
            Op::Pool { ifmap_h, ifmap_w, channels, window_h, window_w, stride } => {
                // single-filter window-reduction tile (depthwise
                // convention): per-pixel window cost exact, OFMAP
                // footprint approximated as one channel
                vec![LayerShape::conv(
                    name, *ifmap_h, *ifmap_w, *window_h, *window_w, *channels, 1, *stride,
                )]
            }
            Op::TableII(l) => vec![LayerShape { name: name.to_string(), ..l.clone() }],
        };
        for t in &tiles {
            t.validate()?;
        }
        Ok(tiles)
    }
}

/// One named node of the operator graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct OpNode {
    pub name: String,
    pub op: Op,
}

impl OpNode {
    pub fn new(name: &str, op: Op) -> Self {
        OpNode { name: name.to_string(), op }
    }

    /// Wire/JSON form: the op's fields plus `"type"` and `"name"`.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("type", Json::str(self.op.kind())), ("name", Json::str(&self.name))];
        match &self.op {
            Op::Conv2d(c) => {
                fields.push(("ifmap_h", Json::u64(c.ifmap_h)));
                fields.push(("ifmap_w", Json::u64(c.ifmap_w)));
                fields.push(("in_channels", Json::u64(c.in_channels)));
                fields.push(("out_channels", Json::u64(c.out_channels)));
                fields.push(("kernel_h", Json::u64(c.kernel_h)));
                fields.push(("kernel_w", Json::u64(c.kernel_w)));
                fields.push(("stride", Json::u64(c.stride)));
                fields.push(("dilation", Json::u64(c.dilation)));
                fields.push(("groups", Json::u64(c.groups)));
            }
            Op::Gemm { m, k, n } => {
                fields.push(("m", Json::u64(*m)));
                fields.push(("k", Json::u64(*k)));
                fields.push(("n", Json::u64(*n)));
            }
            Op::FullyConnected { batch, in_features, out_features } => {
                fields.push(("batch", Json::u64(*batch)));
                fields.push(("in_features", Json::u64(*in_features)));
                fields.push(("out_features", Json::u64(*out_features)));
            }
            Op::Pool { ifmap_h, ifmap_w, channels, window_h, window_w, stride } => {
                fields.push(("ifmap_h", Json::u64(*ifmap_h)));
                fields.push(("ifmap_w", Json::u64(*ifmap_w)));
                fields.push(("channels", Json::u64(*channels)));
                fields.push(("window_h", Json::u64(*window_h)));
                fields.push(("window_w", Json::u64(*window_w)));
                fields.push(("stride", Json::u64(*stride)));
            }
            Op::TableII(l) => {
                fields.push(("ifmap_h", Json::u64(l.ifmap_h)));
                fields.push(("ifmap_w", Json::u64(l.ifmap_w)));
                fields.push(("filt_h", Json::u64(l.filt_h)));
                fields.push(("filt_w", Json::u64(l.filt_w)));
                fields.push(("channels", Json::u64(l.channels)));
                fields.push(("num_filters", Json::u64(l.num_filters)));
                fields.push(("stride", Json::u64(l.stride)));
            }
        }
        Json::obj(fields)
    }

    /// Parse the wire/JSON form. `kernel_w`/`window_w` default to their
    /// `_h` twin; `stride`/`dilation`/`groups` default to 1 (pool stride
    /// defaults to the window — the common non-overlapping pool).
    pub fn from_json(j: &Json) -> std::result::Result<OpNode, String> {
        let ty = j.str_field("type").ok_or("op needs a \"type\" field")?;
        let name = j.str_field("name").unwrap_or("op").to_string();
        let need = |k: &str| {
            j.u64_field(k)
                .ok_or_else(|| format!("op {name:?} ({ty}): missing/invalid u64 field {k:?}"))
        };
        // optional fields default only when ABSENT; a present-but-invalid
        // value (float, string, negative) is an error, never a silent
        // fallback that would simulate a different op than submitted
        let opt = |k: &str, default: u64| -> std::result::Result<u64, String> {
            match j.get(k) {
                None => Ok(default),
                Some(v) => v
                    .as_u64()
                    .ok_or_else(|| format!("op {name:?} ({ty}): invalid u64 field {k:?}")),
            }
        };
        let op = match ty {
            "conv2d" => {
                let kernel_h = need("kernel_h")?;
                Op::Conv2d(Conv2d {
                    ifmap_h: need("ifmap_h")?,
                    ifmap_w: need("ifmap_w")?,
                    in_channels: need("in_channels")?,
                    out_channels: need("out_channels")?,
                    kernel_h,
                    kernel_w: opt("kernel_w", kernel_h)?,
                    stride: opt("stride", 1)?,
                    dilation: opt("dilation", 1)?,
                    groups: opt("groups", 1)?,
                })
            }
            "gemm" => Op::Gemm { m: need("m")?, k: need("k")?, n: need("n")? },
            "fc" => Op::FullyConnected {
                batch: need("batch")?,
                in_features: need("in_features")?,
                out_features: need("out_features")?,
            },
            "pool" => {
                let window_h = need("window_h")?;
                Op::Pool {
                    ifmap_h: need("ifmap_h")?,
                    ifmap_w: need("ifmap_w")?,
                    channels: need("channels")?,
                    window_h,
                    window_w: opt("window_w", window_h)?,
                    stride: opt("stride", window_h)?,
                }
            }
            "layer" => Op::TableII(LayerShape {
                name: name.clone(),
                ifmap_h: need("ifmap_h")?,
                ifmap_w: need("ifmap_w")?,
                filt_h: need("filt_h")?,
                filt_w: need("filt_w")?,
                channels: need("channels")?,
                num_filters: need("num_filters")?,
                stride: need("stride")?,
            }),
            other => {
                return Err(format!(
                    "unknown op type {other:?} (conv2d|gemm|fc|pool|layer)"
                ))
            }
        };
        Ok(OpNode { name, op })
    }
}

/// A named, ordered operator graph — the typed workload the front end
/// hands the engine (after [`Workload::lower`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Workload {
    pub name: String,
    pub nodes: Vec<OpNode>,
}

impl Workload {
    /// Construct without validation (builder/parsers validate).
    pub fn new(name: &str, nodes: Vec<OpNode>) -> Self {
        Workload { name: name.to_string(), nodes }
    }

    /// Start a fluent workload definition.
    pub fn builder(name: &str) -> WorkloadBuilder {
        WorkloadBuilder { name: name.to_string(), nodes: Vec::new() }
    }

    /// Wrap an already-lowered [`Topology`] as raw Table-II ops (how the
    /// built-in conv workloads enter the IR).
    pub fn from_topology(topo: &Topology) -> Workload {
        Workload {
            name: topo.name.clone(),
            nodes: topo
                .layers
                .iter()
                .map(|l| OpNode::new(&l.name, Op::TableII(l.clone())))
                .collect(),
        }
    }

    /// Parse a legacy Table-II conv csv (strict per-row arity; errors
    /// carry `src:line`). Rows become [`Op::TableII`] nodes, so lowering
    /// is bit-identical to the pre-IR parser.
    pub fn parse_conv_csv(name: &str, src: &str, text: &str) -> Result<Workload> {
        csv::parse_conv_csv(name, src, text)
    }

    /// Parse a SCALE-Sim-v2 style GEMM csv (`Layer, M, N, K` rows).
    pub fn parse_gemm_csv(name: &str, src: &str, text: &str) -> Result<Workload> {
        csv::parse_gemm_csv(name, src, text)
    }

    /// Parse csv text, sniffing the format by row arity (8 cells =
    /// Table-II conv, 4 cells = GEMM).
    pub fn parse_csv(name: &str, src: &str, text: &str) -> Result<Workload> {
        csv::parse_auto(name, src, text)
    }

    /// Read and parse a workload csv (conv or GEMM format, sniffed);
    /// name = file stem, errors carry the file path.
    pub fn from_file(path: &std::path::Path) -> Result<Workload> {
        let text = std::fs::read_to_string(path)?;
        let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("workload");
        csv::parse_auto(name, &path.display().to_string(), &text)
    }

    /// Validate every op without lowering.
    pub fn validate(&self) -> Result<()> {
        if self.nodes.is_empty() {
            return Err(Error::Workload(format!("{}: no ops", self.name)));
        }
        for node in &self.nodes {
            node.op.validate(&node.name)?;
        }
        Ok(())
    }

    /// The lowering pass: map every op to its engine GEMM tiles, in
    /// graph order. The result is what [`crate::engine::Engine`] runs.
    pub fn lower(&self) -> Result<Topology> {
        if self.nodes.is_empty() {
            return Err(Error::Workload(format!("{}: no ops", self.name)));
        }
        let mut layers = Vec::with_capacity(self.nodes.len());
        for node in &self.nodes {
            layers.extend(node.op.lower(&node.name)?);
        }
        Ok(Topology::new(&self.name, layers))
    }

    /// Total MACs of the lowered workload.
    pub fn total_macs(&self) -> Result<u64> {
        Ok(self.lower()?.total_macs())
    }

    /// Wire/JSON form: `{"name":..., "ops":[...]}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("ops", Json::Arr(self.nodes.iter().map(OpNode::to_json).collect())),
        ])
    }

    /// Parse the wire/JSON form.
    pub fn from_json(j: &Json) -> std::result::Result<Workload, String> {
        let name = j.str_field("name").unwrap_or("workload");
        let ops = j.get("ops").and_then(Json::as_arr).ok_or("workload needs an \"ops\" array")?;
        let mut nodes = Vec::with_capacity(ops.len());
        for item in ops {
            nodes.push(OpNode::from_json(item)?);
        }
        Ok(Workload::new(name, nodes))
    }
}

/// Fluent [`Workload`] construction; every method appends one op.
pub struct WorkloadBuilder {
    name: String,
    nodes: Vec<OpNode>,
}

impl WorkloadBuilder {
    /// Append an arbitrary op.
    pub fn op(mut self, name: &str, op: Op) -> Self {
        self.nodes.push(OpNode::new(name, op));
        self
    }

    /// Append a convolution (see [`Conv2d`] for struct-update
    /// construction of the spec).
    pub fn conv2d(self, name: &str, spec: Conv2d) -> Self {
        self.op(name, Op::Conv2d(spec))
    }

    /// Append a depthwise conv (square kernel) — `groups = channels`.
    pub fn depthwise(
        self,
        name: &str,
        ifmap_h: u64,
        ifmap_w: u64,
        channels: u64,
        kernel: u64,
        stride: u64,
    ) -> Self {
        self.conv2d(
            name,
            Conv2d {
                ifmap_h,
                ifmap_w,
                in_channels: channels,
                out_channels: channels,
                kernel_h: kernel,
                kernel_w: kernel,
                stride,
                groups: channels,
                ..Conv2d::default()
            },
        )
    }

    /// Append a GEMM `(m x k) @ (k x n)`.
    pub fn gemm(self, name: &str, m: u64, k: u64, n: u64) -> Self {
        self.op(name, Op::Gemm { m, k, n })
    }

    /// Append a fully-connected layer.
    pub fn fc(self, name: &str, batch: u64, in_features: u64, out_features: u64) -> Self {
        self.op(name, Op::FullyConnected { batch, in_features, out_features })
    }

    /// Append a pool with a square window (stride = window: the common
    /// non-overlapping pool).
    pub fn pool(
        self,
        name: &str,
        ifmap_h: u64,
        ifmap_w: u64,
        channels: u64,
        window: u64,
        stride: u64,
    ) -> Self {
        self.op(
            name,
            Op::Pool { ifmap_h, ifmap_w, channels, window_h: window, window_w: window, stride },
        )
    }

    /// Append a raw Table-II row (named by the shape's own name).
    pub fn layer(mut self, shape: LayerShape) -> Self {
        self.nodes.push(OpNode { name: shape.name.clone(), op: Op::TableII(shape) });
        self
    }

    /// Validate every op and finish.
    pub fn build(self) -> Result<Workload> {
        let w = Workload { name: self.name, nodes: self.nodes };
        w.validate()?;
        Ok(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_builds_and_lowers_in_order() {
        let w = Workload::builder("t")
            .conv2d(
                "c1",
                Conv2d {
                    ifmap_h: 16,
                    ifmap_w: 16,
                    in_channels: 4,
                    out_channels: 8,
                    kernel_h: 3,
                    kernel_w: 3,
                    ..Conv2d::default()
                },
            )
            .gemm("g", 32, 64, 16)
            .fc("fc", 1, 256, 10)
            .build()
            .unwrap();
        let t = w.lower().unwrap();
        assert_eq!(t.name, "t");
        assert_eq!(t.layers.len(), 3);
        assert_eq!(t.layers[0], LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1));
        assert_eq!(t.layers[1], LayerShape::gemm("g", 32, 64, 16));
        assert_eq!(t.layers[2], LayerShape::gemm("fc", 1, 256, 10));
    }

    #[test]
    fn pointwise_conv_lowers_to_the_canonical_gemm_tile() {
        let conv = Op::Conv2d(Conv2d {
            ifmap_h: 14,
            ifmap_w: 14,
            in_channels: 64,
            out_channels: 128,
            ..Conv2d::default()
        });
        let gemm = Op::Gemm { m: 14 * 14, k: 64, n: 128 };
        let a = conv.lower("pw").unwrap();
        let b = gemm.lower("pw").unwrap();
        assert_eq!(a, b, "pointwise conv and equivalent GEMM must lower identically");
        assert_eq!(a[0].gemm_view(), (196, 64, 128));
        assert!(a[0].is_gemm());
    }

    #[test]
    fn strided_pointwise_stays_a_conv_tile() {
        // a 1x1 conv with stride 2 samples the ifmap — NOT a reshape
        let op = Op::Conv2d(Conv2d {
            ifmap_h: 14,
            ifmap_w: 14,
            in_channels: 64,
            out_channels: 128,
            stride: 2,
            ..Conv2d::default()
        });
        let t = op.lower("s2").unwrap();
        assert_eq!(t[0], LayerShape::conv("s2", 14, 14, 1, 1, 64, 128, 2));
        assert_eq!(t[0].npx(), 49);
    }

    #[test]
    fn depthwise_lowers_to_the_table_ii_convention() {
        let t = Workload::builder("m")
            .depthwise("dw", 114, 114, 32, 3, 1)
            .build()
            .unwrap()
            .lower()
            .unwrap();
        // matches the legacy mobilenet dw rows: C channels, one filter
        assert_eq!(t.layers[0], LayerShape::conv("dw", 114, 114, 3, 3, 32, 1, 1));
    }

    #[test]
    fn grouped_conv_expands_per_group() {
        let op = Op::Conv2d(Conv2d {
            ifmap_h: 28,
            ifmap_w: 28,
            in_channels: 64,
            out_channels: 128,
            kernel_h: 3,
            kernel_w: 3,
            groups: 4,
            ..Conv2d::default()
        });
        let tiles = op.lower("gc").unwrap();
        assert_eq!(tiles.len(), 4);
        for (i, t) in tiles.iter().enumerate() {
            assert_eq!(t.name, format!("gc.g{i}"));
            assert_eq!((t.channels, t.num_filters), (16, 32));
        }
        // MAC count matches the dense formula divided by groups
        let total: u64 = tiles.iter().map(|t| t.macs()).sum();
        assert_eq!(total, 26 * 26 * (3 * 3 * 16) * 32 * 4);
    }

    #[test]
    fn dilation_preserves_ofmap_dims_and_macs() {
        let op = Op::Conv2d(Conv2d {
            ifmap_h: 32,
            ifmap_w: 32,
            in_channels: 8,
            out_channels: 16,
            kernel_h: 3,
            kernel_w: 3,
            dilation: 2,
            ..Conv2d::default()
        });
        let t = &op.lower("d2").unwrap()[0];
        // effective kernel 5x5 => ofmap 28x28; window stays 3*3*8 taps
        assert_eq!((t.ofmap_h(), t.ofmap_w()), (28, 28));
        assert_eq!(t.window(), 3 * 3 * 8);
        assert_eq!(t.macs(), 28 * 28 * 72 * 16);
    }

    #[test]
    fn pool_lowers_to_a_single_filter_tile() {
        let t = Workload::builder("p")
            .pool("mp", 16, 16, 32, 2, 2)
            .build()
            .unwrap()
            .lower()
            .unwrap();
        assert_eq!(t.layers[0], LayerShape::conv("mp", 16, 16, 2, 2, 32, 1, 2));
        assert_eq!(t.layers[0].npx(), 64);
    }

    #[test]
    fn invalid_ops_are_rejected_with_context() {
        let err = Op::Gemm { m: 0, k: 4, n: 4 }.validate("z").unwrap_err();
        assert!(err.to_string().contains("\"z\""), "{err}");
        assert!(Op::Conv2d(Conv2d {
            ifmap_h: 8,
            ifmap_w: 8,
            in_channels: 6,
            out_channels: 8,
            kernel_h: 3,
            groups: 4, // 4 does not divide 6
            ..Conv2d::default()
        })
        .validate("g")
        .is_err());
        assert!(Op::Conv2d(Conv2d {
            ifmap_h: 6,
            ifmap_w: 6,
            in_channels: 1,
            out_channels: 1,
            kernel_h: 3,
            kernel_w: 3,
            dilation: 4, // effective 9x9 > 6x6
            ..Conv2d::default()
        })
        .validate("d")
        .is_err());
        assert!(Workload::builder("e").build().is_err(), "empty workload");
    }

    #[test]
    fn op_json_round_trips() {
        let nodes = vec![
            OpNode::new(
                "c",
                Op::Conv2d(Conv2d {
                    ifmap_h: 16,
                    ifmap_w: 12,
                    in_channels: 4,
                    out_channels: 8,
                    kernel_h: 3,
                    kernel_w: 5,
                    stride: 2,
                    dilation: 2,
                    groups: 2,
                }),
            ),
            OpNode::new("g", Op::Gemm { m: 32, k: 64, n: 16 }),
            OpNode::new("f", Op::FullyConnected { batch: 1, in_features: 256, out_features: 10 }),
            OpNode::new(
                "p",
                Op::Pool { ifmap_h: 8, ifmap_w: 8, channels: 4, window_h: 2, window_w: 2, stride: 2 },
            ),
            OpNode::new("l", Op::TableII(LayerShape::conv("l", 8, 8, 3, 3, 2, 4, 1))),
        ];
        let w = Workload::new("rt", nodes);
        let wire = w.to_json().to_string();
        let back = Workload::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, w);
    }

    #[test]
    fn op_json_defaults_apply() {
        let j = Json::parse(
            r#"{"type":"conv2d","name":"c","ifmap_h":8,"ifmap_w":8,"in_channels":2,"out_channels":4,"kernel_h":3}"#,
        )
        .unwrap();
        let node = OpNode::from_json(&j).unwrap();
        match node.op {
            Op::Conv2d(c) => {
                assert_eq!(c.kernel_w, 3, "kernel_w defaults to kernel_h");
                assert_eq!((c.stride, c.dilation, c.groups), (1, 1, 1));
            }
            other => panic!("wrong op {other:?}"),
        }
        let j = Json::parse(
            r#"{"type":"pool","name":"p","ifmap_h":8,"ifmap_w":8,"channels":2,"window_h":2}"#,
        )
        .unwrap();
        match OpNode::from_json(&j).unwrap().op {
            Op::Pool { window_w, stride, .. } => {
                assert_eq!(window_w, 2);
                assert_eq!(stride, 2, "pool stride defaults to the window");
            }
            other => panic!("wrong op {other:?}"),
        }
        assert!(OpNode::from_json(&Json::parse(r#"{"type":"warp"}"#).unwrap()).is_err());
        assert!(OpNode::from_json(&Json::parse(r#"{"type":"gemm","m":1}"#).unwrap()).is_err());
        // a present-but-invalid optional field errors — it must never
        // silently default to a different op than the one submitted
        let bad = Json::parse(
            r#"{"type":"conv2d","name":"c","ifmap_h":8,"ifmap_w":8,"in_channels":2,"out_channels":4,"kernel_h":3,"stride":2.5}"#,
        )
        .unwrap();
        assert!(OpNode::from_json(&bad).is_err());
        let bad = Json::parse(
            r#"{"type":"pool","name":"p","ifmap_h":8,"ifmap_w":8,"channels":2,"window_h":2,"stride":"2"}"#,
        )
        .unwrap();
        assert!(OpNode::from_json(&bad).is_err());
    }

    #[test]
    fn from_topology_round_trips_through_lowering() {
        let topo = Topology::new(
            "t",
            vec![
                LayerShape::conv("c1", 16, 16, 3, 3, 4, 8, 1),
                LayerShape::gemm("g", 32, 64, 16),
            ],
        );
        let lowered = Workload::from_topology(&topo).lower().unwrap();
        assert_eq!(lowered, topo, "TableII wrapping must lower verbatim");
    }

    #[test]
    fn total_macs_matches_lowered_topology() {
        let w = Workload::builder("m").gemm("g", 8, 8, 8).gemm("h", 4, 4, 4).build().unwrap();
        assert_eq!(w.total_macs().unwrap(), 8 * 8 * 8 + 4 * 4 * 4);
    }
}
