//! Csv front ends for [`Workload`](super::Workload): the legacy Table-II
//! conv format and the SCALE-Sim-v2 style GEMM format, with strict
//! per-row validation (`src:line` in every error) and format sniffing.
//!
//! ## Table-II conv format (8 cells, legacy)
//!
//! ```text
//! Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width,
//! Channels, Num Filter, Strides,
//! Conv1, 224, 224, 7, 7, 3, 64, 2,
//! ```
//!
//! Rows become [`Op::TableII`] nodes, so lowering reproduces the
//! pre-IR `Topology::parse` bit-identically (pinned by the equivalence
//! suite).
//!
//! ## GEMM format (4 cells, SCALE-Sim v2 `mnk` style)
//!
//! ```text
//! Layer, M, N, K,
//! qkv_proj, 128, 1536, 512,
//! ```
//!
//! `M` = output rows (pixels/batch), `N` = output columns (filters),
//! `K` = contraction. Rows become [`Op::Gemm`] nodes (`m, k, n` =
//! `M, K, N`).
//!
//! Both formats tolerate `#` comments, blank lines and one trailing
//! comma; a header row is recognized (first row only) when **no** cell
//! after the layer name parses as a number — so a data row with a typo
//! is a loud error, never silently skipped as a header (the pre-IR
//! parser's bug).

use super::{Op, OpNode, Workload};
use crate::arch::LayerShape;
use crate::util::csv;
use crate::{Error, Result};

/// Cells per row in each supported format.
const CONV_CELLS: usize = 8;
const GEMM_CELLS: usize = 4;

/// A header row carries no numeric cell after the name column.
fn is_header(row: &[String]) -> bool {
    row.len() >= 2 && row[1..].iter().all(|c| c.parse::<u64>().is_err())
}

/// Numbered, comment-stripped rows; errors if the file holds none.
fn rows(name: &str, src: &str, text: &str) -> Result<Vec<(usize, Vec<String>)>> {
    let rows = csv::parse_numbered(text);
    if rows.is_empty() {
        return Err(Error::Workload(format!("{src}: no rows found (workload {name:?})")));
    }
    Ok(rows)
}

fn arity_error(src: &str, line: usize, want: usize, columns: &str, row: &[String]) -> Error {
    Error::Workload(format!(
        "{src}:{line}: expected {want} cells ({columns}), got {}: {row:?}",
        row.len()
    ))
}

fn cell_u64(src: &str, line: usize, row: &[String], i: usize, label: &str) -> Result<u64> {
    row[i].parse::<u64>().map_err(|_| {
        Error::Workload(format!(
            "{src}:{line}: cell {i} ({label}) is not a number: {:?}",
            row[i]
        ))
    })
}

/// Parse the legacy Table-II conv csv into raw [`Op::TableII`] nodes.
pub(super) fn parse_conv_csv(name: &str, src: &str, text: &str) -> Result<Workload> {
    parse_conv_rows(name, src, &rows(name, src, text)?)
}

fn parse_conv_rows(name: &str, src: &str, rows: &[(usize, Vec<String>)]) -> Result<Workload> {
    const COLUMNS: &str =
        "Layer, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides";
    let mut nodes = Vec::new();
    for (i, (line, row)) in rows.iter().enumerate() {
        if i == 0 && is_header(row) {
            continue;
        }
        if row.len() != CONV_CELLS {
            return Err(arity_error(src, *line, CONV_CELLS, COLUMNS, row));
        }
        let num = |idx: usize, label: &str| cell_u64(src, *line, row, idx, label);
        let shape = LayerShape {
            name: row[0].clone(),
            ifmap_h: num(1, "ifmap height")?,
            ifmap_w: num(2, "ifmap width")?,
            filt_h: num(3, "filter height")?,
            filt_w: num(4, "filter width")?,
            channels: num(5, "channels")?,
            num_filters: num(6, "num filters")?,
            stride: num(7, "stride")?,
        };
        nodes.push(OpNode { name: shape.name.clone(), op: Op::TableII(shape) });
    }
    finish(name, src, nodes)
}

/// Parse the SCALE-Sim-v2 style GEMM csv into [`Op::Gemm`] nodes.
pub(super) fn parse_gemm_csv(name: &str, src: &str, text: &str) -> Result<Workload> {
    parse_gemm_rows(name, src, &rows(name, src, text)?)
}

fn parse_gemm_rows(name: &str, src: &str, rows: &[(usize, Vec<String>)]) -> Result<Workload> {
    const COLUMNS: &str = "Layer, M, N, K";
    let mut nodes = Vec::new();
    for (i, (line, row)) in rows.iter().enumerate() {
        if i == 0 && is_header(row) {
            continue;
        }
        if row.len() != GEMM_CELLS {
            return Err(arity_error(src, *line, GEMM_CELLS, COLUMNS, row));
        }
        let m = cell_u64(src, *line, row, 1, "M")?;
        let n = cell_u64(src, *line, row, 2, "N")?;
        let k = cell_u64(src, *line, row, 3, "K")?;
        nodes.push(OpNode::new(&row[0], Op::Gemm { m, k, n }));
    }
    finish(name, src, nodes)
}

/// Sniff the format by the first row's arity and parse accordingly
/// (tokenizing the text once).
pub(super) fn parse_auto(name: &str, src: &str, text: &str) -> Result<Workload> {
    let rows = rows(name, src, text)?;
    let (line, first) = &rows[0];
    match first.len() {
        CONV_CELLS => parse_conv_rows(name, src, &rows),
        GEMM_CELLS => parse_gemm_rows(name, src, &rows),
        other => Err(Error::Workload(format!(
            "{src}:{line}: unrecognized workload csv: {other} cells per row \
             (Table-II conv = {CONV_CELLS}, GEMM = {GEMM_CELLS})"
        ))),
    }
}

/// Shared tail: non-empty check + op validation (which also validates
/// the lowered tiles via `lower` at use time).
fn finish(name: &str, src: &str, nodes: Vec<OpNode>) -> Result<Workload> {
    if nodes.is_empty() {
        return Err(Error::Workload(format!("{src}: no layers found (workload {name:?})")));
    }
    let w = Workload::new(name, nodes);
    w.validate()?;
    Ok(w)
}

#[cfg(test)]
mod tests {
    use super::*;

    const CONV: &str = "\
Layer name, IFMAP Height, IFMAP Width, Filter Height, Filter Width, Channels, Num Filter, Strides,
Conv1, 224, 224, 7, 7, 3, 64, 2,
FC, 1, 1, 1, 1, 2048, 1000, 1,
";

    const GEMM: &str = "\
Layer, M, N, K,
qkv, 128, 1536, 512,
out, 128, 512, 512,
";

    #[test]
    fn conv_csv_parses_to_table_ii_ops() {
        let w = Workload::parse_conv_csv("t", "t.csv", CONV).unwrap();
        assert_eq!(w.nodes.len(), 2);
        let t = w.lower().unwrap();
        assert_eq!(t.layers[0], LayerShape::conv("Conv1", 224, 224, 7, 7, 3, 64, 2));
        assert_eq!(t.layers[1], LayerShape::gemm("FC", 1, 2048, 1000));
    }

    #[test]
    fn gemm_csv_parses_m_n_k_column_order() {
        let w = Workload::parse_gemm_csv("g", "g.csv", GEMM).unwrap();
        assert_eq!(w.nodes[0].op, Op::Gemm { m: 128, k: 512, n: 1536 });
        let t = w.lower().unwrap();
        assert_eq!(t.layers[0], LayerShape::gemm("qkv", 128, 512, 1536));
        assert_eq!(t.layers[0].gemm_view(), (128, 512, 1536));
    }

    #[test]
    fn auto_sniffs_both_formats() {
        assert_eq!(
            Workload::parse_csv("t", "t.csv", CONV).unwrap(),
            Workload::parse_conv_csv("t", "t.csv", CONV).unwrap()
        );
        assert_eq!(
            Workload::parse_csv("g", "g.csv", GEMM).unwrap(),
            Workload::parse_gemm_csv("g", "g.csv", GEMM).unwrap()
        );
        let err = Workload::parse_csv("x", "x.csv", "a, 1, 2\n").unwrap_err();
        assert!(err.to_string().contains("x.csv:1"), "{err}");
    }

    #[test]
    fn malformed_row_reports_file_and_line() {
        // regression: short row no longer silently tolerated, and the
        // error names the real file line (comments/blank lines counted)
        let text = "\
# preamble comment
Conv1, 8, 8, 3, 3, 4, 16, 1,

Conv2, 8, 8, 3, 3, 4, 16,
";
        let err = Workload::parse_conv_csv("bad", "bad.csv", text).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("bad.csv:4"), "{msg}");
        assert!(msg.contains("expected 8 cells"), "{msg}");

        // extra cells are just as loud
        let err = Workload::parse_conv_csv("bad", "bad.csv", "C, 8, 8, 3, 3, 4, 16, 1, 99,\n")
            .unwrap_err();
        assert!(err.to_string().contains("bad.csv:1"), "{err}");

        // gemm rows are strict too
        let err =
            Workload::parse_gemm_csv("bad", "g.csv", "ok, 8, 8, 8,\nshort, 8, 8,\n").unwrap_err();
        assert!(err.to_string().contains("g.csv:2"), "{err}");
    }

    #[test]
    fn non_numeric_cell_reports_position() {
        let err =
            Workload::parse_conv_csv("bad", "bad.csv", "C1, 8, x, 3, 3, 4, 16, 1,\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("bad.csv:1") && msg.contains("cell 2"), "{msg}");
    }

    #[test]
    fn typo_first_row_is_not_mistaken_for_a_header() {
        // pre-IR parser skipped any first row with a non-numeric second
        // cell as a "header" — a malformed data row vanished silently
        let err = Workload::parse_conv_csv("bad", "bad.csv", "C1, x, 8, 3, 3, 4, 16, 1,\n")
            .unwrap_err();
        assert!(err.to_string().contains("cell 1"), "{err}");
    }

    #[test]
    fn comments_and_header_are_skipped() {
        let text = "# c\nLayer, M, N, K,\n# mid\ng, 8, 16, 32,\n";
        let w = Workload::parse_gemm_csv("g", "g.csv", text).unwrap();
        assert_eq!(w.nodes.len(), 1);
        assert_eq!(w.nodes[0].op, Op::Gemm { m: 8, k: 32, n: 16 });
    }

    #[test]
    fn empty_files_error() {
        assert!(Workload::parse_conv_csv("e", "e.csv", "# only\n").is_err());
        assert!(Workload::parse_gemm_csv("e", "e.csv", "Layer, M, N, K,\n").is_err());
    }
}
