//! Banked DRAM timing substrate — the DRAMSim2 stand-in (§III-D).
//!
//! The paper validates system integration by generating accurate DRAM
//! read/write bandwidth traces "which can then be fed into a DRAM
//! simulator e.g. DRAMSim2". That simulator is external to the original
//! tool; we build the equivalent in-repo so the hand-off can actually be
//! exercised: a row-buffer-per-bank timing model that consumes the
//! `(cycle, addr, is_write)` request stream derived from the memory
//! model's fold-level fetch schedule and reports achieved bandwidth,
//! row-hit rate, and average/worst latency.
//!
//! Timing parameters default to DDR4-2400-ish values expressed in
//! accelerator clock cycles (1 GHz core clock).

use std::collections::VecDeque;

pub mod banked;

pub use banked::{banked_replay_layer, BankedDram, BankedStats, DEFAULT_QUEUE_CAP};

/// DRAM timing/geometry parameters (cycles / bytes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramConfig {
    pub banks: usize,
    /// Row-buffer size per bank.
    pub row_bytes: u64,
    /// Activate (row open) latency.
    pub t_rcd: u64,
    /// Column access latency.
    pub t_cas: u64,
    /// Precharge (row close) latency.
    pub t_rp: u64,
    /// Bytes transferred per burst request.
    pub burst_bytes: u64,
    /// Burst transfer occupancy in cycles.
    pub t_burst: u64,
}

impl Default for DramConfig {
    fn default() -> Self {
        DramConfig {
            banks: 16,
            row_bytes: 2048,
            t_rcd: 18,
            t_cas: 18,
            t_rp: 18,
            burst_bytes: 64,
            t_burst: 4,
        }
    }
}

/// One memory request (burst granularity).
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub cycle: u64,
    pub addr: u64,
    pub is_write: bool,
}

/// Aggregate results of replaying a request stream.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DramStats {
    pub requests: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub total_latency: u64,
    pub max_latency: u64,
    /// Cycle the last request completed.
    pub finish_cycle: u64,
    pub bytes: u64,
}

impl DramStats {
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.row_hits as f64 / self.requests as f64
    }

    pub fn avg_latency(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.total_latency as f64 / self.requests as f64
    }

    /// Achieved bandwidth over the whole replay window (bytes/cycle).
    pub fn achieved_bw(&self) -> f64 {
        if self.finish_cycle == 0 {
            return 0.0;
        }
        self.bytes as f64 / self.finish_cycle as f64
    }
}

struct Bank {
    open_row: Option<u64>,
    ready_at: u64,
}

/// Row-buffer DRAM model. Requests must be fed in nondecreasing cycle
/// order; each bank serves FIFO.
pub struct Dram {
    cfg: DramConfig,
    banks: Vec<Bank>,
    stats: DramStats,
}

impl Dram {
    pub fn new(cfg: DramConfig) -> Self {
        let banks = (0..cfg.banks).map(|_| Bank { open_row: None, ready_at: 0 }).collect();
        Dram { cfg, banks, stats: DramStats::default() }
    }

    fn bank_and_row(&self, addr: u64) -> (usize, u64) {
        let row_global = addr / self.cfg.row_bytes;
        ((row_global % self.cfg.banks as u64) as usize, row_global / self.cfg.banks as u64)
    }

    /// Issue one burst request; returns its completion cycle.
    pub fn issue(&mut self, req: Request) -> u64 {
        let (b, row) = self.bank_and_row(req.addr);
        let bank = &mut self.banks[b];
        let start = req.cycle.max(bank.ready_at);
        let access = match bank.open_row {
            Some(open) if open == row => {
                self.stats.row_hits += 1;
                self.cfg.t_cas
            }
            Some(_) => {
                self.stats.row_misses += 1;
                self.cfg.t_rp + self.cfg.t_rcd + self.cfg.t_cas
            }
            None => {
                self.stats.row_misses += 1;
                self.cfg.t_rcd + self.cfg.t_cas
            }
        };
        bank.open_row = Some(row);
        let done = start + access + self.cfg.t_burst;
        bank.ready_at = done;
        let latency = done - req.cycle;
        self.stats.requests += 1;
        self.stats.total_latency += latency;
        self.stats.max_latency = self.stats.max_latency.max(latency);
        self.stats.finish_cycle = self.stats.finish_cycle.max(done);
        self.stats.bytes += self.cfg.burst_bytes;
        done
    }

    /// Replay a whole stream; returns the stats.
    pub fn replay(mut self, reqs: impl IntoIterator<Item = Request>) -> DramStats {
        for r in reqs {
            self.issue(r);
        }
        self.stats
    }

    pub fn stats(&self) -> DramStats {
        self.stats
    }
}

/// Chop a contiguous byte range into burst requests spread uniformly over
/// a cycle window — how the memory model's per-fold fetches become a
/// request stream.
pub fn burst_stream(
    cfg: &DramConfig,
    base_addr: u64,
    bytes: u64,
    window: (u64, u64),
    is_write: bool,
) -> Vec<Request> {
    if bytes == 0 {
        return Vec::new();
    }
    let n = bytes.div_ceil(cfg.burst_bytes);
    let (start, end) = window;
    let span = end.saturating_sub(start).max(1);
    (0..n)
        .map(|i| Request {
            cycle: start + i * span / n,
            addr: base_addr + i * cfg.burst_bytes,
            is_write,
        })
        .collect()
}

/// Build the cycle-stamped DRAM read-request stream for one layer
/// (§III-E step 3: "SCALE-SIM then generates DRAM traffic trace") from
/// the memory model's double-buffered fold fetches: fold *i*'s bytes are
/// spread over fold *i-1*'s compute window.
pub fn layer_request_stream(
    df: crate::dataflow::Dataflow,
    layer: &crate::arch::LayerShape,
    cfg: &crate::config::ArchConfig,
    dcfg: &DramConfig,
) -> Vec<Request> {
    let mut fetches = Vec::new();
    crate::memory::simulate_with(df, layer, cfg, |f| fetches.push(f));
    let mut reqs = Vec::new();
    let mut window_start = 0u64;
    let mut addr = 0u64; // streaming addresses; banks interleave by row
    for (i, f) in fetches.iter().enumerate() {
        let window = if i == 0 {
            // compulsory fill: spread over a nominal fill window
            (0, f.cycles.max(1))
        } else {
            (window_start, window_start + fetches[i - 1].cycles)
        };
        reqs.extend(burst_stream(dcfg, addr, f.bytes, window, false));
        addr += f.bytes;
        if i > 0 {
            window_start += fetches[i - 1].cycles;
        }
    }
    reqs
}

/// Replay one layer's DRAM read traffic through the banked substrate —
/// the full §III-D hand-off (SCALE-Sim trace -> DRAM simulator).
pub fn replay_layer(
    df: crate::dataflow::Dataflow,
    layer: &crate::arch::LayerShape,
    cfg: &crate::config::ArchConfig,
    dcfg: DramConfig,
) -> DramStats {
    let reqs = layer_request_stream(df, layer, cfg, &dcfg);
    Dram::new(dcfg).replay(reqs)
}

/// FIFO helper retained for request-queue experiments (backpressure
/// ablation in the system-interface example).
pub struct RequestQueue {
    q: VecDeque<Request>,
    pub capacity: usize,
}

impl RequestQueue {
    pub fn new(capacity: usize) -> Self {
        RequestQueue { q: VecDeque::new(), capacity }
    }

    /// Returns false (rejected) when full — the producer must stall.
    pub fn push(&mut self, r: Request) -> bool {
        if self.q.len() >= self.capacity {
            return false;
        }
        self.q.push_back(r);
        true
    }

    pub fn pop(&mut self) -> Option<Request> {
        self.q.pop_front()
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DramConfig {
        DramConfig::default()
    }

    #[test]
    fn sequential_same_row_hits() {
        let mut d = Dram::new(cfg());
        // two bursts in the same row, same bank
        d.issue(Request { cycle: 0, addr: 0, is_write: false });
        d.issue(Request { cycle: 0, addr: 64, is_write: false });
        let s = d.stats();
        assert_eq!(s.row_hits, 1);
        assert_eq!(s.row_misses, 1); // cold first access
    }

    #[test]
    fn row_conflict_pays_precharge() {
        let c = cfg();
        let mut d = Dram::new(c);
        let done1 = d.issue(Request { cycle: 0, addr: 0, is_write: false });
        // same bank, different row: banks stride rows, so jump
        // banks*row_bytes to land on the same bank, next row
        let conflict_addr = c.row_bytes * c.banks as u64;
        let done2 = d.issue(Request { cycle: 0, addr: conflict_addr, is_write: false });
        assert!(done2 > done1);
        assert_eq!(d.stats().row_misses, 2);
        // second waits for bank then pays rp+rcd+cas+burst
        assert_eq!(done2, done1 + c.t_rp + c.t_rcd + c.t_cas + c.t_burst);
    }

    #[test]
    fn banks_serve_in_parallel() {
        let c = cfg();
        let mut d = Dram::new(c);
        // different banks: identical completion time
        let d1 = d.issue(Request { cycle: 0, addr: 0, is_write: false });
        let d2 = d.issue(Request { cycle: 0, addr: c.row_bytes, is_write: false });
        assert_eq!(d1, d2);
    }

    #[test]
    fn burst_stream_covers_bytes() {
        let c = cfg();
        let reqs = burst_stream(&c, 1000, 1000, (0, 100), false);
        assert_eq!(reqs.len(), 16); // ceil(1000/64)
        assert!(reqs.windows(2).all(|w| w[0].cycle <= w[1].cycle));
        assert!(reqs.iter().all(|r| (0..100).contains(&r.cycle)));
    }

    #[test]
    fn achieved_bw_bounded_by_request_rate() {
        let c = cfg();
        let reqs = burst_stream(&c, 0, 64 * 1024, (0, 10_000), false);
        let stats = Dram::new(c).replay(reqs);
        assert!(stats.achieved_bw() > 0.0);
        assert!(stats.hit_rate() > 0.5, "sequential stream should mostly hit");
    }

    #[test]
    fn queue_backpressure() {
        let mut q = RequestQueue::new(2);
        let r = Request { cycle: 0, addr: 0, is_write: false };
        assert!(q.push(r));
        assert!(q.push(r));
        assert!(!q.push(r)); // full
        q.pop().unwrap();
        assert!(q.push(r));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn layer_request_stream_covers_traffic() {
        use crate::arch::LayerShape;
        use crate::config;
        use crate::dataflow::Dataflow;
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 16, 1);
        let cfgm = config::ArchConfig { array_h: 8, array_w: 8, ..config::paper_default() };
        let dcfg = cfg();
        let reqs = layer_request_stream(Dataflow::Os, &l, &cfgm, &dcfg);
        let (traffic, _) = crate::memory::simulate(Dataflow::Os, &l, &cfgm);
        let bytes: u64 = reqs.len() as u64 * dcfg.burst_bytes;
        // bursts round up per fold: covered, within one burst per fold
        assert!(bytes >= traffic.read_bytes(), "{bytes} < {}", traffic.read_bytes());
        // requests are cycle-ordered within each fold window and bounded
        // by the layer runtime
        let runtime = Dataflow::Os.timing(&l, 8, 8).cycles;
        assert!(reqs.iter().all(|r| r.cycle <= runtime));
    }

    #[test]
    fn replay_layer_produces_stats() {
        use crate::arch::LayerShape;
        use crate::config;
        use crate::dataflow::Dataflow;
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 16, 1);
        let cfgm = config::ArchConfig { array_h: 8, array_w: 8, ..config::paper_default() };
        let stats = replay_layer(Dataflow::Os, &l, &cfgm, cfg());
        assert!(stats.requests > 0);
        assert!(stats.achieved_bw() > 0.0);
        assert!(stats.hit_rate() > 0.3, "streaming should mostly row-hit");
    }

    #[test]
    fn empty_stream_stats() {
        let s = Dram::new(cfg()).replay(Vec::new());
        assert_eq!(s.requests, 0);
        assert_eq!(s.achieved_bw(), 0.0);
        assert_eq!(s.avg_latency(), 0.0);
    }
}
