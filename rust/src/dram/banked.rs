//! Tick-driven banked DRAM backend — the selectable high-fidelity
//! memory model behind the multi-array fabric path.
//!
//! [`super::Dram`] answers "when does this burst complete" from bank
//! ready times alone: requests never occupy space, a bank accepts any
//! backlog, and cold misses are folded into the miss count. This model
//! runs the full bank state machine instead:
//!
//! * each bank owns a **bounded request queue**: a burst occupies a slot
//!   from its arrival tick until its data transfer completes, and a
//!   producer arriving at a full queue stalls until the oldest occupant
//!   drains ([`BankedStats::queue_wait_cycles`] accounts the wait);
//! * the row-buffer state machine distinguishes all three access
//!   classes — **row hit** (`t_cas`), **row conflict**
//!   (`t_rp + t_rcd + t_cas`, a different row is open) and **cold
//!   miss** (`t_rcd + t_cas`, bank idle since reset);
//! * **per-transaction latency** (arrival to data, queue wait included)
//!   is accumulated exactly, not averaged from a closed form.
//!
//! Each bank's clock advances tick by tick to the request's arrival
//! (occupants whose transfer completed leave their slots); because every
//! service time is deterministic, the advance is computed in one step
//! per request — the observable state at every tick is identical to a
//! cycle loop, without paying for idle ticks.
//!
//! The model is deterministic end to end (pure integer arithmetic, no
//! clocks, no RNG): its stats join the golden-pinned deterministic
//! class.

use std::collections::VecDeque;

use super::{DramConfig, Request};

/// Queue capacity used when a surface enables the banked model without
/// sizing one (8 in-flight bursts per bank, DDR4-controller-ish).
pub const DEFAULT_QUEUE_CAP: usize = 8;

/// Aggregate results of replaying a request stream through the banked
/// model.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BankedStats {
    pub requests: u64,
    /// Open-row accesses (`t_cas`).
    pub row_hits: u64,
    /// Accesses that had to close another row first
    /// (`t_rp + t_rcd + t_cas`).
    pub row_conflicts: u64,
    /// First touch of an idle bank (`t_rcd + t_cas`).
    pub cold_misses: u64,
    /// Sum of per-transaction latencies (arrival tick to last data
    /// tick, queue wait included).
    pub total_latency_cycles: u64,
    pub max_latency_cycles: u64,
    /// Cycles requests spent stalled waiting for a queue slot.
    pub queue_wait_cycles: u64,
    /// Deepest any bank queue ever got (occupied slots).
    pub max_queue_depth: u64,
    /// Tick the last transfer completed.
    pub finish_cycle: u64,
    pub bytes: u64,
}

impl BankedStats {
    pub fn hit_rate(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.row_hits as f64 / self.requests as f64
    }

    pub fn avg_latency(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.total_latency_cycles as f64 / self.requests as f64
    }

    /// Achieved bandwidth over the whole replay window (bytes/cycle).
    pub fn achieved_bw(&self) -> f64 {
        if self.finish_cycle == 0 {
            return 0.0;
        }
        self.bytes as f64 / self.finish_cycle as f64
    }
}

struct BankState {
    open_row: Option<u64>,
    /// Completion tick of the newest accepted request (service is FIFO).
    ready_at: u64,
    /// Completion ticks of every request still occupying a queue slot,
    /// oldest first.
    occupants: VecDeque<u64>,
}

/// The tick-driven banked model. Requests are admitted in stream
/// (program) order; the arrival tick stamps when the producer offers
/// each burst.
pub struct BankedDram {
    cfg: DramConfig,
    queue_cap: usize,
    banks: Vec<BankState>,
    stats: BankedStats,
}

impl BankedDram {
    pub fn new(cfg: DramConfig, queue_cap: usize) -> Self {
        let banks = (0..cfg.banks)
            .map(|_| BankState { open_row: None, ready_at: 0, occupants: VecDeque::new() })
            .collect();
        BankedDram { cfg, queue_cap: queue_cap.max(1), banks, stats: BankedStats::default() }
    }

    fn bank_and_row(&self, addr: u64) -> (usize, u64) {
        let row_global = addr / self.cfg.row_bytes;
        ((row_global % self.cfg.banks as u64) as usize, row_global / self.cfg.banks as u64)
    }

    /// Advance the target bank to the request's arrival tick, stall for
    /// a queue slot if needed, serve the access, and return its
    /// completion tick.
    pub fn issue(&mut self, req: Request) -> u64 {
        let cap = self.queue_cap;
        let (b, row) = self.bank_and_row(req.addr);
        let Some(bank) = self.banks.get_mut(b) else {
            return req.cycle; // unreachable: bank index is addr % banks
        };
        // occupants whose transfer finished by the arrival tick have
        // left their slots
        while bank.occupants.front().is_some_and(|&done| done <= req.cycle) {
            bank.occupants.pop_front();
        }
        // full queue: the producer stalls until the oldest occupant
        // drains (slots free in completion order under FIFO service)
        let mut admitted_at = req.cycle;
        while bank.occupants.len() >= cap {
            if let Some(done) = bank.occupants.pop_front() {
                admitted_at = admitted_at.max(done);
            }
        }
        self.stats.queue_wait_cycles += admitted_at - req.cycle;
        let start = admitted_at.max(bank.ready_at);
        let access = match bank.open_row {
            Some(open) if open == row => {
                self.stats.row_hits += 1;
                self.cfg.t_cas
            }
            Some(_) => {
                self.stats.row_conflicts += 1;
                self.cfg.t_rp + self.cfg.t_rcd + self.cfg.t_cas
            }
            None => {
                self.stats.cold_misses += 1;
                self.cfg.t_rcd + self.cfg.t_cas
            }
        };
        bank.open_row = Some(row);
        let done = start + access + self.cfg.t_burst;
        bank.ready_at = done;
        bank.occupants.push_back(done);
        self.stats.max_queue_depth = self.stats.max_queue_depth.max(bank.occupants.len() as u64);
        let latency_cycles = done - req.cycle;
        self.stats.requests += 1;
        self.stats.total_latency_cycles += latency_cycles;
        self.stats.max_latency_cycles = self.stats.max_latency_cycles.max(latency_cycles);
        self.stats.finish_cycle = self.stats.finish_cycle.max(done);
        self.stats.bytes += self.cfg.burst_bytes;
        done
    }

    /// Replay a whole stream; returns the stats.
    pub fn replay(mut self, reqs: impl IntoIterator<Item = Request>) -> BankedStats {
        for r in reqs {
            self.issue(r);
        }
        self.stats
    }

    pub fn stats(&self) -> BankedStats {
        self.stats
    }
}

/// Replay one layer's DRAM read traffic through the banked model — the
/// high-fidelity sibling of [`super::replay_layer`], sharing the exact
/// same request stream.
pub fn banked_replay_layer(
    df: crate::dataflow::Dataflow,
    layer: &crate::arch::LayerShape,
    cfg: &crate::config::ArchConfig,
    dcfg: DramConfig,
    queue_cap: usize,
) -> BankedStats {
    let reqs = super::layer_request_stream(df, layer, cfg, &dcfg);
    BankedDram::new(dcfg, queue_cap).replay(reqs)
}

#[cfg(test)]
mod tests {
    use super::super::Dram;
    use super::*;

    fn cfg() -> DramConfig {
        DramConfig::default()
    }

    fn read(cycle: u64, addr: u64) -> Request {
        Request { cycle, addr, is_write: false }
    }

    #[test]
    fn classifies_hit_conflict_and_cold_separately() {
        let c = cfg();
        let mut d = BankedDram::new(c, DEFAULT_QUEUE_CAP);
        d.issue(read(0, 0)); // cold
        d.issue(read(0, 64)); // same row: hit
        d.issue(read(0, c.row_bytes * c.banks as u64)); // same bank, new row
        let s = d.stats();
        assert_eq!((s.cold_misses, s.row_hits, s.row_conflicts), (1, 1, 1));
        assert_eq!(s.requests, 3);
    }

    #[test]
    fn unbounded_queue_matches_the_analytical_replay() {
        // with queues deep enough to never bind, the tick model's
        // timing must agree with the closed-form Dram exactly
        use crate::arch::LayerShape;
        use crate::config;
        use crate::dataflow::Dataflow;
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 16, 1);
        let cfgm = config::ArchConfig { array_h: 8, array_w: 8, ..config::paper_default() };
        let reqs = super::super::layer_request_stream(Dataflow::Os, &l, &cfgm, &cfg());
        let banked = BankedDram::new(cfg(), usize::MAX).replay(reqs.clone());
        let flat = Dram::new(cfg()).replay(reqs);
        assert_eq!(banked.requests, flat.requests);
        assert_eq!(banked.row_hits, flat.row_hits);
        assert_eq!(banked.row_conflicts + banked.cold_misses, flat.row_misses);
        assert_eq!(banked.finish_cycle, flat.finish_cycle);
        assert_eq!(banked.total_latency_cycles, flat.total_latency);
        assert_eq!(banked.queue_wait_cycles, 0);
    }

    #[test]
    fn full_queue_stalls_the_producer() {
        let c = cfg();
        // every request to the same bank/row, all arriving at tick 0:
        // with a 2-deep queue the third request must wait for a slot
        let mut d = BankedDram::new(c, 2);
        d.issue(read(0, 0));
        d.issue(read(0, 64));
        d.issue(read(0, 128));
        let s = d.stats();
        assert!(s.queue_wait_cycles > 0, "{s:?}");
        assert_eq!(s.max_queue_depth, 2);
        // and the wait shows up in that transaction's latency
        let deep = BankedDram::new(c, DEFAULT_QUEUE_CAP)
            .replay([read(0, 0), read(0, 64), read(0, 128)]);
        assert!(s.total_latency_cycles >= deep.total_latency_cycles);
        assert_eq!(s.max_queue_depth, 2);
        assert!(deep.queue_wait_cycles == 0);
    }

    #[test]
    fn queue_depth_tracks_backlog() {
        let c = cfg();
        let mut d = BankedDram::new(c, DEFAULT_QUEUE_CAP);
        for i in 0..6 {
            d.issue(read(0, i * 64)); // one bank, same row, burst pile-up
        }
        assert_eq!(d.stats().max_queue_depth, 6);
        // spaced-out arrivals never queue
        let mut d = BankedDram::new(c, DEFAULT_QUEUE_CAP);
        for i in 0..6 {
            d.issue(read(i * 1000, i * 64));
        }
        assert_eq!(d.stats().max_queue_depth, 1);
    }

    #[test]
    fn latency_includes_queue_wait() {
        let c = cfg();
        let mut d = BankedDram::new(c, 1);
        let d1 = d.issue(read(0, 0));
        // arrives while the first is in service; the single slot frees
        // only at d1, so service (a row hit) starts there
        let d2 = d.issue(read(1, 64));
        assert_eq!(d2, d1 + c.t_cas + c.t_burst);
        let s = d.stats();
        assert_eq!(s.queue_wait_cycles, d1 - 1);
        assert_eq!(s.max_latency_cycles, d2 - 1);
    }

    #[test]
    fn banked_layer_replay_is_deterministic() {
        use crate::arch::LayerShape;
        use crate::config;
        use crate::dataflow::Dataflow;
        let l = LayerShape::conv("c", 16, 16, 3, 3, 8, 16, 1);
        let cfgm = config::ArchConfig { array_h: 8, array_w: 8, ..config::paper_default() };
        let a = banked_replay_layer(Dataflow::Os, &l, &cfgm, cfg(), DEFAULT_QUEUE_CAP);
        let b = banked_replay_layer(Dataflow::Os, &l, &cfgm, cfg(), DEFAULT_QUEUE_CAP);
        assert_eq!(a, b);
        assert!(a.requests > 0);
        assert!(a.row_hits + a.row_conflicts + a.cold_misses == a.requests);
        // derived-metric sanity: hit rate is a fraction of requests and
        // both latency and bandwidth figures are positive and finite
        assert!(a.hit_rate() >= 0.0 && a.hit_rate() <= 1.0);
        assert!(a.avg_latency() > 0.0 && a.avg_latency().is_finite());
        assert!(a.achieved_bw() > 0.0 && a.achieved_bw().is_finite());
    }
}
