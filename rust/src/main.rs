//! `scale-sim` CLI — the leader entrypoint (Fig 1): config + topology in,
//! traces + summary reports out, plus sweep / validate / artifact
//! subcommands. Argument parsing is hand-rolled (clap is unavailable in
//! the offline build). Every subcommand drives the [`scale_sim::engine`]
//! façade; error plumbing uses `Box<dyn Error>` (anyhow is unavailable
//! offline).

use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::time::{Duration, Instant};

use scale_sim::config::{workloads, ArchConfig, Topology};
use scale_sim::engine::{BackendKind, Engine};
use scale_sim::obs::{metrics, trace};
use scale_sim::runtime::{default_artifact_dir, Runtime};
use scale_sim::server::{self, proto, ServeOpts};
use scale_sim::util::bench::{percentile, write_json};
use scale_sim::util::csv::CsvWriter;
use scale_sim::util::fmt_bytes;
use scale_sim::util::json::Json;
use scale_sim::{sweep, Dataflow, LayerShape, Workload};

const USAGE: &str = "\
scale-sim — systolic CNN accelerator simulator (SCALE-Sim reproduction)

USAGE:
  scale-sim run [-c cfg] [-t|--workload spec] [-o outdir] [--format table|json|csv]
                [--dataflow os|ws|is] [--array RxC]
                [--backend analytical|trace|rtl]
                [--dump-traces] [--functional TILE] [--threads N]
                [--trace-out FILE.json]
      Simulate a workload: a built-in name (`resnet50`/`W5`, or a GEMM
      suite name like `mlp`/`attention`/`lstm`), a Table-II conv csv
      path, or a SCALE-Sim-v2 style GEMM csv path (`Layer, M, N, K`
      rows) — the format is sniffed, parsed into the typed operator IR
      and lowered onto the engine. --format json|csv makes the report
      machine-readable on stdout; -o writes the report files.
      --trace-out exports the run's cycle timeline as Chrome trace-event
      JSON (load in Perfetto; docs/OBSERVABILITY.md).

  scale-sim profile [-c cfg] [-t|--workload spec] [--dataflow os|ws|is]
                    [--array RxC] [--backend analytical|trace|rtl]
                    [--dram-bw B] [--nodes N] [--partition channels|pixels|auto]
                    [--trace-out FILE.json] [--metrics-out FILE.prom]
                    [--bench FILE]
      Two-timeline observability for one workload. Simulated time: the
      per-layer fill/stream/drain/stall phase table (cycle sums equal
      the engine report exactly) and, with --trace-out, the span tree
      as Chrome trace-event JSON — per-node tracks under --nodes.
      Host time: BENCH_profile.json (wall clock + cache counters) and,
      with --metrics-out, the deterministic Prometheus snapshot of the
      engine metrics registry. --dram-bw (bytes/cycle) adds the §III-D
      stall spans.

  scale-sim sweep <dataflow|memory|shape> [-t|--workload spec]...
                  [--trace-out FILE.json]
      Reproduce the paper's design-space sweeps (Figs 5-8 series printed
      as tables) through the memoizing engine grid; repeat -t/--workload
      to sweep several workloads (conv and GEMM specs mix freely and
      share lowered-tile cache entries); default is the MLPerf suite.
      Writes BENCH_sweep.json (wall-clock + cache hit-rate);
      --trace-out exports every grid point's cycle timeline on its own
      track.

  scale-sim validate [--max N] [-t|--workload spec]...
      Without workload specs: Fig 4 — run every engine backend
      (analytical, trace-driven, RTL PE-grid) on array-sized matmuls
      through the same Engine entry point; cycle counts must tally
      exactly. With specs: parse + lower + validate each workload
      (built-in, conv csv, or GEMM csv) and print its lowering summary.

  scale-sim analyze [-t topology] [--array RxC] [--dataflow os|ws|is]
      Deep-dive one workload: per-layer SRAM bank requirement (§IV-B),
      best dataflow per layer (flexible-dataflow study), and the DRAM
      bandwidth to provision for <5%% slowdown (§III-D stall model).

  scale-sim scaleout [-t|--workload spec]... [--partition channels|pixels|auto]
                     [--budgets 64,256,...] [--dataflow os|ws|is] [--bench FILE]
                     [--fabric flat,line,ring,mesh] [--link-bw B] [--dram-bw B]
      Reproduce the paper's §IV-E scale-up vs scale-out study (Figs 9 &
      10) through the engine's multi-array model: at each PE budget one
      √P x √P array vs P/64 replicated 8x8 nodes, the workload split
      across nodes by the chosen partition strategy (output channels —
      the paper's choice — OFMAP pixel stripes, or per-layer auto).
      Prints runtime and weight-DRAM-bandwidth ratios plus the required
      interconnect bandwidth the paper only tabulates, and writes
      BENCH_scaleout.json. Default workloads: alphagozero + ncf.
      --fabric adds the route-aware interconnect study: the same node
      counts rerun on each listed topology with per-link bandwidth
      --link-bw and shared DRAM bandwidth --dram-bw (bytes/cycle,
      default 16 each). Per-link peak/average throughput, stall cycles
      and banked-DRAM row-buffer stats go to BENCH_fabric.json; "flat"
      rows keep the legacy even-split model as the baseline.

  scale-sim workloads
      List the built-in workloads: the MLPerf conv suite (Table III)
      and the GEMM suite (tag G: mlp, attention, lstm, ncf_gemm).

  scale-sim artifacts
      Show the functional-runtime platform and the AOT artifacts
      available for the functional path.

  scale-sim dse <run|resume|report> [--spec FILE.json | --scaleout]
               [--state-dir DIR] [--threads N] [--serve H:P] [--shards N]
               [--max-points N] [--backend analytical|trace|rtl]
               [--bench FILE] [--trace-out FILE.json]
      Resumable design-space-exploration campaigns with Pareto
      frontiers (runtime-vs-energy, runtime-vs-peak-DRAM-bandwidth).
      `run` starts a campaign — the paper's bandwidth x dataflow x
      aspect-ratio axes by default, or a JSON spec ({\"workloads\":[..],
      \"dataflows\":[..], \"arrays\":[\"RxC\",..], \"nodes\":[..],
      \"partitions\":[\"channels\",..], \"sram_kb\":[..],
      \"dram_bw\":[..], \"topologies\":[\"flat\",\"mesh\",..],
      \"link_bw\":[..]}). The nodes/partitions axes sweep §IV-E
      multi-array scale-out systems (Pareto frontiers over array
      count); --scaleout runs the built-in §IV-E campaign (8x8 nodes,
      1..256 node counts, all partition strategies) without a spec
      file. With --state-dir every completed point is
      journaled to campaign.jsonl; a killed campaign continues with
      `resume`, re-simulating only unfinished points and producing a
      bit-identical frontier. `report` prints the frontier from a
      journal without simulating. --serve shards the points over a
      running `scale-sim serve` (one shared memo cache across shards).
      A complete campaign writes BENCH_dse.json (--bench overrides).
      --trace-out re-simulates the runtime-vs-energy frontier points
      (cache-warm) and exports their cycle timelines, one track each.

  scale-sim lint [--root DIR] [--baseline FILE] [--list] [--no-baseline]
                 [--write-baseline] [--format text|json]
      Run the in-tree static-analysis pass (rust/src/analysis) over the
      repo's own sources: R1 determinism (no HashMap/HashSet or wall
      clock in serialization/fingerprint paths), R2 lock discipline (no
      guard held across I/O or a second lock()), R3 shim boundary
      (engine-era modules never call the deprecated pre-engine shims),
      R4 panic hygiene (no unwrap/expect/panic! in library code), R5
      golden-bless hygiene (the golden-fixture bless env hook may only
      be read inside rust/tests/golden*); plus the interprocedural
      families built on the crate call graph: R6 lock order (no guard
      held across a callee that transitively locks or does I/O, global
      lock-order graph acyclic), R7 unit taint (cycle-, wall- and
      byte-valued quantities never mix in arithmetic or metric sinks),
      R8 dead surface (every proto Request variant and CLI subcommand
      reaches a handler; no unreachable pub library fn).
      Findings are checked against the ratcheted lint.baseline: new
      violations fail, fixed ones must be removed (the count only goes
      down). --list prints every finding; --format json emits the
      findings as one byte-deterministic JSON document on stdout;
      --write-baseline regenerates the baseline (deliberate review
      only).

  scale-sim serve [--addr H:P] [--workers N] [--queue-cap N]
                  [--state-dir DIR] [--peers H:P,H:P,...]
                  [--cache-stripes N] [-c cfg] [--dataflow os|ws|is]
                  [--array RxC] [--backend analytical|trace|rtl]
      Run the simulation service: a TCP JSON-lines job server with a
      bounded queue, a work-shedding worker pool, and ONE shared
      lock-striped memo cache, so repeated layers from different
      clients never re-simulate. A full queue answers new jobs with a
      terminal `busy` event instead of blocking the connection.
      --state-dir persists results across restarts (pre-warm on start,
      flush on shutdown). --peers federates a fleet: every instance
      lists the others (and is started with the same base config), memo
      keys route to their consistent-hash owner, and the fleet shares
      one logical cache — a down peer just means local compute; results
      never change (docs/INVARIANTS.md §11). --cache-stripes tunes memo
      lock striping (concurrency only; never changes results). Prints
      `listening on ADDR`; stop it with `scale-sim client shutdown`.

  scale-sim client <run|sweep|batch|stats|metrics|shutdown> [--addr H:P]
                   [-t topology]... [--dataflow os|ws|is] [--array RxC]
                   [--kind dataflow|memory|shape]
                   [--nodes N] [--partition channels|pixels|auto]
      Submit a job to a running server and stream its JSON response
      lines (protocol: rust/src/server/proto.rs). `-t` takes a
      built-in name or a conv/GEMM csv path (lowered locally and sent
      inline); the protocol also accepts typed operator specs ("ops").
      `batch` packs every repeated -t/--workload into one envelope:
      sub-jobs run concurrently, their event streams interleave (demux
      by id), and a final `batch_done` closes the envelope. `metrics`
      prints the server's Prometheus text exposition (cache, queue, and
      worker series) raw — scrape-ready.

  scale-sim bench-serve [--clients N] [--rounds N] [--workers N]
                        [--state-dir DIR] [--baseline FILE] [--bless]
      Closed-loop load generator: N concurrent clients (default 8)
      replay a mixed run+sweep MLPerf load against an in-process
      server (retrying shed `busy` jobs), then the server restarts from
      the state dir to prove warm start. Writes BENCH_serve.json
      (throughput, p50/p99 latency, hit rate) and gates it against
      --baseline (default BENCH_serve.baseline.json): the run fails if
      throughput drops below 0.8x the baseline or p99 exceeds 2x. A
      missing baseline or --bless records the current numbers as the
      new floor.
";

type CliResult<T> = std::result::Result<T, Box<dyn std::error::Error>>;

fn fail<T>(msg: String) -> CliResult<T> {
    Err(msg.into())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> CliResult<()> {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("profile") => cmd_profile(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("scaleout") => cmd_scaleout(&args[1..]),
        Some("dse") => cmd_dse(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("workloads") => cmd_workloads(),
        Some("artifacts") => cmd_artifacts(),
        Some("lint") => cmd_lint(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("client") => cmd_client(&args[1..]),
        Some("bench-serve") => cmd_bench_serve(&args[1..]),
        Some("-h") | Some("--help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => fail(format!("unknown command {other:?}\n{USAGE}")),
    }
}

/// Tiny flag parser: returns value for `--name V` / `-n V`.
struct Args<'a>(&'a [String]);

impl<'a> Args<'a> {
    fn value(&self, long: &str, short: Option<&str>) -> Option<&'a str> {
        let mut it = self.0.iter();
        while let Some(a) = it.next() {
            if a == long || short.is_some_and(|s| a == s) {
                return it.next().map(String::as_str);
            }
        }
        None
    }

    /// Every value of a repeatable `--name V` / `-n V` flag, in order.
    /// A trailing bare flag is an error, not a silent no-op (a dropped
    /// `--workload` would otherwise fall back to a full-suite sweep).
    fn values(&self, long: &str, short: Option<&str>) -> CliResult<Vec<&'a str>> {
        let mut out = Vec::new();
        let mut it = self.0.iter();
        while let Some(a) = it.next() {
            if a == long || short.is_some_and(|s| a == s) {
                match it.next() {
                    Some(v) => out.push(v.as_str()),
                    None => return fail(format!("{long} expects a value")),
                }
            }
        }
        Ok(out)
    }

    fn flag(&self, long: &str) -> bool {
        self.0.iter().any(|a| a == long)
    }
}

/// Resolve a workload spec — built-in name (conv or GEMM family) or a
/// csv path (Table-II conv / GEMM format, sniffed) — as the typed IR.
fn load_workload(spec: &str) -> CliResult<Workload> {
    if let Some(w) = workloads::builtin_workload(spec) {
        return Ok(w);
    }
    Ok(Workload::from_file(&PathBuf::from(spec))?)
}

/// [`load_workload`], lowered onto engine tiles.
fn load_topology(spec: &str) -> CliResult<Topology> {
    Ok(load_workload(spec)?.lower()?)
}

/// Shared `-c/--dataflow/--array` handling for run/analyze.
fn base_config(a: &Args) -> CliResult<ArchConfig> {
    let mut cfg = match a.value("--config", Some("-c")) {
        Some(p) => ArchConfig::from_file(&PathBuf::from(p))?,
        None => ArchConfig::default(),
    };
    if let Some(df) = a.value("--dataflow", None) {
        cfg.dataflow = Dataflow::parse(df)?;
    }
    if let Some(arr) = a.value("--array", None) {
        let (r, c) = arr
            .split_once('x')
            .ok_or("--array expects RxC, e.g. 32x32")?;
        cfg.array_h = r.parse()?;
        cfg.array_w = c.parse()?;
    }
    Ok(cfg)
}

fn cmd_run(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    // reject a bad --format before any simulation work happens
    let format = a.value("--format", None).unwrap_or("table");
    if !matches!(format, "table" | "json" | "csv") {
        return fail(format!("unknown format {format:?} (table|json|csv)"));
    }
    let cfg = base_config(&a)?;
    let mut specs = a.values("--topology", Some("-t"))?;
    specs.extend(a.values("--workload", None)?);
    if specs.len() > 1 {
        return fail(format!("run takes exactly one workload, got {specs:?}"));
    }
    let topo = match specs.first() {
        Some(t) => load_topology(t)?,
        None => match &cfg.topology_path {
            Some(p) => Workload::from_file(p)?.lower()?,
            None => {
                return fail(
                    "no workload: pass -t/--workload or set Topology in the cfg".into(),
                )
            }
        },
    };

    let mut b = Engine::builder().config(cfg).dump_traces(a.flag("--dump-traces"));
    if let Some(backend) = a.value("--backend", None) {
        b = b.backend(BackendKind::parse(backend)?);
    }
    if let Some(dir) = a.value("--out", Some("-o")) {
        b = b.out_dir(dir);
    }
    if let Some(t) = a.value("--functional", None) {
        b = b.functional_tile(t.parse()?);
    }
    if let Some(t) = a.value("--threads", None) {
        b = b.threads(t.parse()?);
    }
    let engine = b.build()?;
    let out = engine.run(&topo)?;

    let cfg = engine.cfg();
    let r = &out.report;
    match format {
        // one JSON document on stdout (report shape identical to the
        // serve protocol's `result` event), machine-readable without
        // the server
        "json" => {
            let mut fields = vec![
                ("workload", Json::str(&r.workload)),
                ("dataflow", Json::str(cfg.dataflow.name())),
                ("array_h", Json::u64(cfg.array_h)),
                ("array_w", Json::u64(cfg.array_w)),
                ("backend", Json::str(engine.backend_kind().name())),
                ("total_cycles", Json::u64(r.total_cycles())),
                ("overall_utilization", Json::f64(r.overall_utilization(cfg.total_pes()))),
                ("total_dram_bytes", Json::u64(r.total_dram().total())),
                ("total_energy_mj", Json::f64(r.total_energy().total_mj())),
                ("report", proto::workload_report_to_json(r)),
            ];
            if !out.functional.is_empty() {
                fields.push((
                    "functional",
                    Json::Arr(
                        out.functional
                            .iter()
                            .map(|(layer, err)| {
                                Json::obj(vec![
                                    ("layer", Json::str(layer)),
                                    ("max_rel_err", Json::f64(f64::from(*err))),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            println!("{}", Json::obj(fields));
        }
        "csv" => {
            let mut w = CsvWriter::new(&[
                "layer",
                "cycles",
                "utilization",
                "mapping_efficiency",
                "dram_bytes",
                "avg_read_bw",
                "energy_mj",
            ]);
            for l in &r.layers {
                w.row(&[
                    l.name().to_string(),
                    l.timing.cycles.to_string(),
                    format!("{:.6}", l.timing.utilization),
                    format!("{:.6}", l.timing.mapping_efficiency),
                    l.dram.total().to_string(),
                    format!("{:.6}", l.bandwidth.avg_read_bw),
                    format!("{:.6}", l.energy.total_mj()),
                ]);
            }
            print!("{}", w.as_str());
            // keep stdout pure csv; functional results go to stderr
            for (layer, err) in &out.functional {
                eprintln!("functional[{layer}]: max rel err {err:.2e}");
            }
        }
        "table" => {
            println!(
                "workload {:>14}  dataflow {}  array {}x{}  backend {}",
                r.workload, cfg.dataflow, cfg.array_h, cfg.array_w, engine.backend_kind()
            );
            println!(
                "{:<18} {:>12} {:>8} {:>14} {:>12} {:>10}",
                "layer", "cycles", "util%", "dram_bytes", "avg_rd_bw", "energy_mJ"
            );
            for l in &r.layers {
                println!(
                    "{:<18} {:>12} {:>8.2} {:>14} {:>12.4} {:>10.4}",
                    l.name(),
                    l.timing.cycles,
                    l.timing.utilization * 100.0,
                    l.dram.total(),
                    l.bandwidth.avg_read_bw,
                    l.energy.total_mj(),
                );
            }
            println!(
                "TOTAL: {} cycles, {:.2}% util, {} DRAM, {:.4} mJ",
                r.total_cycles(),
                r.overall_utilization(cfg.total_pes()) * 100.0,
                fmt_bytes(r.total_dram().total()),
                r.total_energy().total_mj()
            );
            for (layer, err) in &out.functional {
                println!(
                    "functional[{layer}]: max rel err {err:.2e} (AOT artifact vs reference)"
                );
            }
            if !out.files_written.is_empty() {
                println!(
                    "wrote {} files under {:?}",
                    out.files_written.len(),
                    out.files_written[0].parent().unwrap()
                );
            }
        }
        _ => unreachable!("--format validated before the run"),
    }
    if let Some(path) = a.value("--trace-out", None) {
        let t = trace::workload_trace(cfg.dataflow, cfg.array_h, cfg.array_w, r, None);
        t.write(Path::new(path))?;
        // stderr keeps --format json|csv stdout machine-readable
        eprintln!("wrote {path} ({} spans)", t.spans.len());
    }
    Ok(())
}

fn cmd_profile(rest: &[String]) -> CliResult<()> {
    use scale_sim::engine::{multi::MultiArrayConfig, Partition};
    use scale_sim::memory::stall;

    let a = Args(rest);
    let cfg = base_config(&a)?;
    let mut specs = a.values("--topology", Some("-t"))?;
    specs.extend(a.values("--workload", None)?);
    if specs.len() != 1 {
        return fail(format!("profile takes exactly one -t/--workload, got {}", specs.len()));
    }
    let topo = load_topology(specs[0])?;
    let dram_bw = match a.value("--dram-bw", None) {
        Some(v) => {
            let bw: f64 = v.parse()?;
            if !(bw > 0.0 && bw.is_finite()) {
                return fail(format!("--dram-bw must be a positive bytes/cycle figure, got {v}"));
            }
            Some(bw)
        }
        None => None,
    };
    let nodes: u64 = match a.value("--nodes", None) {
        Some(n) => n.parse()?,
        None => 1,
    };
    let partition = match a.value("--partition", None) {
        Some(p) => Partition::parse(p)?,
        None => Partition::default(),
    };

    // threads(1): the profile's cache counters and metrics snapshot are
    // part of the two-process determinism contract
    let mut b = Engine::builder().config(cfg).threads(1);
    if let Some(backend) = a.value("--backend", None) {
        b = b.backend(BackendKind::parse(backend)?);
    }
    let engine = b.build()?;
    let cfg = engine.cfg().clone();
    let t0 = Instant::now();

    let (t, total_compute, total_stall) = if nodes > 1 {
        let mc = MultiArrayConfig::new(nodes, cfg.array_h, cfg.array_w, partition);
        let m = engine.run_multi_with(&cfg, &topo, &mc, dram_bw);
        let t = trace::multi_trace(cfg.dataflow, &m);
        println!(
            "profile {} — {} on {nodes} x {}x{} nodes ({} partition, backend {})",
            m.workload,
            cfg.dataflow,
            cfg.array_h,
            cfg.array_w,
            partition.name(),
            engine.backend_kind()
        );
        println!(
            "{:<18} {:>12} {:>10} {:>6} {:>7}",
            "layer", "cycles", "stall", "nodes", "util%"
        );
        for l in &m.layers {
            println!(
                "{:<18} {:>12} {:>10} {:>6} {:>7.2}",
                l.node_report.name(),
                l.cycles,
                l.stall_cycles,
                l.used_nodes,
                l.node_report.timing.utilization * 100.0
            );
        }
        (t, m.total_cycles(), m.total_stall_cycles())
    } else {
        let report = engine.run_topology_with(&cfg, &topo);
        let stalls: Option<Vec<u64>> = dram_bw.map(|bw| {
            topo.layers
                .iter()
                .map(|l| stall::stalled_runtime(cfg.dataflow, l, &cfg, bw).stall_cycles)
                .collect()
        });
        let t =
            trace::workload_trace(cfg.dataflow, cfg.array_h, cfg.array_w, &report, stalls.as_deref());
        println!(
            "profile {} — {} {}x{} (backend {})",
            report.workload, cfg.dataflow, cfg.array_h, cfg.array_w, engine.backend_kind()
        );
        println!(
            "{:<18} {:>12} {:>10} {:>12} {:>10} {:>10} {:>7}",
            "layer", "cycles", "fill", "stream", "drain", "stall", "util%"
        );
        let mut total_stall = 0u64;
        for (i, l) in report.layers.iter().enumerate() {
            // phase sums equal timing.cycles exactly (pinned by the obs
            // suite); the table is the span tree flattened per layer
            let p = trace::phase_totals(cfg.dataflow, cfg.array_h, cfg.array_w, &l.layer);
            let stall = stalls.as_ref().map_or(0, |s| s[i]);
            println!(
                "{:<18} {:>12} {:>10} {:>12} {:>10} {:>10} {:>7.2}",
                l.name(),
                l.timing.cycles,
                p.fill,
                p.stream,
                p.drain,
                stall,
                l.timing.utilization * 100.0
            );
            total_stall += stall;
        }
        (t, report.total_cycles(), total_stall)
    };
    println!("TOTAL: {total_compute} compute cycles + {total_stall} stall cycles");

    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let stats = engine.cache_stats();
    let bench = a.value("--bench", None).unwrap_or("BENCH_profile.json");
    write_json(
        Path::new(bench),
        &[
            ("wall_ms", wall_ms),
            ("layers", topo.layers.len() as f64),
            ("total_cycles", (total_compute + total_stall) as f64),
            ("layer_sims", stats.layer_sims as f64),
            ("cache_hits", stats.cache_hits as f64),
            ("trace_events", t.spans.len() as f64),
        ],
    )?;
    println!("wrote {bench}");
    if let Some(path) = a.value("--trace-out", None) {
        t.write(Path::new(path))?;
        println!("wrote {path} ({} spans)", t.spans.len());
    }
    if let Some(path) = a.value("--metrics-out", None) {
        // deterministic class only: the snapshot is byte-identical
        // across processes for a fixed workload (determinism suite)
        metrics::record_cache(
            metrics::global(),
            &stats,
            &engine.warm_stats(),
            engine.cache_entries() as u64,
        );
        std::fs::write(path, metrics::global().render(false))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    let kind = rest.first().map(String::as_str).unwrap_or("dataflow");
    let mut specs = a.values("--topology", Some("-t"))?;
    specs.extend(a.values("--workload", None)?);
    let topos: Vec<Topology> = if specs.is_empty() {
        workloads::mlperf_suite()
    } else {
        specs.iter().map(|s| load_topology(s)).collect::<CliResult<_>>()?
    };
    let engine = Engine::builder().config(ArchConfig::default()).build()?;

    let outcome = match kind {
        "dataflow" => {
            let out = engine
                .sweep()
                .workloads(&topos)
                .dataflows(&Dataflow::ALL)
                .square_arrays(&[128, 64, 32, 16, 8])
                .run();
            println!("{:<14} {:>4} {:>6} {:>14} {:>8} {:>12} {:>12}", "workload", "df", "array", "cycles", "util%", "E_comp_mJ", "E_mem_mJ");
            for p in &out.points {
                let e = p.report.total_energy();
                println!(
                    "{:<14} {:>4} {:>6} {:>14} {:>8.2} {:>12.4} {:>12.4}",
                    p.workload,
                    p.dataflow.name(),
                    p.array_h,
                    p.report.total_cycles(),
                    p.report.overall_utilization(p.total_pes()) * 100.0,
                    e.compute_mj,
                    e.memory_mj()
                );
            }
            out
        }
        "memory" => {
            let out = engine
                .sweep()
                .workloads(&topos)
                .sram_sizes_kb(&[32, 64, 128, 256, 512, 1024, 2048])
                .run();
            println!("{:<14} {:>8} {:>14} {:>12}", "workload", "sram_kb", "dram_bytes", "avg_rd_bw");
            for p in &out.points {
                println!(
                    "{:<14} {:>8} {:>14} {:>12.4}",
                    p.workload,
                    p.ifmap_sram_kb,
                    p.report.total_dram().total(),
                    p.report.avg_dram_read_bw()
                );
            }
            out
        }
        "shape" => {
            let out = engine
                .sweep()
                .workloads(&topos)
                .dataflows(&Dataflow::ALL)
                .array_shapes(&sweep::fig8_shapes())
                .run();
            println!("{:<14} {:>4} {:>10} {:>14}", "workload", "df", "shape", "cycles");
            for p in &out.points {
                println!(
                    "{:<14} {:>4} {:>10} {:>14}",
                    p.workload,
                    p.dataflow.name(),
                    format!("{}x{}", p.array_h, p.array_w),
                    p.report.total_cycles()
                );
            }
            out
        }
        other => return fail(format!("unknown sweep {other:?} (dataflow|memory|shape)")),
    };

    let stats = &outcome.stats;
    let wall_ms = stats.wall.as_secs_f64() * 1e3;
    println!(
        "sweep: {} points in {:.1} ms — {} layer sims, {} cache hits ({:.1}% hit rate)",
        stats.points,
        wall_ms,
        stats.memo.layer_sims,
        stats.memo.cache_hits,
        stats.hit_rate() * 100.0
    );
    stats.write_bench_json(Path::new("BENCH_sweep.json"))?;
    println!("wrote BENCH_sweep.json");
    if let Some(path) = a.value("--trace-out", None) {
        let mut t = trace::Trace::new();
        let mut skipped = 0usize;
        for (pid, p) in outcome.points.iter().enumerate() {
            let pid = pid as u64;
            // composed multi-array reports have no single-array span
            // decomposition; the CLI sweep never sets the nodes axis,
            // so this only guards future grid shapes
            if p.nodes > 1 {
                skipped += 1;
                continue;
            }
            t.name_process(
                pid,
                format!("{} {} {}x{}", p.workload, p.dataflow.name(), p.array_h, p.array_w),
            );
            let mut cursor = 0u64;
            for l in &p.report.layers {
                cursor = trace::layer_spans(&mut t, pid, cursor, p.dataflow, p.array_h, p.array_w, l, 0);
            }
        }
        if skipped > 0 {
            println!("trace: skipped {skipped} multi-array point(s)");
        }
        t.write(Path::new(path))?;
        println!("wrote {path} ({} spans)", t.spans.len());
    }
    Ok(())
}

fn cmd_scaleout(rest: &[String]) -> CliResult<()> {
    use scale_sim::dram::DramConfig;
    use scale_sim::engine::multi::{
        MultiArrayConfig, MultiOpts, Partition, ScaleoutPoint, NODE_DIM, NODE_PES, PE_SWEEP,
    };
    use scale_sim::engine::{FabricConfig, FabricKind, DEFAULT_LINK_BW};
    use scale_sim::report::scaleout_summary;
    use scale_sim::util::isqrt;

    let a = Args(rest);
    let cfg = base_config(&a)?;
    let partition = match a.value("--partition", None) {
        Some(p) => Partition::parse(p)?,
        None => Partition::OutputChannels,
    };
    // --fabric switches on the route-aware interconnect study;
    // --link-bw/--dram-bw provision it. Validated here at admission so a
    // bad figure never reaches the stall-model assert.
    let fabric_kinds: Option<Vec<FabricKind>> = match a.value("--fabric", None) {
        Some(list) => {
            let mut kinds = Vec::new();
            for s in list.split(',') {
                kinds.push(FabricKind::parse(s.trim())?);
            }
            Some(kinds)
        }
        None => None,
    };
    let positive_bw = |flag: &str| -> CliResult<f64> {
        match a.value(flag, None) {
            Some(v) => {
                let bw: f64 = v.parse()?;
                if !(bw > 0.0 && bw.is_finite()) {
                    return fail(format!(
                        "{flag} must be a positive bytes/cycle figure, got {v}"
                    ));
                }
                Ok(bw)
            }
            None => Ok(DEFAULT_LINK_BW),
        }
    };
    let link_bw = positive_bw("--link-bw")?;
    let fabric_dram_bw = positive_bw("--dram-bw")?;
    if fabric_kinds.is_none()
        && (a.value("--link-bw", None).is_some() || a.value("--dram-bw", None).is_some())
    {
        return fail(
            "--link-bw/--dram-bw provision the fabric study; pass --fabric to enable it"
                .to_string(),
        );
    }
    let budgets: Vec<u64> = match a.value("--budgets", None) {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse::<u64>())
            .collect::<std::result::Result<_, _>>()?,
        None => PE_SWEEP.to_vec(),
    };
    for &pe in &budgets {
        if pe < NODE_PES {
            return fail(format!("PE budget {pe} is below one {NODE_DIM}x{NODE_DIM} node"));
        }
        if isqrt(pe) * isqrt(pe) != pe {
            return fail(format!(
                "PE budget {pe} is not a perfect square (the scale-up side is one √P x √P array)"
            ));
        }
        if pe % NODE_PES != 0 {
            return fail(format!(
                "PE budget {pe} is not a multiple of {NODE_PES} (the scale-out side is whole \
                 {NODE_DIM}x{NODE_DIM} nodes; a remainder would bias the comparison)"
            ));
        }
    }

    let mut specs = a.values("--topology", Some("-t"))?;
    specs.extend(a.values("--workload", None)?);
    let topos: Vec<Topology> = if specs.is_empty() {
        vec![load_topology("alphagozero")?, load_topology("ncf")?]
    } else {
        specs.iter().map(|s| load_topology(s)).collect::<CliResult<_>>()?
    };

    let engine = Engine::builder().config(cfg).build()?;
    let t0 = Instant::now();
    let mut points = Vec::new();
    for topo in &topos {
        for &pe in &budgets {
            let comparison = engine.compare_scaling_with(&topo.layers, pe, partition);
            let mc = MultiArrayConfig::new(pe / NODE_PES, NODE_DIM, NODE_DIM, partition);
            let m = engine.run_multi(topo, &mc);
            points.push(ScaleoutPoint {
                workload: topo.name.clone(),
                partition,
                comparison,
                interconnect_avg_bw: m.avg_interconnect_bw(),
                interconnect_peak_bw: m.peak_interconnect_bw(),
            });
        }
    }
    print!("{}", scaleout_summary(&points));
    let stats = engine.cache_stats();
    println!(
        "scaleout: {} points in {:.1} ms — {} layer sims, {} cache hits ({:.1}% hit rate)",
        points.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        stats.layer_sims,
        stats.cache_hits,
        stats.hit_rate() * 100.0,
    );

    let bench = a.value("--bench", None).unwrap_or("BENCH_scaleout.json");
    let json = Json::obj(vec![
        ("partition", Json::str(partition.name())),
        ("node_dim", Json::u64(NODE_DIM)),
        ("budgets", Json::Arr(budgets.iter().map(|&b| Json::u64(b)).collect())),
        ("workloads", Json::u64(topos.len() as u64)),
        ("layer_sims", Json::u64(stats.layer_sims)),
        ("cache_hits", Json::u64(stats.cache_hits)),
        (
            "points",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        Json::obj(vec![
                            ("workload", Json::str(&p.workload)),
                            ("partition", Json::str(p.partition.name())),
                            ("pe_budget", Json::u64(p.comparison.pe_budget)),
                            ("nodes", Json::u64(p.comparison.nodes)),
                            ("up_cycles", Json::u64(p.comparison.up_cycles)),
                            ("out_cycles", Json::u64(p.comparison.out_cycles)),
                            ("runtime_ratio", Json::f64(p.comparison.runtime_ratio())),
                            ("weight_bw_ratio", Json::f64(p.comparison.weight_bw_ratio())),
                            ("interconnect_avg_bw", Json::f64(p.interconnect_avg_bw)),
                            ("interconnect_peak_bw", Json::f64(p.interconnect_peak_bw)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(bench, format!("{json}\n"))?;
    println!("wrote {bench}");

    if let Some(kinds) = fabric_kinds {
        let base_cfg = engine.cfg().clone();
        let mut fpoints = Vec::new();
        for topo in &topos {
            for &pe in &budgets {
                let mc = MultiArrayConfig::new(pe / NODE_PES, NODE_DIM, NODE_DIM, partition);
                for &kind in &kinds {
                    let opts = MultiOpts {
                        shared_dram_bw: Some(fabric_dram_bw),
                        fabric: (kind != FabricKind::Flat)
                            .then(|| FabricConfig::new(kind, link_bw)),
                        dram: (kind != FabricKind::Flat).then(DramConfig::default),
                    };
                    let m = engine.run_multi_opts(&base_cfg, topo, &mc, &opts);
                    let mut hop_bytes = 0u64;
                    let mut max_peak = 0.0f64;
                    let mut max_avg = 0.0f64;
                    let (mut dram_reqs, mut dram_hits) = (0u64, 0u64);
                    for l in &m.layers {
                        if let Some(f) = &l.fabric {
                            hop_bytes += f.hop_bytes;
                            max_peak = max_peak.max(f.max_link_peak_bw());
                            max_avg = max_avg.max(f.max_link_avg_bw());
                            if let Some(d) = &f.dram {
                                dram_reqs += d.requests;
                                dram_hits += d.row_hits;
                            }
                        }
                    }
                    fpoints.push(Json::obj(vec![
                        ("workload", Json::str(&m.workload)),
                        ("fabric", Json::str(kind.name())),
                        ("nodes", Json::u64(mc.nodes)),
                        ("cycles", Json::u64(m.total_cycles())),
                        ("stall_cycles", Json::u64(m.total_stall_cycles())),
                        ("hop_bytes", Json::u64(hop_bytes)),
                        ("max_link_peak_bw", Json::f64(max_peak)),
                        ("max_link_avg_bw", Json::f64(max_avg)),
                        (
                            "dram_row_hit_rate",
                            Json::f64(if dram_reqs == 0 {
                                0.0
                            } else {
                                dram_hits as f64 / dram_reqs as f64
                            }),
                        ),
                    ]));
                }
            }
        }
        let fjson = Json::obj(vec![
            ("partition", Json::str(partition.name())),
            ("link_bw", Json::f64(link_bw)),
            ("dram_bw", Json::f64(fabric_dram_bw)),
            (
                "fabrics",
                Json::Arr(kinds.iter().map(|k| Json::str(k.name())).collect()),
            ),
            ("points", Json::Arr(fpoints)),
        ]);
        std::fs::write("BENCH_fabric.json", format!("{fjson}\n"))?;
        println!("wrote BENCH_fabric.json");
    }
    Ok(())
}

fn cmd_dse(rest: &[String]) -> CliResult<()> {
    use scale_sim::dse::{self, Campaign, Exec, RunOpts};
    use scale_sim::report::dse_summary;

    let action = rest
        .first()
        .map(String::as_str)
        .ok_or("dse needs an action: run|resume|report")?;
    let a = Args(&rest[1..]);
    let state_dir = a.value("--state-dir", None).map(PathBuf::from);
    let bench_path = a.value("--bench", None).unwrap_or("BENCH_dse.json").to_string();

    if action == "report" {
        let dir = state_dir.ok_or("dse report needs --state-dir")?;
        let out = dse::report_campaign(&dir)?;
        print!("{}", dse_summary(&out));
        if let Some(path) = a.value("--trace-out", None) {
            let backend = match a.value("--backend", None) {
                Some(b) => BackendKind::parse(b)?,
                None => BackendKind::Analytical,
            };
            dse_trace_out(path, &out, backend)?;
        }
        return Ok(());
    }

    let mut opts = RunOpts::default();
    opts.state_dir = state_dir;
    if let Some(n) = a.value("--max-points", None) {
        opts.max_points = Some(n.parse()?);
    }
    if let Some(b) = a.value("--backend", None) {
        opts.backend = BackendKind::parse(b)?;
    }
    if let Some(addr) = a.value("--serve", None) {
        let shards: usize = a.value("--shards", None).unwrap_or("4").parse()?;
        opts.exec = Exec::Serve { addr: addr.to_string(), shards };
    } else if let Some(t) = a.value("--threads", None) {
        opts.exec = Exec::Local { threads: t.parse()? };
    }

    let out = match action {
        "run" => {
            let campaign = match a.value("--spec", None) {
                Some(p) => {
                    let text = std::fs::read_to_string(p)
                        .map_err(|e| format!("cannot read spec {p}: {e}"))?;
                    Campaign::from_json(&Json::parse(text.trim())?)?
                }
                None if a.flag("--scaleout") => Campaign::paper_scaleout(),
                None => Campaign::paper(),
            };
            dse::run_campaign(campaign, &opts)?
        }
        "resume" => {
            let dir = opts
                .state_dir
                .clone()
                .ok_or("dse resume needs --state-dir")?;
            dse::resume_campaign(&dir, &opts)?
        }
        other => return fail(format!("unknown dse action {other:?} (run|resume|report)")),
    };

    if out.is_complete() {
        print!("{}", dse_summary(&out));
        println!(
            "dse: {} points ({} run, {} restored) in {:.1} ms — {} layer sims, {} cache hits ({:.1}% hit rate)",
            out.completed.len(),
            out.ran,
            out.restored,
            out.stats.wall.as_secs_f64() * 1e3,
            out.stats.memo.layer_sims,
            out.stats.memo.cache_hits,
            out.stats.hit_rate() * 100.0,
        );
        out.write_bench_json(Path::new(&bench_path))?;
        println!("wrote {bench_path}");
    } else {
        let hint = match &opts.state_dir {
            Some(d) => format!("continue with `scale-sim dse resume --state-dir {}`", d.display()),
            None => "points are lost without --state-dir".into(),
        };
        println!(
            "dse: campaign incomplete — {}/{} points journaled ({} run this invocation); {hint}",
            out.completed.len(),
            out.campaign.len(),
            out.ran,
        );
    }
    if let Some(path) = a.value("--trace-out", None) {
        dse_trace_out(path, &out, opts.backend)?;
    }
    Ok(())
}

/// `dse --trace-out`: re-simulate the runtime-vs-energy frontier points
/// (cache-warm after a local campaign) and export their cycle timelines,
/// one `pid` track per frontier point.
fn dse_trace_out(
    path: &str,
    out: &scale_sim::dse::CampaignOutcome,
    backend: BackendKind,
) -> CliResult<()> {
    let topos = out.campaign.resolve_workloads(false)?;
    let engine = Engine::builder().backend(backend).threads(1).build()?;
    let mut t = trace::Trace::new();
    let mut skipped = 0usize;
    for (track, &pos) in out.frontier_runtime_energy.iter().enumerate() {
        let p = &out.completed[pos].point;
        // composed multi-array reports have no single-array span
        // decomposition — scale-out frontier points stay tabular
        if p.nodes > 1 {
            skipped += 1;
            continue;
        }
        let track = track as u64;
        let cfg = p.config(engine.cfg());
        let report = engine.run_topology_with(&cfg, &topos[&p.workload]);
        t.name_process(
            track,
            format!(
                "#{} {} {} {}x{} bw{}",
                p.index,
                p.workload,
                p.dataflow.name(),
                p.array_h,
                p.array_w,
                p.dram_bw
            ),
        );
        let mut cursor = 0u64;
        for l in &report.layers {
            let stall = if p.dram_bw.is_finite() && p.dram_bw > 0.0 {
                scale_sim::memory::stall::stalled_runtime(cfg.dataflow, &l.layer, &cfg, p.dram_bw)
                    .stall_cycles
            } else {
                0
            };
            cursor =
                trace::layer_spans(&mut t, track, cursor, cfg.dataflow, cfg.array_h, cfg.array_w, l, stall);
        }
    }
    if skipped > 0 {
        println!("trace: skipped {skipped} multi-array frontier point(s)");
    }
    t.write(Path::new(path))?;
    println!("wrote {path} ({} spans)", t.spans.len());
    Ok(())
}

fn cmd_analyze(rest: &[String]) -> CliResult<()> {
    use scale_sim::memory::stall::provision_bandwidth;
    use scale_sim::trace::bank_analysis;

    let a = Args(rest);
    let cfg = base_config(&a)?;
    let topo = load_topology(a.value("--topology", Some("-t")).unwrap_or("resnet50"))?;
    let engine = Engine::builder().config(cfg).build()?;
    let cfg = engine.cfg();

    println!(
        "analyze {} on {}x{} (banks/provision under {}; dataflow column is the per-layer winner)",
        topo.name, cfg.array_h, cfg.array_w, cfg.dataflow
    );
    let flex = engine.flexible_study(&topo);
    println!(
        "{:<18} {:>6} {:>13} {:>13} {:>12} {:>10}",
        "layer", "best", "best_cycles", "operand_banks", "ofmap_banks", "prov_B/cyc"
    );
    for (layer, fl) in topo.layers.iter().zip(&flex.layers) {
        let banks = bank_analysis(cfg.dataflow, layer, cfg);
        let prov = provision_bandwidth(cfg.dataflow, layer, cfg, 0.05);
        println!(
            "{:<18} {:>6} {:>13} {:>13} {:>12} {:>10.1}",
            layer.name,
            fl.best.name(),
            fl.cycles[fl.best as usize],
            banks.operand_banks,
            banks.ofmap_banks,
            prov
        );
    }
    println!(
        "flexible-dataflow speedup: {:.3}x over best fixed, {:.3}x over worst fixed (wins os/ws/is: {:?})",
        flex.speedup_over_best_fixed(),
        flex.speedup_over_worst_fixed(),
        flex.wins()
    );
    Ok(())
}

fn cmd_validate(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);

    // workload-validation mode: parse + lower + validate each spec
    // (-t accepted as the same alias run/sweep use)
    let mut specs = a.values("--topology", Some("-t"))?;
    specs.extend(a.values("--workload", None)?);
    if !specs.is_empty() {
        for spec in specs {
            let w = load_workload(spec)?;
            let topo = w.lower()?; // lowering validates every op and tile
            let gemm_tiles = topo.layers.iter().filter(|l| l.is_gemm()).count();
            println!(
                "{spec}: OK — {} ops -> {} tiles ({} GEMM-encoded), {} MACs",
                w.nodes.len(),
                topo.layers.len(),
                gemm_tiles,
                topo.total_macs()
            );
        }
        return Ok(());
    }

    let max: usize = a.value("--max", None).unwrap_or("32").parse()?;
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>6}",
        "size", "rtl_cycles", "trace_cycles", "model_cycles", "match"
    );
    let mut n = 4u64;
    while n as usize <= max {
        let layer = LayerShape::gemm("mm", n, n, n);
        let mut cycles = Vec::new();
        for kind in BackendKind::ALL {
            let engine = Engine::builder()
                .dataflow(Dataflow::Os)
                .array(n, n)
                .backend(kind)
                .build()?;
            cycles.push(engine.run_layer(&layer).timing.cycles);
        }
        let (model, trace, rtl) = (cycles[0], cycles[1], cycles[2]);
        let ok = model == trace && trace == rtl;
        println!("{:>6} {:>12} {:>12} {:>12} {:>6}", n, rtl, trace, model, ok);
        if !ok {
            return fail(format!("validation mismatch at {n}: rtl={rtl} trace={trace} model={model}"));
        }
        n *= 2;
    }
    println!("validation OK (cycle-exact across all engine backends, Fig 4)");
    Ok(())
}

fn cmd_workloads() -> CliResult<()> {
    println!("{:<4} {:<14} {:>7} {:>16}", "tag", "name", "layers", "MACs");
    for (tag, name) in workloads::TAGS {
        let t = workloads::builtin(name).unwrap();
        println!("{:<4} {:<14} {:>7} {:>16}", tag, name, t.layers.len(), t.total_macs());
    }
    for w in workloads::gemm_suite() {
        let t = w.lower()?;
        println!("{:<4} {:<14} {:>7} {:>16}", "G", w.name, t.layers.len(), t.total_macs());
    }
    Ok(())
}

fn cmd_artifacts() -> CliResult<()> {
    let dir = default_artifact_dir();
    let rt = Runtime::new(&dir)?;
    println!("runtime platform: {}", rt.platform());
    println!("artifact dir:     {dir:?}");
    let names = rt.available();
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
    }
    for n in names {
        println!("  {n}");
    }
    Ok(())
}

fn cmd_lint(rest: &[String]) -> CliResult<()> {
    use scale_sim::analysis::{self, Baseline};

    let a = Args(rest);
    let root = PathBuf::from(a.value("--root", None).unwrap_or("."));
    let baseline_path = a
        .value("--baseline", None)
        .map(PathBuf::from)
        .unwrap_or_else(|| analysis::default_baseline_path(&root));

    let format = a.value("--format", None).unwrap_or("text");
    if !matches!(format, "text" | "json") {
        return fail(format!("unknown --format `{format}` (expected text or json)"));
    }

    let findings = analysis::lint_root(&root)?;
    let files = analysis::source_count(&root)?;

    if a.flag("--write-baseline") {
        // regenerating keeps the recorded ratchet floor, so a rewrite
        // can never loosen the "strictly below pre-PR" invariant
        let floor = analysis::load_baseline(&baseline_path)
            .ok()
            .and_then(|b| b.pre_pr_violations);
        let mut b = Baseline::from_findings(&findings);
        b.pre_pr_violations = floor;
        b.validate()?;
        std::fs::write(&baseline_path, b.render())?;
        println!(
            "wrote {} ({} finding(s) across {} entries)",
            baseline_path.display(),
            b.total(),
            b.counts.len()
        );
        return Ok(());
    }

    if format == "json" {
        // stdout carries exactly the JSON document (byte-deterministic);
        // drift diagnostics below still decide the exit code
        print!("{}", scale_sim::analysis::report::findings_to_json(&findings));
    } else if a.flag("--list") {
        print!("{}", scale_sim::analysis::report::render_findings(&findings));
    }

    let baseline =
        if a.flag("--no-baseline") { Baseline::default() } else { analysis::load_baseline(&baseline_path)? };
    let drift = baseline.check(&findings);
    if drift.is_empty() {
        if format != "json" {
            println!(
                "{}",
                scale_sim::analysis::report::summary(files, findings.len(), baseline.total())
            );
        }
        return Ok(());
    }
    let drift_text = scale_sim::analysis::report::render_drift(&drift, &findings);
    if format == "json" {
        // keep stdout pure JSON; diagnostics go to stderr
        eprint!("{drift_text}");
    } else {
        print!("{drift_text}");
    }
    fail(format!(
        "lint failed: {} drift(s) against {}",
        drift.len(),
        if a.flag("--no-baseline") { "an empty baseline (--no-baseline)".to_string() } else { baseline_path.display().to_string() }
    ))
}

const DEFAULT_SERVE_ADDR: &str = "127.0.0.1:7433";

fn cmd_serve(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    let mut opts = ServeOpts { cfg: base_config(&a)?, ..ServeOpts::default() };
    opts.addr = a.value("--addr", None).unwrap_or(DEFAULT_SERVE_ADDR).to_string();
    if let Some(w) = a.value("--workers", None) {
        opts.workers = w.parse()?;
    }
    if let Some(q) = a.value("--queue-cap", None) {
        opts.queue_cap = q.parse()?;
    }
    if let Some(d) = a.value("--state-dir", None) {
        opts.state_dir = Some(PathBuf::from(d));
    }
    if let Some(b) = a.value("--backend", None) {
        opts.backend = BackendKind::parse(b)?;
    }
    if let Some(p) = a.value("--peers", None) {
        opts.peers = p.split(',').map(str::to_string).collect();
    }
    if let Some(n) = a.value("--cache-stripes", None) {
        opts.cache_stripes = Some(n.parse()?);
    }

    let workers = opts.workers;
    let persistent = opts.state_dir.is_some();
    let peer_count = opts.peers.len();
    let handle = server::start(opts)?;
    let warm = handle.stats().warm.entries;
    println!(
        "scale-sim serve: {workers} workers, {} state, {warm} warm entries",
        if persistent { "persistent" } else { "in-memory" }
    );
    if peer_count > 0 {
        println!("federated: {peer_count} peer(s) on the consistent-hash ring");
    }
    println!("listening on {}", handle.addr());
    handle.join(); // until a client sends {"req":"shutdown"}
    println!("server stopped (queue drained, store flushed)");
    Ok(())
}

fn cmd_client(rest: &[String]) -> CliResult<()> {
    let action = rest
        .first()
        .map(String::as_str)
        .ok_or("client needs an action: run|sweep|batch|stats|metrics|shutdown")?;
    let a = Args(&rest[1..]);
    let addr = a.value("--addr", None).unwrap_or(DEFAULT_SERVE_ADDR);

    // metrics prints the Prometheus text raw (scrape-ready), not as a
    // JSON event line like the other actions
    if action == "metrics" {
        let mut client = server::Client::connect(addr)
            .map_err(|e| format!("cannot reach server at {addr}: {e}"))?;
        print!("{}", client.metrics()?);
        return Ok(());
    }

    let req = match action {
        "stats" => r#"{"req":"stats"}"#.to_string(),
        "shutdown" => r#"{"req":"shutdown"}"#.to_string(),
        "run" | "sweep" => {
            let mut fields = vec![("req", Json::str(action)), ("id", Json::u64(1))];
            if action == "sweep" {
                fields.push(("kind", Json::str(a.value("--kind", None).unwrap_or("dataflow"))));
            }
            let topo_spec = a.value("--topology", Some("-t"));
            if let Some(spec) = topo_spec.or((action == "run").then_some("resnet50")) {
                // resolve locally (built-in name or csv path) and send the
                // layers inline, so the server needs no file access
                let topo = load_topology(spec)?;
                fields.push(("workload", Json::str(&topo.name)));
                fields.push((
                    "layers",
                    Json::Arr(topo.layers.iter().map(proto::layer_shape_to_json).collect()),
                ));
            }
            if let Some(df) = a.value("--dataflow", None) {
                fields.push(("dataflow", Json::str(df)));
            }
            if let Some(arr) = a.value("--array", None) {
                fields.push(("array", Json::str(arr)));
            }
            if let Some(n) = a.value("--nodes", None) {
                fields.push(("nodes", Json::u64(n.parse()?)));
            }
            if let Some(p) = a.value("--partition", None) {
                fields.push(("partition", Json::str(p)));
            }
            Json::obj(fields).to_string()
        }
        "batch" => {
            let specs = a.values("--workload", Some("-t"))?;
            if specs.is_empty() {
                return fail("client batch needs at least one -t/--workload".to_string());
            }
            let mut jobs = Vec::with_capacity(specs.len());
            for (i, spec) in specs.iter().enumerate() {
                let topo = load_topology(spec)?;
                let mut fields = vec![
                    ("req", Json::str("run")),
                    ("id", Json::u64(i as u64 + 1)),
                    ("workload", Json::str(&topo.name)),
                    (
                        "layers",
                        Json::Arr(topo.layers.iter().map(proto::layer_shape_to_json).collect()),
                    ),
                ];
                if let Some(df) = a.value("--dataflow", None) {
                    fields.push(("dataflow", Json::str(df)));
                }
                if let Some(arr) = a.value("--array", None) {
                    fields.push(("array", Json::str(arr)));
                }
                jobs.push(Json::obj(fields));
            }
            Json::obj(vec![
                ("req", Json::str("batch")),
                ("id", Json::u64(0)),
                ("jobs", Json::Arr(jobs)),
            ])
            .to_string()
        }
        other => {
            return fail(format!(
                "unknown client action {other:?} (run|sweep|batch|stats|metrics|shutdown)"
            ))
        }
    };

    let mut client = server::Client::connect(addr)
        .map_err(|e| format!("cannot reach server at {addr}: {e}"))?;
    // a batch envelope interleaves sub-job streams and only ends at
    // batch_done, so it needs the envelope-aware collector
    let events =
        if action == "batch" { client.request_batch(&req)? } else { client.request(&req)? };
    for e in &events {
        println!("{e}");
    }
    // for single jobs only the last event can be an error; in a batch
    // any sub-job error (or a whole-envelope rejection) fails the call
    let err_ev = if action == "batch" {
        events.iter().find(|e| e.str_field("event") == Some("error"))
    } else {
        events.last().filter(|e| e.str_field("event") == Some("error"))
    };
    if let Some(e) = err_ev {
        return fail(format!(
            "server rejected the job: {}",
            e.str_field("error").unwrap_or("?")
        ));
    }
    Ok(())
}

fn cmd_bench_serve(rest: &[String]) -> CliResult<()> {
    let a = Args(rest);
    let clients: usize = a.value("--clients", None).unwrap_or("8").parse()?;
    let rounds: usize = a.value("--rounds", None).unwrap_or("2").parse()?;
    let workers: usize = match a.value("--workers", None) {
        Some(w) => w.parse()?,
        None => sweep::default_threads(),
    };
    let user_state_dir = a.value("--state-dir", None).is_some();
    let state_dir = match a.value("--state-dir", None) {
        Some(d) => PathBuf::from(d),
        None => std::env::temp_dir().join(format!("scale_sim_bench_serve_{}", std::process::id())),
    };
    // phase 1 must be genuinely cold so BENCH_serve.json measures the
    // cross-client scenario — but never destroy a user-owned snapshot
    if state_dir.join("results.jsonl").exists() {
        if user_state_dir {
            return fail(format!(
                "{} already holds results.jsonl; bench-serve phase 1 must start cold — \
                 pass a fresh --state-dir or remove the snapshot first",
                state_dir.display()
            ));
        }
        let _ = std::fs::remove_file(state_dir.join("results.jsonl"));
    }

    let opts = || ServeOpts {
        workers,
        state_dir: Some(state_dir.clone()),
        ..ServeOpts::default()
    };
    let suite: Vec<&str> = workloads::TAGS.iter().map(|(_, name)| *name).collect();
    // mixed load: every client replays the run suite and adds one
    // dataflow sweep per round (a different workload per client), so
    // the server sees heavy grid jobs interleaved with short runs
    let jobs_expected = clients * rounds * (suite.len() + 1);
    println!(
        "bench-serve phase 1 (cold): {clients} clients x {rounds} rounds x {} runs + 1 sweep on {workers} workers",
        suite.len()
    );

    // ---- phase 1: cold start, concurrent closed-loop clients
    let handle = server::start(opts())?;
    let addr = handle.addr();
    let t0 = Instant::now();
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(jobs_expected);
    let mut dropped = 0u64;
    let mut shed = 0u64;
    std::thread::scope(|s| {
        let suite = &suite;
        let handles: Vec<_> = (0..clients)
            .map(|ci| {
                s.spawn(move || -> (Vec<f64>, u64, u64) {
                    let mut lat = Vec::new();
                    let mut bad = 0u64;
                    let mut retries = 0u64;
                    let mut c = server::Client::connect(addr).expect("bench client connect");
                    for round in 0..rounds {
                        let sweep_wl = suite[ci % suite.len()];
                        let mut reqs: Vec<String> = Vec::with_capacity(suite.len() + 1);
                        for (wi, name) in suite.iter().enumerate() {
                            let id = (ci * 10_000 + round * 100 + wi) as u64;
                            reqs.push(
                                Json::obj(vec![
                                    ("req", Json::str("run")),
                                    ("id", Json::u64(id)),
                                    ("workload", Json::str(*name)),
                                ])
                                .to_string(),
                            );
                        }
                        reqs.push(
                            Json::obj(vec![
                                ("req", Json::str("sweep")),
                                ("id", Json::u64((ci * 10_000 + round * 100 + 99) as u64)),
                                ("kind", Json::str("dataflow")),
                                ("workload", Json::str(sweep_wl)),
                            ])
                            .to_string(),
                        );
                        for req in &reqs {
                            let t = Instant::now();
                            // the bounded queue sheds with a terminal
                            // `busy` under overload — a closed-loop
                            // client backs off and resubmits
                            loop {
                                match c.request(req) {
                                    Ok(events)
                                        if events.last().is_some_and(|e| {
                                            e.str_field("event") == Some("busy")
                                        }) =>
                                    {
                                        retries += 1;
                                        std::thread::sleep(Duration::from_millis(5));
                                    }
                                    Ok(events)
                                        if events.last().is_some_and(|e| {
                                            e.str_field("event") == Some("done")
                                        }) =>
                                    {
                                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                                        break;
                                    }
                                    _ => {
                                        bad += 1;
                                        break;
                                    }
                                }
                            }
                        }
                    }
                    (lat, bad, retries)
                })
            })
            .collect();
        for h in handles {
            let (lat, bad, retries) = h.join().expect("bench client thread");
            latencies_ms.extend(lat);
            dropped += bad;
            shed += retries;
        }
    });
    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
    let cold = handle.stats();
    handle.shutdown(); // drains + flushes the result store

    // ---- phase 2: restart from the state dir; one suite replay must be warm
    let handle = server::start(opts())?;
    let warm_loaded = handle.stats().warm.entries;
    let mut c = server::Client::connect(handle.addr())?;
    for (i, name) in suite.iter().enumerate() {
        let req = Json::obj(vec![
            ("req", Json::str("run")),
            ("id", Json::u64(i as u64)),
            ("workload", Json::str(*name)),
        ])
        .to_string();
        let events = c.request(&req)?;
        if !events.last().is_some_and(|e| e.str_field("event") == Some("done")) {
            return fail(format!("warm replay of {name} did not complete"));
        }
    }
    let warm = handle.stats();
    handle.shutdown();

    let p50 = percentile(&latencies_ms, 50.0);
    let p99 = percentile(&latencies_ms, 99.0);
    let throughput = latencies_ms.len() as f64 / (wall_ms / 1e3);
    println!(
        "phase 1: {}/{jobs_expected} jobs ok ({dropped} dropped, {shed} busy retries) in {wall_ms:.1} ms — {throughput:.1} jobs/s, p50 {p50:.2} ms, p99 {p99:.2} ms",
        latencies_ms.len()
    );
    println!(
        "         cache: {} sims, {} hits ({:.1}% cross-client hit rate), {} entries",
        cold.memo.layer_sims,
        cold.memo.cache_hits,
        cold.memo.hit_rate() * 100.0,
        cold.cache_entries
    );
    println!(
        "phase 2: restart loaded {warm_loaded} warm entries; suite replay hit {} warm entries, {} new sims",
        warm.warm.hits, warm.memo.layer_sims
    );

    write_json(
        Path::new("BENCH_serve.json"),
        &[
            ("clients", clients as f64),
            ("workers", workers as f64),
            ("jobs", latencies_ms.len() as f64),
            ("dropped", dropped as f64),
            ("busy_retries", shed as f64),
            ("wall_ms", wall_ms),
            ("throughput_jobs_per_s", throughput),
            ("p50_ms", p50),
            ("p99_ms", p99),
            ("layer_sims", cold.memo.layer_sims as f64),
            ("cache_hits", cold.memo.cache_hits as f64),
            ("cache_hit_rate", cold.memo.hit_rate()),
            ("warm_entries", warm_loaded as f64),
            ("warm_hits", warm.warm.hits as f64),
        ],
    )?;
    println!("wrote BENCH_serve.json");
    if !user_state_dir {
        let _ = std::fs::remove_dir_all(&state_dir);
    }
    if dropped > 0 {
        return fail(format!("{dropped} jobs dropped"));
    }
    check_serve_baseline(&a, throughput, p99)
}

/// Gate BENCH_serve numbers against the checked-in baseline: fail on a
/// >20% throughput drop or a >2x p99 regression; bless (or a missing
/// baseline on the first run) records the current numbers as the floor.
fn check_serve_baseline(a: &Args, throughput: f64, p99: f64) -> CliResult<()> {
    let baseline_path =
        PathBuf::from(a.value("--baseline", None).unwrap_or("BENCH_serve.baseline.json"));
    if a.flag("--bless") || !baseline_path.exists() {
        write_json(&baseline_path, &[("throughput_jobs_per_s", throughput), ("p99_ms", p99)])?;
        println!(
            "blessed {} (throughput {throughput:.1} jobs/s, p99 {p99:.2} ms)",
            baseline_path.display()
        );
        return Ok(());
    }
    let text = std::fs::read_to_string(&baseline_path)?;
    let j = Json::parse(&text)
        .map_err(|e| format!("{}: {e}", baseline_path.display()))?;
    let base_tp = j
        .f64_field("throughput_jobs_per_s")
        .ok_or_else(|| format!("{}: missing throughput_jobs_per_s", baseline_path.display()))?;
    let base_p99 = j
        .f64_field("p99_ms")
        .ok_or_else(|| format!("{}: missing p99_ms", baseline_path.display()))?;
    println!(
        "baseline {}: throughput {base_tp:.1} jobs/s, p99 {base_p99:.2} ms",
        baseline_path.display()
    );
    if throughput < 0.8 * base_tp {
        return fail(format!(
            "bench-serve regression: throughput {throughput:.1} jobs/s < 80% of baseline {base_tp:.1} \
             (re-bless deliberately with --bless)"
        ));
    }
    if base_p99 > 0.0 && p99 > 2.0 * base_p99 {
        return fail(format!(
            "bench-serve regression: p99 {p99:.2} ms > 2x baseline {base_p99:.2} ms \
             (re-bless deliberately with --bless)"
        ));
    }
    println!("baseline gate ok");
    Ok(())
}
