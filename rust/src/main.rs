//! `scale-sim` CLI — the leader entrypoint (Fig 1): config + topology in,
//! traces + summary reports out, plus sweep / validate / artifact
//! subcommands. Argument parsing is hand-rolled (clap is unavailable in
//! the offline build).

use std::path::PathBuf;
use std::process::ExitCode;

use scale_sim::config::{workloads, ArchConfig, Topology};
use scale_sim::coordinator::{run, RunSpec};
use scale_sim::dataflow::Dataflow;
use scale_sim::runtime::{default_artifact_dir, Runtime};
use scale_sim::util::fmt_bytes;
use scale_sim::{rtl, sweep, LayerShape};

const USAGE: &str = "\
scale-sim — systolic CNN accelerator simulator (SCALE-Sim reproduction)

USAGE:
  scale-sim run [-c cfg] [-t topology] [-o outdir] [--dataflow os|ws|is]
                [--array RxC] [--dump-traces] [--functional TILE]
                [--threads N]
      Simulate a topology (built-in name like `resnet50`/`W5`, or a csv
      path). Writes compute/sram/dram/energy reports when -o is given.

  scale-sim sweep <dataflow|memory|shape> [-t topology]...
      Reproduce the paper's design-space sweeps on the MLPerf suite
      (Figs 5-8 series printed as tables).

  scale-sim validate [--max N]
      Fig 4: run the cycle-level RTL array against the analytical model
      on array-sized matmuls and report both cycle counts.

  scale-sim analyze [-t topology] [--array RxC] [--dataflow os|ws|is]
      Deep-dive one workload: per-layer SRAM bank requirement (§IV-B),
      best dataflow per layer (flexible-dataflow study), and the DRAM
      bandwidth to provision for <5%% slowdown (§III-D stall model).

  scale-sim workloads
      List the built-in MLPerf workloads (Table III).

  scale-sim artifacts
      Show PJRT platform and the AOT artifacts available for the
      functional path.
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    match args.first().map(String::as_str) {
        Some("run") => cmd_run(&args[1..]),
        Some("sweep") => cmd_sweep(&args[1..]),
        Some("analyze") => cmd_analyze(&args[1..]),
        Some("validate") => cmd_validate(&args[1..]),
        Some("workloads") => cmd_workloads(),
        Some("artifacts") => cmd_artifacts(),
        Some("-h") | Some("--help") | None => {
            print!("{USAGE}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command {other:?}\n{USAGE}"),
    }
}

/// Tiny flag parser: returns value for `--name V` / `-n V`.
struct Args<'a>(&'a [String]);

impl<'a> Args<'a> {
    fn value(&self, long: &str, short: Option<&str>) -> Option<&'a str> {
        let mut it = self.0.iter();
        while let Some(a) = it.next() {
            if a == long || short.is_some_and(|s| a == s) {
                return it.next().map(String::as_str);
            }
        }
        None
    }

    fn flag(&self, long: &str) -> bool {
        self.0.iter().any(|a| a == long)
    }
}

fn load_topology(spec: &str) -> anyhow::Result<Topology> {
    if let Some(t) = workloads::builtin(spec) {
        return Ok(t);
    }
    Ok(Topology::from_file(&PathBuf::from(spec))?)
}

fn cmd_run(rest: &[String]) -> anyhow::Result<()> {
    let a = Args(rest);
    let mut cfg = match a.value("--config", Some("-c")) {
        Some(p) => ArchConfig::from_file(&PathBuf::from(p))?,
        None => ArchConfig::default(),
    };
    if let Some(df) = a.value("--dataflow", None) {
        cfg.dataflow = Dataflow::parse(df)?;
    }
    if let Some(arr) = a.value("--array", None) {
        let (r, c) = arr
            .split_once('x')
            .ok_or_else(|| anyhow::anyhow!("--array expects RxC, e.g. 32x32"))?;
        cfg.array_h = r.parse()?;
        cfg.array_w = c.parse()?;
    }
    let topo = match a.value("--topology", Some("-t")) {
        Some(t) => load_topology(t)?,
        None => match &cfg.topology_path {
            Some(p) => Topology::from_file(p)?,
            None => anyhow::bail!("no topology: pass -t or set Topology in the cfg"),
        },
    };

    let mut spec = RunSpec::new(cfg, topo);
    spec.out_dir = a.value("--out", Some("-o")).map(PathBuf::from);
    spec.dump_traces = a.flag("--dump-traces");
    if let Some(t) = a.value("--functional", None) {
        spec.functional_tile = Some(t.parse()?);
    }
    if let Some(t) = a.value("--threads", None) {
        spec.threads = t.parse()?;
    }

    let out = run(&spec)?;
    let r = &out.report;
    println!("workload {:>14}  dataflow {}  array {}x{}", r.workload, spec.cfg.dataflow, spec.cfg.array_h, spec.cfg.array_w);
    println!(
        "{:<18} {:>12} {:>8} {:>14} {:>12} {:>10}",
        "layer", "cycles", "util%", "dram_bytes", "avg_rd_bw", "energy_mJ"
    );
    for l in &r.layers {
        println!(
            "{:<18} {:>12} {:>8.2} {:>14} {:>12.4} {:>10.4}",
            l.name(),
            l.timing.cycles,
            l.timing.utilization * 100.0,
            l.dram.total(),
            l.bandwidth.avg_read_bw,
            l.energy.total_mj(),
        );
    }
    println!(
        "TOTAL: {} cycles, {:.2}% util, {} DRAM, {:.4} mJ",
        r.total_cycles(),
        r.overall_utilization(spec.cfg.total_pes()) * 100.0,
        fmt_bytes(r.total_dram().total()),
        r.total_energy().total_mj()
    );
    for (layer, err) in &out.functional {
        println!("functional[{layer}]: max rel err {err:.2e} (PJRT artifact vs reference)");
    }
    if !out.files_written.is_empty() {
        println!("wrote {} files under {:?}", out.files_written.len(), spec.out_dir.unwrap());
    }
    Ok(())
}

fn cmd_sweep(rest: &[String]) -> anyhow::Result<()> {
    let a = Args(rest);
    let kind = rest.first().map(String::as_str).unwrap_or("dataflow");
    let base = ArchConfig::default();
    let topos: Vec<Topology> = match a.value("--topology", Some("-t")) {
        Some(t) => vec![load_topology(t)?],
        None => workloads::mlperf_suite(),
    };
    let threads = sweep::default_threads();
    match kind {
        "dataflow" => {
            let pts = sweep::dataflow_sweep(&base, &topos, &[128, 64, 32, 16, 8], threads);
            println!("{:<14} {:>4} {:>6} {:>14} {:>8} {:>12} {:>12}", "workload", "df", "array", "cycles", "util%", "E_comp_mJ", "E_mem_mJ");
            for p in pts {
                println!(
                    "{:<14} {:>4} {:>6} {:>14} {:>8.2} {:>12.4} {:>12.4}",
                    p.workload, p.dataflow.name(), p.array, p.cycles, p.utilization * 100.0,
                    p.energy_compute_mj, p.energy_memory_mj
                );
            }
        }
        "memory" => {
            let sizes = [32, 64, 128, 256, 512, 1024, 2048];
            let pts = sweep::memory_sweep(&base, &topos, &sizes, threads);
            println!("{:<14} {:>8} {:>14} {:>12}", "workload", "sram_kb", "dram_bytes", "avg_rd_bw");
            for p in pts {
                println!("{:<14} {:>8} {:>14} {:>12.4}", p.workload, p.sram_kb, p.dram_bytes, p.avg_read_bw);
            }
        }
        "shape" => {
            let pts = sweep::shape_sweep(&base, &topos, &sweep::fig8_shapes(), threads);
            println!("{:<14} {:>4} {:>10} {:>14}", "workload", "df", "shape", "cycles");
            for p in pts {
                println!("{:<14} {:>4} {:>10} {:>14}", p.workload, p.dataflow.name(), format!("{}x{}", p.rows, p.cols), p.cycles);
            }
        }
        other => anyhow::bail!("unknown sweep {other:?} (dataflow|memory|shape)"),
    }
    Ok(())
}

fn cmd_analyze(rest: &[String]) -> anyhow::Result<()> {
    use scale_sim::memory::stall::provision_bandwidth;
    use scale_sim::sim::flex::flexible_study;
    use scale_sim::trace::bank_analysis;

    let a = Args(rest);
    let mut cfg = ArchConfig::default();
    if let Some(df) = a.value("--dataflow", None) {
        cfg.dataflow = Dataflow::parse(df)?;
    }
    if let Some(arr) = a.value("--array", None) {
        let (r, c) = arr.split_once('x').ok_or_else(|| anyhow::anyhow!("--array RxC"))?;
        cfg.array_h = r.parse()?;
        cfg.array_w = c.parse()?;
    }
    let topo = load_topology(a.value("--topology", Some("-t")).unwrap_or("resnet50"))?;

    println!(
        "analyze {} on {}x{} (banks/provision under {}; dataflow column is the per-layer winner)",
        topo.name, cfg.array_h, cfg.array_w, cfg.dataflow
    );
    let flex = flexible_study(&cfg, &topo);
    println!(
        "{:<18} {:>6} {:>13} {:>13} {:>12} {:>10}",
        "layer", "best", "best_cycles", "operand_banks", "ofmap_banks", "prov_B/cyc"
    );
    for (layer, fl) in topo.layers.iter().zip(&flex.layers) {
        let banks = bank_analysis(cfg.dataflow, layer, &cfg);
        let prov = provision_bandwidth(cfg.dataflow, layer, &cfg, 0.05);
        println!(
            "{:<18} {:>6} {:>13} {:>13} {:>12} {:>10.1}",
            layer.name,
            fl.best.name(),
            fl.cycles[fl.best as usize],
            banks.operand_banks,
            banks.ofmap_banks,
            prov
        );
    }
    println!(
        "flexible-dataflow speedup: {:.3}x over best fixed, {:.3}x over worst fixed (wins os/ws/is: {:?})",
        flex.speedup_over_best_fixed(),
        flex.speedup_over_worst_fixed(),
        flex.wins()
    );
    Ok(())
}

fn cmd_validate(rest: &[String]) -> anyhow::Result<()> {
    let a = Args(rest);
    let max: usize = a.value("--max", None).unwrap_or("32").parse()?;
    println!("{:>6} {:>12} {:>12} {:>6}", "size", "rtl_cycles", "model_cycles", "match");
    let mut n = 4usize;
    while n <= max {
        let (x, y) = rtl::random_matrices(n, n, n, n as u64);
        let r = rtl::run_matmul(&x, &y, n, n, n);
        let layer = LayerShape::gemm("mm", n as u64, n as u64, n as u64);
        let model = Dataflow::Os.timing(&layer, n as u64, n as u64).cycles;
        println!("{:>6} {:>12} {:>12} {:>6}", n, r.cycles, model, r.cycles == model);
        anyhow::ensure!(r.cycles == model, "validation mismatch at {n}");
        n *= 2;
    }
    println!("validation OK (cycle-exact, Fig 4)");
    Ok(())
}

fn cmd_workloads() -> anyhow::Result<()> {
    println!("{:<4} {:<14} {:>7} {:>16}", "tag", "name", "layers", "MACs");
    for (tag, name) in workloads::TAGS {
        let t = workloads::builtin(name).unwrap();
        println!("{:<4} {:<14} {:>7} {:>16}", tag, name, t.layers.len(), t.total_macs());
    }
    Ok(())
}

fn cmd_artifacts() -> anyhow::Result<()> {
    let dir = default_artifact_dir();
    let rt = Runtime::new(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifact dir:  {dir:?}");
    let names = rt.available();
    if names.is_empty() {
        println!("no artifacts found — run `make artifacts`");
    }
    for n in names {
        println!("  {n}");
    }
    Ok(())
}
